"""Model zoo: composable architecture definitions over repro.nn."""
from .blocks import ModelConfig
from .model import ModelBundle, build_model

__all__ = ["ModelConfig", "ModelBundle", "build_model"]
