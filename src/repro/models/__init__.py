"""Model zoo: composable architecture definitions over repro.nn, plus the
paper's Section-5 experiment models."""
from .blocks import ModelConfig
from .model import ModelBundle, build_model
from .paper import mlp_init, mlp_loss

__all__ = ["ModelConfig", "ModelBundle", "build_model", "mlp_init",
           "mlp_loss"]
