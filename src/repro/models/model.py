"""Model assembly: stacked-layer scans + the ModelBundle public API.

A ModelBundle packages everything the launcher/optimizer need:

    init(key)                  -> (params, specs)   pure pytrees
    forward(params, batch)     -> logits            (train / eval)
    loss(params, batch)        -> scalar            (next-token CE)
    prefill(params, batch)     -> (logits, cache)
    init_cache(batch, S, ...)  -> cache pytree      (decode)
    decode_step(params, cache, tokens, pos) -> (logits, cache)

Layer stacks are initialized with vmap (stacked leading L axis) and applied
with lax.scan (+ optional jax.checkpoint), so compile time and HLO size do
not grow with depth -- essential for the 512-device dry-runs on one CPU.

Batch dict formats:
    dense/moe/rwkv6/hybrid : {"tokens": (B, S)}
    vlm                    : {"tokens": (B, S - n_prefix),
                              "patches": (B, n_prefix, frontend_dim)}
    encdec                 : {"frames": (B, S_enc, frontend_dim),
                              "tokens": (B, S_dec)}
The modality frontends (SigLIP / conv audio codec) are stubs by assignment:
``patches``/``frames`` arrive as precomputed embeddings.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.nn.module import (Px, cross_entropy_loss, dense, embedding,
                             init_dense, init_embedding, init_rmsnorm,
                             init_layernorm, layernorm, rmsnorm, split_tree,
                             stack_inits)
from . import blocks as B
from .blocks import ModelConfig

__all__ = ["ModelConfig", "ModelBundle", "build_model"]


@dataclasses.dataclass
class ModelBundle:
    cfg: ModelConfig
    init: Callable
    forward: Callable
    loss: Callable
    prefill: Callable
    init_cache: Callable
    decode_step: Callable

    def init_params(self, key):
        return self.init(key)


def _norm(cfg):
    return (rmsnorm if cfg.norm == "rmsnorm" else layernorm)


def _init_norm(cfg):
    return (init_rmsnorm if cfg.norm == "rmsnorm" else init_layernorm)


def _maybe_remat(fn, cfg: ModelConfig):
    if not cfg.remat:
        return fn
    if cfg.remat_policy is None:
        return jax.checkpoint(fn)
    if cfg.remat_policy == "dots":
        return jax.checkpoint(
            fn, policy=jax.checkpoint_policies.dots_saveable)
    raise ValueError(f"unknown remat_policy {cfg.remat_policy!r}; "
                     "have None, 'dots'")


def _positions(b, s, offset=0):
    return jnp.broadcast_to(jnp.arange(s, dtype=jnp.int32) + offset, (b, s))


def _logits(cfg: ModelConfig, params, x):
    if cfg.tie_embeddings:
        table = params["embed"]["table"]
        return x @ table.T.astype(x.dtype)
    return dense(params["head"], x)


def _lm_loss(logits, tokens, mask=None):
    return cross_entropy_loss(logits[:, :-1], tokens[:, 1:],
                              None if mask is None else mask[:, 1:])


# production tensor-parallel axis size; specs fall back to sharding the
# d_model axis when a dimension is not divisible (e.g. vocab 73448, 256206)
MODEL_AXIS_SIZE = 16


def _init_common(cfg: ModelConfig, key):
    ks = jax.random.split(key, 4)
    vocab_ok = cfg.vocab % MODEL_AXIS_SIZE == 0
    emb_spec = ("model", None) if vocab_ok else (None, "model")
    p = {"embed": init_embedding(ks[0], cfg.vocab, cfg.d_model, emb_spec),
         "final_norm": _init_norm(cfg)(ks[1], cfg.d_model)}
    if not cfg.tie_embeddings:
        head_spec = (None, "model") if vocab_ok else ("model", None)
        p["head"] = init_dense(ks[2], cfg.d_model, cfg.vocab, head_spec)
    return p, ks[3]


# ===========================================================================
# dense / moe decoder (also the vlm text stack)
# ===========================================================================

def _build_decoder(cfg: ModelConfig) -> ModelBundle:
    is_vlm = cfg.family == "vlm"

    def init(key):
        p, k = _init_common(cfg, key)
        k1, k2 = jax.random.split(k)
        p["layers"] = stack_inits(
            lambda kk: B.init_decoder_layer(kk, cfg), k1, cfg.n_layers)
        if is_vlm:
            p["projector"] = init_dense(k2, cfg.frontend_dim, cfg.d_model,
                                        (None, None))
        return split_tree(p)

    def _embed_inputs(params, batch):
        tokens = batch["tokens"]
        x = embedding(params["embed"], tokens, cfg.dtype)
        prefix_len = 0
        if is_vlm:
            patches = dense(params["projector"],
                            batch["patches"].astype(cfg.dtype))
            x = jnp.concatenate([patches, x], axis=1)
            prefix_len = cfg.n_prefix
        return x, prefix_len

    def _run_layers(params, x, positions, prefix_len, collect_cache,
                    window="cfg"):
        mode = "prefix" if is_vlm else "causal"

        def body(carry, layer_p):
            h, aux = carry
            h, cache, a = B.decoder_layer_seq(
                layer_p, cfg, h, positions, mode, prefix_len,
                collect_cache=collect_cache, cache_dtype=cfg.dtype,
                window=window)
            return (h, aux + a), cache

        (x, aux), caches = jax.lax.scan(
            _maybe_remat(body, cfg), (x, jnp.zeros((), jnp.float32)),
            params["layers"])
        return x, caches, aux

    def forward(params, batch):
        x, prefix_len = _embed_inputs(params, batch)
        pos = _positions(*x.shape[:2])
        x, _, _ = _run_layers(params, x, pos, prefix_len, False)
        x = _norm(cfg)(params["final_norm"], x)
        return _logits(cfg, params, x)

    def loss(params, batch):
        x, prefix_len = _embed_inputs(params, batch)
        pos = _positions(*x.shape[:2])
        x, _, aux = _run_layers(params, x, pos, prefix_len, False)
        x = _norm(cfg)(params["final_norm"], x)
        if is_vlm:  # only text positions predict
            x = x[:, cfg.n_prefix:]
        logits = _logits(cfg, params, x)
        return _lm_loss(logits, batch["tokens"]) + 0.01 * aux / max(cfg.n_layers, 1)

    def prefill(params, batch, window="cfg"):
        x, prefix_len = _embed_inputs(params, batch)
        pos = _positions(*x.shape[:2])
        x, caches, _ = _run_layers(params, x, pos, prefix_len, True,
                                   window=window)
        x = _norm(cfg)(params["final_norm"], x[:, -1:])
        return _logits(cfg, params, x), caches

    def init_cache(batch, cache_len, dtype=jnp.bfloat16, window="cfg",
                   enc_len=None):
        del enc_len
        one = B.init_decoder_cache(cfg, batch, cache_len, dtype, window)
        return jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (cfg.n_layers,) + l.shape), one)

    def decode_step(params, cache, tokens, pos, window="cfg"):
        x = embedding(params["embed"], tokens, cfg.dtype)  # (B,1,D)

        def body(h, scanned):
            layer_p, cache_l = scanned
            h, new_cache = B.decoder_layer_decode(layer_p, cfg, h, cache_l,
                                                  pos, window=window)
            return h, new_cache

        x, new_caches = jax.lax.scan(body, x, (params["layers"], cache))
        x = _norm(cfg)(params["final_norm"], x)
        return _logits(cfg, params, x)[:, 0], new_caches

    return ModelBundle(cfg, init, forward, loss, prefill, init_cache,
                       decode_step)


# ===========================================================================
# RWKV6 (attention-free; cache = recurrent state)
# ===========================================================================

def _build_rwkv(cfg: ModelConfig) -> ModelBundle:
    from repro.nn import ssm as S

    def init(key):
        p, k = _init_common(cfg, key)
        p["layers"] = stack_inits(
            lambda kk: B.init_rwkv_layer(kk, cfg), k, cfg.n_layers)
        return split_tree(p)

    def _run(params, x, states):
        def body(h, scanned):
            layer_p, st = scanned
            h, new_st = B.rwkv_layer_seq(layer_p, cfg, h, st)
            return h, new_st

        x, new_states = jax.lax.scan(_maybe_remat(body, cfg), x,
                                     (params["layers"], states))
        return x, new_states

    def init_cache(batch, cache_len=0, dtype=jnp.float32, window=None,
                   enc_len=None):
        del cache_len, window, enc_len
        one = S.init_rwkv6_state(batch, cfg.rwkv_cfg(), dtype)
        return jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (cfg.n_layers,) + l.shape), one)

    def forward(params, batch):
        tokens = batch["tokens"]
        x = embedding(params["embed"], tokens, cfg.dtype)
        states = init_cache(tokens.shape[0])
        x, _ = _run(params, x, states)
        x = _norm(cfg)(params["final_norm"], x)
        return _logits(cfg, params, x)

    def loss(params, batch):
        return _lm_loss(forward(params, batch), batch["tokens"])

    def prefill(params, batch):
        tokens = batch["tokens"]
        x = embedding(params["embed"], tokens, cfg.dtype)
        states = init_cache(tokens.shape[0])
        x, new_states = _run(params, x, states)
        x = _norm(cfg)(params["final_norm"], x[:, -1:])
        return _logits(cfg, params, x), new_states

    def decode_step(params, cache, tokens, pos, window=None):
        del pos, window  # recurrent state carries position implicitly
        x = embedding(params["embed"], tokens, cfg.dtype)

        def body(h, scanned):
            layer_p, st = scanned
            h, new_st = B.rwkv_layer_decode(layer_p, cfg, h, st)
            return h, new_st

        x, new_states = jax.lax.scan(body, x, (params["layers"], cache))
        x = _norm(cfg)(params["final_norm"], x)
        return _logits(cfg, params, x)[:, 0], new_states

    return ModelBundle(cfg, init, forward, loss, prefill, init_cache,
                       decode_step)


# ===========================================================================
# Hybrid: mamba2 backbone + one shared attention block every `attn_every`
# layers (zamba2).  Group scan: G groups of g mamba layers + shared attn;
# remainder mamba layers run in a trailing scan.
# ===========================================================================

def _build_hybrid(cfg: ModelConfig) -> ModelBundle:
    from repro.nn import ssm as S

    g = cfg.attn_every
    n_groups = cfg.n_layers // g
    rem = cfg.n_layers - n_groups * g

    def init(key):
        p, k = _init_common(cfg, key)
        k1, k2 = jax.random.split(k)
        p["mamba"] = stack_inits(
            lambda kk: B.init_mamba_layer(kk, cfg), k1, cfg.n_layers)
        p["shared_attn"] = B.init_decoder_layer(
            k2, dataclasses.replace(cfg, n_experts=0, mla=False))
        return split_tree(p)

    def _reshape_groups(tree):
        head = jax.tree_util.tree_map(
            lambda l: l[: n_groups * g].reshape((n_groups, g) + l.shape[1:]),
            tree)
        tail = jax.tree_util.tree_map(lambda l: l[n_groups * g:], tree)
        return head, tail

    def _mamba_scan(layers, states, x, decode=False):
        apply = B.mamba_layer_decode if decode else B.mamba_layer_seq

        def body(h, scanned):
            layer_p, st = scanned
            h, new_st = apply(layer_p, cfg, h, st)
            return h, new_st

        return jax.lax.scan(body, x, (layers, states))

    def _run(params, x, mamba_states, positions, attn_ctx, decode=False,
             window="cfg"):
        """attn_ctx: None (fresh fwd), caches (G,...) for decode, or
        'collect' to gather prefill caches."""
        head_p, tail_p = _reshape_groups(params["mamba"])
        head_s, tail_s = _reshape_groups(mamba_states)
        shared = params["shared_attn"]
        acfg = dataclasses.replace(cfg, n_experts=0, mla=False)
        collect = attn_ctx == "collect"

        def group_body(h, scanned):
            if decode:
                layer_p, st, cache_g = scanned
            else:
                layer_p, st = scanned
            h, new_st = _mamba_scan(layer_p, st, h, decode)
            if decode:
                h, new_cache = B.decoder_layer_decode(shared, acfg, h,
                                                      cache_g, positions,
                                                      window=window)
                return h, (new_st, new_cache)
            h, cache, _ = B.decoder_layer_seq(
                shared, acfg, h, positions, collect_cache=collect,
                cache_dtype=cfg.dtype, window=window)
            return h, (new_st, cache) if collect else (new_st, 0)

        scanned = (head_p, head_s)
        if decode:
            scanned = (head_p, head_s, attn_ctx)
        x, (new_head_s, attn_out) = jax.lax.scan(
            _maybe_remat(group_body, cfg) if not decode else group_body,
            x, scanned)
        if rem:
            x, new_tail_s = _mamba_scan(tail_p, tail_s, x, decode)
        else:
            new_tail_s = tail_s
        new_states = jax.tree_util.tree_map(
            lambda hd, tl: jnp.concatenate(
                [hd.reshape((n_groups * g,) + hd.shape[2:]), tl], axis=0),
            new_head_s, new_tail_s)
        return x, new_states, attn_out

    def _mamba_cache(batch, dtype=jnp.float32):
        one = S.init_mamba2_state(batch, cfg.mamba_cfg(), dtype)
        return jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (cfg.n_layers,) + l.shape), one)

    def init_cache(batch, cache_len, dtype=jnp.bfloat16, window="cfg",
                   enc_len=None):
        del enc_len
        acfg = dataclasses.replace(cfg, n_experts=0, mla=False)
        attn_one = B.init_decoder_cache(acfg, batch, cache_len, dtype, window)
        attn = jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (n_groups,) + l.shape), attn_one)
        return {"mamba": _mamba_cache(batch), "attn": attn}

    def forward(params, batch):
        tokens = batch["tokens"]
        x = embedding(params["embed"], tokens, cfg.dtype)
        pos = _positions(*tokens.shape[:2])
        x, _, _ = _run(params, x, _mamba_cache(tokens.shape[0]), pos, None)
        x = _norm(cfg)(params["final_norm"], x)
        return _logits(cfg, params, x)

    def loss(params, batch):
        return _lm_loss(forward(params, batch), batch["tokens"])

    def prefill(params, batch, window="cfg"):
        tokens = batch["tokens"]
        x = embedding(params["embed"], tokens, cfg.dtype)
        pos = _positions(*tokens.shape[:2])
        x, new_states, attn_caches = _run(
            params, x, _mamba_cache(tokens.shape[0]), pos, "collect",
            window=window)
        x = _norm(cfg)(params["final_norm"], x[:, -1:])
        return (_logits(cfg, params, x),
                {"mamba": new_states, "attn": attn_caches})

    def decode_step(params, cache, tokens, pos, window="cfg"):
        x = embedding(params["embed"], tokens, cfg.dtype)
        x, new_states, new_attn = _run(params, x, cache["mamba"], pos,
                                       cache["attn"], decode=True,
                                       window=window)
        x = _norm(cfg)(params["final_norm"], x)
        return (_logits(cfg, params, x)[:, 0],
                {"mamba": new_states, "attn": new_attn})

    return ModelBundle(cfg, init, forward, loss, prefill, init_cache,
                       decode_step)


# ===========================================================================
# Encoder-decoder (seamless-m4t): audio frames -> encoder; text decoder with
# cross-attention.
# ===========================================================================

def _build_encdec(cfg: ModelConfig) -> ModelBundle:

    def init(key):
        p, k = _init_common(cfg, key)
        k1, k2, k3 = jax.random.split(k, 3)
        p["adapter"] = init_dense(k1, cfg.frontend_dim, cfg.d_model,
                                  (None, None))
        p["enc_layers"] = stack_inits(
            lambda kk: B.init_encoder_layer(kk, cfg), k2, cfg.n_enc_layers)
        p["dec_layers"] = stack_inits(
            lambda kk: B.init_xattn_decoder_layer(kk, cfg), k3, cfg.n_layers)
        return split_tree(p)

    def _encode(params, frames):
        x = dense(params["adapter"], frames.astype(cfg.dtype))
        pos = _positions(*x.shape[:2])

        def body(h, layer_p):
            return B.encoder_layer_seq(layer_p, cfg, h, pos), None

        x, _ = jax.lax.scan(_maybe_remat(body, cfg), x, params["enc_layers"])
        return x

    def _decode_seq(params, tokens, enc_out, collect_cache=False):
        x = embedding(params["embed"], tokens, cfg.dtype)
        pos = _positions(*tokens.shape[:2])

        def body(h, layer_p):
            h, cache = B.xattn_decoder_layer_seq(
                layer_p, cfg, h, pos, enc_out, collect_cache=collect_cache,
                cache_dtype=cfg.dtype)
            return h, cache

        x, caches = jax.lax.scan(_maybe_remat(body, cfg), x,
                                 params["dec_layers"])
        return x, caches

    def forward(params, batch):
        enc_out = _encode(params, batch["frames"])
        x, _ = _decode_seq(params, batch["tokens"], enc_out)
        x = _norm(cfg)(params["final_norm"], x)
        return _logits(cfg, params, x)

    def loss(params, batch):
        return _lm_loss(forward(params, batch), batch["tokens"])

    def prefill(params, batch):
        enc_out = _encode(params, batch["frames"])
        x, caches = _decode_seq(params, batch["tokens"], enc_out,
                                collect_cache=True)
        x = _norm(cfg)(params["final_norm"], x[:, -1:])
        return _logits(cfg, params, x), caches

    def init_cache(batch, cache_len, dtype=jnp.bfloat16, window=None,
                   enc_len=None):
        del window
        enc_len = enc_len or cache_len
        one = B.init_xattn_cache(cfg, batch, cache_len, enc_len, dtype)
        return jax.tree_util.tree_map(
            lambda l: jnp.broadcast_to(l, (cfg.n_layers,) + l.shape), one)

    def decode_step(params, cache, tokens, pos, window=None):
        del window
        x = embedding(params["embed"], tokens, cfg.dtype)

        def body(h, scanned):
            layer_p, cache_l = scanned
            h, new_cache = B.xattn_decoder_layer_decode(layer_p, cfg, h,
                                                        cache_l, pos)
            return h, new_cache

        x, new_caches = jax.lax.scan(body, x, (params["dec_layers"], cache))
        x = _norm(cfg)(params["final_norm"], x)
        return _logits(cfg, params, x)[:, 0], new_caches

    return ModelBundle(cfg, init, forward, loss, prefill, init_cache,
                       decode_step)


# ===========================================================================

_BUILDERS = {
    "dense": _build_decoder,
    "moe": _build_decoder,
    "vlm": _build_decoder,
    "rwkv6": _build_rwkv,
    "hybrid": _build_hybrid,
    "encdec": _build_encdec,
}


def build_model(cfg: ModelConfig) -> ModelBundle:
    if cfg.family not in _BUILDERS:
        raise ValueError(f"unknown family {cfg.family!r}")
    return _BUILDERS[cfg.family](cfg)
