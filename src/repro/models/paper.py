"""The paper's Section-5.2 experiment model: 784 -> 64 sigmoid -> 10
softmax cross-entropy, one shared definition.

The init and loss used to be copy-pasted between ``benchmarks/common.py``
and ``examples/porter_adam_comparison.py``; both now import from here
(dimensions from :mod:`repro.configs.paper_mnist`).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.paper_mnist import CLASSES, HIDDEN, INPUT_DIM

__all__ = ["mlp_init", "mlp_loss"]


def mlp_init(key=None, scale: float = 0.05):
    """Initial parameters of the Section-5.2 MLP (zero biases, Gaussian
    weights scaled by ``scale``)."""
    key = jax.random.PRNGKey(0) if key is None else key
    k1, k2 = jax.random.split(key)
    return {"w1": scale * jax.random.normal(k1, (INPUT_DIM, HIDDEN)),
            "c1": jnp.zeros(HIDDEN),
            "w2": scale * jax.random.normal(k2, (HIDDEN, CLASSES)),
            "c2": jnp.zeros(CLASSES)}


def mlp_loss():
    """Per-agent loss ``(params, (features, labels)) -> scalar`` of the
    Section-5.2 MLP (softmax cross-entropy)."""

    def loss_fn(params, batch):
        f, l = batch
        f = jnp.atleast_2d(f)
        l = jnp.atleast_1d(l)
        h = jax.nn.sigmoid(f @ params["w1"] + params["c1"])
        logits = h @ params["w2"] + params["c2"]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - gold)

    return loss_fn
