"""Transformer-layer building blocks per architecture family.

Each family exposes:
    init_layer(key, cfg)                         -> Px param tree (one layer)
    apply_seq(p, cfg, x, positions, ...)         -> (x, cache_or_None, aux)
    apply_decode(p, cfg, x, cache, pos, ...)     -> (x, new_cache)
    init_cache(cfg, batch, cache_len, dtype)     -> cache pytree (one layer)

The model assembly (models/model.py) stacks layers with jax.vmap at init and
jax.lax.scan at apply so HLO size / compile time are depth-independent.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.nn import attention as A
from repro.nn import moe as M
from repro.nn import ssm as S
from repro.nn.module import (Px, dense, init_dense, init_rmsnorm,
                             init_layernorm, layernorm, rmsnorm)

__all__ = ["ModelConfig", "FAMILIES"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    """One architecture.  Source citations live in repro/configs/<name>.py."""

    name: str
    family: str               # dense | moe | rwkv6 | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    vocab: int
    d_ff: int = 0
    n_heads: int = 0
    n_kv_heads: int = 0
    head_dim: int = 0         # 0 -> d_model // n_heads
    activation: str = "silu"
    rotary_frac: float = 1.0  # chatglm3: 0.5
    rope_theta: float = 10000.0
    window: Optional[int] = None          # sliding-window attention
    qkv_bias: bool = False
    tie_embeddings: bool = True
    norm: str = "rmsnorm"     # rmsnorm | layernorm
    # --- MoE ---
    n_experts: int = 0
    top_k: int = 2
    dense_residual: bool = False
    capacity_factor: float = 1.25
    # --- MLA (minicpm3) ---
    mla: bool = False
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64
    # --- SSM / hybrid ---
    ssm_state: int = 64
    ssm_head_dim: int = 64
    attn_every: int = 6       # hybrid: shared attn after every k mamba layers
    # --- enc-dec / prefix frontends ---
    n_enc_layers: int = 0
    frontend: str = "none"    # none | vision | audio
    frontend_dim: int = 0     # raw embedding dim from the stub frontend
    n_prefix: int = 0         # vlm: number of patch tokens
    # --- numerics / perf ---
    dtype: Any = jnp.bfloat16
    remat: bool = True
    # jax.checkpoint policy for the per-layer remat: None = save nothing
    # (recompute everything), 'dots' = dots_saveable (keep matmul outputs,
    # recompute elementwise/norm ops -- cheaper backward at a small
    # activation-memory cost).  Ignored when remat=False.
    remat_policy: Optional[str] = None
    q_chunk: Optional[int] = None   # chunked-query attention (flash-coarse)

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    def attn_cfg(self, window: Optional[int] = "cfg") -> A.AttnConfig:
        return A.AttnConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            n_kv_heads=self.n_kv_heads, head_dim=self.hd,
            rotary_frac=self.rotary_frac, rope_theta=self.rope_theta,
            window=self.window if window == "cfg" else window,
            qkv_bias=self.qkv_bias)

    def mla_cfg(self) -> A.MLAConfig:
        return A.MLAConfig(
            d_model=self.d_model, n_heads=self.n_heads,
            q_lora_rank=self.q_lora_rank, kv_lora_rank=self.kv_lora_rank,
            qk_nope_dim=self.qk_nope_dim, qk_rope_dim=self.qk_rope_dim,
            v_head_dim=self.v_head_dim, rope_theta=self.rope_theta)

    def mlp_cfg(self) -> M.MlpConfig:
        return M.MlpConfig(self.d_model, self.d_ff, self.activation)

    def moe_cfg(self) -> M.MoeConfig:
        return M.MoeConfig(
            d_model=self.d_model, d_ff=self.d_ff, n_experts=self.n_experts,
            top_k=self.top_k, activation=self.activation,
            dense_residual=self.dense_residual,
            capacity_factor=self.capacity_factor)

    def rwkv_cfg(self) -> S.Rwkv6Config:
        return S.Rwkv6Config(d_model=self.d_model, head_dim=self.ssm_head_dim,
                             d_ff=self.d_ff)

    def mamba_cfg(self) -> S.Mamba2Config:
        return S.Mamba2Config(d_model=self.d_model, d_state=self.ssm_state,
                              head_dim=self.ssm_head_dim)


def _norm_fns(cfg: ModelConfig):
    if cfg.norm == "rmsnorm":
        return init_rmsnorm, rmsnorm
    return init_layernorm, layernorm


# ---------------------------------------------------------------------------
# dense / MLA / MoE decoder layers (attention + FFN)
# ---------------------------------------------------------------------------

def init_decoder_layer(key, cfg: ModelConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    init_n, _ = _norm_fns(cfg)
    p = {"ln1": init_n(k1, cfg.d_model), "ln2": init_n(k2, cfg.d_model)}
    if cfg.mla:
        p["attn"] = A.init_mla(k3, cfg.mla_cfg())
    else:
        p["attn"] = A.init_attention(k3, cfg.attn_cfg())
    if cfg.n_experts > 0:
        p["ffn"] = M.init_moe(k4, cfg.moe_cfg())
    else:
        p["ffn"] = M.init_mlp(k4, cfg.mlp_cfg())
    return p


def decoder_layer_seq(p, cfg: ModelConfig, x, positions, mode="causal",
                      prefix_len: int = 0, collect_cache: bool = False,
                      cache_dtype=jnp.bfloat16,
                      window: Optional[int] = "cfg"):
    _, norm = _norm_fns(cfg)
    h = norm(p["ln1"], x)
    cache = None
    if cfg.mla:
        y = A.mla_attention(p["attn"], cfg.mla_cfg(), h, positions,
                            q_chunk=cfg.q_chunk)
        if collect_cache:
            q_nope, q_rope, ckv, krope = A._mla_qkv(
                p["attn"], cfg.mla_cfg(), h, positions)
            del q_nope, q_rope
            cache = {"ckv": ckv.astype(cache_dtype),
                     "krope": krope.astype(cache_dtype)}
    else:
        acfg = cfg.attn_cfg(window)
        y = A.attention(p["attn"], acfg, h, positions, mode, prefix_len,
                        q_chunk=cfg.q_chunk)
        if collect_cache:
            k = A._split_heads(dense(p["attn"]["wk"], h), acfg.n_kv_heads,
                               acfg.head_dim)
            v = A._split_heads(dense(p["attn"]["wv"], h), acfg.n_kv_heads,
                               acfg.head_dim)
            if acfg.rotary_dim > 0:
                k = A.apply_rope(k, positions, acfg.rotary_dim,
                                 acfg.rope_theta)
            cache = {"k": k.astype(cache_dtype), "v": v.astype(cache_dtype)}
    x = x + y
    h = norm(p["ln2"], x)
    if cfg.n_experts > 0:
        y, aux = M.moe(p["ffn"], cfg.moe_cfg(), h)
    else:
        y, aux = M.mlp(p["ffn"], cfg.mlp_cfg(), h), jnp.zeros((), jnp.float32)
    return x + y, cache, aux


def decoder_layer_decode(p, cfg: ModelConfig, x, cache, pos,
                         window: Optional[int] = "cfg"):
    _, norm = _norm_fns(cfg)
    h = norm(p["ln1"], x)
    if cfg.mla:
        y, cache = A.mla_decode(p["attn"], cfg.mla_cfg(), h, cache, pos)
    else:
        y, cache = A.attention_decode(p["attn"], cfg.attn_cfg(window), h,
                                      cache, pos)
    x = x + y
    h = norm(p["ln2"], x)
    if cfg.n_experts > 0:
        y, _ = M.moe(p["ffn"], cfg.moe_cfg(), h)
    else:
        y = M.mlp(p["ffn"], cfg.mlp_cfg(), h)
    return x + y, cache


def init_decoder_cache(cfg: ModelConfig, batch: int, cache_len: int,
                       dtype=jnp.bfloat16, window: Optional[int] = "cfg"):
    if cfg.mla:
        return A.init_mla_cache(batch, cache_len, cfg.mla_cfg(), dtype)
    w = cfg.window if window == "cfg" else window
    if w is not None and w < cache_len:
        return A.init_window_cache(batch, w, cfg.attn_cfg(w), dtype)
    return A.init_full_cache(batch, cache_len, cfg.attn_cfg(w), dtype)


# ---------------------------------------------------------------------------
# RWKV6 layer (time mix + channel mix live inside rwkv6_block)
# ---------------------------------------------------------------------------

def init_rwkv_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    init_n, _ = _norm_fns(cfg)
    return {"ln": init_n(k1, cfg.d_model),
            "blk": S.init_rwkv6_block(k2, cfg.rwkv_cfg())}


def rwkv_layer_seq(p, cfg: ModelConfig, x, state=None):
    _, norm = _norm_fns(cfg)
    y, st = S.rwkv6_block(p["blk"], cfg.rwkv_cfg(), norm(p["ln"], x), state)
    return y, st


def rwkv_layer_decode(p, cfg: ModelConfig, x, state):
    _, norm = _norm_fns(cfg)
    return S.rwkv6_decode(p["blk"], cfg.rwkv_cfg(), norm(p["ln"], x), state)


# ---------------------------------------------------------------------------
# Mamba2 layer (hybrid backbone)
# ---------------------------------------------------------------------------

def init_mamba_layer(key, cfg: ModelConfig):
    k1, k2 = jax.random.split(key)
    init_n, _ = _norm_fns(cfg)
    return {"ln": init_n(k1, cfg.d_model),
            "blk": S.init_mamba2_block(k2, cfg.mamba_cfg())}


def mamba_layer_seq(p, cfg: ModelConfig, x, state=None):
    _, norm = _norm_fns(cfg)
    y, st = S.mamba2_block(p["blk"], cfg.mamba_cfg(), norm(p["ln"], x), state)
    return x + y, st


def mamba_layer_decode(p, cfg: ModelConfig, x, state):
    _, norm = _norm_fns(cfg)
    y, st = S.mamba2_decode(p["blk"], cfg.mamba_cfg(), norm(p["ln"], x), state)
    return x + y, st


# ---------------------------------------------------------------------------
# Encoder layer (seamless encoder: bidirectional self-attn + MLP)
# ---------------------------------------------------------------------------

def init_encoder_layer(key, cfg: ModelConfig):
    k1, k2, k3, k4 = jax.random.split(key, 4)
    init_n, _ = _norm_fns(cfg)
    return {"ln1": init_n(k1, cfg.d_model), "ln2": init_n(k2, cfg.d_model),
            "attn": A.init_attention(k3, cfg.attn_cfg()),
            "ffn": M.init_mlp(k4, cfg.mlp_cfg())}


def encoder_layer_seq(p, cfg: ModelConfig, x, positions):
    _, norm = _norm_fns(cfg)
    x = x + A.attention(p["attn"], cfg.attn_cfg(), norm(p["ln1"], x),
                        positions, mode="full", q_chunk=cfg.q_chunk)
    return x + M.mlp(p["ffn"], cfg.mlp_cfg(), norm(p["ln2"], x))


# ---------------------------------------------------------------------------
# Cross-attention decoder layer (seamless decoder)
# ---------------------------------------------------------------------------

def init_xattn_decoder_layer(key, cfg: ModelConfig):
    ks = jax.random.split(key, 6)
    init_n, _ = _norm_fns(cfg)
    return {
        "ln1": init_n(ks[0], cfg.d_model), "ln2": init_n(ks[1], cfg.d_model),
        "ln3": init_n(ks[2], cfg.d_model),
        "self_attn": A.init_attention(ks[3], cfg.attn_cfg()),
        "cross_attn": A.init_cross_attention(ks[4], cfg.attn_cfg()),
        "ffn": M.init_mlp(ks[5], cfg.mlp_cfg()),
    }


def xattn_decoder_layer_seq(p, cfg: ModelConfig, x, positions, enc_out,
                            collect_cache=False, cache_dtype=jnp.bfloat16):
    _, norm = _norm_fns(cfg)
    acfg = cfg.attn_cfg()
    h = norm(p["ln1"], x)
    x = x + A.attention(p["self_attn"], acfg, h, positions, mode="causal",
                        q_chunk=cfg.q_chunk)
    x = x + A.cross_attention(p["cross_attn"], acfg, norm(p["ln2"], x),
                              enc_out, q_chunk=cfg.q_chunk)
    x = x + M.mlp(p["ffn"], cfg.mlp_cfg(), norm(p["ln3"], x))
    cache = None
    if collect_cache:
        k = A._split_heads(dense(p["self_attn"]["wk"], h), acfg.n_kv_heads,
                           acfg.head_dim)
        v = A._split_heads(dense(p["self_attn"]["wv"], h), acfg.n_kv_heads,
                           acfg.head_dim)
        if acfg.rotary_dim > 0:
            k = A.apply_rope(k, positions, acfg.rotary_dim, acfg.rope_theta)
        cache = {
            "self": {"k": k.astype(cache_dtype), "v": v.astype(cache_dtype)},
            "cross": A.make_cross_cache(p["cross_attn"], acfg, enc_out,
                                        cache_dtype),
        }
    return x, cache


def xattn_decoder_layer_decode(p, cfg: ModelConfig, x, cache, pos):
    _, norm = _norm_fns(cfg)
    acfg = cfg.attn_cfg()
    y, self_cache = A.attention_decode(p["self_attn"], acfg,
                                       norm(p["ln1"], x), cache["self"], pos)
    x = x + y
    x = x + A.cross_attention_decode(p["cross_attn"], acfg,
                                     norm(p["ln2"], x), cache["cross"])
    x = x + M.mlp(p["ffn"], cfg.mlp_cfg(), norm(p["ln3"], x))
    return x, {"self": self_cache, "cross": cache["cross"]}


def init_xattn_cache(cfg: ModelConfig, batch: int, cache_len: int,
                     enc_len: int, dtype=jnp.bfloat16):
    acfg = cfg.attn_cfg()
    return {"self": A.init_full_cache(batch, cache_len, acfg, dtype),
            "cross": A.init_full_cache(batch, enc_len, acfg, dtype)}


FAMILIES = ("dense", "moe", "rwkv6", "hybrid", "encdec", "vlm")
