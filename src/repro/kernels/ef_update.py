"""Pallas TPU kernel: fused PORTER error-feedback / tracking update.

Algorithm 1 lines 11-14 perform, per agent, a chain of parameter-sized AXPYs:

    q  +=  c                       (surrogate accumulate)
    m  +=  wc                      (mixing-mirror accumulate)
    v   =  v + gamma*(m - q) + g - g_prev      (gradient track)
    x   =  x + gamma*(mx - qx) - eta*v         (parameter step)

Issued as separate jnp ops this is ~13 HBM reads + 4 writes of parameter-
sized buffers; fused it is 7 reads + 4 writes in a single pass.  On a
bandwidth-bound v5e (819 GB/s) that is the dominant cost of a PORTER step
outside the model itself, which is why this is a kernel (see EXPERIMENTS.md
§Perf for the measured effect on the memory roofline term).

This kernel fuses the V-side (``ef_track``):   q+=c; m+=wc; v = v + gamma*
(m-q) + g - gp;   the X-side (``ef_step``) is the same shape with the
gradient terms swapped for -eta*v.  ``ef_gossip`` is the two-term tail of
the same family (q+=c; m+=wc; y = y + gamma*(m-q)) and serves the
CHOCO-SGD / SoteriaFL compressed-gossip updates through the comm-round
engine (core/comm_round.py).  Tiles: (8, 1024) VPU blocks; callers feed
the flat plane layout of kernels/flatten.py so one launch covers every
(agent, leaf) pair.

Mixed precision: inputs may arrive as bf16 planes (2 B/element resident
state); every kernel upcasts to f32 *inside* the block, accumulates in f32,
and writes each output in the dtype of its corresponding state plane
(q/m/x/v/y), so an f32 master-param plane never narrows just because the EF
planes beside it are bf16.  ``out_dtype`` overrides all output dtypes at
once -- the engine requests f32 outputs and applies stochastic rounding
(kernels/sr_cast.py) on the writeback to sub-f32 buffers, keeping the EF
drift unbiased instead of round-to-nearest biased.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024
TILE = 8 * LANE


def _out_shapes(bufs, out_dtype):
    return [jax.ShapeDtypeStruct(b.shape,
                                 b.dtype if out_dtype is None else out_dtype)
            for b in bufs]


def _track_kernel(q_ref, m_ref, v_ref, c_ref, wc_ref, g_ref, gp_ref,
                  gamma_ref, q_out, m_out, v_out):
    q = q_ref[...].astype(jnp.float32) + c_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32) + wc_ref[...].astype(jnp.float32)
    gamma = gamma_ref[0]
    v = (v_ref[...].astype(jnp.float32) + gamma * (m - q)
         + g_ref[...].astype(jnp.float32) - gp_ref[...].astype(jnp.float32))
    q_out[...] = q.astype(q_out.dtype)
    m_out[...] = m.astype(m_out.dtype)
    v_out[...] = v.astype(v_out.dtype)


def ef_track(q, m, v, c, wc, g, gp, gamma, interpret: bool = False,
             out_dtype=None):
    """(q,m,v) update of Algorithm 1 lines 11-12.  All inputs (tiles, TILE)."""
    tiles = q.shape[0]
    blk = pl.BlockSpec((1, TILE), lambda i: (i, 0))
    scl = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _track_kernel,
        grid=(tiles,),
        in_specs=[blk] * 7 + [scl],
        out_specs=[blk] * 3,
        out_shape=_out_shapes((q, m, v), out_dtype),
        interpret=interpret,
    )(q, m, v, c, wc, g, gp, jnp.asarray(gamma, jnp.float32).reshape(1))


def _step_kernel(q_ref, m_ref, x_ref, c_ref, wc_ref, v_ref,
                 gamma_ref, eta_ref, q_out, m_out, x_out):
    q = q_ref[...].astype(jnp.float32) + c_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32) + wc_ref[...].astype(jnp.float32)
    x = (x_ref[...].astype(jnp.float32) + gamma_ref[0] * (m - q)
         - eta_ref[0] * v_ref[...].astype(jnp.float32))
    q_out[...] = q.astype(q_out.dtype)
    m_out[...] = m.astype(m_out.dtype)
    x_out[...] = x.astype(x_out.dtype)


def ef_step(q, m, x, c, wc, v, gamma, eta, interpret: bool = False,
            out_dtype=None):
    """(q,m,x) update of Algorithm 1 lines 13-14.  All inputs (tiles, TILE)."""
    tiles = q.shape[0]
    blk = pl.BlockSpec((1, TILE), lambda i: (i, 0))
    scl = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _step_kernel,
        grid=(tiles,),
        in_specs=[blk] * 6 + [scl, scl],
        out_specs=[blk] * 3,
        out_shape=_out_shapes((q, m, x), out_dtype),
        interpret=interpret,
    )(q, m, x, c, wc, v, jnp.asarray(gamma, jnp.float32).reshape(1),
      jnp.asarray(eta, jnp.float32).reshape(1))


def _gossip_kernel(q_ref, m_ref, y_ref, c_ref, wc_ref, gamma_ref, scale_ref,
                   q_out, m_out, y_out):
    scale = scale_ref[0]
    q = (q_ref[...].astype(jnp.float32)
         + scale * c_ref[...].astype(jnp.float32))
    m = (m_ref[...].astype(jnp.float32)
         + scale * wc_ref[...].astype(jnp.float32))
    y = y_ref[...].astype(jnp.float32) + gamma_ref[0] * (m - q)
    q_out[...] = q.astype(q_out.dtype)
    m_out[...] = m.astype(m_out.dtype)
    y_out[...] = y.astype(y_out.dtype)


def ef_gossip(q, m, y, c, wc, gamma, scale=1.0, interpret: bool = False,
              out_dtype=None):
    """(q,m,y) CHOCO/Soteria update: q += s*c; m += s*wc; y += gamma*(m-q).

    ``scale`` is 1 for CHOCO-SGD and the SoteriaFL shift stepsize alpha for
    shifted compression.  All tensor inputs (tiles, TILE).
    """
    tiles = q.shape[0]
    blk = pl.BlockSpec((1, TILE), lambda i: (i, 0))
    scl = pl.BlockSpec((1,), lambda i: (0,))
    return pl.pallas_call(
        _gossip_kernel,
        grid=(tiles,),
        in_specs=[blk] * 5 + [scl, scl],
        out_specs=[blk] * 3,
        out_shape=_out_shapes((q, m, y), out_dtype),
        interpret=interpret,
    )(q, m, y, c, wc, jnp.asarray(gamma, jnp.float32).reshape(1),
      jnp.asarray(scale, jnp.float32).reshape(1))
