"""Pallas TPU kernels: bit-packed wire buffers for the gossip payloads.

Layouts (single source of truth: :mod:`repro.core.wire_formats`):

* top-k   -- per PACK_BLOCK window, selection *and* packing in one fused
  pass: the bisection threshold from :func:`wire_formats.bisect_threshold`
  (the same routine kernels/block_topk.py zeroes with), then compaction of
  the k survivors into contiguous (bf16 value, index) segments.  TPUs have
  no VMEM scatter, so compaction is a one-hot matmul: rank each survivor by
  cumulative count (first k in index order; threshold ties beyond k drop
  deterministically) and contract the window against the (BLOCK, k)
  rank-indicator -- an MXU pass instead of a serial gather.

* qsgd    -- per-window stochastic quantization to codes in [0, levels]
  plus a sign bit, then shift/OR of ``32 // bits`` fields per uint32 word.
  The uniform noise comes in as an operand (generated from the caller's
  key) so the kernel stays deterministic given its inputs and the jnp
  reference (wire_formats.qsgd_pack_ref) is bit-comparable.

Unpack kernels invert each layout on the receiver: top-k scatters via the
transpose one-hot matmul, qsgd shifts/masks the fields back out.  All four
kernels run per (1, BLOCK) grid row like block_topk; index arithmetic stays
in f32 (positions < 2048 are exactly representable) until the final cast.

The jit'd public wrappers live in :mod:`repro.kernels.ops`
(wire_topk_pack / wire_topk_unpack / wire_qsgd_pack / wire_qsgd_unpack).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.wire_formats import (PACK_BLOCK, TOPK_VALUE_DTYPE,
                                     bisect_threshold, qsgd_bits,
                                     qsgd_elems_per_word,
                                     qsgd_words_per_window,
                                     qsgd_window_omega)

BLOCK = PACK_BLOCK


# ---------------------------------------------------------------------------
# top-k: fused select + compact
# ---------------------------------------------------------------------------

def _topk_pack_kernel(x_ref, k_ref, v_ref, i_ref, *, k: int):
    x = x_ref[...].astype(jnp.float32)                    # (1, BLOCK)
    a = jnp.abs(x)
    thresh = bisect_threshold(a, k_ref[0])                # shared selection
    keep = (a >= thresh).astype(jnp.float32)
    rank = jnp.cumsum(keep, axis=1) - 1.0                 # (1, BLOCK)
    sel = keep * (rank < k).astype(jnp.float32)           # first k, by index
    # one-hot compaction: onehot[e, r] = 1 iff element e lands in slot r
    slot = jax.lax.broadcasted_iota(jnp.float32, (BLOCK, k), 1)
    onehot = sel.reshape(BLOCK, 1) * (rank.reshape(BLOCK, 1) == slot
                                      ).astype(jnp.float32)
    v_ref[...] = jnp.dot(x, onehot,
                         preferred_element_type=jnp.float32
                         ).astype(v_ref.dtype)            # (1, k)
    pos = jax.lax.broadcasted_iota(jnp.float32, (BLOCK, k), 0)
    i_ref[...] = jnp.sum(pos * onehot, axis=0,
                         keepdims=True).astype(jnp.int32)  # (1, k)


def topk_pack(x2d: jax.Array, k: int, interpret: bool = False):
    """(blocks, BLOCK) -> (bf16 values (blocks, k), int32 indices).

    Exactly k slots per window (bisection keeps >= k; the compaction caps
    at the first k in index order).  Indices are window-local; the wire
    layer narrows them to uint16 (wire_formats.TOPK_INDEX_DTYPE).
    """
    blocks = x2d.shape[0]
    blk = pl.BlockSpec((1, BLOCK), lambda i: (i, 0))
    out = pl.BlockSpec((1, k), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_topk_pack_kernel, k=k),
        grid=(blocks,),
        in_specs=[blk, pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=(out, out),
        out_shape=(jax.ShapeDtypeStruct((blocks, k), TOPK_VALUE_DTYPE),
                   jax.ShapeDtypeStruct((blocks, k), jnp.int32)),
        interpret=interpret,
    )(x2d, jnp.full((1,), k, jnp.int32))


def _topk_unpack_kernel(v_ref, i_ref, o_ref, *, k: int):
    vals = v_ref[...].astype(jnp.float32)                 # (1, k)
    idx = i_ref[...].astype(jnp.float32)                  # (1, k)
    # transpose one-hot scatter: dense[j] = sum_r vals[r] * [idx[r] == j]
    cols = jax.lax.broadcasted_iota(jnp.float32, (k, BLOCK), 1)
    onehot = (idx.reshape(k, 1) == cols).astype(jnp.float32)
    o_ref[...] = jnp.dot(vals, onehot,
                         preferred_element_type=jnp.float32
                         ).astype(o_ref.dtype)            # (1, BLOCK)


def topk_unpack(vals: jax.Array, idx: jax.Array,
                interpret: bool = False) -> jax.Array:
    """(values (blocks, k), int32 indices) -> dense f32 (blocks, BLOCK)."""
    blocks, k = vals.shape
    blk = pl.BlockSpec((1, k), lambda i: (i, 0))
    out = pl.BlockSpec((1, BLOCK), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_topk_unpack_kernel, k=k),
        grid=(blocks,),
        in_specs=[blk, blk],
        out_specs=out,
        out_shape=jax.ShapeDtypeStruct((blocks, BLOCK), jnp.float32),
        interpret=interpret,
    )(vals, idx.astype(jnp.int32))


# ---------------------------------------------------------------------------
# qsgd: quantize + shift/OR bit-pack
# ---------------------------------------------------------------------------

def _qsgd_pack_kernel(x_ref, u_ref, w_ref, s_ref, *, levels: int):
    bits = qsgd_bits(levels)
    epw = qsgd_elems_per_word(levels)
    words = qsgd_words_per_window(levels)
    x = x_ref[...].astype(jnp.float32)                    # (1, BLOCK)
    u = u_ref[...].astype(jnp.float32)
    norm = jnp.sqrt(jnp.sum(x * x)) + 1e-30
    y = jnp.abs(x) / norm * levels
    lo = jnp.floor(y)
    code = (lo + (u < (y - lo))).astype(jnp.uint32)       # [0, levels]
    sign = (x < 0).astype(jnp.uint32)
    field = code | (sign << jnp.uint32(bits - 1))         # (1, BLOCK)
    pad = words * epw - BLOCK
    if pad:
        field = jnp.pad(field, ((0, 0), (0, pad)))
    field = field.reshape(words, epw)
    word = jnp.zeros((1, words), jnp.uint32)
    for e in range(epw):                                  # static OR chain
        word = word | (field[:, e].reshape(1, words)
                       << jnp.uint32(bits * e))
    w_ref[...] = word
    omega = qsgd_window_omega(levels)
    s_ref[...] = (norm / (levels * (1.0 + omega))
                  ).astype(jnp.float32).reshape(1, 1)


def qsgd_pack(x2d: jax.Array, noise2d: jax.Array, levels: int,
              interpret: bool = False):
    """(blocks, BLOCK) + uniform noise -> (uint32 words, f32 (blocks, 1)).

    ``noise2d``: U[0,1) per element (the stochastic-rounding draws),
    generated by the caller from its PRNG key so kernel and jnp reference
    quantize identically.
    """
    blocks = x2d.shape[0]
    words = qsgd_words_per_window(levels)
    blk = pl.BlockSpec((1, BLOCK), lambda i: (i, 0))
    return pl.pallas_call(
        functools.partial(_qsgd_pack_kernel, levels=levels),
        grid=(blocks,),
        in_specs=[blk, blk],
        out_specs=(pl.BlockSpec((1, words), lambda i: (i, 0)),
                   pl.BlockSpec((1, 1), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((blocks, words), jnp.uint32),
                   jax.ShapeDtypeStruct((blocks, 1), jnp.float32)),
        interpret=interpret,
    )(x2d, noise2d)


def _qsgd_unpack_kernel(w_ref, s_ref, o_ref, *, levels: int):
    bits = qsgd_bits(levels)
    epw = qsgd_elems_per_word(levels)
    words = w_ref.shape[-1]
    word = w_ref[...]                                     # (1, words) u32
    scale = s_ref[0, 0]
    mag_mask = jnp.uint32(2 ** (bits - 1) - 1)
    field_mask = jnp.uint32(2 ** bits - 1)
    cols = []
    for e in range(epw):
        f = (word >> jnp.uint32(bits * e)) & field_mask
        code = (f & mag_mask).astype(jnp.float32)
        sgn = 1.0 - 2.0 * (f >> jnp.uint32(bits - 1)).astype(jnp.float32)
        cols.append(sgn * code)
    vals = jnp.stack(cols, axis=2).reshape(1, words * epw)[:, :BLOCK]
    o_ref[...] = (vals * scale).astype(o_ref.dtype)


def qsgd_unpack(word: jax.Array, scale: jax.Array, levels: int,
                interpret: bool = False) -> jax.Array:
    """(uint32 (blocks, W), f32 (blocks, 1)) -> dense f32 (blocks, BLOCK)."""
    blocks, words = word.shape
    return pl.pallas_call(
        functools.partial(_qsgd_unpack_kernel, levels=levels),
        grid=(blocks,),
        in_specs=[pl.BlockSpec((1, words), lambda i: (i, 0)),
                  pl.BlockSpec((1, 1), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1, BLOCK), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((blocks, BLOCK), jnp.float32),
        interpret=interpret,
    )(word, scale)
