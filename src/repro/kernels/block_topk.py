"""Pallas TPU kernel: per-block top-k compression via vectorized bisection.

GPU top-k compressors radix-select in shared memory; TPUs have neither an
efficient in-VMEM sort nor scatter.  The TPU adaptation (see DESIGN.md §5):
for each BLOCK-sized window, find the k-th largest |x| by *bisection on the
value range* -- log2-many compare+count sweeps, each a fully vectorized VPU
pass over the block -- then zero everything below the threshold.

The bisection routine itself lives in :mod:`repro.core.wire_formats`
(:func:`bisect_threshold`) so that this dense-emulation kernel and the
bit-packed wire kernels (:mod:`repro.kernels.wire_pack`) select with one
shared pass -- selection and packing cannot drift.  BLOCK likewise aliases
``wire_formats.PACK_BLOCK``, the single source of truth for the window.

Block-local top-k is itself a valid rho = k/BLOCK compressor (Definition 3):
per-block error <= (1 - rho) * per-block energy, and energies add.  It also
matches the packed wire format (gossip 'packed' mode) which ships fixed-size
(k, values+indices) segments per block.

Ties: all elements strictly above the final threshold are kept, elements
equal to it are kept too, so the kept count can exceed k by the number of
exact ties at the threshold -- harmless for the compression contract (error
only shrinks) and vanishingly rare in float gradients.  The jnp reference
(core.compression.block_top_k) keeps exactly k; tests compare against a
tie-free oracle.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.wire_formats import (PACK_BLOCK, N_BISECT_ITERS,
                                     bisect_threshold)

BLOCK = PACK_BLOCK    # elements per selection window (16 x 128 lanes)
N_ITERS = N_BISECT_ITERS


def _block_topk_kernel(x_ref, k_ref, o_ref):
    x = x_ref[...].astype(jnp.float32)          # (1, BLOCK)
    a = jnp.abs(x)
    thresh = bisect_threshold(a, k_ref[0])       # keeps >= k elements
    o_ref[...] = jnp.where(a >= thresh, x, 0.0).astype(o_ref.dtype)


def block_topk(x2d: jax.Array, k: int, interpret: bool = False) -> jax.Array:
    """Keep ~k largest-|.| elements per BLOCK row.  x2d: (blocks, BLOCK)."""
    blocks = x2d.shape[0]
    blk = pl.BlockSpec((1, BLOCK), lambda i: (i, 0))
    return pl.pallas_call(
        _block_topk_kernel,
        grid=(blocks,),
        in_specs=[blk, pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=interpret,
    )(x2d, jnp.full((1,), k, jnp.int32))
