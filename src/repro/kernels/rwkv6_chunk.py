"""Pallas TPU kernel: RWKV6 chunked linear-attention scan.

The RWKV6 (Finch) recurrence with data-dependent per-channel decay

    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t S_{t-1} + (r_t . (u * k_t)) v_t

is the compute hot spot of the rwkv6-7b architecture (and the reason it can
run the long_500k shape).  The pure-jnp chunked form (`repro.nn.ssm`) scans
chunks with `lax.scan`, bouncing the (N,N) state through HBM every chunk.

TPU adaptation: the Pallas grid is **sequential**, so the state can live in a
VMEM scratch buffer across grid steps.  Grid = (B*H, S/C); for each (bh, c)
step the kernel:

  1. resets the scratch state from `s0` when c == 0,
  2. computes the chunk-local cumulative log-decay,
  3. does the intra-chunk causal part as (C,C) MXU matmuls with the
     factorized decays rq = r*exp(la_prev), kk = k*exp(-la)  (safe in f32
     because ssm.py clamps log w to [-5, 0) and C = 16: |la| <= 80),
  4. adds the inter-chunk contribution rq @ S and the u-bonus diagonal,
  5. updates the scratch state in place.

Outputs: o (BH, NC, C, N) and the final state (BH, N, N).

Like ssm.py, exactness vs the per-token recurrence is pinned by tests
(interpret=True on CPU).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 16  # must match repro.nn.ssm.RWKV_CHUNK


def _kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, s0_ref, o_ref, sfin_ref,
            s_scr):
    c_idx = pl.program_id(1)
    n_chunks = pl.num_programs(1)

    @pl.when(c_idx == 0)
    def _():
        s_scr[...] = s0_ref[0].astype(jnp.float32)

    r = r_ref[0, 0].astype(jnp.float32)      # (C, N)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)
    lw = lw_ref[0, 0].astype(jnp.float32)
    u = u_ref[0].astype(jnp.float32)         # (N,)

    la = jnp.cumsum(lw, axis=0)              # inclusive, chunk-local
    la_prev = la - lw
    la_end = la[-1:, :]                      # (1, N)

    rq = r * jnp.exp(la_prev)                # r_t * exp(la_{t-1})
    kk = k * jnp.exp(-la)                    # k_s * exp(-la_s)
    kend = k * jnp.exp(la_end - la)          # k_s * exp(la_C - la_s)

    s = s_scr[...]                           # (N, N)
    qk = rq @ kk.T                           # (C, C) MXU
    tri = jnp.tril(jnp.ones((qk.shape[0], qk.shape[0]), jnp.float32), k=-1)
    o_intra = (qk * tri) @ v
    bonus = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True) * v
    o_inter = rq @ s
    o_ref[0, 0] = (o_intra + o_inter + bonus).astype(o_ref.dtype)

    s_new = s * jnp.exp(la_end).T + kend.T @ v
    s_scr[...] = s_new

    @pl.when(c_idx == n_chunks - 1)
    def _():
        sfin_ref[0] = s_new.astype(sfin_ref.dtype)


def rwkv6_chunk(r, k, v, logw, u, s0, interpret: bool = False):
    """r,k,v,logw: (BH, NC, C, N); u: (BH, N); s0: (BH, N, N).

    Returns (o: (BH, NC, C, N), s_final: (BH, N, N)).
    """
    bh, nc, c, n = r.shape
    blk = pl.BlockSpec((1, 1, c, n), lambda i, j: (i, j, 0, 0))
    uspec = pl.BlockSpec((1, n), lambda i, j: (i, 0))
    sspec = pl.BlockSpec((1, n, n), lambda i, j: (i, 0, 0))
    return pl.pallas_call(
        _kernel,
        grid=(bh, nc),
        in_specs=[blk, blk, blk, blk, uspec, sspec],
        out_specs=[blk, sspec],
        out_shape=[jax.ShapeDtypeStruct(r.shape, jnp.float32),
                   jax.ShapeDtypeStruct(s0.shape, jnp.float32)],
        scratch_shapes=[pltpu.VMEM((n, n), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u, s0)
