"""Pallas TPU kernel: fused smooth clipping (+ optional DP noise add).

The paper's clipping operator (Definition 2) rescales a d-vector by
tau / (tau + ||x||_2).  On parameter-sized buffers (PORTER keeps 5-7 of them
per agent) a naive implementation is three HBM passes (square-reduce, scale,
noise-add); this kernel does it in two:

  pass 1 (``sumsq_kernel``):   per-tile partial sums of squares -> (tiles,)
  pass 2 (``scale_kernel``):   y = x * tau/(tau+norm) [+ sigma * noise]

The tiny (tiles,) partials are combined on-chip by jnp.sum between the
passes (ops.py).  Tiles are (8, 1024) float32 lanes = 32 KiB VMEM blocks --
8-sublane x 128-lane aligned for the VPU; the MXU is not involved (this is a
bandwidth-bound elementwise op).

Noise is passed in as a pre-generated buffer (jax.random on TPU is itself a
kernel; fusing threefry into Pallas is possible but out of scope -- the win
here is eliding the extra read of x, not the RNG).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024           # elements per tile row chunk (8 sublanes x 128 lanes)
TILE = 8 * LANE       # elements per grid step


def _sumsq_kernel(x_ref, out_ref):
    x = x_ref[...].astype(jnp.float32)
    out_ref[0] = jnp.sum(x * x)


def sumsq(x2d: jax.Array, interpret: bool = False) -> jax.Array:
    """Per-tile partial sums of squares.  x2d: (tiles, TILE) padded input."""
    tiles = x2d.shape[0]
    return pl.pallas_call(
        _sumsq_kernel,
        grid=(tiles,),
        in_specs=[pl.BlockSpec((1, TILE), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((1,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((tiles,), jnp.float32),
        interpret=interpret,
    )(x2d)


def _scale_kernel(x_ref, scale_ref, o_ref):
    o_ref[...] = (x_ref[...].astype(jnp.float32)
                  * scale_ref[0]).astype(o_ref.dtype)


def _scale_noise_kernel(x_ref, scale_ref, noise_ref, sigma_ref, o_ref):
    y = x_ref[...].astype(jnp.float32) * scale_ref[0]
    y = y + sigma_ref[0] * noise_ref[...].astype(jnp.float32)
    o_ref[...] = y.astype(o_ref.dtype)


def scale(x2d: jax.Array, scale_val: jax.Array, noise2d=None, sigma=None,
          interpret: bool = False) -> jax.Array:
    """y = x * scale [+ sigma * noise], tile-wise."""
    tiles = x2d.shape[0]
    blk = pl.BlockSpec((1, TILE), lambda i: (i, 0))
    scl = pl.BlockSpec((1,), lambda i: (0,))
    if noise2d is None:
        return pl.pallas_call(
            _scale_kernel,
            grid=(tiles,),
            in_specs=[blk, scl],
            out_specs=blk,
            out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
            interpret=interpret,
        )(x2d, scale_val.reshape(1))
    return pl.pallas_call(
        _scale_noise_kernel,
        grid=(tiles,),
        in_specs=[blk, scl, blk, scl],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct(x2d.shape, x2d.dtype),
        interpret=interpret,
    )(x2d, scale_val.reshape(1), noise2d, sigma.reshape(1))
