"""Pure-jnp oracles for every Pallas kernel (tests assert_allclose against
these; they are also the CPU fallback path in ops.py)."""

from __future__ import annotations

import jax
import jax.numpy as jnp


def smooth_clip_ref(x: jax.Array, tau: float, noise=None,
                    sigma: float = 0.0) -> jax.Array:
    """Definition 2 over the flattened vector, plus optional Gaussian noise."""
    nrm = jnp.linalg.norm(x.reshape(-1).astype(jnp.float32))
    y = x.astype(jnp.float32) * (tau / (tau + nrm))
    if noise is not None:
        y = y + sigma * noise.astype(jnp.float32)
    return y.astype(x.dtype)


def block_topk_ref(x2d: jax.Array, k: int) -> jax.Array:
    """Exact per-row top-k by magnitude (keeps exactly k; tie-free oracle)."""
    a = jnp.abs(x2d.astype(jnp.float32))
    _, idx = jax.lax.top_k(a, k)
    out = jnp.zeros_like(x2d)
    vals = jnp.take_along_axis(x2d, idx, axis=1)
    return jax.vmap(lambda o, i, v: o.at[i].set(v))(out, idx, vals)


def ef_track_ref(q, m, v, c, wc, g, gp, gamma):
    f = jnp.float32
    q2 = q.astype(f) + c.astype(f)
    m2 = m.astype(f) + wc.astype(f)
    v2 = v.astype(f) + gamma * (m2 - q2) + g.astype(f) - gp.astype(f)
    return q2.astype(q.dtype), m2.astype(m.dtype), v2.astype(v.dtype)


def ef_step_ref(q, m, x, c, wc, v, gamma, eta):
    f = jnp.float32
    q2 = q.astype(f) + c.astype(f)
    m2 = m.astype(f) + wc.astype(f)
    x2 = x.astype(f) + gamma * (m2 - q2) - eta * v.astype(f)
    return q2.astype(q.dtype), m2.astype(m.dtype), x2.astype(x.dtype)


def ef_gossip_ref(q, m, y, c, wc, gamma, scale=1.0):
    f = jnp.float32
    q2 = q.astype(f) + scale * c.astype(f)
    m2 = m.astype(f) + scale * wc.astype(f)
    y2 = y.astype(f) + gamma * (m2 - q2)
    return q2.astype(q.dtype), m2.astype(m.dtype), y2.astype(y.dtype)


def rwkv6_scan_ref(r, k, v, logw, u, s0):
    """Oracle: the exact per-token RWKV6 recurrence from repro.nn.ssm."""
    from repro.nn.ssm import rwkv_scan_ref
    return rwkv_scan_ref(r, k, v, logw, u, s0)
