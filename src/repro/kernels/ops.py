"""Public jit'd wrappers for the Pallas kernels.

Handles shape plumbing (flatten -> pad to tile multiples -> 2D tile grid ->
un-pad) and the interpret switch: on CPU (this container) kernels execute in
``interpret=True`` mode, which runs the kernel body in Python/XLA-CPU and is
what the allclose tests validate; on TPU the same code lowers to Mosaic.

The ef_* wrappers are additionally shard_map-safe: the comm-round engine's
per-shard plane path (:func:`repro.kernels.flatten.plane_apply`) invokes
them once *per (agent shard x model shard)* inside ``shard_map``, so they
must stay shape-polymorphic and free of global-device assumptions (no mesh
queries, no collectives) -- each call sees only its shard's plane.

Use ``repro.kernels.ops`` from the algorithm layer; never call the raw
kernels directly.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import block_topk as _bt
from . import ef_update as _ef
from . import rwkv6_chunk as _rw
from . import sr_cast as _srk
from . import ssd_chunk as _ssd
from . import smooth_clip as _sc
from . import wire_pack as _wp
from . import ref

__all__ = ["smooth_clip", "block_topk", "ef_track", "ef_step", "ef_gossip",
           "rwkv6_scan", "ssd_scan", "default_interpret",
           "sr_cast", "sr_cast_ref",
           "wire_topk_pack", "wire_topk_unpack",
           "wire_qsgd_pack", "wire_qsgd_unpack"]


def default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_2d(flat: jax.Array, tile: int):
    d = flat.shape[0]
    pad = (-d) % tile
    padded = jnp.pad(flat, (0, pad))
    return padded.reshape(-1, tile), d


@functools.partial(jax.jit, static_argnames=("tau", "sigma", "interpret"))
def smooth_clip(x: jax.Array, tau: float, noise=None, sigma: float = 0.0,
                interpret: bool | None = None) -> jax.Array:
    """Fused Clip_tau(x) (+ sigma*noise) over an arbitrary-shape array."""
    interpret = default_interpret() if interpret is None else interpret
    shape = x.shape
    x2d, d = _pad_2d(x.reshape(-1), _sc.TILE)
    partials = _sc.sumsq(x2d, interpret=interpret)
    nrm = jnp.sqrt(jnp.sum(partials))
    factor = (tau / (tau + nrm)).astype(jnp.float32)
    if noise is not None:
        n2d, _ = _pad_2d(noise.reshape(-1), _sc.TILE)
        y2d = _sc.scale(x2d, factor, n2d, jnp.asarray(sigma, jnp.float32),
                        interpret=interpret)
    else:
        y2d = _sc.scale(x2d, factor, interpret=interpret)
    return y2d.reshape(-1)[:d].reshape(shape)


@functools.partial(jax.jit, static_argnames=("frac", "interpret"))
def block_topk(x: jax.Array, frac: float,
               interpret: bool | None = None) -> jax.Array:
    """rho = frac compressor: per-2048-block magnitude top-k (kernel)."""
    interpret = default_interpret() if interpret is None else interpret
    shape = x.shape
    x2d, d = _pad_2d(x.reshape(-1), _bt.BLOCK)
    k = max(int(round(frac * _bt.BLOCK)), 1)
    y2d = _bt.block_topk(x2d, k, interpret=interpret)
    return y2d.reshape(-1)[:d].reshape(shape)


@functools.partial(jax.jit, static_argnames=("k", "interpret"))
def wire_topk_pack(rows: jax.Array, k: int, interpret: bool | None = None):
    """Fused select+pack: (nb, PACK_BLOCK) -> (bf16 vals, uint16 idx).

    One pass per window (bisection threshold + one-hot compaction); the
    indices are window-local so uint16 always suffices.  This is the wire
    payload the codec gossip executors ship (4 bytes per kept element).
    """
    interpret = default_interpret() if interpret is None else interpret
    vals, idx = _wp.topk_pack(rows, k, interpret=interpret)
    return vals, idx.astype(jnp.uint16)


@functools.partial(jax.jit, static_argnames=("interpret",))
def wire_topk_unpack(vals: jax.Array, idx: jax.Array,
                     interpret: bool | None = None) -> jax.Array:
    """Receiver side: packed segments -> dense f32 (nb, PACK_BLOCK)."""
    interpret = default_interpret() if interpret is None else interpret
    return _wp.topk_unpack(vals, idx.astype(jnp.int32), interpret=interpret)


@functools.partial(jax.jit, static_argnames=("levels", "interpret"))
def wire_qsgd_pack(rows: jax.Array, key: jax.Array, levels: int,
                   interpret: bool | None = None):
    """Per-window QSGD quantize + uint32 bit-pack: (nb, PACK_BLOCK) ->
    (uint32 words (nb, W), f32 scale (nb, 1)).  The stochastic-rounding
    noise is drawn from ``key`` outside the kernel so the jnp reference
    (core.wire_formats.qsgd_pack_ref) quantizes identically."""
    interpret = default_interpret() if interpret is None else interpret
    noise = jax.random.uniform(key, rows.shape, jnp.float32)
    return _wp.qsgd_pack(rows.astype(jnp.float32), noise, levels,
                         interpret=interpret)


@functools.partial(jax.jit, static_argnames=("levels", "interpret"))
def wire_qsgd_unpack(word: jax.Array, scale: jax.Array, levels: int,
                     interpret: bool | None = None) -> jax.Array:
    """Receiver side: bit-packed codes + scales -> dense f32 windows."""
    interpret = default_interpret() if interpret is None else interpret
    return _wp.qsgd_unpack(word, scale, levels, interpret=interpret)


def _tile_args(arrays, tile):
    flat = [a.reshape(-1) for a in arrays]
    d = flat[0].shape[0]
    out = []
    for f in flat:
        assert f.shape[0] == d, "ef kernels need same-size operands"
        x2d, _ = _pad_2d(f, tile)
        out.append(x2d)
    return out, d


@functools.partial(jax.jit, static_argnames=("interpret",))
def sr_cast(x: jax.Array, key: jax.Array,
            interpret: bool | None = None) -> jax.Array:
    """Stochastic-rounding f32 -> bf16 cast over an arbitrary-shape array.

    Random bits come from ``key`` outside the kernel, so this and
    :func:`sr_cast_ref` round bit-identically for the same key (the pattern
    wire_qsgd_pack uses for its dither noise).
    """
    interpret = default_interpret() if interpret is None else interpret
    shape = x.shape
    x2d, d = _pad_2d(x.reshape(-1).astype(jnp.float32), _srk.TILE)
    bits = jax.random.bits(key, x2d.shape, jnp.uint32)
    y2d = _srk.sr_cast(x2d, bits, interpret=interpret)
    return y2d.reshape(-1)[:d].reshape(shape)


@jax.jit
def sr_cast_ref(x: jax.Array, key: jax.Array) -> jax.Array:
    """jnp reference for :func:`sr_cast` (same pad + bits draw, no pallas)."""
    shape = x.shape
    x2d, d = _pad_2d(x.reshape(-1).astype(jnp.float32), _srk.TILE)
    bits = jax.random.bits(key, x2d.shape, jnp.uint32)
    y2d = _srk.sr_cast_ref(x2d, bits)
    return y2d.reshape(-1)[:d].reshape(shape)


@jax.jit
def sr_cast_leaf(x: jax.Array, key: jax.Array) -> jax.Array:
    """Sharding-preserving SR cast: no plane padding, bits drawn in ``x``'s
    own shape.  The ref engine's writeback uses this on whole state leaves
    -- the :func:`sr_cast` / :func:`sr_cast_ref` pair reshapes through
    padded planes, which reshards an agent-sharded leaf and puts the
    flattened buffer (and its u32 bits) on the wire.  The key folds per
    leading-axis row, so each agent row's bits derive from its own key and
    the SPMD partitioner generates them shard-locally (a single
    whole-array draw from a replicated key lowers with partitioner
    collectives on the agent mesh)."""
    if x.ndim == 0:
        bits = jax.random.bits(key, x.shape, jnp.uint32)
        return _srk.sr_cast_ref(x.astype(jnp.float32), bits)
    ks = jax.vmap(lambda i: jax.random.fold_in(key, i))(
        jnp.arange(x.shape[0]))
    bits = jax.vmap(
        lambda kk, row: jax.random.bits(kk, row.shape, jnp.uint32))(ks, x)
    return _srk.sr_cast_ref(x.astype(jnp.float32), bits)


@functools.partial(jax.jit, static_argnames=("interpret", "out_dtype"))
def ef_track(q, m, v, c, wc, g, gp, gamma, interpret: bool | None = None,
             out_dtype=None):
    """Fused Algorithm-1 lines 11-12 (q += c; m += wc; v update).

    out_dtype: force all three outputs to one dtype (the engine requests
    f32 here and stochastically rounds the writeback to bf16 buffers);
    ``None`` keeps each output in its state operand's dtype.
    """
    interpret = default_interpret() if interpret is None else interpret
    shape = q.shape
    (q2, m2, v2, c2, wc2, g2, gp2), d = _tile_args(
        (q, m, v, c, wc, g, gp), _ef.TILE)
    qo, mo, vo = _ef.ef_track(q2, m2, v2, c2, wc2, g2, gp2, gamma,
                              interpret=interpret, out_dtype=out_dtype)
    unpad = lambda a: a.reshape(-1)[:d].reshape(shape)
    return unpad(qo), unpad(mo), unpad(vo)


@functools.partial(jax.jit, static_argnames=("interpret", "out_dtype"))
def ef_step(q, m, x, c, wc, v, gamma, eta, interpret: bool | None = None,
            out_dtype=None):
    """Fused Algorithm-1 lines 13-14 (q += c; m += wc; x update)."""
    interpret = default_interpret() if interpret is None else interpret
    shape = q.shape
    (q2, m2, x2, c2, wc2, v2), d = _tile_args((q, m, x, c, wc, v), _ef.TILE)
    qo, mo, xo = _ef.ef_step(q2, m2, x2, c2, wc2, v2, gamma, eta,
                             interpret=interpret, out_dtype=out_dtype)
    unpad = lambda a: a.reshape(-1)[:d].reshape(shape)
    return unpad(qo), unpad(mo), unpad(xo)


@functools.partial(jax.jit, static_argnames=("interpret", "out_dtype"))
def ef_gossip(q, m, y, c, wc, gamma, scale=1.0, interpret: bool | None = None,
              out_dtype=None):
    """Fused CHOCO/Soteria update (q += s*c; m += s*wc; y += gamma*(m-q))."""
    interpret = default_interpret() if interpret is None else interpret
    shape = q.shape
    (q2, m2, y2, c2, wc2), d = _tile_args((q, m, y, c, wc), _ef.TILE)
    qo, mo, yo = _ef.ef_gossip(q2, m2, y2, c2, wc2, gamma, scale,
                               interpret=interpret, out_dtype=out_dtype)
    unpad = lambda a: a.reshape(-1)[:d].reshape(shape)
    return unpad(qo), unpad(mo), unpad(yo)


@functools.partial(jax.jit, static_argnames=("interpret",))
def rwkv6_scan(r, k, v, logw, u, s0, interpret: bool | None = None):
    """RWKV6 chunked linear-attention scan (kernel).

    r,k,v,logw: (B,S,H,N) with S % 16 == 0; u: (H,N); s0: (B,H,N,N).
    Returns (o: (B,S,H,N) f32, s_final: (B,H,N,N) f32).  The VMEM-resident
    state makes this the TPU-native replacement for the lax.scan chunk loop
    in repro.nn.ssm (which round-trips the state through HBM every chunk).
    """
    interpret = default_interpret() if interpret is None else interpret
    b, s_len, h, n = r.shape
    c = _rw.CHUNK
    assert s_len % c == 0, "pad sequence to a multiple of 16"
    nc = s_len // c

    def to_bh(x):
        return x.transpose(0, 2, 1, 3).reshape(b * h, nc, c, n)

    u_bh = jnp.tile(u, (b, 1))
    o, s_fin = _rw.rwkv6_chunk(to_bh(r), to_bh(k), to_bh(v), to_bh(logw),
                               u_bh, s0.reshape(b * h, n, n),
                               interpret=interpret)
    o = o.reshape(b, h, s_len, n).transpose(0, 2, 1, 3)
    return o, s_fin.reshape(b, h, n, n)


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_scan(xh, bmat, cmat, dla, h0, interpret: bool | None = None):
    """Mamba2 SSD chunked scan (kernel).

    xh: (B,S,H,P); bmat/cmat: (B,S,N); dla: (B,S,H) per-step log-decay;
    h0: (B,H,P,N).  S % 64 == 0.  Returns (y: (B,S,H,P), h_fin: (B,H,P,N)).
    """
    interpret = default_interpret() if interpret is None else interpret
    b, s_len, h, p = xh.shape
    n = bmat.shape[-1]
    c = _ssd.CHUNK
    assert s_len % c == 0, "pad sequence to a multiple of 64"
    nc = s_len // c

    xh_bh = xh.transpose(0, 2, 1, 3).reshape(b * h, nc, c, p)
    dla_bh = dla.transpose(0, 2, 1).reshape(b * h, nc, c, 1)
    bm = jnp.broadcast_to(bmat[:, None], (b, h, s_len, n)).reshape(
        b * h, nc, c, n)
    cm = jnp.broadcast_to(cmat[:, None], (b, h, s_len, n)).reshape(
        b * h, nc, c, n)
    y, h_fin = _ssd.ssd_chunk(xh_bh, bm, cm, dla_bh,
                              h0.reshape(b * h, p, n), interpret=interpret)
    y = y.reshape(b, h, s_len, p).transpose(0, 2, 1, 3)
    return y, h_fin.reshape(b, h, p, n)
