"""Pallas TPU kernels for PORTER's hot spots (interpret-validated on CPU).

smooth_clip : fused norm + rescale (+ DP noise)        -- Definition 2
block_topk  : per-block top-k via bisection select     -- Definition 3
ef_update   : fused error-feedback/tracking AXPYs      -- Algorithm 1 l.11-14
              (ef_track / ef_step for PORTER, ef_gossip for CHOCO/Soteria)
flatten     : pytree <-> padded (tiles, 8*1024) f32 planes -- the flat tile
              layout the comm-round engine feeds the ef kernels
rwkv6_chunk : RWKV6 chunked linear-attention scan with VMEM-resident state
ssd_chunk   : Mamba2 SSD chunked scan (zamba2 backbone), same state trick

ops.py are the public jit'd wrappers (interpret=True on CPU, Mosaic on TPU);
ref.py + repro.nn.ssm scan references are the oracles the tests sweep
against (shapes x dtypes, hypothesis).
"""
from . import flatten, ops, ref

__all__ = ["flatten", "ops", "ref"]
