"""Pallas TPU kernel: stochastic-rounding f32 -> bf16 cast.

The mixed-precision engine keeps its EF state planes (``q``, ``m``, ``v``)
in bf16 but accumulates every update in f32 inside the fused kernels
(:mod:`repro.kernels.ef_update`).  A round-to-nearest writeback would bias
the EF recursion: the same tiny increment rounds the same way every step,
so drift accumulates in a fixed direction and the compressed-difference
contraction (Definition 3) no longer holds in expectation.  Stochastic
rounding makes the writeback unbiased, ``E[sr(x)] = x`` within a binade:

    bf16_bits(x) = high16( bits(x) + (r & 0xFFFF) )      r ~ U[0, 2^32)

i.e. add a uniform random value strictly below the truncated mantissa cut,
then truncate -- values exactly representable in bf16 (low 16 bits zero)
never move, and anything in between rounds up with probability equal to
its fractional position between the two neighbouring bf16 values.

The random bits are drawn *outside* the kernel (``jax.random.bits`` from a
threaded key) and passed as an operand, exactly like the QSGD pack kernel's
dither noise: the pallas kernel and the pure-jnp reference then consume
identical bits, so ``sr_cast`` (interpret or compiled) and
:func:`sr_cast_ref` are bit-identical for the same key -- which is what the
parity tests pin.

Non-finite caveat: the bit-space add walks NaN payloads and can wrap a
negative NaN; the EF planes are finite by construction (clipped gradients,
bounded mixing), so the kernel does not special-case them.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANE = 1024
TILE = 8 * LANE

def _sr_body(vals, bits):
    """Shared f32->bf16 stochastic-rounding arithmetic (jnp ops only).

    Masks/shift amounts are built inside the body (not module-level
    constants): pallas_call rejects captured traced constants.
    """
    b = jax.lax.bitcast_convert_type(vals.astype(jnp.float32), jnp.uint32)
    r = bits & jnp.uint32(0xFFFF)
    hi = ((b + r) >> jnp.uint32(16)).astype(jnp.uint16)
    return jax.lax.bitcast_convert_type(hi, jnp.bfloat16)


def _sr_kernel(x_ref, r_ref, o_ref):
    o_ref[...] = _sr_body(x_ref[...], r_ref[...])


def sr_cast(x, bits, interpret: bool = False):
    """Stochastically round an f32 ``(tiles, TILE)`` plane to bf16.

    ``bits``: uint32 plane of the same shape (only the low 16 bits of each
    word are used).
    """
    if x.shape != bits.shape:
        raise ValueError(f"sr_cast shape mismatch: {x.shape} vs {bits.shape}")
    tiles = x.shape[0]
    blk = pl.BlockSpec((1, TILE), lambda i: (i, 0))
    return pl.pallas_call(
        _sr_kernel,
        grid=(tiles,),
        in_specs=[blk, blk],
        out_specs=blk,
        out_shape=jax.ShapeDtypeStruct(x.shape, jnp.bfloat16),
        interpret=interpret,
    )(x, bits)


def sr_cast_ref(x, bits):
    """jnp reference: bit-identical to :func:`sr_cast` on the same bits."""
    if x.shape != bits.shape:
        raise ValueError(f"sr_cast shape mismatch: {x.shape} vs {bits.shape}")
    return _sr_body(x, bits)
