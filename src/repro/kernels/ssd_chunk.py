"""Pallas TPU kernel: Mamba2 SSD chunked scan (zamba2's backbone hot spot).

Recurrence per head (head dim P, state dim N, *scalar* per-step decay a_t):

    h_t = a_t h_{t-1} + (dt_t x_t) B_t^T          h in R^{P x N}
    y_t = h_t C_t

Same VMEM-resident-state trick as rwkv6_chunk.py (sequential grid over
chunks), but the scalar decay lets the intra-chunk (C,C) decay matrix
exp(la_t - la_s), t >= s, be formed directly (exponent <= 0 -- no
factorization, no overflow), so the chunk can be CHUNK=64 for full MXU
utilization rather than rwkv6's clamped 16.

Grid = (B*H, S/CHUNK).  Inputs per (bh, c) step: xh (C,P) dt-scaled inputs,
bmat/cmat (C,N), dla (C,) per-step log-decay.  Outputs y (C,P) and the final
(P,N) state.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

CHUNK = 64  # must match repro.nn.ssm.SSD_CHUNK


def _kernel(xh_ref, b_ref, c_ref, dla_ref, h0_ref, y_ref, hfin_ref, h_scr):
    c_idx = pl.program_id(1)
    n_chunks = pl.num_programs(1)

    @pl.when(c_idx == 0)
    def _():
        h_scr[...] = h0_ref[0].astype(jnp.float32)

    xh = xh_ref[0, 0].astype(jnp.float32)    # (C, P)
    bm = b_ref[0, 0].astype(jnp.float32)     # (C, N)
    cm = c_ref[0, 0].astype(jnp.float32)     # (C, N)
    dla = dla_ref[0, 0].astype(jnp.float32)  # (C,) -- as (C, 1) block below

    la = jnp.cumsum(dla, axis=0)             # (C, 1) inclusive
    lend = la[-1:, :]                        # (1, 1)

    # intra-chunk: y[t] += sum_{s<=t} exp(la_t - la_s) (C_t.B_s) xh_s
    dmat = la - la.T                         # (C, C), exponent <= 0 on tril
    clen = dmat.shape[0]
    tri = jnp.tril(jnp.ones((clen, clen), jnp.float32))
    dec = jnp.exp(jnp.where(tri > 0, dmat, -jnp.inf))
    cb = cm @ bm.T                           # (C, C) MXU
    y_intra = (cb * dec) @ xh

    # inter-chunk: y[t] += exp(la_t) C_t h_prev^T    (h: (P, N))
    h = h_scr[...]
    y_inter = jnp.exp(la) * (cm @ h.T)

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: h = exp(lend) h + sum_s exp(lend - la_s) xh_s B_s^T
    xdec = xh * jnp.exp(lend - la)
    h_new = h * jnp.exp(lend[0, 0]) + xdec.T @ bm
    h_scr[...] = h_new

    @pl.when(c_idx == n_chunks - 1)
    def _():
        hfin_ref[0] = h_new.astype(hfin_ref.dtype)


def ssd_chunk(xh, bmat, cmat, dla, h0, interpret: bool = False):
    """xh: (BH, NC, C, P); bmat/cmat: (BH, NC, C, N); dla: (BH, NC, C, 1);
    h0: (BH, P, N).  Returns (y: (BH, NC, C, P), h_final: (BH, P, N))."""
    bh, nc, c, p = xh.shape
    n = bmat.shape[-1]
    xblk = pl.BlockSpec((1, 1, c, p), lambda i, j: (i, j, 0, 0))
    nblk = pl.BlockSpec((1, 1, c, n), lambda i, j: (i, j, 0, 0))
    dblk = pl.BlockSpec((1, 1, c, 1), lambda i, j: (i, j, 0, 0))
    hspec = pl.BlockSpec((1, p, n), lambda i, j: (i, 0, 0))
    return pl.pallas_call(
        _kernel,
        grid=(bh, nc),
        in_specs=[xblk, nblk, nblk, dblk, hspec],
        out_specs=[xblk, hspec],
        out_shape=[jax.ShapeDtypeStruct(xh.shape, jnp.float32),
                   jax.ShapeDtypeStruct(h0.shape, jnp.float32)],
        scratch_shapes=[pltpu.VMEM((p, n), jnp.float32)],
        interpret=interpret,
    )(xh, bmat, cmat, dla, h0)
