"""Flat tile-buffer layout: pytree <-> padded ``(tiles, 8*1024)`` planes.

The fused error-feedback kernels (:mod:`repro.kernels.ef_update`) operate on
2-D tile planes whose rows are one ``(8, 1024)`` VPU tile each.  The
algorithm layer, however, keeps its state as agent-stacked pytrees (leading
``n_agents`` axis per leaf).  This module is the bridge: it concatenates all
leaves of a tree into one flat per-agent vector, zero-pads to a tile
multiple, and exposes the result as a ``(rows * tiles_per_row, TILE)``
plane the kernels can grid over in a single launch -- one kernel invocation
covers every (agent, leaf) pair instead of one pallas_call per leaf.

The plane dtype is a first-class layout parameter: ``FlatSpec.plane_dtype``
(default f32) is the storage dtype of the packed plane, so a bf16 engine
ships and keeps 2 B/element planes end to end while the kernels still
accumulate in f32 internally.  Writebacks to sub-f32 resident buffers go
through :mod:`repro.kernels.sr_cast` (stochastic rounding) in the engine,
not here -- pack/unpack themselves use deterministic ``astype``.

Padding correctness is the subtle part: the pad region is zero on the way
in, whatever the kernel computes there is dropped by :func:`from_planes`,
and per-leaf dtypes are restored on the way out (the planes carry the
spec's ``plane_dtype``; the kernels accumulate in f32 internally).
tests/test_comm_round.py pins this for odd, non-tile-aligned shapes.

Time-varying topologies need no plumbing here: the comm-round engine mixes
in the pytree domain *before* packing, so under a
:class:`repro.core.mixing.TopologySchedule` the round's ``wc = W_t @ c``
arrives at :func:`plane_apply` as ordinary data -- the plane layout, the
kernel grids and the per-shard program are all schedule-invariant (one
executable per chunk size, exactly as with a static graph).

Per-shard planes: a single global plane concatenates leaves with *different*
model-parallel PartitionSpecs, which XLA SPMD can only realize by
all-gathering every buffer over the model axis on pack and resharding again
on unpack.  :class:`ShardedFlatSpec` + :func:`plane_apply` instead run the
pack -> kernel -> unpack pipeline *inside* ``shard_map`` with the engine's
leaf specs, building one padded ``(tiles, TILE)`` plane per (agent shard x
model shard).  The fused updates are elementwise, so the per-shard program
needs no communication at all -- no byte of the plane ever crosses the
model axis.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["LANE", "SUBLANES", "TILE", "FlatSpec", "flat_spec", "to_planes",
           "from_planes", "derived_plane_dtype", "ShardedFlatSpec",
           "sharded_spec", "specs_have_model_axes", "plane_apply"]

LANE = 1024
SUBLANES = 8
TILE = SUBLANES * LANE  # elements per (8, 1024) f32 VPU tile


class FlatSpec(NamedTuple):
    """Static description of a tree's flat layout (per row).

    ``rows`` is the leading (agent) axis size, or 0 for an unstacked tree;
    ``shapes``/``dtypes``/``sizes`` describe each leaf *without* the row
    axis; ``d`` is the per-row element count and ``tiles`` the number of
    TILE-sized rows of the plane each logical row occupies;
    ``plane_dtype`` is the storage dtype of the packed plane (f32 or bf16 --
    the trailing default keeps pre-plane_dtype positional construction
    working).
    """

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    sizes: Tuple[int, ...]
    rows: int
    d: int
    tiles: int
    plane_dtype: Any = jnp.float32

    @property
    def padded(self) -> int:
        return self.tiles * TILE

    @property
    def plane_shape(self) -> Tuple[int, int]:
        n = max(self.rows, 1)
        return (n * self.tiles, TILE)


def derived_plane_dtype(tree) -> Any:
    """Narrowest lossless storage dtype for ``tree``'s packed plane.

    The promotion of all leaf dtypes: an all-bf16 buffer packs as a
    2 B/element bf16 plane, an f32 buffer (or a mixed bf16+f32 tree) packs
    as f32.  This is what keeps the f32 master params exact while the EF
    planes around them ride at half width.
    """
    leaves = jax.tree_util.tree_leaves(tree)
    if not leaves:
        raise ValueError("cannot derive a plane dtype for an empty pytree")
    return jnp.result_type(*[l.dtype for l in leaves])


def flat_spec(tree, stacked: bool = True,
              plane_dtype: Any = None) -> FlatSpec:
    """Compute the flat layout of ``tree`` (leaves may be ShapeDtypeStructs).

    stacked: leaves carry a leading agent axis (must agree across leaves),
    which becomes ``spec.rows``; the per-row vector concatenates the
    remaining dims of every leaf in tree-flatten order.

    plane_dtype: storage dtype of the packed plane; ``None`` (default)
    derives it from the tree via :func:`derived_plane_dtype`, so f32 trees
    keep their historical f32 planes and bf16 buffers pack at 2 B/element.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        raise ValueError("cannot flatten an empty pytree")
    if stacked:
        rows = leaves[0].shape[0]
        for l in leaves:
            if l.ndim < 1 or l.shape[0] != rows:
                raise ValueError(
                    "stacked flatten needs a shared leading agent axis; got "
                    f"shapes {[tuple(x.shape) for x in leaves]}")
        shapes = tuple(tuple(l.shape[1:]) for l in leaves)
    else:
        rows = 0
        shapes = tuple(tuple(l.shape) for l in leaves)
    sizes = tuple(math.prod(s) if s else 1 for s in shapes)
    d = sum(sizes)
    tiles = -(-d // TILE)
    if plane_dtype is None:
        plane_dtype = jnp.result_type(*[l.dtype for l in leaves])
    return FlatSpec(treedef=treedef, shapes=shapes,
                    dtypes=tuple(l.dtype for l in leaves), sizes=sizes,
                    rows=rows, d=d, tiles=tiles,
                    plane_dtype=jnp.dtype(plane_dtype))


def to_planes(tree, spec: FlatSpec) -> jax.Array:
    """Pack ``tree`` into a ``spec.plane_dtype`` plane of ``plane_shape``.

    The tree must match ``spec`` structurally; its leaves may have any
    floating dtype (cast to the plane dtype here, restored by
    :func:`from_planes`).
    """
    pdt = spec.plane_dtype
    leaves = jax.tree_util.tree_leaves(tree)
    if spec.rows:
        parts = [l.reshape(l.shape[0], -1).astype(pdt) for l in leaves]
        flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
        flat = jnp.pad(flat, ((0, 0), (0, spec.padded - spec.d)))
        return flat.reshape(spec.rows * spec.tiles, TILE)
    parts = [l.reshape(-1).astype(pdt) for l in leaves]
    flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    flat = jnp.pad(flat, (0, spec.padded - spec.d))
    return flat.reshape(spec.tiles, TILE)


def from_planes(planes: jax.Array, spec: FlatSpec):
    """Invert :func:`to_planes`: drop padding, split leaves, restore dtypes."""
    if spec.rows:
        flat = planes.reshape(spec.rows, spec.padded)[:, :spec.d]
        offs, out = 0, []
        for shape, dtype, size in zip(spec.shapes, spec.dtypes, spec.sizes):
            leaf = flat[:, offs:offs + size]
            out.append(leaf.reshape((spec.rows,) + shape).astype(dtype))
            offs += size
        return spec.treedef.unflatten(out)
    flat = planes.reshape(-1)[:spec.d]
    offs, out = 0, []
    for shape, dtype, size in zip(spec.shapes, spec.dtypes, spec.sizes):
        out.append(flat[offs:offs + size].reshape(shape).astype(dtype))
        offs += size
    return spec.treedef.unflatten(out)


# ---------------------------------------------------------------------------
# per-shard planes: pack/kernel/unpack inside shard_map
# ---------------------------------------------------------------------------

class ShardedFlatSpec(NamedTuple):
    """Static description of the *per-shard* flat layout.

    Unlike :class:`FlatSpec`, the tile counts are not recorded here: each
    device derives its own local :class:`FlatSpec` from its shard's shapes
    at trace time inside ``shard_map`` (every shard of an evenly-sharded
    tree sees the same local shapes, so the derived layout is identical
    across devices).  What this spec pins down is *where* the planes live:
    the mesh and the per-leaf PartitionSpecs the pack/unpack must respect,
    plus the storage dtype of every per-shard plane.
    """

    mesh: Any
    leaf_specs: Any               # pytree of PartitionSpec, agent axis first
    plane_dtype: Any = None       # None: derive per tree from leaf dtypes


def specs_have_model_axes(leaf_specs,
                          agent_axes: Sequence[str] = ("data",)) -> bool:
    """True when any leaf spec shards a non-agent (model) mesh axis.

    Pure agent sharding (every leaf ``P(agents, None, ...)``) keeps the
    single global plane shardable along its row axis, so the in-jit pack is
    already reshard-free there; only model axes force per-shard planes.
    """
    agent = set(agent_axes)
    for s in jax.tree_util.tree_leaves(
            leaf_specs, is_leaf=lambda x: isinstance(x, P)):
        if not isinstance(s, P):
            continue
        for entry in tuple(s):
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            if any(n not in agent for n in names):
                return True
    return False


def sharded_spec(mesh, leaf_specs,
                 plane_dtype: Any = None) -> ShardedFlatSpec:
    """Pin the per-shard plane layout for ``plane_apply``."""
    if mesh is None or leaf_specs is None:
        raise ValueError("per-shard planes need both a mesh and leaf_specs")
    return ShardedFlatSpec(
        mesh=mesh, leaf_specs=leaf_specs,
        plane_dtype=None if plane_dtype is None else jnp.dtype(plane_dtype))


def plane_apply(kernel, trees: Sequence[Any], n_out: int,
                sharded: "ShardedFlatSpec | None" = None,
                plane_dtype: Any = None):
    """Run ``kernel`` over the flat planes of ``trees``.

    kernel: ``(plane, ...) -> (plane, ...)`` over same-layout tile planes
    (``n_out`` outputs); ``trees``: same-structure agent-stacked pytrees.
    Output ``i`` is restored with the leaf dtypes of ``trees[i]`` -- the
    engine's update methods return (a permutation of) their first ``n_out``
    input buffers, and under mixed precision those buffers legitimately
    differ in dtype (f32 master params next to bf16 EF planes), so a single
    shared spec would silently downcast the master copy.

    plane_dtype: storage dtype of the packed planes; ``None`` (the default,
    and ``sharded.plane_dtype`` when a sharded spec is given) derives each
    tree's plane dtype from its own leaves (:func:`derived_plane_dtype`),
    so a bf16 EF buffer packs at 2 B/element while the f32 master param
    tree beside it keeps an exact f32 plane.

    With ``sharded=None`` this is the single-plane path: one global pack,
    one kernel launch, one unpack.  With a :class:`ShardedFlatSpec` the same
    three steps run inside ``shard_map`` over ``sharded.mesh``, so every
    device packs only its local (agent shard x model shard) block and the
    kernel grid covers one per-shard plane -- no leaf ever crosses the
    model axis.
    """
    if plane_dtype is None and sharded is not None:
        plane_dtype = sharded.plane_dtype

    def local(*ts):
        specs = [flat_spec(t, plane_dtype=plane_dtype) for t in ts]
        outs = kernel(*(to_planes(t, s) for t, s in zip(ts, specs)))
        return tuple(from_planes(o, specs[i]) for i, o in enumerate(outs))

    if sharded is None:
        return local(*trees)

    from repro.compat import shard_map  # deferred: keep kernels jax-only

    specs = sharded.leaf_specs
    fn = shard_map(local, mesh=sharded.mesh,
                   in_specs=(specs,) * len(trees),
                   out_specs=(specs,) * n_out, check_vma=False)
    return fn(*trees)
