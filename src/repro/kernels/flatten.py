"""Flat tile-buffer layout: pytree <-> padded ``(tiles, 8*1024)`` f32 planes.

The fused error-feedback kernels (:mod:`repro.kernels.ef_update`) operate on
2-D tile planes whose rows are one ``(8, 1024)`` f32 VPU tile each.  The
algorithm layer, however, keeps its state as agent-stacked pytrees (leading
``n_agents`` axis per leaf).  This module is the bridge: it concatenates all
leaves of a tree into one flat per-agent vector, zero-pads to a tile
multiple, and exposes the result as a ``(rows * tiles_per_row, TILE)`` f32
plane the kernels can grid over in a single launch -- one kernel invocation
covers every (agent, leaf) pair instead of one pallas_call per leaf.

Padding correctness is the subtle part: the pad region is zero on the way
in, whatever the kernel computes there is dropped by :func:`from_planes`,
and per-leaf dtypes are restored on the way out (the planes themselves are
always f32, the kernels' accumulation dtype).  tests/test_comm_round.py pins
this for odd, non-tile-aligned shapes.

Time-varying topologies need no plumbing here: the comm-round engine mixes
in the pytree domain *before* packing, so under a
:class:`repro.core.mixing.TopologySchedule` the round's ``wc = W_t @ c``
arrives at :func:`plane_apply` as ordinary data -- the plane layout, the
kernel grids and the per-shard program are all schedule-invariant (one
executable per chunk size, exactly as with a static graph).

Per-shard planes: a single global plane concatenates leaves with *different*
model-parallel PartitionSpecs, which XLA SPMD can only realize by
all-gathering every buffer over the model axis on pack and resharding again
on unpack.  :class:`ShardedFlatSpec` + :func:`plane_apply` instead run the
pack -> kernel -> unpack pipeline *inside* ``shard_map`` with the engine's
leaf specs, building one padded ``(tiles, TILE)`` plane per (agent shard x
model shard).  The fused updates are elementwise, so the per-shard program
needs no communication at all -- no byte of the plane ever crosses the
model axis.
"""

from __future__ import annotations

import math
from typing import Any, NamedTuple, Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

__all__ = ["LANE", "SUBLANES", "TILE", "FlatSpec", "flat_spec", "to_planes",
           "from_planes", "ShardedFlatSpec", "sharded_spec",
           "specs_have_model_axes", "plane_apply"]

LANE = 1024
SUBLANES = 8
TILE = SUBLANES * LANE  # elements per (8, 1024) f32 VPU tile


class FlatSpec(NamedTuple):
    """Static description of a tree's flat layout (per row).

    ``rows`` is the leading (agent) axis size, or 0 for an unstacked tree;
    ``shapes``/``dtypes``/``sizes`` describe each leaf *without* the row
    axis; ``d`` is the per-row element count and ``tiles`` the number of
    TILE-sized rows of the plane each logical row occupies.
    """

    treedef: Any
    shapes: Tuple[Tuple[int, ...], ...]
    dtypes: Tuple[Any, ...]
    sizes: Tuple[int, ...]
    rows: int
    d: int
    tiles: int

    @property
    def padded(self) -> int:
        return self.tiles * TILE

    @property
    def plane_shape(self) -> Tuple[int, int]:
        n = max(self.rows, 1)
        return (n * self.tiles, TILE)


def flat_spec(tree, stacked: bool = True) -> FlatSpec:
    """Compute the flat layout of ``tree`` (leaves may be ShapeDtypeStructs).

    stacked: leaves carry a leading agent axis (must agree across leaves),
    which becomes ``spec.rows``; the per-row vector concatenates the
    remaining dims of every leaf in tree-flatten order.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    if not leaves:
        raise ValueError("cannot flatten an empty pytree")
    if stacked:
        rows = leaves[0].shape[0]
        for l in leaves:
            if l.ndim < 1 or l.shape[0] != rows:
                raise ValueError(
                    "stacked flatten needs a shared leading agent axis; got "
                    f"shapes {[tuple(x.shape) for x in leaves]}")
        shapes = tuple(tuple(l.shape[1:]) for l in leaves)
    else:
        rows = 0
        shapes = tuple(tuple(l.shape) for l in leaves)
    sizes = tuple(math.prod(s) if s else 1 for s in shapes)
    d = sum(sizes)
    tiles = -(-d // TILE)
    return FlatSpec(treedef=treedef, shapes=shapes,
                    dtypes=tuple(l.dtype for l in leaves), sizes=sizes,
                    rows=rows, d=d, tiles=tiles)


def to_planes(tree, spec: FlatSpec) -> jax.Array:
    """Pack ``tree`` into an f32 plane of shape ``spec.plane_shape``.

    The tree must match ``spec`` structurally; its leaves may have any
    floating dtype (cast to f32 here, restored by :func:`from_planes`).
    """
    leaves = jax.tree_util.tree_leaves(tree)
    if spec.rows:
        parts = [l.reshape(l.shape[0], -1).astype(jnp.float32)
                 for l in leaves]
        flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts, axis=1)
        flat = jnp.pad(flat, ((0, 0), (0, spec.padded - spec.d)))
        return flat.reshape(spec.rows * spec.tiles, TILE)
    parts = [l.reshape(-1).astype(jnp.float32) for l in leaves]
    flat = parts[0] if len(parts) == 1 else jnp.concatenate(parts)
    flat = jnp.pad(flat, (0, spec.padded - spec.d))
    return flat.reshape(spec.tiles, TILE)


def from_planes(planes: jax.Array, spec: FlatSpec):
    """Invert :func:`to_planes`: drop padding, split leaves, restore dtypes."""
    if spec.rows:
        flat = planes.reshape(spec.rows, spec.padded)[:, :spec.d]
        offs, out = 0, []
        for shape, dtype, size in zip(spec.shapes, spec.dtypes, spec.sizes):
            leaf = flat[:, offs:offs + size]
            out.append(leaf.reshape((spec.rows,) + shape).astype(dtype))
            offs += size
        return spec.treedef.unflatten(out)
    flat = planes.reshape(-1)[:spec.d]
    offs, out = 0, []
    for shape, dtype, size in zip(spec.shapes, spec.dtypes, spec.sizes):
        out.append(flat[offs:offs + size].reshape(shape).astype(dtype))
        offs += size
    return spec.treedef.unflatten(out)


# ---------------------------------------------------------------------------
# per-shard planes: pack/kernel/unpack inside shard_map
# ---------------------------------------------------------------------------

class ShardedFlatSpec(NamedTuple):
    """Static description of the *per-shard* flat layout.

    Unlike :class:`FlatSpec`, the tile counts are not recorded here: each
    device derives its own local :class:`FlatSpec` from its shard's shapes
    at trace time inside ``shard_map`` (every shard of an evenly-sharded
    tree sees the same local shapes, so the derived layout is identical
    across devices).  What this spec pins down is *where* the planes live:
    the mesh and the per-leaf PartitionSpecs the pack/unpack must respect.
    """

    mesh: Any
    leaf_specs: Any               # pytree of PartitionSpec, agent axis first


def specs_have_model_axes(leaf_specs,
                          agent_axes: Sequence[str] = ("data",)) -> bool:
    """True when any leaf spec shards a non-agent (model) mesh axis.

    Pure agent sharding (every leaf ``P(agents, None, ...)``) keeps the
    single global plane shardable along its row axis, so the in-jit pack is
    already reshard-free there; only model axes force per-shard planes.
    """
    agent = set(agent_axes)
    for s in jax.tree_util.tree_leaves(
            leaf_specs, is_leaf=lambda x: isinstance(x, P)):
        if not isinstance(s, P):
            continue
        for entry in tuple(s):
            if entry is None:
                continue
            names = entry if isinstance(entry, tuple) else (entry,)
            if any(n not in agent for n in names):
                return True
    return False


def sharded_spec(mesh, leaf_specs) -> ShardedFlatSpec:
    """Pin the per-shard plane layout for ``plane_apply``."""
    if mesh is None or leaf_specs is None:
        raise ValueError("per-shard planes need both a mesh and leaf_specs")
    return ShardedFlatSpec(mesh=mesh, leaf_specs=leaf_specs)


def plane_apply(kernel, trees: Sequence[Any], n_out: int,
                sharded: "ShardedFlatSpec | None" = None):
    """Run ``kernel`` over the flat planes of ``trees``.

    kernel: ``(plane, ...) -> (plane, ...)`` over same-layout tile planes
    (``n_out`` outputs); ``trees``: same-structure agent-stacked pytrees.
    Returns ``n_out`` pytrees with the layout (and leaf dtypes) of
    ``trees[0]``.

    With ``sharded=None`` this is the single-plane path: one global pack,
    one kernel launch, one unpack.  With a :class:`ShardedFlatSpec` the same
    three steps run inside ``shard_map`` over ``sharded.mesh``, so every
    device packs only its local (agent shard x model shard) block and the
    kernel grid covers one per-shard plane -- no leaf ever crosses the
    model axis.
    """

    def local(*ts):
        spec = flat_spec(ts[0])
        outs = kernel(*(to_planes(t, spec) for t in ts))
        return tuple(from_planes(o, spec) for o in outs)

    if sharded is None:
        return local(*trees)

    from repro.compat import shard_map  # deferred: keep kernels jax-only

    specs = sharded.leaf_specs
    fn = shard_map(local, mesh=sharded.mesh,
                   in_specs=(specs,) * len(trees),
                   out_specs=(specs,) * n_out, check_vma=False)
    return fn(*trees)
