"""repro: a multi-pod JAX framework reproducing PORTER (Li & Chi, 2023) --
decentralized nonconvex optimization with gradient clipping and
communication compression -- and extending it to a production-style
decentralized training stack (model zoo, mesh launcher, Pallas kernels,
roofline tooling).  See DESIGN.md for the system inventory.

Module map:

    api         THE entry point: ExperimentSpec (declarative experiment)
                + build(spec, loss_fn) -> Algorithm over the registry of
                all eight optimizers (porter-gc/dp, beer, porter-adam,
                dsgd, choco, dp-sgd, soteriafl); owns topology/compressor/
                engine construction and the gamma derivation
    core        the paper's algorithms and their substrate
      .comm_round   the one fused EF/gossip round primitive: CommRound
                    compresses an increment, accumulates surrogate q and
                    mixing mirror m, and applies a caller-supplied fused
                    update (ef_track/ef_step/ef_gossip kernels over the
                    flat tile layout); PORTER, PORTER-Adam, CHOCO-SGD and
                    SoteriaFL are thin clients of it
      .registry     the Algorithm protocol + registry repro.api publishes
                    every optimizer through
      .porter       Algorithm 1 (PORTER-DP / PORTER-GC / BEER)
      .baselines    DSGD, CHOCO-SGD, DP-SGD, SoteriaFL-SGD
      .gossip       dense / ring / packed wire executors + byte accounting
      .compression  rho-compressors (Definition 3)
      .clipping     smooth / piecewise clipping (Definition 2)
      .mixing       topologies and mixing matrices (Definition 1), plus
                    time-varying TopologySchedule generators (churn,
                    stragglers, graph rotation, ER resampling) with
                    window-connectivity validation and joint spectral gaps
      .privacy      LDP calibration and accounting (Theorem 1)
    kernels     Pallas TPU kernels (+ flatten: pytree <-> tile planes)
    launch      mesh builder, sharded step builders, train/serve drivers
    models, nn  the model zoo and its building blocks
    data        synthetic datasets matching the paper's experiments
    configs     per-architecture ModelConfigs (paper + production scale)
    compat      jax version shims (shard_map)
"""

__version__ = "0.1.0"
