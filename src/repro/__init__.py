"""repro: a multi-pod JAX framework reproducing PORTER (Li & Chi, 2023) --
decentralized nonconvex optimization with gradient clipping and
communication compression -- and extending it to a production-style
decentralized training stack (model zoo, mesh launcher, Pallas kernels,
roofline tooling).  See DESIGN.md for the system inventory."""

__version__ = "0.1.0"
