"""repro.api -- the one facade over every decentralized optimizer.

The paper analyzes PORTER-DP, PORTER-GC, BEER, CHOCO-SGD, DSGD, DP-SGD and
SoteriaFL-SGD in one framework; this module exposes them through one
framework too.  A declarative :class:`ExperimentSpec` names the algorithm
and its knobs (topology, compressor, gossip mode, clipping/privacy,
comm backend), and :func:`build` turns it into a ready-to-train
:class:`repro.core.registry.Algorithm`:

    from repro.api import ExperimentSpec, build

    spec = ExperimentSpec(algo="porter-gc", n_agents=10,
                          topology="erdos_renyi", topology_p=0.8,
                          compressor="top_k", frac=0.05, eta=0.05, tau=1.0)
    algo = build(spec, loss_fn)
    state = algo.init(params0)
    step = jax.jit(algo.step)
    state, metrics = step(state, batch, key)   # metrics: loss, wire_bytes, ...

``build`` owns everything that used to be copy-pasted at every call site:
topology + mixing-matrix construction, compressor construction, the
comm-round engine, and the paper's consensus-stepsize derivation

    gamma = gamma_scale * (1 - alpha) * rho        (default scale 1/2)

with ``alpha`` the mixing rate of the resolved topology and ``rho`` the
resolved compressor's contraction factor.  A ``topology_schedule`` spec
string swaps the static graph for a time-varying
:class:`repro.core.mixing.TopologySchedule` (churn, stragglers, graph
rotation, per-round ER resampling); ``alpha`` then becomes the schedule's
per-round geometric mixing rate, and the gossip executors index the
schedule table by the state's step counter inside the compiled program.  Launch-level hooks (mesh,
agent axes, shard-local compression, sharded leaf specs) are keyword
arguments of :func:`build` -- they are runtime objects, not experiment
declarations, so they stay out of the spec.

Registered algorithms (see :func:`repro.core.registry.list_algorithms`):

    porter-gc    Algorithm 1, Option II (batch-then-clip)
    porter-dp    Algorithm 1, Option I  (per-sample clip + Gaussian noise)
    beer         the unclipped ancestor [ZLL+22] (tau pinned to inf)
    porter-adam  beyond-paper: Adam-preconditioned tracked gradient
    dsgd         decentralized SGD with uncompressed gossip
    choco        CHOCO-SGD [KSJ19], compressed gossip, no tracking
    dp-sgd       centralized DP-SGD [ACG+16] (Table 1 reference point)
    soteriafl    SoteriaFL-SGD [LZLC22], server/client shifted compression
    dp-csgp      beyond-paper: DP compressed gossip over *directed* graphs
                 (column-stochastic W + push-sum de-biasing, arXiv
                 2512.13583); pair with topology_schedule="directed:..."
    clip21       beyond-paper: Clip21 error-feedback clipping (arXiv
                 2305.18929) -- clips the gradient *residual* against a
                 running estimate, so the clipping bias vanishes once the
                 iterates stabilize; bit-exact porter-gc at tau=inf
    subgrad-comp beyond-paper: nonsmooth subgradient method with
                 compressed gossip (arXiv 2607.01755 family) --
                 CHOCO's round with the 1/sqrt(t) diminishing stepsize

Fleet mode (``ExperimentSpec(fleet=True)``): the agent axis becomes a
simulated *fleet* of n = 1k-100k agents on however few devices exist --
same agent-stacked state layout, but mixing runs through
:func:`repro.core.fleet.make_fleet_mixer`: the identical dense einsum at
n <= FLEET_DENSE_GATE (bit-exact against the per-device engine, pinned
by tests/test_fleet.py) and a sparse COO scatter-add above it, where the
topology/schedule builders also switch to the sparse fleet generators so
no dense (n, n) table is ever materialized.

The per-algorithm functional APIs (``porter_step``, ``choco_step``, ...)
remain importable for tests and power users, but no call site should build
mixers/topologies/engines by hand anymore -- that is the facade's job.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Mapping, Optional, Sequence

import jax
import jax.numpy as jnp

from repro.core import baselines as BL
from repro.core.beer import beer_config
from repro.core.clip21 import Clip21State, clip21_init, clip21_step
from repro.core.comm_round import CommRound, resolve_backend
from repro.core.compression import Compressor, make_compressor
from repro.core.fleet import (FLEET_DENSE_GATE, fleet_er_schedule,
                              fleet_rotating_schedule, fleet_topology,
                              make_fleet_mixer)
from repro.core import mixing as MX
from repro.core import wire_formats
from repro.core.gossip import MixFn, make_mixer
from repro.core.mixing import Topology, TopologySchedule, make_topology
from repro.core.porter import (PorterConfig, PorterState, porter_init,
                               porter_step)
from repro.core.subgrad import SubgradState, subgrad_init, subgrad_step
from repro.core.porter_adam import (PorterAdamState, porter_adam_init,
                                    porter_adam_step)
from repro.core.push_sum import DpCsgpState, dp_csgp_init, dp_csgp_step
from repro.core.registry import (Algorithm, AlgorithmInfo, algorithm_info,
                                 get_factory, list_algorithms,
                                 register_algorithm)

__all__ = [
    "ExperimentSpec",
    "VARIANT_TO_ALGO",
    "build",
    "build_engine",
    "resolve_topology",
    "resolve_schedule",
    "resolve_fleet_topology",
    "resolve_fleet_schedule",
    "resolve_compressor",
    "resolve_wire_format",
    "resolve_gamma",
    "resolve_plane_dtype",
    "Algorithm",
    "AlgorithmInfo",
    "algorithm_info",
    "list_algorithms",
]

# compressors whose knob is a kept-fraction (rho = frac)
_FRAC_COMPRESSORS = ("top_k", "block_top_k", "random_k")

# legacy PorterConfig.variant spelling -> registry name (launch drivers
# keep accepting --variant / variant= as sugar; one mapping, kept next to
# the registrations it must stay in sync with)
VARIANT_TO_ALGO = {"gc": "porter-gc", "dp": "porter-dp", "beer": "beer",
                   "csgp": "dp-csgp"}


@dataclasses.dataclass(frozen=True)
class ExperimentSpec:
    """Declarative description of one decentralized-training experiment.

    Every field is a plain value (names, floats, bools) so specs can be
    logged, swept and diffed; :func:`build` resolves them into objects.
    ``gamma=None`` means "derive it": gamma_scale * (1 - alpha) * rho
    (the paper's stable choice) for compressed gossip, 1.0 for plain DSGD.
    ``tau=None`` disables clipping where that is optional (dsgd, choco,
    porter-gc/beer); the DP algorithms (porter-dp, dp-sgd, soteriafl)
    *reject* it -- their noise is calibrated to tau's sensitivity, so an
    unclipped run would silently void the privacy guarantee.
    """

    algo: str = "porter-gc"
    # agents + communication graph (Definition 1)
    n_agents: int = 10
    # fleet mode: simulate n_agents as a vectorized fleet (n >> devices).
    # The state layout is unchanged (leading agent axis, vmapped gradients,
    # shardable over devices); mixing routes through the fleet mixer --
    # bit-exact dense einsum at n <= repro.core.fleet.FLEET_DENSE_GATE,
    # sparse COO scatter-add above it (topology kinds ring / exponential /
    # erdos_renyi; schedules rotate / erdos_renyi).  Needs the default
    # dense gossip_mode and wire.
    fleet: bool = False
    topology: str = "ring"
    topology_weights: str = "metropolis"
    topology_p: float = 0.8          # erdos_renyi edge probability
    topology_seed: int = 0
    # time-varying topology (None = the static graph above).  A generator
    # spec string, resolved by resolve_schedule into a
    # repro.core.mixing.TopologySchedule whose (period, n, n) table the
    # gossip executors index with the traced round counter:
    #   "static"                              period-1 wrapper (parity tests)
    #   "rotate:ring+star+complete"           one graph kind per round
    #   "rotate:ring/metropolis+ring/lazy"    per-round weight schemes
    #   "rotate:ring+star,weights=lazy"       bare kinds + key=value knobs
    #   "erdos_renyi:period=8,p=0.6"          fresh connected ER every round
    #   "dropout:rate=0.2,period=8"           agent churn (offline w.p. rate)
    #   "straggler:rate=0.3,period=8"         per-link deadline misses
    #   "directed:ring_skips,skip=2"          COLUMN-stochastic: directed
    #   "directed:digraph,p=0.5,period=8"     ring w/ chords, random digraph,
    #   "directed:one_way,rate=0.2,period=8"  one-way link loss (push-sum
    #                                         algorithms only, e.g. dp-csgp)
    # Unset keys default to the topology_* fields above; the consensus
    # stepsize derivation then uses the schedule's joint spectral gap
    # (joint contraction factor for the directed family).
    # Server/client algorithms (dp-sgd, soteriafl) have no graph and
    # ignore it.
    topology_schedule: Optional[str] = None
    # compression (Definition 3)
    compressor: str = "top_k"
    frac: float = 0.05               # kept fraction for the sparse family
    compressor_kwargs: Mapping[str, Any] = dataclasses.field(
        default_factory=dict)        # extras, e.g. block=, rank=, bits=
    # wire format / engine backend
    gossip_mode: str = "dense"       # 'dense' | 'ring' | 'packed'
    # 'dense' ships the dense emulation; 'packed_bits' fuses compression
    # with bit-packing so only compact buffers cross the wire (bf16+u16
    # top-k segments, uint32 QSGD code words -- core.wire_formats).  Needs
    # gossip_mode 'ring'/'packed' and a top_k/block_top_k/qsgd compressor.
    wire: str = "dense"              # 'dense' | 'packed_bits'
    # issue both PORTER comm rounds before either fused update, so the
    # collectives overlap the other round's local compute; bit-exact to the
    # sequential order (CommRound.overlap).  Single-round algos ignore it.
    overlap: bool = False
    comm_backend: str = "auto"       # 'auto' | 'pallas' | 'ref'
    interpret: Optional[bool] = None
    # stepsizes
    eta: float = 0.05
    gamma: Optional[float] = None    # None -> derived (see resolve_gamma)
    gamma_scale: float = 0.5
    # clipping / privacy (Definition 2 / Theorem 1)
    tau: Optional[float] = 1.0
    clip_mode: str = "smooth"
    sigma_p: float = 0.0
    dp: bool = False                 # per-sample clip+noise oracle for dsgd
    # porter-adam moments
    b1: float = 0.9
    b2: float = 0.999
    adam_eps: float = 1e-8
    # soteriafl shift stepsize
    alpha_shift: float = 0.5
    # EF/tracking buffer accumulation dtype
    buffer_dtype: Any = jnp.float32
    # storage dtype of the EF state planes: None = legacy f32 layout;
    # 'bf16' puts every parameter-sized EF buffer (q, m, v, g_prev, the
    # soteriafl shift) in bfloat16 -- resident optimizer state and the
    # gossip wire both drop to 2 B/element while the master params stay
    # f32 and the fused kernels keep f32 accumulation with a
    # stochastic-rounding writeback (kernels/sr_cast.py).  Accepts 'f32' /
    # 'bf16' strings or jnp dtypes (resolve_plane_dtype).
    plane_dtype: Any = None
    # rematerialization of the loss/grad inside algo.step: None = off,
    # 'full' = jax.checkpoint around the loss (recompute everything in the
    # backward pass), 'dots' = checkpoint with the dots_saveable policy
    # (keep matmul outputs, recompute the cheap elementwise rest) -- the
    # right knob for the models/ transformer+SSM stack on pod meshes.
    remat_policy: Optional[str] = None

    def replace(self, **kw) -> "ExperimentSpec":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class Resolved:
    """What :func:`build` constructed from a spec (the factory context)."""

    info: AlgorithmInfo
    topology: Optional[Topology]
    compressor: Optional[Compressor]
    mixer: Optional[MixFn]
    engine: Optional[CommRound]
    gamma: Optional[float]
    schedule: Optional[TopologySchedule] = None


# ---------------------------------------------------------------------------
# resolvers: spec fields -> objects (the construction no call site repeats)
# ---------------------------------------------------------------------------

def resolve_topology(spec: ExperimentSpec) -> Topology:
    return make_topology(spec.topology, spec.n_agents,
                         weights=spec.topology_weights, p=spec.topology_p,
                         seed=spec.topology_seed)


def _parse_schedule_kv(rest: str) -> Mapping[str, str]:
    kv = {}
    for item in filter(None, (s.strip() for s in rest.split(","))):
        k, sep, v = item.partition("=")
        if not sep:
            raise ValueError(
                f"bad schedule argument {item!r}: expected key=value "
                "(e.g. 'dropout:rate=0.2,period=8')")
        kv[k.strip()] = v.strip()
    return kv


def resolve_schedule(spec: ExperimentSpec,
                     topology: Optional[Topology] = None
                     ) -> Optional[TopologySchedule]:
    """Parse ``spec.topology_schedule`` into a TopologySchedule (or None).

    Unset generator knobs default to the spec's static-topology fields
    (weights, p, seed, and the base graph kind for churn generators);
    ``topology`` short-circuits the period-1 'static' wrapper so an
    externally supplied Topology override keeps parity."""
    if spec.topology_schedule is None:
        return None
    text = spec.topology_schedule
    kind, _, rest = text.partition(":")
    kind = kind.strip()
    if kind == "static":
        if rest.strip():
            raise ValueError(f"'static' schedule takes no arguments; got "
                             f"{text!r}")
        top = resolve_topology(spec) if topology is None else topology
        return MX.static_schedule(top)
    if kind == "directed":
        return _resolve_directed_schedule(spec, text, rest)
    allowed = {"rotate": {"kinds", "weights", "p", "seed"},
               "erdos_renyi": {"p", "period", "weights", "seed"},
               "dropout": {"rate", "period", "base", "weights", "p", "seed"},
               "straggler": {"rate", "period", "base", "weights", "p",
                             "seed"}}
    if kind not in allowed:
        raise ValueError(
            f"unknown topology schedule kind {kind!r} in {text!r}; have "
            "static, rotate, erdos_renyi, dropout, straggler, directed")
    if kind == "rotate" and rest:
        # the kinds list may lead bare: 'rotate:ring+star,weights=lazy'
        first, _, more = rest.partition(",")
        if "=" not in first:
            kv = {"kinds": first.strip(), **_parse_schedule_kv(more)}
        else:
            kv = dict(_parse_schedule_kv(rest))
    else:
        kv = dict(_parse_schedule_kv(rest))
    # reject typo'd keys BEFORE running a generator: the churn samplers do
    # real work (up to 1000 window-connectivity attempts)
    unknown = set(kv) - allowed[kind]
    if unknown:
        raise ValueError(f"unknown {kind!r} schedule keys {sorted(unknown)} "
                         f"in {text!r}; allowed: {sorted(allowed[kind])}")
    if kind == "rotate":
        kinds = [k for k in kv.pop("kinds", "").split("+") if k]
        if not kinds:
            raise ValueError("rotate schedule needs '+'-separated graph "
                             "kinds, e.g. 'rotate:ring+star+complete'")
        return MX.rotating_schedule(
            kinds, spec.n_agents,
            weights=kv.pop("weights", spec.topology_weights),
            p=float(kv.pop("p", spec.topology_p)),
            seed=int(kv.pop("seed", spec.topology_seed)))
    if kind == "erdos_renyi":
        return MX.erdos_renyi_schedule(
            spec.n_agents, p=float(kv.pop("p", spec.topology_p)),
            period=int(kv.pop("period", 8)),
            weights=kv.pop("weights", spec.topology_weights),
            seed=int(kv.pop("seed", spec.topology_seed)))
    gen = (MX.dropout_schedule if kind == "dropout"
           else MX.straggler_schedule)
    return gen(
        spec.n_agents, rate=float(kv.pop("rate", 0.2)),
        period=int(kv.pop("period", 8)),
        base=kv.pop("base", spec.topology),
        weights=kv.pop("weights", spec.topology_weights),
        p=float(kv.pop("p", spec.topology_p)),
        seed=int(kv.pop("seed", spec.topology_seed)))


def _resolve_directed_schedule(spec: ExperimentSpec, text: str,
                               rest: str) -> TopologySchedule:
    """'directed:<subkind>,key=value,...' -> a column-stochastic schedule.

    Subkinds (repro.core.mixing generators):
      ring_skips   static directed ring, optional skip chords   {skip}
      digraph      per-round random digraph                     {p, period,
                                                                 seed}
      one_way      directed churn: one-way link loss on the     {rate,
                   ring-with-skips base                          period,
                                                                 skip, seed}
    The leading subkind token may be bare (no '='), mirroring the rotate
    kinds list.  These tables are **column**-stochastic -- only push-sum
    algorithms (dp-csgp) de-bias them correctly; the doubly-stochastic
    family would silently drift toward the Perron vector.
    """
    first, _, more = rest.partition(",")
    sub = first.strip()
    if not sub or "=" in sub:
        raise ValueError(
            f"directed schedule needs a leading subkind in {text!r}, e.g. "
            "'directed:ring_skips,skip=2'; have ring_skips, digraph, "
            "one_way")
    allowed = {"ring_skips": {"skip"},
               "digraph": {"p", "period", "seed"},
               "one_way": {"rate", "period", "skip", "seed"}}
    if sub not in allowed:
        raise ValueError(
            f"unknown directed schedule subkind {sub!r} in {text!r}; have "
            f"{sorted(allowed)}")
    kv = dict(_parse_schedule_kv(more))
    unknown = set(kv) - allowed[sub]
    if unknown:
        raise ValueError(f"unknown directed:{sub} schedule keys "
                         f"{sorted(unknown)} in {text!r}; allowed: "
                         f"{sorted(allowed[sub])}")
    if sub == "ring_skips":
        return MX.directed_ring_schedule(spec.n_agents,
                                         skip=int(kv.pop("skip", 0)))
    if sub == "digraph":
        return MX.random_digraph_schedule(
            spec.n_agents, p=float(kv.pop("p", spec.topology_p)),
            period=int(kv.pop("period", 8)),
            seed=int(kv.pop("seed", spec.topology_seed)))
    return MX.directed_churn_schedule(
        spec.n_agents, rate=float(kv.pop("rate", 0.2)),
        period=int(kv.pop("period", 8)), skip=int(kv.pop("skip", 2)),
        seed=int(kv.pop("seed", spec.topology_seed)))


def resolve_compressor(spec: ExperimentSpec) -> Compressor:
    kwargs = dict(spec.compressor_kwargs)
    if spec.compressor in _FRAC_COMPRESSORS:
        kwargs.setdefault("frac", spec.frac)
    return make_compressor(spec.compressor, **kwargs)


_PLANE_DTYPES = {"f32": jnp.float32, "float32": jnp.float32,
                 "bf16": jnp.bfloat16, "bfloat16": jnp.bfloat16}


def resolve_plane_dtype(spec_or_name) -> Optional[Any]:
    """``spec.plane_dtype`` -> a concrete jnp dtype or None (legacy f32).

    Accepts an :class:`ExperimentSpec`, a name ('f32'/'bf16' and their long
    spellings), or a dtype-like; validates against the engine's supported
    planes (f32 exact, bf16 with stochastic-rounding writeback).
    """
    val = (spec_or_name.plane_dtype
           if isinstance(spec_or_name, ExperimentSpec) else spec_or_name)
    if val is None:
        return None
    if isinstance(val, str):
        if val not in _PLANE_DTYPES:
            raise ValueError(f"unknown plane_dtype {val!r}; have "
                             f"{sorted(_PLANE_DTYPES)}")
        val = _PLANE_DTYPES[val]
    dt = jnp.dtype(val)
    if dt not in (jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16)):
        raise ValueError(f"plane_dtype must be f32 or bf16, got {dt}")
    return dt


def _apply_remat(loss_fn, policy: Optional[str]):
    """Wrap ``loss_fn`` in jax.checkpoint per ``spec.remat_policy``.

    The registered algorithms differentiate the loss inside their step
    (``jax.value_and_grad`` in ``_agent_gradient``), so checkpointing the
    loss function itself is exactly "remat around the loss/grad": the
    backward pass recomputes activations instead of keeping the whole
    forward resident -- what makes the models/ stack fit next to eight
    agent-stacked state buffers.
    """
    if policy is None:
        return loss_fn
    if policy == "full":
        return jax.checkpoint(loss_fn)
    if policy == "dots":
        return jax.checkpoint(
            loss_fn, policy=jax.checkpoint_policies.dots_saveable)
    raise ValueError(f"unknown remat_policy {policy!r}; have None, "
                     "'full', 'dots'")


def resolve_wire_format(spec: ExperimentSpec):
    """``spec.wire`` -> a :class:`repro.core.wire_formats.WireFormat` or None.

    'packed_bits' registers the compressor family's bit-packed layout
    (top_k / block_top_k -> bf16+u16 ``topk_bits``; qsgd -> uint32
    ``qsgd_bits`` with the spec's ``levels``) and routes pack/unpack through
    the fused Pallas kernels whenever the comm backend resolves to pallas.
    """
    if spec.wire == "dense":
        return None
    if spec.wire != "packed_bits":
        raise ValueError(f"unknown wire format {spec.wire!r}; have "
                         f"{wire_formats.WIRE_MODES}")
    if spec.gossip_mode not in ("ring", "packed"):
        raise ValueError(
            "wire='packed_bits' needs gossip_mode 'ring' or 'packed' "
            f"(got {spec.gossip_mode!r}); dense gossip ships the dense "
            "emulation by definition")
    use_pallas = resolve_backend(spec.comm_backend) == "pallas"
    if spec.compressor == "qsgd":
        levels = int(spec.compressor_kwargs.get("levels", 16))
        return wire_formats.make_wire_format(
            "qsgd", levels=levels, use_pallas=use_pallas,
            interpret=spec.interpret)
    return wire_formats.make_wire_format(
        spec.compressor, frac=spec.frac, use_pallas=use_pallas,
        interpret=spec.interpret)


def resolve_gamma(spec: ExperimentSpec, topology: Topology,
                  compressor: Compressor,
                  schedule: Optional[TopologySchedule] = None) -> float:
    """The paper's consensus stepsize: gamma_scale * (1 - alpha) * rho.

    Under a time-varying schedule ``alpha`` is the schedule's per-round
    geometric mixing rate (joint_alpha^(1/period)) -- an individual churn
    round may not mix at all, but the window does, and that is the rate
    consensus actually contracts by.  A period-1 schedule reproduces the
    static derivation exactly."""
    if spec.gamma is not None:
        return spec.gamma
    alpha = topology.alpha if schedule is None else schedule.alpha
    gamma = spec.gamma_scale * (1.0 - alpha) * compressor.rho
    if gamma <= 0.0:
        # e.g. low_rank advertises rho=0 (data-dependent contraction):
        # a zero gamma would silently disable gossip and train agents in
        # isolation, so demand an explicit choice instead
        raise ValueError(
            f"derived gamma is 0 (alpha={alpha:.4g}, "
            f"rho={compressor.rho:.4g} for {compressor.name}); pass an "
            "explicit gamma= in the ExperimentSpec")
    return gamma


def _check_fleet_spec(spec: ExperimentSpec, algo: Optional[str] = None):
    """Reject spec combinations the fleet executor cannot honour."""
    if spec.gossip_mode != "dense":
        raise ValueError(
            f"fleet mode applies mixing as one vectorized dense/COO sweep "
            f"over the whole fleet axis; gossip_mode={spec.gossip_mode!r} "
            "is a per-device wire executor -- use gossip_mode='dense'")
    if spec.wire != "dense":
        raise ValueError(
            f"fleet mode ships no per-link packed buffers (the simulated "
            f"fleet axis is device-local); wire={spec.wire!r} -- use "
            "wire='dense'")
    if algo in _PUSH_SUM_ALGOS and spec.n_agents > FLEET_DENSE_GATE:
        raise ValueError(
            f"{algo} initializes its push-sum mirrors from the dense "
            f"round-0 mixing table; fleet mode supports it only at "
            f"n_agents <= {FLEET_DENSE_GATE} (got {spec.n_agents})")


def resolve_fleet_topology(spec: ExperimentSpec):
    """Fleet topology: the ordinary dense resolution at
    n <= FLEET_DENSE_GATE (per-device bit parity), the sparse COO builders
    of :mod:`repro.core.fleet` above it (make_topology's Python O(n^2)
    weight loops and dense eigensolves do not survive n = 100k)."""
    if spec.n_agents <= FLEET_DENSE_GATE:
        return resolve_topology(spec)
    return fleet_topology(spec.topology, spec.n_agents,
                          weights=spec.topology_weights, p=spec.topology_p,
                          seed=spec.topology_seed)


def resolve_fleet_schedule(spec: ExperimentSpec, topology=None):
    """Fleet analogue of :func:`resolve_schedule`: dense resolution below
    the gate, sparse generators ('rotate:...', 'erdos_renyi:...') above.
    Directed (column-stochastic) schedules never take the fleet path."""
    if spec.topology_schedule is None:
        return None
    if spec.n_agents <= FLEET_DENSE_GATE:
        top = topology if isinstance(topology, Topology) else None
        sched = resolve_schedule(spec, top)
        if sched is not None and sched.is_directed:
            raise ValueError(
                "fleet mode mixes with doubly-stochastic tables only; "
                f"{spec.topology_schedule!r} is column-stochastic (push-sum "
                "runs per-device, fleet=False)")
        return sched
    text = spec.topology_schedule
    kind, _, rest = text.partition(":")
    kind = kind.strip()
    if kind == "rotate":
        first, _, more = rest.partition(",")
        if "=" not in first:
            kv = {"kinds": first.strip(), **_parse_schedule_kv(more)}
        else:
            kv = dict(_parse_schedule_kv(rest))
        kinds = [k for k in kv.pop("kinds", "").split("+") if k]
        if not kinds:
            raise ValueError("rotate schedule needs '+'-separated graph "
                             "kinds, e.g. 'rotate:ring+exponential'")
        sched = fleet_rotating_schedule(
            kinds, spec.n_agents,
            weights=kv.pop("weights", spec.topology_weights),
            seed=int(kv.pop("seed", spec.topology_seed)))
    elif kind == "erdos_renyi":
        kv = dict(_parse_schedule_kv(rest))
        degree = kv.pop("degree", None)
        sched = fleet_er_schedule(
            spec.n_agents, period=int(kv.pop("period", 4)),
            degree=None if degree is None else int(degree),
            weights=kv.pop("weights", spec.topology_weights),
            seed=int(kv.pop("seed", spec.topology_seed)))
    else:
        raise ValueError(
            f"fleet mode at n_agents={spec.n_agents} > {FLEET_DENSE_GATE} "
            f"supports the sparse generators 'rotate:...' and "
            f"'erdos_renyi:...'; got {text!r}")
    if kv:
        raise ValueError(f"unknown fleet {kind!r} schedule keys "
                         f"{sorted(kv)} in {text!r}")
    return sched


def build_engine(spec: ExperimentSpec, *,
                 mesh=None, agent_axes: Sequence[str] = ("data",),
                 leaf_specs=None, compress_fn=None,
                 topology: Optional[Topology] = None,
                 schedule: Optional[TopologySchedule] = None) -> CommRound:
    """Comm-round engine for ``spec`` (compressor + mixer + backend).

    The only sanctioned way to get a :class:`CommRound` outside repro.core;
    benchmarks that exercise the engine directly use this instead of wiring
    make_topology/make_mixer/CommRound by hand.

    mesh/leaf_specs/agent_axes feed both the gossip executor (ring/packed
    wire formats) and the engine's pallas path: leaf specs that carry model
    axes switch the fused update to per-shard planes (pack/unpack inside
    shard_map), so ``comm_backend='pallas'`` stays reshard-free on
    tensor-parallel layouts.

    When the spec declares a ``topology_schedule`` (or ``schedule`` is
    passed directly), the mixer is built from the schedule's stacked table
    and the engine's round methods must be fed the absolute round index
    (every registered algorithm passes its state's step counter).
    """
    if spec.fleet:
        _check_fleet_spec(spec)
        top = resolve_fleet_topology(spec) if topology is None else topology
        sched = (resolve_fleet_schedule(spec, top) if schedule is None
                 else schedule)
    else:
        top = resolve_topology(spec) if topology is None else topology
        sched = resolve_schedule(spec, top) if schedule is None else schedule
    comp = resolve_compressor(spec)
    codec = resolve_wire_format(spec)
    if codec is not None and compress_fn is not None:
        raise ValueError(
            "wire='packed_bits' fuses (shard-local) compression with "
            "packing inside the codec executor; a compress_fn override "
            "would be silently ignored -- drop it (launch.steps skips the "
            "shard-local compressor automatically under packed_bits)")
    if spec.fleet:
        mixer = make_fleet_mixer(sched if sched is not None else top)
    else:
        mixer = make_mixer(sched if sched is not None else top,
                           spec.gossip_mode, mesh=mesh, frac=spec.frac,
                           agent_axes=agent_axes, leaf_specs=leaf_specs,
                           codec=codec)
    return CommRound(compressor=comp, mixer=mixer, compress_fn=compress_fn,
                     backend=spec.comm_backend, interpret=spec.interpret,
                     mesh=mesh, leaf_specs=leaf_specs,
                     agent_axes=tuple(agent_axes), overlap=spec.overlap,
                     plane_dtype=resolve_plane_dtype(spec))


def build(spec: ExperimentSpec, loss_fn, *,
          mesh=None, agent_axes: Sequence[str] = ("data",), leaf_specs=None,
          compress_fn=None, topology: Optional[Topology] = None) -> Algorithm:
    """Resolve ``spec`` into a ready-to-train :class:`Algorithm`.

    loss_fn: (params, batch) -> scalar loss, per agent.
    mesh / agent_axes / leaf_specs: sharded-launch hooks, forwarded to the
      gossip executor (required for 'ring'/'packed' wire formats).
    compress_fn: optional (key, tree) -> tree compression override (e.g.
      the shard-local compressor from repro.launch.steps).
    topology: pre-built Topology override; by default the spec's
      topology fields are resolved via make_topology.
    """
    info = algorithm_info(spec.algo)
    loss_fn = _apply_remat(loss_fn, spec.remat_policy)
    top, sched = None, None
    if info.decentralized:
        if spec.fleet:
            _check_fleet_spec(spec, algo=spec.algo)
            top = (resolve_fleet_topology(spec) if topology is None
                   else topology)
            sched = resolve_fleet_schedule(spec, top)
        else:
            top = resolve_topology(spec) if topology is None else topology
            sched = resolve_schedule(spec, top)
        if sched is not None and sched.is_directed \
                and spec.algo not in _PUSH_SUM_ALGOS:
            raise ValueError(
                f"{spec.algo} assumes doubly-stochastic mixing but "
                f"{spec.topology_schedule!r} is column-stochastic "
                "(directed): without push-sum de-biasing the iterates "
                "drift toward the Perron vector -- use algo='dp-csgp' "
                "for directed topologies")
    comp, mixer, engine = None, None, None
    if info.decentralized and info.compressed:
        # the one engine-construction path, shared with microbenchmarks
        engine = build_engine(spec, mesh=mesh, agent_axes=agent_axes,
                              leaf_specs=leaf_specs,
                              compress_fn=compress_fn, topology=top,
                              schedule=sched)
        comp, mixer = engine.compressor, engine.mixer
    elif info.decentralized:
        if spec.fleet:
            mixer = make_fleet_mixer(sched if sched is not None else top)
        else:
            mixer = make_mixer(sched if sched is not None else top,
                               spec.gossip_mode, mesh=mesh, frac=spec.frac,
                               agent_axes=agent_axes, leaf_specs=leaf_specs)
    elif info.compressed:
        # server/client: compression without gossip
        comp = resolve_compressor(spec)
        engine = CommRound(compressor=comp, mixer=None,
                           compress_fn=compress_fn,
                           backend=spec.comm_backend,
                           interpret=spec.interpret,
                           mesh=mesh, leaf_specs=leaf_specs,
                           agent_axes=tuple(agent_axes),
                           plane_dtype=resolve_plane_dtype(spec))
    gamma = None
    if info.decentralized:
        gamma = (resolve_gamma(spec, top, comp, sched) if info.compressed
                 else (1.0 if spec.gamma is None else spec.gamma))
    r = Resolved(info=info, topology=top, compressor=comp, mixer=mixer,
                 engine=engine, gamma=gamma, schedule=sched)
    return get_factory(spec.algo)(spec, loss_fn, r)


def _bind_init(spec: ExperimentSpec, r: Resolved, init_fn):
    """Uniform init(params, n_agents=None, w=None) with spec defaults.

    ``w`` is passed through as given: every init here broadcasts a single
    replica, so W X^0 = X^0 exactly (rows of W sum to 1) and the default
    no-mix path is both correct and free -- materializing topology.w at
    init would cost an O(n^2 d) einsum on the large-model launch path for
    a bit-identical result.
    """

    def init(params, n_agents: Optional[int] = None, w=None):
        n = spec.n_agents if n_agents is None else n_agents
        return init_fn(params, n, w)

    return init


def _algorithm(spec, r, *, state_cls, init, step, config=None) -> Algorithm:
    return Algorithm(name=spec.algo, info=r.info, spec=spec,
                     state_cls=state_cls, init=init, step=step,
                     topology=r.topology, compressor=r.compressor,
                     mixer=r.mixer, engine=r.engine, gamma=r.gamma,
                     config=config, schedule=r.schedule)


# ---------------------------------------------------------------------------
# the eleven registered entry points
# ---------------------------------------------------------------------------

# algorithms that de-bias column-stochastic (directed) mixing correctly;
# everything else is rejected by build() when handed a directed schedule
_PUSH_SUM_ALGOS = frozenset({"dp-csgp"})


def _require_tau(spec: ExperimentSpec) -> float:
    """DP oracles calibrate noise to tau's sensitivity -- no clipping, no
    guarantee -- so tau=None is an error rather than a silent fallback."""
    if spec.tau is None:
        raise ValueError(f"{spec.algo} is a DP algorithm: its Gaussian "
                         "noise is calibrated to the clipping threshold, "
                         "so tau=None (unclipped) would void the privacy "
                         "guarantee -- set a finite tau")
    return spec.tau


def _porter_family(spec: ExperimentSpec, loss_fn, r: Resolved, variant: str,
                   adam: bool = False) -> Algorithm:
    if variant == "gc" and spec.tau is None:
        # unclipped PORTER-GC *is* BEER (paper Section 4.3); routing through
        # beer_config keeps the no-clip point exact instead of feeding
        # tau=inf into the smooth clip factor (inf/(inf+nrm) is NaN)
        variant = "beer"
    # under bf16 planes the stored gradient g_prev is a bf16 buffer, so the
    # fresh gradient must be cast to the same dtype -- otherwise the state's
    # dtype flips between init and step and scan/chunked carries diverge
    pdt = resolve_plane_dtype(spec)
    grad_dtype = spec.buffer_dtype if pdt is None else pdt
    if variant == "beer":
        cfg = beer_config(spec.eta, r.gamma, clip_mode=spec.clip_mode,
                          grad_dtype=grad_dtype)
    else:
        tau = (_require_tau(spec) if variant == "dp"
               else (float("inf") if spec.tau is None else spec.tau))
        cfg = PorterConfig(eta=spec.eta, gamma=r.gamma, tau=tau,
                           variant=variant, clip_mode=spec.clip_mode,
                           sigma_p=spec.sigma_p,
                           grad_dtype=grad_dtype)
    if adam:
        step = functools.partial(porter_adam_step, cfg, loss_fn, None, None,
                                 engine=r.engine, b1=spec.b1, b2=spec.b2,
                                 adam_eps=spec.adam_eps)
        init = _bind_init(
            spec, r, functools.partial(porter_adam_init, plane_dtype=pdt))
        return _algorithm(spec, r, state_cls=PorterAdamState, init=init,
                          step=step, config=cfg)
    step = functools.partial(porter_step, cfg, loss_fn, None, None,
                             engine=r.engine)
    init = _bind_init(
        spec, r,
        functools.partial(porter_init, buffer_dtype=spec.buffer_dtype,
                          plane_dtype=pdt))
    return _algorithm(spec, r, state_cls=PorterState, init=init, step=step,
                      config=cfg)


@register_algorithm("porter-gc", comm_rounds=2)
def _build_porter_gc(spec, loss_fn, r):
    return _porter_family(spec, loss_fn, r, "gc")


@register_algorithm("porter-dp", dp=True, comm_rounds=2)
def _build_porter_dp(spec, loss_fn, r):
    return _porter_family(spec, loss_fn, r, "dp")


@register_algorithm("beer", comm_rounds=2)
def _build_beer(spec, loss_fn, r):
    return _porter_family(spec, loss_fn, r, "beer")


@register_algorithm("porter-adam", comm_rounds=2)
def _build_porter_adam(spec, loss_fn, r):
    return _porter_family(spec, loss_fn, r, "gc", adam=True)


@register_algorithm("dsgd", compressed=False, comm_rounds=1)
def _build_dsgd(spec, loss_fn, r):
    step = functools.partial(BL.dsgd_step, spec.eta, r.gamma, loss_fn,
                             r.mixer, tau=spec.tau, clip_mode=spec.clip_mode,
                             sigma_p=spec.sigma_p, dp=spec.dp)
    init = _bind_init(spec, r, lambda params, n, w: BL.dsgd_init(params, n))
    return _algorithm(spec, r, state_cls=BL.DsgdState, init=init, step=step)


@register_algorithm("choco", comm_rounds=1)
def _build_choco(spec, loss_fn, r):
    step = functools.partial(BL.choco_step, spec.eta, r.gamma, loss_fn,
                             None, None, engine=r.engine, tau=spec.tau,
                             clip_mode=spec.clip_mode)
    pdt = resolve_plane_dtype(spec)
    init = _bind_init(
        spec, r,
        lambda params, n, w: BL.choco_init(params, n, plane_dtype=pdt))
    return _algorithm(spec, r, state_cls=BL.ChocoState, init=init, step=step)


@register_algorithm("dp-sgd", dp=True, decentralized=False, compressed=False)
def _build_dpsgd(spec, loss_fn, r):
    tau = _require_tau(spec)

    def step(state, batch, key):
        # the registry protocol feeds agent-stacked batches (n_agents, b,
        # ...); the central server pools them into one batch of n*b
        # samples.  Validate the contract instead of guessing from ndim.
        lead = {l.shape[0] for l in jax.tree_util.tree_leaves(batch)
                if hasattr(l, "shape") and l.ndim >= 1}
        if lead != {spec.n_agents}:
            raise ValueError(
                f"dp-sgd consumes agent-stacked batches with leading dim "
                f"n_agents={spec.n_agents} (the registry's uniform batch "
                f"layout); got leading dims {sorted(lead)} -- call "
                "repro.core.baselines.dpsgd_step directly for plain "
                "central batches")
        flat = jax.tree_util.tree_map(
            lambda l: l.reshape((-1,) + l.shape[2:]) if l.ndim >= 2 else l,
            batch)
        return BL.dpsgd_step(spec.eta, loss_fn, state, flat, key, tau=tau,
                             clip_mode=spec.clip_mode, sigma_p=spec.sigma_p)

    def init(params, n_agents=None, w=None):
        del n_agents, w  # single server replica
        return BL.dpsgd_init(params)

    return _algorithm(spec, r, state_cls=BL.DpSgdState, init=init, step=step)


@register_algorithm("dp-csgp", dp=True, comm_rounds=2)
def _build_dp_csgp(spec, loss_fn, r):
    tau = _require_tau(spec)
    pdt = resolve_plane_dtype(spec)
    cfg = PorterConfig(eta=spec.eta, gamma=r.gamma, tau=tau, variant="dp",
                       clip_mode=spec.clip_mode, sigma_p=spec.sigma_p,
                       grad_dtype=spec.buffer_dtype if pdt is None else pdt)
    step = functools.partial(dp_csgp_step, cfg, loss_fn, None, None,
                             engine=r.engine)
    # the push-sum mirrors need the actual round-0 matrix (m = W q with a
    # column-stochastic W has no no-mix shortcut -- see dp_csgp_init)
    w0 = r.schedule.ws[0] if r.schedule is not None else r.topology.w
    init = _bind_init(
        spec, r,
        functools.partial(dp_csgp_init, w0=w0,
                          buffer_dtype=spec.buffer_dtype, plane_dtype=pdt))
    return _algorithm(spec, r, state_cls=DpCsgpState, init=init, step=step,
                      config=cfg)


@register_algorithm("clip21", comm_rounds=2)
def _build_clip21(spec, loss_fn, r):
    # clip21 clips the *residual*, always piecewise: the smooth factor
    # tau/(tau+||delta||) never reaches 1, so the EF estimate could never
    # lock onto the gradient (and tau=inf would be NaN) -- see core/clip21
    pdt = resolve_plane_dtype(spec)
    tau = float("inf") if spec.tau is None else spec.tau
    cfg = PorterConfig(eta=spec.eta, gamma=r.gamma, tau=tau, variant="gc",
                       clip_mode="piecewise",
                       grad_dtype=spec.buffer_dtype if pdt is None else pdt)
    step = functools.partial(clip21_step, cfg, loss_fn, None, None,
                             engine=r.engine)
    init = _bind_init(
        spec, r,
        functools.partial(clip21_init, buffer_dtype=spec.buffer_dtype,
                          plane_dtype=pdt))
    return _algorithm(spec, r, state_cls=Clip21State, init=init, step=step,
                      config=cfg)


@register_algorithm("subgrad-comp", comm_rounds=1)
def _build_subgrad(spec, loss_fn, r):
    step = functools.partial(subgrad_step, spec.eta, r.gamma, loss_fn,
                             None, None, engine=r.engine, tau=spec.tau,
                             clip_mode=spec.clip_mode)
    pdt = resolve_plane_dtype(spec)
    init = _bind_init(
        spec, r,
        lambda params, n, w: subgrad_init(params, n, plane_dtype=pdt))
    return _algorithm(spec, r, state_cls=SubgradState, init=init, step=step)


@register_algorithm("soteriafl", dp=True, decentralized=False)
def _build_soteriafl(spec, loss_fn, r):
    tau = _require_tau(spec)
    step = functools.partial(BL.soteria_step, spec.eta, spec.alpha_shift,
                             loss_fn, None, engine=r.engine, tau=tau,
                             clip_mode=spec.clip_mode, sigma_p=spec.sigma_p)
    pdt = resolve_plane_dtype(spec)
    init = _bind_init(
        spec, r,
        lambda params, n, w: BL.soteria_init(params, n, plane_dtype=pdt))
    return _algorithm(spec, r, state_cls=BL.SoteriaState, init=init,
                      step=step)
