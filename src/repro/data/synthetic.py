"""Synthetic datasets (the container is offline; see DESIGN.md).

Faithful stand-ins for the paper's experiment data with matching dimensions:

* ``a9a_like``    -- binary classification, d=123 sparse-ish features (the
                     LIBSVM a9a layout), labels in {0, 1}; used by the
                     logistic-regression + nonconvex-regularizer experiment
                     (paper Section 5.1).
* ``mnist_like``  -- 10-class 784-dim images with class-dependent Gaussian
                     means (paper Section 5.2's one-hidden-layer MLP).
* ``token_stream``-- integer LM token batches for the model-zoo training
                     path (agent-sharded, deterministic per agent/step).

Everything is a pure function of (seed, shapes): every agent regenerates its
own shard deterministically, which is exactly how a decentralized system
avoids a data server.
"""

from __future__ import annotations

import dataclasses
from typing import Iterator, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "a9a_like", "mnist_like", "shard_to_agents", "agent_batch_iterator",
    "token_batch",
]


def a9a_like(num: int = 32561, dim: int = 123, seed: int = 0,
             sparsity: float = 0.11) -> Tuple[np.ndarray, np.ndarray]:
    """Binary classification with a planted linear signal + label noise.

    a9a is ~11% dense binary features; we mimic that so gradient scales (and
    hence clipping behaviour) are comparable.
    """
    rng = np.random.default_rng(seed)
    x = (rng.random((num, dim)) < sparsity).astype(np.float32)
    w_star = rng.normal(size=(dim,)).astype(np.float32)
    logits = x @ w_star / np.sqrt(dim * sparsity)
    p = 1.0 / (1.0 + np.exp(-4.0 * logits))
    y = (rng.random(num) < p).astype(np.float32)
    return x, y


def mnist_like(num: int = 60000, dim: int = 784, classes: int = 10,
               seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """10-class images: class-dependent smooth means + pixel noise in [0,1]."""
    rng = np.random.default_rng(seed)
    # smooth class prototypes: random low-frequency mixtures
    freq = rng.normal(size=(classes, 8, dim)).astype(np.float32)
    coef = rng.normal(size=(classes, 8, 1)).astype(np.float32)
    protos = np.tanh((freq * coef).sum(axis=1) / 4.0) * 0.5 + 0.5
    y = rng.integers(0, classes, size=num)
    x = protos[y] + 0.25 * rng.normal(size=(num, dim)).astype(np.float32)
    x = np.clip(x, 0.0, 1.0).astype(np.float32)
    return x, y.astype(np.int32)


def shard_to_agents(x: np.ndarray, y: np.ndarray, n_agents: int,
                    seed: int = 0) -> Tuple[np.ndarray, np.ndarray]:
    """Shuffle and split evenly across agents (paper Section 5 protocol).

    Returns arrays with a leading (n_agents, m) layout; m = num // n_agents.
    """
    rng = np.random.default_rng(seed)
    perm = rng.permutation(len(x))
    m = len(x) // n_agents
    keep = perm[: m * n_agents]
    xs = x[keep].reshape(n_agents, m, *x.shape[1:])
    ys = y[keep].reshape(n_agents, m, *y.shape[1:])
    return xs, ys


def agent_batch_iterator(xs: np.ndarray, ys: np.ndarray, batch: int,
                         seed: int = 0) -> Iterator[Tuple[jnp.ndarray, jnp.ndarray]]:
    """Yields (n_agents, batch, ...) mini-batches, iid uniform per agent
    (paper line 4: 'Draw the local mini-batch of size b uniformly at
    random')."""
    n_agents, m = xs.shape[0], xs.shape[1]
    rng = np.random.default_rng(seed)
    while True:
        idx = rng.integers(0, m, size=(n_agents, batch))
        xb = np.take_along_axis(
            xs, idx.reshape(n_agents, batch, *([1] * (xs.ndim - 2))), axis=1)
        yb = np.take_along_axis(
            ys, idx.reshape(n_agents, batch, *([1] * (ys.ndim - 2))), axis=1)
        yield jnp.asarray(xb), jnp.asarray(yb)


def token_batch(key: jax.Array, n_agents: int, batch: int, seq: int,
                vocab: int) -> jnp.ndarray:
    """Deterministic synthetic LM tokens: (n_agents, batch, seq) int32."""
    return jax.random.randint(key, (n_agents, batch, seq), 0, vocab,
                              dtype=jnp.int32)
