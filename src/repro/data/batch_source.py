"""On-device batch sources: pure ``(key, step) -> batch`` synthesis.

A :class:`~repro.launch.runtime.BatchSource` is the chunked runtime's data
contract -- a pure, jit-traceable function of a PRNG key and the absolute
round index.  Because the source runs *inside* the compiled program, the
scan-fused chunk runner synthesizes every round's batch on device with
zero host round trips (the old per-step loops built batches host-side and
shipped them through each dispatch).

* :func:`batch_source` -- family-aware synthetic streams for the model-zoo
  configs (tokens / vision-language / encoder-decoder); this is the logic
  that used to live in ``repro.launch.train.make_train_batch``.
* :func:`minibatch_source` -- iid uniform per-agent minibatches from an
  agent-sharded dataset held on device (paper Section 5 line 4: "Draw the
  local mini-batch of size b uniformly at random"), the on-device
  replacement for :func:`repro.data.agent_batch_iterator`.

Both ignore ``step`` -- their streams are iid in the key -- but take it so
deterministic sources (epoch schedules, curricula) fit the same protocol.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .synthetic import token_batch

__all__ = ["batch_source", "minibatch_source"]


def batch_source(cfg, n_agents: int, batch: int, seq: int):
    """Family-aware synthetic BatchSource for a model-zoo config.

    Returns agent-stacked batches with the same layout the train driver
    always fed ``bundle.loss``: ``tokens (n_agents, b, s)`` int32, plus
    ``patches`` / ``frames`` float32 for the vlm / encdec families.
    """
    if cfg.family == "vlm":
        def source(key, step):
            del step
            k1, k2 = jax.random.split(key)
            return {"tokens": token_batch(k1, n_agents, batch,
                                          seq - cfg.n_prefix, cfg.vocab),
                    "patches": jax.random.normal(
                        k2, (n_agents, batch, cfg.n_prefix,
                             cfg.frontend_dim))}
    elif cfg.family == "encdec":
        def source(key, step):
            del step
            k1, k2 = jax.random.split(key)
            return {"frames": jax.random.normal(
                        k1, (n_agents, batch, seq, cfg.frontend_dim)),
                    "tokens": token_batch(k2, n_agents, batch, seq,
                                          cfg.vocab)}
    else:
        def source(key, step):
            del step
            return {"tokens": token_batch(key, n_agents, batch, seq,
                                          cfg.vocab)}
    return source


def minibatch_source(xs, ys, batch: int):
    """Uniform iid per-agent minibatches from an agent-sharded dataset.

    xs / ys: ``(n_agents, m, ...)`` arrays (e.g. from
    :func:`repro.data.shard_to_agents`); they are moved to device once at
    construction.  Each call draws ``batch`` indices uniformly per agent
    and gathers ``(n_agents, batch, ...)`` feature/label stacks entirely
    on device.
    """
    xs = jnp.asarray(xs)
    ys = jnp.asarray(ys)
    n_agents, m = xs.shape[0], xs.shape[1]

    def source(key, step):
        del step
        idx = jax.random.randint(key, (n_agents, batch), 0, m)
        take = jax.vmap(lambda data, i: jnp.take(data, i, axis=0))
        return take(xs, idx), take(ys, idx)

    return source
