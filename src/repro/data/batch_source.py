"""On-device batch sources: pure ``(key, step) -> batch`` synthesis.

A :class:`~repro.launch.runtime.BatchSource` is the chunked runtime's data
contract -- a pure, jit-traceable function of a PRNG key and the absolute
round index.  Because the source runs *inside* the compiled program, the
scan-fused chunk runner synthesizes every round's batch on device with
zero host round trips (the old per-step loops built batches host-side and
shipped them through each dispatch).

* :func:`batch_source` -- family-aware synthetic streams for the model-zoo
  configs (tokens / vision-language / encoder-decoder); this is the logic
  that used to live in ``repro.launch.train.make_train_batch``.
* :func:`minibatch_source` -- iid uniform per-agent minibatches from an
  agent-sharded dataset held on device (paper Section 5 line 4: "Draw the
  local mini-batch of size b uniformly at random"), the on-device
  replacement for :func:`repro.data.agent_batch_iterator`.

Both ignore ``step`` -- their streams are iid in the key -- but take it so
deterministic sources (epoch schedules, curricula) fit the same protocol.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from .synthetic import token_batch

__all__ = ["batch_source", "minibatch_source", "dirichlet_partition",
           "dirichlet_source"]


def batch_source(cfg, n_agents: int, batch: int, seq: int):
    """Family-aware synthetic BatchSource for a model-zoo config.

    Returns agent-stacked batches with the same layout the train driver
    always fed ``bundle.loss``: ``tokens (n_agents, b, s)`` int32, plus
    ``patches`` / ``frames`` float32 for the vlm / encdec families.
    """
    if cfg.family == "vlm":
        def source(key, step):
            del step
            k1, k2 = jax.random.split(key)
            return {"tokens": token_batch(k1, n_agents, batch,
                                          seq - cfg.n_prefix, cfg.vocab),
                    "patches": jax.random.normal(
                        k2, (n_agents, batch, cfg.n_prefix,
                             cfg.frontend_dim))}
    elif cfg.family == "encdec":
        def source(key, step):
            del step
            k1, k2 = jax.random.split(key)
            return {"frames": jax.random.normal(
                        k1, (n_agents, batch, seq, cfg.frontend_dim)),
                    "tokens": token_batch(k2, n_agents, batch, seq,
                                          cfg.vocab)}
    else:
        def source(key, step):
            del step
            return {"tokens": token_batch(key, n_agents, batch, seq,
                                          cfg.vocab)}
    return source


def minibatch_source(xs, ys, batch: int):
    """Uniform iid per-agent minibatches from an agent-sharded dataset.

    xs / ys: ``(n_agents, m, ...)`` arrays (e.g. from
    :func:`repro.data.shard_to_agents`); they are moved to device once at
    construction.  Each call draws ``batch`` indices uniformly per agent
    and gathers ``(n_agents, batch, ...)`` feature/label stacks entirely
    on device.
    """
    xs = jnp.asarray(xs)
    ys = jnp.asarray(ys)
    n_agents, m = xs.shape[0], xs.shape[1]

    def source(key, step):
        del step
        idx = jax.random.randint(key, (n_agents, batch), 0, m)
        take = jax.vmap(lambda data, i: jnp.take(data, i, axis=0))
        return take(xs, idx), take(ys, idx)

    return source


def dirichlet_partition(xs, ys, n_agents: int, alpha: float = 0.3,
                        shard: int = 0, seed: int = 0):
    """Heterogeneous per-agent shards: class mixture ~ Dirichlet(alpha).

    The standard federated-learning non-iid protocol [HQB19]: each agent i
    draws a class-mixture vector p_i ~ Dirichlet(alpha * 1) and fills an
    equal-size shard of ``shard`` samples whose class counts follow
    Multinomial(shard, p_i); samples are drawn (with replacement, so a
    popular class on a small dataset still fills its quota) uniformly from
    that class's pool.  ``alpha -> inf`` recovers iid shards,
    ``alpha -> 0`` approaches one-class-per-agent pathology -- the axis
    the fleet ablation sweeps heterogeneity on.

    Host-side numpy (runs once at setup, scales to n = 100k agents as a
    pure O(n * shard) sample-index build); returns
    ``(n_agents, shard, ...)`` stacks ready for
    :func:`minibatch_source`.
    """
    xs = np.asarray(xs)
    ys = np.asarray(ys)
    if xs.shape[0] != ys.shape[0]:
        raise ValueError(f"xs/ys disagree on dataset size: "
                         f"{xs.shape[0]} vs {ys.shape[0]}")
    if alpha <= 0.0:
        raise ValueError(f"Dirichlet concentration must be > 0, got {alpha}")
    labels = ys.reshape(ys.shape[0], -1)[:, 0]
    # binary +/-1 labels (a9a_like) and 0..K-1 ints both map to classes
    classes, class_ids = np.unique(labels, return_inverse=True)
    pools = [np.nonzero(class_ids == c)[0] for c in range(classes.size)]
    shard = int(shard) if shard else max(xs.shape[0] // n_agents, 1)
    rng = np.random.default_rng(seed)
    mix = rng.dirichlet(np.full(classes.size, alpha), size=n_agents)
    idx = np.empty((n_agents, shard), dtype=np.int64)
    for i in range(n_agents):
        counts = rng.multinomial(shard, mix[i])
        cursor = 0
        for c, cnt in enumerate(counts):
            if cnt:
                idx[i, cursor:cursor + cnt] = rng.choice(pools[c], size=cnt,
                                                         replace=True)
                cursor += cnt
        rng.shuffle(idx[i])
    return xs[idx], ys[idx]


def dirichlet_source(xs, ys, n_agents: int, batch: int, alpha: float = 0.3,
                     shard: int = 0, seed: int = 0):
    """Dirichlet-heterogeneous BatchSource: :func:`dirichlet_partition`
    composed with :func:`minibatch_source` (the fleet quickstart's data
    path -- per-agent non-iid shards, on-device minibatching)."""
    sx, sy = dirichlet_partition(xs, ys, n_agents, alpha=alpha, shard=shard,
                                 seed=seed)
    return minibatch_source(sx, sy, batch)
