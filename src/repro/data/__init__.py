"""Synthetic, agent-sharded data pipelines (offline container)."""
from .batch_source import (batch_source, dirichlet_partition,
                           dirichlet_source, minibatch_source)
from .synthetic import (a9a_like, agent_batch_iterator, mnist_like,
                        shard_to_agents, token_batch)

__all__ = ["a9a_like", "mnist_like", "shard_to_agents",
           "agent_batch_iterator", "token_batch", "batch_source",
           "minibatch_source", "dirichlet_partition", "dirichlet_source"]
