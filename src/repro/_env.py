"""Process-environment knobs that must be set before jax backend init.

jax locks the host device count at first backend initialization, so any
driver that wants forced host devices (dry-run sweeps, sharded CPU
benchmarks) has to mutate XLA_FLAGS before anything queries a device.
This module is deliberately jax-free (and importable through the
docstring-only ``repro`` package root) so callers can import it first,
then import jax.

One shared implementation instead of a copy per driver: the append/defer
precedence rule lives here only.
"""

from __future__ import annotations

import os

__all__ = ["ensure_host_device_count"]

_FLAG = "--xla_force_host_platform_device_count"


def ensure_host_device_count(n: int) -> None:
    """Force ``n`` host platform devices unless the caller already chose.

    Appends to any user-provided XLA_FLAGS (never clobbers them) and
    defers entirely when a host-device count is already present -- running
    a driver under an outer harness that set its own count keeps the outer
    choice.  A no-op after jax backend init (the count is locked); call
    before importing anything that might initialize jax.
    """
    existing = os.environ.get("XLA_FLAGS", "")
    if _FLAG in existing:
        return
    os.environ["XLA_FLAGS"] = f"{existing} {_FLAG}={n}".strip()
