"""DP-CSGP: differentially-private compressed gossip over *directed* graphs.

The paper's recipe (per-sample clipping + Gaussian perturbation + compressed
error-feedback gossip, Algorithm 1 Option I) assumes a doubly-stochastic
mixing matrix -- every agent hears exactly the agents it is heard by.  Real
fleets lose links one way at a time; DP-CSGP (arXiv 2512.13583, PAPERS.md)
extends the recipe to directed, possibly unbalanced graphs via
**column-stochastic** weights and **push-sum** correction:

* Each agent carries a scalar push-sum weight ``xw_i`` (init 1) mixed with
  the *same* column-stochastic ``W_t`` as the parameters.  Column sums of 1
  conserve total mass (``1^T W = 1^T``), so while the raw iterates drift
  toward the graph's Perron vector, the de-biased ratio ``z = x / xw`` stays
  an unbiased consensus estimate -- gradients are evaluated at ``z``, not
  ``x``.
* The weight plane runs the *same* EF/gossip recursion as the params
  (surrogate ``q_w``, mirror ``m_w``) but its increment is **never
  compressed**: ``cw = xw - q_w`` exactly.  Compressing it would break the
  column-mass invariant the de-biasing relies on.  The composed weight
  update is ``xw' = ((1-gamma) I + gamma W_t) xw`` -- still
  column-stochastic, so weights stay strictly positive and converge to
  ``n * pi`` (the Perron vector of the window product).

State: :class:`PorterState`'s buffers plus the three ``(n,)`` weight planes
(``xw``, ``q_w``, ``m_w``).  Communication and both fused updates are
delegated to :meth:`repro.core.comm_round.CommRound.step_ps`, whose
executors ship the weight inside the collectives the param round already
issues (an extra flat column for dense/ring, +4 bitcast bytes on codec
buffers) -- directed gossip adds zero communication ops.

Reduction sanity: with a doubly-stochastic ``W`` (row sums 1 too) the
weight increments are identically zero, ``xw`` stays exactly 1, and
``z = x / 1`` is bit-identical to ``x`` -- DP-CSGP's trajectory coincides
with PORTER-DP's (pinned by tests/test_push_sum.py).
"""

from __future__ import annotations

import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import clipping
from .comm_round import CommRound, resolve_engine
from .compression import Compressor
from .gossip import MixFn, make_dense_mixer
from .porter import (LossFn, PorterConfig, _agent_gradient, consensus_error)

__all__ = [
    "DpCsgpState",
    "dp_csgp_init",
    "dp_csgp_step",
    "debias",
]

# Push-sum weights are strictly positive in exact arithmetic (positive
# diagonals keep every agent a fraction of its own mass); the floor only
# guards the division against fp underflow on pathologically long windows.
_WEIGHT_FLOOR = 1e-12


class DpCsgpState(NamedTuple):
    x: Any
    v: Any
    q_x: Any
    q_v: Any
    g_prev: Any
    m_x: Any
    m_v: Any
    xw: jax.Array     # (n,) push-sum weights
    q_w: jax.Array    # (n,) weight surrogate (EF)
    m_w: jax.Array    # (n,) weight mixing mirror
    step: jax.Array


def debias(x, xw):
    """z = x / xw, broadcasting the (n,) weight over each leaf's agent axis.

    With ``xw`` exactly 1 (doubly-stochastic mixing) this is bit-identity
    (IEEE division by 1.0), which is what makes the PORTER-DP reduction
    exact.
    """
    w = jnp.maximum(xw.astype(jnp.float32), _WEIGHT_FLOOR)
    return jax.tree_util.tree_map(
        lambda l: (l / w.reshape((-1,) + (1,) * (l.ndim - 1))
                   .astype(l.dtype)).astype(l.dtype), x)


def _zeros_like_f(tree, dtype):
    return jax.tree_util.tree_map(lambda l: jnp.zeros(l.shape, dtype), tree)


def dp_csgp_init(params: Any, n_agents: int, w: Optional[np.ndarray] = None,
                 w0: Optional[np.ndarray] = None,
                 buffer_dtype: Any = jnp.float32,
                 plane_dtype: Any = None) -> DpCsgpState:
    """Initialize from a single replica; X^0 = x0 1^T, weights all 1.

    Unlike :func:`repro.core.porter.porter_init`, the mirrors *must* be
    materialized against the actual round-0 matrix: ``m = W q`` with
    ``q_x = x0 1^T`` and ``q_w = 1`` gives ``m_x = W x0 1^T`` and
    ``m_w = W 1`` -- the no-mix shortcut (``m_x = x``) assumes row sums of
    1, which column-stochastic tables do not have.  ``w0`` is the resolved
    round-0 matrix (the facade passes ``schedule.ws[0]`` / ``topology.w``);
    an explicit ``w`` from the registry's uniform ``init(params, n, w)``
    protocol takes precedence.  With neither, the doubly-stochastic
    shortcut applies (and is exact for every undirected topology).

    ``plane_dtype``: storage dtype for the param-sized EF buffers (see
    :func:`repro.core.porter.porter_init`).  The three (n,) push-sum weight
    planes (xw, q_w, m_w) always stay f32 -- rounding the de-biasing mass
    would break the column-mass invariant ``1^T xw = n``.
    """
    x = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, (n_agents,) + p.shape), params)
    pdt = None if plane_dtype is None else jnp.dtype(plane_dtype)
    zeros = _zeros_like_f(x, buffer_dtype if pdt is None else pdt)
    ones = jnp.ones((n_agents,), jnp.float32)
    weff = w if w is not None else w0
    if weff is None:
        m_x, m_w = x, ones
    else:
        weff = np.asarray(weff, np.float64)
        if weff.ndim == 3:           # a stacked schedule table: round 0
            weff = weff[0]
        m_x = make_dense_mixer(weff)(x)
        m_w = jnp.asarray(weff.sum(axis=1), jnp.float32)  # W @ 1 (row sums)
    q_x = x
    if pdt is not None:
        q_x = jax.tree_util.tree_map(lambda l: l.astype(pdt), x)
        m_x = jax.tree_util.tree_map(lambda l: l.astype(pdt), m_x)
    return DpCsgpState(x=x, v=zeros, q_x=q_x, q_v=zeros, g_prev=zeros,
                       m_x=m_x, m_v=zeros, xw=ones, q_w=ones, m_w=m_w,
                       step=jnp.zeros((), jnp.int32))


def dp_csgp_step(
    cfg: PorterConfig,
    loss_fn: LossFn,
    mixer: Optional[MixFn],
    compressor: Optional[Compressor],
    state: DpCsgpState,
    batch: Any,
    key: jax.Array,
    compress_fn=None,
    engine: Optional[CommRound] = None,
) -> Tuple[DpCsgpState, Dict[str, jax.Array]]:
    """One DP-CSGP iteration over all agents (pure; jit/pjit-able).

    Identical to :func:`repro.core.porter.porter_step` except (1) the
    gradient oracle evaluates at the de-biased point ``z = x / xw``, (2) the
    x-side round is the push-sum :meth:`CommRound.step_ps` carrying the
    weight planes, and (3) ``wire_bytes`` charges the weight's extra bytes
    on the x stream.  The v-side (gradient-tracking) round needs no
    de-biasing -- tracking accumulates gradient *differences*, which the
    column-stochastic mix conserves in total mass like any other mass.
    """
    eng = resolve_engine(engine, mixer, compressor, compress_fn)
    n = jax.tree_util.tree_leaves(state.x)[0].shape[0]
    _, k_noise, k_cv, k_cx = jax.random.split(key, 4)

    # ---- stochastic gradients at the de-biased consensus estimate ---------
    z = debias(state.x, state.xw)
    agent_keys = jax.random.split(k_noise, n)
    grad_fn = functools.partial(_agent_gradient, cfg, loss_fn)
    losses, g = jax.vmap(grad_fn)(z, batch, agent_keys)
    g = jax.tree_util.tree_map(lambda l: l.astype(cfg.grad_dtype), g)

    # ---- comm rounds: plain track + push-sum step -------------------------
    if eng.overlap:
        # same overlap legality as PORTER: the x-side exchange reads only
        # (x, q_x, xw, q_w), which the v-side update never touches
        k_cv, sr_v = eng.sr_split(k_cv, (state.q_v, state.m_v, state.v))
        k_cx, sr_x = eng.sr_split(k_cx, (state.q_x, state.m_x, state.x))
        c_v, wc_v = eng.exchange(k_cv, state.v, state.q_v, t=state.step)
        c_x, wc_x, cw, wcw = eng.exchange_ps(
            k_cx, state.x, state.q_x, state.xw, state.q_w, t=state.step)
        v, q_v, m_v = eng.track_update(c_v, wc_v, state.v, state.q_v,
                                       state.m_v, g, state.g_prev, cfg.gamma,
                                       sr_key=sr_v)
        x, q_x, m_x, xw, q_w, m_w = eng.step_ps_update(
            c_x, wc_x, cw, wcw, state.x, state.q_x, state.m_x, v,
            state.xw, state.q_w, state.m_w, cfg.gamma, cfg.eta, sr_key=sr_x)
    else:
        v, q_v, m_v = eng.track(k_cv, state.v, state.q_v, state.m_v, g,
                                state.g_prev, cfg.gamma, t=state.step)
        x, q_x, m_x, xw, q_w, m_w = eng.step_ps(
            k_cx, state.x, state.q_x, state.m_x, v, state.xw, state.q_w,
            state.m_w, cfg.gamma, cfg.eta, t=state.step)

    new_state = DpCsgpState(x=x, v=v, q_x=q_x, q_v=q_v, g_prev=g,
                            m_x=m_x, m_v=m_v, xw=xw, q_w=q_w, m_w=m_w,
                            step=state.step + 1)
    metrics = {
        "loss": jnp.mean(losses),
        # consensus on the de-biased estimates: the raw x drift toward the
        # Perron vector is push-sum working, not disagreement
        "consensus_x": consensus_error(debias(x, xw)),
        "consensus_v": consensus_error(v),
        "v_norm": clipping.tree_global_norm(v) / np.sqrt(n),
        # v stream is a plain round, x stream carries the weight plane
        "wire_bytes": jnp.asarray(
            eng.wire_bytes(state.x)
            + eng.wire_bytes(state.x, push_sum=True), jnp.float32),
    }
    return new_state, metrics
