"""Communication graphs and mixing (gossip) matrices: doubly-stochastic
undirected mixing (paper Definition 1) and column-stochastic directed
mixing for push-sum (DP-CSGP).

Undirected graphs carry a doubly stochastic W (W 1 = 1, W^T 1 = 1, w_ij = 0
off the graph); the mixing rate is alpha = || W - (1/n) 11^T ||_op.  Graph
builders return symmetric adjacency matrices (numpy, host-side -- a few
hundred entries, feeding compile-time constants).  Weight schemes:

* ``metropolis``      w_ij = 1/(1 + max(deg_i, deg_j)) -- doubly stochastic.
* ``best_constant``   W = I - (2 / (lam_1(L) + lam_{n-1}(L))) L -- the
                      fastest constant-edge-weight matrix [XB04 Thm/closed
                      form].  This is our offline surrogate for the paper's
                      FDLA matrix (FDLA proper needs an SDP solver); it may
                      carry negative entries, which the paper's analysis
                      explicitly allows.
* ``lazy``            (I + W)/2 of the metropolis matrix.

Directed graphs carry a *column*-stochastic W only (1^T W = 1^T; rows need
not sum to 1): node j splits unit mass equally over its out-neighbours
(self-loop included), ``w_ij = 1/outdeg_j`` for every edge j -> i.  The
adjacency convention everywhere is ``A[i, j] = 1  <=>  edge j -> i`` --
consistent with ``x_new = W @ x`` delivering j's mass to i.  Column
stochasticity conserves column mass (sums over agents), which is exactly
what the push-sum weight plane and gradient-tracking invariants need; the
de-bias happens at read points (``x_i / w_i``), not in W.

All functions are deterministic given a seed so that experiments are
reproducible across processes/agents.

Time-varying topologies: :class:`TopologySchedule` stacks a periodic window
of mixing matrices ``W_0 .. W_{p-1}`` built by a generator.  Round ``t`` of
training mixes with ``W_{t mod p}``.  The registered generators, and which
stochasticity each one produces (see ``SCHEDULE_STOCHASTICITY``):

* doubly stochastic (undirected): ``rotate`` (graph rotation),
  ``erdos_renyi`` (per-round resampling), ``dropout`` (agent churn),
  ``straggler`` (symmetric link failures) -- plus ``static`` wrapping a
  built :class:`Topology`.
* column stochastic (directed, push-sum): ``ring_skips`` (directed ring
  with skip chords), ``digraph`` (per-round random digraph), ``one_way``
  (directed churn: each directed link drops independently -- an agent can
  hear you while you can't hear it).

Construction validates the window: doubly stochastic schedules need a
*connected* union graph and report the joint spectral quantities of the
window product ``(W_{p-1} - J) ... (W_0 - J)`` (``J = 11^T/n``); directed
schedules need a *strongly connected* union digraph and report the joint
contraction factor -- the second-largest eigenvalue modulus of the window
product ``W_{p-1} ... W_0`` (the Perron root 1 excluded), the quantity
push-sum consensus actually contracts by.  The executors in
:mod:`repro.core.gossip` index the stacked table with a traced round
index, so one compiled program serves the whole schedule.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional, Sequence, Tuple

import numpy as np

try:  # scipy is a jax dependency, but keep a numpy-only fallback anyway
    from scipy.sparse.linalg import LinearOperator as _LinOp
    from scipy.sparse.linalg import eigs as _eigs
    from scipy.sparse.linalg import eigsh as _eigsh
except Exception:  # pragma: no cover - exercised only without scipy
    _LinOp = _eigs = _eigsh = None

__all__ = [
    "Topology",
    "TopologySchedule",
    "ring_graph",
    "torus_graph",
    "erdos_renyi_graph",
    "complete_graph",
    "star_graph",
    "exponential_graph",
    "hypercube_graph",
    "build_adjacency",
    "mixing_matrix",
    "mixing_rate",
    "spectral_gap",
    "contraction_factor",
    "make_topology",
    "static_schedule",
    "rotating_schedule",
    "erdos_renyi_schedule",
    "dropout_schedule",
    "straggler_schedule",
    "directed_ring_graph",
    "column_stochastic_matrix",
    "directed_ring_schedule",
    "random_digraph_schedule",
    "directed_churn_schedule",
    "make_schedule",
    "SCHEDULE_STOCHASTICITY",
    "VALIDATE_DENSE_GATE",
    "mixing_rate_power",
    "joint_window_alpha",
    "joint_window_contraction",
    "union_connected",
]

# n above which schedule validation switches from dense linear algebra
# (O(n^3) SVD / eigvals / window products) to matvec power iteration and
# edge-list BFS.  tests/test_topology_schedule.py pins dense/sparse
# agreement on every generator at n = 64.
VALIDATE_DENSE_GATE = 256

GraphKind = Literal["ring", "torus", "erdos_renyi", "complete", "star",
                    "exponential", "hypercube"]
WeightKind = Literal["metropolis", "best_constant", "lazy"]


def ring_graph(n: int) -> np.ndarray:
    a = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        a[i, (i + 1) % n] = a[(i + 1) % n, i] = 1.0
    if n == 2:
        a = np.minimum(a, 1.0)
    np.fill_diagonal(a, 0.0)
    return a


def torus_graph(n: int) -> np.ndarray:
    """2D torus on the most-square factorization of n."""
    r = int(np.sqrt(n))
    while n % r:
        r -= 1
    c = n // r
    a = np.zeros((n, n), dtype=np.float64)

    def node(i, j):
        return (i % r) * c + (j % c)

    for i in range(r):
        for j in range(c):
            u = node(i, j)
            for v in (node(i + 1, j), node(i, j + 1)):
                if u != v:
                    a[u, v] = a[v, u] = 1.0
    return a


def erdos_renyi_graph(n: int, p: float, seed: int = 0) -> np.ndarray:
    """ER(p) graph; re-sample until connected (as in the paper's setup, p=0.8)."""
    rng = np.random.default_rng(seed)
    for _ in range(1000):
        a = (rng.random((n, n)) < p).astype(np.float64)
        a = np.triu(a, 1)
        a = a + a.T
        if _is_connected(a):
            return a
    raise RuntimeError("could not sample a connected ER graph")


def complete_graph(n: int) -> np.ndarray:
    a = np.ones((n, n), dtype=np.float64)
    np.fill_diagonal(a, 0.0)
    return a


def exponential_graph(n: int) -> np.ndarray:
    """One-peer exponential graph: i ~ i +- 2^k (mod n) -- O(log n) degree
    with O(log n)-hop diameter; the standard large-n decentralized topology
    (e.g. SGP [ALBR19])."""
    a = np.zeros((n, n), dtype=np.float64)
    k = 1
    while k < n:
        for i in range(n):
            a[i, (i + k) % n] = a[(i + k) % n, i] = 1.0
        k *= 2
    np.fill_diagonal(a, 0.0)
    return a


def hypercube_graph(n: int) -> np.ndarray:
    """Hypercube on n = 2^m nodes (i ~ j iff popcount(i^j) == 1)."""
    if n & (n - 1):
        raise ValueError(f"hypercube needs a power-of-two size, got {n}")
    a = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        for b in range(n.bit_length() - 1):
            j = i ^ (1 << b)
            a[i, j] = a[j, i] = 1.0
    return a


def star_graph(n: int) -> np.ndarray:
    a = np.zeros((n, n), dtype=np.float64)
    a[0, 1:] = a[1:, 0] = 1.0
    return a


def _is_connected(a: np.ndarray) -> bool:
    n = a.shape[0]
    seen = {0}
    frontier = [0]
    while frontier:
        u = frontier.pop()
        for v in np.nonzero(a[u])[0]:
            if v not in seen:
                seen.add(int(v))
                frontier.append(int(v))
    return len(seen) == n


def build_adjacency(kind: GraphKind, n: int, p: float = 0.8,
                    seed: int = 0) -> np.ndarray:
    if kind == "ring":
        return ring_graph(n)
    if kind == "torus":
        return torus_graph(n)
    if kind == "erdos_renyi":
        return erdos_renyi_graph(n, p, seed)
    if kind == "complete":
        return complete_graph(n)
    if kind == "star":
        return star_graph(n)
    if kind == "exponential":
        return exponential_graph(n)
    if kind == "hypercube":
        return hypercube_graph(n)
    raise ValueError(f"unknown graph kind {kind!r}")


def mixing_matrix(adj: np.ndarray, weights: WeightKind = "metropolis") -> np.ndarray:
    n = adj.shape[0]
    deg = adj.sum(axis=1)
    if weights in ("metropolis", "lazy"):
        w = np.zeros_like(adj)
        for i in range(n):
            for j in np.nonzero(adj[i])[0]:
                w[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        np.fill_diagonal(w, 1.0 - w.sum(axis=1))
        if weights == "lazy":
            w = 0.5 * (np.eye(n) + w)
        return w
    if weights == "best_constant":
        lap = np.diag(deg) - adj
        lam = np.sort(np.linalg.eigvalsh(lap))  # ascending, lam[0] ~ 0
        eps = 2.0 / (lam[-1] + lam[1])
        return np.eye(n) - eps * lap
    raise ValueError(f"unknown weight kind {weights!r}")


def mixing_rate(w: np.ndarray) -> float:
    """alpha = || W - 11^T/n ||_op (Definition 1)."""
    n = w.shape[0]
    m = w - np.ones((n, n)) / n
    return float(np.linalg.norm(m, ord=2))


def spectral_gap(w: np.ndarray) -> float:
    """1 - alpha: the gap PORTER's rates are parameterized by (Theorems 2-4).

    For the symmetric mixing matrices built here this equals
    ``1 - max |lambda_i(W - J)|`` (tests/test_topology_schedule.py pins the
    agreement against dense ``numpy.linalg.eigvals``)."""
    return 1.0 - mixing_rate(w)


def contraction_factor(w: np.ndarray) -> float:
    """Second-largest eigenvalue modulus of a (column-)stochastic matrix.

    The Perron root 1 is excluded (one eigenvalue closest to 1 is dropped);
    what remains bounds how fast the relative disagreement -- and the
    push-sum weight plane -- contracts per application of W.  For the
    symmetric doubly stochastic matrices built here this coincides with
    :func:`mixing_rate` (W - J has the same non-Perron spectrum); for
    directed column-stochastic W the operator norm of W - J can exceed 1
    even when W mixes, so the eigenvalue modulus is the honest report.  A
    matrix whose eigenvalue 1 is not simple (e.g. a disconnected round)
    returns 1.0.
    """
    ev = np.linalg.eigvals(np.asarray(w, np.float64))
    perron = int(np.argmin(np.abs(ev - 1.0)))
    rest = np.delete(ev, perron)
    if rest.size == 0:
        return 0.0
    return float(np.max(np.abs(rest)))


def _is_strongly_connected(a: np.ndarray) -> bool:
    """Strong connectivity of the digraph ``A[i, j] = 1 <=> j -> i``:
    node 0 reaches everyone (BFS on A^T) and everyone reaches node 0
    (BFS on A)."""
    return _is_connected_directed(a.T) and _is_connected_directed(a)


def _is_connected_directed(a: np.ndarray) -> bool:
    """BFS from node 0 following rows as out-edges of the frontier node."""
    n = a.shape[0]
    seen = {0}
    frontier = [0]
    while frontier:
        u = frontier.pop()
        for v in np.nonzero(a[u])[0]:
            if v not in seen:
                seen.add(int(v))
                frontier.append(int(v))
    return len(seen) == n


# ---------------------------------------------------------------------------
# Sparse (matvec / edge-list) validators for large-n schedules.
#
# The dense validators above build (n, n) window products and call
# numpy.linalg SVD/eigvals -- O(n^3) per window, which is the latent
# scaling bug ISSUE 10 names: at fleet sizes (n = 1k-100k) validation
# dominates construction.  The functions below compute the same three
# quantities -- per-round alpha, joint window alpha / contraction, union
# connectivity -- with only matvecs (O(period * nnz) per iteration) and
# adjacency-list BFS, and _finalize_schedule / _finalize_directed_schedule
# switch to them at n > VALIDATE_DENSE_GATE.
# ---------------------------------------------------------------------------

def _deflated_window_matvec(ws, x: np.ndarray, transpose: bool) -> np.ndarray:
    """Apply B = (W_{p-1} - J) ... (W_0 - J) (or B^T) to ``x`` without
    forming the product.  (W - J) x = W x - mean(x) 1, and the same holds
    for W^T since J^T = J."""
    order = range(len(ws) - 1, -1, -1) if transpose else range(len(ws))
    for t in order:
        w = ws[t].T if transpose else ws[t]
        x = w @ x - x.mean()
    return x


def joint_window_alpha(ws, method: str = "dense", iters: int = 300,
                       seed: int = 0) -> float:
    """``|| (W_{p-1} - J) ... (W_0 - J) ||_op`` for a doubly stochastic
    window.  ``method="dense"`` is the exact product + SVD (the historical
    path); ``method="power"`` is power iteration on B^T B -- converges to
    sigma_max(B)^2 for any B, no symmetry assumption."""
    ws = np.stack([np.asarray(w, np.float64) for w in ws])
    n = ws.shape[-1]
    if method == "dense":
        j = np.ones((n, n)) / n
        b = np.eye(n)
        for w in ws:
            b = (w - j) @ b
        return float(np.linalg.norm(b, ord=2))
    if method != "power":
        raise ValueError(f"unknown method {method!r}; have dense, power")
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n)
    x -= x.mean()
    x /= np.linalg.norm(x) + 1e-300
    if _eigsh is not None and n >= 3:
        # Lanczos on the PSD operator B^T B: resolves the clustered
        # near-1 spectra of large rings, where plain power iteration
        # underestimates the gap by orders of magnitude
        op = _LinOp((n, n), matvec=lambda v: _deflated_window_matvec(
            ws, _deflated_window_matvec(ws, v, False), True),
            dtype=np.float64)
        try:
            val = _eigsh(op, k=1, which="LA", v0=x, maxiter=max(50 * n, 2000),
                         tol=1e-12, return_eigenvectors=False)
            return float(np.sqrt(max(float(val[0]), 0.0)))
        except Exception:
            pass  # ARPACK no-convergence: fall through to power iteration
    est = 0.0
    for _ in range(iters):
        y = _deflated_window_matvec(
            ws, _deflated_window_matvec(ws, x, False), True)
        nrm = float(np.linalg.norm(y))
        if nrm < 1e-300:
            return 0.0
        est = nrm                # -> sigma_max(B)^2
        x = y / nrm
    return float(np.sqrt(est))


def mixing_rate_power(w: np.ndarray, iters: int = 300, seed: int = 0) -> float:
    """alpha = ||W - J||_op by power iteration (sparse analogue of
    :func:`mixing_rate`)."""
    return joint_window_alpha([w], method="power", iters=iters, seed=seed)


def joint_window_contraction(ws, method: str = "dense", iters: int = 400,
                             seed: int = 0) -> float:
    """Second-largest eigenvalue modulus of the window product
    ``P = W_{p-1} ... W_0`` of column-stochastic matrices.

    ``method="dense"`` forms the product and calls
    :func:`contraction_factor`.  ``method="power"`` exploits that the
    sum-zero subspace is P-invariant (1^T W = 1^T), where P's spectrum is
    exactly its non-Perron spectrum: iterate x <- P x on that subspace and
    average the renormalized log growth -- the oscillation a complex
    leading pair induces in per-step norms is bounded, so the running
    geometric mean converges to the spectral radius.
    """
    ws = np.stack([np.asarray(w, np.float64) for w in ws])
    n = ws.shape[-1]
    if method == "dense":
        prod = np.eye(n)
        for w in ws:
            prod = w @ prod
        return contraction_factor(prod)
    if method != "power":
        raise ValueError(f"unknown method {method!r}; have dense, power")

    def window_deflated(v):
        for w in ws:
            v = w @ v
        return v - v.mean()

    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n)
    x -= x.mean()
    nrm = np.linalg.norm(x)
    if nrm < 1e-300:
        return 0.0
    x /= nrm
    if _eigs is not None and n >= 4:
        # Arnoldi on (I - J) P: its range lies in the sum-zero subspace
        # where it acts as P, so its largest-magnitude eigenvalue IS the
        # non-Perron spectral radius of P
        op = _LinOp((n, n), matvec=window_deflated, dtype=np.float64)
        try:
            val = _eigs(op, k=1, which="LM", v0=x, maxiter=max(50 * n, 2000),
                        tol=1e-12, return_eigenvectors=False)
            return float(np.abs(val[0]))
        except Exception:
            pass  # ARPACK no-convergence: fall through to power iteration
    logs = []
    for _ in range(iters):
        for w in ws:
            x = w @ x
        x -= x.mean()            # numerical re-deflation; invariant exactly
        nrm = float(np.linalg.norm(x))
        if nrm < 1e-300:
            return 0.0
        logs.append(np.log(nrm))
        x /= nrm
    tail = logs[len(logs) // 2:]
    return float(np.exp(np.mean(tail)))


def union_connected(adjs, directed: bool = False) -> bool:
    """Window-union (strong, when directed) connectivity via adjacency-list
    BFS on the nonzero edges -- no dense union matrix walks.

    ``adjs`` is the stacked ``(period, n, n)`` adjacency table (the
    convention is ``A[i, j] != 0 <=> edge j -> i``)."""
    adjs = np.stack([np.asarray(a) for a in adjs])
    n = adjs.shape[-1]
    rows, cols = np.nonzero((np.abs(adjs).sum(axis=0) > 0))

    def bfs(fwd_rows, fwd_cols) -> bool:
        adj = [[] for _ in range(n)]
        for u, v in zip(fwd_rows.tolist(), fwd_cols.tolist()):
            adj[u].append(v)
        seen = np.zeros(n, dtype=bool)
        seen[0] = True
        frontier = [0]
        while frontier:
            u = frontier.pop()
            for v in adj[u]:
                if not seen[v]:
                    seen[v] = True
                    frontier.append(v)
        return bool(seen.all())

    if not directed:
        return bfs(np.concatenate([rows, cols]), np.concatenate([cols, rows]))
    # edge j -> i: node 0 reaches all following j -> i (cols -> rows), and
    # all reach node 0 on the reversed digraph
    return bfs(cols, rows) and bfs(rows, cols)


def _w_is_banded_ring(w: np.ndarray) -> bool:
    n = w.shape[0]
    off = w.copy()
    np.fill_diagonal(off, 0.0)
    allowed = ring_graph(n) > 0
    return bool(np.all((np.abs(off) < 1e-12) | allowed))


@dataclasses.dataclass(frozen=True)
class Topology:
    """A communication graph with its mixing matrix and spectral summary."""

    kind: str
    n: int
    adjacency: np.ndarray
    w: np.ndarray
    alpha: float

    @property
    def spectral_gap(self) -> float:
        return 1.0 - self.alpha

    def is_banded_ring(self) -> bool:
        """True when W only couples ring neighbours (enables ppermute gossip)."""
        return _w_is_banded_ring(self.w)


def make_topology(kind: GraphKind, n: int, weights: WeightKind = "metropolis",
                  p: float = 0.8, seed: int = 0) -> Topology:
    adj = build_adjacency(kind, n, p=p, seed=seed)
    w = mixing_matrix(adj, weights)
    # sanity: row/col sums = 1 (Definition 1)
    assert np.allclose(w.sum(0), 1.0, atol=1e-9) and np.allclose(w.sum(1), 1.0,
                                                                 atol=1e-9)
    return Topology(kind=kind, n=n, adjacency=adj, w=w, alpha=mixing_rate(w))


# ---------------------------------------------------------------------------
# Time-varying topologies: periodic schedules of mixing matrices
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TopologySchedule:
    """A periodic window of mixing matrices; round t mixes with W_{t mod p}.

    ``ws`` is the stacked ``(period, n, n)`` table (host-side float64; the
    gossip executors push an f32 copy to device and index it with a traced
    round counter).  ``stochasticity`` is ``"doubly"`` for undirected
    schedules (every round doubly stochastic) or ``"column"`` for directed
    push-sum schedules (columns sum to 1, rows need not).  ``alphas`` are
    the per-round mixing rates -- an individual round of a churn schedule
    may not mix at all (alpha_t = 1 when the round's graph is
    disconnected); what the construction guarantees instead is that the
    *window* mixes: the union graph is (strongly, for directed) connected
    and ``joint_alpha < 1``.  For doubly stochastic schedules
    ``joint_alpha`` is ``|| (W_{p-1}-J) ... (W_0-J) ||_op``; for directed
    schedules it is the joint contraction factor -- the second-largest
    eigenvalue modulus of ``W_{p-1} ... W_0``.
    """

    kind: str
    n: int
    ws: np.ndarray            # (period, n, n)
    adjacencies: np.ndarray   # (period, n, n), binary
    alphas: Tuple[float, ...]
    joint_alpha: float        # window contraction (see class docstring)
    stochasticity: str = "doubly"   # "doubly" | "column"

    @property
    def period(self) -> int:
        return self.ws.shape[0]

    @property
    def is_directed(self) -> bool:
        """True for column-stochastic (push-sum) schedules."""
        return self.stochasticity == "column"

    @property
    def alpha(self) -> float:
        """Per-round geometric mixing rate: joint_alpha^(1/period).

        This is the schedule's stand-in for Definition 1's alpha in the
        paper's ``gamma = scale * (1 - alpha) * rho`` derivation; a
        period-1 schedule reproduces the static topology's alpha exactly.
        """
        if self.period == 1:
            return self.alphas[0]
        return float(self.joint_alpha ** (1.0 / self.period))

    @property
    def spectral_gap(self) -> float:
        return 1.0 - self.alpha

    @property
    def joint_spectral_gap(self) -> float:
        return 1.0 - self.joint_alpha

    def window_union(self) -> np.ndarray:
        """Binary adjacency of the union graph over one period."""
        return (self.adjacencies.sum(axis=0) > 0).astype(np.float64)

    def is_banded_ring(self) -> bool:
        """True when every round's W only couples ring neighbours (the
        ppermute fast path then stays valid with traced band weights)."""
        return all(_w_is_banded_ring(w) for w in self.ws)

    def at(self, t: int) -> np.ndarray:
        """Host-side W_t (numpy) for round ``t``."""
        return self.ws[int(t) % self.period]


def _finalize_schedule(kind: str, n: int, ws, adjs) -> TopologySchedule:
    """Validate the window and compute its joint spectral summary."""
    ws = np.stack([np.asarray(w, np.float64) for w in ws])
    adjs = np.stack([np.asarray(a, np.float64) for a in adjs])
    if ws.ndim != 3 or ws.shape[1] != n or ws.shape[2] != n:
        raise ValueError(f"schedule table must be (period, {n}, {n}); got "
                         f"{ws.shape}")
    for t, w in enumerate(ws):
        if not (np.allclose(w.sum(0), 1.0, atol=1e-9)
                and np.allclose(w.sum(1), 1.0, atol=1e-9)):
            raise ValueError(f"schedule round {t} is not doubly stochastic "
                             "(Definition 1)")
    sparse = n > VALIDATE_DENSE_GATE
    if sparse:
        connected = union_connected(adjs, directed=False)
    else:
        connected = _is_connected((adjs.sum(axis=0) > 0).astype(np.float64))
    if not connected:
        raise ValueError(
            f"{kind!r} schedule: the union graph over the {ws.shape[0]}-round "
            "window is disconnected -- some agent never talks to the rest, "
            "so no amount of rounds reaches consensus.  Lower the churn "
            "rate, lengthen the period, or densify the base graph.")
    joint = joint_window_alpha(ws, method="power" if sparse else "dense")
    if joint >= 1.0 - (1e-9 if sparse else 1e-12):
        raise ValueError(
            f"{kind!r} schedule does not mix over its window "
            f"(joint alpha = {joint:.6f} >= 1); the paper's consensus "
            "stepsize would degenerate to 0")
    rate = mixing_rate_power if sparse else mixing_rate
    return TopologySchedule(kind=kind, n=n, ws=ws, adjacencies=adjs,
                            alphas=tuple(rate(w) for w in ws),
                            joint_alpha=joint)


def static_schedule(topology: Topology) -> TopologySchedule:
    """Period-1 schedule: the static topology viewed through the
    time-varying engine (tests pin trajectory parity against the baked
    path)."""
    sched = _finalize_schedule(f"static:{topology.kind}", topology.n,
                               [topology.w], [topology.adjacency])
    # keep alpha bit-identical to the static path (same mixing_rate call,
    # but make the equality structural rather than numerical luck)
    return dataclasses.replace(sched, alphas=(topology.alpha,))


def rotating_schedule(kinds: Sequence[str], n: int,
                      weights: WeightKind = "metropolis", p: float = 0.8,
                      seed: int = 0) -> TopologySchedule:
    """Rotate through a list of graphs, one per round.

    Each entry is a graph kind, optionally with its own weight scheme as
    ``kind/weights`` (e.g. ``ring/lazy``) -- rotating weight schemes on a
    fixed ring keeps every round banded, which the ring wire format's
    traced-band fast path exploits.
    """
    if not kinds:
        raise ValueError("rotating schedule needs at least one graph kind")
    ws, adjs = [], []
    for entry in kinds:
        kind, _, wk = str(entry).partition("/")
        adj = build_adjacency(kind, n, p=p, seed=seed)
        ws.append(mixing_matrix(adj, wk or weights))
        adjs.append(adj)
    return _finalize_schedule(f"rotate:{'+'.join(map(str, kinds))}", n, ws,
                              adjs)


def erdos_renyi_schedule(n: int, p: float = 0.8, period: int = 8,
                         weights: WeightKind = "metropolis",
                         seed: int = 0) -> TopologySchedule:
    """Fresh connected ER(p) graph every round (per-round resampling)."""
    if period < 1:
        raise ValueError(f"period must be >= 1, got {period}")
    ws, adjs = [], []
    for t in range(period):
        adj = erdos_renyi_graph(n, p, seed=seed * 10007 + t)
        ws.append(mixing_matrix(adj, weights))
        adjs.append(adj)
    return _finalize_schedule(f"erdos_renyi:p={p}", n, ws, adjs)


def _churn_weights(weights: WeightKind) -> WeightKind:
    if weights == "best_constant":
        raise ValueError(
            "churn schedules cannot use best_constant weights: a round with "
            "dropped agents/links has a disconnected Laplacian (lambda_2 = "
            "0), so the closed form divides by zero -- use metropolis or "
            "lazy")
    return weights


def _pruned_rounds(kind: str, n: int, base_adj: np.ndarray, period: int,
                   weights: WeightKind, seed: int, prune_one):
    """Sample a window of pruned copies of ``base_adj`` until the union is
    connected; ``prune_one(rng, adj) -> adj_t`` drops agents or links."""
    rng = np.random.default_rng(seed)
    for _ in range(1000):
        adjs = [prune_one(rng, base_adj) for _ in range(period)]
        if _is_connected((np.sum(adjs, axis=0) > 0).astype(np.float64)):
            ws = [mixing_matrix(a, weights) for a in adjs]
            return _finalize_schedule(kind, n, ws, adjs)
    raise RuntimeError(
        f"could not sample a window-connected {kind!r} schedule in 1000 "
        "tries; the churn rate is too high for this period/base graph")


def dropout_schedule(n: int, rate: float = 0.2, period: int = 8,
                     base: GraphKind = "ring",
                     weights: WeightKind = "metropolis", p: float = 0.8,
                     seed: int = 0) -> TopologySchedule:
    """Agent churn: each round every agent is offline independently with
    probability ``rate``.  An offline agent keeps only its self-loop (its
    row of W is e_i -- it neither sends nor receives this round), and the
    survivors re-derive Metropolis weights on the pruned graph, so every
    round stays doubly stochastic."""
    if not 0.0 <= rate < 1.0:
        raise ValueError(f"dropout rate must be in [0, 1), got {rate}")
    base_adj = build_adjacency(base, n, p=p, seed=seed)

    def prune(rng, adj):
        active = rng.random(n) >= rate
        a = adj * active[:, None] * active[None, :]
        return a

    return _pruned_rounds(f"dropout:rate={rate},base={base}", n, base_adj,
                          period, _churn_weights(weights), seed, prune)


def straggler_schedule(n: int, rate: float = 0.2, period: int = 8,
                       base: GraphKind = "ring",
                       weights: WeightKind = "metropolis", p: float = 0.8,
                       seed: int = 0) -> TopologySchedule:
    """Straggler delay masks: each *link* of the base graph independently
    misses the round's deadline with probability ``rate`` (the slow
    neighbour's increment simply doesn't arrive; the drop is symmetric so
    W_t stays doubly stochastic)."""
    if not 0.0 <= rate < 1.0:
        raise ValueError(f"straggler rate must be in [0, 1), got {rate}")
    base_adj = build_adjacency(base, n, p=p, seed=seed)

    def prune(rng, adj):
        keep = np.triu(rng.random((n, n)) >= rate, 1)
        keep = keep + keep.T
        return adj * keep

    return _pruned_rounds(f"straggler:rate={rate},base={base}", n, base_adj,
                          period, _churn_weights(weights), seed, prune)


# ---------------------------------------------------------------------------
# Directed (column-stochastic) schedules for push-sum / DP-CSGP
# ---------------------------------------------------------------------------

def directed_ring_graph(n: int, skip: int = 0) -> np.ndarray:
    """Directed ring adjacency ``A[i, j] = 1 <=> j -> i``: every node sends
    to its clockwise neighbour (j -> j+1), plus an optional skip chord
    (j -> j+skip) when ``skip >= 2``.  ``skip = 0`` is the pure directed
    cycle -- the only variant whose W stays a circulant ring band (the
    ppermute fast path)."""
    if n < 2:
        raise ValueError(f"directed ring needs n >= 2, got {n}")
    if skip and not 2 <= skip < n:
        raise ValueError(f"skip must be 0 or in [2, n), got {skip}")
    a = np.zeros((n, n), dtype=np.float64)
    for j in range(n):
        a[(j + 1) % n, j] = 1.0
        if skip:
            a[(j + skip) % n, j] = 1.0
    np.fill_diagonal(a, 0.0)
    return a


def column_stochastic_matrix(adj: np.ndarray) -> np.ndarray:
    """Equal-out-weight column-stochastic W for a directed adjacency
    (``adj[i, j] = 1 <=> j -> i``): node j splits unit mass uniformly over
    its out-neighbours *including itself*, ``w_ij = 1 / (outdeg_j + 1)``.
    Columns sum to 1 exactly; every diagonal entry is positive (the
    self-loop), which keeps every round aperiodic and the push-sum weights
    strictly positive."""
    n = adj.shape[0]
    a = (np.asarray(adj, np.float64) > 0).astype(np.float64)
    np.fill_diagonal(a, 0.0)
    out = a.sum(axis=0) + 1.0                 # out-degree incl. self-loop
    w = (a + np.eye(n)) / out[None, :]
    return w


def _finalize_directed_schedule(kind: str, n: int, ws, adjs
                                ) -> TopologySchedule:
    """Directed analogue of :func:`_finalize_schedule`: per-round column
    stochasticity + positive diagonals, window-union *strong* connectivity,
    and a joint contraction factor (eigenvalue modulus of the window
    product) strictly below 1."""
    ws = np.stack([np.asarray(w, np.float64) for w in ws])
    adjs = np.stack([np.asarray(a, np.float64) for a in adjs])
    if ws.ndim != 3 or ws.shape[1] != n or ws.shape[2] != n:
        raise ValueError(f"schedule table must be (period, {n}, {n}); got "
                         f"{ws.shape}")
    for t, w in enumerate(ws):
        if not np.allclose(w.sum(0), 1.0, atol=1e-9):
            raise ValueError(f"directed schedule round {t} is not column "
                             "stochastic (1^T W != 1^T)")
        if np.any(w < -1e-12):
            raise ValueError(f"directed schedule round {t} has negative "
                             "entries; push-sum weights must stay positive")
        if np.any(np.diag(w) <= 0.0):
            raise ValueError(f"directed schedule round {t} is missing a "
                             "self-loop; push-sum weights could hit zero")
    sparse = n > VALIDATE_DENSE_GATE
    if sparse:
        connected = union_connected(adjs, directed=True)
    else:
        connected = _is_strongly_connected(
            (adjs.sum(axis=0) > 0).astype(np.float64))
    if not connected:
        raise ValueError(
            f"{kind!r} schedule: the union digraph over the "
            f"{ws.shape[0]}-round window is not strongly connected -- some "
            "agent's mass never reaches (or never hears from) the rest, so "
            "push-sum cannot reach consensus.  Lower the loss rate, "
            "lengthen the period, or densify the base digraph.")
    joint = joint_window_contraction(
        ws, method="power" if sparse else "dense")
    if joint >= 1.0 - (1e-9 if sparse else 1e-12):
        raise ValueError(
            f"{kind!r} schedule does not contract over its window "
            f"(joint contraction factor = {joint:.6f} >= 1); the consensus "
            "stepsize would degenerate to 0")
    per_round = ((lambda w: joint_window_contraction([w], method="power"))
                 if sparse else contraction_factor)
    return TopologySchedule(kind=kind, n=n, ws=ws, adjacencies=adjs,
                            alphas=tuple(per_round(w) for w in ws),
                            joint_alpha=joint, stochasticity="column")


def directed_ring_schedule(n: int, skip: int = 0) -> TopologySchedule:
    """Static (period-1) directed ring, optionally with skip chords.

    ``skip = 0`` keeps W a circulant ring band, so the ppermute ring
    executor applies; ``skip >= 2`` adds j -> j+skip chords (denser, faster
    contraction, dense/packed executors only)."""
    adj = directed_ring_graph(n, skip=skip)
    return _finalize_directed_schedule(f"ring_skips:skip={skip}", n,
                                       [column_stochastic_matrix(adj)], [adj])


def random_digraph_schedule(n: int, p: float = 0.5, period: int = 8,
                            seed: int = 0) -> TopologySchedule:
    """Per-round random digraph: each directed edge j -> i (i != j) is
    present independently with probability ``p``, resampled every round;
    self-loops always.  The window is resampled until its union digraph is
    strongly connected and the product contracts."""
    if not 0.0 < p <= 1.0:
        raise ValueError(f"digraph edge probability must be in (0, 1], got {p}")
    if period < 1:
        raise ValueError(f"period must be >= 1, got {period}")
    rng = np.random.default_rng(seed)
    return _directed_window(f"digraph:p={p}", n, period, lambda: (
        (rng.random((n, n)) < p).astype(np.float64)
        * (1.0 - np.eye(n))))


def directed_churn_schedule(n: int, rate: float = 0.2, period: int = 8,
                            skip: int = 2, seed: int = 0) -> TopologySchedule:
    """Directed churn (one-way link loss): start from the directed ring
    with skip chords and drop every directed edge independently with
    probability ``rate`` each round.  A drop is one-way -- j -> i can fail
    while i -> j survives -- which is exactly the asymmetry the
    doubly-stochastic churn schedules cannot express."""
    if not 0.0 <= rate < 1.0:
        raise ValueError(f"one-way loss rate must be in [0, 1), got {rate}")
    if period < 1:
        raise ValueError(f"period must be >= 1, got {period}")
    base = directed_ring_graph(n, skip=skip)
    rng = np.random.default_rng(seed)
    return _directed_window(f"one_way:rate={rate},skip={skip}", n, period,
                            lambda: base * (rng.random((n, n)) >= rate))


def _directed_window(kind: str, n: int, period: int, sample_adj
                     ) -> TopologySchedule:
    """Sample ``period`` directed adjacencies until the window validates
    (strongly connected union, contracting product) -- the directed
    analogue of :func:`_pruned_rounds`."""
    last_err = None
    for _ in range(1000):
        adjs = [sample_adj() for _ in range(period)]
        ws = [column_stochastic_matrix(a) for a in adjs]
        try:
            return _finalize_directed_schedule(kind, n, ws, adjs)
        except ValueError as e:
            last_err = e
    raise RuntimeError(
        f"could not sample a window-connected {kind!r} schedule in 1000 "
        f"tries; the loss rate is too high for this period/base digraph "
        f"(last: {last_err})")


_SCHEDULE_GENERATORS = {
    "rotate": rotating_schedule,
    "erdos_renyi": erdos_renyi_schedule,
    "dropout": dropout_schedule,
    "straggler": straggler_schedule,
    "ring_skips": directed_ring_schedule,
    "digraph": random_digraph_schedule,
    "one_way": directed_churn_schedule,
}

# generator registry with the stochasticity each kind produces; the
# topology-schedule property sweep completeness-checks itself against this
SCHEDULE_STOCHASTICITY = {
    "rotate": "doubly",
    "erdos_renyi": "doubly",
    "dropout": "doubly",
    "straggler": "doubly",
    "ring_skips": "column",
    "digraph": "column",
    "one_way": "column",
}
assert set(SCHEDULE_STOCHASTICITY) == set(_SCHEDULE_GENERATORS)


def make_schedule(kind: str, n: int, **kwargs) -> TopologySchedule:
    """Generator dispatch (mirrors :func:`build_adjacency` for graphs).

    ``kind='static'`` expects ``topology=`` (a built :class:`Topology`);
    the other generators take their own keyword knobs -- see each
    generator's signature and ``SCHEDULE_STOCHASTICITY`` for which kinds
    are doubly vs column stochastic.
    """
    if kind == "static":
        top = kwargs.pop("topology", None)
        if top is None or kwargs:
            raise ValueError("static schedule needs exactly topology=<Topology>")
        return static_schedule(top)
    if kind not in _SCHEDULE_GENERATORS:
        raise ValueError(f"unknown schedule kind {kind!r}; have "
                         f"{['static'] + sorted(_SCHEDULE_GENERATORS)}")
    return _SCHEDULE_GENERATORS[kind](n=n, **kwargs)
