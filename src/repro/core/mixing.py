"""Communication graphs and mixing (gossip) matrices (paper Definition 1).

The mixing matrix W satisfies W 1 = 1, W^T 1 = 1 and w_ij = 0 for (i,j) not in
the graph; the mixing rate is alpha = || W - (1/n) 11^T ||_op.

Graph builders return symmetric adjacency matrices (numpy, host-side -- these
are a few hundred entries and feed compile-time constants).  Weight schemes:

* ``metropolis``      w_ij = 1/(1 + max(deg_i, deg_j)) -- doubly stochastic.
* ``best_constant``   W = I - (2 / (lam_1(L) + lam_{n-1}(L))) L -- the
                      fastest constant-edge-weight matrix [XB04 Thm/closed
                      form].  This is our offline surrogate for the paper's
                      FDLA matrix (FDLA proper needs an SDP solver); it may
                      carry negative entries, which the paper's analysis
                      explicitly allows.
* ``lazy``            (I + W)/2 of the metropolis matrix.

All functions are deterministic given a seed so that experiments are
reproducible across processes/agents.
"""

from __future__ import annotations

import dataclasses
from typing import Literal, Optional

import numpy as np

__all__ = [
    "Topology",
    "ring_graph",
    "torus_graph",
    "erdos_renyi_graph",
    "complete_graph",
    "star_graph",
    "exponential_graph",
    "hypercube_graph",
    "build_adjacency",
    "mixing_matrix",
    "mixing_rate",
    "make_topology",
]

GraphKind = Literal["ring", "torus", "erdos_renyi", "complete", "star",
                    "exponential", "hypercube"]
WeightKind = Literal["metropolis", "best_constant", "lazy"]


def ring_graph(n: int) -> np.ndarray:
    a = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        a[i, (i + 1) % n] = a[(i + 1) % n, i] = 1.0
    if n == 2:
        a = np.minimum(a, 1.0)
    np.fill_diagonal(a, 0.0)
    return a


def torus_graph(n: int) -> np.ndarray:
    """2D torus on the most-square factorization of n."""
    r = int(np.sqrt(n))
    while n % r:
        r -= 1
    c = n // r
    a = np.zeros((n, n), dtype=np.float64)

    def node(i, j):
        return (i % r) * c + (j % c)

    for i in range(r):
        for j in range(c):
            u = node(i, j)
            for v in (node(i + 1, j), node(i, j + 1)):
                if u != v:
                    a[u, v] = a[v, u] = 1.0
    return a


def erdos_renyi_graph(n: int, p: float, seed: int = 0) -> np.ndarray:
    """ER(p) graph; re-sample until connected (as in the paper's setup, p=0.8)."""
    rng = np.random.default_rng(seed)
    for _ in range(1000):
        a = (rng.random((n, n)) < p).astype(np.float64)
        a = np.triu(a, 1)
        a = a + a.T
        if _is_connected(a):
            return a
    raise RuntimeError("could not sample a connected ER graph")


def complete_graph(n: int) -> np.ndarray:
    a = np.ones((n, n), dtype=np.float64)
    np.fill_diagonal(a, 0.0)
    return a


def exponential_graph(n: int) -> np.ndarray:
    """One-peer exponential graph: i ~ i +- 2^k (mod n) -- O(log n) degree
    with O(log n)-hop diameter; the standard large-n decentralized topology
    (e.g. SGP [ALBR19])."""
    a = np.zeros((n, n), dtype=np.float64)
    k = 1
    while k < n:
        for i in range(n):
            a[i, (i + k) % n] = a[(i + k) % n, i] = 1.0
        k *= 2
    np.fill_diagonal(a, 0.0)
    return a


def hypercube_graph(n: int) -> np.ndarray:
    """Hypercube on n = 2^m nodes (i ~ j iff popcount(i^j) == 1)."""
    if n & (n - 1):
        raise ValueError(f"hypercube needs a power-of-two size, got {n}")
    a = np.zeros((n, n), dtype=np.float64)
    for i in range(n):
        for b in range(n.bit_length() - 1):
            j = i ^ (1 << b)
            a[i, j] = a[j, i] = 1.0
    return a


def star_graph(n: int) -> np.ndarray:
    a = np.zeros((n, n), dtype=np.float64)
    a[0, 1:] = a[1:, 0] = 1.0
    return a


def _is_connected(a: np.ndarray) -> bool:
    n = a.shape[0]
    seen = {0}
    frontier = [0]
    while frontier:
        u = frontier.pop()
        for v in np.nonzero(a[u])[0]:
            if v not in seen:
                seen.add(int(v))
                frontier.append(int(v))
    return len(seen) == n


def build_adjacency(kind: GraphKind, n: int, p: float = 0.8,
                    seed: int = 0) -> np.ndarray:
    if kind == "ring":
        return ring_graph(n)
    if kind == "torus":
        return torus_graph(n)
    if kind == "erdos_renyi":
        return erdos_renyi_graph(n, p, seed)
    if kind == "complete":
        return complete_graph(n)
    if kind == "star":
        return star_graph(n)
    if kind == "exponential":
        return exponential_graph(n)
    if kind == "hypercube":
        return hypercube_graph(n)
    raise ValueError(f"unknown graph kind {kind!r}")


def mixing_matrix(adj: np.ndarray, weights: WeightKind = "metropolis") -> np.ndarray:
    n = adj.shape[0]
    deg = adj.sum(axis=1)
    if weights in ("metropolis", "lazy"):
        w = np.zeros_like(adj)
        for i in range(n):
            for j in np.nonzero(adj[i])[0]:
                w[i, j] = 1.0 / (1.0 + max(deg[i], deg[j]))
        np.fill_diagonal(w, 1.0 - w.sum(axis=1))
        if weights == "lazy":
            w = 0.5 * (np.eye(n) + w)
        return w
    if weights == "best_constant":
        lap = np.diag(deg) - adj
        lam = np.sort(np.linalg.eigvalsh(lap))  # ascending, lam[0] ~ 0
        eps = 2.0 / (lam[-1] + lam[1])
        return np.eye(n) - eps * lap
    raise ValueError(f"unknown weight kind {weights!r}")


def mixing_rate(w: np.ndarray) -> float:
    """alpha = || W - 11^T/n ||_op (Definition 1)."""
    n = w.shape[0]
    m = w - np.ones((n, n)) / n
    return float(np.linalg.norm(m, ord=2))


@dataclasses.dataclass(frozen=True)
class Topology:
    """A communication graph with its mixing matrix and spectral summary."""

    kind: str
    n: int
    adjacency: np.ndarray
    w: np.ndarray
    alpha: float

    @property
    def spectral_gap(self) -> float:
        return 1.0 - self.alpha

    def is_banded_ring(self) -> bool:
        """True when W only couples ring neighbours (enables ppermute gossip)."""
        n = self.n
        off = self.w.copy()
        np.fill_diagonal(off, 0.0)
        allowed = ring_graph(n) > 0
        return bool(np.all((np.abs(off) < 1e-12) | allowed))


def make_topology(kind: GraphKind, n: int, weights: WeightKind = "metropolis",
                  p: float = 0.8, seed: int = 0) -> Topology:
    adj = build_adjacency(kind, n, p=p, seed=seed)
    w = mixing_matrix(adj, weights)
    # sanity: row/col sums = 1 (Definition 1)
    assert np.allclose(w.sum(0), 1.0, atol=1e-9) and np.allclose(w.sum(1), 1.0,
                                                                 atol=1e-9)
    return Topology(kind=kind, n=n, adjacency=adj, w=w, alpha=mixing_rate(w))
