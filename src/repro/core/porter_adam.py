"""PORTER-Adam: a beyond-paper variant that Adam-preconditions the tracked
gradient.

The paper's Algorithm 1 uses a plain SGD step `X -= eta * V`.  Since `v_i`
tracks the *global* gradient at every agent (the tracking identity
v-bar == g-bar is preserved -- preconditioning happens after tracking), each
agent can apply a local Adam update to its own tracked estimate:

    m_i = b1 m_i + (1-b1) v_i
    s_i = b2 s_i + (1-b2) v_i^2
    x_i = x_i + gamma (M_x - Q_x)_i - eta * m-hat_i / (sqrt(s-hat_i) + eps)

Caveat (why this is "beyond-paper" and not covered by Theorems 2-4): the
update is a *nonlinear* function of v_i, so the mean iterate is no longer an
exact function of v-bar -- agents' moments can drift apart.  Empirically
(tests/test_porter_adam.py) consensus still contracts because m_i, s_i are
driven by the tracked (therefore agreeing) v_i's, and the preconditioner
accelerates the ill-conditioned MLP problem.  A proof is future work; the
implementation exists so the framework can train real models with the
optimizer people actually use.

Communication is *identical* to PORTER (same two compressed streams via the
same :class:`repro.core.comm_round.CommRound` engine -- the parameter round
is ``engine.step`` with the preconditioned update as the descent direction);
moments are purely local state.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from .comm_round import CommRound
from .compression import Compressor
from .gossip import MixFn
from .porter import (LossFn, PorterConfig, PorterState, _agent_gradient,
                     _resolve_engine, consensus_error, porter_init)

__all__ = ["PorterAdamState", "porter_adam_init", "make_porter_adam_step"]


class PorterAdamState(NamedTuple):
    base: PorterState
    m: Any          # first moment, agent-stacked
    s: Any          # second moment, agent-stacked


def porter_adam_init(params, n_agents: int, w=None,
                     plane_dtype=None) -> PorterAdamState:
    base = porter_init(params, n_agents, w=w, plane_dtype=plane_dtype)
    # Adam moments are purely local (never hit a plane or the wire) and the
    # second moment is variance-fragile, so they stay f32 under bf16 planes.
    zeros = jax.tree_util.tree_map(
        lambda l: jnp.zeros_like(l, dtype=jnp.float32), base.v)
    return PorterAdamState(base=base, m=zeros, s=zeros)


def porter_adam_step(
    cfg: PorterConfig,
    loss_fn: LossFn,
    mixer: Optional[MixFn],
    compressor: Optional[Compressor],
    state: PorterAdamState,
    batch: Any,
    key: jax.Array,
    b1: float = 0.9,
    b2: float = 0.999,
    adam_eps: float = 1e-8,
    compress_fn=None,
    engine: Optional[CommRound] = None,
) -> Tuple[PorterAdamState, Dict[str, jax.Array]]:
    st = state.base
    n = jax.tree_util.tree_leaves(st.x)[0].shape[0]
    _, k_noise, k_cv, k_cx = jax.random.split(key, 4)
    eng = _resolve_engine(engine, mixer, compressor, compress_fn)

    # gradients + tracking: identical to Algorithm 1 lines 4-12
    agent_keys = jax.random.split(k_noise, n)
    grad_fn = functools.partial(_agent_gradient, cfg, loss_fn)
    losses, g = jax.vmap(grad_fn)(st.x, batch, agent_keys)
    g = jax.tree_util.tree_map(lambda l: l.astype(cfg.grad_dtype), g)

    if eng.overlap:
        # the x-side exchange reads only (st.x, st.q_x) -- independent of
        # the track update AND the Adam moments -- so both collectives are
        # in flight before the local moment math runs (see CommRound.overlap)
        k_cv, sr_v = eng.sr_split(k_cv, (st.q_v, st.m_v, st.v))
        k_cx, sr_x = eng.sr_split(k_cx, (st.q_x, st.m_x, st.x))
        c_v, wc_v = eng.exchange(k_cv, st.v, st.q_v, t=st.step)
        c_x, wc_x = eng.exchange(k_cx, st.x, st.q_x, t=st.step)
        v, q_v, m_v = eng.track_update(c_v, wc_v, st.v, st.q_v, st.m_v, g,
                                       st.g_prev, cfg.gamma, sr_key=sr_v)
    else:
        c_x = wc_x = sr_x = None
        v, q_v, m_v = eng.track(k_cv, st.v, st.q_v, st.m_v, g, st.g_prev,
                                cfg.gamma, t=st.step)

    # local Adam moments on the tracked gradient
    step_no = (st.step + 1).astype(jnp.float32)
    bc1 = 1.0 - b1 ** step_no
    bc2 = 1.0 - b2 ** step_no
    m = jax.tree_util.tree_map(lambda m0, vv: b1 * m0 + (1 - b1) * vv,
                               state.m, v)
    s = jax.tree_util.tree_map(
        lambda s0, vv: b2 * s0 + (1 - b2) * jnp.square(vv), state.s, v)
    update = jax.tree_util.tree_map(
        lambda mm, ss: (mm / bc1) / (jnp.sqrt(ss / bc2) + adam_eps), m, s)

    # parameter round: Algorithm 1 lines 13-14 with the preconditioned update
    if eng.overlap:
        x, q_x, m_x = eng.step_update(c_x, wc_x, st.x, st.q_x, st.m_x,
                                      update, cfg.gamma, cfg.eta,
                                      sr_key=sr_x)
    else:
        x, q_x, m_x = eng.step(k_cx, st.x, st.q_x, st.m_x, update,
                               cfg.gamma, cfg.eta, t=st.step)

    new_base = PorterState(x=x, v=v, q_x=q_x, q_v=q_v, g_prev=g, m_x=m_x,
                           m_v=m_v, step=st.step + 1)
    metrics = {"loss": jnp.mean(losses), "consensus_x": consensus_error(x),
               "consensus_v": consensus_error(v),
               "wire_bytes": jnp.asarray(2.0 * eng.wire_bytes(st.x),
                                         jnp.float32)}
    return PorterAdamState(base=new_base, m=m, s=s), metrics


def make_porter_adam_step(cfg: PorterConfig, loss_fn: LossFn, mixer: MixFn,
                          compressor: Compressor, backend: str = "auto",
                          interpret: Optional[bool] = None, **adam_kw):
    engine = CommRound(compressor=compressor, mixer=mixer,
                       compress_fn=adam_kw.pop("compress_fn", None),
                       backend=backend, interpret=interpret)
    return functools.partial(porter_adam_step, cfg, loss_fn, None, None,
                             engine=engine, **adam_kw)
