"""Communication compression operators (paper Definition 3).

A rho-compressor is a (possibly randomized, possibly biased) map C with

    E || C(x) - x ||_2^2  <=  (1 - rho) ||x||_2^2 ,   rho in [0, 1].

Instances implemented here:

* ``identity``      rho = 1 (no compression)
* ``random_k``      paper Example 1 -- Bernoulli(k/d) mask, *biased*, rho = k/d
* ``top_k``         paper Example 2 -- global magnitude top-k, rho = k/d
* ``block_top_k``   TPU-idiomatic top-k performed per fixed-size block
                    (still rho = k/d; see kernels/block_topk.py for the
                    Pallas version -- this module is the jnp reference)
* ``qsgd``          scaled stochastic quantizer; the unbiased QSGD operator
                    Q satisfies E||Q(x)-x||^2 <= omega ||x||^2, so the scaled
                    version Q/(1+omega) is a rho = 1/(1+omega) compressor.

All compressors operate on flat vectors; :func:`compress_tree` maps a
compressor over an agent-stacked pytree, giving every (agent, leaf) pair an
independent PRNG stream.

Dense emulation vs. wire format: the functions here return *dense* arrays (the
zeros are materialized) which is what the convergence math sees.  The
bit-packed layouts that actually shrink collective bytes are registered in
:mod:`repro.core.wire_formats` -- one shared constants module (PACK_BLOCK,
``topk_bits`` for the top-k family, ``qsgd_bits`` for qsgd) consumed by the
codec gossip executors (:mod:`repro.core.gossip`), the fused pallas kernels
(:mod:`repro.kernels.wire_pack`), and the byte accounting
(:meth:`repro.core.comm_round.CommRound.wire_bytes`), so the three cannot
drift.  Select them with ``ExperimentSpec(wire="packed_bits")``.

bf16 payload note (Definition 3): the ``topk_bits`` wire format ships kept
values as bf16, so the shipped operator is C'(x) = bf16(C(x)) rather than
C(x).  Rounding each kept value multiplies it by (1 + eps) with
|eps| <= 2^-8, hence ||C'(x) - x||^2 <= (1 - rho') ||x||^2 with
rho' >= rho * (1 - 2^-8)^2 ~ rho * 0.992 -- still a valid (slightly
smaller) Definition-3 constant; gamma derived from the registry's rho is
conservative by < 1%.  ``qsgd_bits`` code words are exact (the per-window
f32 scale carries all rounding), so its rho is unchanged.

The same bound covers RESIDENT bf16 planes (``ExperimentSpec(
plane_dtype="bf16")``, SPerf-9): the EF buffers q/m live in bf16, so the
engine's effective operator is again bf16-rounded, C'(x) = bf16(C(x)) --
except the writeback is a *stochastic* rounding (kernels/sr_cast.py), so
on top of the worst-case rho' >= rho * (1 - 2^-8)^2 per-step bound the
rounding error is mean-zero and does not accumulate directionally in the
EF recursion (a round-to-nearest writeback would re-round the same drift
the same way every step and break the contraction *in expectation*; SR
preserves it).  gamma derived from the registry's rho therefore stays
conservative for bf16 planes too.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "Compressor",
    "identity",
    "random_k",
    "top_k",
    "block_top_k",
    "qsgd",
    "low_rank",
    "sign",
    "make_compressor",
    "compress_tree",
    "topk_pack",
    "topk_unpack",
]


@dataclasses.dataclass(frozen=True)
class Compressor:
    """A rho-compression operator (Definition 3).

    Attributes:
      name: registry name.
      rho: contraction factor in (0, 1]; E||C(x)-x||^2 <= (1-rho)||x||^2.
      fn: (key, x) -> compressed dense x (same shape/dtype).
      deterministic: True when ``fn`` ignores the key (e.g. top-k).
      bits_per_element: estimated wire bits per *transmitted* element, used by
        the communication accounting (32 for sparse value+index schemes
        counts value bits; index bits are added by the accounting).
    """

    name: str
    rho: float
    fn: Callable[[jax.Array, jax.Array], jax.Array]
    deterministic: bool = False
    bits_per_element: int = 32

    def __call__(self, key: Optional[jax.Array], x: jax.Array) -> jax.Array:
        if key is None:
            key = jax.random.PRNGKey(0)
        return self.fn(key, x)

    def wire_bits(self, d: int) -> float:
        """Estimated bits on the wire for one compressed d-vector."""
        if self.name == "identity":
            return 32.0 * d
        if self.name == "qsgd":
            return self.bits_per_element * d
        if self.name == "sign":
            return 1.0 * d + 32.0   # one bit per coordinate + the f32 scale
        # sparse schemes: value + log2(d) index bits per kept element
        k = max(int(round(self.rho * d)), 1)
        return k * (self.bits_per_element + float(np.ceil(np.log2(max(d, 2)))))


def _identity(key, x):
    del key
    return x


def identity() -> Compressor:
    return Compressor("identity", 1.0, _identity, deterministic=True)


def random_k(frac: float) -> Compressor:
    """Paper Example 1: keep each coordinate w.p. ``frac`` (biased, no rescale)."""

    def fn(key, x):
        mask = jax.random.bernoulli(key, frac, x.shape)
        return jnp.where(mask, x, jnp.zeros_like(x))

    return Compressor(f"random_k({frac})", float(frac), fn)


def _topk_dense(x: jax.Array, k: int) -> jax.Array:
    flat = x.reshape(-1)
    d = flat.shape[0]
    k = min(k, d)
    _, idx = jax.lax.top_k(jnp.abs(flat), k)
    out = jnp.zeros_like(flat).at[idx].set(flat[idx])
    return out.reshape(x.shape)


def top_k(frac: float) -> Compressor:
    """Paper Example 2: keep the k = frac*d largest-magnitude coordinates."""

    def fn(key, x):
        del key
        k = max(int(round(frac * x.size)), 1)
        return _topk_dense(x, k)

    return Compressor(f"top_k({frac})", float(frac), fn, deterministic=True)


def block_top_k(frac: float, block: int = 2048) -> Compressor:
    """Per-block top-k: the TPU-idiomatic variant (see kernels/block_topk.py).

    Selecting k_b = frac*block elements independently inside each ``block``-sized
    window still satisfies Definition 3 with rho = frac: the error in each block
    is at most (1-frac) of that block's energy, and energies add.
    """

    def fn(key, x):
        del key
        flat = x.reshape(-1)
        d = flat.shape[0]
        pad = (-d) % block
        padded = jnp.pad(flat, (0, pad))
        blocks = padded.reshape(-1, block)
        k_b = max(int(round(frac * block)), 1)
        _, idx = jax.lax.top_k(jnp.abs(blocks), k_b)
        vals = jnp.take_along_axis(blocks, idx, axis=1)
        out = jnp.zeros_like(blocks)
        out = jax.vmap(lambda o, i, v: o.at[i].set(v))(out, idx, vals)
        return out.reshape(-1)[:d].reshape(x.shape)

    return Compressor(f"block_top_k({frac},{block})", float(frac), fn,
                      deterministic=True)


def low_rank(rank: int = 2, power_iters: int = 1) -> Compressor:
    """PowerSGD-style rank-r compressor [Vogels et al. 2019], adapted to the
    Definition-3 contract.

    The input vector is reshaped to a near-square matrix M; ``power_iters``
    subspace iterations with a fixed (key-seeded) Gaussian sketch give an
    orthonormal Q whose projection P = (M Q) Q^T is the best-effort rank-r
    approximation.  Projections are contractions (||P - M||^2 <= ||M||^2 with
    strict inequality unless M is rank-deficient), so Definition 3 holds with
    a data-dependent rho; we report the conservative floor
    rho >= rank / min_dim for random matrices (validated empirically in
    tests/test_compression.py).  Wire format: the (m, r) + (n, r) factors --
    r*(m+n) floats instead of m*n.
    """

    def fn(key, x):
        flat = x.reshape(-1).astype(jnp.float32)
        d = flat.shape[0]
        m = int(np.ceil(np.sqrt(d)))
        n = int(np.ceil(d / m))
        pad = m * n - d
        mat = jnp.pad(flat, (0, pad)).reshape(m, n)
        r = min(rank, m, n)
        q = jax.random.normal(key, (n, r))
        for _ in range(power_iters):
            p_ = mat @ q                       # (m, r)
            p_, _ = jnp.linalg.qr(p_)
            q = mat.T @ p_                     # (n, r)
        q_orth, _ = jnp.linalg.qr(q)
        approx = (mat @ q_orth) @ q_orth.T
        return approx.reshape(-1)[:d].reshape(x.shape).astype(x.dtype)

    return Compressor(f"low_rank({rank})", 0.0, fn)  # rho data-dependent


def sign() -> Compressor:
    """l1-scaled sign compressor [KRSJ19]: C(x) = (||x||_1 / d) sign(x).

    Deterministic 1-bit-per-coordinate scheme (the shipped payload is the
    sign bitmap plus one f32 scale; see :meth:`Compressor.wire_bits`).
    Definition 3 holds with the data-dependent
    rho(x) = ||x||_1^2 / (d ||x||_2^2), which Cauchy-Schwarz bounds below
    by 1/d; like ``low_rank`` the registry reports the conservative 0.0
    and the contract suite checks the exact per-d floor.
    """

    def fn(key, x):
        del key
        flat = x.reshape(-1).astype(jnp.float32)
        scale = jnp.mean(jnp.abs(flat))
        out = scale * jnp.sign(flat)
        return out.reshape(x.shape).astype(x.dtype)

    return Compressor("sign", 0.0, fn, deterministic=True,
                      bits_per_element=1)


def qsgd(levels: int = 16) -> Compressor:
    """Scaled stochastic quantizer.

    QSGD with s levels is unbiased with relative variance
    omega <= min(d/s^2, sqrt(d)/s).  Scaling the output by 1/(1+omega) turns it
    into a rho = 1/(1+omega) contraction (standard trick, cf. [RSF21]).
    omega depends on d, so rho here is a conservative static bound computed for
    d up to ~1e9 via the sqrt(d)/s branch at construction time is impossible;
    instead we compute the scale per-call from the actual d.
    """

    def fn(key, x):
        flat = x.reshape(-1)
        d = flat.shape[0]
        norm = jnp.linalg.norm(flat) + 1e-30
        y = jnp.abs(flat) / norm * levels
        lo = jnp.floor(y)
        prob = y - lo
        rnd = jax.random.uniform(key, flat.shape)
        q = (lo + (rnd < prob)) / levels
        omega = min(np.sqrt(d) / levels, d / levels**2)
        out = jnp.sign(flat) * q * norm / (1.0 + omega)
        return out.reshape(x.shape).astype(x.dtype)

    # rho reported for "typical" d ~ 1e6; exact value enforced in tests per-d.
    omega_typ = np.sqrt(1e6) / levels
    return Compressor(f"qsgd({levels})", float(1.0 / (1.0 + omega_typ)), fn,
                      bits_per_element=int(np.ceil(np.log2(levels + 1))) + 1)


_REGISTRY = {
    "identity": identity,
    "random_k": random_k,
    "top_k": top_k,
    "block_top_k": block_top_k,
    "qsgd": qsgd,
    "low_rank": low_rank,
    "sign": sign,
}


def make_compressor(name: str, **kwargs) -> Compressor:
    if name not in _REGISTRY:
        raise ValueError(f"unknown compressor {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name](**kwargs)


def compress_tree(comp: Compressor, key: jax.Array, tree):
    """Apply ``comp`` leaf-wise to a pytree with independent PRNG streams.

    Leaves may carry a leading agent axis; compression is applied to the whole
    leaf buffer per agent row (vmapped) so every agent compresses its own
    vector independently, as in the paper.
    """
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))

    def one(key, leaf):
        if leaf.ndim >= 2:  # (n_agents, ...) -> compress per agent row
            n = leaf.shape[0]
            ks = jax.random.split(key, n)
            return jax.vmap(lambda kk, row: comp(kk, row))(ks, leaf)
        return comp(key, leaf)

    return treedef.unflatten([one(k, l) for k, l in zip(keys, leaves)])


# ---------------------------------------------------------------------------
# Packed top-k wire format (used by gossip 'packed_topk' mode).
# ---------------------------------------------------------------------------

def topk_pack(x: jax.Array, k: int):
    """Pack a vector into (values, int32 indices) of its top-k magnitudes."""
    flat = x.reshape(-1)
    vals_abs, idx = jax.lax.top_k(jnp.abs(flat), k)
    del vals_abs
    return flat[idx], idx.astype(jnp.int32)


def topk_unpack(values: jax.Array, indices: jax.Array, d: int) -> jax.Array:
    """Scatter packed (values, indices) back into a dense d-vector."""
    return jnp.zeros((d,), values.dtype).at[indices].set(values)
