"""BEER [ZLL+22] -- the unclipped ancestor of PORTER.

The paper (Section 4.3): "When the gradients are bounded, we can omit the
clipping operator in PORTER-GC, which become the same as BEER."  So BEER is
PORTER with ``variant='beer'``; this module just packages that fact so
experiments can ask for BEER by name and so the equivalence is pinned by a
test (tests/test_porter.py::test_beer_is_unclipped_porter).
"""

from __future__ import annotations

from .porter import PorterConfig

__all__ = ["beer_config"]


def beer_config(eta: float, gamma: float, **kwargs) -> PorterConfig:
    """PorterConfig pinned to the BEER point of the algorithm family.

    ``variant`` and ``tau`` are what *make* BEER (no clipping); accepting a
    caller's values and ignoring them would silently run a different
    algorithm, so they are rejected instead
    (tests/test_porter.py::test_beer_config_rejects_clipping_overrides).
    """
    for fixed in ("variant", "tau"):
        if fixed in kwargs:
            raise ValueError(
                f"beer_config fixes {fixed!r} (BEER is unclipped PORTER); "
                f"got {fixed}={kwargs[fixed]!r} -- use PorterConfig directly "
                "for a clipped variant")
    return PorterConfig(eta=eta, gamma=gamma, variant="beer", tau=float("inf"),
                        **kwargs)
