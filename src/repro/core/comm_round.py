"""The comm-round engine: one fused EF/gossip primitive for every
compressed-communication algorithm in the repo.

Every compressed decentralized method here (PORTER, PORTER-Adam, BEER,
CHOCO-SGD, SoteriaFL) repeats the same per-round pattern around a buffer
``y`` with surrogate ``q`` and mixing mirror ``m``:

    c   =  C(y - q)          compress the increment        (hits the wire)
    q  +=  c                 surrogate accumulate          (local)
    m  +=  W c               mixing-mirror accumulate      (receive side)
    y'  =  f(y, m - q, ...)  algorithm-specific fused update

:class:`CommRound` owns that pattern once.  Compression and mixing run in
the *pytree domain* (so shard-local compressors and the ring/packed wire
executors keep their PartitionSpecs), while the AXPY chain of the update
runs over the flat tile layout of :mod:`repro.kernels.flatten` so the fused
Pallas kernels (:mod:`repro.kernels.ef_update`) touch each parameter once
per round instead of ~13 separate HBM-bound tree_map passes.

Backends:

* ``'pallas'`` -- flatten to (tiles, 8*1024) planes, run ef_track /
  ef_step / ef_gossip (Mosaic on TPU; pass ``interpret=True`` for CPU CI).
* ``'ref'``    -- pure-jnp tree_map chain, bit-identical to the pre-engine
  per-algorithm bodies; the numerical oracle.
* ``'auto'``   -- 'pallas' on TPU, 'ref' elsewhere (the default, resolved
  by :func:`resolve_backend`: BENCH_comm.json measures pallas-interpret
  ~3x slower than ref on CPU, so off-TPU auto must mean ref).

Mixed precision (``plane_dtype='bf16'`` through the facade): the EF state
buffers (q, m, v, g_prev) live in bf16, so packed planes and the gossip
wire both carry 2 B/element while the master params ``x`` stay f32 exact
(the plane dtype is derived *per buffer tree* -- see
:func:`repro.kernels.flatten.derived_plane_dtype`).  Every fused kernel
still accumulates in f32 inside the block; the writeback to a bf16 buffer
goes through the stochastic-rounding cast (:mod:`repro.kernels.sr_cast`)
so the EF drift stays unbiased, with the SR key split off the round key
(:meth:`CommRound.sr_split`) -- f32 engines never split, so their RNG
streams are bit-identical to the pre-mixed-precision code.  The push-sum
weight plane stays f32-exact on every path.

Sharding: for pure data/agent-sharded states (every buffer
P(agents, None, ...)) the flat plane is sharded along its row axis and the
in-jit pack is reshard-free.  When the engine is built with ``mesh`` +
``leaf_specs`` that carry model axes (tensor-parallel layouts), the pallas
path switches to *per-shard planes*: pack -> kernel -> unpack runs inside
``shard_map`` with those leaf specs, one padded plane per (agent shard x
model shard), so no buffer is ever all-gathered over the model axis
(:func:`repro.kernels.flatten.plane_apply`).  ``backend='pallas'`` is
therefore safe on every layout the launch layer builds.

Time-varying topologies: the engine's methods take the absolute round index
``t`` and forward it to the mixer (:func:`repro.core.gossip.apply_mixer`),
which gathers ``W_{t mod period}`` from its device-resident schedule table
inside the compiled program.  ``W_t`` therefore enters the round as a traced
value, and everything downstream of the mix -- including the fused ef_track
/ ef_step / ef_gossip plane kernels -- consumes ``wc = W_t @ c`` as data,
so the pallas path and the per-shard plane layout need no schedule plumbing
at all.

Push-sum (directed graphs): :meth:`CommRound.exchange_ps` /
:meth:`CommRound.step_ps` run the same round over a *column*-stochastic
``W_t`` while carrying the scalar push-sum weight plane (DP-CSGP's
de-biasing state, read points divide by it) through the **same**
collectives the param round already issues -- an extra flat column for
the dense/ring executors, +4 bitcast bytes on the codec buffers -- so
directed gossip adds zero communication ops (HLO-asserted) and the weight
increment is transported exactly (never compressed: compressing it would
break the column-mass invariant ``1^T W = 1^T`` that push-sum relies on).

Wire accounting: :meth:`CommRound.wire_bytes` converts (gossip mode,
compressor, n_agents, d) into per-round bytes via
:func:`repro.core.gossip.gossip_wire_bytes` / ``Compressor.wire_bits`` so
every algorithm reports the same ``wire_bytes`` metric and cross-algorithm
comparisons are apples-to-apples (benchmarks/ablation.py).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from jax.sharding import PartitionSpec as P

from ..kernels import flatten as FL
from ..kernels import ops
from . import wire_formats as WF
from .compression import Compressor
from .gossip import PACK_BLOCK, MixFn, apply_mixer, gossip_wire_bytes

__all__ = ["CommRound", "compress_stacked", "resolve_backend",
           "resolve_engine"]

CompressFn = Callable[[jax.Array, Any], Any]  # (key, tree) -> tree


def resolve_backend(backend: str) -> str:
    """Resolve 'auto' to a concrete comm-round backend for this process.

    'auto' means the fused pallas kernels *on TPU only*: off-TPU the
    kernels run in interpret mode, which BENCH_comm.json measures at ~3x
    the ref backend's wall time on every compressor (e.g. top_k 17483 vs
    5672 us/round on CPU), so auto resolves to 'ref' everywhere except a
    real TPU backend.  This is the single resolution point -- the engine
    and the facade's wire-format builder both call it, so they can never
    disagree.
    """
    if backend == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "ref"
    if backend not in ("pallas", "ref"):
        raise ValueError(f"unknown comm-round backend {backend!r}")
    return backend


def _sr_dtype(tree) -> bool:
    """True when ``tree``'s buffers take the stochastic-rounding writeback
    (bf16 -- the only sub-f32 plane dtype the engine supports)."""
    leaves = jax.tree_util.tree_leaves(tree)
    dt = jnp.result_type(*[l.dtype for l in leaves])
    return jnp.dtype(dt) == jnp.dtype(jnp.bfloat16)


def compress_stacked(comp: Compressor, key: jax.Array, tree):
    """Compress each agent's row of every leaf independently (paper setup:
    every agent compresses its own increment; per-leaf to match the
    convergence tests' rho accounting)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))

    def one(k, leaf):
        n = leaf.shape[0]
        ks = jax.random.split(k, n)
        return jax.vmap(lambda kk, row: comp(kk, row))(ks, leaf)

    return treedef.unflatten([one(k, l) for k, l in zip(keys, leaves)])


def _tree(op, *trees):
    return jax.tree_util.tree_map(op, *trees)


def resolve_engine(engine: Optional["CommRound"], mixer=None,
                   compressor: Optional[Compressor] = None,
                   compress_fn: Optional[CompressFn] = None,
                   backend: str = "auto",
                   interpret: Optional[bool] = None) -> "CommRound":
    """Return ``engine`` or build one from the pieces -- never both.

    When an ``engine`` is given it owns its compressor/mixer/compress_fn;
    passing a *different* object alongside it used to be silently ignored
    (the footgun: the positional pieces looked load-bearing but were not).
    Now it raises -- build the engine with the right pieces instead (the
    facade :func:`repro.api.build` / :func:`repro.api.build_engine` is the
    one place engines are constructed).

    ``mixer=None`` without an engine is allowed: server/client algorithms
    (SoteriaFL, DP-SGD accounting) compress without gossip.
    """
    if engine is not None:
        for what, given, owned in (("mixer", mixer, engine.mixer),
                                   ("compressor", compressor,
                                    engine.compressor),
                                   ("compress_fn", compress_fn,
                                    engine.compress_fn)):
            if given is not None and given is not owned:
                raise ValueError(
                    f"both engine= and a conflicting {what} were given; the "
                    f"engine owns its {what} -- pass the pieces the engine "
                    "was built with (or None), or rebuild it via "
                    "repro.api.build_engine")
        return engine
    if compressor is None:
        raise ValueError("need either engine= or a compressor")
    return CommRound(compressor=compressor, mixer=mixer,
                     compress_fn=compress_fn, backend=backend,
                     interpret=interpret)


@dataclasses.dataclass(frozen=True)
class CommRound:
    """One compressed communication round: compress -> accumulate -> update.

    Attributes:
      compressor: the rho-compressor (Definition 3); also drives wire
        accounting.
      mixer: gossip executor ``tree -> W @ tree`` over the agent axis
        (core.gossip); its ``wire_mode`` tag selects the wire format for
        byte accounting.
      compress_fn: optional (key, tree) -> tree override, e.g. the
        shard-local compressor from launch.steps.  Defaults to per-agent
        per-leaf compression of ``compressor``.
      backend: 'pallas' | 'ref' | 'auto'.
      interpret: Pallas interpret mode; None = auto (True off-TPU).
      mesh / leaf_specs / agent_axes: sharded-layout hooks (the facade
        ``repro.api.build_engine`` plumbs them from the launch layer).  When
        ``leaf_specs`` shard a non-agent mesh axis, the pallas path packs
        per-shard planes inside ``shard_map`` instead of one global plane.
      overlap: comm/compute overlap.  The PORTER family runs *two* comm
        rounds per step whose exchanges are data-independent (the x-side
        inputs ``(x, q_x)`` are untouched by the v-side update); with
        ``overlap=True`` the algorithm steps issue both compress+collective
        pairs *before* either fused update, so XLA's async collectives run
        while the other round's local compute proceeds.  Every intermediate
        value is identical to the sequential order, so the flag is bit-exact
        by construction (tests pin this for all registered algorithms);
        single-round algorithms ignore it.
      plane_dtype: declared storage dtype of the EF state planes (None =
        legacy f32).  The *actual* plane dtype is always derived from the
        buffers themselves (so f32 master params keep f32 planes next to
        bf16 EF buffers); this field drives the scalar-``d`` wire-byte
        accounting and documents the engine's precision contract.  Must be
        f32 or bf16: the SR writeback targets bf16 only.

    Wire formats: when the mixer was built with a
    :class:`repro.core.wire_formats.WireFormat` codec (``spec.wire =
    "packed_bits"`` through the facade), :meth:`exchange` routes through
    ``mixer.exchange`` -- compression is *fused with packing* and only
    bit-packed buffers cross the wire; the locally applied increment is the
    round-trip ``c = unpack(pack(y - q))``, which keeps the ``m = W q``
    invariant exact.  :meth:`wire_bytes` then reports the **measured** nbytes
    of the shipped buffers (shapes traced with ``jax.eval_shape`` on the
    codec itself) and :meth:`wire_bytes_model` keeps the analytic byte model
    as a cross-check (``bench_comm_round.py --achieved-bytes`` asserts they
    agree).
    """

    compressor: Compressor
    mixer: MixFn
    compress_fn: Optional[CompressFn] = None
    backend: str = "auto"
    interpret: Optional[bool] = None
    mesh: Any = None
    leaf_specs: Any = None
    agent_axes: Sequence[str] = ("data",)
    overlap: bool = False
    plane_dtype: Any = None

    def __post_init__(self):
        if self.backend not in ("pallas", "ref", "auto"):
            raise ValueError(f"unknown comm-round backend {self.backend!r}")
        if self.plane_dtype is not None:
            pdt = jnp.dtype(self.plane_dtype)
            if pdt not in (jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16)):
                raise ValueError(
                    f"plane_dtype must be f32 or bf16, got {pdt} -- the "
                    "stochastic-rounding writeback targets bf16 only")

    # -- backend plumbing ---------------------------------------------------

    def _use_pallas(self) -> bool:
        return resolve_backend(self.backend) == "pallas"

    def _kernel_kw(self):
        return {} if self.interpret is None else {"interpret": self.interpret}

    def _sharded_planes(self) -> Optional[FL.ShardedFlatSpec]:
        """Per-shard plane layout, or None for the single-plane fast path."""
        if (self.mesh is None or self.leaf_specs is None
                or not FL.specs_have_model_axes(self.leaf_specs,
                                                self.agent_axes)):
            return None
        return FL.sharded_spec(self.mesh, self.leaf_specs)

    # -- stochastic-rounding plumbing ---------------------------------------

    def sr_split(self, key, trees) -> Tuple[jax.Array, Optional[jax.Array]]:
        """Split an SR key off ``key`` when any of ``trees`` is bf16.

        Returns ``(compress_key, sr_key)``; for all-f32 buffers the key is
        returned untouched with ``sr_key=None``, so f32 engines keep their
        historical RNG streams bit-identical.  Overlap-mode algorithm steps
        call this before :meth:`exchange` with the same buffer tuple the
        sequential path passes internally, which keeps overlap==sequential
        bit-exact under mixed precision too.
        """
        if not any(_sr_dtype(t) for t in trees):
            return key, None
        k_c, k_sr = jax.random.split(key)
        return k_c, k_sr

    def _plane_update(self, kfn, trees, sr_key):
        """Fused 3-output kernel over planes, with SR writeback when asked.

        ``kfn(*planes, out_dtype=...)`` must return three planes whose
        destinations are ``trees[:3]`` in order.  With an ``sr_key`` and
        any bf16 destination, the kernel is asked for f32 outputs and each
        bf16-bound plane is stochastically rounded before unpacking; f32
        destinations pass through exact.  Under per-shard planes the SR key
        is folded with every mesh axis index so no two shards reuse bits.
        """
        sharded = self._sharded_planes()
        needs = [_sr_dtype(t) for t in trees[:3]]
        if sr_key is None or not any(needs):
            return FL.plane_apply(lambda *p: kfn(*p), trees, 3, sharded)
        kw = self._kernel_kw()
        axis_names = (tuple(sharded.mesh.axis_names)
                      if sharded is not None else ())

        def kernel(*planes):
            outs = kfn(*planes, out_dtype=jnp.float32)
            key = sr_key
            for ax in axis_names:
                key = jax.random.fold_in(key, jax.lax.axis_index(ax))
            keys = jax.random.split(key, 3)
            return tuple(ops.sr_cast(o, keys[i], **kw) if needs[i] else o
                         for i, o in enumerate(outs))

        return FL.plane_apply(kernel, trees, 3, sharded)

    @staticmethod
    def _sr_writeback(tree_f32, like, key):
        """Cast an f32 result tree back to ``like``'s buffer dtypes (ref
        backend): stochastic rounding into bf16 leaves, plain astype into
        everything else."""
        leaves, treedef = jax.tree_util.tree_flatten(like)
        vals = jax.tree_util.tree_leaves(tree_f32)
        keys = jax.random.split(key, len(vals))
        out = []
        for val, l, kk in zip(vals, leaves, keys):
            if jnp.dtype(l.dtype) == jnp.dtype(jnp.bfloat16):
                out.append(ops.sr_cast_leaf(val, kk))
            else:
                out.append(val.astype(l.dtype))
        return treedef.unflatten(out)

    @staticmethod
    def _f32(tree):
        return _tree(lambda l: l.astype(jnp.float32), tree)

    # -- the shared front half: compress + mix ------------------------------

    def compress(self, key: jax.Array, delta):
        """c = C(delta), in the pytree domain (shard-local aware)."""
        if self.compress_fn is not None:
            return self.compress_fn(key, delta)
        return compress_stacked(self.compressor, key, delta)

    def exchange(self, key: jax.Array, y, q, t=None) -> Tuple[Any, Any]:
        """Compress the increment of ``y`` against surrogate ``q`` and mix.

        Returns ``(c, wc)`` with ``c = C(y - q)`` (what the agent puts on
        the wire) and ``wc = W @ c`` (what it accumulates off the wire).
        ``t`` is the absolute round index -- required (and traced) when the
        mixer runs a time-varying topology schedule, ignored otherwise; the
        fused plane kernels downstream consume ``wc`` as data, so the whole
        pallas path is schedule-agnostic.

        With a codec mixer (bit-packed wire format) the compression step is
        fused into the executor: pack once, apply the round-tripped
        increment locally, ship only the packed buffers.

        The increment is computed in the *surrogate's* dtype: with a bf16
        ``q`` beside the f32 master ``y = x``, a plain subtract would
        promote to f32 and put a 4 B/element buffer on the wire.  The
        narrowing is a deterministic cast (its error is measured afresh by
        the next round's ``y - q``, so EF self-corrects); stochastic
        rounding is reserved for the *accumulating* q/m/v writebacks where
        bias compounds.
        """
        delta = _tree(lambda a, b: (a - b).astype(b.dtype), y, q)
        if getattr(self.mixer, "wire_codec", None) is not None:
            return self.mixer.exchange(key, delta, t)
        c = self.compress(key, delta)
        return c, apply_mixer(self.mixer, c, t)

    def exchange_ps(self, key, y, q, yw, qw, t=None):
        """Push-sum exchange: :meth:`exchange` plus the scalar weight plane.

        ``yw``/``qw`` are the (n,) push-sum weight buffer and its surrogate.
        Returns ``(c, wc, cw, wcw)`` where ``(c, wc)`` are the compressed
        param increment and its mix exactly as in :meth:`exchange`, and
        ``cw = yw - qw`` (the weight increment, **never compressed** -- the
        column-mass invariant ``1^T W = 1^T`` breaks otherwise) with
        ``wcw = W_t @ cw``.  The weight rides *inside* the collectives the
        param round already issues (an extra flat column for dense/ring, +4
        bitcast bytes on the codec buffers), so the collective count is
        identical to :meth:`exchange` -- the HLO tests pin this.
        """
        delta = _tree(lambda a, b: (a - b).astype(b.dtype), y, q)
        dw = jnp.subtract(yw, qw)
        if getattr(self.mixer, "wire_codec", None) is not None:
            return self.mixer.exchange_ps(key, delta, dw, t)
        push = getattr(self.mixer, "push", None)
        if push is None:
            raise ValueError(
                "push-sum needs a mixer with weight-plane transport (the "
                "dense or ring executor, or a codec executor built with "
                "wire='packed_bits'); the plain packed all-gather mixer "
                "ships (value, index) pairs only and has no slot for the "
                "weight scalar -- use gossip='ring'/'dense' or a bit-packed "
                "wire format for directed (column-stochastic) topologies")
        c = self.compress(key, delta)
        wc, wcw = push(c, dw, t)
        return c, wc, dw, wcw

    # -- fused state updates ------------------------------------------------

    def track(self, key, v, q, m, g, g_prev, gamma: float, t=None):
        """PORTER Algorithm 1 lines 11-12 (gradient-estimate track).

        q += c; m += Wc; v' = v + gamma*(m - q) + g - g_prev.
        Returns (v', q', m').  ``t``: absolute round index for time-varying
        mixers (see :meth:`exchange`).
        """
        key, sr_key = self.sr_split(key, (q, m, v))
        c, wc = self.exchange(key, v, q, t)
        return self.track_update(c, wc, v, q, m, g, g_prev, gamma,
                                 sr_key=sr_key)

    def track_update(self, c, wc, v, q, m, g, g_prev, gamma: float,
                     sr_key=None):
        """The fused second half of :meth:`track` (no communication).

        Exposed separately so overlap mode can issue several exchanges
        before running any update (see the ``overlap`` attribute).
        ``sr_key``: stochastic-rounding key for bf16 buffers (from
        :meth:`sr_split`); None falls back to deterministic casts.
        """
        kw = self._kernel_kw()
        if self._use_pallas():
            qo, mo, vo = self._plane_update(
                lambda *p, out_dtype=None: ops.ef_track(
                    *p, gamma, out_dtype=out_dtype, **kw),
                (q, m, v, c, wc, g, g_prev), sr_key)
            return vo, qo, mo
        if sr_key is not None and any(_sr_dtype(t) for t in (q, m, v)):
            q2f = _tree(jnp.add, self._f32(q), self._f32(c))
            m2f = _tree(jnp.add, self._f32(m), self._f32(wc))
            v2f = _tree(lambda v0, mm, qq, gn, gp: v0 + gamma * (mm - qq)
                        + gn - gp, self._f32(v), m2f, q2f, self._f32(g),
                        self._f32(g_prev))
            kq, km, kv = jax.random.split(sr_key, 3)
            return (self._sr_writeback(v2f, v, kv),
                    self._sr_writeback(q2f, q, kq),
                    self._sr_writeback(m2f, m, km))
        q2 = _tree(jnp.add, q, c)
        m2 = _tree(jnp.add, m, wc)
        v2 = _tree(lambda v0, mm, qq, gn, gp: v0 + gamma * (mm - qq)
                   + gn - gp, v, m2, q2, g, g_prev)
        return v2, q2, m2

    def step(self, key, x, q, m, v, gamma: float, eta: float, t=None):
        """PORTER Algorithm 1 lines 13-14 (parameter step).

        q += c; m += Wc; x' = x + gamma*(m - q) - eta*v, cast to x.dtype.
        Returns (x', q', m').  ``v`` may be any descent direction (PORTER
        passes the tracked gradient, PORTER-Adam its preconditioned form).
        ``t``: absolute round index for time-varying mixers.
        """
        key, sr_key = self.sr_split(key, (q, m, x))
        c, wc = self.exchange(key, x, q, t)
        return self.step_update(c, wc, x, q, m, v, gamma, eta, sr_key=sr_key)

    def step_update(self, c, wc, x, q, m, v, gamma: float, eta: float,
                    sr_key=None):
        """The fused second half of :meth:`step` (no communication).

        ``sr_key``: stochastic-rounding key for bf16 buffers (the master
        params ``x`` normally stay f32 and take an exact writeback; only
        the q/m surrogates round stochastically).
        """
        kw = self._kernel_kw()
        if self._use_pallas():
            qo, mo, xo = self._plane_update(
                lambda *p, out_dtype=None: ops.ef_step(
                    *p, gamma, eta, out_dtype=out_dtype, **kw),
                (q, m, x, c, wc, v), sr_key)
            return xo, qo, mo
        if sr_key is not None and any(_sr_dtype(t) for t in (q, m, x)):
            q2f = _tree(jnp.add, self._f32(q), self._f32(c))
            m2f = _tree(jnp.add, self._f32(m), self._f32(wc))
            x2f = _tree(lambda x0, mm, qq, vv:
                        x0 + gamma * (mm - qq) - eta * vv,
                        self._f32(x), m2f, q2f, self._f32(v))
            kq, km, kx = jax.random.split(sr_key, 3)
            return (self._sr_writeback(x2f, x, kx),
                    self._sr_writeback(q2f, q, kq),
                    self._sr_writeback(m2f, m, km))
        q2 = _tree(jnp.add, q, c)
        m2 = _tree(jnp.add, m, wc)
        x2 = _tree(lambda x0, mm, qq, vv:
                   (x0 + gamma * (mm - qq) - eta * vv).astype(x0.dtype),
                   x, m2, q2, v)
        return x2, q2, m2

    def step_ps(self, key, x, q, m, v, xw, qw, mw, gamma: float, eta: float,
                t=None):
        """Push-sum parameter step: :meth:`step` plus the weight recursion.

        The param buffers update exactly as :meth:`step`; the (n,) weight
        planes follow the same EF/gossip recursion with the *exact*
        increment (``qw += cw; mw += W cw; xw' = xw + gamma*(mw - qw)``),
        which composes to ``xw' = ((1-gamma) I + gamma W) xw`` -- still
        column-stochastic, so the weights stay strictly positive and
        converge to ``n * pi`` (the Perron vector).  Read points de-bias by
        ``x / xw``.  Returns (x', q', m', xw', qw', mw').
        """
        key, sr_key = self.sr_split(key, (q, m, x))
        c, wc, cw, wcw = self.exchange_ps(key, x, q, xw, qw, t)
        return self.step_ps_update(c, wc, cw, wcw, x, q, m, v, xw, qw, mw,
                                   gamma, eta, sr_key=sr_key)

    def step_ps_update(self, c, wc, cw, wcw, x, q, m, v, xw, qw, mw,
                       gamma: float, eta: float, sr_key=None):
        """The fused second half of :meth:`step_ps` (no communication).

        The weight-plane update is three (n,)-vector AXPYs -- negligible
        next to the param planes, so it stays plain jnp on every backend,
        and it is *always* f32-exact: compressing or rounding the push-sum
        weight would break the column-mass invariant ``1^T xw = n``.
        """
        x2, q2, m2 = self.step_update(c, wc, x, q, m, v, gamma, eta,
                                      sr_key=sr_key)
        qw2 = qw + cw
        mw2 = mw + wcw
        xw2 = (xw + gamma * (mw2 - qw2)).astype(xw.dtype)
        return x2, q2, m2, xw2, qw2, mw2

    def gossip_apply(self, key, y, q, m, gamma: float, scale: float = 1.0,
                     t=None):
        """CHOCO-SGD / SoteriaFL-style round (no tracking term).

        q += scale*c; m += scale*Wc; y' = y + gamma*(m - q).
        Returns (y', q', m').  ``scale`` is the shift stepsize (1 for
        CHOCO, alpha for shifted compression); ``t`` the absolute round
        index for time-varying mixers.
        """
        key, sr_key = self.sr_split(key, (q, m, y))
        c, wc = self.exchange(key, y, q, t)
        kw = self._kernel_kw()
        if self._use_pallas():
            qo, mo, yo = self._plane_update(
                lambda *p, out_dtype=None: ops.ef_gossip(
                    *p, gamma, scale, out_dtype=out_dtype, **kw),
                (q, m, y, c, wc), sr_key)
            return yo, qo, mo
        if sr_key is not None and any(_sr_dtype(t) for t in (q, m, y)):
            q2f = _tree(lambda a, b: a + scale * b, self._f32(q),
                        self._f32(c))
            m2f = _tree(lambda a, b: a + scale * b, self._f32(m),
                        self._f32(wc))
            y2f = _tree(lambda y0, mm, qq: y0 + gamma * (mm - qq),
                        self._f32(y), m2f, q2f)
            kq, km, ky = jax.random.split(sr_key, 3)
            return (self._sr_writeback(y2f, y, ky),
                    self._sr_writeback(q2f, q, kq),
                    self._sr_writeback(m2f, m, km))
        q2 = _tree(lambda a, b: a + scale * b, q, c)
        m2 = _tree(lambda a, b: a + scale * b, m, wc)
        y2 = _tree(lambda y0, mm, qq: y0 + gamma * (mm - qq), y, m2, q2)
        return y2, q2, m2

    def shift(self, key, y, q, scale: float = 1.0):
        """SoteriaFL shifted compression (mirrorless surrogate accumulate).

        c = C(y - q); q' = q + scale*c.  Returns (c, q') -- the caller owns
        the server-side aggregation of ``c`` (a mean, not a gossip mix).
        """
        c = self.compress(key, _tree(lambda a, b: (a - b).astype(b.dtype),
                                     y, q))
        return c, _tree(lambda a, b: (a + scale * b).astype(a.dtype), q, c)

    # -- wire accounting ----------------------------------------------------

    def _packed_windows(self, tree, n_agents: int) -> int:
        """PACK_BLOCK windows the packed executor actually pads for ``tree``.

        ``make_packed_mixer.local`` packs each *leaf* separately and, under
        a sharded layout, runs once per model shard -- so the window count
        is summed per (leaf x model shard), not derived from the
        concatenated element count (which under-reports whenever separate
        pads each round up).  Falls back to unsharded per-leaf counts when
        the engine carries no layout or the specs do not match ``tree``.
        """
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        shard_counts = [1] * len(leaves)
        if self.mesh is not None and self.leaf_specs is not None:
            specs, sdef = jax.tree_util.tree_flatten(
                self.leaf_specs, is_leaf=lambda x: isinstance(x, P))
            if sdef == treedef:
                agent = set(self.agent_axes)

                def nshards(s) -> int:
                    n = 1
                    for entry in tuple(s):
                        if entry is None:
                            continue
                        names = (entry if isinstance(entry, tuple)
                                 else (entry,))
                        for name in names:
                            if name not in agent:
                                n *= int(self.mesh.shape[name])
                    return n

                shard_counts = [nshards(s) if isinstance(s, P) else 1
                                for s in specs]
        total = 0
        for leaf, ns in zip(leaves, shard_counts):
            d_leaf = int(leaf.size) // n_agents
            local = -(-d_leaf // ns)               # per-shard elements
            total += ns * (-(-local // PACK_BLOCK))
        return total

    def _ps_weight_bytes(self, n_agents: int, measured: bool) -> float:
        """Extra bytes the push-sum weight plane puts on the wire per round.

        Each shipped agent buffer set carries one exact f32 weight (4
        bytes): as a flat extra column for dense/ring, as bitcast words
        appended to the last codec buffer.  The multiplier follows each
        mode's link convention (:func:`repro.core.gossip.gossip_wire_bytes`):
        'ring' ships per-agent to its live neighbors (one shift at n=2),
        every other mode ships all n agents' buffers.  For codec mixers the
        measured path traces the weight-word layout off the codec itself
        (:func:`repro.core.wire_formats.measured_weight_nbytes`).
        """
        codec = getattr(self.mixer, "wire_codec", None)
        if codec is not None and measured:
            per = float(WF.measured_weight_nbytes(codec))
        else:
            per = 4.0
        mode = getattr(self.mixer, "wire_mode", "dense")
        if mode == "ring":
            return (1.0 if n_agents == 2 else 2.0) * per
        return float(n_agents) * per

    def wire_bytes(self, tree_or_d, n_agents: Optional[int] = None,
                   push_sum: bool = False) -> float:
        """Model-level bytes crossing agent links per round for one buffer.

        Accepts either an agent-stacked pytree (n and d inferred) or a
        per-agent parameter count ``d`` plus ``n_agents``.  Accounting
        follows the mixer's wire format, with each mode's convention taken
        from :func:`repro.core.gossip.gossip_wire_bytes`: 'ring' exchanges
        dense neighbor increments (2*d floats per agent, n-independent);
        'packed' all-gathers (value, int32 index) pairs; 'dense' emulation
        charges the compressor's own payload (``Compressor.wire_bits``),
        which is n*d floats for identity and k*(value+index) for the
        sparse family -- i.e. the bytes a real deployment of that
        compressor would move.  For 'packed' with a pytree the window
        count is exact per (leaf x model shard) via
        :meth:`_packed_windows` -- the executor pads each leaf (and each
        shard) separately, so ``gossip_wire_bytes``'s single-buffer model
        would under-report; the scalar-``d`` overload keeps the
        single-buffer convention.  Compare algorithms under the *same*
        gossip mode (as benchmarks/ablation.py does); cross-mode numbers
        follow each wire format's own link accounting.

        ``push_sum=True`` accounts a :meth:`exchange_ps` round instead: the
        weight plane's bytes (4 per shipped buffer set, see
        :meth:`_ps_weight_bytes`) are added on top, in both the measured and
        the model path, so ``--achieved-bytes`` parity covers the directed
        codec path too.

        Mixed precision: the dense-neighbor 'ring' payload and the value
        half of 'packed' pairs ship in the engine's ``plane_dtype`` (2
        B/element for bf16 -- what a pytree of bf16 buffers actually puts
        through ``ppermute``/all-gather); indices stay int32 and the
        push-sum weight stays 4-byte f32.  The 'dense' emulation path
        charges ``Compressor.wire_bits`` unchanged -- that model describes
        the compressor's own (f32 value, index) deployment payload, not
        buffers this process ships, so it does not narrow with the planes.
        """
        codec = getattr(self.mixer, "wire_codec", None)
        if codec is not None:
            return self._codec_bytes(tree_or_d, n_agents, measured=True,
                                     push_sum=push_sum)
        tree = None
        if n_agents is None:
            tree = tree_or_d
            leaves = jax.tree_util.tree_leaves(tree)
            n_agents = leaves[0].shape[0]
            d = sum(int(l.size) // n_agents for l in leaves)
        else:
            d = int(tree_or_d)
        db = (float(jnp.dtype(self.plane_dtype).itemsize)
              if self.plane_dtype is not None else 4.0)
        extra = (self._ps_weight_bytes(n_agents, measured=True)
                 if push_sum else 0.0)
        mode = getattr(self.mixer, "wire_mode", "dense")
        if mode in ("ring", "packed"):
            frac = getattr(self.mixer, "wire_frac", None)
            frac = self.compressor.rho if frac is None else frac
            if mode == "packed" and tree is not None:
                k_b = max(int(round(frac * PACK_BLOCK)), 1)
                windows = self._packed_windows(tree, n_agents)
                return (float(n_agents) * windows * k_b * (db + 4.0)
                        + extra)
            return gossip_wire_bytes(mode, n_agents, d, frac=frac,
                                     dtype_bytes=db) + extra
        return n_agents * self.compressor.wire_bits(d) / 8.0 + extra

    def wire_bytes_model(self, tree_or_d, n_agents: Optional[int] = None,
                         push_sum: bool = False) -> float:
        """The *analytic* byte model for the same round (cross-check).

        For codec (bit-packed) mixers this is the layout arithmetic of
        :class:`repro.core.wire_formats.WireFormat` -- windows times
        (payload + overhead) bytes per window -- whereas
        :meth:`wire_bytes` measures the shipped buffers' nbytes from their
        traced shapes; ``bench_comm_round.py --achieved-bytes`` asserts the
        two agree exactly.  For every other mixer the model *is* the
        accounting, so this returns the same value as :meth:`wire_bytes`.
        """
        if getattr(self.mixer, "wire_codec", None) is not None:
            return self._codec_bytes(tree_or_d, n_agents, measured=False,
                                     push_sum=push_sum)
        return self.wire_bytes(tree_or_d, n_agents, push_sum=push_sum)

    def _codec_bytes(self, tree_or_d, n_agents: Optional[int],
                     measured: bool, push_sum: bool = False) -> float:
        """Collective bytes under a codec mixer, measured or modeled.

        Windows are counted per (leaf x model shard) exactly like
        :meth:`_packed_windows` (each shard pads and packs separately);
        per-window bytes come either from ``jax.eval_shape`` over the codec
        itself (measured -- cannot drift from the executor) or from the
        registered layout constants (model).  'ring' ships each agent's
        buffers to its live neighbors (one shift at n=2 by band folding,
        else two); 'packed' all-gathers every agent's buffers.
        """
        codec = self.mixer.wire_codec
        if n_agents is None:
            tree = tree_or_d
            n_agents = jax.tree_util.tree_leaves(tree)[0].shape[0]
            windows = self._packed_windows(tree, n_agents)
        else:
            windows = codec.windows(int(tree_or_d))
        if measured:
            per_window = float(WF.measured_pack_nbytes(codec, PACK_BLOCK))
        else:
            per_window = float(codec.payload_bytes_per_window
                               + codec.overhead_bytes_per_window)
        per_agent = windows * per_window
        if push_sum:
            per_agent += (float(WF.measured_weight_nbytes(codec))
                          if measured else 4.0)
        mode = getattr(self.mixer, "wire_mode", "packed")
        if mode == "ring":
            shifts = 1.0 if n_agents == 2 else 2.0
            return shifts * per_agent
        return float(n_agents) * per_agent
