"""Algorithm registry: one uniform surface for every decentralized optimizer.

The paper's headline claim is a *uniform* analysis framework covering the
clipping variants (PORTER-DP / PORTER-GC), their no-clip ancestor (BEER) and
the baselines it compares against (CHOCO-SGD, DSGD, SoteriaFL, DP-SGD).  The
code mirrors that: every algorithm is registered here as a factory that
:func:`repro.api.build` turns into an :class:`Algorithm` with one shape:

    state = algo.init(params)                       # or init(params, n, w)
    state, metrics = algo.step(state, batch, key)   # pure; jit/pjit-able

Metrics schema (uniform, enforced by tests/test_api_registry.py): every
``step`` emits at least ``loss`` (mean agent loss) and ``wire_bytes``
(model-level bytes crossing links per round); decentralized algorithms add
``consensus_x``.

This module holds only the registry machinery -- the nine concrete
registrations live in :mod:`repro.api`, which also owns the construction of
topologies, mixers, compressors and comm-round engines (no call site should
build those by hand).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax

__all__ = [
    "Algorithm",
    "AlgorithmInfo",
    "register_algorithm",
    "algorithm_info",
    "get_factory",
    "list_algorithms",
]

# step(state, batch, key) -> (state, metrics)
StepFn = Callable[[Any, Any, jax.Array], Tuple[Any, Dict[str, jax.Array]]]
# init(params, n_agents=None, w=None) -> state
InitFn = Callable[..., Any]


@dataclasses.dataclass(frozen=True)
class AlgorithmInfo:
    """Static capabilities of a registered algorithm.

    dp:            the gradient oracle clips per-sample and adds Gaussian
                   noise (an LDP mechanism; drivers calibrate sigma_p and
                   accept non-decreasing smoke losses for these).
    decentralized: runs over a communication graph (needs topology + mixer;
                   emits ``consensus_x``).
    compressed:    communicates through a rho-compressor (needs a
                   :class:`repro.core.comm_round.CommRound` engine).
    comm_rounds:   gossip exchanges per ``step`` (mixer applications).  This
                   is a *declared budget*, not a measurement: the static
                   analyzer (:mod:`repro.analysis.hlo`) multiplies it by the
                   mixer's per-round :class:`repro.core.gossip.GossipBudget`
                   and the number of gossiped leaves to bound how many
                   collectives the compiled step may contain.  PORTER-family
                   algorithms exchange both the compressed innovation and the
                   compressed iterate (2); single-gossip baselines exchange
                   once (1); centralized algorithms never gossip (0).
    """

    name: str
    dp: bool = False
    decentralized: bool = True
    compressed: bool = True
    comm_rounds: int = 1


@dataclasses.dataclass(frozen=True)
class Algorithm:
    """A built, ready-to-train algorithm (the registry's uniform protocol).

    ``init``/``step`` are the only members a driver needs; the remaining
    fields expose what :func:`repro.api.build` resolved (topology, mixing
    matrix, compressor, engine, the derived consensus stepsize gamma, and
    the algorithm-native config object) so launch code, checkpointing and
    benchmarks never re-derive them.
    """

    name: str
    info: AlgorithmInfo
    spec: Any                       # the ExperimentSpec this was built from
    state_cls: type                 # NamedTuple class of the training state
    init: InitFn
    step: StepFn
    topology: Optional[Any] = None  # repro.core.mixing.Topology
    compressor: Optional[Any] = None
    mixer: Optional[Any] = None
    engine: Optional[Any] = None    # repro.core.comm_round.CommRound
    gamma: Optional[float] = None
    config: Optional[Any] = None    # e.g. the PorterConfig actually used
    schedule: Optional[Any] = None  # repro.core.mixing.TopologySchedule


# name -> (info, factory(spec, loss_fn, resolved) -> Algorithm)
_REGISTRY: Dict[str, Tuple[AlgorithmInfo, Callable]] = {}


def _ensure_builtin():
    """The nine built-in registrations live in repro.api (they need the
    facade's resolvers); import it lazily so lookups work regardless of
    which of repro.core / repro.api the caller imported first."""
    import repro.api  # noqa: F401  (registers on import)


def register_algorithm(name: str, *, dp: bool = False,
                       decentralized: bool = True, compressed: bool = True,
                       comm_rounds: Optional[int] = None):
    """Decorator: register ``factory(spec, loss_fn, resolved) -> Algorithm``
    under ``name``.  ``resolved`` is the build context (topology, mixer,
    compressor, engine, gamma) that :func:`repro.api.build` constructed from
    the spec -- factories never build those pieces themselves.

    ``comm_rounds`` declares how many gossip exchanges one ``step`` performs
    (see :class:`AlgorithmInfo`); it defaults to 1 for decentralized
    algorithms and 0 otherwise, and is enforced against the compiled HLO by
    ``python -m repro.analysis``."""
    if comm_rounds is None:
        comm_rounds = 1 if decentralized else 0
    if comm_rounds < 0:
        raise ValueError(f"comm_rounds must be >= 0, got {comm_rounds}")
    if not decentralized and comm_rounds:
        raise ValueError(
            f"algorithm {name!r}: centralized algorithms gossip zero times "
            f"per step, got comm_rounds={comm_rounds}")
    info = AlgorithmInfo(name=name, dp=dp, decentralized=decentralized,
                         compressed=compressed, comm_rounds=comm_rounds)

    def deco(factory):
        if name in _REGISTRY:
            raise ValueError(f"algorithm {name!r} registered twice")
        _REGISTRY[name] = (info, factory)
        return factory

    return deco


def _lookup(name: str) -> Tuple[AlgorithmInfo, Callable]:
    _ensure_builtin()
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown algorithm {name!r}; registered: "
                         f"{list_algorithms()}") from None


def algorithm_info(name: str) -> AlgorithmInfo:
    return _lookup(name)[0]


def get_factory(name: str) -> Callable:
    return _lookup(name)[1]


def list_algorithms() -> Tuple[str, ...]:
    _ensure_builtin()
    return tuple(sorted(_REGISTRY))
