"""Nonsmooth decentralized subgradient method with compressed gossip
(arXiv 2607.01755 family).

For nonsmooth objectives (hinge losses, l1 terms, ReLU kinks) the smooth
analysis behind PORTER's gradient tracking does not apply, but the
classical subgradient scheme still converges with a diminishing stepsize;
composed with a Definition-3 rho-compressor on the gossip wire it is a
one-comm-round CommRound client -- structurally CHOCO-SGD's round with
the constant stepsize replaced by the 1/sqrt(t) schedule the nonsmooth
rate needs:

    x_i^{t+1/2} = x_i^t - (eta / sqrt(t+1)) * u_i^t,   u in d f_i(x_i^t)
    q/m/x via engine.gossip_apply (compressed surrogate gossip)

``jax.grad`` at a kink returns one member of the subdifferential (it is a
valid subgradient everywhere for the piecewise-smooth losses here), so the
oracle body is value_and_grad exactly like the baselines.  Optional
``tau`` clips the subgradient -- the bounded-subgradient assumption
enforced rather than assumed.
"""

from __future__ import annotations

from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import clipping
from .comm_round import CommRound, resolve_engine
from .compression import Compressor
from .gossip import MixFn
from .porter import consensus_error

__all__ = [
    "SubgradState",
    "subgrad_init",
    "subgrad_step",
]


class SubgradState(NamedTuple):
    x: Any
    q: Any      # own surrogate x-hat
    m: Any      # mixing mirror: sum_j w_ij x-hat_j
    step: jax.Array


def subgrad_init(params, n_agents: int, plane_dtype=None) -> SubgradState:
    """Same plane layout as CHOCO (the round body is the same engine
    call); ``plane_dtype`` shrinks the surrogate/mirror storage."""
    x = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, (n_agents,) + p.shape), params)
    dt = jnp.float32 if plane_dtype is None else jnp.dtype(plane_dtype)
    zeros = jax.tree_util.tree_map(
        lambda l: jnp.zeros_like(l, dtype=dt), x)
    return SubgradState(x=x, q=zeros, m=zeros,
                        step=jnp.zeros((), jnp.int32))


def subgrad_step(eta: float, gamma: float, loss_fn,
                 mixer: Optional[MixFn], compressor: Optional[Compressor],
                 state: SubgradState, batch, key,
                 tau: Optional[float] = None, clip_mode: str = "piecewise",
                 engine: Optional[CommRound] = None,
                 ) -> Tuple[SubgradState, Dict[str, jax.Array]]:
    """One compressed-gossip subgradient round (diminishing stepsize)."""
    eng = resolve_engine(engine, mixer, compressor)
    n = jax.tree_util.tree_leaves(state.x)[0].shape[0]
    k_g, k_c = jax.random.split(key)
    keys = jax.random.split(k_g, n)

    def agent_subgrad(p, b, k):
        del k
        loss, g = jax.value_and_grad(loss_fn)(p, b)
        if tau is not None:
            g = clipping.tree_clip(g, tau, clip_mode)
        return loss, g

    losses, g = jax.vmap(agent_subgrad)(state.x, batch, keys)
    # nonsmooth rate's schedule: eta_t = eta / sqrt(t + 1)
    eta_t = eta * jax.lax.rsqrt(state.step.astype(jnp.float32) + 1.0)
    x_half = jax.tree_util.tree_map(
        lambda x0, gg: x0 - eta_t * gg.astype(x0.dtype), state.x, g)
    x, q, m = eng.gossip_apply(k_c, x_half, state.q, state.m, gamma,
                               t=state.step)
    return SubgradState(x=x, q=q, m=m, step=state.step + 1), {
        "loss": jnp.mean(losses), "consensus_x": consensus_error(x),
        "wire_bytes": jnp.asarray(eng.wire_bytes(state.x), jnp.float32)}
