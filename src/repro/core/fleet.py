"""Fleet-scale agent simulation: n >> devices (ROADMAP open item 3).

The per-device engine tops out at n = tens of agents (one per device
slot).  Fleet mode keeps the *same* agent-stacked state layout -- every
buffer leaf carries a leading agent axis -- but lets that axis grow to
n = 1k-100k simulated agents: the per-agent gradient vmap inside every
registered ``step`` vectorizes over the fleet, under pjit the fleet axis
shards over devices (thousands of simulated agents per device, so the
engine's planes become ``(fleet_chunk, tiles, lane)`` per shard), and the
*mixing* -- the only O(n^2) ingredient -- switches to a sparse COO
executor so the dense ``(n, n)`` table is never materialized.

Two regimes, one mixer:

* ``n <= FLEET_DENSE_GATE`` -- the fleet mixer wraps the *identical*
  ``_einsum_w`` dense apply that :func:`repro.core.gossip.make_dense_mixer`
  uses, on the identical W table.  Given the same resolved topology the
  fleet path is therefore **bit-exact** against the per-device engine --
  the oracle tests in tests/test_fleet.py pin this.
* ``n > FLEET_DENSE_GATE`` -- mixing is applied as a scatter-add over the
  COO triplets (O(nnz * d), nnz ~ degree * n), built by the sparse
  topology generators below (banded ring, exponential hyper-cubelike
  chords, degree-sampled Erdos-Renyi).  The two apply paths are asserted
  to agree numerically on densifiable sizes.

The fleet mixer satisfies the full MixFn protocol of
:mod:`repro.core.gossip` -- ``__call__(tree, t)``, ``time_varying``,
``budget``, ``push`` (push-sum weight rider), ``wire_mode`` -- so
:class:`repro.core.comm_round.CommRound` and every registered algorithm
run unchanged on top of it; select it with ``ExperimentSpec(fleet=True)``.
Mixing is pure local math (gathers + scatter-adds over the fleet axis):
its :class:`GossipBudget` declares **zero** per-leaf collectives, which
the analyzer census (repro.analysis) proves against the lowered HLO.

Spectral summaries at fleet scale never call ``numpy.linalg`` on dense
tables: ``alpha = ||W - J||_op`` comes from power iteration on the
mean-deflated operator (W is symmetric for the metropolis/lazy weights
built here), matching :func:`repro.core.mixing.mixing_rate` to rtol ~1e-6
on densifiable sizes (pinned by tests).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

try:  # scipy is a jax dependency, but keep a numpy-only fallback anyway
    from scipy.sparse.linalg import LinearOperator as _LinOp
    from scipy.sparse.linalg import eigsh as _eigsh
except Exception:  # pragma: no cover - exercised only without scipy
    _LinOp = _eigsh = None

from .gossip import GossipBudget, _einsum_w, _entry, _schedule_table
from .mixing import Topology, TopologySchedule, WeightKind

__all__ = [
    "FLEET_DENSE_GATE",
    "FleetTopology",
    "FleetSchedule",
    "fleet_topology",
    "fleet_rotating_schedule",
    "fleet_er_schedule",
    "make_fleet_mixer",
    "coo_matvec",
    "coo_alpha",
]

# n at or below which the fleet mixer densifies and reuses the einsum
# apply (bit parity with make_dense_mixer); above it, COO scatter-add.
FLEET_DENSE_GATE = 256


# ---------------------------------------------------------------------------
# COO mixing tables
# ---------------------------------------------------------------------------

def _check_coo(n: int, rows: np.ndarray, cols: np.ndarray,
               vals: np.ndarray) -> None:
    if not (rows.shape == cols.shape == vals.shape) or rows.ndim != 1:
        raise ValueError(f"COO triplets must be flat and aligned; got "
                         f"{rows.shape}/{cols.shape}/{vals.shape}")
    if rows.size and (rows.min() < 0 or rows.max() >= n
                      or cols.min() < 0 or cols.max() >= n):
        raise ValueError(f"COO indices out of range for n={n}")


def coo_matvec(n: int, rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
               x: np.ndarray) -> np.ndarray:
    """Host-side W @ x for one COO table (validation / power iteration)."""
    return np.bincount(rows, weights=vals * x[cols], minlength=n)


def coo_alpha(n: int, rows: np.ndarray, cols: np.ndarray, vals: np.ndarray,
              iters: int = 200, seed: int = 0) -> float:
    """``||W - J||_op`` by power iteration on the mean-deflated operator.

    For the symmetric doubly-stochastic W built here, B = W - J is
    symmetric, so plain power iteration on ``B x = W x - mean(x) 1``
    converges to the dominant |eigenvalue| = alpha (Definition 1).
    """
    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n)
    x -= x.mean()
    x /= np.linalg.norm(x) + 1e-300

    def deflated(v):
        y = coo_matvec(n, rows, cols, vals, v)
        return y - y.mean()    # deflate the Perron direction exactly

    if _eigsh is not None and n >= 3:
        # Lanczos resolves the clustered near-1 ring spectra that plain
        # power iteration needs O(n^2) iterations for
        op = _LinOp((n, n), matvec=deflated, dtype=np.float64)
        try:
            val = _eigsh(op, k=1, which="LM", v0=x, maxiter=max(50 * n, 2000),
                         tol=1e-12, return_eigenvectors=False)
            return float(np.abs(val[0]))
        except Exception:
            pass  # ARPACK no-convergence: fall through to power iteration
    est = 0.0
    for _ in range(iters):
        y = deflated(x)
        nrm = np.linalg.norm(y)
        if nrm < 1e-300:
            return 0.0
        est = nrm
        x = y / nrm
    return float(est)


def _coo_joint_alpha(n: int, rows: np.ndarray, cols: np.ndarray,
                     vals: np.ndarray, iters: int = 120,
                     seed: int = 0) -> float:
    """``|| (W_{p-1}-J) ... (W_0-J) ||_op`` for stacked (period, nnz)
    triplets, via power iteration on B^T B (B = the window product).

    Each round's B_t is symmetric here, so B^T is the product applied in
    reverse round order; B^T B is PSD and power iteration converges to
    sigma_max^2 regardless of B's own symmetry.
    """
    period = rows.shape[0]

    def apply_b(x, order):
        for t in order:
            x = coo_matvec(n, rows[t], cols[t], vals[t], x)
            x -= x.mean()
        return x

    rng = np.random.default_rng(seed)
    x = rng.standard_normal(n)
    x -= x.mean()
    x /= np.linalg.norm(x) + 1e-300

    def btb(v):
        return apply_b(apply_b(v, range(period)), range(period - 1, -1, -1))

    if _eigsh is not None and n >= 3:
        op = _LinOp((n, n), matvec=btb, dtype=np.float64)
        try:
            val = _eigsh(op, k=1, which="LA", v0=x, maxiter=max(50 * n, 2000),
                         tol=1e-12, return_eigenvectors=False)
            return float(np.sqrt(max(float(val[0]), 0.0)))
        except Exception:
            pass  # ARPACK no-convergence: fall through to power iteration
    est = 0.0
    for _ in range(iters):
        y = btb(x)
        nrm = np.linalg.norm(y)
        if nrm < 1e-300:
            return 0.0
        est = nrm              # -> sigma_max^2
        x = y / nrm
    return float(np.sqrt(est))


def _coo_connected(n: int, rows: np.ndarray, cols: np.ndarray) -> bool:
    """BFS connectivity over the (undirected view of the) COO edge set --
    never materializes an (n, n) table."""
    adj = [[] for _ in range(n)]
    for r, c in zip(rows.reshape(-1).tolist(), cols.reshape(-1).tolist()):
        if r != c:
            adj[r].append(c)
            adj[c].append(r)
    seen = np.zeros(n, dtype=bool)
    seen[0] = True
    frontier = [0]
    while frontier:
        u = frontier.pop()
        for v in adj[u]:
            if not seen[v]:
                seen[v] = True
                frontier.append(v)
    return bool(seen.all())


@dataclasses.dataclass(frozen=True)
class FleetTopology:
    """A sparse (COO) mixing matrix for fleet-scale n.

    ``rows/cols/vals`` include the diagonal, so ``W x`` is one scatter-add.
    ``alpha`` is the power-iteration estimate of ``||W - J||_op``.
    """

    kind: str
    n: int
    rows: np.ndarray      # (nnz,) int32
    cols: np.ndarray      # (nnz,) int32
    vals: np.ndarray      # (nnz,) float64
    alpha: float

    @property
    def spectral_gap(self) -> float:
        return 1.0 - self.alpha

    @property
    def nnz(self) -> int:
        return int(self.rows.size)

    def densify(self) -> np.ndarray:
        """Dense (n, n) W -- for tests and small-n parity only."""
        w = np.zeros((self.n, self.n), dtype=np.float64)
        np.add.at(w, (self.rows, self.cols), self.vals)
        return w


@dataclasses.dataclass(frozen=True)
class FleetSchedule:
    """A periodic window of COO mixing tables (doubly stochastic only).

    Triplets are stacked ``(period, nnz)`` with a shared nnz (rounds pad
    with zero-valued diagonal entries), so the compiled program gathers
    round ``t``'s triplets with the traced counter exactly like the dense
    schedule table.
    """

    kind: str
    n: int
    rows: np.ndarray      # (period, nnz) int32
    cols: np.ndarray      # (period, nnz) int32
    vals: np.ndarray      # (period, nnz) float64
    alphas: Tuple[float, ...]
    joint_alpha: float

    @property
    def period(self) -> int:
        return int(self.rows.shape[0])

    @property
    def is_directed(self) -> bool:
        return False      # fleet schedules are doubly stochastic

    @property
    def alpha(self) -> float:
        """Per-round geometric mixing rate (mirrors TopologySchedule)."""
        if self.period == 1:
            return self.alphas[0]
        return float(self.joint_alpha ** (1.0 / self.period))

    @property
    def spectral_gap(self) -> float:
        return 1.0 - self.alpha

    def densify(self, t: int) -> np.ndarray:
        w = np.zeros((self.n, self.n), dtype=np.float64)
        np.add.at(w, (self.rows[t], self.cols[t]), self.vals[t])
        return w


# ---------------------------------------------------------------------------
# Sparse generators: banded ring / exponential chords / degree-sampled ER
# ---------------------------------------------------------------------------

def _metropolis_coo(n: int, nbr_rows: np.ndarray, nbr_cols: np.ndarray,
                    lazy: bool = False
                    ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Metropolis weights from an undirected edge list (both directions
    present in nbr_rows/cols, no self loops): w_ij = 1/(1 + max(d_i, d_j)),
    diagonal = 1 - row sum.  Matches mixing.mixing_matrix exactly."""
    deg = np.bincount(nbr_rows, minlength=n).astype(np.float64)
    w_off = 1.0 / (1.0 + np.maximum(deg[nbr_rows], deg[nbr_cols]))
    diag = 1.0 - np.bincount(nbr_rows, weights=w_off, minlength=n)
    if lazy:
        w_off = 0.5 * w_off
        diag = 0.5 * (1.0 + diag)
    rows = np.concatenate([nbr_rows, np.arange(n)]).astype(np.int32)
    cols = np.concatenate([nbr_cols, np.arange(n)]).astype(np.int32)
    vals = np.concatenate([w_off, diag])
    return rows, cols, vals


def _symmetrize(pairs: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Unique undirected edges (i < j, no self loops) -> both directions."""
    i, j = pairs[:, 0], pairs[:, 1]
    keep = i != j
    i, j = np.minimum(i, j)[keep], np.maximum(i, j)[keep]
    uniq = np.unique(np.stack([i, j], axis=1), axis=0)
    rows = np.concatenate([uniq[:, 0], uniq[:, 1]])
    cols = np.concatenate([uniq[:, 1], uniq[:, 0]])
    return rows, cols


def _fleet_edges(kind: str, n: int, p: float, seed: int,
                 degree: Optional[int]) -> Tuple[np.ndarray, np.ndarray]:
    """Sparse undirected edge list (both directions) for one round."""
    idx = np.arange(n)
    if kind == "ring":
        if n < 3:
            raise ValueError(f"fleet ring needs n >= 3, got {n}")
        rows = np.concatenate([idx, idx])
        cols = np.concatenate([(idx + 1) % n, (idx - 1) % n])
        return rows, cols
    if kind == "exponential":
        # chords at hop distances 2^k (k = 0 .. floor(log2(n-1))): the
        # standard O(log n)-degree expander used for large-n gossip
        hops = [1 << k for k in range(int(np.log2(max(n - 1, 1))) + 1)
                if (1 << k) <= n // 2]
        pairs = np.concatenate(
            [np.stack([idx, (idx + h) % n], axis=1) for h in hops])
        return _symmetrize(pairs)
    if kind == "erdos_renyi":
        # degree-sampled ER: draw ~ n*deg/2 random pairs instead of
        # flipping n^2/2 coins -- the only ER construction that scales to
        # n = 100k.  ``degree`` defaults to a connectivity-safe
        # 2 * ceil(log2 n); a ring backbone guarantees connectivity
        # without a 1000-attempt resample loop at fleet scale.
        deg = int(degree) if degree is not None else 2 * max(
            int(np.ceil(np.log2(max(n, 2)))), 2)
        rng = np.random.default_rng(seed)
        m = max((n * deg) // 2, 1)
        pairs = rng.integers(0, n, size=(m, 2))
        backbone = np.stack([idx, (idx + 1) % n], axis=1)
        return _symmetrize(np.concatenate([pairs, backbone]))
    raise ValueError(f"unknown fleet topology kind {kind!r}; have "
                     "ring, exponential, erdos_renyi")


def fleet_topology(kind: str, n: int, weights: WeightKind = "metropolis",
                   p: float = 0.8, seed: int = 0,
                   degree: Optional[int] = None,
                   alpha_iters: int = 200) -> FleetTopology:
    """Sparse static topology for fleet-scale n (never builds (n, n)).

    Supported kinds: ``ring`` (banded), ``exponential`` (2^k chords),
    ``erdos_renyi`` (degree-sampled, ring backbone).  Weights: metropolis
    or lazy (best_constant needs a dense eigensolve by definition).
    """
    if weights not in ("metropolis", "lazy"):
        raise ValueError(
            f"fleet topologies support metropolis/lazy weights, got "
            f"{weights!r}: best_constant needs the dense Laplacian "
            "eigensolve the sparse path exists to avoid")
    nbr_rows, nbr_cols = _fleet_edges(kind, n, p, seed, degree)
    rows, cols, vals = _metropolis_coo(n, nbr_rows, nbr_cols,
                                       lazy=(weights == "lazy"))
    _check_coo(n, rows, cols, vals)
    if not _coo_connected(n, nbr_rows, nbr_cols):
        raise ValueError(f"fleet topology {kind!r} (n={n}) is disconnected")
    alpha = coo_alpha(n, rows, cols, vals, iters=alpha_iters, seed=seed)
    return FleetTopology(kind=f"fleet:{kind}", n=n, rows=rows, cols=cols,
                         vals=vals, alpha=alpha)


def _pad_rounds(tables: Sequence[Tuple[np.ndarray, np.ndarray, np.ndarray]]
                ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Stack per-round COO triplets, padding to a common nnz with
    zero-valued (0, 0) entries (harmless under scatter-add)."""
    nnz = max(r.size for r, _, _ in tables)
    rows = np.zeros((len(tables), nnz), dtype=np.int32)
    cols = np.zeros((len(tables), nnz), dtype=np.int32)
    vals = np.zeros((len(tables), nnz), dtype=np.float64)
    for t, (r, c, v) in enumerate(tables):
        rows[t, :r.size], cols[t, :c.size], vals[t, :v.size] = r, c, v
    return rows, cols, vals


def _finalize_fleet_schedule(kind: str, n: int, tables,
                             alpha_iters: int = 200) -> FleetSchedule:
    rows, cols, vals = _pad_rounds(tables)
    for t in range(rows.shape[0]):
        _check_coo(n, rows[t], cols[t], vals[t])
        rsum = np.bincount(rows[t], weights=vals[t], minlength=n)
        csum = np.bincount(cols[t], weights=vals[t], minlength=n)
        if not (np.allclose(rsum, 1.0, atol=1e-9)
                and np.allclose(csum, 1.0, atol=1e-9)):
            raise ValueError(f"fleet schedule round {t} is not doubly "
                             "stochastic (Definition 1)")
    union_r = rows.reshape(-1)
    union_c = cols.reshape(-1)
    live = np.abs(vals.reshape(-1)) > 0
    if not _coo_connected(n, union_r[live], union_c[live]):
        raise ValueError(f"{kind!r} fleet schedule: window union graph is "
                         "disconnected")
    alphas = tuple(coo_alpha(n, rows[t], cols[t], vals[t],
                             iters=alpha_iters, seed=t)
                   for t in range(rows.shape[0]))
    joint = (alphas[0] if rows.shape[0] == 1
             else _coo_joint_alpha(n, rows, cols, vals))
    if joint >= 1.0 - 1e-9:
        raise ValueError(f"{kind!r} fleet schedule does not mix over its "
                         f"window (joint alpha = {joint:.6f})")
    return FleetSchedule(kind=kind, n=n, rows=rows, cols=cols, vals=vals,
                         alphas=alphas, joint_alpha=joint)


def fleet_rotating_schedule(kinds: Sequence[str], n: int,
                            weights: WeightKind = "metropolis",
                            seed: int = 0) -> FleetSchedule:
    """Rotate through sparse graph kinds (``kind`` or ``kind/weights``),
    one per round -- the fleet analogue of mixing.rotating_schedule."""
    if not kinds:
        raise ValueError("fleet rotating schedule needs >= 1 graph kind")
    tables = []
    for entry in kinds:
        kind, _, wk = str(entry).partition("/")
        top = fleet_topology(kind, n, weights=wk or weights, seed=seed)
        tables.append((top.rows, top.cols, top.vals))
    return _finalize_fleet_schedule(
        f"fleet-rotate:{'+'.join(map(str, kinds))}", n, tables)


def fleet_er_schedule(n: int, period: int = 4, degree: Optional[int] = None,
                      weights: WeightKind = "metropolis",
                      seed: int = 0) -> FleetSchedule:
    """Fresh degree-sampled ER graph every round (per-round resampling)."""
    if period < 1:
        raise ValueError(f"period must be >= 1, got {period}")
    tables = []
    for t in range(period):
        top = fleet_topology("erdos_renyi", n, weights=weights,
                             seed=seed * 10007 + t, degree=degree)
        tables.append((top.rows, top.cols, top.vals))
    return _finalize_fleet_schedule(f"fleet-erdos_renyi:period={period}", n,
                                    tables)


# ---------------------------------------------------------------------------
# The fleet mixer
# ---------------------------------------------------------------------------

def _coo_apply(rows, cols, vals, leaf):
    """One scatter-add application of W to an agent-stacked leaf: f32
    accumulation, cast back to the leaf dtype (mirrors gossip._einsum_w)."""
    lf = leaf.astype(jnp.float32)
    contrib = vals.reshape(vals.shape + (1,) * (leaf.ndim - 1)) * lf[cols]
    out = jnp.zeros_like(lf).at[rows].add(contrib)
    return out.astype(leaf.dtype)


def make_fleet_mixer(obj: Union[Topology, TopologySchedule, FleetTopology,
                                FleetSchedule],
                     dense_gate: int = FLEET_DENSE_GATE):
    """MixFn over a fleet of simulated agents.

    ``obj`` is a dense :class:`Topology`/:class:`TopologySchedule` (small
    n -- the apply is then the *identical* einsum of make_dense_mixer, so
    the fleet path is bit-exact against the per-device engine) or a sparse
    :class:`FleetTopology`/:class:`FleetSchedule` (COO scatter-add; the
    (n, n) table is never materialized).  A FleetTopology/Schedule with
    ``n <= dense_gate`` is densified back onto the einsum path; pass
    ``dense_gate=0`` to force the scatter path (tests).
    """
    if isinstance(obj, (Topology, TopologySchedule)):
        w = obj.ws if isinstance(obj, TopologySchedule) else obj.w
        w_np, time_varying = _schedule_table(w)
        w_j = jnp.asarray(w_np, dtype=jnp.float32)
        n = int(w_np.shape[-1])

        if time_varying:
            def apply_w(tree, t):
                w_t = _entry(w_j, t)
                return jax.tree_util.tree_map(
                    lambda l: _einsum_w(w_t, l), tree)
        else:
            def apply_w(tree, t=None):
                del t
                return jax.tree_util.tree_map(
                    lambda l: _einsum_w(w_j, l), tree)
        note = (f"fleet dense-gate (n={n} <= {dense_gate}): the einsum "
                "apply of make_dense_mixer, bit-exact vs the per-device "
                "engine")
    elif isinstance(obj, (FleetTopology, FleetSchedule)):
        n = obj.n
        time_varying = isinstance(obj, FleetSchedule)
        if n <= dense_gate:
            dense = (np.stack([obj.densify(t) for t in range(obj.period)])
                     if time_varying else obj.densify())
            w_np, _ = _schedule_table(dense)
            w_j = jnp.asarray(w_np, dtype=jnp.float32)
            if time_varying:
                def apply_w(tree, t):
                    w_t = _entry(w_j, t)
                    return jax.tree_util.tree_map(
                        lambda l: _einsum_w(w_t, l), tree)
            else:
                def apply_w(tree, t=None):
                    del t
                    return jax.tree_util.tree_map(
                        lambda l: _einsum_w(w_j, l), tree)
            note = f"fleet dense-gate (n={n} <= {dense_gate}), COO densified"
        else:
            rows_j = jnp.asarray(obj.rows, jnp.int32)
            cols_j = jnp.asarray(obj.cols, jnp.int32)
            vals_j = jnp.asarray(obj.vals, jnp.float32)
            if time_varying:
                period = obj.period

                def apply_w(tree, t):
                    tm = jnp.mod(t, period)
                    r, c, v = rows_j[tm], cols_j[tm], vals_j[tm]
                    return jax.tree_util.tree_map(
                        lambda l: _coo_apply(r, c, v, l), tree)
            else:
                def apply_w(tree, t=None):
                    del t
                    return jax.tree_util.tree_map(
                        lambda l: _coo_apply(rows_j, cols_j, vals_j, l),
                        tree)
            note = (f"fleet COO scatter-add (n={n}, nnz="
                    f"{obj.rows.size}): local math over the fleet axis")
    else:
        raise TypeError(f"make_fleet_mixer: unsupported table type "
                        f"{type(obj).__name__}")

    if time_varying:
        def mix(tree, t):
            return apply_w(tree, t)
    else:
        def mix(tree, t=None):
            return apply_w(tree, t)

    def push(tree, wvec, t=None):
        """Push-sum weight rider: mix the scalar weight plane with the
        same W by concatenating it as one extra flat column on leaf 0
        (exactly make_dense_mixer.push's layout, so the per-device
        parity covers push-sum algorithms too)."""
        if time_varying and t is None:
            raise ValueError("time-varying fleet mixer needs the round "
                             "index (pass t=state.step)")
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        l0 = leaves[0]
        flat0 = l0.reshape(l0.shape[0], -1).astype(jnp.float32)
        aug = jnp.concatenate(
            [flat0, wvec.astype(jnp.float32)[:, None]], axis=1)
        aug_m = apply_w({"a": aug}, t)["a"]
        out0 = aug_m[:, :-1].reshape(l0.shape).astype(l0.dtype)
        w_m = aug_m[:, -1].astype(wvec.dtype)
        rest_tree = treedef.unflatten([l0] + leaves[1:])
        rest = jax.tree_util.tree_leaves(apply_w(rest_tree, t))[1:]
        return treedef.unflatten([out0] + list(rest)), w_m

    mix.push = push
    mix.time_varying = time_varying
    mix.n = n
    mix.budget = GossipBudget(
        executor="fleet", per_leaf={}, spmd_dependent=True, note=note)
    mix.wire_mode = "dense"
    mix.wire_frac = None
    mix.schedule = obj if time_varying else None
    return mix
