"""Local differential privacy: Theorem 1 calibration and a moments accountant.

The paper (Theorem 1) shows PORTER-DP is (eps, delta)-LDP over T iterations
with batch size b = 1 and sampling probability q = 1/m when

    sigma_p^2 = T tau^2 log(1/delta) / (m^2 eps^2)  =  T tau^2 phi_m^2 / d,

where phi_m = sqrt(d log(1/delta)) / (m eps) is the centralized baseline
utility (Eq. 4).  The smooth clipping operator guarantees every per-sample
gradient has norm < tau, so the subsampled-Gaussian sensitivity is 2*tau...
actually <= tau per sample for add/remove and <= 2 tau for replace; the paper
uses the [ACG+16] moments bound with sensitivity tau, which we follow.

This module provides:

* ``phi_m`` -- the baseline utility (Eq. 4).
* ``calibrate_sigma`` -- Theorem 1's noise scale (Eq. 5).
* ``MomentsAccountant`` -- tracks the [ACG+16, Lemma 3] log-MGF bound
  alpha(lambda) <= q^2 lambda (lambda+1) / ((1-q) s^2) + O(q^3 lambda^3 / s^3)
  with s = sigma_p / tau (the noise multiplier), composed over steps, and
  converts to (eps, delta) via the tail bound
  delta = min_lambda exp(T alpha(lambda) - lambda eps).

The accountant is an upper bound; tests check that Theorem 1's sigma indeed
yields eps' <= O(eps) under the accountant and that eps decreases
monotonically in sigma and increases in T.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Optional

import numpy as np

__all__ = [
    "phi_m",
    "calibrate_sigma",
    "MomentsAccountant",
    "ldp_epsilon",
]


def phi_m(d: int, m: int, eps: float, delta: float) -> float:
    """Baseline utility phi_m = sqrt(d log(1/delta)) / (m eps)   (Eq. 4)."""
    return math.sqrt(d * math.log(1.0 / delta)) / (m * eps)


def calibrate_sigma(tau: float, T: int, m: int, eps: float, delta: float) -> float:
    """Theorem 1 / Eq. (5): sigma_p = tau sqrt(T log(1/delta)) / (m eps).

    Note the paper states sigma_p^2 = T tau^2 log(1/delta) / (m^2 eps^2) and
    also writes the experiment setting sigma_p = tau sqrt(T log(1/delta))/(m eps);
    these agree.
    """
    if eps <= 0 or not (0 < delta < 1):
        raise ValueError("need eps > 0 and delta in (0,1)")
    return tau * math.sqrt(T * math.log(1.0 / delta)) / (m * eps)


@dataclasses.dataclass
class MomentsAccountant:
    """[ACG+16]-style moments accountant for the subsampled Gaussian mechanism.

    q: per-sample inclusion probability (= b/m; paper uses b=1 -> q=1/m).
    noise_multiplier: s = sigma_p / tau.
    """

    q: float
    noise_multiplier: float
    steps: int = 0
    max_lambda: int = 64

    def step(self, n: int = 1) -> None:
        self.steps += n

    def _log_mgf_one_step(self, lam: float) -> float:
        """Lemma-3 style bound on alpha_M(lambda) for one subsampled step."""
        q, s = self.q, self.noise_multiplier
        if s <= 0:
            return math.inf
        main = q * q * lam * (lam + 1.0) / max((1.0 - q) * s * s, 1e-12)
        tail = (q ** 3) * (lam ** 3) / (s ** 3)
        return main + 2.0 * tail

    def epsilon(self, delta: float) -> float:
        """Smallest eps such that the composed mechanism is (eps, delta)-DP."""
        best = math.inf
        for lam in range(1, self.max_lambda + 1):
            a = self.steps * self._log_mgf_one_step(float(lam))
            if not math.isfinite(a):
                continue
            eps = (a + math.log(1.0 / delta)) / lam
            best = min(best, eps)
        return best

    def delta(self, eps: float) -> float:
        best = 1.0
        for lam in range(1, self.max_lambda + 1):
            a = self.steps * self._log_mgf_one_step(float(lam))
            x = a - lam * eps
            # x >= 0 is a vacuous tail bound (delta >= 1) and would
            # overflow exp for large compositions; it can never beat the
            # 1.0 cap, so skip it
            if not math.isfinite(x) or x >= 0.0:
                continue
            best = min(best, math.exp(x))
        return best


def ldp_epsilon(tau: float, sigma_p: float, T: int, m: int,
                delta: float, b: int = 1) -> float:
    """eps achieved by T rounds of PORTER-DP with given noise, per accountant."""
    acct = MomentsAccountant(q=b / m, noise_multiplier=sigma_p / tau)
    acct.step(T)
    return acct.epsilon(delta)
