"""Gossip (neighbor mixing) executors over agent-stacked pytrees.

PORTER communicates *increments*: each round every agent broadcasts
``incr_i = C(y_i - q_i)`` to its neighbors, every agent accumulates its own
surrogate ``q_i += incr_i`` and a *mixing mirror* ``m_i += sum_j w_ij incr_j``,
and the gossip term used by the algorithm is ``(Q (W - I))_i = m_i - q_i``
(exactly, by linearity of the accumulation).  This mirrors what a real
deployment does -- only increments ever hit the wire -- and makes the
collective bytes of the three wire formats directly comparable:

* ``dense``    all-gather of the dense increment   (n * d bytes / round)
               -- the paper's math, zeros included; baseline.
* ``ring``     W is banded on a ring: two ppermute shifts (2 * d bytes),
               independent of n.  Exact for ring topologies.
* ``packed``   all-gather of top-k (values, indices) pairs
               (n * 2k bytes) + local scatter-add.  Exact whenever the
               compressor output is k-sparse (top-k / block-top-k), which is
               how the paper's claimed communication saving is realized on
               the wire.  This is a beyond-paper systems contribution.

All executors compute ``W @ incr`` over the leading agent axis.  The dense
executor is pure einsum and works both in single-device simulation and under
pjit (XLA inserts the all-gather).  ``ring`` and ``packed`` are shard_map
programs and require a mesh.

Time-varying topologies: every factory also accepts a stacked
``(period, n, n)`` table (a :class:`repro.core.mixing.TopologySchedule`'s
``ws``).  The returned mixer then takes the *absolute round index* as a
second, traced argument and gathers ``W_{t mod period}`` from a device copy
of the table inside the compiled program -- one executable serves the whole
schedule, and because the index is the state's own step counter the
trajectory is chunking- and restart-invariant like the PRNG stream.  The
ring fast path keeps its two-ppermute shift structure and only traces the
*band weights* per round (the graph stays a ring; weights rotate), so its
wire bytes stay 2*d regardless of the schedule.  Static mixers ignore the
round index; :func:`apply_mixer` dispatches either way.

Push-sum (directed, column-stochastic W): the dense and ring executors
expose ``mix.push(tree, wvec, t)`` which mixes the scalar push-sum weight
plane (shape (n,)) alongside the params with the *same* W, and the codec
executors expose ``mix.exchange_ps(key, tree, dw, t)`` which ships the
exact f32 weight increment bitcast inside the packed buffers.  In every
case the weight rides inside a collective the executor already issues --
concatenated onto the first leaf's flattened block (dense einsum, ring
ppermute) or appended as bitcast words to the last wire buffer (codec) --
so carrying the weight plane adds 4 bytes per shipped buffer and zero
extra collectives (the compiled-HLO tests pin this).  Weights are never
compressed: the column-mass conservation push-sum de-biasing relies on
(1^T W = 1^T) must hold exactly for the weight recursion.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map

from . import wire_formats as WF
from .mixing import Topology, TopologySchedule
# packed wire format selection window: single source of truth is
# wire_formats.PACK_BLOCK (the executors, the kernels, and the byte model
# all import it from there, so none can drift -- the PR-3 bug class).
from .wire_formats import PACK_BLOCK

__all__ = [
    "GossipBudget",
    "MixFn",
    "PACK_BLOCK",
    "apply_mixer",
    "make_dense_mixer",
    "make_ring_mixer",
    "make_packed_mixer",
    "make_ring_codec_mixer",
    "make_packed_codec_mixer",
    "make_mixer",
    "gossip_wire_bytes",
]

# tree of (n, ...) -> tree of (n, ...); time-varying mixers additionally
# take the traced absolute round index (see apply_mixer)
MixFn = Callable[..., object]


@dataclasses.dataclass(frozen=True)
class GossipBudget:
    """Declared collective budget of one gossip executor.

    Every mixer factory attaches one of these as ``mix.budget`` -- the
    executor's *contract* for what its compiled program may ship, declared
    at construction time and enforced against the lowered HLO by the
    collective census in :mod:`repro.analysis.hlo`.

    ``per_leaf`` maps an HLO collective category (``"collective-permute"``,
    ``"all-gather"``, ...) to the maximum number of such ops the executor
    may emit *per gossiped leaf, per comm round*.  The census multiplies by
    the leaf count and the algorithm's declared
    :attr:`repro.core.registry.AlgorithmInfo.comm_rounds` to bound the whole
    step.  Budgets are upper bounds (XLA's combiner passes may merge ops
    below them); categories absent from ``per_leaf`` are *forbidden* -- a
    single op of an unbudgeted category is a violation.

    ``spmd_dependent`` marks executors (dense einsum gossip) whose
    collective schedule is chosen by the SPMD partitioner, not by the
    executor: under a mesh the census reports their counts without
    enforcing, and enforces the zero-collective contract only in the
    unmeshed harness.

    Push-sum transport never changes a budget: the weight plane rides
    inside already-shipped buffers (``mix.push`` / ``mix.exchange_ps`` add
    zero collectives by construction, and the census proves it).
    """

    executor: str
    per_leaf: "dict[str, int]" = dataclasses.field(default_factory=dict)
    spmd_dependent: bool = False
    note: str = ""

    def bound(self, n_leaves: int, comm_rounds: int) -> "dict[str, int]":
        """Per-category op ceiling for a whole compiled step."""
        return {cat: per * n_leaves * comm_rounds
                for cat, per in self.per_leaf.items()}


def apply_mixer(mixer: MixFn, tree, t=None):
    """Invoke ``mixer``, forwarding the round index only when it needs one.

    Static mixers (and ad-hoc test doubles) keep their 1-argument call
    shape; mixers built from a schedule are tagged ``time_varying`` and
    require ``t`` (the algorithm steps pass their state's step counter)."""
    if getattr(mixer, "time_varying", False):
        if t is None:
            raise ValueError(
                "this mixer runs a time-varying topology schedule and needs "
                "the absolute round index (pass t=state.step)")
        return mixer(tree, t)
    return mixer(tree)


def _schedule_table(w) -> Tuple[np.ndarray, bool]:
    """Normalize ``w`` to a numpy table; True when it is a (p, n, n) stack."""
    w = np.asarray(w, dtype=np.float64)
    if w.ndim == 2:
        return w, False
    if w.ndim == 3:
        return w, True
    raise ValueError(f"mixing matrix must be (n, n) or (period, n, n); got "
                     f"shape {w.shape}")


def _entry(table: jax.Array, t) -> jax.Array:
    """W_t from a stacked device table, traced-index safe."""
    return table[jnp.mod(jnp.asarray(t, jnp.int32), table.shape[0])]


def _einsum_w(w: jax.Array, leaf: jax.Array) -> jax.Array:
    out = jnp.einsum("ij,j...->i...", w.astype(jnp.float32),
                     leaf.astype(jnp.float32))
    return out.astype(leaf.dtype)


def make_dense_mixer(w) -> MixFn:
    """W @ incr via einsum over the agent axis (all-gather under pjit).

    ``w``: (n, n) static matrix, or a stacked (period, n, n) schedule table
    -- the mixer then indexes it with the traced round argument.

    Push-sum: ``mix.push(tree, wvec, t)`` additionally mixes the scalar
    push-sum weight plane ``wvec`` (shape (n,)) with the *same* W.  The
    weight rides as one extra column concatenated onto the first leaf's
    flattened agent block, so the einsum count -- and under pjit the
    collective count -- is identical to the plain call; for f32 leaves the
    param output is bit-identical to ``mix(tree, t)``.
    """
    w_np, time_varying = _schedule_table(w)
    w_j = jnp.asarray(w_np, dtype=jnp.float32)

    if time_varying:
        def mix(tree, t):
            w_t = _entry(w_j, t)
            return jax.tree_util.tree_map(lambda l: _einsum_w(w_t, l), tree)
    else:
        def mix(tree, t=None):
            del t  # static
            return jax.tree_util.tree_map(lambda l: _einsum_w(w_j, l), tree)

    def push(tree, wvec, t=None):
        if time_varying and t is None:
            raise ValueError("time-varying dense mixer needs the round "
                             "index (pass t=state.step)")
        w_t = _entry(w_j, t) if time_varying else w_j
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        l0 = leaves[0]
        flat0 = l0.reshape(l0.shape[0], -1).astype(jnp.float32)
        aug = jnp.concatenate(
            [flat0, wvec.astype(jnp.float32)[:, None]], axis=1)
        aug_m = jnp.einsum("ij,jd->id", w_t.astype(jnp.float32), aug)
        out0 = aug_m[:, :-1].reshape(l0.shape).astype(l0.dtype)
        w_m = aug_m[:, -1].astype(wvec.dtype)
        rest = [_einsum_w(w_t, l) for l in leaves[1:]]
        return treedef.unflatten([out0] + rest), w_m

    mix.push = push
    mix.time_varying = time_varying
    mix.budget = GossipBudget(
        executor="dense", per_leaf={}, spmd_dependent=True,
        note="einsum over the agent axis; unmeshed it emits zero "
             "collectives, under pjit the SPMD partitioner chooses them")
    return mix


# ---------------------------------------------------------------------------
# Ring mixer: two ppermutes; supports the multi-pod ('pod','data') agent grid.
# ---------------------------------------------------------------------------

def _ring_weights(w: np.ndarray) -> Tuple[float, float, float]:
    """Extract (w_self, w_prev, w_next) from a circulant ring mixing matrix.

    At ``n == 2`` the two off-diagonal bands coincide: both ppermute shifts
    deliver the *same* (only) neighbor, so summing a prev and a next term
    would double-count it (``w_self*x + 2*w01*nb``, row sum != 1).  The whole
    neighbor weight is therefore folded into ``w_prev`` and ``w_next`` is
    zeroed, collapsing the executor to a single shift term.  The structure
    check accumulates band weights instead of assigning them, so coinciding
    positions can no longer mask a mismatch (``ref[0, 1]`` used to be
    silently overwritten).
    """
    n = w.shape[0]
    if n < 2:
        raise ValueError("ring gossip needs at least 2 agents; "
                         "use dense gossip for a single agent")
    w_self = float(w[0, 0])
    w_next = float(w[0, 1 % n])
    w_prev = float(w[0, (n - 1) % n])
    if n == 2:
        w_prev, w_next = float(w[0, 1]), 0.0
    # verify circulant-banded structure (accumulate: at n=2 both bands land
    # on the same entry, and with w_next folded to 0 the sum is exact)
    ref = np.zeros_like(w)
    for i in range(n):
        ref[i, i] += w_self
        ref[i, (i + 1) % n] += w_next
        ref[i, (i - 1) % n] += w_prev
    if not np.allclose(ref, w, atol=1e-10):
        raise ValueError("mixing matrix is not a circulant ring band; "
                         "use dense or packed gossip")
    return w_self, w_prev, w_next


def make_ring_mixer(w, mesh: Mesh,
                    agent_axes: Sequence[str] = ("data",),
                    leaf_specs=None) -> MixFn:
    """Banded-W gossip via lax.ppermute (wire bytes: 2*d, n-independent).

    For the multi-pod agent grid the logical agent index is
    pod * data_size + data; shifts that cross the pod boundary are patched
    with an extra ppermute over the 'pod' axis.

    ``w`` may be a stacked (period, n, n) schedule table; every round must
    then be a circulant ring band.  The *shift structure* stays static --
    which bands are ever nonzero across the window decides which ppermutes
    the program emits -- and only the three band weights are traced
    (gathered per round from a (period, 3) device table), so the compiled
    collective schedule and the 2*d wire accounting are schedule-invariant.
    """
    w_np, time_varying = _schedule_table(w)
    if time_varying:
        band_tab = np.stack([_ring_weights(wt) for wt in w_np])  # (p, 3)
        use_prev = bool(np.any(band_tab[:, 1] != 0.0))
        use_next = bool(np.any(band_tab[:, 2] != 0.0))
        bands_j = jnp.asarray(band_tab, jnp.float32)
    else:
        w_self, w_prev, w_next = _ring_weights(w_np)
        use_prev, use_next = bool(w_prev), bool(w_next)
    axes = tuple(agent_axes)

    def shift(x, direction: int, axis: str):
        size = mesh.shape[axis]
        perm = [(i, (i + direction) % size) for i in range(size)]
        if x.dtype == jnp.bfloat16:
            # ship the u16 bit pattern, like the codec executors: XLA's
            # float normalization (CPU has no native bf16) widens bf16
            # compute *and its collectives* to f32, silently doubling the
            # wire; integer collectives are never normalized, so the
            # bitcast pins bf16 planes at 2 B/elem
            raw = jax.lax.ppermute(
                jax.lax.bitcast_convert_type(x, jnp.uint16), axis, perm)
            return jax.lax.bitcast_convert_type(raw, jnp.bfloat16)
        return jax.lax.ppermute(x, axis, perm)

    def banded_copies(x):
        """Shifted copies of ``x`` paired with their band slot (0=self,
        1=prev, 2=next), in the accumulation order ``local`` uses.

        Zero-weight bands send nothing (n=2 ring folds everything into
        w_prev; its second ppermute would be a dead wire transfer);
        use_prev/use_next are static over the whole schedule window.  The
        shifts move x in its own dtype (bf16 planes ship 2 B/elem).
        """
        if len(axes) == 1:
            ax = axes[0]
            cps = [(0, x)]
            if use_prev:
                cps.append((1, shift(x, +1, ax)))  # agent i-1 arrives at i
            if use_next:
                cps.append((2, shift(x, -1, ax)))
            return cps
        pod_ax, data_ax = axes
        dsize = mesh.shape[data_ax]
        didx = jax.lax.axis_index(data_ax)
        cps = [(0, x)]
        # intra-pod shifted copies (wrap inside the pod is wrong at the seam);
        # seam fix: data==0 must receive pod-1's last agent; data==dsize-1
        # must receive pod+1's first agent.
        if use_prev:
            prev_intra = shift(x, +1, data_ax)
            prev_cross = shift(prev_intra, +1, pod_ax)
            cps.append((1, jnp.where(didx == 0, prev_cross, prev_intra)))
        if use_next:
            next_intra = shift(x, -1, data_ax)
            next_cross = shift(next_intra, -1, pod_ax)
            cps.append((2, jnp.where(didx == dsize - 1, next_cross,
                                     next_intra)))
        return cps

    def local(x, b_self, b_prev, b_next):  # x: (1, ...) local agent block
        # the band weights are traced f32 scalars under a schedule, so the
        # weighted sum promotes -- cast back so W @ x keeps x's dtype
        bands = (b_self, b_prev, b_next)
        out = None
        for i, cp in banded_copies(x):
            term = bands[i] * cp
            out = term if out is None else out + term
        return out.astype(x.dtype)

    def mix(tree, t=None):
        if leaf_specs is not None:
            specs = leaf_specs
        else:
            specs = jax.tree_util.tree_map(
                lambda l: P(axes if len(axes) > 1 else axes[0],
                            *([None] * (l.ndim - 1))), tree)
        if time_varying:
            if t is None:
                raise ValueError("time-varying ring mixer needs the round "
                                 "index (pass t=state.step)")
            b = _entry(bands_j, t)  # (3,) replicated, traced per round
            fn = shard_map(
                lambda tr, bb: jax.tree_util.tree_map(
                    lambda l: local(l, bb[0], bb[1], bb[2]), tr),
                mesh=mesh, in_specs=(specs, P()), out_specs=specs,
                check_vma=False)
            return fn(tree, b)
        fn = shard_map(
            lambda tr: jax.tree_util.tree_map(
                lambda l: local(l, w_self, w_prev, w_next), tr),
            mesh=mesh, in_specs=(specs,), out_specs=specs,
            check_vma=False)
        return fn(tree)

    def push(tree, wvec, t=None):
        """Push-sum ring gossip: mix ``tree`` and the (n,) weight plane
        ``wvec`` with the same banded W.  The weight scalar is concatenated
        onto the first leaf's flattened local block before the shifts, so
        the ppermute count is identical to the plain call (the weight adds
        4 wire bytes per shipped block, no extra collective)."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        if leaf_specs is not None:
            specs = leaf_specs
        else:
            specs = jax.tree_util.tree_map(
                lambda l: P(axes if len(axes) > 1 else axes[0],
                            *([None] * (l.ndim - 1))), tree)
        spec_leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda s: isinstance(s, P))
        w_spec = P(axes if len(axes) > 1 else axes[0])
        if time_varying:
            if t is None:
                raise ValueError("time-varying ring mixer needs the round "
                                 "index (pass t=state.step)")
            b = _entry(bands_j, t)
        else:
            b = jnp.asarray([w_self, w_prev, w_next], jnp.float32)

        def run(lvs, wv, bb):
            # The exact f32 weight word rides as bitcast lanes of the
            # payload dtype (1 lane beside f32 planes, 2 beside bf16), so
            # one ppermute per band still carries payload + weight and a
            # bf16 plane keeps its 2 B/elem wire.  Mixing happens on the
            # *split* halves -- payload accumulated in f32 and cast back,
            # weight bitcast back to f32 and mixed exactly -- which is
            # elementwise identical to concatenating in f32 throughout
            # (bit-exact for legacy f32 planes).
            l0 = lvs[0]
            flat0 = l0.reshape(1, -1)
            d0 = flat0.shape[1]
            nl = 4 // jnp.dtype(l0.dtype).itemsize
            wword = jax.lax.bitcast_convert_type(
                wv.astype(jnp.float32).reshape(1, 1),
                l0.dtype).reshape(1, nl)
            aug = jnp.concatenate([flat0, wword], axis=1)
            out0 = w_m = None
            for i, cp in banded_copies(aug):
                pay = bb[i] * cp[:, :d0].astype(jnp.float32)
                wgt = bb[i] * jax.lax.bitcast_convert_type(
                    cp[:, d0:], jnp.float32).reshape(())
                out0 = pay if out0 is None else out0 + pay
                w_m = wgt if w_m is None else w_m + wgt
            out0 = out0.reshape(l0.shape).astype(l0.dtype)
            w_m = w_m.reshape(wv.shape).astype(wv.dtype)
            rest = [local(l, bb[0], bb[1], bb[2]) for l in lvs[1:]]
            return [out0] + rest, w_m

        fn = shard_map(run, mesh=mesh,
                       in_specs=(spec_leaves, w_spec, P()),
                       out_specs=(spec_leaves, w_spec), check_vma=False)
        outs, w_m = fn(leaves, wvec, b)
        return treedef.unflatten(outs), w_m

    mix.push = push
    mix.time_varying = time_varying
    # one ppermute per live band; the multi-pod seam patch doubles it (an
    # extra shift over the 'pod' axis); n=2 folding halves it (use_next=0)
    _shifts = int(use_prev) + int(use_next)
    mix.budget = GossipBudget(
        executor="ring",
        per_leaf={"collective-permute":
                  _shifts * (2 if len(axes) == 2 else 1)},
        note=f"{_shifts} live band(s) x "
             f"{2 if len(axes) == 2 else 1} agent axis(es); "
             "push-sum weight rides in leaf 0, zero extra")
    return mix


# ---------------------------------------------------------------------------
# Packed top-k mixer: all-gather (values, indices) only.
# ---------------------------------------------------------------------------

def make_packed_mixer(w, mesh: Mesh, frac: float,
                      agent_axes: Sequence[str] = ("data",),
                      leaf_specs=None) -> MixFn:
    """W @ incr where only top-k (values, int32 indices) cross the wire.

    Exact when ``incr`` is k-sparse per agent (top-k / block-top-k
    compressors); otherwise it *re-compresses* the increment, which composes
    two rho-contractions and is still a valid compressor (documented).

    Each leaf may additionally be sharded over the 'model' axis; packing then
    selects top-k *per model shard* (block top-k across shards), keeping the
    collective strictly within the agent axes.

    ``w`` may be a stacked (period, n, n) schedule table; the round's W is
    gathered outside the shard_map body and enters it through the same
    replicated-argument slot the static matrix already used, so the wire
    payload (packed pairs only) is schedule-invariant.
    """
    w_np, time_varying = _schedule_table(w)
    w_np = w_np.astype(np.float32)
    n = w_np.shape[-1]
    axes = tuple(agent_axes)
    gather_axis = axes if len(axes) > 1 else axes[0]

    block = PACK_BLOCK  # selection window; matches kernels/block_topk.py

    def local(x, w_col):
        # x: (1, ...) local agent's increment block (possibly model-sharded).
        # Pack per 2048-elem window (the block-top-k wire format): top_k stays
        # int32-safe and cheap even on multi-billion-element expert leaves.
        flat = x.reshape(-1)
        d = flat.shape[0]
        pad = (-d) % block
        rows = jnp.pad(flat, (0, pad)).reshape(-1, block)   # (nb, block)
        nb = rows.shape[0]
        k_b = max(int(round(frac * block)), 1)
        vals_abs, idx = jax.lax.top_k(jnp.abs(rows), k_b)   # (nb, k_b)
        del vals_abs
        vals = jnp.take_along_axis(rows, idx, axis=1)
        # gather every agent's packed increment: (n, nb, k_b) each.  bf16
        # values gather as their u16 bit pattern, like the codec
        # executors: XLA's float normalization (no native bf16 on CPU)
        # widens bf16 collectives to f32, silently doubling the wire;
        # integer collectives are never normalized.
        if vals.dtype == jnp.bfloat16:
            all_vals = jax.lax.bitcast_convert_type(
                jax.lax.all_gather(
                    jax.lax.bitcast_convert_type(vals, jnp.uint16),
                    gather_axis),
                jnp.bfloat16).reshape(n, nb, k_b)
        else:
            all_vals = jax.lax.all_gather(vals, gather_axis
                                          ).reshape(n, nb, k_b)
        all_idx = jax.lax.all_gather(idx.astype(jnp.int32),
                                     gather_axis).reshape(n, nb, k_b)
        # weighted per-row scatter-add: sum_j w_ij * unpack(incr_j).
        # The gathered values cross the wire in x's dtype (2 B/elem for
        # bf16 planes); the receive-side accumulation runs in f32 and casts
        # back, so mixing never widens the resident buffer.
        weighted = (all_vals.astype(jnp.float32)
                    * w_col.astype(jnp.float32)[:, None, None])  # (n, nb, k_b)
        out = jnp.zeros((nb, block), jnp.float32)
        row_ids = jnp.arange(nb)[:, None]

        def add_agent(o, j):
            return o.at[row_ids, all_idx[j]].add(weighted[j]), None

        out, _ = jax.lax.scan(add_agent, out, jnp.arange(n))
        return out.reshape(-1)[:d].reshape(x.shape).astype(x.dtype)

    w_j = jnp.asarray(w_np)  # (n, n) or (period, n, n)

    def mix(tree, t=None):
        if time_varying:
            if t is None:
                raise ValueError("time-varying packed mixer needs the round "
                                 "index (pass t=state.step)")
            w_rows = _entry(w_j, t)  # (n, n), traced per round
        else:
            w_rows = w_j

        def run(tr, w_all):
            if len(axes) == 1:
                i = jax.lax.axis_index(axes[0])
            else:
                i = (jax.lax.axis_index(axes[0]) * mesh.shape[axes[1]]
                     + jax.lax.axis_index(axes[1]))
            row = w_all[i]
            return jax.tree_util.tree_map(lambda l: local(l, row), tr)

        if leaf_specs is not None:
            specs = leaf_specs
        else:
            specs = jax.tree_util.tree_map(
                lambda l: P(axes if len(axes) > 1 else axes[0],
                            *([None] * (l.ndim - 1))), tree)
        fn = shard_map(run, mesh=mesh,
                       in_specs=(specs, P()), out_specs=specs,
                       check_vma=False)
        return fn(tree, w_rows)

    mix.time_varying = time_varying
    mix.budget = GossipBudget(
        executor="packed", per_leaf={"all-gather": 2},
        note="one all-gather each for the (values, indices) planes")
    return mix


# ---------------------------------------------------------------------------
# Codec-aware executors: only bit-packed buffers ever cross the wire.
#
# Unlike the mixers above (dense increment in, mixed increment out), a codec
# executor *fuses compression with packing*: it takes the raw increment
# ``delta = y - q``, packs it per PACK_BLOCK window into the wire buffers of
# a :class:`repro.core.wire_formats.WireFormat`, ships only those buffers
# (ppermute for ring, all-gather for packed), and unpacks on the receiver.
# It returns BOTH ``c = unpack(pack(delta))`` (the locally round-tripped
# increment every agent accumulates into its surrogate q) and ``wc = W c``
# -- the two must come from the *same* packed buffers or the ``m = W q``
# invariant breaks, which is why the codec path replaces the engine's
# separate compress step rather than composing with it.  Drive these
# through ``mix.exchange(key, tree, t)`` (CommRound does); the plain call
# raises.
# ---------------------------------------------------------------------------

def _codec_mix_error(*a, **k):
    raise ValueError(
        "codec gossip executors fuse compression with packing and return "
        "(c, wc); call mix.exchange(key, tree, t) -- the CommRound engine "
        "does this -- instead of mixing a pre-compressed tree")


def _agent_index(mesh: Mesh, axes: Tuple[str, ...]):
    if len(axes) == 1:
        return jax.lax.axis_index(axes[0])
    return (jax.lax.axis_index(axes[0]) * mesh.shape[axes[1]]
            + jax.lax.axis_index(axes[1]))


def _pack_local(codec: WF.WireFormat, key, x):
    """Pack one (1, ...) local block: returns (bufs, c_rows, d)."""
    flat = x.reshape(-1).astype(jnp.float32)
    rows = WF.to_windows(flat)
    bufs = codec.pack(key, rows)
    return bufs, codec.unpack(*bufs), flat.shape[0]


# Wire armor: float wire buffers are bitcast to same-width uints for the
# collective itself.  Without this, XLA's convert-mover is free to hoist
# the receiver-side f32 upcast across the collective (the CPU backend does
# not model comm cost), silently shipping the bf16 value plane -- or the
# qsgd scale column -- as dense f32.  A bitcast is a hard boundary no
# convert can cross, and the round trip is bit-exact.

_ARMOR_UINT = {2: jnp.uint16, 4: jnp.uint32}


def _armor_bufs(bufs):
    """Bitcast float buffers to uint for shipping -> (armored, orig dtypes)."""
    out, kinds = [], []
    for b in bufs:
        # issubdtype, not dtype.kind: ml_dtypes' bfloat16 reports kind 'V'
        if jnp.issubdtype(b.dtype, jnp.floating):
            u = _ARMOR_UINT[jnp.dtype(b.dtype).itemsize]
            out.append(jax.lax.bitcast_convert_type(b, u))
            kinds.append(b.dtype)
        else:
            out.append(b)
            kinds.append(None)
    return tuple(out), tuple(kinds)


def _unarmor_bufs(bufs, kinds):
    """Inverse of :func:`_armor_bufs` on the received buffers."""
    return tuple(jax.lax.bitcast_convert_type(b, k) if k is not None else b
                 for b, k in zip(bufs, kinds))


# Push-sum weight transport for codec executors: the exact (uncompressed)
# f32 weight increment is bitcast into words of the last wire buffer's
# dtype and appended to its flattened payload -- +4 bytes per shipped
# buffer, zero extra collectives.  Bitcasting (not casting) keeps the
# transport exact: the receiver recovers the identical f32 bits.

def _weight_word_count(dtype) -> int:
    itemsize = jnp.dtype(dtype).itemsize
    if itemsize not in (2, 4):
        raise ValueError(f"cannot bitcast an f32 push-sum weight into "
                         f"{jnp.dtype(dtype)} wire words")
    return 4 // itemsize


def _append_weight(bufs, wloc):
    """(bufs, (1,) weight) -> (shipped bufs, original last-buffer shape)."""
    last = bufs[-1]
    w32 = jax.lax.bitcast_convert_type(
        wloc.astype(jnp.float32).reshape(1), jnp.uint32)
    if jnp.dtype(last.dtype).itemsize == 4:
        words = w32
    else:
        words = jax.lax.bitcast_convert_type(w32, jnp.uint16).reshape(-1)
    if words.dtype != last.dtype:
        words = jax.lax.bitcast_convert_type(words, last.dtype)
    return tuple(bufs[:-1]) + (jnp.concatenate([last.reshape(-1), words]),), \
        last.shape


def _split_weight(bufs, last_shape):
    """Inverse of :func:`_append_weight`: -> (original bufs, f32 weight)."""
    last = bufs[-1]
    nw = _weight_word_count(last.dtype)
    words = last[last.shape[0] - nw:]
    orig = last[:last.shape[0] - nw].reshape(last_shape)
    if jnp.dtype(words.dtype).itemsize == 2:
        words = jax.lax.bitcast_convert_type(words, jnp.uint16)
        w32 = jax.lax.bitcast_convert_type(words, jnp.uint32)
    else:
        w32 = jax.lax.bitcast_convert_type(words, jnp.uint32).reshape(-1)[:1]
    w32 = w32.reshape(())
    return tuple(bufs[:-1]) + (orig,), \
        jax.lax.bitcast_convert_type(w32, jnp.float32)


def make_ring_codec_mixer(w, mesh: Mesh, codec: WF.WireFormat,
                          agent_axes: Sequence[str] = ("data",),
                          leaf_specs=None) -> MixFn:
    """Banded-W gossip that ppermutes *packed* buffers (bf16+u16 segments or
    uint32 code words) instead of dense f32 planes.  Keeps the two-shift
    structure, the n=2 band folding, the multi-pod seam patch, and the
    traced (period, 3) band table of :func:`make_ring_mixer`; the receiver
    unpacks each neighbor's buffers before applying its band weight."""
    w_np, time_varying = _schedule_table(w)
    if time_varying:
        band_tab = np.stack([_ring_weights(wt) for wt in w_np])  # (p, 3)
        use_prev = bool(np.any(band_tab[:, 1] != 0.0))
        use_next = bool(np.any(band_tab[:, 2] != 0.0))
        bands_j = jnp.asarray(band_tab, jnp.float32)
    else:
        w_self, w_prev, w_next = _ring_weights(w_np)
        use_prev, use_next = bool(w_prev), bool(w_next)
    axes = tuple(agent_axes)

    def shift_bufs(bufs, direction: int, axis: str):
        size = mesh.shape[axis]
        perm = [(i, (i + direction) % size) for i in range(size)]
        armored, kinds = _armor_bufs(bufs)
        shipped = tuple(jax.lax.ppermute(b, axis, perm) for b in armored)
        return _unarmor_bufs(shipped, kinds)

    def local(x, b_self, b_prev, b_next, key):
        bufs, c_rows, d = _pack_local(codec, key, x)
        out = b_self * c_rows
        if len(axes) == 1:
            ax = axes[0]
            if use_prev:
                out = out + b_prev * codec.unpack(
                    *shift_bufs(bufs, +1, ax))   # agent i-1 arrives at i
            if use_next:
                out = out + b_next * codec.unpack(*shift_bufs(bufs, -1, ax))
        else:
            pod_ax, data_ax = axes
            dsize = mesh.shape[data_ax]
            didx = jax.lax.axis_index(data_ax)
            # seam fix as in make_ring_mixer, applied per wire buffer (all
            # agents' buffers share shapes, so the select is element-free)
            if use_prev:
                intra = shift_bufs(bufs, +1, data_ax)
                cross = shift_bufs(intra, +1, pod_ax)
                sel = tuple(jnp.where(didx == 0, c, i_)
                            for c, i_ in zip(cross, intra))
                out = out + b_prev * codec.unpack(*sel)
            if use_next:
                intra = shift_bufs(bufs, -1, data_ax)
                cross = shift_bufs(intra, -1, pod_ax)
                sel = tuple(jnp.where(didx == dsize - 1, c, i_)
                            for c, i_ in zip(cross, intra))
                out = out + b_next * codec.unpack(*sel)
        to_leaf = lambda rows: WF.from_windows(rows, d, x.shape
                                               ).astype(x.dtype)
        return to_leaf(c_rows), to_leaf(out)

    def exchange(key, tree, t=None):
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = jax.random.split(key, len(leaves))
        if leaf_specs is not None:
            specs = leaf_specs
        else:
            specs = jax.tree_util.tree_map(
                lambda l: P(axes if len(axes) > 1 else axes[0],
                            *([None] * (l.ndim - 1))), tree)
        spec_leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda s: isinstance(s, P))

        if time_varying:
            if t is None:
                raise ValueError("time-varying ring codec mixer needs the "
                                 "round index (pass t=state.step)")
            b = _entry(bands_j, t)
        else:
            b = jnp.asarray([w_self, w_prev, w_next], jnp.float32)

        def run(lvs, ks, bb):
            i = _agent_index(mesh, axes)
            outs = [local(l, bb[0], bb[1], bb[2],
                          jax.random.fold_in(ks[j], i))
                    for j, l in enumerate(lvs)]
            return [o[0] for o in outs], [o[1] for o in outs]

        fn = shard_map(run, mesh=mesh,
                       in_specs=(spec_leaves, P(), P()),
                       out_specs=(spec_leaves, spec_leaves),
                       check_vma=False)
        cs, wcs = fn(leaves, keys, b)
        return treedef.unflatten(cs), treedef.unflatten(wcs)

    def local_ps(x, b_self, b_prev, b_next, wloc, key):
        """Leaf-0 variant of ``local``: the agent's exact f32 weight
        increment rides bitcast inside the shipped buffers (+4 bytes, no
        extra ppermute); returns (c, wc, cw, wcw) local blocks."""
        bufs, c_rows, d = _pack_local(codec, key, x)
        ship, last_shape = _append_weight(bufs, wloc)
        w_loc = wloc.astype(jnp.float32).reshape(())
        out = b_self * c_rows
        w_out = b_self * w_loc

        def absorb(shipped, band):
            nonlocal out, w_out
            orig, wj = _split_weight(shipped, last_shape)
            out = out + band * codec.unpack(*orig)
            w_out = w_out + band * wj

        if len(axes) == 1:
            ax = axes[0]
            if use_prev:
                absorb(shift_bufs(ship, +1, ax), b_prev)
            if use_next:
                absorb(shift_bufs(ship, -1, ax), b_next)
        else:
            pod_ax, data_ax = axes
            dsize = mesh.shape[data_ax]
            didx = jax.lax.axis_index(data_ax)
            if use_prev:
                intra = shift_bufs(ship, +1, data_ax)
                cross = shift_bufs(intra, +1, pod_ax)
                absorb(tuple(jnp.where(didx == 0, c, i_)
                             for c, i_ in zip(cross, intra)), b_prev)
            if use_next:
                intra = shift_bufs(ship, -1, data_ax)
                cross = shift_bufs(intra, -1, pod_ax)
                absorb(tuple(jnp.where(didx == dsize - 1, c, i_)
                             for c, i_ in zip(cross, intra)), b_next)
        to_leaf = lambda rows: WF.from_windows(rows, d, x.shape
                                               ).astype(x.dtype)
        return (to_leaf(c_rows), to_leaf(out),
                w_loc.reshape(wloc.shape), w_out.reshape(wloc.shape))

    def exchange_ps(key, tree, dw, t=None):
        """Push-sum exchange: like ``exchange`` plus the exact (n,) weight
        increment ``dw``, shipped inside leaf 0's packed buffers.  Returns
        (c, wc, cw, wcw); cw == dw exactly (weights are never compressed,
        else the column-mass invariant breaks)."""
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = jax.random.split(key, len(leaves))
        if leaf_specs is not None:
            specs = leaf_specs
        else:
            specs = jax.tree_util.tree_map(
                lambda l: P(axes if len(axes) > 1 else axes[0],
                            *([None] * (l.ndim - 1))), tree)
        spec_leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda s: isinstance(s, P))
        w_spec = P(axes if len(axes) > 1 else axes[0])

        if time_varying:
            if t is None:
                raise ValueError("time-varying ring codec mixer needs the "
                                 "round index (pass t=state.step)")
            b = _entry(bands_j, t)
        else:
            b = jnp.asarray([w_self, w_prev, w_next], jnp.float32)

        def run(lvs, wv, ks, bb):
            i = _agent_index(mesh, axes)
            c0, wc0, cw, wcw = local_ps(lvs[0], bb[0], bb[1], bb[2], wv,
                                        jax.random.fold_in(ks[0], i))
            rest = [local(l, bb[0], bb[1], bb[2],
                          jax.random.fold_in(ks[j], i))
                    for j, l in enumerate(lvs[1:], start=1)]
            return ([c0] + [o[0] for o in rest],
                    [wc0] + [o[1] for o in rest], cw, wcw)

        fn = shard_map(run, mesh=mesh,
                       in_specs=(spec_leaves, w_spec, P(), P()),
                       out_specs=(spec_leaves, spec_leaves, w_spec, w_spec),
                       check_vma=False)
        cs, wcs, cw, wcw = fn(leaves, dw, keys, b)
        return (treedef.unflatten(cs), treedef.unflatten(wcs),
                cw.astype(dw.dtype), wcw.astype(dw.dtype))

    def mix(*a, **k):                      # fresh object per factory call
        _codec_mix_error()

    mix.exchange = exchange
    mix.exchange_ps = exchange_ps
    mix.time_varying = time_varying
    mix.wire_codec = codec
    _shifts = int(use_prev) + int(use_next)
    mix.budget = GossipBudget(
        executor="ring_codec",
        per_leaf={"collective-permute":
                  _shifts * (2 if len(axes) == 2 else 1) * codec.n_buffers},
        note=f"{codec.name}: each live band ships {codec.n_buffers} packed "
             "buffers; exchange_ps bitcasts the weight into the last one "
             "(zero extra)")
    return mix


def make_packed_codec_mixer(w, mesh: Mesh, codec: WF.WireFormat,
                            agent_axes: Sequence[str] = ("data",),
                            leaf_specs=None) -> MixFn:
    """All-gather gossip over *packed* buffers: every agent ships its
    bit-packed windows, the receiver unpacks each sender's buffers and
    accumulates ``sum_j w_ij unpack(bufs_j)`` in a scan.  Per-shard planes
    (model-sharded leaves pack per shard) and the traced-``W_t`` schedule
    slot of :func:`make_packed_mixer` are preserved."""
    w_np, time_varying = _schedule_table(w)
    w_np = w_np.astype(np.float32)
    n = w_np.shape[-1]
    axes = tuple(agent_axes)
    gather_axis = axes if len(axes) > 1 else axes[0]
    w_j = jnp.asarray(w_np)

    def gather_bufs(bufs):
        armored, kinds = _armor_bufs(bufs)
        gathered = tuple(
            jax.lax.all_gather(b, gather_axis).reshape(n, *b.shape)
            for b in armored)
        return _unarmor_bufs(gathered, kinds)

    def local(x, w_col, key):
        bufs, c_rows, d = _pack_local(codec, key, x)
        all_bufs = gather_bufs(bufs)

        def add_agent(o, j):
            return o + w_col[j] * codec.unpack(*[ab[j] for ab in all_bufs]
                                               ), None

        out, _ = jax.lax.scan(add_agent, jnp.zeros_like(c_rows),
                              jnp.arange(n))
        to_leaf = lambda rows: WF.from_windows(rows, d, x.shape
                                               ).astype(x.dtype)
        return to_leaf(c_rows), to_leaf(out)

    def exchange(key, tree, t=None):
        if time_varying:
            if t is None:
                raise ValueError("time-varying packed codec mixer needs the "
                                 "round index (pass t=state.step)")
            w_rows = _entry(w_j, t)
        else:
            w_rows = w_j
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = jax.random.split(key, len(leaves))
        if leaf_specs is not None:
            specs = leaf_specs
        else:
            specs = jax.tree_util.tree_map(
                lambda l: P(axes if len(axes) > 1 else axes[0],
                            *([None] * (l.ndim - 1))), tree)
        spec_leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda s: isinstance(s, P))

        def run(lvs, w_all, ks):
            i = _agent_index(mesh, axes)
            row = w_all[i]
            outs = [local(l, row, jax.random.fold_in(ks[j], i))
                    for j, l in enumerate(lvs)]
            return [o[0] for o in outs], [o[1] for o in outs]

        fn = shard_map(run, mesh=mesh,
                       in_specs=(spec_leaves, P(), P()),
                       out_specs=(spec_leaves, spec_leaves),
                       check_vma=False)
        cs, wcs = fn(leaves, w_rows, keys)
        return treedef.unflatten(cs), treedef.unflatten(wcs)

    def local_ps(x, w_col, wloc, key):
        """Leaf-0 variant of ``local``: the exact f32 weight increment is
        bitcast into the shipped buffers (+4 bytes in the all-gather, no
        extra collective); returns (c, wc, cw, wcw) local blocks."""
        bufs, c_rows, d = _pack_local(codec, key, x)
        ship, last_shape = _append_weight(bufs, wloc)
        all_bufs = gather_bufs(ship)

        def add_agent(carry, j):
            o, wacc = carry
            orig, wj = _split_weight(tuple(ab[j] for ab in all_bufs),
                                     last_shape)
            return (o + w_col[j] * codec.unpack(*orig),
                    wacc + w_col[j] * wj), None

        (out, w_out), _ = jax.lax.scan(
            add_agent, (jnp.zeros_like(c_rows), jnp.zeros((), jnp.float32)),
            jnp.arange(n))
        to_leaf = lambda rows: WF.from_windows(rows, d, x.shape
                                               ).astype(x.dtype)
        return (to_leaf(c_rows), to_leaf(out),
                wloc.astype(jnp.float32),
                w_out.reshape(wloc.shape))

    def exchange_ps(key, tree, dw, t=None):
        """Push-sum exchange: like ``exchange`` plus the exact (n,) weight
        increment ``dw``, shipped inside leaf 0's packed buffers.  Returns
        (c, wc, cw, wcw); cw == dw exactly."""
        if time_varying:
            if t is None:
                raise ValueError("time-varying packed codec mixer needs the "
                                 "round index (pass t=state.step)")
            w_rows = _entry(w_j, t)
        else:
            w_rows = w_j
        leaves, treedef = jax.tree_util.tree_flatten(tree)
        keys = jax.random.split(key, len(leaves))
        if leaf_specs is not None:
            specs = leaf_specs
        else:
            specs = jax.tree_util.tree_map(
                lambda l: P(axes if len(axes) > 1 else axes[0],
                            *([None] * (l.ndim - 1))), tree)
        spec_leaves = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda s: isinstance(s, P))
        w_spec = P(axes if len(axes) > 1 else axes[0])

        def run(lvs, wv, w_all, ks):
            i = _agent_index(mesh, axes)
            row = w_all[i]
            c0, wc0, cw, wcw = local_ps(lvs[0], row, wv,
                                        jax.random.fold_in(ks[0], i))
            rest = [local(l, row, jax.random.fold_in(ks[j], i))
                    for j, l in enumerate(lvs[1:], start=1)]
            return ([c0] + [o[0] for o in rest],
                    [wc0] + [o[1] for o in rest], cw, wcw)

        fn = shard_map(run, mesh=mesh,
                       in_specs=(spec_leaves, w_spec, P(), P()),
                       out_specs=(spec_leaves, spec_leaves, w_spec, w_spec),
                       check_vma=False)
        cs, wcs, cw, wcw = fn(leaves, dw, w_rows, keys)
        return (treedef.unflatten(cs), treedef.unflatten(wcs),
                cw.astype(dw.dtype), wcw.astype(dw.dtype))

    def mix(*a, **k):
        _codec_mix_error()

    mix.exchange = exchange
    mix.exchange_ps = exchange_ps
    mix.time_varying = time_varying
    mix.wire_codec = codec
    mix.budget = GossipBudget(
        executor="packed_codec", per_leaf={"all-gather": codec.n_buffers},
        note=f"{codec.name}: one all-gather per packed buffer; "
             "exchange_ps bitcasts the weight into the last one (zero "
             "extra)")
    return mix


def make_mixer(topology: Union[Topology, TopologySchedule],
               mode: str = "dense",
               mesh: Optional[Mesh] = None, frac: Optional[float] = None,
               agent_axes: Sequence[str] = ("data",),
               leaf_specs=None, codec: Optional[WF.WireFormat] = None) -> MixFn:
    """leaf_specs: optional pytree of PartitionSpecs matching the gossiped
    buffers (agent axis first, model-parallel dims preserved) -- required for
    ring/packed under a mesh whose leaves are also model-sharded.

    ``topology`` may be a static :class:`Topology` or a time-varying
    :class:`TopologySchedule`; a schedule hands the executor its stacked
    ``(period, n, n)`` table, and the mixer is tagged ``time_varying`` so
    callers (the comm-round engine, dsgd) route the round index to it via
    :func:`apply_mixer`.

    The returned MixFn is tagged with ``wire_mode`` (and ``wire_frac`` for
    packed) so the comm-round engine can account per-round wire bytes
    without being told the gossip mode twice.

    ``codec``: optional :class:`repro.core.wire_formats.WireFormat`; with a
    codec the ring / packed executor becomes the bit-packed variant (only
    packed buffers cross the wire; drive it via ``mix.exchange``).  Dense
    gossip has no codec form -- its whole point is shipping the dense
    emulation the convergence math sees."""
    schedule = topology if isinstance(topology, TopologySchedule) else None
    w = schedule.ws if schedule is not None else topology.w
    if mode == "dense":
        if codec is not None:
            raise ValueError(
                "dense gossip ships the dense emulation by definition; "
                "bit-packed wire formats need gossip mode 'ring' or "
                "'packed'")
        mix = make_dense_mixer(w)
    elif mode == "ring":
        if mesh is None:
            raise ValueError("ring gossip needs a mesh")
        if schedule is not None and not schedule.is_banded_ring():
            raise ValueError(
                f"schedule {schedule.kind!r} has rounds that are not "
                "circulant ring bands; the ring wire format only supports "
                "weight-varying ring schedules -- use dense or packed "
                "gossip for churn/resampling schedules")
        if codec is not None:
            mix = make_ring_codec_mixer(w, mesh, codec, agent_axes,
                                        leaf_specs)
        else:
            mix = make_ring_mixer(w, mesh, agent_axes, leaf_specs)
    elif mode == "packed":
        if codec is not None:
            if mesh is None:
                raise ValueError("packed gossip needs a mesh")
            mix = make_packed_codec_mixer(w, mesh, codec, agent_axes,
                                          leaf_specs)
        else:
            if mesh is None or frac is None:
                raise ValueError(
                    "packed gossip needs a mesh and a top-k fraction")
            mix = make_packed_mixer(w, mesh, frac, agent_axes,
                                    leaf_specs)
    else:
        raise ValueError(f"unknown gossip mode {mode!r}")
    mix.wire_mode = mode
    mix.wire_frac = frac
    mix.schedule = schedule
    return mix


def gossip_wire_bytes(mode: str, n_agents: int, d_params: int,
                      frac: float = 1.0, dtype_bytes: int = 4) -> float:
    """Per-round bytes crossing agent links for one buffer (model-level).

    'packed' mirrors the actual block-packed format of
    :func:`make_packed_mixer`: each agent pads its buffer to PACK_BLOCK-sized
    windows and all-gathers ``max(round(frac*PACK_BLOCK), 1)`` (value, int32
    index) pairs *per window* -- ``nb * k_b`` pairs total, not
    ``max(frac*d, 1)``.  The distinction matters for small or badly padded
    buffers (a 10-element leaf still ships one full window's k_b pairs) and
    is what the wire-bytes tests pin against the executor's payload.

    Codec executors (bit-packed wire formats) are accounted by
    :func:`repro.core.wire_formats.codec_collective_bytes` against the same
    ring/packed link conventions; :meth:`CommRound.wire_bytes` reports the
    *measured* packed-buffer nbytes and keeps this model as the cross-check.
    """
    if mode == "dense":
        return float(n_agents) * d_params * dtype_bytes
    if mode == "ring":
        # n=2 folds both bands onto the single neighbor (one ppermute)
        shifts = 1.0 if n_agents == 2 else 2.0
        return shifts * d_params * dtype_bytes
    if mode == "packed":
        nb = -(-int(d_params) // PACK_BLOCK)          # windows after padding
        k_b = max(int(round(frac * PACK_BLOCK)), 1)   # pairs per window
        return float(n_agents) * nb * k_b * (dtype_bytes + 4)
    raise ValueError(mode)
