"""Bit-packed wire formats: the one shared constants module for the packed
gossip payloads (ISSUE-6; the PR-3 drift-bug class motivated centralizing).

The executor (:mod:`repro.core.gossip`), the Pallas kernels
(:mod:`repro.kernels.wire_pack`), and the byte model all import the layout
from here, so none of them can drift from the others:

* ``topk_bits``  -- per PACK_BLOCK window, the ``k_b = max(round(frac *
  PACK_BLOCK), 1)`` largest-|.| elements as two contiguous segments:
  bf16 values and uint16 *window-local* indices (PACK_BLOCK < 2**16, so
  16 bits always suffice).  4 bytes per kept element -- exactly 8x denser
  than the dense f32 window at the same sparsity, and exactly 4x fewer
  wire bytes than dense at frac = 0.25.  int32 remains the logical index
  type on the unpack side.

* ``qsgd_bits``  -- per PACK_BLOCK window, QSGD codes bit-packed into
  uint32 words plus one f32 scale.  Each element's field is
  ``bits = ceil(log2(levels + 1)) + 1`` wide (magnitude code in
  [0, levels] plus a sign bit); ``32 // bits`` fields per word.  At
  ``levels = 7`` the field is exactly 4 bits -- a 16-state signed
  alphabet ("s=16" in the benchmarks) -- so the code payload is exactly
  8x denser than dense f32; the per-window f32 scale is accounted
  separately as overhead (payload ratio 8.0x, total ~7.97x at
  PACK_BLOCK = 2048).

Quantization granularity: the wire codec normalizes *per window* (the
scale that ships is per PACK_BLOCK window), unlike
:func:`repro.core.compression.qsgd` which normalizes over the whole
vector.  Per-window QSGD is still a Definition-3 compressor with
``omega = min(sqrt(PACK_BLOCK)/s, PACK_BLOCK/s**2)`` (errors and energies
add over windows), and the engine applies the *round-tripped* increment
locally (``c := unpack(pack(delta))``), so the ``m = W q`` invariant is
exact regardless of what the codec does to the values.

bf16 rho note (Definition 3): the ``topk_bits`` value payload is bf16, so
the round-tripped increment carries an extra relative rounding error of at
most 2**-8 per kept value; the effective contraction is
``rho' >= rho * (1 - 2**-8)**2`` -- far inside the slack of every contract
test, but stated here (and in EXPERIMENTS.md) rather than hidden.

The selection threshold is the same value-range bisection the
:mod:`repro.kernels.block_topk` kernel uses; it lives here (pure jnp, legal
inside Pallas kernel bodies) so selection and packing share one routine.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

__all__ = [
    "PACK_BLOCK",
    "N_BISECT_ITERS",
    "TOPK_VALUE_DTYPE",
    "TOPK_INDEX_DTYPE",
    "WIRE_FORMATS",
    "WIRE_MODES",
    "WireFormat",
    "bisect_threshold",
    "topk_keep",
    "qsgd_bits",
    "qsgd_elems_per_word",
    "qsgd_words_per_window",
    "qsgd_window_omega",
    "topk_pack_ref",
    "topk_unpack_ref",
    "qsgd_pack_ref",
    "qsgd_unpack_ref",
    "make_wire_format",
    "measured_pack_nbytes",
    "codec_collective_bytes",
    "to_windows",
    "from_windows",
]

# packed wire format selection window (16 x 128 lanes).  gossip.py and
# kernels/block_topk.py re-export this; it is defined only here.
PACK_BLOCK = 2048

# bisection iterations for the top-k threshold (f32 has 24 mantissa bits)
N_BISECT_ITERS = 24

TOPK_VALUE_DTYPE = jnp.bfloat16
TOPK_INDEX_DTYPE = jnp.uint16   # window-local; PACK_BLOCK < 2**16

# spec-level wire knob values (ExperimentSpec.wire)
WIRE_MODES = ("dense", "packed_bits")

# registered payload layouts (one per compressor family)
WIRE_FORMATS = ("topk_bits", "qsgd_bits")


def topk_keep(frac: float) -> int:
    """Kept elements per PACK_BLOCK window at sparsity ``frac``."""
    return max(int(round(frac * PACK_BLOCK)), 1)


def qsgd_bits(levels: int) -> int:
    """Field width: magnitude code in [0, levels] plus one sign bit."""
    return int(np.ceil(np.log2(levels + 1))) + 1


def qsgd_elems_per_word(levels: int) -> int:
    return 32 // qsgd_bits(levels)


def qsgd_words_per_window(levels: int) -> int:
    epw = qsgd_elems_per_word(levels)
    return -(-PACK_BLOCK // epw)


def qsgd_window_omega(levels: int) -> float:
    """QSGD relative variance at the window size (per-window normalization)."""
    return float(min(np.sqrt(PACK_BLOCK) / levels, PACK_BLOCK / levels ** 2))


# ---------------------------------------------------------------------------
# Shared selection threshold (used verbatim inside the Pallas kernels)
# ---------------------------------------------------------------------------

def bisect_threshold(a: jax.Array, k) -> jax.Array:
    """Threshold keeping >= k of the values in ``a`` via value bisection.

    ``a``: non-negative magnitudes (any shape, reduced globally).  Returns
    the scalar ``lo`` with ``count(a >= lo) >= k`` after N_BISECT_ITERS
    halvings -- log2-many compare+count sweeps, each a fully vectorized VPU
    pass, which is the TPU replacement for sort/radix-select.  Pure jnp, so
    it runs identically inside a Pallas kernel body, under vmap (per-row
    thresholds), and in the jnp reference codecs.
    """
    hi = jnp.max(a)
    lo = jnp.zeros_like(hi)

    def body(_, carry):
        lo, hi = carry
        mid = 0.5 * (lo + hi)
        cnt = jnp.sum((a >= mid).astype(jnp.int32))
        # too few kept -> threshold too high; too many -> raise it
        return jax.lax.cond(cnt >= k,
                            lambda: (mid, hi),
                            lambda: (lo, mid))

    lo, hi = jax.lax.fori_loop(0, N_BISECT_ITERS, body, (lo, hi))
    return lo


# ---------------------------------------------------------------------------
# jnp reference codecs (the numerical oracles for kernels/wire_pack.py; also
# what the gossip executors run off-TPU)
# ---------------------------------------------------------------------------

def to_windows(flat: jax.Array) -> jax.Array:
    """Pad a flat vector to PACK_BLOCK windows: (d,) -> (nb, PACK_BLOCK)."""
    d = flat.shape[0]
    pad = (-d) % PACK_BLOCK
    return jnp.pad(flat, (0, pad)).reshape(-1, PACK_BLOCK)


def from_windows(rows: jax.Array, d: int, shape=None) -> jax.Array:
    out = rows.reshape(-1)[:d]
    return out if shape is None else out.reshape(shape)


def topk_pack_ref(rows: jax.Array, k: int):
    """Per-window top-k pack: (nb, PACK_BLOCK) -> (bf16 (nb, k), u16 (nb, k)).

    Selection matches the kernel: bisection threshold, then the first k
    qualifying elements in *index order* (ties beyond k drop
    deterministically).  The packed segments are index-ordered, not
    magnitude-sorted -- the unpacked window is identical either way.
    """
    rows32 = rows.astype(jnp.float32)
    a = jnp.abs(rows32)
    nb = rows32.shape[0]
    th = jax.vmap(lambda r: bisect_threshold(r, k))(a)          # (nb,)
    keep = a >= th[:, None]
    rank = jnp.cumsum(keep.astype(jnp.int32), axis=1) - 1
    sel = keep & (rank < k)
    col = jnp.where(sel, rank, k)                               # spill -> k
    row_ids = jnp.broadcast_to(jnp.arange(nb)[:, None], col.shape)
    vals = jnp.zeros((nb, k + 1), jnp.float32)
    vals = vals.at[row_ids, col].set(rows32)[:, :k]
    pos = jnp.broadcast_to(jnp.arange(PACK_BLOCK)[None, :], col.shape)
    idx = jnp.zeros((nb, k + 1), jnp.int32)
    idx = idx.at[row_ids, col].set(pos)[:, :k]
    return vals.astype(TOPK_VALUE_DTYPE), idx.astype(TOPK_INDEX_DTYPE)


def topk_unpack_ref(vals: jax.Array, idx: jax.Array,
                    dtype=jnp.float32) -> jax.Array:
    """(bf16 (nb, k), u16 (nb, k)) -> dense (nb, PACK_BLOCK) window."""
    nb, k = vals.shape
    row_ids = jnp.broadcast_to(jnp.arange(nb)[:, None], (nb, k))
    out = jnp.zeros((nb, PACK_BLOCK), jnp.float32)
    out = out.at[row_ids, idx.astype(jnp.int32)].add(vals.astype(jnp.float32))
    return out.astype(dtype)


def qsgd_pack_ref(key: jax.Array, rows: jax.Array, levels: int):
    """Per-window QSGD quantize + bit-pack.

    (nb, PACK_BLOCK) -> (uint32 words (nb, W), f32 scale (nb, 1)) with
    W = qsgd_words_per_window(levels).  Stochastic rounding draws one
    uniform per element from ``key``; the scale already folds in the
    1/(1+omega) Definition-3 contraction so unpack is sign*code*scale.
    """
    bits = qsgd_bits(levels)
    epw = qsgd_elems_per_word(levels)
    words = qsgd_words_per_window(levels)
    rows32 = rows.astype(jnp.float32)
    nb = rows32.shape[0]
    norm = jnp.sqrt(jnp.sum(rows32 * rows32, axis=1)) + 1e-30    # (nb,)
    y = jnp.abs(rows32) / norm[:, None] * levels
    lo = jnp.floor(y)
    prob = y - lo
    u = jax.random.uniform(key, rows32.shape)
    code = (lo + (u < prob)).astype(jnp.uint32)                  # [0, levels]
    sign = (rows32 < 0).astype(jnp.uint32)
    field = code | (sign << jnp.uint32(bits - 1))
    pad = words * epw - PACK_BLOCK
    field = jnp.pad(field, ((0, 0), (0, pad))).reshape(nb, words, epw)
    word = jnp.zeros((nb, words), jnp.uint32)
    for e in range(epw):                                         # static OR
        word = word | (field[:, :, e] << jnp.uint32(bits * e))
    omega = qsgd_window_omega(levels)
    scale = (norm / (levels * (1.0 + omega))).astype(jnp.float32)
    return word, scale[:, None]


def qsgd_unpack_ref(word: jax.Array, scale: jax.Array, levels: int,
                    dtype=jnp.float32) -> jax.Array:
    """(uint32 (nb, W), f32 (nb, 1)) -> dense (nb, PACK_BLOCK) window."""
    bits = qsgd_bits(levels)
    epw = qsgd_elems_per_word(levels)
    nb, words = word.shape
    mag_mask = jnp.uint32(2 ** (bits - 1) - 1)
    field_mask = jnp.uint32(2 ** bits - 1)
    cols = []
    for e in range(epw):
        f = (word >> jnp.uint32(bits * e)) & field_mask
        code = (f & mag_mask).astype(jnp.float32)
        sgn = 1.0 - 2.0 * (f >> jnp.uint32(bits - 1)).astype(jnp.float32)
        cols.append(sgn * code)
    vals = jnp.stack(cols, axis=2).reshape(nb, words * epw)[:, :PACK_BLOCK]
    return (vals * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Format registry: layout + byte model in one object
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WireFormat:
    """One bit-packed payload layout: codec + byte model, inseparable.

    Attributes:
      name: "topk_bits" | "qsgd_bits".
      deterministic: True when ``pack`` ignores its key (top-k).
      payload_bytes_per_window / overhead_bytes_per_window: exact bytes
        each PACK_BLOCK window puts on the wire (overhead = per-window
        scales; the acceptance ratios count payload, totals include both).
      pack: (key, rows (nb, PACK_BLOCK)) -> tuple of wire buffers.
      unpack: (*buffers, dtype=...) -> (nb, PACK_BLOCK) dense window.
      n_buffers: how many wire buffers ``pack`` returns (the codec gossip
        executors ship each one through its own collective, so this is the
        per-leaf collective multiplier the static analyzer budgets
        against -- see :class:`repro.core.gossip.GossipBudget`).
    """

    name: str
    deterministic: bool
    payload_bytes_per_window: int
    overhead_bytes_per_window: int
    pack: Callable
    unpack: Callable
    n_buffers: int = 2

    def windows(self, d: int) -> int:
        return -(-int(d) // PACK_BLOCK)

    def payload_bytes(self, d: int) -> float:
        return float(self.windows(d) * self.payload_bytes_per_window)

    def overhead_bytes(self, d: int) -> float:
        return float(self.windows(d) * self.overhead_bytes_per_window)

    def buffer_bytes(self, d: int) -> float:
        """Modeled nbytes of one agent's packed buffers for a d-vector."""
        return self.payload_bytes(d) + self.overhead_bytes(d)


def make_wire_format(compressor_name: str, *, frac: Optional[float] = None,
                     levels: Optional[int] = None, use_pallas: bool = False,
                     interpret: Optional[bool] = None) -> WireFormat:
    """The wire format for a compressor family.

    ``use_pallas`` routes pack/unpack through the fused
    :mod:`repro.kernels.wire_pack` kernels (``interpret`` as in kernels.ops);
    otherwise the jnp reference codecs above run (XLA-fused, the oracle).
    """
    if compressor_name in ("top_k", "block_top_k"):
        if frac is None:
            raise ValueError("topk_bits wire format needs frac")
        k = topk_keep(frac)
        if use_pallas:
            from ..kernels import ops as _ops

            def pack(key, rows, _k=k):
                del key
                return _ops.wire_topk_pack(rows, _k, interpret=interpret)

            def unpack(vals, idx, dtype=jnp.float32):
                return _ops.wire_topk_unpack(vals, idx, interpret=interpret
                                             ).astype(dtype)
        else:
            def pack(key, rows, _k=k):
                del key
                return topk_pack_ref(rows, _k)

            unpack = topk_unpack_ref
        return WireFormat(
            name="topk_bits", deterministic=True,
            payload_bytes_per_window=4 * k,      # bf16 value + u16 index
            overhead_bytes_per_window=0,
            pack=pack, unpack=unpack, n_buffers=2)
    if compressor_name == "qsgd":
        if levels is None:
            raise ValueError("qsgd_bits wire format needs levels")
        words = qsgd_words_per_window(levels)
        if use_pallas:
            from ..kernels import ops as _ops

            def pack(key, rows, _l=levels):
                return _ops.wire_qsgd_pack(rows, key, _l, interpret=interpret)

            def unpack(word, scale, dtype=jnp.float32, _l=levels):
                return _ops.wire_qsgd_unpack(word, scale, _l,
                                             interpret=interpret).astype(dtype)
        else:
            def pack(key, rows, _l=levels):
                return qsgd_pack_ref(key, rows, _l)

            def unpack(word, scale, dtype=jnp.float32, _l=levels):
                return qsgd_unpack_ref(word, scale, _l, dtype)
        return WireFormat(
            name="qsgd_bits", deterministic=False,
            payload_bytes_per_window=4 * words,  # bit-packed uint32 codes
            overhead_bytes_per_window=4,         # one f32 scale per window
            pack=pack, unpack=unpack, n_buffers=2)
    raise ValueError(
        f"compressor {compressor_name!r} has no registered bit-packed wire "
        f"format; have {WIRE_FORMATS} (top_k/block_top_k -> topk_bits, "
        "qsgd -> qsgd_bits)")


def measured_pack_nbytes(fmt: WireFormat, d: int) -> int:
    """Actual nbytes of the shipped buffers for a d-vector: traced shapes
    via jax.eval_shape on the codec itself, so the measurement cannot drift
    from what the executor ships (the model in :meth:`WireFormat
    .buffer_bytes` is the cross-check, not the source)."""
    nb = fmt.windows(d)
    rows = jax.ShapeDtypeStruct((nb, PACK_BLOCK), jnp.float32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    bufs = jax.eval_shape(lambda k, r: fmt.pack(k, r), key, rows)
    return sum(int(np.prod(b.shape)) * np.dtype(b.dtype).itemsize
               for b in jax.tree_util.tree_leaves(bufs))


def measured_weight_nbytes(fmt: WireFormat) -> int:
    """Measured nbytes the push-sum weight scalar adds to one shipped buffer
    set.  The codec gossip executors bitcast the exact f32 weight increment
    into words of the *last* wire buffer's dtype and append them to its
    flattened payload (:mod:`repro.core.gossip`); this traces that buffer's
    dtype via ``jax.eval_shape`` on the codec itself -- like
    :func:`measured_pack_nbytes`, the measurement cannot drift from what the
    executor ships."""
    rows = jax.ShapeDtypeStruct((1, PACK_BLOCK), jnp.float32)
    key = jax.ShapeDtypeStruct((2,), jnp.uint32)
    bufs = jax.eval_shape(lambda k, r: fmt.pack(k, r), key, rows)
    itemsize = np.dtype(jax.tree_util.tree_leaves(bufs)[-1].dtype).itemsize
    if itemsize not in (2, 4):
        raise ValueError(
            f"no push-sum weight word layout for a {itemsize}-byte wire "
            "buffer dtype")
    return (4 // itemsize) * itemsize


def codec_collective_bytes(fmt: WireFormat, mode: str, n_agents: int,
                           d: int) -> float:
    """Per-round link bytes for one agent buffer under a codec-aware
    executor, matching :func:`repro.core.gossip.gossip_wire_bytes`'s
    conventions: 'ring' ships each agent's packed buffers to its live
    neighbors (one shift at n=2, else two); 'packed' all-gathers every
    agent's packed buffers."""
    per_agent = fmt.buffer_bytes(d)
    if mode == "ring":
        shifts = 1.0 if n_agents == 2 else 2.0
        return shifts * per_agent
    if mode == "packed":
        return float(n_agents) * per_agent
    raise ValueError(f"no codec wire accounting for gossip mode {mode!r}")
