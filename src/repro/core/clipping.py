"""Gradient clipping operators (paper Definition 2 and Remark 1).

* ``smooth_clip``     Clip_tau(x) = tau / (tau + ||x||) * x      (Definition 2)
* ``piecewise_clip``  Clip_tau(x) = x * min(1, tau/||x||)        (Remark 1)

Both map any vector into the ball of radius tau; the smooth variant is a
strict contraction (||Clip(x)|| < tau always) which is what the paper's
analysis uses, and what Theorem 1's sensitivity bound relies on.

Pytree versions clip by the *global* norm across all leaves (the model
parameter vector x in the paper is the flattened pytree).  Per-sample
clipped mini-batch gradients for PORTER-DP are produced by
``clipped_grad_accumulate`` which scans over the local batch so the
activation working set stays one-sample-sized (TPU memory-hierarchy
adaptation of DP-SGD, see DESIGN.md).
"""

from __future__ import annotations

import functools
from typing import Callable, Literal

import jax
import jax.numpy as jnp

__all__ = [
    "smooth_clip",
    "piecewise_clip",
    "tree_global_norm",
    "tree_clip",
    "clip_factor",
    "clipped_grad_accumulate",
]

ClipMode = Literal["smooth", "piecewise", "none"]


def smooth_clip(x: jax.Array, tau: float) -> jax.Array:
    """Definition 2 on a single array (norm over the whole array)."""
    nrm = jnp.linalg.norm(x.reshape(-1))
    return (tau / (tau + nrm)) * x


def piecewise_clip(x: jax.Array, tau: float) -> jax.Array:
    """Remark 1 on a single array."""
    nrm = jnp.linalg.norm(x.reshape(-1))
    return x * jnp.minimum(1.0, tau / jnp.maximum(nrm, 1e-30))


def tree_global_norm(tree) -> jax.Array:
    """l2 norm of the concatenation of all leaves (per the paper's x in R^d)."""
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves))


def clip_factor(norm: jax.Array, tau: float, mode: ClipMode) -> jax.Array:
    if mode == "smooth":
        return tau / (tau + norm)
    if mode == "piecewise":
        return jnp.minimum(1.0, tau / jnp.maximum(norm, 1e-30))
    if mode == "none":
        return jnp.ones_like(norm)
    raise ValueError(f"unknown clip mode {mode!r}")


def tree_clip(tree, tau: float, mode: ClipMode = "smooth"):
    """Clip a pytree by its global l2 norm."""
    norm = tree_global_norm(tree)
    c = clip_factor(norm, tau, mode)
    return jax.tree_util.tree_map(lambda l: (l * c).astype(l.dtype), tree)


def clipped_grad_accumulate(
    loss_fn: Callable,
    params,
    batch,
    tau: float,
    mode: ClipMode = "smooth",
) -> tuple:
    """Mean of per-sample clipped gradients: (1/b) sum_z Clip_tau(grad l(x; z)).

    This is PORTER-DP line 6.  ``batch`` is a pytree whose leaves have a
    leading local-batch axis b; the scan peels one sample at a time so peak
    memory is one sample's activations plus one parameter-sized accumulator.

    Returns (mean_clipped_grad, mean_loss).
    """
    b = jax.tree_util.tree_leaves(batch)[0].shape[0]
    grad_fn = jax.value_and_grad(loss_fn)

    def body(carry, idx):
        acc, loss_acc = carry
        # keep a singleton batch dim: loss_fns are written for batched inputs
        sample = jax.tree_util.tree_map(
            lambda x: jax.lax.dynamic_slice_in_dim(x, idx, 1, axis=0), batch)
        loss, g = grad_fn(params, sample)
        g = tree_clip(g, tau, mode)
        acc = jax.tree_util.tree_map(jnp.add, acc, g)
        return (acc, loss_acc + loss), None

    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p, dtype=jnp.float32),
                                   params)
    (acc, loss_sum), _ = jax.lax.scan(body, (zeros, 0.0), jnp.arange(b))
    mean_g = jax.tree_util.tree_map(lambda a: a / b, acc)
    return mean_g, loss_sum / b
