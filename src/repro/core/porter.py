"""PORTER (paper Algorithm 1): decentralized nonconvex optimization with
gradient clipping and communication compression.

State layout: every buffer is an *agent-stacked pytree* -- each leaf carries a
leading ``n_agents`` axis which, under pjit, is sharded over the mesh's agent
axes (``('data',)`` or ``('pod','data')``).  Buffers (paper notation):

    x       X^t      parameters, one replica per agent
    v       V^t      gradient-tracking estimates
    q_x     Q_x^t    compressed surrogate of X (error feedback)
    q_v     Q_v^t    compressed surrogate of V
    g_prev  G_p^t    previous perturbed/clipped stochastic gradient
    m_x     (W Q_x)  mixing mirror: sum_j w_ij q_{x,j}, accumulated from wire
    m_v     (W Q_v)  increments -- see core/gossip.py; (Q(W-I))_i = m_i - q_i

The two mirrors are the receive-side state a real deployment keeps anyway;
they let every wire format (dense / ring / packed top-k) share one algorithm
body.

One iteration (Algorithm 1, lines 4-14):

    G^t   = clipped/perturbed stochastic gradient at X^{t-1}     (DP or GC)
    c_v   = C(V^{t-1} - Q_v^{t-1});  Q_v += c_v;  M_v += W c_v   (comm)
    V^t   = V^{t-1} + gamma (M_v - Q_v) + G^t - G^{t-1}
    c_x   = C(X^{t-1} - Q_x^{t-1});  Q_x += c_x;  M_x += W c_x   (comm)
    X^t   = X^{t-1} + gamma (M_x - Q_x) - eta V^t

The communication + fused-update halves (lines 11-14) are delegated to the
comm-round engine (:class:`repro.core.comm_round.CommRound`): ``track`` is
lines 11-12, ``step`` is lines 13-14.  This module only owns the gradient
oracle (lines 4-10) and the metrics.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import clipping
from .comm_round import CommRound, compress_stacked, resolve_engine
from .compression import Compressor
from .gossip import MixFn, make_dense_mixer
from .mixing import Topology

__all__ = [
    "PorterConfig",
    "PorterState",
    "porter_init",
    "porter_step",
    "make_porter_step",
    "average_params",
    "consensus_error",
]

LossFn = Callable[[Any, Any], jax.Array]  # (params, batch) -> scalar loss

# Backwards-compatible alias: the per-agent compression helper now lives in
# comm_round (it is the engine's default compress path).
_compress_stacked = compress_stacked


@dataclasses.dataclass(frozen=True)
class PorterConfig:
    """Hyper-parameters of Algorithm 1.

    variant: 'dp' (clip-then-batch + Gaussian noise, Option I),
             'gc' (batch-then-clip, Option II),
             'beer' (no clipping -- the BEER ancestor, tau ignored).
    """

    eta: float                      # gradient stepsize
    gamma: float                    # consensus stepsize
    tau: float = 1.0                # clipping threshold
    variant: str = "gc"             # 'dp' | 'gc' | 'beer'
    clip_mode: str = "smooth"       # 'smooth' | 'piecewise'
    sigma_p: float = 0.0            # DP perturbation std (Theorem 1)
    grad_dtype: Any = jnp.float32   # accumulation dtype for the EF buffers

    def __post_init__(self):
        if self.variant not in ("dp", "gc", "beer"):
            raise ValueError(f"unknown variant {self.variant!r}")


class PorterState(NamedTuple):
    x: Any
    v: Any
    q_x: Any
    q_v: Any
    g_prev: Any
    m_x: Any
    m_v: Any
    step: jax.Array


def _zeros_like_f(tree, dtype):
    return jax.tree_util.tree_map(lambda l: jnp.zeros(l.shape, dtype), tree)


def porter_init(params: Any, n_agents: int, w: Optional[np.ndarray] = None,
                buffer_dtype: Any = jnp.float32,
                plane_dtype: Any = None) -> PorterState:
    """Initialize from a single replica; X^0 = x0 1^T (paper line 2).

    ``plane_dtype``: storage dtype for the six EF buffers (q_x, q_v, m_x,
    m_v, v, g_prev) -- ``'bf16'``/``jnp.bfloat16`` halves the resident
    optimizer state while the master params ``x`` keep their own dtype
    (typically f32) for an exact parameter trajectory.  None keeps the
    legacy layout: surrogates in x's dtype, zeros in ``buffer_dtype``.
    """
    x = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, (n_agents,) + p.shape), params)
    pdt = None if plane_dtype is None else jnp.dtype(plane_dtype)
    zeros = _zeros_like_f(x, buffer_dtype if pdt is None else pdt)
    if w is None:
        m_x = x  # all agents equal and rows of W sum to 1 => W X0 = X0
    else:
        mixer = make_dense_mixer(w)
        m_x = mixer(x)
    q_x = x
    if pdt is not None:
        q_x = jax.tree_util.tree_map(lambda l: l.astype(pdt), x)
        m_x = jax.tree_util.tree_map(lambda l: l.astype(pdt), m_x)
    return PorterState(x=x, v=zeros, q_x=q_x, q_v=zeros, g_prev=zeros,
                       m_x=m_x, m_v=zeros, step=jnp.zeros((), jnp.int32))


def _agent_gradient(cfg: PorterConfig, loss_fn: LossFn, params, batch,
                    key: jax.Array) -> Tuple[jax.Array, Any]:
    """One agent's G_p (Algorithm 1 lines 5-10).  batch leaves: (b, ...)."""
    if cfg.variant == "dp":
        # Option I: clip each sample's gradient, average, perturb.
        g, loss = clipping.clipped_grad_accumulate(
            loss_fn, params, batch, cfg.tau, cfg.clip_mode)
        leaves, treedef = jax.tree_util.tree_flatten(g)
        keys = jax.random.split(key, len(leaves))
        noised = [
            l + cfg.sigma_p * jax.random.normal(k, l.shape, l.dtype)
            for k, l in zip(keys, leaves)
        ]
        return loss, treedef.unflatten(noised)
    # Option II / BEER: one batch gradient, clip after (or not at all).
    loss, g = jax.value_and_grad(loss_fn)(params, batch)
    if cfg.variant == "gc":
        g = clipping.tree_clip(g, cfg.tau, cfg.clip_mode)
    return loss, g


# Backwards-compatible alias: engine resolution (and its conflict check)
# lives in comm_round; porter_adam and older call sites import it from here.
_resolve_engine = resolve_engine


def porter_step(
    cfg: PorterConfig,
    loss_fn: LossFn,
    mixer: Optional[MixFn],
    compressor: Optional[Compressor],
    state: PorterState,
    batch: Any,
    key: jax.Array,
    compress_fn=None,
    engine: Optional[CommRound] = None,
    grad_override: Optional[Tuple[jax.Array, Any]] = None,
) -> Tuple[PorterState, Dict[str, jax.Array]]:
    """One PORTER iteration over all agents (pure; jit/pjit-able).

    batch: pytree with leaves (n_agents, b, ...).
    compress_fn: optional (key, tree) -> tree override for the compression
    (e.g. the shard-local compressor from repro.launch.steps, which keeps
    top-k selection inside each model shard and avoids resharding
    all-gathers).  Defaults to per-agent-row compression of ``compressor``.
    engine: optional pre-built CommRound (the facade repro.api.build makes
    one per algorithm).  An engine owns its compressor/mixer/compress_fn;
    passing a *different* object alongside ``engine=`` raises (it used to be
    silently ignored).  With ``engine=`` the positional mixer/compressor may
    simply be None.
    grad_override: optional ``(losses, g)`` replacing the gradient oracle
    (lines 4-10) while keeping the comm rounds (lines 11-14) -- clip21
    feeds its error-feedback clipped gradient through here.  ``losses`` is
    the per-agent loss vector, ``g`` the agent-stacked gradient tree; the
    key is still consumed identically so PRNG streams stay aligned with
    the un-overridden step.
    """
    eng = resolve_engine(engine, mixer, compressor, compress_fn)
    n = jax.tree_util.tree_leaves(state.x)[0].shape[0]
    _, k_noise, k_cv, k_cx = jax.random.split(key, 4)

    # ---- stochastic gradients (local; lines 4-10) -------------------------
    if grad_override is None:
        agent_keys = jax.random.split(k_noise, n)
        grad_fn = functools.partial(_agent_gradient, cfg, loss_fn)
        losses, g = jax.vmap(grad_fn)(state.x, batch, agent_keys)
    else:
        losses, g = grad_override
    g = jax.tree_util.tree_map(lambda l: l.astype(cfg.grad_dtype), g)

    # ---- comm rounds: track (lines 11-12) + step (lines 13-14) ------------
    # the state's own step counter is the absolute round index: it advances
    # inside the scan, survives checkpoints, and selects W_t when the mixer
    # runs a time-varying topology schedule (static mixers ignore it)
    if eng.overlap:
        # comm/compute overlap: the x-side exchange reads only (x, q_x),
        # which the v-side update never touches, so both compress+collective
        # pairs are issued before either fused update -- the collectives
        # run while the other round's local compute proceeds, and every
        # value equals the sequential order's (bit-exact by construction)
        # SR keys split exactly as the sequential track/step would, so
        # overlap stays bit-exact under mixed precision too
        k_cv, sr_v = eng.sr_split(k_cv, (state.q_v, state.m_v, state.v))
        k_cx, sr_x = eng.sr_split(k_cx, (state.q_x, state.m_x, state.x))
        c_v, wc_v = eng.exchange(k_cv, state.v, state.q_v, t=state.step)
        c_x, wc_x = eng.exchange(k_cx, state.x, state.q_x, t=state.step)
        v, q_v, m_v = eng.track_update(c_v, wc_v, state.v, state.q_v,
                                       state.m_v, g, state.g_prev, cfg.gamma,
                                       sr_key=sr_v)
        x, q_x, m_x = eng.step_update(c_x, wc_x, state.x, state.q_x,
                                      state.m_x, v, cfg.gamma, cfg.eta,
                                      sr_key=sr_x)
    else:
        v, q_v, m_v = eng.track(k_cv, state.v, state.q_v, state.m_v, g,
                                state.g_prev, cfg.gamma, t=state.step)
        x, q_x, m_x = eng.step(k_cx, state.x, state.q_x, state.m_x, v,
                               cfg.gamma, cfg.eta, t=state.step)

    new_state = PorterState(x=x, v=v, q_x=q_x, q_v=q_v, g_prev=g,
                            m_x=m_x, m_v=m_v, step=state.step + 1)
    metrics = {
        "loss": jnp.mean(losses),
        "consensus_x": consensus_error(x),
        "consensus_v": consensus_error(v),
        "v_norm": clipping.tree_global_norm(v) / np.sqrt(n),
        # two compressed streams (Q_x and Q_v) per round
        "wire_bytes": jnp.asarray(2.0 * eng.wire_bytes(state.x),
                                  jnp.float32),
    }
    return new_state, metrics


def make_porter_step(cfg: PorterConfig, loss_fn: LossFn, mixer: MixFn,
                     compressor: Compressor, compress_fn=None,
                     backend: str = "auto",
                     interpret: Optional[bool] = None):
    """Bind the static pieces; returns step(state, batch, key).

    backend / interpret configure the comm-round engine ('auto' = fused
    Pallas kernels on TPU, jnp reference elsewhere).
    """
    engine = CommRound(compressor=compressor, mixer=mixer,
                       compress_fn=compress_fn, backend=backend,
                       interpret=interpret)
    return functools.partial(porter_step, cfg, loss_fn, None, None,
                             engine=engine)


def average_params(x_stacked):
    """x-bar: the average replica (paper's evaluation point)."""
    return jax.tree_util.tree_map(lambda l: jnp.mean(l, axis=0), x_stacked)


def consensus_error(tree) -> jax.Array:
    """|| Y - y_bar 1^T ||_F^2 across all leaves."""
    def leaf_err(l):
        lf = l.astype(jnp.float32)
        mean = jnp.mean(lf, axis=0, keepdims=True)
        return jnp.sum(jnp.square(lf - mean))

    return sum(leaf_err(l) for l in jax.tree_util.tree_leaves(tree))
