"""PORTER (paper Algorithm 1): decentralized nonconvex optimization with
gradient clipping and communication compression.

State layout: every buffer is an *agent-stacked pytree* -- each leaf carries a
leading ``n_agents`` axis which, under pjit, is sharded over the mesh's agent
axes (``('data',)`` or ``('pod','data')``).  Buffers (paper notation):

    x       X^t      parameters, one replica per agent
    v       V^t      gradient-tracking estimates
    q_x     Q_x^t    compressed surrogate of X (error feedback)
    q_v     Q_v^t    compressed surrogate of V
    g_prev  G_p^t    previous perturbed/clipped stochastic gradient
    m_x     (W Q_x)  mixing mirror: sum_j w_ij q_{x,j}, accumulated from wire
    m_v     (W Q_v)  increments -- see core/gossip.py; (Q(W-I))_i = m_i - q_i

The two mirrors are the receive-side state a real deployment keeps anyway;
they let every wire format (dense / ring / packed top-k) share one algorithm
body.

One iteration (Algorithm 1, lines 4-14):

    G^t   = clipped/perturbed stochastic gradient at X^{t-1}     (DP or GC)
    c_v   = C(V^{t-1} - Q_v^{t-1});  Q_v += c_v;  M_v += W c_v   (comm)
    V^t   = V^{t-1} + gamma (M_v - Q_v) + G^t - G^{t-1}
    c_x   = C(X^{t-1} - Q_x^{t-1});  Q_x += c_x;  M_x += W c_x   (comm)
    X^t   = X^{t-1} + gamma (M_x - Q_x) - eta V^t
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import clipping
from .compression import Compressor
from .gossip import MixFn, make_dense_mixer
from .mixing import Topology

__all__ = [
    "PorterConfig",
    "PorterState",
    "porter_init",
    "porter_step",
    "make_porter_step",
    "average_params",
    "consensus_error",
]

LossFn = Callable[[Any, Any], jax.Array]  # (params, batch) -> scalar loss


@dataclasses.dataclass(frozen=True)
class PorterConfig:
    """Hyper-parameters of Algorithm 1.

    variant: 'dp' (clip-then-batch + Gaussian noise, Option I),
             'gc' (batch-then-clip, Option II),
             'beer' (no clipping -- the BEER ancestor, tau ignored).
    """

    eta: float                      # gradient stepsize
    gamma: float                    # consensus stepsize
    tau: float = 1.0                # clipping threshold
    variant: str = "gc"             # 'dp' | 'gc' | 'beer'
    clip_mode: str = "smooth"       # 'smooth' | 'piecewise'
    sigma_p: float = 0.0            # DP perturbation std (Theorem 1)
    grad_dtype: Any = jnp.float32   # accumulation dtype for the EF buffers

    def __post_init__(self):
        if self.variant not in ("dp", "gc", "beer"):
            raise ValueError(f"unknown variant {self.variant!r}")


class PorterState(NamedTuple):
    x: Any
    v: Any
    q_x: Any
    q_v: Any
    g_prev: Any
    m_x: Any
    m_v: Any
    step: jax.Array


def _zeros_like_f(tree, dtype):
    return jax.tree_util.tree_map(lambda l: jnp.zeros(l.shape, dtype), tree)


def porter_init(params: Any, n_agents: int, w: Optional[np.ndarray] = None,
                buffer_dtype: Any = jnp.float32) -> PorterState:
    """Initialize from a single replica; X^0 = x0 1^T (paper line 2)."""
    x = jax.tree_util.tree_map(
        lambda p: jnp.broadcast_to(p, (n_agents,) + p.shape), params)
    zeros = _zeros_like_f(x, buffer_dtype)
    if w is None:
        m_x = x  # all agents equal and rows of W sum to 1 => W X0 = X0
    else:
        mixer = make_dense_mixer(w)
        m_x = mixer(x)
    return PorterState(x=x, v=zeros, q_x=x, q_v=zeros, g_prev=zeros,
                       m_x=m_x, m_v=zeros, step=jnp.zeros((), jnp.int32))


def _compress_stacked(comp: Compressor, key: jax.Array, tree):
    """Compress each agent's row of every leaf independently."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    keys = jax.random.split(key, len(leaves))

    def one(k, leaf):
        n = leaf.shape[0]
        ks = jax.random.split(k, n)
        return jax.vmap(lambda kk, row: comp(kk, row))(ks, leaf)

    return treedef.unflatten([one(k, l) for k, l in zip(keys, leaves)])


def _agent_gradient(cfg: PorterConfig, loss_fn: LossFn, params, batch,
                    key: jax.Array) -> Tuple[jax.Array, Any]:
    """One agent's G_p (Algorithm 1 lines 5-10).  batch leaves: (b, ...)."""
    if cfg.variant == "dp":
        # Option I: clip each sample's gradient, average, perturb.
        g, loss = clipping.clipped_grad_accumulate(
            loss_fn, params, batch, cfg.tau, cfg.clip_mode)
        leaves, treedef = jax.tree_util.tree_flatten(g)
        keys = jax.random.split(key, len(leaves))
        noised = [
            l + cfg.sigma_p * jax.random.normal(k, l.shape, l.dtype)
            for k, l in zip(keys, leaves)
        ]
        return loss, treedef.unflatten(noised)
    # Option II / BEER: one batch gradient, clip after (or not at all).
    loss, g = jax.value_and_grad(loss_fn)(params, batch)
    if cfg.variant == "gc":
        g = clipping.tree_clip(g, cfg.tau, cfg.clip_mode)
    return loss, g


def porter_step(
    cfg: PorterConfig,
    loss_fn: LossFn,
    mixer: MixFn,
    compressor: Compressor,
    state: PorterState,
    batch: Any,
    key: jax.Array,
    compress_fn=None,
) -> Tuple[PorterState, Dict[str, jax.Array]]:
    """One PORTER iteration over all agents (pure; jit/pjit-able).

    batch: pytree with leaves (n_agents, b, ...).
    compress_fn: optional (key, tree) -> tree override for the compression
    (e.g. the shard-local compressor from repro.launch.steps, which keeps
    top-k selection inside each model shard and avoids resharding
    all-gathers).  Defaults to per-agent-row compression of ``compressor``.
    """
    n = jax.tree_util.tree_leaves(state.x)[0].shape[0]
    _, k_noise, k_cv, k_cx = jax.random.split(key, 4)
    if compress_fn is None:
        compress_fn = functools.partial(_compress_stacked, compressor)

    # ---- stochastic gradients (local; lines 4-10) -------------------------
    agent_keys = jax.random.split(k_noise, n)
    grad_fn = functools.partial(_agent_gradient, cfg, loss_fn)
    losses, g = jax.vmap(grad_fn)(state.x, batch, agent_keys)
    g = jax.tree_util.tree_map(lambda l: l.astype(cfg.grad_dtype), g)

    # ---- gradient-estimate track (lines 11-12) ----------------------------
    incr_v = compress_fn(k_cv,
                         jax.tree_util.tree_map(jnp.subtract, state.v,
                                                state.q_v))
    q_v = jax.tree_util.tree_map(jnp.add, state.q_v, incr_v)
    m_v = jax.tree_util.tree_map(jnp.add, state.m_v, mixer(incr_v))
    gossip_v = jax.tree_util.tree_map(lambda m, q: m - q, m_v, q_v)
    v = jax.tree_util.tree_map(
        lambda v0, gv, gn, gp: v0 + cfg.gamma * gv + gn - gp,
        state.v, gossip_v, g, state.g_prev)

    # ---- parameter update (lines 13-14) -----------------------------------
    incr_x = compress_fn(k_cx,
                         jax.tree_util.tree_map(jnp.subtract, state.x,
                                                state.q_x))
    q_x = jax.tree_util.tree_map(jnp.add, state.q_x, incr_x)
    m_x = jax.tree_util.tree_map(jnp.add, state.m_x, mixer(incr_x))
    gossip_x = jax.tree_util.tree_map(lambda m, q: m - q, m_x, q_x)
    x = jax.tree_util.tree_map(
        lambda x0, gx, vv: (x0 + cfg.gamma * gx - cfg.eta * vv).astype(x0.dtype),
        state.x, gossip_x, v)

    new_state = PorterState(x=x, v=v, q_x=q_x, q_v=q_v, g_prev=g,
                            m_x=m_x, m_v=m_v, step=state.step + 1)
    metrics = {
        "loss": jnp.mean(losses),
        "consensus_x": consensus_error(x),
        "consensus_v": consensus_error(v),
        "v_norm": clipping.tree_global_norm(v) / np.sqrt(n),
    }
    return new_state, metrics


def make_porter_step(cfg: PorterConfig, loss_fn: LossFn, mixer: MixFn,
                     compressor: Compressor, compress_fn=None):
    """Bind the static pieces; returns step(state, batch, key)."""
    return functools.partial(porter_step, cfg, loss_fn, mixer, compressor,
                             compress_fn=compress_fn)


def average_params(x_stacked):
    """x-bar: the average replica (paper's evaluation point)."""
    return jax.tree_util.tree_map(lambda l: jnp.mean(l, axis=0), x_stacked)


def consensus_error(tree) -> jax.Array:
    """|| Y - y_bar 1^T ||_F^2 across all leaves."""
    def leaf_err(l):
        lf = l.astype(jnp.float32)
        mean = jnp.mean(lf, axis=0, keepdims=True)
        return jnp.sum(jnp.square(lf - mean))

    return sum(leaf_err(l) for l in jax.tree_util.tree_leaves(tree))
