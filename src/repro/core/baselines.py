"""Baseline algorithms the paper compares against (Table 1 and Section 5).

* ``dsgd``          decentralized SGD with gossip averaging (no tracking, no
                    EF, optionally clipped) -- the naive adaptation.
* ``choco``         CHOCO-SGD [KSJ19]: compressed gossip with surrogate
                    mirrors, no gradient tracking.
* ``dp_sgd``        centralized DP-SGD [ACG+16] -- Table 1's single-server
                    baseline (utility phi_m reference point).
* ``soteriafl``     SoteriaFL-SGD [LZLC22]: server/client LDP with *shifted*
                    compression -- the paper's Section-5 head-to-head.

All share the agent-stacked pytree layout of :mod:`repro.core.porter` so the
same data pipeline, loss functions and metrics apply.  The compressed
algorithms route their communication through the comm-round engine
(:class:`repro.core.comm_round.CommRound`): CHOCO's surrogate/mirror round
is ``engine.gossip_apply``, SoteriaFL's shifted compression is
``engine.shift`` -- there is no hand-rolled ``q += c; m += Wc`` body left in
this module.

Metrics schema (uniform across algorithms, so benchmarks/ablation.py can
compare them on equal footing):

    loss         mean agent loss
    consensus_x  ||X - x-bar 1^T||_F^2   (decentralized algorithms)
    wire_bytes   model-level bytes crossing links per round (all agents)
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import clipping
from .comm_round import CommRound, resolve_engine
from .compression import Compressor
from .gossip import MixFn, apply_mixer, gossip_wire_bytes
from .porter import LossFn, average_params, consensus_error

__all__ = [
    "DsgdState", "dsgd_init", "dsgd_step",
    "ChocoState", "choco_init", "choco_step",
    "DpSgdState", "dpsgd_init", "dpsgd_step",
    "SoteriaState", "soteria_init", "soteria_step",
]


def _tree(op, *trees):
    return jax.tree_util.tree_map(op, *trees)


def _stack(params, n):
    return _tree(lambda p: jnp.broadcast_to(p, (n,) + p.shape), params)


def _param_count(tree, n_agents: int) -> int:
    return sum(int(l.size) // n_agents
               for l in jax.tree_util.tree_leaves(tree))


def _dp_gradient(loss_fn, params, batch, key, tau, clip_mode, sigma_p):
    g, loss = clipping.clipped_grad_accumulate(loss_fn, params, batch, tau,
                                               clip_mode)
    leaves, treedef = jax.tree_util.tree_flatten(g)
    keys = jax.random.split(key, len(leaves))
    g = treedef.unflatten([
        l + sigma_p * jax.random.normal(k, l.shape, l.dtype)
        for k, l in zip(keys, leaves)
    ])
    return loss, g


# ---------------------------------------------------------------------------
# DSGD
# ---------------------------------------------------------------------------

class DsgdState(NamedTuple):
    x: Any
    step: jax.Array


def dsgd_init(params, n_agents: int) -> DsgdState:
    return DsgdState(x=_stack(params, n_agents),
                     step=jnp.zeros((), jnp.int32))


def dsgd_step(eta: float, gamma: float, loss_fn: LossFn, mixer: MixFn,
              state: DsgdState, batch, key,
              tau: Optional[float] = None, clip_mode: str = "smooth",
              sigma_p: float = 0.0, dp: bool = False
              ) -> Tuple[DsgdState, Dict[str, jax.Array]]:
    """X^{t+1} = X + gamma X(W - I) - eta G   (uncompressed gossip)."""
    n = jax.tree_util.tree_leaves(state.x)[0].shape[0]
    keys = jax.random.split(key, n)

    def agent_grad(p, b, k):
        if dp:
            return _dp_gradient(loss_fn, p, b, k, tau, clip_mode, sigma_p)
        loss, g = jax.value_and_grad(loss_fn)(p, b)
        if tau is not None:
            g = clipping.tree_clip(g, tau, clip_mode)
        return loss, g

    losses, g = jax.vmap(agent_grad)(state.x, batch, keys)
    # W_t X; the step counter selects the round's matrix under a schedule
    mixed = apply_mixer(mixer, state.x, state.step)
    x = _tree(lambda x0, wx, gg: x0 + gamma * (wx - x0) - eta * gg,
              state.x, mixed, g)
    # uncompressed gossip of the full parameter buffer every round
    frac = getattr(mixer, "wire_frac", None)
    wire = gossip_wire_bytes(getattr(mixer, "wire_mode", "dense"), n,
                             _param_count(state.x, n),
                             frac=1.0 if frac is None else frac)
    return DsgdState(x=x, step=state.step + 1), {
        "loss": jnp.mean(losses), "consensus_x": consensus_error(x),
        "wire_bytes": jnp.asarray(wire, jnp.float32)}


# ---------------------------------------------------------------------------
# CHOCO-SGD
# ---------------------------------------------------------------------------

class ChocoState(NamedTuple):
    x: Any
    q: Any      # own surrogate x-hat
    m: Any      # mixing mirror: sum_j w_ij x-hat_j
    step: jax.Array


def choco_init(params, n_agents: int, plane_dtype=None) -> ChocoState:
    """``plane_dtype``: storage dtype of the surrogate/mirror buffers
    (bf16 halves them); the params ``x`` keep their own dtype."""
    x = _stack(params, n_agents)
    dt = jnp.float32 if plane_dtype is None else jnp.dtype(plane_dtype)
    zeros = _tree(lambda l: jnp.zeros_like(l, dtype=dt), x)
    return ChocoState(x=x, q=zeros, m=zeros, step=jnp.zeros((), jnp.int32))


def choco_step(eta: float, gamma: float, loss_fn: LossFn,
               mixer: Optional[MixFn], compressor: Optional[Compressor],
               state: ChocoState, batch, key,
               tau: Optional[float] = None, clip_mode: str = "smooth",
               engine: Optional[CommRound] = None,
               ) -> Tuple[ChocoState, Dict[str, jax.Array]]:
    """CHOCO-SGD: x+ = x - eta g;  q += C(x+ - q);  x = x+ + gamma (m - q)."""
    eng = resolve_engine(engine, mixer, compressor)
    n = jax.tree_util.tree_leaves(state.x)[0].shape[0]
    k_g, k_c = jax.random.split(key)
    keys = jax.random.split(k_g, n)

    def agent_grad(p, b, k):
        del k
        loss, g = jax.value_and_grad(loss_fn)(p, b)
        if tau is not None:
            g = clipping.tree_clip(g, tau, clip_mode)
        return loss, g

    losses, g = jax.vmap(agent_grad)(state.x, batch, keys)
    x_half = _tree(lambda x0, gg: x0 - eta * gg, state.x, g)
    x, q, m = eng.gossip_apply(k_c, x_half, state.q, state.m, gamma,
                               t=state.step)
    return ChocoState(x=x, q=q, m=m, step=state.step + 1), {
        "loss": jnp.mean(losses), "consensus_x": consensus_error(x),
        "wire_bytes": jnp.asarray(eng.wire_bytes(state.x), jnp.float32)}


# ---------------------------------------------------------------------------
# Centralized DP-SGD (Table 1 baseline)
# ---------------------------------------------------------------------------

class DpSgdState(NamedTuple):
    x: Any
    step: jax.Array


def dpsgd_init(params) -> DpSgdState:
    # copy: the state must own its buffers -- the chunked runtime donates
    # them, which would otherwise delete the caller's params mid-harness
    return DpSgdState(x=_tree(jnp.array, params),
                      step=jnp.zeros((), jnp.int32))


def dpsgd_step(eta: float, loss_fn: LossFn, state: DpSgdState, batch, key,
               tau: float = 1.0, clip_mode: str = "smooth",
               sigma_p: float = 0.0) -> Tuple[DpSgdState, Dict[str, jax.Array]]:
    loss, g = _dp_gradient(loss_fn, state.x, batch, key, tau, clip_mode,
                           sigma_p)
    x = _tree(lambda x0, gg: x0 - eta * gg, state.x, g)
    # one dense gradient upload to the server per round, at each buffer's
    # actual dtype width (a bf16 run moves half the bytes of an f32 one)
    wire = sum(int(l.size) * jnp.dtype(l.dtype).itemsize
               for l in jax.tree_util.tree_leaves(state.x))
    return DpSgdState(x=x, step=state.step + 1), {
        "loss": loss, "wire_bytes": jnp.asarray(float(wire), jnp.float32)}


# ---------------------------------------------------------------------------
# SoteriaFL-SGD (server/client, shifted compression)
# ---------------------------------------------------------------------------

class SoteriaState(NamedTuple):
    x: Any       # server model (replicated view)
    h: Any       # per-client shift, agent-stacked
    h_bar: Any   # server-side average shift
    step: jax.Array


def soteria_init(params, n_agents: int, plane_dtype=None) -> SoteriaState:
    """``plane_dtype``: storage dtype of the agent-stacked client shifts
    ``h`` (the memory-dominant buffer; bf16 halves it).  The server-side
    ``h_bar`` is a single replica and stays f32 exact."""
    dt = jnp.float32 if plane_dtype is None else jnp.dtype(plane_dtype)
    zeros_stacked = _tree(
        lambda p: jnp.zeros((n_agents,) + p.shape, dt), params)
    zeros = _tree(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    # copy x: the state must own its buffers (donation-safe, see dpsgd_init)
    return SoteriaState(x=_tree(jnp.array, params), h=zeros_stacked,
                        h_bar=zeros, step=jnp.zeros((), jnp.int32))


def soteria_step(eta: float, alpha_shift: float, loss_fn: LossFn,
                 compressor: Optional[Compressor], state: SoteriaState,
                 batch, key,
                 tau: float = 1.0, clip_mode: str = "smooth",
                 sigma_p: float = 0.0,
                 engine: Optional[CommRound] = None
                 ) -> Tuple[SoteriaState, Dict[str, jax.Array]]:
    """SoteriaFL-SGD: clients send C(g_i - h_i); server uses h_bar + mean(c).

    g_i is the per-sample-clipped + perturbed local gradient (LDP).  The
    client side is the engine's shifted-compression primitive; the server
    mean replaces the gossip mirror.
    """
    eng = resolve_engine(engine, None, compressor)
    n = jax.tree_util.tree_leaves(state.h)[0].shape[0]
    k_g, k_c = jax.random.split(key)
    keys = jax.random.split(k_g, n)

    def client(h_i, b, k):
        loss, g = _dp_gradient(loss_fn, state.x, b, k, tau, clip_mode, sigma_p)
        return loss, g

    losses, g = jax.vmap(client)(state.h, batch, keys)
    c, h = eng.shift(k_c, g, state.h, scale=alpha_shift)
    c_bar = _tree(lambda cc: jnp.mean(cc, axis=0), c)
    g_tilde = _tree(jnp.add, state.h_bar, c_bar)
    h_bar = _tree(lambda hb, cb: hb + alpha_shift * cb, state.h_bar, c_bar)
    x = _tree(lambda x0, gt: (x0 - eta * gt).astype(x0.dtype), state.x, g_tilde)
    # n compressed client uploads per round (server broadcast not counted,
    # matching the LDP literature's upload accounting); accounted from the
    # engine so the metric always reflects the compressor that actually ran
    wire = eng.wire_bytes(state.h)
    return SoteriaState(x=x, h=h, h_bar=h_bar, step=state.step + 1), {
        "loss": jnp.mean(losses),
        "wire_bytes": jnp.asarray(wire, jnp.float32)}
