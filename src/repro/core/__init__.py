"""repro.core -- the paper's contribution: PORTER and its substrate.

Public surface:

    compression : rho-compressors (Definition 3) + packed wire format
    clipping    : smooth / piecewise clipping (Definition 2, Remark 1)
    mixing      : graphs, mixing matrices, mixing rate (Definition 1),
                  time-varying TopologySchedule (churn / stragglers / ER
                  resampling) with window-connectivity validation
    privacy     : phi_m, Theorem-1 sigma calibration, moments accountant
    gossip      : dense / ring / packed mixers over agent-stacked pytrees
                  (static W or a schedule table indexed by a traced round)
    comm_round  : the one fused EF/gossip round primitive (CommRound) every
                  compressed algorithm is a thin client of
    registry    : the Algorithm protocol + registry every optimizer is
                  published through (init/step/state_cls, uniform
                  loss/wire_bytes metrics)
    porter      : Algorithm 1 (PORTER-DP / PORTER-GC / BEER)
    baselines   : DSGD, CHOCO-SGD, DP-SGD, SoteriaFL-SGD
    clip21      : Clip21 error-feedback clipping (residual clip, EF21-style)
    subgrad     : nonsmooth subgradient method with compressed gossip
    fleet       : fleet-scale simulated agents (n >> devices): sparse COO
                  topologies/schedules + the fleet mixer (dense-gate einsum
                  bit parity, COO scatter-add at n = 1k-100k)

The recommended entry point is the facade one level up, :mod:`repro.api`:
declare an ``ExperimentSpec`` (algorithm name + topology + compressor +
clipping/privacy knobs) and ``build(spec, loss_fn)`` it into a ready
``Algorithm`` -- the facade owns topology/mixer/compressor/engine
construction and the ``gamma = 0.5 * (1 - alpha) * rho`` derivation, and it
registers all eight entry points (porter-gc, porter-dp, beer, porter-adam,
dsgd, choco, dp-sgd, soteriafl).  The per-algorithm functions below remain
as thin, stable wrappers for tests and power users.
"""

from . import (baselines, beer, clip21, clipping, comm_round, compression,
               fleet, gossip, mixing, porter, privacy, registry, subgrad,
               wire_formats)


from .clip21 import Clip21State, clip21_init, clip21_step, clip21_update
from .clipping import piecewise_clip, smooth_clip, tree_clip, tree_global_norm
from .comm_round import CommRound, resolve_engine
from .compression import Compressor, make_compressor
from .fleet import (FLEET_DENSE_GATE, FleetSchedule, FleetTopology,
                    fleet_er_schedule, fleet_rotating_schedule,
                    fleet_topology, make_fleet_mixer)
from .gossip import apply_mixer, make_mixer
from .mixing import (Topology, TopologySchedule, make_schedule,
                     make_topology, mixing_rate, spectral_gap)
from .subgrad import SubgradState, subgrad_init, subgrad_step
from .porter import (PorterConfig, PorterState, average_params,
                     consensus_error, make_porter_step, porter_init,
                     porter_step)
from .privacy import MomentsAccountant, calibrate_sigma, ldp_epsilon, phi_m
from .registry import (Algorithm, AlgorithmInfo, algorithm_info,
                       list_algorithms, register_algorithm)
from .wire_formats import WireFormat, make_wire_format

__all__ = [
    "baselines", "beer", "clip21", "clipping", "comm_round", "compression",
    "fleet", "gossip", "mixing", "porter", "privacy", "registry", "subgrad",
    "wire_formats",
    "WireFormat", "make_wire_format",
    "Clip21State", "clip21_init", "clip21_step", "clip21_update",
    "SubgradState", "subgrad_init", "subgrad_step",
    "FLEET_DENSE_GATE", "FleetTopology", "FleetSchedule", "fleet_topology",
    "fleet_rotating_schedule", "fleet_er_schedule", "make_fleet_mixer",
    "CommRound", "resolve_engine", "Compressor", "make_compressor",
    "Topology", "TopologySchedule", "make_topology", "make_schedule",
    "spectral_gap", "apply_mixer",
    "mixing_rate", "PorterConfig", "PorterState", "porter_init", "porter_step",
    "make_porter_step", "average_params", "consensus_error",
    "MomentsAccountant", "calibrate_sigma", "ldp_epsilon", "phi_m",
    "make_mixer", "smooth_clip", "piecewise_clip", "tree_clip",
    "tree_global_norm",
    "Algorithm", "AlgorithmInfo", "algorithm_info", "list_algorithms",
    "register_algorithm",
]
