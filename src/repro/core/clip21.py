"""Clip21-style error-feedback clipping (arXiv 2305.18929), decentralized.

Plain clipping biases the update whenever gradients exceed tau -- the
clipped-off mass is simply lost, and PORTER's Theorems pay for it with a
neighbourhood term.  Clip21 removes the bias *asymptotically* by clipping
the **residual** against a per-agent running estimate instead of the
gradient itself (EF21 with Clip in place of the compressor):

    delta_i^t = g_i^t - hat g_i^{t-1}
    hat g_i^t = hat g_i^{t-1} + Clip_tau(delta_i^t)

Once the iterates stabilize, ||delta|| falls below tau and the estimate
tracks the true gradient *exactly* -- each application contracts the
residual by at least tau in norm (:func:`clip21_update`; the hypothesis
suite pins both contraction inequalities).

Decentralized composition: ``hat g^t`` simply replaces the gradient oracle
of PORTER's Algorithm 1 -- the tracking/consensus comm rounds (lines
11-14) are untouched, making this a thin CommRound client.  The step
re-runs porter's *unclipped* gradient oracle with the identical key
schedule and hands ``(losses, hat g)`` to :func:`repro.core.porter
.porter_step` via ``grad_override``; with tau = inf the clip factor is
exactly 1.0, ``hat g = g`` bitwise, and the whole step is **bit-exact**
against porter-gc with a piecewise clip at tau = inf (pinned by
tests/test_fleet.py).

Clipping is piecewise (min(1, tau/||delta||), paper Remark 1): the smooth
surrogate tau/(tau+||delta||) never reaches factor 1, so the EF estimate
would never lock on (and tau = inf would be 0*inf = NaN).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import clipping
from .comm_round import CommRound
from .compression import Compressor
from .gossip import MixFn
from .porter import (PorterConfig, PorterState, _agent_gradient, porter_init,
                     porter_step)

__all__ = [
    "Clip21State",
    "clip21_update",
    "clip21_init",
    "clip21_step",
]


class Clip21State(NamedTuple):
    base: PorterState   # porter's x/v/EF planes, incl. the round counter
    g_est: Any          # hat g: per-agent EF gradient estimate


def clip21_update(g_est: Any, g_raw: Any, tau: float) -> Any:
    """One agent's EF-clip: ``g_est + Clip_tau(g_raw - g_est)``.

    Piecewise factor f = min(1, tau/||delta||).  Written as a ``where`` on
    f >= 1 rather than ``g_est + f*delta`` so the locked-on branch returns
    ``g_raw`` *bitwise* (a + 1.0*(b - a) only approximates b in floats);
    tau = inf therefore reduces to the identity on the raw gradient.

    Contraction (the Clip21 descent ingredient, pinned by hypothesis):
    the new residual r' = g_raw - g_est' satisfies both
    ``||r'|| <= ||r||`` and ``||r'|| <= max(||r|| - tau, 0)``.
    """
    delta = jax.tree_util.tree_map(lambda a, b: a - b, g_raw, g_est)
    factor = clipping.clip_factor(clipping.tree_global_norm(delta), tau,
                                  "piecewise")
    return jax.tree_util.tree_map(
        lambda ge, gr, d: jnp.where(factor >= 1.0, gr,
                                    (ge + factor * d).astype(gr.dtype)),
        g_est, g_raw, delta)


def clip21_init(params: Any, n_agents: int, w=None,
                buffer_dtype: Any = jnp.float32,
                plane_dtype: Any = None) -> Clip21State:
    """hat g^0 = 0: the first round clips the full gradient (as in the
    paper), and porter's own planes initialize exactly as porter-gc's."""
    base = porter_init(params, n_agents, w=w, buffer_dtype=buffer_dtype,
                       plane_dtype=plane_dtype)
    g_est = jax.tree_util.tree_map(
        lambda l: jnp.zeros(l.shape, jnp.float32), base.x)
    return Clip21State(base=base, g_est=g_est)


def clip21_step(
    cfg: PorterConfig,
    loss_fn,
    mixer: Optional[MixFn],
    compressor: Optional[Compressor],
    state: Clip21State,
    batch: Any,
    key: jax.Array,
    compress_fn=None,
    engine: Optional[CommRound] = None,
) -> Tuple[Clip21State, Dict[str, jax.Array]]:
    """One Clip21 iteration: EF-clipped oracle + porter comm rounds.

    ``cfg.tau`` is the residual clip threshold; the raw gradient is never
    clipped (variant forced to 'beer' for the oracle call).  The key is
    split exactly as porter_step splits it, so the gradient batch noise
    and both comm-round streams coincide with porter-gc's.
    """
    n = jax.tree_util.tree_leaves(state.base.x)[0].shape[0]
    _, k_noise, _, _ = jax.random.split(key, 4)
    agent_keys = jax.random.split(k_noise, n)
    raw_cfg = dataclasses.replace(cfg, variant="beer")
    grad_fn = functools.partial(_agent_gradient, raw_cfg, loss_fn)
    losses, g_raw = jax.vmap(grad_fn)(state.base.x, batch, agent_keys)

    g_est = jax.vmap(lambda ge, gr: clip21_update(ge, gr, cfg.tau))(
        state.g_est, g_raw)

    base, metrics = porter_step(cfg, loss_fn, mixer, compressor, state.base, batch,
                                key, compress_fn=compress_fn, engine=engine,
                                grad_override=(losses, g_est))
    resid = jax.tree_util.tree_map(lambda a, b: a - b, g_raw, g_est)
    metrics["clip_residual"] = (clipping.tree_global_norm(resid)
                                / jnp.sqrt(jnp.float32(n)))
    return Clip21State(base=base, g_est=g_est), metrics
