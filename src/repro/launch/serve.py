"""Batched decode (serving) driver: prefill a prompt batch, then greedy-decode
N tokens with the per-family cache machinery.  On CPU this exercises reduced
configs; the cache/step code is identical to the dry-run's serve_step.

    PYTHONPATH=src python -m repro.launch.serve --arch zamba2-7b --smoke \
        --prompt-len 32 --gen 16 --batch 2
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, get_smoke
from repro.models import build_model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args(argv)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, remat=False)
    bundle = build_model(cfg)
    params, _ = bundle.init(jax.random.PRNGKey(0))
    key = jax.random.PRNGKey(1)
    b, s = args.batch, args.prompt_len

    if cfg.family == "vlm":
        batch = {"tokens": jax.random.randint(key, (b, s - cfg.n_prefix), 0,
                                              cfg.vocab),
                 "patches": jax.random.normal(key, (b, cfg.n_prefix,
                                                    cfg.frontend_dim))}
    elif cfg.family == "encdec":
        batch = {"frames": jax.random.normal(key, (b, s, cfg.frontend_dim)),
                 "tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}
    else:
        batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab)}

    t0 = time.time()
    logits, cache = jax.jit(bundle.prefill)(params, batch)
    # grow attention caches so `gen` decode writes fit
    total = s + args.gen

    def grow(leaf):
        if leaf.ndim >= 3 and leaf.shape[2] == s:
            pad = [(0, 0)] * leaf.ndim
            pad[2] = (0, args.gen)
            return jnp.pad(leaf, pad)
        return leaf

    if cfg.family in ("dense", "moe", "vlm", "hybrid"):
        cache = jax.tree_util.tree_map(grow, cache)
    print(f"[prefill] {cfg.name} batch={b} prompt={s}: "
          f"{time.time()-t0:.2f}s, last-token logits {logits.shape}")

    decode = jax.jit(bundle.decode_step)
    tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)[:, None]
    outs = [tok]
    t1 = time.time()
    for i in range(args.gen):
        pos = jnp.asarray(s + i, jnp.int32)
        logits_d, cache = decode(params, cache, tok, pos)
        tok = jnp.argmax(logits_d, axis=-1).astype(jnp.int32)[:, None]
        outs.append(tok)
    dt = time.time() - t1
    gen = jnp.concatenate(outs, axis=1)
    print(f"[decode] {args.gen} tokens x {b} seqs in {dt:.2f}s "
          f"({args.gen*b/max(dt,1e-9):.1f} tok/s)")
    print("[sample ids]", np.asarray(gen[0])[:16].tolist())
    assert bool(jnp.all((gen >= 0) & (gen < cfg.vocab)))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
