"""Reproduce the full artifacts/dryrun set used by EXPERIMENTS.md with one
command (baselines on both meshes + optimized sweeps + every SPerf
iteration tag).  This is the provenance script for the roofline/perf tables.

    PYTHONPATH=src python -m repro.launch.sweep             # everything (~1.5h on 1 CPU)
    PYTHONPATH=src python -m repro.launch.sweep --only perf # just the SPerf ladders

The ensure_host_device_count call below must run before any jax-importing
import (jax locks the device count at first backend init); it appends to
any user-provided XLA_FLAGS instead of clobbering them, and defers to a
caller-chosen device count if one is already set (repro/_env.py).
"""

from repro._env import ensure_host_device_count

ensure_host_device_count(512)

import argparse
from pathlib import Path

from repro.configs import ARCHS
from repro.launch import shapes as SH
from repro.launch.dryrun import run_one

OUT = Path("artifacts/dryrun")

# (arch, shape, multi_pod, kwargs, tag)
PERF_LADDERS = [
    # Perf-1: rwkv6-7b x train_4k
    ("rwkv6-7b", "train_4k", False, {}, ""),
    ("rwkv6-7b", "train_4k", False, dict(local_compress=True), "lc"),
    ("rwkv6-7b", "train_4k", False,
     dict(local_compress=True, gossip="ring"), "lc_ring"),
    ("rwkv6-7b", "train_4k", False,
     dict(local_compress=True, gossip="ring", buffer_dtype="bf16",
          plane_dtype="bf16"), "lc_ring_bf16"),
    ("rwkv6-7b", "train_4k", False,
     dict(local_compress=True, gossip="packed"), "lc_packed"),
    # Perf-2: minicpm3-4b x prefill_32k
    ("minicpm3-4b", "prefill_32k", False, {}, ""),
    ("minicpm3-4b", "prefill_32k", False, dict(q_chunk=512), "qc512"),
    ("minicpm3-4b", "prefill_32k", False, dict(q_chunk=1024), "qc1024"),
    ("minicpm3-4b", "prefill_32k", False, dict(q_chunk=2048), "qc2048"),
    ("minicpm3-4b", "prefill_32k", False, dict(q_chunk=4096), "qc4096"),
    # Perf-3: arctic-480b x train_4k
    ("arctic-480b", "train_4k", False, {}, ""),
    ("arctic-480b", "train_4k", False, dict(local_compress=True), "lc"),
    ("arctic-480b", "train_4k", False,
     dict(local_compress=True, gossip="ring"), "lc_ring"),
    ("arctic-480b", "train_4k", False,
     dict(local_compress=True, gossip="packed"), "lc_packed"),
    ("arctic-480b", "train_4k", False,
     dict(local_compress=True, buffer_dtype="bf16"), "lc_bf16"),
    ("arctic-480b", "train_4k", False,
     dict(local_compress=True, capacity=1.0), "lc_cap1"),
    # Perf-4: serving levers
    ("grok-1-314b", "decode_32k", False, dict(fsdp=True), "fsdp"),
    ("grok-1-314b", "decode_32k", False,
     dict(fsdp=True, cache_dtype="int8"), "fsdp_int8"),
    ("zamba2-7b", "decode_32k", False, dict(cache_dtype="int8"), "int8"),
    # PORTER-DP at scale
    ("tinyllama-1.1b", "train_4k", False,
     dict(variant="dp", local_compress=True), "dp"),
    # SPerf-5: per-shard planes -- the fused pallas engine on the
    # tensor-parallel mesh, vs the same rung on the 'ref' backend above
    ("rwkv6-7b", "train_4k", False,
     dict(local_compress=True, gossip="ring", comm_backend="pallas"),
     "lc_ring_pallas"),
    ("rwkv6-7b", "train_4k", False,
     dict(local_compress=True, gossip="packed", comm_backend="pallas"),
     "lc_packed_pallas"),
    ("arctic-480b", "train_4k", False,
     dict(local_compress=True, gossip="ring", comm_backend="pallas"),
     "lc_ring_pallas"),
    # SPerf-6: the scan-fused chunk runner -- 8 comm rounds in one
    # executable (donated state, on-device batch synthesis) vs the
    # per-round lc_ring rung above
    ("rwkv6-7b", "train_4k", False,
     dict(local_compress=True, gossip="ring", chunk=8), "lc_ring_chunk8"),
    # Churn: time-varying topology schedules through the same chunked
    # program -- the W_t table is a traced gather, so these lower the same
    # single executable as their static rungs.  The ring rung rotates band
    # weights (the shift structure stays static); the dropout rung models
    # agent churn on the 16-agent data axis.
    ("rwkv6-7b", "train_4k", False,
     dict(local_compress=True, gossip="ring",
          topology_schedule="rotate:ring/metropolis+ring/lazy", chunk=8),
     "lc_ring_sched_chunk8"),
    ("rwkv6-7b", "train_4k", False,
     dict(local_compress=True,
          topology_schedule="dropout:rate=0.1,period=8", chunk=8),
     "lc_churn_chunk8"),
    # SPerf-7: bit-packed wire formats -- the gossip collectives ship the
    # compact (bf16 value, uint16 index) / uint32-word buffers from
    # core/wire_formats instead of dense f32 planes.  local_compress stays
    # set for rung-name continuity, but the codec subsumes it (selection
    # happens per model shard inside the codec executor); the overlap rung
    # additionally issues both comm rounds' collectives before either fused
    # update (bit-exact to sequential).
    ("rwkv6-7b", "train_4k", False,
     dict(local_compress=True, gossip="packed", wire="packed_bits"),
     "lc_packed_bits"),
    ("rwkv6-7b", "train_4k", False,
     dict(local_compress=True, gossip="ring", wire="packed_bits"),
     "lc_ring_bits"),
    ("rwkv6-7b", "train_4k", False,
     dict(local_compress=True, gossip="ring", wire="packed_bits",
          overlap=True), "lc_ring_bits_ovl"),
    ("arctic-480b", "train_4k", False,
     dict(local_compress=True, gossip="packed", wire="packed_bits"),
     "lc_packed_bits"),
    # SPerf-8: directed graphs / push-sum (dp-csgp) -- column-stochastic
    # W_t with the weight plane riding inside the existing collectives
    # (an extra flat column for dense/ring, +4 bitcast bytes under
    # packed_bits), so these lower the same executables as their
    # doubly-stochastic counterparts with zero extra communication ops.
    ("rwkv6-7b", "train_4k", False,
     dict(variant="csgp", local_compress=True,
          topology_schedule="directed:one_way,rate=0.1,period=8", chunk=8),
     "csgp_oneway_chunk8"),
    ("rwkv6-7b", "train_4k", False,
     dict(variant="csgp", local_compress=True, gossip="ring",
          wire="packed_bits", topology_schedule="directed:ring_skips"),
     "csgp_ring_bits"),
    # SPerf-9: mixed-precision state planes + remat -- bf16 EF buffers
    # (stochastic-rounding writeback, f32 master params) halve both the
    # resident optimizer state and the dense-neighbor gossip wire; the
    # packed_bits rung shows the codec wire is already compact, so bf16
    # planes there buy memory only; the tinyllama rung checkpoints the
    # loss ('dots' policy) so the real-model stack trains with all eight
    # state buffers resident (see benchmarks/bench_memory.py).
    ("rwkv6-7b", "train_4k", False,
     dict(local_compress=True, gossip="ring", wire="packed_bits",
          plane_dtype="bf16"), "lc_packed_bits_bf16"),
    ("tinyllama-1.1b", "train_4k", False,
     dict(local_compress=True, gossip="ring", plane_dtype="bf16",
          remat_policy="dots", chunk=4), "lc_ring_bf16_remat"),
]


def _baselines(multi_pod: bool):
    for arch in ARCHS:
        for shape in SH.SHAPES:
            if SH.shape_applicable(arch, shape):
                yield (arch, shape, multi_pod, {}, "")


def _optimized():
    for arch in ARCHS:
        yield (arch, "train_4k", False,
               dict(local_compress=True, gossip="ring"), "opt_train")
        yield (arch, "train_4k", True,
               dict(local_compress=True, gossip="ring"), "opt_train")
        yield (arch, "prefill_32k", False, dict(q_chunk=1024), "opt_prefill")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="all",
                    choices=["all", "baseline", "opt", "perf"])
    args = ap.parse_args()

    jobs = []
    if args.only in ("all", "baseline"):
        jobs += list(_baselines(False)) + list(_baselines(True))
    if args.only in ("all", "opt"):
        jobs += list(_optimized())
    if args.only in ("all", "perf"):
        jobs += PERF_LADDERS

    n_ok = 0
    for arch, shape, mp, kw, tag in jobs:
        kwargs = dict(variant=kw.pop("variant", "gc"),
                      gossip=kw.pop("gossip", "dense"))
        rec = run_one(arch, shape, mp, kwargs["variant"], kwargs["gossip"],
                      OUT, tag=tag, **kw)
        n_ok += rec["ok"]
    print(f"\n{n_ok}/{len(jobs)} sweep jobs ok")
    return 0 if n_ok == len(jobs) else 1


if __name__ == "__main__":
    raise SystemExit(main())
