"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module never touches
jax device state (jax locks the device count on first backend init, and the
smoke tests must see 1 CPU device while the dry-run sees 512 forced hosts).

Single pod : (data=16, model=16)            = 256 chips (v5e pod)
Multi-pod  : (pod=2, data=16, model=16)     = 512 chips

PORTER's decentralized agents live on the *agent axes*: ('data',) single-pod
(16 agents), ('pod','data') multi-pod (32 agents).  Tensor parallelism for
each agent's replica lives on 'model'.
"""

from __future__ import annotations

from typing import Tuple

import jax
import numpy as np

__all__ = ["make_production_mesh", "agent_axes", "n_agents", "HW"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def agent_axes(mesh) -> Tuple[str, ...]:
    return ("pod", "data") if "pod" in mesh.axis_names else ("data",)


def n_agents(mesh) -> int:
    return int(np.prod([mesh.shape[a] for a in agent_axes(mesh)]))


class HW:
    """TPU v5e hardware constants for the roofline analysis."""

    PEAK_FLOPS_BF16 = 197e12        # per chip
    HBM_BW = 819e9                  # bytes/s per chip
    ICI_BW = 50e9                   # bytes/s per link
    HBM_BYTES = 16 * 2**30          # 16 GiB per chip
