"""Launcher: production meshes, input specs, sharded step builders, dry-run.

NOTE: repro.launch.dryrun must be imported/run FIRST in its process (it sets
XLA_FLAGS before jax initializes); do not import it from here.
"""
from . import mesh, runtime, shapes, steps
from .mesh import HW, agent_axes, make_production_mesh, n_agents
from .runtime import BatchSource, make_runner, run_chunked

__all__ = ["mesh", "shapes", "steps", "runtime", "make_production_mesh",
           "agent_axes", "n_agents", "HW", "BatchSource", "make_runner",
           "run_chunked"]
