"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production meshes and extract roofline terms from the compiled artifact.

The ensure_host_device_count call below MUST stay ahead of any
jax-importing import: jax locks the device count at first backend init,
and the dry-run needs 512 placeholder host devices to build the (2,16,16)
mesh.  It appends to (never clobbers) user-provided XLA_FLAGS and defers
to a caller-chosen device count (repro/_env.py).  Do NOT set this flag
globally -- smoke tests and benchmarks see 1 device.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch rwkv6-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all            # single-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod

Artifacts: artifacts/dryrun/<arch>__<shape>__<mesh>[__tag].json with
memory/cost analysis, per-category collective bytes parsed from the
optimized HLO, and the three roofline terms (seconds, per device):

    compute    = HLO_FLOPs / 197e12           (bf16 peak, v5e)
    memory     = HLO_bytes / 819e9            (HBM bandwidth)
    collective = wire_bytes / 50e9            (ICI link bandwidth)

The compiled module is the per-device SPMD program, so all three terms are
per-chip without further division.
"""

from repro._env import ensure_host_device_count

ensure_host_device_count(512)

import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config
from repro.launch import shapes as SH
from repro.launch.mesh import HW, make_production_mesh
from repro.launch.steps import (build_prefill_step, build_serve_step,
                                build_train_step)

# the HLO parsing machinery's canonical home is the analysis subsystem;
# these re-exports keep the historical dryrun import sites working
from repro.analysis.hlo import (COLLECTIVES, WIRE_FACTOR,  # noqa: F401
                                _shape_bytes, check_census,
                                parse_collectives)


def count_params(shapes_tree, top_k: int = 2):
    """(total, active) parameter counts; MoE experts scaled by top_k/E."""
    total = active = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes_tree)[0]:
        n = int(np.prod(leaf.shape))
        total += n
        keys = [str(p.key) for p in path if hasattr(p, "key")]
        if any(k in ("w_gate", "w_in", "w_out") for k in keys) and \
                len(leaf.shape) >= 3 and "ffn" in keys:
            # expert-stacked weight (L, E, d, f) or (E, d, f)
            e = leaf.shape[-3]
            active += int(n * min(top_k, e) / e)
        else:
            active += n
    return total, active


def model_flops(cfg, shape, params_shapes, kind: str) -> float:
    total, active = count_params(params_shapes, cfg.top_k)
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * active * tokens
    return 2.0 * active * shape.global_batch  # decode: one token per seq


class _ChunkedLower:
    """Adapter: lower the chunked runner in place of the one-step setup."""

    def __init__(self, runner, setup):
        self.runner = runner
        self.setup = setup

    @property
    def algorithm(self):
        return self.setup.algorithm

    def lower(self):
        return self.runner.lower(self.setup.state_shapes,
                                 self.setup.key_shape)


def run_one(arch: str, shape_name: str, multi_pod: bool, variant: str,
            gossip: str, out_dir: Path, tag: str = "", fsdp: bool = False,
            compressor: str = "block_top_k", remat: bool = True,
            remat_policy: str = None,
            local_compress: bool = False, buffer_dtype="f32",
            plane_dtype: str = None,
            q_chunk=None, capacity: float = None, cache_dtype="bf16",
            topology: str = "ring", topology_schedule: str = None,
            comm_backend: str = "auto", chunk: int = None,
            wire: str = "dense", overlap: bool = False,
            analyze: bool = False):
    shape = SH.SHAPES[shape_name]
    cfg = get_config(arch)
    if capacity is not None:
        cfg = dataclasses.replace(cfg, capacity_factor=capacity)
    mesh = make_production_mesh(multi_pod=multi_pod)
    mesh_name = "pod2x16x16" if multi_pod else "pod16x16"
    rec = {
        "arch": arch, "shape": shape_name, "mesh": mesh_name,
        "kind": shape.kind, "variant": variant, "gossip": gossip,
        "tag": tag, "ok": False,
    }
    t0 = time.time()
    try:
        if shape.kind == "train":
            setup = build_train_step(
                cfg, mesh, shape, variant=variant, gossip_mode=gossip,
                compressor_name=compressor, remat=remat,
                remat_policy=remat_policy,
                local_compress=local_compress,
                topology_kind=topology,
                topology_schedule=topology_schedule,
                comm_backend=comm_backend,
                wire=wire, overlap=overlap,
                buffer_dtype=jnp.bfloat16 if buffer_dtype == "bf16"
                else jnp.float32,
                plane_dtype=plane_dtype)
            if topology_schedule:
                rec["topology_schedule"] = topology_schedule
            if wire != "dense":
                rec["wire"] = wire
            if overlap:
                rec["overlap"] = True
            if plane_dtype:
                rec["plane_dtype"] = plane_dtype
            if remat_policy:
                rec["remat_policy"] = remat_policy
            params_shapes = setup.state_shapes.x
            if chunk:
                # scan-fused chunk runner: one executable covering `chunk`
                # comm rounds with donated state and on-device batches;
                # the roofline terms below then describe a whole chunk
                from repro.data import batch_source
                from repro.launch.runtime import make_runner
                src = batch_source(setup.cfg, setup.n_agents,
                                   shape.global_batch // setup.n_agents,
                                   shape.seq_len)
                runner = make_runner(setup.algorithm, src, chunk,
                                     state_sharding=setup.state_shardings,
                                     batch_sharding=setup.batch_shardings)
                rec["chunk"] = chunk
                setup = _ChunkedLower(runner, setup)
        elif shape.kind == "prefill":
            setup = build_prefill_step(cfg, mesh, shape, fsdp=fsdp,
                                       q_chunk=q_chunk)
            params_shapes = setup.arg_shapes[0]
        else:
            setup = build_serve_step(
                cfg, mesh, shape, fsdp=fsdp,
                cache_dtype=jnp.int8 if cache_dtype == "int8"
                else jnp.bfloat16)
            params_shapes = setup.arg_shapes[0]

        lowered = setup.lower()
        rec["lower_s"] = round(time.time() - t0, 2)
        t1 = time.time()
        compiled = lowered.compile()
        rec["compile_s"] = round(time.time() - t1, 2)

        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # older jax: one dict per device
            ca = ca[0] if ca else {}
        flops = float(ca.get("flops", 0.0))
        bytes_acc = float(ca.get("bytes accessed", 0.0))
        rec["cost_analysis"] = {"flops": flops, "bytes_accessed": bytes_acc}

        try:
            ma = compiled.memory_analysis()
            rec["memory_analysis"] = {
                k: int(getattr(ma, k))
                for k in ("argument_size_in_bytes", "output_size_in_bytes",
                          "temp_size_in_bytes", "generated_code_size_in_bytes",
                          "alias_size_in_bytes")
                if hasattr(ma, k)
            } if ma is not None else None
        except Exception:
            rec["memory_analysis"] = None

        hlo = compiled.as_text()
        coll = parse_collectives(hlo)
        rec["collectives"] = coll
        wire = sum(WIRE_FACTOR[c] * v["bytes"] for c, v in coll.items())
        rec["hlo_ops"] = {"lines": hlo.count("\n")}

        if analyze and shape.kind == "train":
            # the analyzer's collective census: measured counts vs. the
            # gossip executor's declared budget x leaves x comm rounds
            # (x chunk when the executable covers a whole chunk)
            algo = setup.algorithm
            budget = (getattr(algo.mixer, "budget", None)
                      if algo.mixer is not None else None)
            n_leaves = len(jax.tree_util.tree_leaves(params_shapes))
            rounds = algo.info.comm_rounds * (rec.get("chunk") or 1)
            # the partitioner rule only holds on agent-axes-only meshes;
            # the production meshes shard the model axis, where GSPMD
            # gathering weights for the matmuls is the whole point
            rec["census"] = check_census(
                hlo, budget=budget, n_leaves=n_leaves,
                comm_rounds=rounds, meshed=True,
                spmd_rule="model" not in mesh.shape).to_json()

        mf = model_flops(cfg, shape, params_shapes, shape.kind)
        if rec.get("chunk"):
            # the compiled program covers `chunk` comm rounds; put the
            # useful-flops numerator on the same basis so the ratio is
            # comparable with the per-round rungs
            mf *= rec["chunk"]
        n_chips = int(np.prod(list(mesh.shape.values())))
        total_p, active_p = count_params(params_shapes, cfg.top_k)
        rec["params_total"] = total_p
        rec["params_active"] = active_p

        compute_t = flops / HW.PEAK_FLOPS_BF16
        memory_t = bytes_acc / HW.HBM_BW
        coll_t = wire / HW.ICI_BW
        dominant = max(
            (("compute", compute_t), ("memory", memory_t),
             ("collective", coll_t)), key=lambda kv: kv[1])[0]
        rec["roofline"] = {
            "compute_s": compute_t, "memory_s": memory_t,
            "collective_s": coll_t, "dominant": dominant,
            "model_flops_global": mf,
            "hlo_flops_per_chip": flops,
            "useful_flops_ratio": (mf / n_chips) / flops if flops else None,
            "n_chips": n_chips,
            "wire_bytes_per_chip": wire,
        }
        rec["ok"] = True
    except Exception as e:  # record the failure, keep sweeping
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-4000:]
    rec["total_s"] = round(time.time() - t0, 2)

    out_dir.mkdir(parents=True, exist_ok=True)
    suffix = f"__{tag}" if tag else ""
    fname = out_dir / f"{arch}__{shape_name}__{mesh_name}{suffix}.json"
    fname.write_text(json.dumps(rec, indent=2))
    status = "ok" if rec["ok"] else "FAIL"
    if analyze and "census" in rec:
        # --analyze replaces the raw cost-analysis roofline with the
        # analyzer's collective-census report
        cen = rec["census"]
        counts = {c: v for c, v in cen["counts"].items() if v}
        bound = cen.get("bound")
        verdict = ("within-budget" if cen["ok"] and cen["enforced"]
                   else "report-only" if not cen["enforced"]
                   else "OVER-BUDGET")
        print(f"[{status}] {arch:>20s} {shape_name:>12s} {mesh_name:>10s} "
              f"census[{cen.get('executor') or 'no-gossip'}] {verdict} "
              f"counts={counts or 0} bound={bound}", flush=True)
        for v in cen["violations"]:
            print("    census:", v, flush=True)
    else:
        r = rec.get("roofline", {})
        print(f"[{status}] {arch:>20s} {shape_name:>12s} {mesh_name:>10s} "
              f"lower={rec.get('lower_s', '-')}s "
              f"compile={rec.get('compile_s', '-')}s "
              f"dom={r.get('dominant', '-')} "
              f"c/m/x={r.get('compute_s', 0):.3g}/"
              f"{r.get('memory_s', 0):.3g}/"
              f"{r.get('collective_s', 0):.3g}s",
              flush=True)
    if not rec["ok"]:
        print("   ", rec["error"], flush=True)
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, help="architecture id or 'all'")
    ap.add_argument("--shape", default=None, help="input shape name or 'all'")
    ap.add_argument("--all", action="store_true",
                    help="sweep all (arch x shape)")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--variant", default="gc",
                    choices=["gc", "dp", "beer", "csgp"],
                    help="algorithm alias (repro.api.VARIANT_TO_ALGO); "
                         "'csgp' is push-sum DP-CSGP -- pair it with a "
                         "'directed:...' --topology-schedule")
    ap.add_argument("--gossip", default="dense",
                    choices=["dense", "ring", "packed"])
    ap.add_argument("--compressor", default="block_top_k")
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--fsdp", action="store_true",
                    help="FSDP the serving params over the data axis")
    ap.add_argument("--local-compress", action="store_true",
                    help="shard-local compression (no resharding gathers)")
    ap.add_argument("--buffer-dtype", default="f32", choices=["f32", "bf16"])
    ap.add_argument("--plane-dtype", default=None, choices=["f32", "bf16"],
                    help="EF state-plane storage dtype: 'bf16' halves the "
                         "six non-master state buffers and the gossip wire "
                         "(stochastic-rounding writeback; master params "
                         "stay f32)")
    ap.add_argument("--remat-policy", default=None,
                    choices=["full", "dots"],
                    help="jax.checkpoint policy around the loss/grad for "
                         "train shapes ('full' recomputes everything, "
                         "'dots' keeps matmul outputs)")
    ap.add_argument("--q-chunk", type=int, default=None,
                    help="chunked-query attention block for prefill")
    ap.add_argument("--capacity", type=float, default=None,
                    help="MoE capacity factor override (default 1.25)")
    ap.add_argument("--cache-dtype", default="bf16",
                    choices=["bf16", "int8"],
                    help="decode KV/state cache dtype")
    ap.add_argument("--topology", default="ring",
                    help="agent graph for train shapes (ring, exponential, "
                         "hypercube, erdos_renyi, complete, torus)")
    ap.add_argument("--topology-schedule", default=None,
                    help="time-varying topology spec for train shapes "
                         "(e.g. 'dropout:rate=0.2,period=8'); the W_t "
                         "table is a traced gather, so the lowered "
                         "program is schedule-periodic-free (one "
                         "executable)")
    ap.add_argument("--comm-backend", default="auto",
                    choices=["auto", "ref", "pallas"],
                    help="comm-round engine backend (pallas packs per-shard "
                         "planes under model-sharded layouts)")
    ap.add_argument("--wire", default="dense",
                    choices=["dense", "packed_bits"],
                    help="wire format for train shapes: 'packed_bits' ships "
                         "the bit-packed buffers from core/wire_formats "
                         "(bf16+uint16 top-k segments, uint32 QSGD words) "
                         "instead of dense f32 planes")
    ap.add_argument("--overlap", action="store_true",
                    help="issue both comm rounds' collectives before either "
                         "fused update (bit-exact comm/compute overlap)")
    ap.add_argument("--chunk", type=int, default=None,
                    help="lower the scan-fused chunk runner over N comm "
                         "rounds (train shapes; one executable, donated "
                         "state, on-device batch synthesis)")
    ap.add_argument("--analyze", action="store_true",
                    help="replace the cost-analysis roofline printout with "
                         "the analyzer's collective-census report (counts "
                         "vs. the gossip executor's declared budget; see "
                         "python -m repro.analysis)")
    ap.add_argument("--tag", default="")
    ap.add_argument("--out", default="artifacts/dryrun")
    args = ap.parse_args()

    out_dir = Path(args.out)
    # explicit --arch/--shape override --all
    archs = [args.arch] if args.arch not in (None, "all") else ARCHS
    shapes = [args.shape] if args.shape not in (None, "all") \
        else list(SH.SHAPES)

    results = []
    for arch in archs:
        for shape_name in shapes:
            if not SH.shape_applicable(arch, shape_name):
                print(f"[skip] {arch} {shape_name} (full attention; "
                      f"see DESIGN.md)", flush=True)
                continue
            results.append(run_one(
                arch, shape_name, args.multi_pod, args.variant, args.gossip,
                out_dir, tag=args.tag, fsdp=args.fsdp,
                compressor=args.compressor, remat=not args.no_remat,
                remat_policy=args.remat_policy,
                local_compress=args.local_compress,
                buffer_dtype=args.buffer_dtype,
                plane_dtype=args.plane_dtype, q_chunk=args.q_chunk,
                capacity=args.capacity, cache_dtype=args.cache_dtype,
                topology=args.topology,
                topology_schedule=args.topology_schedule,
                comm_backend=args.comm_backend,
                chunk=args.chunk, wire=args.wire, overlap=args.overlap,
                analyze=args.analyze))
    n_ok = sum(r["ok"] for r in results)
    print(f"\n{n_ok}/{len(results)} combinations lowered+compiled OK")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
