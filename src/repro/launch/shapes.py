"""Input-shape registry and ShapeDtypeStruct builders for every
(architecture x input shape) combination, plus PartitionSpec assignment for
batches, parameters and decode caches.

The four assigned shapes:

    train_4k       seq=4096    global_batch=256   train_step (PORTER)
    prefill_32k    seq=32768   global_batch=32    prefill
    decode_32k     seq=32768   global_batch=128   serve_step (1 new token)
    long_500k      seq=524288  global_batch=1     serve_step, sub-quadratic only

long_500k applicability (see DESIGN.md): rwkv6-7b (SSM), h2o-danube-3-4b
(sliding window), zamba2-7b (hybrid; shared attention runs a 4096 window for
this shape).  The six pure full-attention archs skip it.

Encoder-decoder split: seamless uses S_enc = S_dec = seq/2 for train/prefill
and enc_len = min(seq, 4096) for decode shapes.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.models import ModelConfig

__all__ = ["ShapeSpec", "SHAPES", "LONG_CONTEXT_ARCHS", "shape_applicable",
           "train_batch_specs", "serve_token_specs", "cache_pspecs",
           "decode_window"]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # 'train' | 'prefill' | 'decode'


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

LONG_CONTEXT_ARCHS = ("rwkv6-7b", "h2o-danube-3-4b", "zamba2-7b")


def shape_applicable(arch: str, shape: str) -> bool:
    if shape == "long_500k":
        return arch in LONG_CONTEXT_ARCHS
    return True


def decode_window(cfg: ModelConfig, shape: ShapeSpec) -> Optional[int]:
    """Effective attention window for a decode shape (None = cfg default)."""
    if shape.name == "long_500k" and cfg.family == "hybrid":
        return 4096  # zamba2 shared attention runs windowed at 500k
    return "cfg"


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(tuple(shape), dtype)


# ---------------------------------------------------------------------------
# Train batches: leaves carry a leading agent axis.
# ---------------------------------------------------------------------------

def train_batch_specs(cfg: ModelConfig, shape: ShapeSpec, n_agents: int,
                      agent_axes: Tuple[str, ...]):
    """Returns (batch ShapeDtypeStructs, batch PartitionSpecs).

    Leaves: (n_agents, per_agent_batch, ...).
    """
    assert shape.kind == "train"
    b = shape.global_batch // n_agents
    s = shape.seq_len
    ax = agent_axes if len(agent_axes) > 1 else agent_axes[0]
    if cfg.family == "vlm":
        batch = {
            "tokens": _sds((n_agents, b, s - cfg.n_prefix), jnp.int32),
            "patches": _sds((n_agents, b, cfg.n_prefix, cfg.frontend_dim),
                            jnp.float32),
        }
        specs = {"tokens": P(ax, None, None),
                 "patches": P(ax, None, None, None)}
    elif cfg.family == "encdec":
        half = s // 2
        batch = {
            "frames": _sds((n_agents, b, half, cfg.frontend_dim),
                           jnp.float32),
            "tokens": _sds((n_agents, b, half), jnp.int32),
        }
        specs = {"frames": P(ax, None, None, None),
                 "tokens": P(ax, None, None)}
    else:
        batch = {"tokens": _sds((n_agents, b, s), jnp.int32)}
        specs = {"tokens": P(ax, None, None)}
    return batch, specs


# ---------------------------------------------------------------------------
# Inference batches.
# ---------------------------------------------------------------------------

def serve_token_specs(cfg: ModelConfig, shape: ShapeSpec,
                      batch_axes: Tuple[str, ...], n_batch_devices: int):
    """Prefill: full token batch.  Decode: (B, 1) next-token ids."""
    bsz = shape.global_batch
    ax = batch_axes if len(batch_axes) > 1 else batch_axes[0]
    b_ax = ax if bsz % n_batch_devices == 0 and bsz >= n_batch_devices else None
    if shape.kind == "prefill":
        s = shape.seq_len
        if cfg.family == "vlm":
            batch = {"tokens": _sds((bsz, s - cfg.n_prefix), jnp.int32),
                     "patches": _sds((bsz, cfg.n_prefix, cfg.frontend_dim),
                                     jnp.float32)}
            specs = {"tokens": P(b_ax, None), "patches": P(b_ax, None, None)}
        elif cfg.family == "encdec":
            half = s // 2
            batch = {"frames": _sds((bsz, half, cfg.frontend_dim),
                                    jnp.float32),
                     "tokens": _sds((bsz, half), jnp.int32)}
            specs = {"frames": P(b_ax, None, None), "tokens": P(b_ax, None)}
        else:
            batch = {"tokens": _sds((bsz, s), jnp.int32)}
            specs = {"tokens": P(b_ax, None)}
        return batch, specs
    # decode: one token per sequence
    return (_sds((bsz, 1), jnp.int32), P(b_ax, None))


# ---------------------------------------------------------------------------
# Decode-cache partition specs, assigned by leaf name + rank.
# ---------------------------------------------------------------------------

def cache_pspecs(cache_shapes, batch_axes: Tuple[str, ...],
                 n_batch_devices: int, model_axis: str = "model",
                 model_size: int = 16):
    """Build a PartitionSpec tree mirroring an (abstract) cache pytree.

    Conventions (leading L or G stack axis is never sharded):
      k/v/ckv/krope  (L,B,T,...) : B over batch axes when divisible, and the
                                   time axis over 'model' when divisible;
                                   when B is too small the time axis takes
                                   (batch_axes + model) combined.
      positions      (L,B,W)     : follow B.
      S (rwkv state) (L,B,H,N,N) : B over batch axes, H over 'model'.
      h (ssd state)  (L,B,H,P,N) : same.
      shift/conv     (L,B,...)   : B over batch axes, channels over 'model'.
    """
    ax = batch_axes if len(batch_axes) > 1 else batch_axes[0]

    def rule(path, leaf):
        name = None
        for entry in reversed(path):
            if isinstance(entry, jax.tree_util.DictKey):
                name = str(entry.key)
                break
        shape = leaf.shape
        b = shape[1] if len(shape) > 1 else 1
        b_ok = b % n_batch_devices == 0 and b >= n_batch_devices
        b_ax = ax if b_ok else None

        if name in ("k", "v") and len(shape) == 5:
            t = shape[2]
            if b_ok:
                t_ax = model_axis if t % model_size == 0 else None
            else:
                both = tuple(batch_axes) + (model_axis,)
                t_ax = both if t % (n_batch_devices * model_size) == 0 else (
                    model_axis if t % model_size == 0 else None)
            return P(None, b_ax, t_ax, None, None)
        if name == "ckv" or name == "krope":
            t = shape[2]
            t_ax = model_axis if t % model_size == 0 else None
            return P(None, b_ax, t_ax, None)
        if name == "positions":
            return P(None, b_ax, None)
        if name in ("S",) and len(shape) == 5:
            h = shape[2]
            h_ax = model_axis if h % model_size == 0 else None
            return P(None, b_ax, h_ax, None, None)
        if name == "h" and len(shape) == 5:
            h = shape[2]
            h_ax = model_axis if h % model_size == 0 else None
            return P(None, b_ax, h_ax, None, None)
        if name in ("shift_t", "shift_c") and len(shape) == 3:
            d = shape[2]
            return P(None, b_ax, model_axis if d % model_size == 0 else None)
        if name == "conv" and len(shape) == 4:
            c = shape[3]
            return P(None, b_ax, None,
                     model_axis if c % model_size == 0 else None)
        # fallback: replicate
        return P(*([None] * len(shape)))

    return jax.tree_util.tree_map_with_path(rule, cache_shapes)
