"""Step builders: jit-able, sharded train / prefill / serve steps for any
(architecture x input shape x mesh) combination.

``build_train_step`` wires the full PORTER stack around a model bundle:
agent-stacked parameters + EF/tracking buffers sharded over the agent axes,
tensor parallelism over 'model', gossip over the agent axes.

``build_prefill_step`` / ``build_serve_step`` wire the inference paths
(PORTER is a training-time algorithm; serving uses a single replica).

Everything here is *abstract-friendly*: shapes come from eval_shape, no
parameter is ever materialized, so grok-1-314b lowers on one CPU.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import api
from repro.core import PorterConfig
from repro.core.porter import PorterState
from repro.models import ModelBundle, ModelConfig, build_model
from repro.nn.module import prepend_axis_specs
from . import shapes as SH
from .mesh import agent_axes, n_agents

__all__ = ["abstract_init", "build_train_step", "build_prefill_step",
           "build_serve_step", "make_shard_local_compress", "TrainSetup",
           "ServeSetup"]


def make_shard_local_compress(comp, mesh: Mesh, leaf_specs):
    """Shard-local compression: run the compressor inside shard_map so top-k
    selection never crosses a shard boundary.

    The naive path (flatten leaf -> global blocks -> top-k) reshapes across
    the model-sharded dimension, which XLA SPMD can only implement by
    all-gathering the entire buffer over the model axis -- measured at
    ~930 GiB/step for rwkv6-7b train_4k (see EXPERIMENTS.md SPerf).  Applying
    the compressor per shard keeps selection local; per-shard top-k is block
    top-k with shard-sized blocks, still a valid rho-compressor
    (Definition 3), and composes with the packed wire format.

    Only deterministic compressors are supported (the paper's top-k family);
    randomized ones would need per-shard keys threaded through shard_map.
    """
    if not comp.deterministic:
        raise ValueError("shard-local compression needs a deterministic "
                         "compressor (top_k / block_top_k)")

    from repro.compat import shard_map

    def compress(key, tree):
        del key  # deterministic

        def run(t):
            return jax.tree_util.tree_map(lambda l: comp(None, l), t)

        fn = shard_map(run, mesh=mesh, in_specs=(leaf_specs,),
                       out_specs=leaf_specs, check_vma=False)
        return fn(tree)

    return compress


def abstract_init(bundle: ModelBundle, key=None):
    """(param ShapeDtypeStructs, PartitionSpecs) without materializing."""
    if key is None:
        key = jax.random.PRNGKey(0)
    box = {}

    def wrapper(k):
        values, specs = bundle.init(k)
        box["specs"] = specs  # static python objects, captured during trace
        return values

    shapes = jax.eval_shape(wrapper, key)
    return shapes, box["specs"]


def _shardings(mesh: Mesh, specs):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))


@dataclasses.dataclass
class TrainSetup:
    cfg: ModelConfig
    bundle: ModelBundle
    jitted: Any                  # jit(step)
    state_shapes: Any            # PorterState of ShapeDtypeStruct
    batch_shapes: Any
    state_shardings: Any
    batch_shardings: Any
    key_shape: Any
    n_agents: int
    porter_cfg: PorterConfig
    algorithm: Any = None        # the built repro.api Algorithm

    def lower(self):
        return self.jitted.lower(self.state_shapes, self.batch_shapes,
                                 self.key_shape)

    def init_state(self, key) -> PorterState:
        params, _ = self.bundle.init(key)
        return self.algorithm.init(params, n_agents=self.n_agents)


def _state_partition_specs(state_shapes, stacked_specs, ax_entry):
    """PartitionSpecs for any registered algorithm's state NamedTuple.

    Param-shaped buffer trees (x, v, the EF surrogates and mirrors) share
    the agent-stacked leaf specs; bare 1-D fields are the ``(n,)`` push-sum
    weight planes, sharded over the agent axes like any agent-stacked
    buffer; bare scalars (the step counter) replicate.  Deriving this from
    the state's own shape keeps one launch path for every state layout
    (PorterState, PorterAdamState, DpCsgpState, ...) instead of
    hand-writing a spec tuple per algorithm.
    """
    def field_spec(val):
        if hasattr(val, "shape"):
            if val.ndim == 0:
                return P()
            if val.ndim == 1:
                return P(ax_entry)
        return stacked_specs

    return type(state_shapes)(*[field_spec(v) for v in state_shapes])


def build_train_step(
    cfg: ModelConfig,
    mesh: Mesh,
    shape: SH.ShapeSpec,
    variant: str = "gc",
    gossip_mode: str = "dense",
    compressor_name: str = "block_top_k",
    frac: float = 0.05,
    topology_kind: str = "ring",
    topology_schedule: Optional[str] = None,
    tau: float = 1.0,
    sigma_p: float = 0.0,
    buffer_dtype=jnp.float32,
    plane_dtype=None,
    remat: bool = True,
    remat_policy: Optional[str] = None,
    local_compress: bool = False,
    comm_backend: str = "auto",
    wire: str = "dense",
    overlap: bool = False,
) -> TrainSetup:
    """PORTER train step, sharded for ``mesh``.

    Construction is delegated to the ``repro.api`` facade (one
    ExperimentSpec -> Algorithm build), which owns the paper's stable
    hyper-parameter choices: gamma = (1-alpha) * rho / 2, eta from O(1/L)
    heuristics (configurable by the caller for real runs; the dry-run only
    needs a lowerable program).

    topology_schedule: optional time-varying topology spec string (see
    ``repro.api.ExperimentSpec.topology_schedule``); the schedule table is
    indexed by the state's step counter inside the compiled program, so the
    chunked runner still lowers one executable per chunk size.

    comm_backend: backend of the comm-round engine -- 'auto' runs the fused
    ef_track/ef_step Pallas kernels on TPU and the jnp reference elsewhere;
    shard-local compression and the packed wire format compose with either
    (compression/mixing stay in the pytree domain, only the AXPY chain runs
    over the flat tile planes).  The stacked leaf specs built here flow
    through ``api.build`` into the engine, so with model-sharded parameter
    leaves the pallas path packs *per-shard planes* inside shard_map
    (kernels/flatten.py) -- no pack/unpack reshard, 'pallas' is safe on
    tensor-parallel layouts.

    wire: 'dense' ships f32 planes; 'packed_bits' ships the bit-packed
    buffers from ``repro.core.wire_formats`` (bf16+uint16 top-k segments or
    uint32 QSGD words).  Under packed_bits the wire codec runs *inside*
    shard_map, so selection is already per model shard -- it subsumes
    ``local_compress`` and the shard-local compressor is skipped (the
    ``lc_packed_bits`` sweep rung sets both; the engine would raise on the
    explicit compress_fn + codec combination).

    overlap: issue both comm rounds' collectives before either fused update
    (``CommRound(overlap=True)``); bit-exact to the sequential order.

    plane_dtype: storage dtype of the EF state planes ('bf16' halves the
    six non-master state buffers AND the gossip wire; master params stay
    f32 -- see ``repro.api.ExperimentSpec.plane_dtype``).

    remat_policy: jax.checkpoint policy around the loss/grad ('full' or
    'dots'); composes with the flax-level ``remat`` flag -- the model's
    internal remat decides *block* boundaries, this knob checkpoints the
    whole loss so eight agent-stacked state buffers fit beside the
    activations on the pod mesh.
    """
    cfg = dataclasses.replace(cfg, remat=remat)
    bundle = build_model(cfg)
    ax = agent_axes(mesh)
    n = n_agents(mesh)
    spec = api.ExperimentSpec(
        algo=api.VARIANT_TO_ALGO[variant],
        n_agents=n, topology=topology_kind, topology_weights="metropolis",
        topology_schedule=topology_schedule,
        compressor=compressor_name, frac=frac, gossip_mode=gossip_mode,
        comm_backend=comm_backend, wire=wire, overlap=overlap,
        eta=1e-3, tau=tau, sigma_p=sigma_p,
        buffer_dtype=buffer_dtype, plane_dtype=plane_dtype,
        remat_policy=remat_policy)

    # ---- abstract state & shardings ---------------------------------------
    params_shapes, pspecs = abstract_init(bundle)
    ax_entry = ax if len(ax) > 1 else ax[0]
    stacked_specs = prepend_axis_specs(pspecs, ax_entry)

    compress_fn = None
    if local_compress and wire == "dense":
        # packed_bits fuses (shard-local) selection into the wire codec;
        # building the explicit shard-local compressor too would make
        # api.build raise on the redundant combination.
        compress_fn = make_shard_local_compress(
            api.resolve_compressor(spec), mesh, stacked_specs)
    algo = api.build(spec, bundle.loss, mesh=mesh, agent_axes=ax,
                     leaf_specs=stacked_specs, compress_fn=compress_fn)
    pcfg = algo.config
    step = algo.step
    state_shapes = jax.eval_shape(
        lambda p: algo.init(p, n_agents=n, w=None), params_shapes)
    state_specs = _state_partition_specs(state_shapes, stacked_specs,
                                         ax_entry)
    batch_shapes, batch_specs = SH.train_batch_specs(cfg, shape, n, ax)

    state_sh = _shardings(mesh, state_specs)
    batch_sh = _shardings(mesh, batch_specs)
    repl = NamedSharding(mesh, P())
    metrics_sh = {k: repl for k in
                  ("loss", "consensus_x", "consensus_v", "v_norm",
                   "wire_bytes")}
    jitted = jax.jit(step,
                     in_shardings=(state_sh, batch_sh, repl),
                     out_shardings=(state_sh, metrics_sh))
    key_shape = jax.ShapeDtypeStruct((2,), jnp.uint32)
    return TrainSetup(cfg=cfg, bundle=bundle, jitted=jitted,
                      state_shapes=state_shapes, batch_shapes=batch_shapes,
                      state_shardings=state_sh, batch_shardings=batch_sh,
                      key_shape=key_shape, n_agents=n, porter_cfg=pcfg,
                      algorithm=algo)


# ---------------------------------------------------------------------------
# Inference
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ServeSetup:
    cfg: ModelConfig
    bundle: ModelBundle
    jitted: Any
    arg_shapes: Tuple
    param_shardings: Any

    def lower(self):
        return self.jitted.lower(*self.arg_shapes)


def _serve_param_specs(pspecs, fsdp_axis: Optional[str]):
    """Serving params: model-sharded; optionally FSDP over the data axis
    (beyond-paper memory optimization for big checkpoints)."""
    if fsdp_axis is None:
        return pspecs

    def add_fsdp(s: P) -> P:
        entries = list(tuple(s))
        for i, e in enumerate(entries):
            if e is None:
                entries[i] = fsdp_axis
                return P(*entries)
        return s

    return jax.tree_util.tree_map(add_fsdp, pspecs,
                                  is_leaf=lambda x: isinstance(x, P))


def build_prefill_step(cfg: ModelConfig, mesh: Mesh, shape: SH.ShapeSpec,
                       fsdp: bool = False, remat: bool = False,
                       q_chunk=None) -> ServeSetup:
    cfg = dataclasses.replace(cfg, remat=remat, q_chunk=q_chunk)
    bundle = build_model(cfg)
    ax = agent_axes(mesh)
    nb = n_agents(mesh)
    params_shapes, pspecs = abstract_init(bundle)
    pspecs = _serve_param_specs(pspecs, "data" if fsdp else None)
    batch_shapes, batch_specs = SH.serve_token_specs(cfg, shape, ax, nb)
    param_sh = _shardings(mesh, pspecs)
    batch_sh = _shardings(mesh, batch_specs)
    jitted = jax.jit(bundle.prefill, in_shardings=(param_sh, batch_sh))
    return ServeSetup(cfg=cfg, bundle=bundle, jitted=jitted,
                      arg_shapes=(params_shapes, batch_shapes),
                      param_shardings=param_sh)


def build_serve_step(cfg: ModelConfig, mesh: Mesh, shape: SH.ShapeSpec,
                     fsdp: bool = False,
                     cache_dtype=jnp.bfloat16) -> ServeSetup:
    """One-token decode step with a seq_len-deep cache (greedy sampling).

    cache_dtype: bf16 default.  int8 halves cache footprint/traffic of the
    (memory-bound) decode shapes; NOTE this configuration currently measures
    the *traffic/memory* effect only -- numerically-correct int8 caching
    additionally needs per-head quantization scales on write/read, which the
    cache layout does not carry yet (documented gap, EXPERIMENTS SPerf-4)."""
    cfg = dataclasses.replace(cfg, remat=False)
    bundle = build_model(cfg)
    ax = agent_axes(mesh)
    nb = n_agents(mesh)
    window = SH.decode_window(cfg, shape)
    model_size = mesh.shape["model"]

    def serve_step(params, cache, tokens, pos):
        logits, new_cache = bundle.decode_step(params, cache, tokens, pos,
                                               window=window)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return next_tok[:, None], new_cache

    params_shapes, pspecs = abstract_init(bundle)
    pspecs = _serve_param_specs(pspecs, "data" if fsdp else None)
    bsz = shape.global_batch
    enc_len = min(shape.seq_len, 4096) if cfg.family == "encdec" else None
    cache_shapes = jax.eval_shape(
        lambda: bundle.init_cache(bsz, shape.seq_len, dtype=cache_dtype,
                                  window=window, enc_len=enc_len))
    cache_specs = SH.cache_pspecs(cache_shapes, ax, nb,
                                  model_size=model_size)
    tok_shapes, tok_specs = SH.serve_token_specs(cfg, shape, ax, nb)

    param_sh = _shardings(mesh, pspecs)
    cache_sh = _shardings(mesh, cache_specs)
    tok_sh = _shardings(mesh, tok_specs)
    repl = NamedSharding(mesh, P())
    jitted = jax.jit(serve_step,
                     in_shardings=(param_sh, cache_sh, tok_sh, repl),
                     out_shardings=(tok_sh, cache_sh))
    pos_shape = jax.ShapeDtypeStruct((), jnp.int32)
    return ServeSetup(cfg=cfg, bundle=bundle, jitted=jitted,
                      arg_shapes=(params_shapes, cache_shapes, tok_shapes,
                                  pos_shape),
                      param_shardings=param_sh)
