"""Checkpointing for PORTER training state (orbax is not available offline).

Layout: one directory per step, one .npz per top-level PorterState buffer,
plus a JSON manifest with the treedef and step metadata.  Pytrees are
flattened with key-paths so restore is structure-checked; device arrays are
pulled to host as numpy.  Works for agent-stacked states of any size the
host can hold (per-agent sharded save on real pods would stream shard-wise;
the manifest format already records per-leaf shapes/dtypes to support that).

    save_state(dir, state, step=10)
    state = restore_state(dir, like=state)           # latest
    state = restore_state(dir, like=state, step=10)
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Optional

import jax
import numpy as np

from repro.core.porter import PorterState

__all__ = ["save_state", "restore_state", "latest_step"]

_BUFFERS = ("x", "v", "q_x", "q_v", "g_prev", "m_x", "m_v")


def _flatten(tree):
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out[key] = np.asarray(leaf)
    return out


def save_state(ckpt_dir: str, state: PorterState, step: Optional[int] = None):
    step = int(state.step) if step is None else step
    d = Path(ckpt_dir) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    manifest = {"step": step, "buffers": {}}
    for name in _BUFFERS:
        flat = _flatten(getattr(state, name))
        np.savez(d / f"{name}.npz", **flat)
        manifest["buffers"][name] = {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in flat.items()
        }
    (d / "manifest.json").write_text(json.dumps(manifest, indent=2))
    return str(d)


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in d.glob("step_*"))
    return steps[-1] if steps else None


def restore_state(ckpt_dir: str, like: PorterState,
                  step: Optional[int] = None) -> PorterState:
    """Restore into the structure of ``like`` (shape/dtype checked)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    new = {}
    for name in _BUFFERS:
        data = np.load(d / f"{name}.npz")
        ref = getattr(like, name)
        flat_ref = _flatten(ref)
        if set(data.files) != set(flat_ref):
            raise ValueError(f"checkpoint buffer {name} keys mismatch: "
                             f"{sorted(set(data.files) ^ set(flat_ref))[:5]}")
        leaves_ref, treedef = jax.tree_util.tree_flatten(ref)
        paths = [
            "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path)
            for path, _ in jax.tree_util.tree_flatten_with_path(ref)[0]
        ]
        leaves = []
        for path_key, ref_leaf in zip(paths, leaves_ref):
            arr = data[path_key]
            if tuple(arr.shape) != tuple(ref_leaf.shape):
                raise ValueError(f"{name}/{path_key}: shape {arr.shape} != "
                                 f"{ref_leaf.shape}")
            leaves.append(jax.numpy.asarray(arr, dtype=ref_leaf.dtype))
        new[name] = treedef.unflatten(leaves)
    return PorterState(step=jax.numpy.asarray(manifest["step"],
                                              jax.numpy.int32), **new)
