"""Checkpointing for decentralized training state (orbax is unavailable
offline).

Works for *any* registered algorithm's state -- every state in the repo is a
NamedTuple of pytree buffers (PorterState, ChocoState, SoteriaState,
PorterAdamState with its nested base, ...).  Layout: one directory per step,
one .npz per top-level state field, plus a JSON manifest recording the state
class, field list and per-leaf shapes/dtypes.  Pytrees are flattened with
key-paths so restore is structure-checked; device arrays are pulled to host
as numpy.  Per-agent sharded save on real pods would stream shard-wise; the
manifest format already records per-leaf shapes/dtypes to support that.

    save_state(dir, state, step=10, extra={"rounds_executed": 10})
    state = restore_state(dir, like=state)           # latest
    state = restore_state(dir, like=state, step=10)
    manifest = read_manifest(dir)                    # latest manifest dict

``like`` supplies both the structure and the NamedTuple class to
reconstruct, so the same two functions round-trip every algorithm the
registry knows about (tests/test_checkpoint.py).

``extra`` is free-form JSON metadata recorded in the manifest; the train
driver uses it for cumulative privacy accounting across resumes
(``rounds_executed``, ``sigma_p``, ...): the accountant must advance by
rounds actually *run*, not by the ``--steps`` target, and sigma must stay
at the value the already-spent rounds were calibrated with.
"""

from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Optional

import jax
import ml_dtypes
import numpy as np

__all__ = ["save_state", "restore_state", "latest_step", "read_manifest"]


def _to_numpy(leaf):
    """Host copy in an npz-native dtype.  bf16 planes (mixed-precision
    engines) are stored as their u16 bit pattern: numpy serializes
    ml_dtypes arrays as raw void records, which np.load cannot cast back
    -- the bitcast round-trips exactly and restore views it back through
    the reference leaf's dtype."""
    arr = np.asarray(leaf)
    if arr.dtype == ml_dtypes.bfloat16:
        return arr.view(np.uint16)
    return arr


def _flatten(tree):
    out = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        # a bare-array field has an empty path; npz keys cannot be empty
        out[key or "_root"] = _to_numpy(leaf)
    return out


def _leaf_paths(tree):
    return ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                     for p in path) or "_root"
            for path, _ in jax.tree_util.tree_flatten_with_path(tree)[0]]


def _state_fields(state) -> tuple:
    fields = getattr(state, "_fields", None)
    if fields is None:
        raise TypeError(f"expected a NamedTuple state, got "
                        f"{type(state).__name__}")
    return fields


def _state_step(state) -> int:
    """The iteration counter, wherever the state keeps it (PorterAdamState
    nests it inside its PORTER base)."""
    if hasattr(state, "step"):
        return int(state.step)  # analysis: ok -- host-side restore, state is concrete
    for name in _state_fields(state):
        v = getattr(state, name)
        if hasattr(v, "_fields"):
            try:
                return _state_step(v)
            except AttributeError:
                continue
    raise AttributeError(f"{type(state).__name__} carries no step counter")


def save_state(ckpt_dir: str, state: Any, step: Optional[int] = None,
               extra: Optional[dict] = None) -> str:
    step = _state_step(state) if step is None else step
    d = Path(ckpt_dir) / f"step_{step:08d}"
    d.mkdir(parents=True, exist_ok=True)
    manifest = {"step": step, "state_cls": type(state).__name__,
                "fields": list(_state_fields(state)),
                "extra": dict(extra) if extra else {}, "buffers": {}}
    for name in _state_fields(state):
        flat = _flatten(getattr(state, name))
        np.savez(d / f"{name}.npz", **flat)
        manifest["buffers"][name] = {
            k: {"shape": list(v.shape), "dtype": str(v.dtype)}
            for k, v in flat.items()
        }
    (d / "manifest.json").write_text(json.dumps(manifest, indent=2))
    return str(d)


def latest_step(ckpt_dir: str) -> Optional[int]:
    d = Path(ckpt_dir)
    if not d.exists():
        return None
    steps = sorted(int(p.name.split("_")[1]) for p in d.glob("step_*"))
    return steps[-1] if steps else None


def read_manifest(ckpt_dir: str, step: Optional[int] = None) -> dict:
    """The manifest dict of the checkpoint at ``step`` (default latest)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    return json.loads((d / "manifest.json").read_text())


def _restore_field(d: Path, name: str, ref):
    data = np.load(d / f"{name}.npz")
    ref_keys = set(_leaf_paths(ref))  # keys only -- no device-to-host copy
    if set(data.files) != ref_keys:
        raise ValueError(f"checkpoint buffer {name} keys mismatch: "
                         f"{sorted(set(data.files) ^ ref_keys)[:5]}")
    leaves_ref, treedef = jax.tree_util.tree_flatten(ref)
    leaves = []
    for path_key, ref_leaf in zip(_leaf_paths(ref), leaves_ref):
        arr = data[path_key]
        if tuple(arr.shape) != tuple(ref_leaf.shape):
            raise ValueError(f"{name}/{path_key}: shape {arr.shape} != "
                             f"{ref_leaf.shape}")
        if (np.dtype(ref_leaf.dtype) == ml_dtypes.bfloat16
                and arr.dtype != ml_dtypes.bfloat16):
            # stored as the u16 bit pattern (see _to_numpy): bit-exact view
            arr = arr.view(ml_dtypes.bfloat16)
        leaves.append(jax.numpy.asarray(arr, dtype=ref_leaf.dtype))
    return treedef.unflatten(leaves)


def restore_state(ckpt_dir: str, like: Any, step: Optional[int] = None):
    """Restore into the structure (and class) of ``like``; shape/dtype
    checked leaf-wise."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    d = Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((d / "manifest.json").read_text())
    saved_cls = manifest.get("state_cls")
    if saved_cls is not None and saved_cls != type(like).__name__:
        raise ValueError(f"checkpoint holds a {saved_cls}, but restore was "
                         f"asked for a {type(like).__name__}")
    new = {}
    for name in _state_fields(like):
        if name == "step":
            # the manifest's step is authoritative (save_state's step=
            # override labels the checkpoint without mutating the state)
            new[name] = jax.numpy.asarray(manifest["step"],
                                          jax.numpy.int32)
            continue
        if not (d / f"{name}.npz").exists():
            raise ValueError(f"checkpoint at {d} has no buffer {name!r}")
        new[name] = _restore_field(d, name, getattr(like, name))
    return type(like)(**new)
