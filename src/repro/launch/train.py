"""End-to-end decentralized training driver for *any* registered algorithm.

``--algo`` picks an entry from the algorithm registry (porter-gc, porter-dp,
beer, porter-adam, dsgd, choco, dp-sgd, soteriafl); the driver builds it
through the ``repro.api`` facade, so topology/compressor/engine construction
and the gamma derivation live in one place.  Runs for real on whatever
devices exist -- the CPU container trains reduced configs; on a TPU pod the
same driver shards over the production mesh (the step builder is shared
with the dry-run).

Training runs through the chunked runtime (``repro.launch.runtime``):
``--chunk N`` scan-fuses N comm rounds into one compiled dispatch with
donated state and on-device batch synthesis (``repro.data.batch_source``),
so the host syncs once per chunk instead of once per round.  Logging,
checkpointing and divergence gating happen at chunk boundaries; the
trajectory is chunking-invariant (same key stream per round), so ``--chunk
8`` reproduces ``--chunk 1``.  Checkpoints record cumulative executed
rounds and the calibrated sigma in their manifest, so a ``--resume`` run
advances the privacy accountant only by rounds actually spent and never
re-calibrates noise mid-stream.

Examples (CPU, ~100M-scale and smoke-scale):
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 50 --batch 8 --seq 128 --chunk 8
    PYTHONPATH=src python -m repro.launch.train --smoke --algo choco
    PYTHONPATH=src python -m repro.launch.train --arch rwkv6-7b --smoke \
        --algo porter-dp --epsilon 0.1 --steps 30
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import numpy as np

from repro.api import (VARIANT_TO_ALGO, ExperimentSpec, algorithm_info,
                       build, list_algorithms)
from repro.configs import get_config, get_smoke
from repro.core import MomentsAccountant, calibrate_sigma, ldp_epsilon
from repro.data import batch_source
from repro.launch.runtime import run_chunked
from repro.models import build_model


def resolve_privacy(info, args, start: int, manifest_extra: dict):
    """(sigma_p, accountant, rounds_prev) honoring rounds already spent.

    Fresh DP run: Theorem-1 calibration of sigma for the ``--steps``
    horizon.  Resume: sigma comes from the checkpoint manifest (the rounds
    already executed were perturbed with *that* sigma -- re-calibrating as
    if no rounds were spent would silently mis-state the guarantee), and
    the moments accountant is advanced by the manifest's cumulative
    ``rounds_executed`` before a single new round runs.
    """
    rounds_prev = int(manifest_extra.get("rounds_executed", start))
    if not info.dp:
        return 0.0, None, rounds_prev
    sigma_saved = manifest_extra.get("sigma_p")
    if start > 0 and sigma_saved:
        # the accountant describes the mechanism that actually ran: the
        # manifest's tau / local_samples govern it, and changing them on
        # resume would mix rounds clipped/noised under different regimes
        # -- refuse rather than silently mis-state the guarantee
        for knob, arg_val in (("tau", args.tau),
                              ("local_samples", args.local_samples)):
            saved = manifest_extra.get(knob)
            if saved is not None and saved != arg_val:
                raise ValueError(
                    f"--resume with --{knob.replace('_', '-')}={arg_val} "
                    f"but the checkpoint's {rounds_prev} rounds ran with "
                    f"{knob}={saved}; resume with the recorded value (the "
                    "noise was calibrated to it)")
        sigma_p = float(sigma_saved)
        acct = MomentsAccountant(q=1.0 / args.local_samples,
                                 noise_multiplier=sigma_p / args.tau)
        acct.step(rounds_prev)
        print(f"[privacy] resumed: sigma_p={sigma_p:.4g} from the manifest; "
              f"{rounds_prev} rounds already spent, accountant eps so far="
              f"{acct.epsilon(args.delta):.4g}")
    else:
        if start > 0:
            # a DP checkpoint without sigma_p metadata predates the
            # accounting manifest: the spent rounds' noise scale is
            # unknown, so any eps we print would be fiction -- refuse
            # instead of silently re-calibrating over them
            raise ValueError(
                f"--resume of a DP run, but the checkpoint manifest "
                f"records no sigma_p for the {rounds_prev} rounds already "
                "spent (pre-runtime checkpoint?); restart fresh or re-save "
                "the checkpoint with privacy metadata")
        sigma_p = calibrate_sigma(args.tau, args.steps, args.local_samples,
                                  args.epsilon, args.delta)
        acct = MomentsAccountant(q=1.0 / args.local_samples,
                                 noise_multiplier=sigma_p / args.tau)
        acct.step(rounds_prev)
        eps_plan = ldp_epsilon(args.tau, sigma_p, args.steps,
                               args.local_samples, args.delta)
        print(f"[privacy] sigma_p={sigma_p:.4g} for "
              f"({args.epsilon},{args.delta})-LDP over {args.steps} steps; "
              f"accountant eps={eps_plan:.4g}")
    return sigma_p, acct, rounds_prev


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-trainable)")
    ap.add_argument("--algo", default=None, choices=list(list_algorithms()),
                    help="registered algorithm (default porter-gc; "
                         "see repro.api)")
    ap.add_argument("--variant", default=None,
                    choices=sorted(VARIANT_TO_ALGO),
                    help="deprecated alias for --algo (gc/dp/beer -> "
                         "porter-*, csgp -> dp-csgp)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--chunk", type=int, default=1,
                    help="comm rounds scan-fused per dispatch (donated "
                         "state, on-device batches); logging/checkpoint/"
                         "divergence gating happen at chunk boundaries")
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4, help="per-agent batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--topology-schedule", default=None,
                    help="time-varying topology generator spec (e.g. "
                         "'dropout:rate=0.2,period=8', "
                         "'rotate:ring+star+complete', "
                         "'erdos_renyi:period=8'); round t mixes with "
                         "W_{t mod period}, indexed inside the compiled "
                         "chunk by the state's step counter")
    ap.add_argument("--compressor", default="top_k")
    ap.add_argument("--frac", type=float, default=0.05)
    ap.add_argument("--fleet", action="store_true",
                    help="vectorized fleet mode (n >> devices): one "
                         "leading agent axis, dense/COO mixing sweep "
                         "(see core/fleet.py; forces dense gossip/wire)")
    ap.add_argument("--plane-dtype", default=None, choices=["f32", "bf16"],
                    help="EF/gossip state plane dtype (bf16 halves resident "
                         "state + dense wire; f32 master params, stochastic-"
                         "rounding writeback). Default: derive from params")
    ap.add_argument("--remat-policy", default=None,
                    choices=["full", "dots"],
                    help="jax.checkpoint around the loss/grad ('full' "
                         "rematerializes everything, 'dots' saves matmul "
                         "outputs); default off")
    ap.add_argument("--eta", type=float, default=3e-2)
    ap.add_argument("--tau", type=float, default=1.0)
    ap.add_argument("--epsilon", type=float, default=0.1,
                    help="LDP epsilon target (DP algorithms)")
    ap.add_argument("--delta", type=float, default=1e-3)
    ap.add_argument("--local-samples", type=int, default=4096,
                    help="m: per-agent dataset size (privacy accounting)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.algo and args.variant:
        ap.error("--algo and --variant are mutually exclusive")
    if args.chunk < 1:
        ap.error("--chunk must be >= 1")
    algo_name = (args.algo or
                 (VARIANT_TO_ALGO[args.variant] if args.variant
                  else "porter-gc"))
    info = algorithm_info(algo_name)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, remat=False)
    bundle = build_model(cfg)

    # probe the checkpoint before calibrating: resume must keep the sigma
    # the spent rounds were perturbed with, and the accountant must start
    # from the manifest's cumulative round count
    start, manifest_extra = 0, {}
    if args.resume and args.ckpt_dir:
        from repro.launch.checkpoint import latest_step, read_manifest
        if latest_step(args.ckpt_dir) is not None:
            start = int(latest_step(args.ckpt_dir))
            manifest_extra = read_manifest(args.ckpt_dir).get("extra", {})
    sigma_p, acct, rounds_prev = resolve_privacy(info, args, start,
                                                 manifest_extra)

    # a schedule is part of the trajectory: round t's W_t is indexed by the
    # restored step counter, so resuming under a *different* schedule would
    # silently splice two topologies into one run -- refuse, like tau
    saved_sched = manifest_extra.get("topology_schedule")
    if start > 0 and saved_sched != args.topology_schedule:
        raise ValueError(
            f"--resume with --topology-schedule={args.topology_schedule!r} "
            f"but the checkpoint's {rounds_prev} rounds ran with "
            f"{saved_sched!r}; resume with the recorded schedule (the step "
            "counter continues its period mid-window)")

    # plane dtype is part of the state layout: the checkpoint's buffers ARE
    # that dtype, and restoring them into a different layout would silently
    # re-round (bf16 -> f32 resurrects no precision, f32 -> bf16 drops it
    # outside the SR path) -- refuse, like the schedule
    saved_planes = manifest_extra.get("plane_dtype")
    if start > 0 and saved_planes != args.plane_dtype:
        raise ValueError(
            f"--resume with --plane-dtype={args.plane_dtype!r} but the "
            f"checkpoint's {rounds_prev} rounds ran with "
            f"{saved_planes!r}; resume with the recorded plane dtype")

    spec = ExperimentSpec(algo=algo_name, n_agents=args.agents,
                          topology=args.topology,
                          topology_schedule=args.topology_schedule,
                          compressor=args.compressor, frac=args.frac,
                          plane_dtype=args.plane_dtype,
                          remat_policy=args.remat_policy,
                          eta=args.eta, tau=args.tau, sigma_p=sigma_p,
                          fleet=args.fleet)
    algo = build(spec, bundle.loss)

    params, _ = bundle.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    if algo.schedule is not None:
        s = algo.schedule
        top_note = (f"{s.kind}, period={s.period}, "
                    f"joint gap={s.joint_spectral_gap:.3f}, "
                    f"per-round alpha={s.alpha:.3f}")
    elif algo.topology is not None:
        top_note = f"{args.topology}, alpha={algo.topology.alpha:.3f}"
    else:
        top_note = "server/client"
    mp_note = "".join(
        [f" planes={args.plane_dtype}" if args.plane_dtype else "",
         f" remat={args.remat_policy}" if args.remat_policy else ""])
    print(f"[model] {cfg.name}: {n_params/1e6:.2f}M params, "
          f"{args.agents} agents ({top_note}), "
          f"{args.compressor}(rho={args.frac}) algo={algo_name} "
          f"chunk={args.chunk}{mp_note}")

    state = algo.init(params)
    if start > 0:
        from repro.launch.checkpoint import restore_state
        state = restore_state(args.ckpt_dir, like=state)
        print(f"[ckpt] resumed from step {start}")
        if start >= args.steps:
            print(f"[done] checkpoint already at step {start} >= "
                  f"--steps {args.steps}; nothing to train")
            if args.out:  # downstream readers still expect the file
                Path(args.out).parent.mkdir(parents=True, exist_ok=True)
                Path(args.out).write_text(json.dumps([]))
            return 0

    source = batch_source(cfg, args.agents, args.batch, args.seq)
    history = []
    run = {"t": start, "diverged": False}
    t0 = time.time()

    def ckpt_extra(t_end: int) -> dict:
        extra = {"rounds_executed": rounds_prev + (t_end - start)}
        if args.topology_schedule is not None:
            extra["topology_schedule"] = args.topology_schedule
        if args.plane_dtype is not None:
            extra["plane_dtype"] = args.plane_dtype
        if info.dp:
            extra.update(sigma_p=sigma_p, tau=args.tau,
                         epsilon=args.epsilon, delta=args.delta,
                         local_samples=args.local_samples)
        return extra

    def on_chunk(t_start, t_end, st, metrics):
        # one host sync per chunk: the stacked metrics come down together
        m_host = jax.device_get(metrics)
        wall = round(time.time() - t0, 2)
        for i, t in enumerate(range(t_start, t_end)):
            if t % args.log_every == 0 or t == args.steps - 1:
                m = {k: float(v[i]) for k, v in m_host.items()}
                m["step"] = t
                m["wall_s"] = wall
                history.append(m)
                extra = "".join(
                    f"  {label} {m[k]:.3e}" for k, label in
                    (("consensus_x", "consensus_x"), ("v_norm", "|v|"))
                    if k in m)
                print(f"  step {t:5d}  loss {m['loss']:.4f}{extra}  "
                      f"wire {m['wire_bytes']/1e6:.3f}MB/round  "
                      f"({m['wall_s']}s)")
        run["t"] = t_end
        if not np.isfinite(m_host["loss"][-1]):
            # gate BEFORE checkpointing: the last good checkpoint must
            # survive so --resume can recover from it
            run["diverged"] = True
            print(f"[diverged] non-finite loss at step {t_end - 1}; "
                  "stopping")
            return False
        if args.ckpt_dir and \
                t_end // args.ckpt_every > t_start // args.ckpt_every:
            from repro.launch.checkpoint import save_state
            save_state(args.ckpt_dir, st, step=t_end,
                       extra=ckpt_extra(t_end))

    run_chunked(algo, source, state, jax.random.PRNGKey(1), args.steps,
                chunk=args.chunk, start=start, on_chunk=on_chunk)

    executed = run["t"] - start
    if acct is not None:
        acct.step(executed)
        print(f"[privacy] executed {executed} rounds this run "
              f"({rounds_prev + executed} cumulative); accountant "
              f"eps={acct.epsilon(args.delta):.4g} at delta={args.delta:g}")
    if args.out:  # written even on divergence: downstream readers expect it
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(json.dumps(history, indent=2))
    if run["diverged"] or not history:
        return 1
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"[done] loss {first:.4f} -> {last:.4f} in {executed} steps "
          f"({time.time()-t0:.1f}s)")
    # Exit gate: fail on divergence, not on noise.  The smoke task is random
    # tokens (loss sits at its entropy floor and fluctuates), and DP runs
    # are perturbation-dominated, so require descent *or* staying within a
    # small band of the initial loss; NaN/blow-up still exits nonzero.
    ok = np.isfinite(last) and (last < first
                                or abs(last - first) <= 0.02 * abs(first))
    return 0 if (ok or (info.dp and np.isfinite(last))) else 1


if __name__ == "__main__":
    raise SystemExit(main())
