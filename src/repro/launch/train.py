"""End-to-end decentralized training driver for *any* registered algorithm.

``--algo`` picks an entry from the algorithm registry (porter-gc, porter-dp,
beer, porter-adam, dsgd, choco, dp-sgd, soteriafl); the driver builds it
through the ``repro.api`` facade, so topology/compressor/engine construction
and the gamma derivation live in one place.  Runs for real on whatever
devices exist -- the CPU container trains reduced configs; on a TPU pod the
same driver shards over the production mesh (the step builder is shared
with the dry-run).

Examples (CPU, ~100M-scale and smoke-scale):
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --smoke --steps 50 --batch 8 --seq 128
    PYTHONPATH=src python -m repro.launch.train --smoke --algo choco
    PYTHONPATH=src python -m repro.launch.train --arch rwkv6-7b --smoke \
        --algo porter-dp --epsilon 0.1 --steps 30
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import (VARIANT_TO_ALGO, ExperimentSpec, algorithm_info,
                       build, list_algorithms)
from repro.configs import get_config, get_smoke
from repro.core import calibrate_sigma, ldp_epsilon
from repro.data import token_batch
from repro.models import build_model


def make_train_batch(cfg, key, n_agents, b, s):
    if cfg.family == "vlm":
        k1, k2 = jax.random.split(key)
        return {"tokens": token_batch(k1, n_agents, b, s - cfg.n_prefix,
                                      cfg.vocab),
                "patches": jax.random.normal(
                    k2, (n_agents, b, cfg.n_prefix, cfg.frontend_dim))}
    if cfg.family == "encdec":
        k1, k2 = jax.random.split(key)
        return {"frames": jax.random.normal(
                    k1, (n_agents, b, s, cfg.frontend_dim)),
                "tokens": token_batch(k2, n_agents, b, s, cfg.vocab)}
    return {"tokens": token_batch(key, n_agents, b, s, cfg.vocab)}


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="tinyllama-1.1b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-trainable)")
    ap.add_argument("--algo", default=None, choices=list(list_algorithms()),
                    help="registered algorithm (default porter-gc; "
                         "see repro.api)")
    ap.add_argument("--variant", default=None, choices=["gc", "dp", "beer"],
                    help="deprecated alias for --algo porter-<variant>")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--agents", type=int, default=4)
    ap.add_argument("--batch", type=int, default=4, help="per-agent batch")
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--topology", default="ring")
    ap.add_argument("--compressor", default="top_k")
    ap.add_argument("--frac", type=float, default=0.05)
    ap.add_argument("--eta", type=float, default=3e-2)
    ap.add_argument("--tau", type=float, default=1.0)
    ap.add_argument("--epsilon", type=float, default=0.1,
                    help="LDP epsilon target (DP algorithms)")
    ap.add_argument("--delta", type=float, default=1e-3)
    ap.add_argument("--local-samples", type=int, default=4096,
                    help="m: per-agent dataset size (privacy accounting)")
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    if args.algo and args.variant:
        ap.error("--algo and --variant are mutually exclusive")
    algo_name = (args.algo or
                 (VARIANT_TO_ALGO[args.variant] if args.variant
                  else "porter-gc"))
    info = algorithm_info(algo_name)

    cfg = get_smoke(args.arch) if args.smoke else get_config(args.arch)
    cfg = dataclasses.replace(cfg, remat=False)
    bundle = build_model(cfg)

    sigma_p = 0.0
    if info.dp:
        sigma_p = calibrate_sigma(args.tau, args.steps, args.local_samples,
                                  args.epsilon, args.delta)
        eps_acct = ldp_epsilon(args.tau, sigma_p, args.steps,
                               args.local_samples, args.delta)
        print(f"[privacy] sigma_p={sigma_p:.4g} for "
              f"({args.epsilon},{args.delta})-LDP over {args.steps} steps; "
              f"accountant eps={eps_acct:.4g}")

    spec = ExperimentSpec(algo=algo_name, n_agents=args.agents,
                          topology=args.topology,
                          compressor=args.compressor, frac=args.frac,
                          eta=args.eta, tau=args.tau, sigma_p=sigma_p)
    algo = build(spec, bundle.loss)

    params, _ = bundle.init(jax.random.PRNGKey(0))
    n_params = sum(int(np.prod(p.shape))
                   for p in jax.tree_util.tree_leaves(params))
    top_note = (f"{args.topology}, alpha={algo.topology.alpha:.3f}"
                if algo.topology is not None else "server/client")
    print(f"[model] {cfg.name}: {n_params/1e6:.2f}M params, "
          f"{args.agents} agents ({top_note}), "
          f"{args.compressor}(rho={args.frac}) algo={algo_name}")

    state = algo.init(params)
    start = 0
    if args.resume and args.ckpt_dir:
        from repro.launch.checkpoint import latest_step, restore_state
        if latest_step(args.ckpt_dir) is not None:
            state = restore_state(args.ckpt_dir, like=state)
            start = int(latest_step(args.ckpt_dir))
            print(f"[ckpt] resumed from step {start}")
            if start >= args.steps:
                print(f"[done] checkpoint already at step {start} >= "
                      f"--steps {args.steps}; nothing to train")
                if args.out:  # downstream readers still expect the file
                    Path(args.out).parent.mkdir(parents=True, exist_ok=True)
                    Path(args.out).write_text(json.dumps([]))
                return 0
    step = jax.jit(algo.step)

    key = jax.random.PRNGKey(1)
    history = []
    t0 = time.time()
    for t in range(start, args.steps):
        key, kb, ks = jax.random.split(key, 3)
        batch = make_train_batch(cfg, kb, args.agents, args.batch, args.seq)
        state, metrics = step(state, batch, ks)
        if t % args.log_every == 0 or t == args.steps - 1:
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = t
            m["wall_s"] = round(time.time() - t0, 2)
            history.append(m)
            extra = "".join(
                f"  {label} {m[k]:.3e}" for k, label in
                (("consensus_x", "consensus_x"), ("v_norm", "|v|"))
                if k in m)
            print(f"  step {t:5d}  loss {m['loss']:.4f}{extra}  "
                  f"wire {m['wire_bytes']/1e6:.3f}MB/round  ({m['wall_s']}s)")
        if args.ckpt_dir and (t + 1) % args.ckpt_every == 0:
            from repro.launch.checkpoint import save_state
            save_state(args.ckpt_dir, state, step=t + 1)
    first, last = history[0]["loss"], history[-1]["loss"]
    print(f"[done] loss {first:.4f} -> {last:.4f} in {args.steps} steps "
          f"({time.time()-t0:.1f}s)")
    if args.out:
        Path(args.out).parent.mkdir(parents=True, exist_ok=True)
        Path(args.out).write_text(json.dumps(history, indent=2))
    # Exit gate: fail on divergence, not on noise.  The smoke task is random
    # tokens (loss sits at its entropy floor and fluctuates), and DP runs
    # are perturbation-dominated, so require descent *or* staying within a
    # small band of the initial loss; NaN/blow-up still exits nonzero.
    ok = np.isfinite(last) and (last < first
                                or abs(last - first) <= 0.02 * abs(first))
    return 0 if (ok or (info.dp and np.isfinite(last))) else 1


if __name__ == "__main__":
    raise SystemExit(main())
