"""Chunked training runtime: scan-fused comm rounds with donated state.

Every driver in the repo used to execute training as a per-step Python
loop -- one jit dispatch per comm round, host-side batch synthesis, fresh
state buffers every step, and a host sync per metric read.  PRs 1-3 fused
the *inside* of a round (pallas kernels, per-shard planes); this module
removes the overhead *between* rounds:

* :class:`BatchSource` -- the data contract: a pure, jit-traceable
  ``(key, step_index) -> batch`` so batch synthesis moves on device and
  inside the compiled program (see :mod:`repro.data.batch_source`).
* :func:`make_runner` -- jits ``lax.scan`` over ``chunk`` calls of the
  registry's uniform ``algo.step``, donates the carried state
  (``donate_argnums``), derives each round's PRNG keys from the base key
  and the absolute round index, and returns stacked per-step metrics as
  device arrays.  One dispatch, one host sync
  and one state round-trip per *chunk* instead of per round.
* :func:`run_chunked` -- drives a ``[start, steps)`` horizon chunk by
  chunk with a boundary callback (logging / checkpointing / divergence
  gating hook); at most one extra executable for the tail remainder.

Key-stream contract: round ``t``'s keys are a pure function of the base
key and the *absolute* round index,

    kb, ks = jax.random.split(jax.random.fold_in(key, t))
    state, metrics = algo.step(state, source(kb, t), ks)

so the trajectory is independent of the chunking (``chunk=k`` reproduces
``chunk=1`` bit-for-bit modulo float reassociation;
tests/test_runtime.py pins allclose at atol 1e-5 across algorithms) AND
independent of restarts: a resumed run continues the uninterrupted
stream instead of replaying the keys -- and hence the DP noise -- that
earlier rounds already consumed (which would void the accountant's
independent-composition assumption).  The base key passes through
unchanged.

Donation contract: the runner consumes its ``state`` argument -- after a
call, only the *returned* state is valid.  Checkpoint saves therefore
happen at chunk boundaries on the returned state (it is pulled to host
before the next chunk consumes it), and a state restored via
``launch/checkpoint.py`` is donated on its first chunk like any other.

Sharded launches (``launch/steps.py`` / ``launch/dryrun.py``) pass the
step's ``state_sharding`` so in/out shardings -- including the per-shard
planes of the PR-3 engine -- are preserved under the scan, plus an
optional ``batch_sharding`` constraint for the in-program batches.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional, Protocol, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

__all__ = ["BatchSource", "ChunkRunner", "make_runner", "run_chunked"]


def _dealias(state):
    """Copy repeated buffers so the state can be donated.

    The registry inits deliberately alias (PorterState's ``q_x``/``m_x``
    *are* ``x``, and the zero buffers share one array) to avoid O(n d)
    copies on the launch path; XLA refuses to donate the same buffer
    twice.  Only the first chunk ever pays the copy -- scan outputs are
    distinct buffers, so later calls just walk the tree.
    """
    seen = set()

    def buffer_key(leaf):
        try:
            return leaf.unsafe_buffer_pointer()
        except Exception:  # sharded / committed arrays: object identity
            return id(leaf)

    def dedupe(leaf):
        if not isinstance(leaf, jax.Array):
            return leaf
        k = buffer_key(leaf)
        if k in seen:
            return jnp.array(leaf)
        seen.add(k)
        return leaf

    return jax.tree_util.tree_map(dedupe, state)


class BatchSource(Protocol):
    """Pure, jit-traceable batch synthesis: ``(key, step_index) -> batch``.

    ``key`` is a fresh PRNG key for this round; ``step_index`` is the
    absolute round index as a traced int32 scalar (deterministic sources
    index with it, iid sources ignore it).  The returned batch must be
    agent-stacked exactly like the batches the per-step loops fed
    ``algo.step`` -- leading dim ``n_agents``.
    """

    def __call__(self, key: jax.Array, step: jax.Array) -> Any: ...


@dataclasses.dataclass
class ChunkRunner:
    """A compiled chunk program: ``(state, key, start) -> (state, key,
    stacked metrics)``.

    ``state`` is DONATED: after a call only the returned state is valid.
    ``start`` is a traced scalar, so one executable serves every chunk
    offset (``cache_size()`` stays 1 per runner).
    """

    chunk: int
    donate: bool
    jitted: Any

    def __call__(self, state, key, start: int = 0):
        if self.donate:
            state = _dealias(state)
        return self.jitted(state, key, jnp.asarray(start, jnp.int32))

    def lower(self, state_shapes, key_shape=None):
        """Abstract lowering (dry-run path): no buffer is materialized."""
        if key_shape is None:
            key_shape = jax.ShapeDtypeStruct((2,), jnp.uint32)
        start = jax.ShapeDtypeStruct((), jnp.int32)
        return self.jitted.lower(state_shapes, key_shape, start)

    def cache_size(self) -> Optional[int]:
        """Compiled-executable count (None if this jax can't report it)."""
        getter = getattr(self.jitted, "_cache_size", None)
        return getter() if getter is not None else None


def make_runner(algo, source: BatchSource, chunk: int, *, donate: bool = True,
                state_sharding=None, batch_sharding=None) -> ChunkRunner:
    """Build the scan-fused runner over ``chunk`` rounds of ``algo.step``.

    algo: a registry :class:`~repro.core.registry.Algorithm` (anything with
      the uniform ``step(state, batch, key) -> (state, metrics)``), or the
      bare step function itself.
    source: a :class:`BatchSource`; batches are synthesized inside the
      compiled program, so a chunk costs one dispatch and zero host round
      trips for data.
    donate: donate the carried state (``donate_argnums``) -- the chunk
      updates state in place instead of allocating a second copy.
    state_sharding / batch_sharding: sharded-launch hooks.  The state
      sharding is applied to both the input and output state (preserved
      under the scan); the batch sharding is applied as a constraint on
      each synthesized batch.
    """
    if chunk < 1:
        raise ValueError(f"chunk must be >= 1, got {chunk}")
    step = getattr(algo, "step", algo)

    def run_chunk(state, key, start):
        def body(st, t):
            # keys are a pure function of (base key, absolute round): the
            # stream is chunking- and restart-invariant (no DP-noise
            # replay on resume)
            kb, ks = jax.random.split(jax.random.fold_in(key, t))
            batch = source(kb, t)
            if batch_sharding is not None:
                batch = jax.lax.with_sharding_constraint(batch,
                                                         batch_sharding)
            st, metrics = step(st, batch, ks)
            return st, metrics

        state, metrics = jax.lax.scan(
            body, state, start + jnp.arange(chunk, dtype=jnp.int32))
        return state, key, metrics

    kw = {}
    if state_sharding is not None:
        mesh = jax.tree_util.tree_leaves(state_sharding)[0].mesh
        repl = NamedSharding(mesh, P())
        # repl is a pytree prefix covering the key/start inputs and the
        # key + stacked-metrics outputs (scalars stay replicated)
        kw = dict(in_shardings=(state_sharding, repl, repl),
                  out_shardings=(state_sharding, repl, repl))
    jitted = jax.jit(run_chunk, donate_argnums=(0,) if donate else (), **kw)
    return ChunkRunner(chunk=chunk, donate=donate, jitted=jitted)


def run_chunked(algo, source: BatchSource, state, key, steps: int, *,
                chunk: int, start: int = 0, donate: bool = True,
                state_sharding=None, batch_sharding=None,
                on_chunk: Optional[Callable] = None) -> Tuple[Any, Any]:
    """Run rounds ``[start, steps)`` in scan-fused chunks of ``chunk``.

    ``on_chunk(t0, t1, state, metrics)`` fires at every chunk boundary with
    the post-chunk state and the stacked (length ``t1 - t0``) metrics for
    rounds ``[t0, t1)`` -- still device arrays, so the callback decides
    when to sync.  Returning ``False`` stops the run at that boundary
    (divergence gating).  The callback must not keep a reference to
    ``state`` past its return: the next chunk donates it.

    Compiles one executable for the main chunk size plus at most one for
    the tail remainder.  Returns the final ``(state, key)``.
    """
    runners = {}
    t = start
    while t < steps:
        size = min(chunk, steps - t)
        runner = runners.get(size)
        if runner is None:
            runner = runners[size] = make_runner(
                algo, source, size, donate=donate,
                state_sharding=state_sharding, batch_sharding=batch_sharding)
        state, key, metrics = runner(state, key, t)
        t += size
        if on_chunk is not None:
            if on_chunk(t - size, t, state, metrics) is False:
                break
    return state, key
