"""The paper's Section-5.1 experiment protocol (logistic regression with
nonconvex regularization on a9a-shaped data).  benchmarks/common.py and the
examples consume these constants; kept here so the protocol is pinned in one
place next to the architecture configs."""

N_AGENTS = 10
GRAPH = dict(kind="erdos_renyi", p=0.8, weights="best_constant", seed=1)
DIM = 123                  # a9a feature dimension
LAMBDA = 0.2               # nonconvex regularizer weight
RHO = 0.05                 # random-5% sparsification (paper: k = d/20)
TAU = 1.0
BATCH = 1
PRIVACY_LEVELS = [(1e-2, 1e-3), (1e-1, 1e-3)]   # (epsilon, delta)
