"""grok-1-314b -- Grok-1 314B MoE, 8 experts top-2 [hf:xai-org/grok-1].

64L, d_model=6144, 48 heads GQA kv=8, expert d_ff=32768, vocab=131072.
Experts are ffn-parallel (8 experts < 16-way model axis -> shard d_ff).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="grok-1-314b", family="moe", n_layers=64, d_model=6144,
    n_heads=48, n_kv_heads=8, d_ff=32768, vocab=131072, n_experts=8,
    top_k=2, activation="gelu", tie_embeddings=True)

SMOKE = ModelConfig(
    name="grok-smoke", family="moe", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=256, vocab=512, n_experts=4, top_k=2,
    activation="gelu")
