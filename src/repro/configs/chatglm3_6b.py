"""chatglm3-6b -- ChatGLM3 6B: GQA kv=2, RoPE applied to half the head
channels ("2d" rotary), qkv bias [arXiv:2406.12793].

28L, d_model=4096, 32 heads kv=2, d_ff=13696 (SwiGLU), vocab=65024.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="chatglm3-6b", family="dense", n_layers=28, d_model=4096,
    n_heads=32, n_kv_heads=2, d_ff=13696, vocab=65024, rotary_frac=0.5,
    qkv_bias=True, activation="silu", tie_embeddings=False)

SMOKE = ModelConfig(
    name="chatglm3-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=384, vocab=512, rotary_frac=0.5,
    qkv_bias=True, tie_embeddings=False)
