"""zamba2-7b -- Zamba2 7B hybrid: Mamba2 backbone with shared attention
blocks [arXiv:2411.15242].

81 mamba2 layers (d_model=3584, ssm_state=64), one shared attention+MLP
block (32 heads kv=32, d_ff=14336) applied after every 6 mamba layers
(13 applications + 3 trailing mamba layers).  Sub-quadratic decode: runs
long_500k (shared-attn cache windowed at 4096 for that shape; see DESIGN.md).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
    n_heads=32, n_kv_heads=32, d_ff=14336, vocab=32000, ssm_state=64,
    ssm_head_dim=64, attn_every=6, activation="silu", tie_embeddings=True)

SMOKE = ModelConfig(
    name="zamba2-smoke", family="hybrid", n_layers=5, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=256, vocab=512, ssm_state=16,
    ssm_head_dim=32, attn_every=2)
