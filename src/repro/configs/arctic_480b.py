"""arctic-480b -- Snowflake Arctic 480B: dense-MoE hybrid, 128 experts
top-2 with a parallel dense residual MLP [hf:Snowflake/snowflake-arctic-base].

35L, d_model=7168, 56 heads GQA kv=8, expert d_ff=4864, vocab=32000.
Experts are expert-parallel (128 experts over the 16-way model axis).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b", family="moe", n_layers=35, d_model=7168,
    n_heads=56, n_kv_heads=8, d_ff=4864, vocab=32000, n_experts=128,
    top_k=2, dense_residual=True, activation="silu", tie_embeddings=True)

SMOKE = ModelConfig(
    name="arctic-smoke", family="moe", n_layers=2, d_model=128, n_heads=4,
    n_kv_heads=2, d_ff=128, vocab=512, n_experts=4, top_k=2,
    dense_residual=True)
