"""tinyllama-1.1b -- TinyLlama 1.1B, llama2 architecture at small scale
[arXiv:2401.02385].

22L, d_model=2048, 32 heads GQA kv=4, d_ff=5632 (SwiGLU), vocab=32000.
This is also the end-to-end trainable example scale (examples/).
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="tinyllama-1.1b", family="dense", n_layers=22, d_model=2048,
    n_heads=32, n_kv_heads=4, d_ff=5632, vocab=32000, activation="silu",
    tie_embeddings=False)

SMOKE = ModelConfig(
    name="tinyllama-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=352, vocab=512, tie_embeddings=False)
