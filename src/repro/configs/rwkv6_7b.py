"""rwkv6-7b -- RWKV-6 "Finch" 7B: attention-free linear RNN with
data-dependent per-channel decay [arXiv:2404.05892].

32L, d_model=4096, head_dim=64 (64 heads), channel-mix hidden 14336,
vocab 65536.  Sub-quadratic: runs the long_500k shape.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="rwkv6-7b", family="rwkv6", n_layers=32, d_model=4096,
    d_ff=14336, vocab=65536, ssm_head_dim=64, norm="layernorm",
    tie_embeddings=True)

SMOKE = ModelConfig(
    name="rwkv6-smoke", family="rwkv6", n_layers=2, d_model=128,
    d_ff=448, vocab=512, ssm_head_dim=32, norm="layernorm")
