"""h2o-danube-3-4b -- H2O Danube3 4B, llama+mistral mix with sliding-window
attention [arXiv:2401.16818] (danube lineage; window 4096).

24L, d_model=3840, 32 heads GQA kv=8, d_ff=10240, vocab=32000,
SWA window=4096.  Runs long_500k via the windowed cache.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="h2o-danube-3-4b", family="dense", n_layers=24, d_model=3840,
    n_heads=32, n_kv_heads=8, d_ff=10240, vocab=32000, window=4096,
    activation="silu", tie_embeddings=False)

SMOKE = ModelConfig(
    name="danube-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=2, d_ff=320, vocab=512, window=32,
    tie_embeddings=False)
