"""paligemma-3b -- PaliGemma 3B VLM: SigLIP vision encoder + gemma decoder
[arXiv:2407.07726].  The SigLIP tower + projector input is a stub by
assignment: ``patches`` arrive as precomputed (B, 256, 1152) embeddings;
the learned projector and the gemma language stack are implemented.

18L, d_model=2048, 8 heads (kv=1, MQA), head_dim=256, d_ff=16384,
vocab=257216.  Prefix-LM mask over the 256 patch tokens.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="paligemma-3b", family="vlm", n_layers=18, d_model=2048,
    n_heads=8, n_kv_heads=1, head_dim=256, d_ff=16384, vocab=257216,
    activation="gelu", frontend="vision", frontend_dim=1152, n_prefix=256,
    tie_embeddings=True)

SMOKE = ModelConfig(
    name="paligemma-smoke", family="vlm", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=1, head_dim=32, d_ff=256, vocab=512,
    activation="gelu", frontend="vision", frontend_dim=64, n_prefix=8)
