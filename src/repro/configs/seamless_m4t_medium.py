"""seamless-m4t-medium -- SeamlessM4T medium speech/text translation
[arXiv:2308.11596]; we implement the TRANSFORMER BACKBONE (encoder-decoder);
the mel-spectrogram + conv feature extractor frontend is a stub by
assignment: ``frames`` arrive as precomputed (B, S_enc, 1024) embeddings.

12L encoder + 12L decoder, d_model=1024, 16 heads (kv=16), d_ff=4096,
vocab=256206.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium", family="encdec", n_layers=12,
    n_enc_layers=12, d_model=1024, n_heads=16, n_kv_heads=16, d_ff=4096,
    vocab=256206, activation="gelu_plain", norm="layernorm",
    frontend="audio", frontend_dim=1024, tie_embeddings=True)

SMOKE = ModelConfig(
    name="seamless-smoke", family="encdec", n_layers=2, n_enc_layers=2,
    d_model=128, n_heads=4, n_kv_heads=4, d_ff=256, vocab=512,
    activation="gelu_plain", norm="layernorm", frontend="audio",
    frontend_dim=64)
