"""minicpm3-4b -- MiniCPM3 4B with multi-head latent attention (MLA)
[hf:openbmb/MiniCPM3-4B].

62L, d_model=2560, 40 heads (kv=40 -- MLA shares a 256-dim latent),
d_ff=6400, vocab=73448.  MLA dims from the model card: q_lora 768,
kv_lora 256, qk_nope 64, qk_rope 32, v_head 64.
"""
from repro.models import ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b", family="dense", n_layers=62, d_model=2560,
    n_heads=40, n_kv_heads=40, d_ff=6400, vocab=73448, mla=True,
    q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64, qk_rope_dim=32,
    v_head_dim=64, activation="silu", tie_embeddings=True)

SMOKE = ModelConfig(
    name="minicpm3-smoke", family="dense", n_layers=2, d_model=128,
    n_heads=4, n_kv_heads=4, d_ff=320, vocab=512, mla=True,
    q_lora_rank=48, kv_lora_rank=32, qk_nope_dim=16, qk_rope_dim=8,
    v_head_dim=16)
