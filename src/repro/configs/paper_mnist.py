"""The paper's Section-5.2 experiment protocol: one-hidden-layer MLP
(784 -> 64 sigmoid -> 10 softmax-CE) on MNIST-shaped data, same decentralized
setup as Section 5.1."""

from .paper_logreg import GRAPH, N_AGENTS, PRIVACY_LEVELS, RHO, TAU, BATCH

INPUT_DIM = 784
HIDDEN = 64
CLASSES = 10
