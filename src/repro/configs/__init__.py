"""Architecture registry: the 10 assigned configs + reduced smoke variants.

``get_config("rwkv6-7b")`` / ``get_smoke("rwkv6-7b")``; arch ids use hyphens
(CLI style), module files use underscores.
"""
from importlib import import_module

ARCHS = [
    "rwkv6-7b", "minicpm3-4b", "seamless-m4t-medium", "tinyllama-1.1b",
    "h2o-danube-3-4b", "chatglm3-6b", "grok-1-314b", "arctic-480b",
    "paligemma-3b", "zamba2-7b",
]

_MODULES = {
    "rwkv6-7b": "rwkv6_7b",
    "minicpm3-4b": "minicpm3_4b",
    "seamless-m4t-medium": "seamless_m4t_medium",
    "tinyllama-1.1b": "tinyllama_1_1b",
    "h2o-danube-3-4b": "h2o_danube_3_4b",
    "chatglm3-6b": "chatglm3_6b",
    "grok-1-314b": "grok_1_314b",
    "arctic-480b": "arctic_480b",
    "paligemma-3b": "paligemma_3b",
    "zamba2-7b": "zamba2_7b",
}


def _module(arch: str):
    if arch not in _MODULES:
        raise ValueError(f"unknown arch {arch!r}; have {ARCHS}")
    return import_module(f"repro.configs.{_MODULES[arch]}")


def get_config(arch: str):
    return _module(arch).CONFIG


def get_smoke(arch: str):
    return _module(arch).SMOKE
