"""Linear-recurrence token mixers: RWKV6 (Finch) and Mamba2 (SSD).

Both are trained/prefilled with a *chunked* parallel form (intra-chunk
matmuls on the MXU + an inter-chunk `lax.scan` over states) and decoded with
the exact O(1)-state recurrence.  The chunked forms are exact (tested against
the per-token scan references below).

Numerics (TPU adaptation, documented in DESIGN.md):
* RWKV6's decay is per-channel, so the chunk factorization
  qk[t,s] = <r_t * exp(la_{t-1}), k_s * exp(-la_s)> needs the per-chunk
  cumulative log-decay `la` to stay within float32 exp range.  We clamp
  log w to [-5, -1e-6] and use chunk = 16, bounding |la| <= 80
  (exp(+-80) is representable in f32 and the combined products are <= 1).
* Mamba2's decay is scalar per head, so the (c, c) decay matrix
  exp(la_t - la_s) (t >= s, exponent <= 0) is built directly -- no
  factorization, no overflow; chunk = 64.

RWKV6 recurrence (head dim N):
    S_t = diag(w_t) S_{t-1} + k_t v_t^T
    o_t = r_t S_{t-1} + (r_t . (u * k_t)) v_t
Mamba2 / SSD recurrence (head dim P, state N):
    h_t = a_t h_{t-1} + (dt_t x_t) B_t^T
    y_t = h_t C_t + D x_t
"""

from __future__ import annotations

import dataclasses
from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .module import Px, dense, init_dense, init_layernorm, layernorm, param

__all__ = [
    "Rwkv6Config", "init_rwkv6_block", "rwkv6_block", "rwkv6_decode",
    "init_rwkv6_state", "rwkv_scan_ref",
    "Mamba2Config", "init_mamba2_block", "mamba2_block", "mamba2_decode",
    "init_mamba2_state", "ssd_scan_ref",
]

LOGW_MIN, LOGW_MAX = -5.0, -1e-6
RWKV_CHUNK = 16
SSD_CHUNK = 64


# ===========================================================================
# RWKV6
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class Rwkv6Config:
    d_model: int
    head_dim: int = 64
    decay_lora: int = 64
    d_ff: int = 0           # channel-mix hidden (0 -> 3.5x d_model)

    @property
    def n_heads(self) -> int:
        return self.d_model // self.head_dim

    @property
    def ffn_dim(self) -> int:
        return self.d_ff or int(3.5 * self.d_model)


def init_rwkv6_block(key, cfg: Rwkv6Config):
    ks = jax.random.split(key, 12)
    d, h, n = cfg.d_model, cfg.n_heads, cfg.head_dim
    return {
        # --- time mix (attention analogue) ---
        "mu": param(ks[0], (5, d), (None, None), 0.5, mode="uniform"),
        "wr": init_dense(ks[1], d, d, (None, "model")),
        "wk": init_dense(ks[2], d, d, (None, "model")),
        "wv": init_dense(ks[3], d, d, (None, "model")),
        "wg": init_dense(ks[4], d, d, (None, "model")),
        "w0": param(ks[5], (d,), (None,), 0.5, mode="uniform"),
        "w_lora_a": init_dense(ks[6], d, cfg.decay_lora, (None, None)),
        "w_lora_b": init_dense(ks[7], cfg.decay_lora, d, (None, "model"),
                               scale=0.01),
        "u": param(ks[8], (h, n), ("model", None), 0.3, mode="uniform"),
        "out_norm": init_layernorm(ks[8], d),
        "wo": init_dense(ks[9], d, d, ("model", None)),
        # --- channel mix ---
        "mu_c": param(ks[10], (2, d), (None, None), 0.5, mode="uniform"),
        "ck": init_dense(ks[11], d, cfg.ffn_dim, (None, "model")),
        "cr": init_dense(ks[11], d, d, (None, None)),
        "cv": init_dense(ks[11], cfg.ffn_dim, d, ("model", None)),
    }


def _token_shift(x, shift_state):
    """x: (B,S,D); shift_state: (B,D) = last token of previous segment."""
    prev = jnp.concatenate([shift_state[:, None, :], x[:, :-1, :]], axis=1)
    return prev


def _rwkv_rkvwg(p, cfg, x, prev):
    """Projections with per-channel token-shift lerp (static mu; see DESIGN)."""
    mu = p["mu"].astype(x.dtype)  # (5, d) for r,k,v,w,g
    mix = [x + (prev - x) * mu[i] for i in range(5)]
    b, s, d = x.shape
    h, n = cfg.n_heads, cfg.head_dim
    r = dense(p["wr"], mix[0]).reshape(b, s, h, n)
    k = dense(p["wk"], mix[1]).reshape(b, s, h, n)
    v = dense(p["wv"], mix[2]).reshape(b, s, h, n)
    logw_raw = p["w0"].astype(jnp.float32) + dense(
        p["w_lora_b"], jnp.tanh(dense(p["w_lora_a"], mix[3]))).astype(jnp.float32)
    # data-dependent decay w = exp(-softplus(.)) in (0,1); clamp for chunk form
    logw = jnp.clip(-jax.nn.softplus(-logw_raw), LOGW_MIN, LOGW_MAX)
    logw = logw.reshape(b, s, h, n)
    g = jax.nn.silu(dense(p["wg"], mix[4]))
    return r, k, v, logw, g


def _rwkv_chunk_scan(r, k, v, logw, u, s0):
    """Exact chunked RWKV6 linear attention.

    r,k,v,logw: (B, S, H, N) with S % CHUNK == 0; u: (H, N);
    s0: (B, H, N, N) initial state.  Returns (o, s_final).
    """
    b, s, h, n = r.shape
    c = RWKV_CHUNK
    nc = s // c
    rs = r.reshape(b, nc, c, h, n).astype(jnp.float32)
    ks = k.reshape(b, nc, c, h, n).astype(jnp.float32)
    vs = v.reshape(b, nc, c, h, n).astype(jnp.float32)
    lw = logw.reshape(b, nc, c, h, n).astype(jnp.float32)
    la = jnp.cumsum(lw, axis=2)                      # (B,NC,C,H,N) inclusive
    la_prev = la - lw                                # exclusive cumsum
    la_end = la[:, :, -1:, :, :]                     # (B,NC,1,H,N)

    rq = rs * jnp.exp(la_prev)                       # r_t * exp(la_{t-1})
    kk = ks * jnp.exp(-la)                           # k_s * exp(-la_s)
    kend = ks * jnp.exp(la_end - la)                 # k_s * exp(la_C - la_s)

    # intra-chunk quadratic part: strictly lower-triangular + u-bonus diag
    qk = jnp.einsum("bnthd,bnshd->bnhts", rq, kk)    # (B,NC,H,C,C)
    tri = jnp.tril(jnp.ones((c, c), jnp.float32), k=-1)
    qk = qk * tri
    bonus = jnp.einsum("bnthd,hd,bnthd->bnth", rs, u.astype(jnp.float32), ks)
    o_intra = jnp.einsum("bnhts,bnshd->bnthd", qk, vs)
    o_intra = o_intra + bonus[..., None] * vs

    # reshape to scan over chunk axis
    rq_t = rq.transpose(1, 0, 2, 3, 4)               # (NC,B,C,H,N)
    kend_t = kend.transpose(1, 0, 2, 3, 4)
    v_t = vs.transpose(1, 0, 2, 3, 4)
    la_end_t = la_end.transpose(1, 0, 2, 3, 4)       # (NC,B,1,H,N)

    def scan_step(s_prev, inp):
        rq_c, kend_c, v_c, lae_c = inp               # (B,C,H,N) / (B,1,H,N)
        o_inter = jnp.einsum("bthk,bhkv->bthv", rq_c, s_prev)
        outer = jnp.einsum("bthk,bthv->bhkv", kend_c, v_c)
        decay = jnp.exp(lae_c[:, 0])                 # (B,H,N) on the k-dim
        s_new = s_prev * decay[..., None] + outer
        return s_new, o_inter

    s_final, o_inter = jax.lax.scan(
        scan_step, s0.astype(jnp.float32), (rq_t, kend_t, v_t, la_end_t))
    o_inter = o_inter.transpose(1, 0, 2, 3, 4)       # (B,NC,C,H,N)
    o = (o_intra + o_inter).reshape(b, s, h, n)
    return o, s_final


def rwkv_scan_ref(r, k, v, logw, u, s0):
    """Per-token recurrent reference (exact; used by tests and decode)."""
    b, s, h, n = r.shape

    def step(state, t):
        rt, kt, vt, wt = (r[:, t].astype(jnp.float32),
                          k[:, t].astype(jnp.float32),
                          v[:, t].astype(jnp.float32),
                          jnp.exp(logw[:, t].astype(jnp.float32)))
        ot = jnp.einsum("bhk,bhkv->bhv", rt, state)
        bonus = jnp.einsum("bhk,hk,bhk->bh", rt, u.astype(jnp.float32), kt)
        ot = ot + bonus[..., None] * vt
        state = state * wt[..., None] + kt[..., None] * vt[:, :, None, :]
        return state, ot

    s_fin, o = jax.lax.scan(step, s0.astype(jnp.float32), jnp.arange(s))
    return o.transpose(1, 0, 2, 3), s_fin


def init_rwkv6_state(batch: int, cfg: Rwkv6Config, dtype=jnp.float32):
    h, n, d = cfg.n_heads, cfg.head_dim, cfg.d_model
    return {"S": jnp.zeros((batch, h, n, n), dtype),
            "shift_t": jnp.zeros((batch, d), dtype),
            "shift_c": jnp.zeros((batch, d), dtype)}


def rwkv6_block(p, cfg: Rwkv6Config, x, state: Optional[Dict] = None,
                chunked: bool = True):
    """Full time-mix + channel-mix over a sequence.  x: (B,S,D).

    Returns (y, final_state).  S must be a multiple of RWKV_CHUNK when
    ``chunked`` (pad upstream).
    """
    b, s, d = x.shape
    if state is None:
        state = init_rwkv6_state(b, cfg)
    prev = _token_shift(x, state["shift_t"].astype(x.dtype))
    r, k, v, logw, g = _rwkv_rkvwg(p, cfg, x, prev)
    u = p["u"]
    if chunked and s % RWKV_CHUNK == 0 and s > 1:
        o, s_fin = _rwkv_chunk_scan(r, k, v, logw, u, state["S"])
    else:
        o, s_fin = rwkv_scan_ref(r, k, v, logw, u, state["S"])
    o = o.reshape(b, s, d).astype(x.dtype)
    o = layernorm(p["out_norm"], o) * g
    y = x + dense(p["wo"], o)

    # channel mix
    prev_c = _token_shift(y, state["shift_c"].astype(x.dtype))
    mu_c = p["mu_c"].astype(x.dtype)
    xr = y + (prev_c - y) * mu_c[0]
    xk = y + (prev_c - y) * mu_c[1]
    hidden = jnp.square(jax.nn.relu(dense(p["ck"], xk)))
    out = jax.nn.sigmoid(dense(p["cr"], xr)) * dense(p["cv"], hidden)
    y2 = y + out
    new_state = {"S": s_fin, "shift_t": x[:, -1, :].astype(jnp.float32),
                 "shift_c": y[:, -1, :].astype(jnp.float32)}
    return y2, new_state


def rwkv6_decode(p, cfg: Rwkv6Config, x, state):
    """One-token step.  x: (B,1,D)."""
    return rwkv6_block(p, cfg, x, state, chunked=False)


# ===========================================================================
# Mamba2 (SSD)
# ===========================================================================

@dataclasses.dataclass(frozen=True)
class Mamba2Config:
    d_model: int
    d_state: int = 64
    head_dim: int = 64
    expand: int = 2
    d_conv: int = 4

    @property
    def d_inner(self) -> int:
        return self.expand * self.d_model

    @property
    def n_heads(self) -> int:
        return self.d_inner // self.head_dim


def init_mamba2_block(key, cfg: Mamba2Config):
    ks = jax.random.split(key, 6)
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.d_state, cfg.n_heads
    conv_ch = di + 2 * n
    return {
        # in_proj -> [z (di), x (di), B (n), C (n), dt (h)]
        "w_in": init_dense(ks[0], d, 2 * di + 2 * n + h, (None, "model")),
        "conv_w": param(ks[1], (cfg.d_conv, conv_ch), (None, "model"),
                        1.0 / np.sqrt(cfg.d_conv)),
        "conv_b": param(ks[1], (conv_ch,), ("model",), 0.0, mode="zeros"),
        "a_log": param(ks[2], (h,), ("model",), 0.5, mode="uniform"),
        "dt_bias": param(ks[3], (h,), ("model",), 0.5, mode="uniform"),
        "d_skip": param(ks[4], (h,), ("model",), 1.0, mode="ones"),
        "out_norm": init_layernorm(ks[4], di),
        "w_out": init_dense(ks[5], di, d, ("model", None)),
    }


def _ssd_chunk_scan(xh, bmat, cmat, dla, h0):
    """Exact chunked SSD.

    xh: (B,S,H,P) dt-scaled inputs; bmat/cmat: (B,S,N); dla: (B,S,H)
    *per-step* log-decay (log a_t); h0: (B,H,P,N).  Returns (y, h_final).
    """
    b, s, h, p = xh.shape
    n = bmat.shape[-1]
    c = min(SSD_CHUNK, s)
    nc = s // c
    xs = xh.reshape(b, nc, c, h, p).astype(jnp.float32)
    bs = bmat.reshape(b, nc, c, n).astype(jnp.float32)
    cs = cmat.reshape(b, nc, c, n).astype(jnp.float32)
    # cumulative decay, re-zeroed at every chunk boundary
    dl = dla.reshape(b, nc, c, h).astype(jnp.float32)
    lrel = jnp.cumsum(dl, axis=2)      # inclusive, relative to chunk start
    lrel_prev = lrel - dl              # exclusive (unused; kept for clarity)
    del lrel_prev
    lend = lrel[:, :, -1:, :]

    # intra-chunk: y[t] += sum_{s<=t} exp(lrel_t - lrel_s) (C_t.B_s) xh_s
    dmat = lrel[:, :, :, None, :] - lrel[:, :, None, :, :]   # (B,NC,C,C,H)
    tri = jnp.tril(jnp.ones((c, c), bool))
    dmat = jnp.where(tri[None, None, :, :, None], dmat, -jnp.inf)
    dec = jnp.exp(dmat)
    cb = jnp.einsum("bntk,bnsk->bnts", cs, bs)               # (B,NC,C,C)
    m = cb[:, :, :, :, None] * dec                           # (B,NC,C,C,H)
    y_intra = jnp.einsum("bntsh,bnshp->bnthp", m, xs)

    # inter-chunk state scan.  y_t reads h_t (inclusive of step t's decay),
    # so the state contribution carries exp(lrel_t), not exp(lrel_{t-1}).
    kend = jnp.exp(lend - lrel)                              # (B,NC,C,H)
    xdec = xs * kend[..., None]                              # decayed inputs
    outer = jnp.einsum("bnchp,bnck->bnhpk", xdec, bs)        # (B,NC,H,P,N)
    cin = jnp.exp(lrel)                                      # (B,NC,C,H)

    def scan_step(h_prev, inp):
        outer_c, lend_c, cs_c, cin_c = inp
        y_inter = jnp.einsum("bck,bhpk,bch->bchp", cs_c, h_prev, cin_c)
        h_new = h_prev * jnp.exp(lend_c)[:, 0, :, None, None] + outer_c
        return h_new, y_inter

    h_fin, y_inter = jax.lax.scan(
        scan_step, h0.astype(jnp.float32),
        (outer.transpose(1, 0, 2, 3, 4), lend.transpose(1, 0, 2, 3),
         cs.transpose(1, 0, 2, 3), cin.transpose(1, 0, 2, 3)))
    y_inter = y_inter.transpose(1, 0, 2, 3, 4)               # (B,NC,C,H,P)
    y = (y_intra + y_inter).reshape(b, s, h, p)
    return y, h_fin


def ssd_scan_ref(xh, bmat, cmat, dla, h0):
    """Per-token SSD reference.  dla: (B,S,H) per-step log-decay."""
    b, s, h, p = xh.shape

    def step(state, t):
        a_t = jnp.exp(dla[:, t].astype(jnp.float32))             # (B,H)
        outer = jnp.einsum("bhp,bk->bhpk", xh[:, t].astype(jnp.float32),
                           bmat[:, t].astype(jnp.float32))
        state = state * a_t[..., None, None] + outer
        y = jnp.einsum("bk,bhpk->bhp", cmat[:, t].astype(jnp.float32), state)
        return state, y

    h_fin, y = jax.lax.scan(step, h0.astype(jnp.float32), jnp.arange(s))
    return y.transpose(1, 0, 2, 3), h_fin


def init_mamba2_state(batch: int, cfg: Mamba2Config, dtype=jnp.float32):
    conv_ch = cfg.d_inner + 2 * cfg.d_state
    return {"h": jnp.zeros((batch, cfg.n_heads, cfg.head_dim, cfg.d_state),
                           dtype),
            "conv": jnp.zeros((batch, cfg.d_conv - 1, conv_ch), dtype)}


def _causal_conv(seq, w, b, conv_state):
    """Depthwise causal conv1d.  seq: (B,S,C); w: (K,C); returns (y, new_state)."""
    k = w.shape[0]
    padded = jnp.concatenate([conv_state.astype(seq.dtype), seq], axis=1)
    out = sum(padded[:, i: i + seq.shape[1], :] * w[i].astype(seq.dtype)
              for i in range(k))
    new_state = padded[:, -(k - 1):, :] if k > 1 else conv_state
    return out + b.astype(seq.dtype), new_state


def mamba2_block(p, cfg: Mamba2Config, x, state: Optional[Dict] = None,
                 chunked: bool = True):
    """x: (B,S,D) -> (y, new_state)."""
    b, s, d = x.shape
    di, n, h, pd = cfg.d_inner, cfg.d_state, cfg.n_heads, cfg.head_dim
    if state is None:
        state = init_mamba2_state(b, cfg)
    zxbcdt = dense(p["w_in"], x)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di: di + di + 2 * n]
    dt_raw = zxbcdt[..., -h:]
    xbc, conv_state = _causal_conv(xbc, p["conv_w"], p["conv_b"],
                                   state["conv"])
    xbc = jax.nn.silu(xbc)
    xin = xbc[..., :di].reshape(b, s, h, pd)
    bmat = xbc[..., di: di + n]
    cmat = xbc[..., di + n:]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32)
                         + p["dt_bias"].astype(jnp.float32))   # (B,S,H)
    a = -jnp.exp(p["a_log"].astype(jnp.float32))               # (H,) negative
    dla = dt * a[None, None, :]                                # per-step log a
    xh = xin.astype(jnp.float32) * dt[..., None]
    if chunked and s % SSD_CHUNK == 0 and s > 1:
        y, h_fin = _ssd_chunk_scan(xh, bmat, cmat, dla, state["h"])
    else:
        y, h_fin = ssd_scan_ref(xh, bmat, cmat, dla, state["h"])
    y = y + xin.astype(jnp.float32) * p["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, s, di).astype(x.dtype)
    y = layernorm(p["out_norm"], y * jax.nn.silu(z))
    out = dense(p["w_out"], y)
    new_state = {"h": h_fin, "conv": conv_state.astype(jnp.float32)}
    return out, new_state


def mamba2_decode(p, cfg: Mamba2Config, x, state):
    return mamba2_block(p, cfg, x, state, chunked=False)
