"""Minimal pure-JAX neural-net library (attention, MoE, SSM, modules)."""
from . import attention, module, moe, ssm
from .module import Px, split_tree, cross_entropy_loss

__all__ = ["attention", "module", "moe", "ssm", "Px", "split_tree",
           "cross_entropy_loss"]
