"""Feed-forward blocks: gated MLPs and Mixture-of-Experts.

MoE uses a *sort-based dropless-ish dispatch* (TPU adaptation): tokens are
routed top-k, assigned capacity slots via a cumulative-count within each
expert, gathered into (E, C, d) buffers with one scatter, processed by a
batched expert matmul (MXU-friendly), and combined with gather + weighted
sum.  FLOPs are proportional to *active* experts (capacity drops overflow),
unlike one-hot "soft" dispatch whose einsum touches every expert.

Sharding: expert weights (E, d, f)
  * expert-parallel  P('model', None, None)  when E >= model-axis size
    (arctic-480b: 128 experts / 16-way axis)
  * ffn-parallel     P(None, None, 'model')  otherwise (grok-1: 8 experts)

A router load-balance auxiliary loss (Switch-style) is returned so training
can regularize routing.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .module import Px, dense, init_dense, param

__all__ = ["MlpConfig", "init_mlp", "mlp", "MoeConfig", "init_moe", "moe"]


@dataclasses.dataclass(frozen=True)
class MlpConfig:
    d_model: int
    d_ff: int
    activation: str = "silu"   # 'silu' (gated), 'gelu' (gated), 'relu2', 'gelu_plain'


def _act(name: str, x):
    if name == "silu":
        return jax.nn.silu(x)
    if name == "gelu":
        return jax.nn.gelu(x)
    if name == "relu2":
        return jnp.square(jax.nn.relu(x))
    raise ValueError(name)


def init_mlp(key, cfg: MlpConfig):
    k1, k2, k3 = jax.random.split(key, 3)
    gated = cfg.activation in ("silu", "gelu")
    p = {
        "w_in": init_dense(k1, cfg.d_model, cfg.d_ff, (None, "model")),
        "w_out": init_dense(k2, cfg.d_ff, cfg.d_model, ("model", None)),
    }
    if gated:
        p["w_gate"] = init_dense(k3, cfg.d_model, cfg.d_ff, (None, "model"))
    return p


def mlp(p, cfg: MlpConfig, x):
    if "w_gate" in p:
        h = _act(cfg.activation, dense(p["w_gate"], x)) * dense(p["w_in"], x)
    else:
        act = "gelu" if cfg.activation == "gelu_plain" else cfg.activation
        h = _act(act, dense(p["w_in"], x))
    return dense(p["w_out"], h)


# ---------------------------------------------------------------------------
# Mixture of Experts
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class MoeConfig:
    d_model: int
    d_ff: int
    n_experts: int
    top_k: int = 2
    capacity_factor: float = 1.25
    activation: str = "silu"
    dense_residual: bool = False      # arctic: parallel dense MLP
    dense_d_ff: Optional[int] = None  # hidden of the residual MLP
    expert_parallel_threshold: int = 16

    @property
    def expert_spec(self):
        if self.n_experts >= self.expert_parallel_threshold:
            return ("model", None, None)     # expert-parallel
        return (None, None, "model")         # ffn-parallel


def init_moe(key, cfg: MoeConfig):
    ks = jax.random.split(key, 6)
    e, d, f = cfg.n_experts, cfg.d_model, cfg.d_ff
    sp = cfg.expert_spec
    sp_out = (sp[0], sp[2], sp[1]) if sp[0] is None else ("model", None, None)
    scale = 1.0 / np.sqrt(d)
    p = {
        "router": init_dense(ks[0], d, e, (None, None), scale=scale),
        "w_gate": param(ks[1], (e, d, f), sp, scale),
        "w_in": param(ks[2], (e, d, f), sp, scale),
        "w_out": param(ks[3], (e, f, d), sp_out, 1.0 / np.sqrt(f)),
    }
    if cfg.dense_residual:
        p["dense_mlp"] = init_mlp(
            ks[4], MlpConfig(d, cfg.dense_d_ff or f, cfg.activation))
    return p


def moe(p, cfg: MoeConfig, x) -> Tuple[jax.Array, jax.Array]:
    """x: (B, S, d) -> (out, aux_loss).

    Sort-free capacity assignment: position of token t in expert e's buffer is
    the count of earlier tokens routed to e (cumsum of one-hot); tokens past
    capacity are dropped (their combine weight contribution is zero), which is
    the standard Switch/GShard behaviour.
    """
    b, s, d = x.shape
    n_tok = b * s
    e, k = cfg.n_experts, cfg.top_k
    cap = int(np.ceil(cfg.capacity_factor * n_tok * k / e))
    cap = max(cap, 1)

    xt = x.reshape(n_tok, d)
    logits = dense(p["router"], xt.astype(jnp.float32))       # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, gate_idx = jax.lax.top_k(probs, k)             # (T, k)
    gate_vals = gate_vals / (jnp.sum(gate_vals, -1, keepdims=True) + 1e-9)

    # ---- capacity slot assignment ----------------------------------------
    flat_expert = gate_idx.reshape(-1)                         # (T*k,)
    onehot = jax.nn.one_hot(flat_expert, e, dtype=jnp.int32)   # (T*k, E)
    pos_in_expert = jnp.cumsum(onehot, axis=0) - onehot        # exclusive
    slot = jnp.sum(pos_in_expert * onehot, axis=-1)            # (T*k,)
    keep = slot < cap
    dest = jnp.where(keep, flat_expert * cap + slot, e * cap)  # overflow bin

    # ---- dispatch: scatter tokens into (E*C+1, d) -------------------------
    xk = jnp.repeat(xt, k, axis=0)                             # (T*k, d)
    buf = jnp.zeros((e * cap + 1, d), xt.dtype).at[dest].set(xk)
    buf = buf[: e * cap].reshape(e, cap, d)

    # ---- expert compute (batched over E; MXU matmuls) ---------------------
    gate_h = jnp.einsum("ecd,edf->ecf", buf, p["w_gate"].astype(buf.dtype))
    in_h = jnp.einsum("ecd,edf->ecf", buf, p["w_in"].astype(buf.dtype))
    h = _act(cfg.activation, gate_h) * in_h
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["w_out"].astype(buf.dtype))

    # ---- combine: gather back + weighted sum over k -----------------------
    out_flat = out_buf.reshape(e * cap, d)
    gathered = jnp.where(keep[:, None],
                         out_flat[jnp.minimum(dest, e * cap - 1)], 0.0)
    weighted = gathered.reshape(n_tok, k, d) * gate_vals[..., None].astype(x.dtype)
    out = jnp.sum(weighted, axis=1).reshape(b, s, d)

    # ---- Switch load-balance aux loss -------------------------------------
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(
        jax.nn.one_hot(gate_idx[:, 0], e, dtype=jnp.float32), axis=0)
    aux = e * jnp.sum(me * ce)

    if "dense_mlp" in p:
        dcfg = MlpConfig(cfg.d_model, cfg.dense_d_ff or cfg.d_ff,
                         cfg.activation)
        out = out + mlp(p["dense_mlp"], dcfg, x)
    return out, aux
