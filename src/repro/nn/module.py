"""Minimal pure-JAX module system (flax is not available offline).

Convention: ``init_*`` functions return a pytree whose leaves are
``Px(value, spec)`` pairs -- the array together with its
``PartitionSpec`` over the ('data', 'model') mesh (agent axes are prepended
later by the launcher, see repro/launch).  ``split_tree`` separates the two
parallel pytrees.  ``apply`` functions are plain functions of
(params, inputs).

Initializers are jittable (jax.random based) so layer stacks can be built
with ``jax.vmap`` over per-layer keys -- the model zoo scans over stacked
layer parameters to keep HLO size and compile time independent of depth.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

__all__ = [
    "Px", "split_tree", "param", "init_dense", "dense", "init_embedding",
    "embedding", "init_rmsnorm", "rmsnorm", "init_layernorm", "layernorm",
    "rope_freqs", "apply_rope", "cross_entropy_loss", "prepend_axis_specs",
    "stack_inits",
]


class Px(NamedTuple):
    """A parameter leaf: the array plus its PartitionSpec."""
    value: jax.Array
    spec: P


def _is_px(x) -> bool:
    return isinstance(x, Px)


def split_tree(tree) -> Tuple[Any, Any]:
    """Split a Px-leaf pytree into (values, specs)."""
    values = jax.tree_util.tree_map(lambda l: l.value, tree, is_leaf=_is_px)
    specs = jax.tree_util.tree_map(lambda l: l.spec, tree, is_leaf=_is_px)
    return values, specs


def param(key, shape: Sequence[int], spec: Sequence[Optional[str]],
          scale: float = 1.0, dtype=jnp.float32, mode: str = "normal") -> Px:
    shape = tuple(shape)
    if mode == "normal":
        v = scale * jax.random.normal(key, shape, dtype)
    elif mode == "zeros":
        v = jnp.zeros(shape, dtype)
    elif mode == "ones":
        v = jnp.ones(shape, dtype)
    elif mode == "uniform":
        v = scale * jax.random.uniform(key, shape, dtype, -1.0, 1.0)
    else:
        raise ValueError(mode)
    return Px(v, P(*spec))


def init_dense(key, d_in: int, d_out: int, spec=(None, "model"),
               bias: bool = False, scale: Optional[float] = None,
               dtype=jnp.float32):
    scale = scale if scale is not None else 1.0 / np.sqrt(d_in)
    k_w, k_b = jax.random.split(key)
    p = {"w": param(k_w, (d_in, d_out), spec, scale, dtype)}
    if bias:
        p["b"] = param(k_b, (d_out,), (spec[-1],), 0.0, dtype, mode="zeros")
    return p


def dense(p, x: jax.Array) -> jax.Array:
    y = x @ p["w"].astype(x.dtype)
    if "b" in p:
        y = y + p["b"].astype(x.dtype)
    return y


def init_embedding(key, vocab: int, d: int, spec=("model", None),
                   dtype=jnp.float32):
    return {"table": param(key, (vocab, d), spec, 0.02, dtype)}


def embedding(p, tokens: jax.Array, dtype=jnp.float32) -> jax.Array:
    return jnp.take(p["table"], tokens, axis=0).astype(dtype)


def init_rmsnorm(key, d: int, dtype=jnp.float32):
    del key
    return {"scale": Px(jnp.ones((d,), dtype), P(None))}


def rmsnorm(p, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def init_layernorm(key, d: int, dtype=jnp.float32):
    del key
    return {"scale": Px(jnp.ones((d,), dtype), P(None)),
            "bias": Px(jnp.zeros((d,), dtype), P(None))}


def layernorm(p, x: jax.Array, eps: float = 1e-5) -> jax.Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(xf - mu), axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings: full / partial ("2d", chatglm-style) rotary fraction.
# ---------------------------------------------------------------------------

def rope_freqs(rotary_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, rotary_dim, 2, dtype=jnp.float32)
                            / rotary_dim))


def apply_rope(x: jax.Array, positions: jax.Array, rotary_dim: int,
               theta: float = 10000.0) -> jax.Array:
    """Rotate the first ``rotary_dim`` channels of the last axis.

    x: (..., seq, heads, head_dim); positions: (..., seq) int32.
    rotary_dim < head_dim gives partial rotary (chatglm3's "2d" RoPE applies
    rotation to half the channels).
    """
    hd = x.shape[-1]
    rot, rest = x[..., :rotary_dim], x[..., rotary_dim:]
    freqs = rope_freqs(rotary_dim, theta)  # (rotary_dim/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (..., seq, rd/2)
    cos = jnp.cos(ang)[..., None, :].astype(x.dtype)
    sin = jnp.sin(ang)[..., None, :].astype(x.dtype)
    x1, x2 = rot[..., : rotary_dim // 2], rot[..., rotary_dim // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    if rotary_dim < hd:
        out = jnp.concatenate([out, rest], axis=-1)
    return out


def cross_entropy_loss(logits: jax.Array, labels: jax.Array,
                       mask: Optional[jax.Array] = None) -> jax.Array:
    """Token-level CE without materializing one-hots (vocab can be 257k)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)


def prepend_axis_specs(specs, axes) -> Any:
    """Prepend mesh axes (e.g. agent axes, or a layer-stack None) to specs."""
    def one(s: P) -> P:
        return P(axes, *tuple(s))
    return jax.tree_util.tree_map(one, specs,
                                  is_leaf=lambda x: isinstance(x, P))


def stack_inits(init_fn, key, n: int):
    """Initialize n copies of a layer with stacked (n, ...) leaves.

    Returns a Px pytree whose values carry a leading layer axis and whose
    specs carry a leading None.
    """
    keys = jax.random.split(key, n)
    vals0 = init_fn(keys[0])
    values, specs = split_tree(vals0)
    stacked = jax.vmap(lambda k: split_tree(init_fn(k))[0])(keys)
    specs = prepend_axis_specs(specs, None)
    return jax.tree_util.tree_map(
        lambda v, s: Px(v, s), stacked, specs,
        is_leaf=lambda x: isinstance(x, P))
