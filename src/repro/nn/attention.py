"""Attention blocks: MHA/GQA/MQA, sliding-window, prefix-LM, cross-attention,
and MiniCPM3-style MLA (multi-head latent attention), with decode caches.

Sharding convention over the ('data','model') mesh: head-projection weights
are sharded over 'model' on the head*head_dim axis; output projections on the
input axis.  Caches shard batch over the agent/data axes when batch >= axis
size, otherwise sequence (see launch/shapes.py).

Cache formats
  full GQA   : {k, v: (B, S, Hk, hd), ...}   write at ``pos``
  windowed   : {k, v: (B, W, Hk, hd), positions: (B, W) int32}  ring buffer
  MLA latent : {ckv: (B, S, dc), krope: (B, S, dr)}
  cross      : {k, v: (B, T_enc, Hk, hd)}    precomputed at prefill
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from .module import Px, apply_rope, dense, init_dense, init_rmsnorm, rmsnorm

__all__ = [
    "AttnConfig", "MLAConfig", "init_attention", "attention",
    "init_full_cache", "init_window_cache", "attention_decode",
    "init_mla", "mla_attention", "init_mla_cache", "mla_decode",
    "init_cross_attention", "cross_attention", "make_cross_cache",
    "cross_attention_decode",
]

NEG_INF = -1e30


@dataclasses.dataclass(frozen=True)
class AttnConfig:
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    rotary_frac: float = 1.0      # chatglm3 "2d" RoPE = 0.5
    rope_theta: float = 10000.0
    window: Optional[int] = None  # sliding-window size (h2o-danube3)
    qkv_bias: bool = False

    @property
    def rotary_dim(self) -> int:
        rd = int(self.head_dim * self.rotary_frac)
        return rd - rd % 2


@dataclasses.dataclass(frozen=True)
class MLAConfig:
    d_model: int
    n_heads: int
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64
    rope_theta: float = 10000.0


# ---------------------------------------------------------------------------
# Standard GQA attention
# ---------------------------------------------------------------------------

def init_attention(key, cfg: AttnConfig):
    kq, kk, kv, ko = jax.random.split(key, 4)
    h, hk, hd, d = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, cfg.d_model
    return {
        "wq": init_dense(kq, d, h * hd, (None, "model"), bias=cfg.qkv_bias),
        "wk": init_dense(kk, d, hk * hd, (None, "model"), bias=cfg.qkv_bias),
        "wv": init_dense(kv, d, hk * hd, (None, "model"), bias=cfg.qkv_bias),
        "wo": init_dense(ko, h * hd, d, ("model", None)),
    }


def _split_heads(x, n, hd):
    return x.reshape(*x.shape[:-1], n, hd)


def _gqa_scores(q, k):
    """q: (B,S,Hk,G,hd), k: (B,T,Hk,hd) -> (B,Hk,G,S,T)."""
    return jnp.einsum("bskgd,btkd->bkgst", q, k)


def _gqa_out(probs, v):
    return jnp.einsum("bkgst,btkd->bskgd", probs, v)


def _mask_bias(mask: jax.Array, dtype) -> jax.Array:
    return jnp.where(mask, 0.0, NEG_INF).astype(dtype)


def make_mask(s: int, t: int, mode: str = "causal",
              window: Optional[int] = None, prefix_len: int = 0,
              q_offset: int = 0) -> jax.Array:
    """(s, t) boolean mask; True = attend.  q position i is q_offset + i."""
    qi = jnp.arange(s)[:, None] + q_offset
    ki = jnp.arange(t)[None, :]
    if mode == "full":
        m = jnp.ones((s, t), bool)
    elif mode == "causal":
        m = ki <= qi
    elif mode == "prefix":
        m = (ki <= qi) | (ki < prefix_len)
    else:
        raise ValueError(mode)
    if window is not None:
        m = m & (ki > qi - window)
    return m


def attention(p, cfg: AttnConfig, x: jax.Array, positions: jax.Array,
              mode: str = "causal", prefix_len: int = 0,
              q_chunk: Optional[int] = None) -> jax.Array:
    """Full-sequence attention.  x: (B,S,D); positions: (B,S).

    q_chunk: process queries in blocks of this size (lax.scan), so the
    materialized score tensor is (B,H,q_chunk,S) instead of (B,H,S,S) --
    the coarse-grained flash-attention adaptation that makes 32k prefill
    fit HBM (see EXPERIMENTS.md SPerf, minicpm3 x prefill_32k).
    """
    b, s, _ = x.shape
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // hk
    q = _split_heads(dense(p["wq"], x), h, hd)
    k = _split_heads(dense(p["wk"], x), hk, hd)
    v = _split_heads(dense(p["wv"], x), hk, hd)
    if cfg.rotary_dim > 0:
        q = apply_rope(q, positions, cfg.rotary_dim, cfg.rope_theta)
        k = apply_rope(k, positions, cfg.rotary_dim, cfg.rope_theta)
    q = q.reshape(b, s, hk, g, hd)

    def attend_block(q_blk, offset, blk_len):
        scores = _gqa_scores(q_blk, k) / np.sqrt(hd)
        mask = make_mask(blk_len, s, mode, cfg.window, prefix_len,
                         q_offset=offset)
        scores = scores + _mask_bias(mask, scores.dtype)
        probs = jax.nn.softmax(scores.astype(jnp.float32),
                               axis=-1).astype(x.dtype)
        return _gqa_out(probs, v)

    if q_chunk and s > q_chunk and s % q_chunk == 0:
        nc = s // q_chunk
        q_blocks = q.reshape(b, nc, q_chunk, hk, g, hd).transpose(
            1, 0, 2, 3, 4, 5)

        def body(_, inp):
            q_blk, i = inp
            return None, attend_block(q_blk, i * q_chunk, q_chunk)

        _, outs = jax.lax.scan(body, None, (q_blocks, jnp.arange(nc)))
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h * hd)
    else:
        out = attend_block(q, 0, s).reshape(b, s, h * hd)
    return dense(p["wo"], out)


def init_full_cache(batch: int, seq: int, cfg: AttnConfig,
                    dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    hk, hd = cfg.n_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, seq, hk, hd), dtype),
            "v": jnp.zeros((batch, seq, hk, hd), dtype)}


def init_window_cache(batch: int, window: int, cfg: AttnConfig,
                      dtype=jnp.bfloat16) -> Dict[str, jax.Array]:
    hk, hd = cfg.n_kv_heads, cfg.head_dim
    return {"k": jnp.zeros((batch, window, hk, hd), dtype),
            "v": jnp.zeros((batch, window, hk, hd), dtype),
            "positions": jnp.full((batch, window), -1, jnp.int32)}


def attention_decode(p, cfg: AttnConfig, x: jax.Array, cache: Dict[str, Any],
                     pos: jax.Array) -> Tuple[jax.Array, Dict[str, Any]]:
    """One-token decode.  x: (B,1,D); pos: scalar int32 (same for the batch).

    Full cache: write kv at ``pos`` and attend over [0, pos].
    Windowed cache: ring-buffer slot pos % W; mask by stored positions.
    """
    b = x.shape[0]
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    g = h // hk
    positions = jnp.full((b, 1), pos, jnp.int32)
    q = _split_heads(dense(p["wq"], x), h, hd)
    k_new = _split_heads(dense(p["wk"], x), hk, hd)
    v_new = _split_heads(dense(p["wv"], x), hk, hd)
    if cfg.rotary_dim > 0:
        q = apply_rope(q, positions, cfg.rotary_dim, cfg.rope_theta)
        k_new = apply_rope(k_new, positions, cfg.rotary_dim, cfg.rope_theta)
    q = q.reshape(b, 1, hk, g, hd)

    windowed = "positions" in cache
    slot = (pos % cache["k"].shape[1]) if windowed else pos
    k = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), slot, axis=1)
    v = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), slot, axis=1)
    new_cache = dict(cache, k=k, v=v)

    scores = _gqa_scores(q, k.astype(x.dtype)) / np.sqrt(hd)  # (B,Hk,G,1,T)
    if windowed:
        pos_ids = jax.lax.dynamic_update_slice_in_dim(
            cache["positions"], positions, slot, axis=1)
        new_cache["positions"] = pos_ids
        valid = (pos_ids <= pos) & (pos_ids >= 0)
        if cfg.window is not None:
            valid = valid & (pos_ids > pos - cfg.window)
        mask = valid[:, None, None, None, :]
    else:
        t = k.shape[1]
        mask = (jnp.arange(t) <= pos)[None, None, None, None, :]
    scores = jnp.where(mask, scores, NEG_INF)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = _gqa_out(probs, v.astype(x.dtype)).reshape(b, 1, h * hd)
    return dense(p["wo"], out), new_cache


# ---------------------------------------------------------------------------
# MLA (MiniCPM3 / DeepSeek-style multi-head latent attention)
# ---------------------------------------------------------------------------

def init_mla(key, cfg: MLAConfig):
    ks = jax.random.split(key, 8)
    h = cfg.n_heads
    qk = cfg.qk_nope_dim + cfg.qk_rope_dim
    return {
        "wdq": init_dense(ks[0], cfg.d_model, cfg.q_lora_rank, (None, None)),
        "q_norm": init_rmsnorm(ks[1], cfg.q_lora_rank),
        "wuq": init_dense(ks[2], cfg.q_lora_rank, h * qk, (None, "model")),
        "wdkv": init_dense(ks[3], cfg.d_model,
                           cfg.kv_lora_rank + cfg.qk_rope_dim, (None, None)),
        "kv_norm": init_rmsnorm(ks[4], cfg.kv_lora_rank),
        "wuk": init_dense(ks[5], cfg.kv_lora_rank, h * cfg.qk_nope_dim,
                          (None, "model")),
        "wuv": init_dense(ks[6], cfg.kv_lora_rank, h * cfg.v_head_dim,
                          (None, "model")),
        "wo": init_dense(ks[7], h * cfg.v_head_dim, cfg.d_model,
                         ("model", None)),
    }


def _mla_qkv(p, cfg: MLAConfig, x, positions):
    """Shared q / latent computation.  Returns q_nope, q_rope, ckv, krope."""
    b, s, _ = x.shape
    h = cfg.n_heads
    cq = rmsnorm(p["q_norm"], dense(p["wdq"], x))
    q = dense(p["wuq"], cq).reshape(b, s, h, cfg.qk_nope_dim + cfg.qk_rope_dim)
    q_nope, q_rope = q[..., : cfg.qk_nope_dim], q[..., cfg.qk_nope_dim:]
    q_rope = apply_rope(q_rope, positions, cfg.qk_rope_dim, cfg.rope_theta)
    dkv = dense(p["wdkv"], x)
    ckv = rmsnorm(p["kv_norm"], dkv[..., : cfg.kv_lora_rank])
    krope = dkv[..., cfg.kv_lora_rank:][:, :, None, :]  # (B,S,1,dr)
    krope = apply_rope(krope, positions, cfg.qk_rope_dim, cfg.rope_theta)
    return q_nope, q_rope, ckv, krope[:, :, 0, :]


def _mla_attend(p, cfg: MLAConfig, q_nope, q_rope, ckv, krope, mask, dtype):
    """q_*: (B,S,H,*); ckv: (B,T,dc); krope: (B,T,dr) -> (B,S,H*v)."""
    b, s, h = q_nope.shape[:3]
    k_nope = dense(p["wuk"], ckv).reshape(b, -1, h, cfg.qk_nope_dim)
    v = dense(p["wuv"], ckv).reshape(b, -1, h, cfg.v_head_dim)
    scores = (jnp.einsum("bshd,bthd->bhst", q_nope, k_nope)
              + jnp.einsum("bshd,btd->bhst", q_rope, krope))
    scores = scores / np.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
    scores = scores + _mask_bias(mask, scores.dtype)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(dtype)
    out = jnp.einsum("bhst,bthd->bshd", probs, v).reshape(b, s, -1)
    return dense(p["wo"], out)


def mla_attention(p, cfg: MLAConfig, x, positions,
                  q_chunk: Optional[int] = None) -> jax.Array:
    s = x.shape[1]
    q_nope, q_rope, ckv, krope = _mla_qkv(p, cfg, x, positions)
    if q_chunk and s > q_chunk and s % q_chunk == 0:
        # chunked queries: expand k/v once, scan score blocks (flash-coarse)
        b, _, h = q_nope.shape[:3]
        k_nope = dense(p["wuk"], ckv).reshape(b, -1, h, cfg.qk_nope_dim)
        v = dense(p["wuv"], ckv).reshape(b, -1, h, cfg.v_head_dim)
        nc = s // q_chunk
        qn = q_nope.reshape(b, nc, q_chunk, h, -1).transpose(1, 0, 2, 3, 4)
        qr = q_rope.reshape(b, nc, q_chunk, h, -1).transpose(1, 0, 2, 3, 4)

        def body(_, inp):
            qn_b, qr_b, i = inp
            scores = (jnp.einsum("bshd,bthd->bhst", qn_b, k_nope)
                      + jnp.einsum("bshd,btd->bhst", qr_b, krope))
            scores = scores / np.sqrt(cfg.qk_nope_dim + cfg.qk_rope_dim)
            mask = make_mask(q_chunk, s, "causal", q_offset=i * q_chunk)
            scores = scores + _mask_bias(mask, scores.dtype)
            probs = jax.nn.softmax(scores.astype(jnp.float32),
                                   axis=-1).astype(x.dtype)
            return None, jnp.einsum("bhst,bthd->bshd", probs, v)

        _, outs = jax.lax.scan(body, None, (qn, qr, jnp.arange(nc)))
        out = outs.transpose(1, 0, 2, 3, 4).reshape(b, s, -1)
        return dense(p["wo"], out)
    mask = make_mask(s, s, "causal")
    return _mla_attend(p, cfg, q_nope, q_rope, ckv, krope, mask, x.dtype)


def init_mla_cache(batch: int, seq: int, cfg: MLAConfig, dtype=jnp.bfloat16):
    return {"ckv": jnp.zeros((batch, seq, cfg.kv_lora_rank), dtype),
            "krope": jnp.zeros((batch, seq, cfg.qk_rope_dim), dtype)}


def mla_decode(p, cfg: MLAConfig, x, cache, pos):
    b = x.shape[0]
    positions = jnp.full((b, 1), pos, jnp.int32)
    q_nope, q_rope, ckv_new, krope_new = _mla_qkv(p, cfg, x, positions)
    ckv = jax.lax.dynamic_update_slice_in_dim(
        cache["ckv"], ckv_new.astype(cache["ckv"].dtype), pos, axis=1)
    krope = jax.lax.dynamic_update_slice_in_dim(
        cache["krope"], krope_new.astype(cache["krope"].dtype), pos, axis=1)
    t = ckv.shape[1]
    mask = (jnp.arange(t) <= pos)[None, :]
    out = _mla_attend(p, cfg, q_nope, q_rope, ckv.astype(x.dtype),
                      krope.astype(x.dtype), mask, x.dtype)
    return out, {"ckv": ckv, "krope": krope}


# ---------------------------------------------------------------------------
# Cross-attention (seamless-m4t enc-dec)
# ---------------------------------------------------------------------------

def init_cross_attention(key, cfg: AttnConfig):
    return init_attention(key, cfg)


def _cross_kv(p, cfg: AttnConfig, enc_out):
    hk, hd = cfg.n_kv_heads, cfg.head_dim
    k = _split_heads(dense(p["wk"], enc_out), hk, hd)
    v = _split_heads(dense(p["wv"], enc_out), hk, hd)
    return k, v


def cross_attention(p, cfg: AttnConfig, x, enc_out,
                    q_chunk: Optional[int] = None) -> jax.Array:
    """x: (B,S,D) decoder states; enc_out: (B,T,D).  No mask (full)."""
    b, s, _ = x.shape
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(dense(p["wq"], x), h, hd).reshape(b, s, hk, h // hk, hd)
    k, v = _cross_kv(p, cfg, enc_out)

    def attend(q_blk):
        scores = _gqa_scores(q_blk, k) / np.sqrt(hd)
        probs = jax.nn.softmax(scores.astype(jnp.float32),
                               axis=-1).astype(x.dtype)
        return _gqa_out(probs, v)

    if q_chunk and s > q_chunk and s % q_chunk == 0:
        nc = s // q_chunk
        qb = q.reshape(b, nc, q_chunk, hk, h // hk, hd).transpose(
            1, 0, 2, 3, 4, 5)
        _, outs = jax.lax.scan(lambda _, qq: (None, attend(qq)), None, qb)
        out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(b, s, h * hd)
    else:
        out = attend(q).reshape(b, s, h * hd)
    return dense(p["wo"], out)


def make_cross_cache(p, cfg: AttnConfig, enc_out, dtype=jnp.bfloat16):
    k, v = _cross_kv(p, cfg, enc_out)
    return {"k": k.astype(dtype), "v": v.astype(dtype)}


def cross_attention_decode(p, cfg: AttnConfig, x, cross_cache):
    b = x.shape[0]
    h, hk, hd = cfg.n_heads, cfg.n_kv_heads, cfg.head_dim
    q = _split_heads(dense(p["wq"], x), h, hd).reshape(b, 1, hk, h // hk, hd)
    k, v = cross_cache["k"].astype(x.dtype), cross_cache["v"].astype(x.dtype)
    scores = _gqa_scores(q, k) / np.sqrt(hd)
    probs = jax.nn.softmax(scores.astype(jnp.float32), axis=-1).astype(x.dtype)
    out = _gqa_out(probs, v).reshape(b, 1, h * hd)
    return dense(p["wo"], out)
