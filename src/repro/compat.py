"""Version compatibility shims for the supported jax range.

``shard_map`` moved twice: it lives in ``jax.experimental.shard_map`` up to
~0.4.x, is re-exported as ``jax.shard_map`` from 0.6, and its replication
check kwarg was renamed ``check_rep`` -> ``check_vma`` along the way.  The
shim below resolves whichever implementation exists and translates the
kwarg, so callers can uniformly write

    from repro.compat import shard_map
    shard_map(fn, mesh=mesh, in_specs=..., out_specs=..., check_vma=False)

on any supported jax.
"""

from __future__ import annotations

import inspect

import jax

__all__ = ["shard_map"]

try:
    _shard_map_impl = jax.shard_map  # jax >= 0.6
except AttributeError:
    from jax.experimental.shard_map import shard_map as _shard_map_impl

_PARAMS = inspect.signature(_shard_map_impl).parameters


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=None, **kwargs):
    """``jax.shard_map`` with the ``check_rep``/``check_vma`` rename handled.

    ``check_vma`` (new name) is accepted regardless of the underlying jax;
    on older versions it is forwarded as ``check_rep``.  ``None`` leaves the
    implementation default in place.
    """
    if check_vma is not None:
        if "check_vma" in _PARAMS:
            kwargs["check_vma"] = check_vma
        elif "check_rep" in _PARAMS:
            kwargs["check_rep"] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)
