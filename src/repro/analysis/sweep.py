"""Repo-wide invariant sweep: every registered algorithm x executor x wire.

Drives the four passes in :mod:`repro.analysis.hlo` over a tiny stock
problem (d = 2*PACK_BLOCK so the packed wire formats get real windows) on
a CPU host mesh, so ``python -m repro.analysis --all`` proves -- without
running a training step -- that:

* each compiled step ships no more collectives than its gossip executor's
  declared :class:`~repro.core.gossip.GossipBudget` times the algorithm's
  registered ``comm_rounds`` (and *zero* for the centralized algorithms);
* under ``wire='packed_bits'`` only bf16/u16/u32 buffers cross the wire
  (f32 capped at the codec's declared per-window overhead);
* every algorithm's chunk runner donates all carried state leaves and
  never retraces across a schedule period.

The harness deliberately mirrors the repo's own test idiom (the
test_wire_pack / test_runtime problem shapes), so a budget violation here
reproduces in one of those tests' terms.
"""

from __future__ import annotations

import dataclasses
import json
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import repro.api as api
from repro.api import ExperimentSpec, build
from repro.core import FLEET_DENSE_GATE
from repro.core import wire_formats as WF
from repro.core.registry import algorithm_info, list_algorithms
from repro.data import minibatch_source

from . import hlo as H

__all__ = [
    "Case",
    "census_matrix",
    "run_census_case",
    "probe_algorithm",
    "run_all",
    "repo_root",
    "make_agent_mesh",
]

# census problem: big enough for two real PACK_BLOCK windows per leaf
N_AGENTS = 4
D_CENSUS = 2 * WF.PACK_BLOCK

# probe problem (donation / retrace): the chunked-runtime test shape
D_PROBE, M_PROBE, B_PROBE = 16, 32, 3

# schedule specs used to prove traced-W_t invariance (period 3 each)
CHURN_SCHEDULE = "dropout:rate=0.25,period=3"
DIRECTED_SCHEDULE = "directed:one_way,rate=0.2,period=3"


def repo_root() -> Path:
    """<repo>/src/repro/api.py -> <repo>."""
    return Path(api.__file__).resolve().parents[2]


def make_agent_mesh(n: int = N_AGENTS) -> Mesh:
    devs = jax.devices()
    if len(devs) < n:
        raise RuntimeError(
            f"census needs {n} devices for the agent mesh, have "
            f"{len(devs)} -- run via `python -m repro.analysis` (it forces "
            "host devices before jax init) or set "
            "--xla_force_host_platform_device_count")
    return Mesh(np.asarray(devs[:n]), ("data",))


def census_loss(p, b):
    return jnp.mean((p["w"] - b) ** 2)


@dataclasses.dataclass(frozen=True)
class Case:
    label: str
    spec: ExperimentSpec
    needs_mesh: bool


def _spec_for(algo: str, **kw) -> ExperimentSpec:
    base = dict(algo=algo, n_agents=N_AGENTS, topology="ring",
                topology_weights="metropolis", compressor="block_top_k",
                frac=0.25, comm_backend="ref", interpret=True, eta=0.1)
    if algorithm_info(algo).dp:
        base.update(tau=5.0, sigma_p=0.01)
    base.update(kw)
    return ExperimentSpec(**base)


def census_matrix(quick: bool = False) -> List[Case]:
    """Every registered algorithm x {dense, ring, packed} x {f32,
    packed_bits} x {static, scheduled}, minus invalid combos (dense gossip
    has no packed form; uncompressed/centralized algorithms have no codec;
    directed schedules are push-sum-only)."""
    engine_algos = [a for a in list_algorithms()
                    if (i := algorithm_info(a)).decentralized
                    and i.compressed and a != "dp-csgp"]
    central = [a for a in list_algorithms()
               if not algorithm_info(a).decentralized]
    if quick:
        engine_algos = ["porter-gc"]
        central = central[:1]

    cases: List[Case] = []
    for a in engine_algos:
        cases += [
            Case(f"{a}/dense/f32", _spec_for(a, gossip_mode="dense"), False),
            Case(f"{a}/ring/f32", _spec_for(a, gossip_mode="ring"), True),
            Case(f"{a}/packed/f32", _spec_for(a, gossip_mode="packed"),
                 True),
            Case(f"{a}/ring/packed_bits",
                 _spec_for(a, gossip_mode="ring", wire="packed_bits"), True),
            Case(f"{a}/packed/packed_bits",
                 _spec_for(a, gossip_mode="packed", wire="packed_bits"),
                 True),
        ]
    if not quick:
        cases += [
            Case("dsgd/dense/f32", _spec_for("dsgd", gossip_mode="dense"),
                 False),
            Case("dsgd/ring/f32", _spec_for("dsgd", gossip_mode="ring"),
                 True),
            Case("dsgd/packed/f32", _spec_for("dsgd", gossip_mode="packed"),
                 True),
        ]
    for a in central:
        cases.append(Case(f"{a}/none/f32", _spec_for(a), False))

    # directed (column-stochastic) schedules ride push-sum only
    cases.append(
        Case("dp-csgp/ring/packed_bits/directed",
             _spec_for("dp-csgp", gossip_mode="ring", wire="packed_bits",
                       topology_schedule="directed:ring_skips"), True))
    if not quick:
        cases += [
            Case("dp-csgp/dense/f32/directed",
                 _spec_for("dp-csgp", gossip_mode="dense",
                           topology_schedule=DIRECTED_SCHEDULE), False),
            Case("dp-csgp/packed/packed_bits/directed",
                 _spec_for("dp-csgp", gossip_mode="packed",
                           wire="packed_bits",
                           topology_schedule=DIRECTED_SCHEDULE), True),
            # traced-W_t schedules must not change the census
            Case("porter-gc/ring/packed_bits/rotate",
                 _spec_for("porter-gc", gossip_mode="ring",
                           wire="packed_bits",
                           topology_schedule=
                           "rotate:ring/metropolis+ring/lazy"), True),
            Case("porter-gc/packed/f32/churn",
                 _spec_for("porter-gc", gossip_mode="packed",
                           topology_schedule=CHURN_SCHEDULE), True),
            Case("porter-gc/ring/packed_bits/qsgd",
                 _spec_for("porter-gc", gossip_mode="ring",
                           wire="packed_bits", compressor="qsgd",
                           compressor_kwargs={"levels": 16}), True),
        ]
    # qsgd packed: the u32-word + f32-scale dtype-flow corner
    cases.append(
        Case("porter-gc/packed/packed_bits/qsgd",
             _spec_for("porter-gc", gossip_mode="packed",
                       wire="packed_bits", compressor="qsgd",
                       compressor_kwargs={"levels": 16}), True))
    # mixed-precision planes: with plane_dtype='bf16' the gossip
    # collectives themselves must ship <= 2 B/elem (dtype flow runs on
    # these even without a packed-bits codec -- see run_census_case);
    # the push-sum case additionally proves the f32-exact weight rider
    # stays a bounded scalar, not a hidden dense upcast.
    cases.append(
        Case("porter-gc/ring/f32/bf16planes",
             _spec_for("porter-gc", gossip_mode="ring",
                       plane_dtype="bf16"), True))
    if not quick:
        cases += [
            Case("porter-gc/packed/f32/bf16planes",
                 _spec_for("porter-gc", gossip_mode="packed",
                           plane_dtype="bf16"), True),
            Case("porter-gc/ring/packed_bits/bf16planes",
                 _spec_for("porter-gc", gossip_mode="ring",
                           wire="packed_bits", plane_dtype="bf16"), True),
            Case("dp-csgp/ring/f32/bf16planes/directed",
                 _spec_for("dp-csgp", gossip_mode="ring",
                           plane_dtype="bf16",
                           topology_schedule="directed:ring_skips"), True),
        ]
    # fleet mode: the whole mixing sweep is device-local math (schedule
    # einsum below FLEET_DENSE_GATE, COO scatter-add above), so the
    # unmeshed census must count ZERO collectives -- the fleet budget
    # declares an empty per_leaf table, making every category unbudgeted
    cases.append(Case("porter-gc/fleet/dense",
                      _spec_for("porter-gc", gossip_mode="dense",
                                fleet=True), False))
    if not quick:
        cases += [
            Case("clip21/fleet/dense",
                 _spec_for("clip21", gossip_mode="dense", fleet=True),
                 False),
            Case("subgrad-comp/fleet/coo",
                 _spec_for("subgrad-comp", gossip_mode="dense", fleet=True,
                           n_agents=2 * FLEET_DENSE_GATE), False),
        ]
    return cases


def _agent_shardings(mesh: Mesh, tree, n: int):
    """Leading-axis-``n`` leaves shard over 'data'; the rest replicate."""
    def spec(l):
        if getattr(l, "ndim", 0) >= 1 and l.shape[0] == n:
            return NamedSharding(mesh, P("data", *([None] * (l.ndim - 1))))
        return NamedSharding(mesh, P())
    return jax.tree_util.tree_map(spec, tree)


def lowered_step_text(algo, *, mesh: Optional[Mesh], n: int = N_AGENTS,
                      d: int = D_CENSUS) -> str:
    """Compile ``algo.step`` on the stock census problem; return its
    optimized HLO."""
    params0 = {"w": jnp.zeros(d)}
    state = algo.init(params0)
    batch = jnp.zeros((n, 1, d))
    key = jax.random.PRNGKey(0)
    if mesh is not None:
        state = jax.device_put(state, _agent_shardings(mesh, state, n))
        batch = jax.device_put(batch, NamedSharding(mesh, P("data", None,
                                                            None)))
        key = jax.device_put(key, NamedSharding(mesh, P()))
    return jax.jit(algo.step).lower(state, batch, key).compile().as_text()


def run_census_case(case: Case, mesh: Optional[Mesh]) -> dict:
    """Lower one spec and run the census (+ dtype flow for packed wires)."""
    rec = {"label": case.label, "algo": case.spec.algo,
           "gossip": case.spec.gossip_mode, "wire": case.spec.wire,
           "schedule": case.spec.topology_schedule, "ok": False}
    use_mesh = mesh if case.needs_mesh else None
    try:
        algo = build(case.spec, census_loss, mesh=use_mesh)
        hlo_text = lowered_step_text(algo, mesh=use_mesh,
                                     n=case.spec.n_agents)
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
        return rec

    info = algorithm_info(case.spec.algo)
    budget = (getattr(algo.mixer, "budget", None) if algo.mixer is not None
              else H.NO_GOSSIP_BUDGET)
    n_leaves = 1  # the census problem gossips a single {'w'} leaf
    census = H.check_census(
        hlo_text, budget=budget, n_leaves=n_leaves,
        comm_rounds=info.comm_rounds, meshed=use_mesh is not None)
    rec["census"] = census.to_json()
    ok = census.ok

    if case.spec.wire == "packed_bits":
        codec = algo.engine.mixer.wire_codec
        allowance = (info.comm_rounds * N_AGENTS * n_leaves
                     * codec.overhead_bytes(D_CENSUS) + 64)
        flow = H.check_dtype_flow(hlo_text,
                                  f32_allowance_bytes=allowance)
        rec["dtype_flow"] = flow.to_json()
        ok = ok and flow.ok
    elif case.spec.plane_dtype is not None:
        # bf16 state planes without a packed-bits codec: the plane wire is
        # the collectives themselves, so the same <=2 B/elem contract
        # applies directly.  The f32 allowance covers only scalar riders
        # (push-sum weight words, traced band weights) -- one leaked dense
        # f32 plane is 4*D_CENSUS = 8 KiB and trips it immediately.
        flow = H.check_dtype_flow(hlo_text, f32_allowance_bytes=1024)
        rec["dtype_flow"] = flow.to_json()
        ok = ok and flow.ok
    rec["ok"] = ok
    return rec


# ---------------------------------------------------------------------------
# Donation + retrace probes (mesh-free; the chunked-runtime problem).
# ---------------------------------------------------------------------------

def probe_loss(params, batch):
    f, l = batch
    f, l = jnp.atleast_2d(f), jnp.atleast_1d(l)
    logits = f @ params["w"] + params["b"]
    return jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits)))


def probe_problem(seed: int = 0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=D_PROBE)
    f = rng.normal(size=(N_AGENTS, M_PROBE, D_PROBE)).astype(np.float32)
    l = (f @ w_true > 0).astype(np.float32)
    params0 = {"w": jnp.zeros(D_PROBE), "b": jnp.zeros(())}
    return params0, minibatch_source(f, l, B_PROBE)


def probe_algorithm(name: str) -> dict:
    """Donation + schedule-period retrace for one algorithm (dense gossip;
    the runner contract is executor-independent)."""
    rec = {"algo": name, "ok": False}
    info = algorithm_info(name)
    params0, source = probe_problem()
    try:
        algo = build(_spec_for(name, n_agents=N_AGENTS,
                               gossip_mode="dense"), probe_loss)
        donation = H.check_donation(algo, source, params0, chunk=2)
        rec["donation"] = donation.to_json()

        if info.decentralized:
            sched = (DIRECTED_SCHEDULE if name == "dp-csgp"
                     else CHURN_SCHEDULE)
            algo_s = build(_spec_for(name, n_agents=N_AGENTS,
                                     gossip_mode="dense",
                                     topology_schedule=sched), probe_loss)
            retrace = H.check_retrace(algo_s, source, params0,
                                      chunks=(2, 3), period=3)
            rec["schedule"] = sched
        else:
            retrace = H.check_retrace(algo, source, params0,
                                      chunks=(2, 3), period=1)
        rec["retrace"] = retrace.to_json()
        rec["ok"] = donation.ok and retrace.ok
    except Exception as e:
        rec["error"] = f"{type(e).__name__}: {e}"
    return rec


# ---------------------------------------------------------------------------
# Top-level driver.
# ---------------------------------------------------------------------------

def run_all(*, quick: bool = False, mesh: Optional[Mesh] = None,
            do_census: bool = True, do_probes: bool = True,
            do_lint: bool = True, do_tables: bool = True,
            algos: Optional[Sequence[str]] = None,
            log=print) -> dict:
    """The ``--all`` sweep: census + probes + AST lint + table checks.

    Returns the machine-readable report dict; ``report['ok']`` aggregates.
    """
    from . import ast_rules

    report: dict = {"quick": quick}
    failures: List[str] = []

    if do_census:
        if mesh is None:
            mesh = make_agent_mesh()
        records = []
        cases = census_matrix(quick=quick)
        if algos:
            cases = [c for c in cases if c.spec.algo in set(algos)]
        for case in cases:
            rec = run_census_case(case, mesh)
            records.append(rec)
            status = "ok" if rec["ok"] else "FAIL"
            counts = rec.get("census", {}).get("counts", {})
            shown = {k: v for k, v in counts.items() if v} or {}
            log(f"[census {status}] {rec['label']:<42s} {shown}"
                + (f"  {rec.get('error', '')}" if not rec["ok"] else ""))
            if not rec["ok"]:
                failures.append(f"census:{rec['label']}")
        report["census"] = records

    if do_probes:
        probes = []
        names = list(algos) if algos else sorted(list_algorithms())
        if quick:
            names = names[:3]
        for name in names:
            rec = probe_algorithm(name)
            probes.append(rec)
            status = "ok" if rec["ok"] else "FAIL"
            log(f"[probe  {status}] {name:<42s} "
                f"donated={rec.get('donation', {}).get('aliased', '?')} "
                f"executables={rec.get('retrace', {}).get('executables')}"
                + (f"  {rec.get('error', '')}" if not rec["ok"] else ""))
            if not rec["ok"]:
                failures.append(f"probe:{name}")
        report["probes"] = probes

    if do_lint:
        root = repo_root()
        targets = [root / "src", root / "benchmarks", root / "examples"]
        findings = ast_rules.lint_paths([t for t in targets if t.exists()],
                                        root=root)
        for f in findings:
            log(f"[lint   FAIL] {f}")
            failures.append(f"lint:{f.path}:{f.line}")
        log(f"[lint] {len(findings)} finding(s) over "
            f"{', '.join(t.name for t in targets if t.exists())}")
        report["lint"] = [f.to_json() for f in findings]

    if do_tables:
        tfindings = ast_rules.check_tables()
        for f in tfindings:
            log(f"[tables FAIL] {f}")
            failures.append(f"tables:{f.path}")
        log(f"[tables] {len(tfindings)} drift(s)")
        report["tables"] = [f.to_json() for f in tfindings]

    report["failures"] = failures
    report["ok"] = not failures
    return report


def write_report(report: dict, out_path) -> Path:
    out_path = Path(out_path)
    out_path.parent.mkdir(parents=True, exist_ok=True)
    out_path.write_text(json.dumps(report, indent=2))
    return out_path
