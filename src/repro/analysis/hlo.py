"""Compiled-program invariant passes: census, donation, retrace, dtype flow.

The byte model in EXPERIMENTS.md is only honest if the compiled programs
actually ship what it claims.  PRs 3, 6 and 7 each pinned that with
hand-rolled HLO string matching scattered across four test files; this
module is the canonical home of those passes, shared by the tests, the
dry-run tool (``--analyze``) and the ``python -m repro.analysis`` sweep.

Four passes, all operating on a lowered/compiled executable without running
a training step:

* **Collective census** (:func:`check_census`): count every collective op
  in the optimized HLO and bound it by the *declared* budget -- the gossip
  executor's :class:`repro.core.gossip.GossipBudget` times the leaf count
  times the algorithm's registered ``comm_rounds``.  Ops are attributed by
  the ``source_file`` HLO metadata: collectives issued from
  ``core/gossip.py`` (the only module that calls ``lax.ppermute`` /
  ``lax.all_gather``) are judged against the budget; partitioner-inserted
  collectives (GSPMD resharding) are held to a separate rule -- they must
  be all-reduces (cross-agent metric and gradient reductions) or gathers
  feeding the compressor's TopK custom-call, anything else means sharded
  state is being silently materialized.
* **Donation** (:func:`donation_hlo_report` / :func:`check_donation`):
  every carried state leaf must be input-output aliased in the lowered
  module, and the call-site buffers must actually be consumed (no read
  after donation).
* **Retrace** (:func:`check_retrace`): one executable per chunk size across
  a whole schedule period -- the traced ``W_t`` gather and round index must
  never trigger recompilation.
* **Dtype flow** (:func:`check_dtype_flow`): under ``wire='packed_bits'``
  the shipped buffers stay bf16/u16/u32 end-to-end; a dense-f32 collective
  sneaking between pack and ship defeats the wire format silently.

Parsing helpers (:func:`parse_collectives`, :func:`shape_bytes`) moved here
from ``repro.launch.dryrun``, which now re-exports them.  This module is
import-safe before jax backend initialization (jax is imported, never
queried, at import time), so ``repro._env.ensure_host_device_count`` calls
still win the race.
"""

from __future__ import annotations

import dataclasses
import re
from typing import Dict, List, Mapping, Optional, Sequence, Tuple

from repro.core.gossip import GossipBudget

__all__ = [
    "COLLECTIVES",
    "WIRE_FACTOR",
    "NO_GOSSIP_BUDGET",
    "GOSSIP_SOURCES",
    "SPMD_GATHER_SOURCES",
    "CollectiveOp",
    "CensusReport",
    "DonationReport",
    "RetraceReport",
    "DtypeFlowReport",
    "shape_bytes",
    "parse_collectives",
    "collective_ops",
    "collective_counts",
    "check_census",
    "check_dtype_flow",
    "donation_hlo_report",
    "check_donation",
    "check_retrace",
]

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
# effective wire traffic per byte of result (all-reduce = RS + AG)
WIRE_FACTOR = {"all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
               "all-to-all": 1.0, "collective-permute": 1.0}

# centralized algorithms (dp-sgd, soteriafl) gossip nothing: any collective
# in their compiled step is a violation
NO_GOSSIP_BUDGET = GossipBudget(
    executor="none", per_leaf={},
    note="no gossip executor; the step must compile collective-free")

# the only module that issues collectives by hand (lax.ppermute /
# lax.all_gather inside shard_map); everything else in the HLO is
# partitioner-inserted
GOSSIP_SOURCES = ("core/gossip.py",)

# partitioner gathers tolerated outside the gossip executor: GSPMD cannot
# shard the TopK custom-call along the agent axis, so block_top_k's operand
# is gathered and TopK runs replicated
SPMD_GATHER_SOURCES = ("core/compression.py",)

_DTYPE_BYTES = {"f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1,
                "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4,
                "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1, "c64": 8,
                "c128": 16, "token": 0}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(r"^%?[\w.\-]+\s*=\s*(.+?)\s+([\w\-]+)\(")
_SOURCE_RE = re.compile(r'source_file="([^"]+)"')


def _norm_source(path: str) -> str:
    """Repo-relative source tag: '.../src/repro/core/gossip.py' ->
    'core/gossip.py'; unknown layouts fall back to the basename."""
    if "/repro/" in path:
        return path.rsplit("/repro/", 1)[1]
    return path.rsplit("/", 1)[-1]


def shape_bytes(text: str) -> int:
    """Sum bytes of every typed shape in an HLO result-type string."""
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


# legacy alias kept for the dryrun-era import sites
_shape_bytes = shape_bytes


@dataclasses.dataclass(frozen=True)
class CollectiveOp:
    """One collective instruction in the optimized HLO."""

    category: str                 # canonical name from COLLECTIVES
    op: str                       # raw op token ('all-gather-start', ...)
    result_bytes: int
    dtypes: Tuple[str, ...]       # dtype tokens in the result type
    dtype_bytes: Mapping[str, int]  # per-dtype result bytes
    source: str = ""              # repo-relative source_file metadata

    @property
    def gossip(self) -> bool:
        """Issued by a gossip executor (vs. partitioner-inserted)."""
        return self.source in GOSSIP_SOURCES


def _dtype_split(text: str) -> Dict[str, int]:
    out: Dict[str, int] = {}
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        out[dt] = out.get(dt, 0) + n * _DTYPE_BYTES[dt]
    return out


def collective_ops(hlo_text: str) -> List[CollectiveOp]:
    """Every collective op in the HLO, with result bytes split per dtype.

    Async pairs are counted at ``-start`` (the ``-done`` re-states the same
    transfer); sync ops count once.
    """
    ops: List[CollectiveOp] = []
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line.strip())
        if not m:
            continue
        result_type, op = m.groups()
        for c in COLLECTIVES:
            if op == c or op.startswith(c + "-start") or op == c + "-done":
                if op.endswith("-done"):
                    break  # counted at -start
                per = _dtype_split(result_type)
                src = _SOURCE_RE.search(line)
                ops.append(CollectiveOp(
                    category=c, op=op,
                    result_bytes=sum(per.values()),
                    dtypes=tuple(sorted(per)), dtype_bytes=per,
                    source=_norm_source(src.group(1)) if src else ""))
                break
    return ops


def parse_collectives(hlo_text: str):
    """Per-category result bytes + op counts for every collective in the
    HLO (the dryrun-era aggregate view, kept signature-compatible)."""
    out = {c: {"bytes": 0, "count": 0} for c in COLLECTIVES}
    for op in collective_ops(hlo_text):
        out[op.category]["bytes"] += op.result_bytes
        out[op.category]["count"] += 1
    return out


def collective_counts(hlo_text: str) -> Dict[str, int]:
    """Per-category op counts only (zero categories included)."""
    return {c: v["count"] for c, v in parse_collectives(hlo_text).items()}


# ---------------------------------------------------------------------------
# Pass 1: collective census against declared budgets.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CensusReport:
    """Measured collective counts vs. the declared budget for one step.

    ``counts``/``bytes`` cover the gossip-attributed collectives (those
    whose ``source_file`` metadata points into :data:`GOSSIP_SOURCES`);
    ``spmd_counts``/``spmd_sources`` cover partitioner-inserted ones.
    ``enforced`` is False for SPMD-partitioner-dependent executors (dense
    einsum gossip under a mesh): their gossip counts are reported, never
    judged.  The partitioner rule (all-reduce or allowlisted gather only)
    is judged whenever a budget is present.
    """

    counts: Dict[str, int]
    bytes: Dict[str, int]
    bound: Optional[Dict[str, int]]
    budget: Optional[GossipBudget]
    enforced: bool
    spmd_counts: Dict[str, int] = dataclasses.field(default_factory=dict)
    spmd_sources: Dict[str, int] = dataclasses.field(default_factory=dict)
    violations: List[str] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_json(self) -> dict:
        return {
            "counts": self.counts, "bytes": self.bytes, "bound": self.bound,
            "executor": self.budget.executor if self.budget else None,
            "enforced": self.enforced,
            "spmd_counts": self.spmd_counts,
            "spmd_sources": self.spmd_sources,
            "violations": self.violations,
            "ok": self.ok,
        }


def check_census(hlo_text: str, *, mixer=None,
                 budget: Optional[GossipBudget] = None,
                 n_leaves: int = 1, comm_rounds: int = 1,
                 enforce: Optional[bool] = None,
                 meshed: bool = True,
                 spmd_gather_sources: Sequence[str] = SPMD_GATHER_SOURCES,
                 spmd_scalar_bytes: int = 16,
                 spmd_rule: bool = True,
                 ) -> CensusReport:
    """Count collectives in ``hlo_text`` and bound them by the budget.

    ``budget`` defaults to ``mixer.budget``.  Collectives split by HLO
    ``source_file`` attribution:

    * gossip-attributed (issued from :data:`GOSSIP_SOURCES`): per-step
      ceiling is ``budget.per_leaf[cat] * n_leaves * comm_rounds``; any op
      of a category absent from the budget is a violation.  ``enforce``
      overrides the default policy (skip enforcement for
      ``spmd_dependent`` budgets when ``meshed``).
    * partitioner-inserted (everything else): must be an all-reduce
      (cross-agent metric / gradient reductions the agent-axis sharding
      legitimately induces), an all-gather attributed to
      ``spmd_gather_sources`` (the compressor's unpartitionable TopK), or
      a scalar-sized op of at most ``spmd_scalar_bytes`` (PRNG key
      plumbing for per-agent DP noise shows up as 4-8 byte
      collective-permutes).  Any other partitioner collective means
      sharded state is being materialized behind the executor's back.

    The partitioner rule is calibrated for agent-axes-only meshes (the
    sweep's 4-agent census mesh).  On meshes with a model axis GSPMD
    legitimately gathers sharded weights/activations for the
    model-parallel matmuls -- callers lowering on such meshes pass
    ``spmd_rule=False`` (launch/dryrun does); the partitioner ops are
    still recorded in ``spmd_counts``/``spmd_sources``, just not judged.

    With no budget at all the census is report-only.
    """
    if budget is None and mixer is not None:
        budget = getattr(mixer, "budget", None)
    ops = collective_ops(hlo_text)
    gossip_ops = [op for op in ops if op.gossip]
    spmd_ops = [op for op in ops if not op.gossip]

    counts = {c: 0 for c in COLLECTIVES}
    nbytes = {c: 0 for c in COLLECTIVES}
    for op in gossip_ops:
        counts[op.category] += 1
        nbytes[op.category] += op.result_bytes
    spmd_counts: Dict[str, int] = {}
    spmd_sources: Dict[str, int] = {}
    for op in spmd_ops:
        spmd_counts[op.category] = spmd_counts.get(op.category, 0) + 1
        spmd_sources[op.source] = spmd_sources.get(op.source, 0) + 1

    if budget is None:
        return CensusReport(counts=counts, bytes=nbytes, bound=None,
                            budget=None, enforced=False,
                            spmd_counts=spmd_counts,
                            spmd_sources=spmd_sources)

    enforced = (not (budget.spmd_dependent and meshed)
                if enforce is None else enforce)
    bound = budget.bound(n_leaves, comm_rounds)
    violations: List[str] = []
    if enforced:
        for cat, count in counts.items():
            if not count:
                continue
            ceiling = bound.get(cat)
            if ceiling is None:
                violations.append(
                    f"unbudgeted collective {cat!r}: {count} gossip op(s) "
                    f"but executor {budget.executor!r} declares none")
            elif count > ceiling:
                violations.append(
                    f"{cat}: {count} gossip op(s) > budget {ceiling} "
                    f"({budget.per_leaf[cat]}/leaf x {n_leaves} leaves x "
                    f"{comm_rounds} round(s), executor "
                    f"{budget.executor!r})")
    for op in spmd_ops:
        if not spmd_rule:
            break
        if op.category == "all-reduce":
            continue
        if (op.category == "all-gather"
                and op.source in spmd_gather_sources):
            continue
        if op.result_bytes <= spmd_scalar_bytes:
            continue
        violations.append(
            f"partitioner-inserted {op.category} "
            f"({op.result_bytes} bytes, source "
            f"{op.source or 'unattributed'!r}) -- only all-reduce "
            "reductions and the compressor TopK gather are expected "
            "outside the gossip executor")
    return CensusReport(counts=counts, bytes=nbytes, bound=bound,
                        budget=budget, enforced=enforced,
                        spmd_counts=spmd_counts, spmd_sources=spmd_sources,
                        violations=violations)


# ---------------------------------------------------------------------------
# Pass 2: dtype flow -- packed wire buffers never upcast to dense f32.
# ---------------------------------------------------------------------------

PACKED_WIRE_DTYPES = ("bf16", "u16", "u32", "s32")


@dataclasses.dataclass
class DtypeFlowReport:
    """Per-dtype bytes crossing collectives, judged against the packed-wire
    contract: payload stays in packed dtypes; f32 on the wire is capped by
    ``f32_allowance_bytes`` (the QSGD per-window scales and the push-sum
    weight word are legitimate, bounded f32 riders)."""

    dtype_bytes: Dict[str, int]
    packed_bytes: int
    f32_bytes: int
    f32_allowance_bytes: int
    violations: List[str] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_json(self) -> dict:
        return {
            "dtype_bytes": self.dtype_bytes,
            "packed_bytes": self.packed_bytes,
            "f32_bytes": self.f32_bytes,
            "f32_allowance_bytes": self.f32_allowance_bytes,
            "violations": self.violations, "ok": self.ok,
        }


def check_dtype_flow(hlo_text: str, *, f32_allowance_bytes: int = 0,
                     allowed: Sequence[str] = PACKED_WIRE_DTYPES,
                     require_packed: bool = True,
                     sources: Optional[Sequence[str]] = GOSSIP_SOURCES,
                     ) -> DtypeFlowReport:
    """Under ``wire='packed_bits'`` only packed dtypes may cross the wire.

    Sums collective result bytes per dtype over the wire collectives --
    those attributed to ``sources`` (default: the gossip executor; pass
    ``sources=None`` to take every collective, e.g. for synthetic HLO).
    Partitioner metric reductions are f32 by design and are the census'
    business, not the wire contract's.  Violations: any dtype outside
    ``allowed`` + {f32}; f32 beyond the allowance (QSGD ships one f32 scale
    per window as a separate buffer -- size it via
    ``wire_format.overhead_bytes(d) * n_agents`` and add a few words for
    the push-sum weight); and, when ``require_packed``, a program with
    wire collectives but none in a packed dtype (the check would be
    vacuous).
    """
    totals: Dict[str, int] = {}
    for op in collective_ops(hlo_text):
        if sources is not None and op.source not in sources:
            continue
        for dt, b in op.dtype_bytes.items():
            totals[dt] = totals.get(dt, 0) + b
    packed = sum(b for dt, b in totals.items() if dt in allowed)
    f32 = totals.get("f32", 0)
    violations: List[str] = []
    for dt, b in sorted(totals.items()):
        if dt in allowed or dt == "f32":
            continue
        violations.append(
            f"collective ships {b} bytes of {dt}; packed wire formats "
            f"allow only {tuple(allowed)} (+ bounded f32 riders)")
    if f32 > f32_allowance_bytes:
        violations.append(
            f"{f32} f32 bytes cross collectives, allowance is "
            f"{f32_allowance_bytes} -- a dense plane is leaking past the "
            "pack/ship boundary")
    if require_packed and totals and not packed:
        violations.append(
            "no packed-dtype (bf16/u16/u32) collective found although the "
            "program ships collectives -- the packed wire path is not "
            "actually in the compiled program")
    return DtypeFlowReport(dtype_bytes=totals, packed_bytes=packed,
                           f32_bytes=f32,
                           f32_allowance_bytes=f32_allowance_bytes,
                           violations=violations)


# ---------------------------------------------------------------------------
# Pass 3: donation -- carried state aliased in, consumed at the call site.
# ---------------------------------------------------------------------------

_DONATION_MARKS = ("tf.aliasing_output", "jax.buffer_donor")


@dataclasses.dataclass
class DonationReport:
    n_state_leaves: int
    aliased: int                      # donation marks in the lowered module
    consumed: Optional[bool] = None   # runtime probe (None = not run)
    reusable: Optional[bool] = None   # outputs stay alive / callable again
    violations: List[str] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_json(self) -> dict:
        return {"n_state_leaves": self.n_state_leaves,
                "aliased": self.aliased, "consumed": self.consumed,
                "reusable": self.reusable,
                "violations": self.violations, "ok": self.ok}


def donation_hlo_report(lowered_text: str,
                        n_state_leaves: int) -> DonationReport:
    """Static leg: every carried state leaf must carry a donation mark
    (``tf.aliasing_output`` input-output alias, or ``jax.buffer_donor``
    when XLA declined the alias) in the lowered module."""
    aliased = sum(lowered_text.count(m) for m in _DONATION_MARKS)
    violations = []
    if aliased < n_state_leaves:
        violations.append(
            f"only {aliased} donation mark(s) for {n_state_leaves} carried "
            "state leaves -- un-donated leaves double the state HBM "
            "footprint per chunk")
    return DonationReport(n_state_leaves=n_state_leaves, aliased=aliased,
                          violations=violations)


def check_donation(algo, source, params0, *, chunk: int = 2,
                   seed: int = 0) -> DonationReport:
    """Static + runtime donation check for ``algo`` under the chunk runner.

    Builds the donating runner, asserts every state leaf is aliased in the
    lowered module, then runs two chunks and probes the buffers: the second
    call's input leaves must be deleted (consumed), its outputs alive.
    The probe starts from the *second* state because ``init`` aliases
    leaves (q_x is x), which would make per-leaf deletion ambiguous.
    """
    import jax
    from repro.launch.runtime import make_runner

    runner = make_runner(algo, source, chunk)
    state_shapes = jax.eval_shape(lambda p: algo.init(p), params0)
    n_leaves = len(jax.tree_util.tree_leaves(state_shapes))
    report = donation_hlo_report(runner.lower(state_shapes).as_text(),
                                 n_leaves)

    state = algo.init(params0)
    mid, _, _ = runner(state, jax.random.PRNGKey(seed), 0)
    final, _, _ = runner(mid, jax.random.PRNGKey(seed + 1), chunk)
    consumed = all(leaf.is_deleted()
                   for leaf in jax.tree_util.tree_leaves(mid))
    reusable = not any(leaf.is_deleted()
                       for leaf in jax.tree_util.tree_leaves(final))
    report.consumed, report.reusable = consumed, reusable
    if not consumed:
        report.violations.append(
            "donated state buffers survive the call -- the executable "
            "aliases on paper but the runtime keeps a live reference "
            "(read after donation)")
    if not reusable:
        report.violations.append(
            "returned state leaves are already deleted -- an output "
            "aliases a buffer the program later donates away")
    return report


# ---------------------------------------------------------------------------
# Pass 4: retrace -- one executable per chunk size across a schedule period.
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RetraceReport:
    executables: Dict[int, Optional[int]]   # chunk -> cache size after runs
    calls_per_chunk: int
    violations: List[str] = dataclasses.field(default_factory=list)

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_json(self) -> dict:
        return {"executables": {str(k): v
                                for k, v in self.executables.items()},
                "calls_per_chunk": self.calls_per_chunk,
                "violations": self.violations, "ok": self.ok}


def check_retrace(algo, source, params0, *, chunks: Sequence[int] = (2, 3),
                  period: int = 1, seed: int = 0,
                  runner_factory=None) -> RetraceReport:
    """Run enough chunks to cross a full schedule period at every chunk
    size and assert each runner compiled exactly one executable -- the
    traced ``W_t`` gather and round offset must never specialize.

    ``runner_factory(algo, source, chunk)`` defaults to the production
    :func:`repro.launch.runtime.make_runner`; the analyzer self-tests
    inject a known-bad runner (``static_argnums`` on the round offset) to
    prove the rule fires."""
    import jax

    if runner_factory is None:
        from repro.launch.runtime import make_runner
        runner_factory = make_runner

    executables: Dict[int, Optional[int]] = {}
    violations: List[str] = []
    n_calls = 0
    for chunk in chunks:
        runner = runner_factory(algo, source, chunk)
        # cover the period boundary plus one extra call past it
        n_calls = max(2, -(-period // chunk) + 1)
        state = algo.init(params0)
        for i in range(n_calls):
            state, _, _ = runner(state, jax.random.PRNGKey(seed),
                                 i * chunk)
        size = runner.cache_size()
        executables[chunk] = size
        if size is not None and size > 1:
            violations.append(
                f"chunk={chunk}: {size} executables after {n_calls} calls "
                f"spanning a period-{period} schedule -- the round index "
                "or W_t table is retracing")
    return RetraceReport(executables=executables, calls_per_chunk=n_calls,
                         violations=violations)
