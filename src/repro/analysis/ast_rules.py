"""AST lint for repo conventions that no runtime test can see.

Three source rules (stdlib-``ast`` only -- importable and runnable without
jax) plus a table-completeness check that does import the repo:

* ``host-escape-in-step``: inside ``step`` / ``*_step`` functions (and
  everything nested in them -- ``lax.scan`` bodies, closures) no host-side
  escape may touch traced values: ``.item()``, stdlib ``time.*`` /
  ``random.*``, ``np.random.*``, or ``float()/int()/bool()`` applied to an
  expression referencing a step parameter.  Under ``jit`` these either
  crash (concretization) or silently pin the trace to host values; either
  way they are bugs the compiler hides until the worst moment.
* ``host-sync-eval`` (benchmarks/ and examples/ only): ``float(jnp.…(…))``
  / ``int(jax.…(…))`` and ``.item()`` force one device round-trip per
  call.  Eval callbacks convert once via ``np.asarray`` at the boundary
  instead -- per-element implicit syncs in report loops are what made the
  pre-PR-4 training loop dispatch-bound.
* ``jax-free-modules``: modules that must win the import race against the
  jax backend (``repro/_env.py``) may not import jax, directly or from.

A finding is suppressed by putting ``analysis: ok`` in a comment on the
flagged line (used sparingly; every use should say why).

:func:`check_tables` closes the registry/contract tables against their
generator dicts: schedule kinds in ``core.mixing`` vs. the ``allowed``
dicts in ``api.resolve_schedule`` / ``api._resolve_directed_schedule``
(AST-extracted -- they are function locals), ``VARIANT_TO_ALGO`` vs. the
registry, and the dryrun ``--variant`` choices vs. ``VARIANT_TO_ALGO``.
"""

from __future__ import annotations

import ast
import dataclasses
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Set, Tuple

__all__ = [
    "LintFinding",
    "JAX_FREE_MODULES",
    "lint_source",
    "lint_file",
    "lint_paths",
    "check_tables",
]

SUPPRESS_TOKEN = "analysis: ok"

# repo-relative module paths that must stay importable before jax backend
# init (they set XLA flags; importing jax first would lock the device count)
JAX_FREE_MODULES = ("src/repro/_env.py",)

_CAST_BUILTINS = {"float", "int", "bool"}


@dataclasses.dataclass(frozen=True)
class LintFinding:
    rule: str
    path: str
    line: int
    message: str

    def __str__(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_json(self) -> dict:
        return dataclasses.asdict(self)


def _suppressed_lines(src: str) -> Set[int]:
    return {i for i, line in enumerate(src.splitlines(), start=1)
            if SUPPRESS_TOKEN in line}


def _import_roots(tree: ast.AST) -> Dict[str, str]:
    """Map bound names to the root module they come from.

    ``import numpy as np`` -> {'np': 'numpy'};
    ``from jax import random`` -> {'random': 'jax'} (so stdlib-``random``
    detection cannot misfire on jax.random).
    """
    roots: Dict[str, str] = {}
    for node in ast.walk(tree):
        if isinstance(node, ast.Import):
            for alias in node.names:
                root = alias.name.split(".")[0]
                bound = alias.asname or root
                roots[bound] = root
        elif isinstance(node, ast.ImportFrom):
            if node.level or not node.module:
                continue  # relative imports never shadow stdlib names
            root = node.module.split(".")[0]
            for alias in node.names:
                roots[alias.asname or alias.name] = root
    return roots


def _attr_chain(node: ast.AST) -> Tuple[str, ...]:
    """('np', 'random', 'normal') for np.random.normal; () if not a plain
    dotted name."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return tuple(reversed(parts))
    return ()


def _param_names(fn: ast.AST) -> Set[str]:
    """Parameter names of ``fn`` and every function nested inside it
    (scan bodies, closures) -- the names that carry traced values."""
    names: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            a = node.args
            for arg in (a.posonlyargs + a.args + a.kwonlyargs):
                names.add(arg.arg)
            if a.vararg:
                names.add(a.vararg.arg)
            if a.kwarg:
                names.add(a.kwarg.arg)
    return names


def _is_step_fn(node: ast.AST) -> bool:
    return (isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
            and (node.name == "step" or node.name.endswith("_step")))


def _check_step_scopes(tree: ast.AST, roots: Dict[str, str], path: str,
                       skip: Set[int]) -> List[LintFinding]:
    findings: List[LintFinding] = []
    seen: Set[int] = set()  # node ids already covered by an outer step fn

    def emit(node, msg):
        if node.lineno not in skip:
            findings.append(LintFinding("host-escape-in-step", path,
                                        node.lineno, msg))

    for fn in ast.walk(tree):
        if not _is_step_fn(fn) or id(fn) in seen:
            continue
        for inner in ast.walk(fn):
            seen.add(id(inner))
        params = _param_names(fn)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            if isinstance(func, ast.Attribute) and func.attr == "item" \
                    and not node.args and not node.keywords:
                emit(node, f"`.item()` in {fn.name!r} blocks on the device "
                           "and hides a per-round host sync")
                continue
            chain = _attr_chain(func)
            if len(chain) >= 2:
                root = roots.get(chain[0])
                if root == "time":
                    emit(node, f"host clock `{'.'.join(chain)}()` inside "
                               f"{fn.name!r}: traced code runs at trace "
                               "time, not per step -- thread timestamps "
                               "through the state instead")
                    continue
                if root == "random" and chain[0] == "random":
                    emit(node, f"stdlib `random.{chain[1]}` inside "
                               f"{fn.name!r}: host RNG is invisible to the "
                               "jax key stream (breaks restart-invariance) "
                               "-- use jax.random with the step key")
                    continue
                if root == "numpy" and len(chain) >= 3 \
                        and chain[1] == "random":
                    emit(node, f"`{'.'.join(chain)}` inside {fn.name!r}: "
                               "numpy RNG runs at trace time and bakes one "
                               "draw into the executable -- use jax.random")
                    continue
            if isinstance(func, ast.Name) and func.id in _CAST_BUILTINS \
                    and len(node.args) == 1 and not node.keywords:
                referenced = {n.id for n in ast.walk(node.args[0])
                              if isinstance(n, ast.Name)}
                hit = referenced & params
                if hit:
                    emit(node, f"`{func.id}(...)` over traced value(s) "
                               f"{sorted(hit)} inside {fn.name!r}: "
                               "concretizes the trace (crashes under jit, "
                               "silently pins constants otherwise)")
    return findings


def _check_host_sync(tree: ast.AST, roots: Dict[str, str], path: str,
                     skip: Set[int]) -> List[LintFinding]:
    findings: List[LintFinding] = []

    def emit(node, msg):
        if node.lineno not in skip:
            findings.append(LintFinding("host-sync-eval", path, node.lineno,
                                        msg))

    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        func = node.func
        if isinstance(func, ast.Attribute) and func.attr == "item" \
                and not node.args and not node.keywords:
            emit(node, "`.item()` forces a device round-trip per call; "
                       "convert once via np.asarray at the boundary")
            continue
        if isinstance(func, ast.Name) and func.id in _CAST_BUILTINS \
                and len(node.args) == 1 and isinstance(node.args[0],
                                                       ast.Call):
            chain = _attr_chain(node.args[0].func)
            if chain and roots.get(chain[0]) == "jax":
                emit(node, f"`{func.id}({'.'.join(chain)}(...))` syncs the "
                           "device per call -- batch the computation and "
                           "convert once (np.asarray) instead")
    return findings


def _check_jax_free(tree: ast.AST, path: str,
                    skip: Set[int]) -> List[LintFinding]:
    findings = []
    for node in ast.walk(tree):
        mods: List[Tuple[int, str]] = []
        if isinstance(node, ast.Import):
            mods = [(node.lineno, a.name) for a in node.names]
        elif isinstance(node, ast.ImportFrom) and node.module \
                and not node.level:
            mods = [(node.lineno, node.module)]
        for line, mod in mods:
            if (mod == "jax" or mod.startswith("jax.")) and line not in skip:
                findings.append(LintFinding(
                    "jax-free-modules", path, line,
                    f"imports {mod!r} but must stay jax-free: it runs "
                    "before backend init to set XLA flags, and importing "
                    "jax here locks the device count first"))
    return findings


def lint_source(src: str, path: str = "<string>", *,
                host_sync: bool = False,
                jax_free: bool = False) -> List[LintFinding]:
    """Lint one source string.  ``host_sync``/``jax_free`` opt the file into
    the benchmarks-and-examples rule / the jax-free-module rule; the step
    rule always applies."""
    try:
        tree = ast.parse(src, filename=path)
    except SyntaxError as e:
        return [LintFinding("syntax", path, e.lineno or 0, str(e.msg))]
    skip = _suppressed_lines(src)
    roots = _import_roots(tree)
    findings = _check_step_scopes(tree, roots, path, skip)
    if host_sync:
        findings += _check_host_sync(tree, roots, path, skip)
    if jax_free:
        findings += _check_jax_free(tree, path, skip)
    return sorted(findings, key=lambda f: (f.path, f.line, f.rule))


def _rel(path: Path, root: Optional[Path]) -> str:
    try:
        return str(path.relative_to(root)) if root else str(path)
    except ValueError:
        return str(path)


def lint_file(path, root=None) -> List[LintFinding]:
    path = Path(path)
    rel = _rel(path, Path(root) if root else None)
    parts = Path(rel).parts
    host_sync = "benchmarks" in parts or "examples" in parts
    jax_free = rel.replace("\\", "/") in JAX_FREE_MODULES
    return lint_source(path.read_text(), rel, host_sync=host_sync,
                       jax_free=jax_free)


def lint_paths(paths: Iterable, root=None) -> List[LintFinding]:
    """Lint every ``*.py`` under each path (files are linted directly)."""
    findings: List[LintFinding] = []
    for p in paths:
        p = Path(p)
        files = sorted(p.rglob("*.py")) if p.is_dir() else [p]
        for f in files:
            findings += lint_file(f, root=root)
    return findings


# ---------------------------------------------------------------------------
# Table completeness: registry / contract tables vs. their generator dicts.
# ---------------------------------------------------------------------------

def _extract_allowed_kind_dicts(api_path: Path) -> Set[str]:
    """Union of string keys of every dict literal bound to a name
    ``allowed`` in repro/api.py (they are locals of resolve_schedule and
    _resolve_directed_schedule, so they cannot be imported)."""
    tree = ast.parse(api_path.read_text(), filename=str(api_path))
    kinds: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Dict):
            names = {t.id for t in node.targets if isinstance(t, ast.Name)}
            if "allowed" not in names:
                continue
            for k in node.value.keys:
                if isinstance(k, ast.Constant) and isinstance(k.value, str):
                    kinds.add(k.value)
    return kinds


def _extract_argparse_choices(path: Path, flag: str) -> Optional[Set[str]]:
    """``choices=[...]`` of the add_argument call registering ``flag``."""
    tree = ast.parse(path.read_text(), filename=str(path))
    for node in ast.walk(tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr == "add_argument" and node.args
                and isinstance(node.args[0], ast.Constant)
                and node.args[0].value == flag):
            continue
        for kw in node.keywords:
            if kw.arg == "choices" and isinstance(kw.value,
                                                  (ast.List, ast.Tuple)):
                return {e.value for e in kw.value.elts
                        if isinstance(e, ast.Constant)}
    return None


def check_tables() -> List[LintFinding]:
    """Close the contract tables against their generator dicts.  Imports
    the repo lazily (jax must already be importable); pure-AST callers use
    :func:`lint_paths` only."""
    findings: List[LintFinding] = []

    def flag(path, msg):
        findings.append(LintFinding("table-completeness", path, 0, msg))

    from repro.core import mixing as MX
    gen = set(MX._SCHEDULE_GENERATORS)
    sto = set(MX.SCHEDULE_STOCHASTICITY)
    if gen != sto:
        flag("src/repro/core/mixing.py",
             f"SCHEDULE_STOCHASTICITY {sorted(sto)} != schedule generators "
             f"{sorted(gen)}")

    import repro.api as api
    from repro.core.registry import list_algorithms
    api_path = Path(api.__file__)
    registered = set(list_algorithms())
    variants = set(api.VARIANT_TO_ALGO.values())
    if not variants <= registered:
        flag("src/repro/api.py",
             f"VARIANT_TO_ALGO targets unregistered algorithms "
             f"{sorted(variants - registered)}")

    allowed = _extract_allowed_kind_dicts(api_path)
    if allowed != gen:
        flag("src/repro/api.py",
             "resolve_schedule/_resolve_directed_schedule 'allowed' kind "
             f"dicts {sorted(allowed)} drifted from the schedule "
             f"generators {sorted(gen)}")

    # dryrun must not be imported in-process (it pins 512 host devices at
    # import); read its --variant choices straight from the source
    dryrun_path = api_path.parent / "launch" / "dryrun.py"
    choices = _extract_argparse_choices(dryrun_path, "--variant")
    if choices is None:
        flag("src/repro/launch/dryrun.py",
             "could not locate the --variant add_argument choices")
    elif choices != set(api.VARIANT_TO_ALGO):
        flag("src/repro/launch/dryrun.py",
             f"--variant choices {sorted(choices)} drifted from "
             f"VARIANT_TO_ALGO {sorted(api.VARIANT_TO_ALGO)}")
    return findings
