"""Static-analysis subsystem: compiled-program and source-convention checks.

Submodules:

* :mod:`repro.analysis.hlo` -- collective census vs. declared
  :class:`~repro.core.gossip.GossipBudget`\\ s, donation checker, retrace
  detector, dtype-flow (the canonical home of the HLO parsing that used to
  live in ``launch/dryrun.py`` and four test files).
* :mod:`repro.analysis.ast_rules` -- stdlib-only AST lint (host escapes in
  step functions, host syncs in eval callbacks, jax-free modules) plus
  table-completeness checks.
* :mod:`repro.analysis.sweep` -- the algorithm x executor x wire matrix
  behind ``python -m repro.analysis --all``.

This ``__init__`` stays lazy on purpose: ``python -m repro.analysis``
executes it *before* ``__main__`` gets the chance to call
``ensure_host_device_count``, so importing anything jax-backed here would
lock the backend to the ambient device count and break the CPU-mesh
census.  Attribute access forwards to the submodules instead.
"""

from typing import TYPE_CHECKING

__all__ = ["hlo", "ast_rules", "sweep"]

if TYPE_CHECKING:  # pragma: no cover - typing aid only
    from . import ast_rules, hlo, sweep  # noqa: F401


def __getattr__(name):
    if name in __all__:
        import importlib
        return importlib.import_module(f".{name}", __name__)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
