"""CLI for the static-analysis subsystem.

Usage (PYTHONPATH=src):

    python -m repro.analysis --all            # census + probes + lint + tables
    python -m repro.analysis --census         # collective census only
    python -m repro.analysis --probes         # donation + retrace only
    python -m repro.analysis --lint [PATH...] # AST lint (no jax needed)
    python -m repro.analysis --tables         # table-completeness checks
    python -m repro.analysis --all --quick    # PR-sized subset
    python -m repro.analysis --all --algo porter-gc --algo dp-csgp

Exits non-zero on any violation; writes the machine-readable report to
--out (default artifacts/analysis/report.json).

The ensure_host_device_count call below MUST stay ahead of any
jax-importing import: the census builds a 4-agent CPU mesh, and jax locks
the device count at first backend init (same contract as launch/dryrun).
"""

from repro._env import ensure_host_device_count

ensure_host_device_count(8)

import argparse
import sys
from pathlib import Path


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="python -m repro.analysis")
    ap.add_argument("--all", action="store_true",
                    help="census + probes + lint + tables")
    ap.add_argument("--census", action="store_true",
                    help="collective census + dtype flow over the "
                         "algorithm x executor x wire matrix")
    ap.add_argument("--probes", action="store_true",
                    help="donation + retrace runtime probes per algorithm")
    ap.add_argument("--lint", nargs="*", metavar="PATH", default=None,
                    help="AST lint; default paths: src benchmarks examples")
    ap.add_argument("--tables", action="store_true",
                    help="registry/contract table completeness")
    ap.add_argument("--quick", action="store_true",
                    help="PR-sized census subset (porter-gc + one case "
                         "per family)")
    ap.add_argument("--algo", action="append", default=None,
                    help="restrict census/probes to these algorithms "
                         "(repeatable)")
    ap.add_argument("--list", action="store_true",
                    help="print the census matrix and exit")
    ap.add_argument("--out", default="artifacts/analysis/report.json")
    args = ap.parse_args(argv)

    lint_only = args.lint is not None and not (
        args.all or args.census or args.probes or args.tables or args.list)
    if lint_only:
        # pure-AST path: usable in environments without jax
        from repro.analysis import ast_rules
        paths = [Path(p) for p in args.lint] or None
        if not paths:
            root = Path.cwd()
            paths = [p for p in (root / "src", root / "benchmarks",
                                 root / "examples") if p.exists()]
        findings = ast_rules.lint_paths(paths)
        for f in findings:
            print(f)
        print(f"{len(findings)} finding(s)")
        return 1 if findings else 0

    from repro.analysis import sweep

    if args.list:
        for case in sweep.census_matrix(quick=args.quick):
            mesh = "mesh" if case.needs_mesh else "    "
            print(f"  [{mesh}] {case.label}")
        return 0

    if not (args.all or args.census or args.probes or args.tables
            or args.lint is not None):
        ap.error("pick a pass: --all, --census, --probes, --lint, --tables")

    report = sweep.run_all(
        quick=args.quick,
        do_census=args.all or args.census,
        do_probes=args.all or args.probes,
        do_lint=args.all or args.lint is not None,
        do_tables=args.all or args.tables,
        algos=args.algo)
    out = sweep.write_report(report, args.out)
    n_fail = len(report["failures"])
    print(f"\n{'OK' if report['ok'] else 'FAIL'}: "
          f"{n_fail} violation(s); report -> {out}")
    return 0 if report["ok"] else 1


if __name__ == "__main__":
    sys.exit(main())
