"""Benchmark harness: one function per paper table/figure, plus the roofline
report over the dry-run artifacts and kernel microbenchmarks.

Each function prints ``name,us_per_call,derived`` CSV rows (us_per_call is
the jitted per-step wall time on this host; 'derived' carries the
experiment's headline quantity).  Full curves are written to
artifacts/bench/*.json for EXPERIMENTS.md.

    PYTHONPATH=src python -m benchmarks.run            # everything
    PYTHONPATH=src python -m benchmarks.run fig2 table1
"""

from __future__ import annotations

import functools
import json
import sys
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (calibrate_sigma, ldp_epsilon, phi_m, smooth_clip,
                        piecewise_clip)
from repro.data import a9a_like, minibatch_source, mnist_like, \
    shard_to_agents
from benchmarks import common as C

ART = Path("artifacts/bench")
ROWS = []


def emit(name, us, derived):
    row = f"{name},{us:.1f},{derived}"
    ROWS.append(row)
    print(row, flush=True)


def _save(name, obj):
    ART.mkdir(parents=True, exist_ok=True)
    (ART / f"{name}.json").write_text(json.dumps(obj, indent=2))


# ---------------------------------------------------------------------------
# Figure 1: clipping operator curves
# ---------------------------------------------------------------------------

def bench_fig1_clipping():
    taus = [1.0]
    norms = np.linspace(0.01, 8.0, 50)
    curves = {}
    for tau in taus:
        # vectorized: each norm is a one-element vector; one host sync total
        xs = jnp.asarray(norms)[:, None]
        sm = np.asarray(jax.vmap(
            lambda v: jnp.linalg.norm(smooth_clip(v, tau)))(xs))
        pw = np.asarray(jax.vmap(
            lambda v: jnp.linalg.norm(piecewise_clip(v, tau)))(xs))
        curves[tau] = {"input_norm": norms.tolist(), "smooth": sm.tolist(),
                       "piecewise": pw.tolist()}
    _save("fig1_clipping", curves)
    x = jax.random.normal(jax.random.PRNGKey(0), (100000,))
    us = C.timed(jax.jit(lambda v: smooth_clip(v, 1.0)), x)
    # derived: max gap between the two operators over the sweep
    gap = max(abs(a - b) for a, b in zip(curves[1.0]["smooth"],
                                         curves[1.0]["piecewise"]))
    emit("fig1_clipping_ops", us, f"max_operator_gap={gap:.3f}")


# ---------------------------------------------------------------------------
# Figure 2: logistic regression + nonconvex reg on a9a-like (PORTER-DP vs
# SoteriaFL-SGD vs DSGD-DP) under two LDP levels
# ---------------------------------------------------------------------------

def bench_fig2_logreg(steps=600):
    x, y = a9a_like(20000, 123, seed=0)
    xs, ys = shard_to_agents(x, y, C.N_AGENTS)
    xe, ye = jnp.asarray(x[:4000]), jnp.asarray(y[:4000])
    m = xs.shape[1]
    top = C.paper_topology()
    loss_fn = C.logreg_loss()
    acc = C.accuracy_fn("logreg")
    params0 = {"w": jnp.zeros(123), "b": jnp.zeros(())}
    out = {}
    for eps in (1e-2, 1e-1):
        sigma = calibrate_sigma(1.0, steps, m, eps, 1e-3)
        eta = 0.01 if eps <= 1e-2 else 0.04  # best-tuned per privacy level
        for name, runner in [
            ("porter_dp", lambda it, cb: C.run_porter(
                loss_fn, params0, it, top, steps, eta=eta, variant="dp",
                sigma_p=sigma, eval_cb=cb)),
            ("soteriafl_sgd", lambda it, cb: C.run_soteria(
                loss_fn, params0, it, steps, eta=eta, sigma_p=sigma,
                eval_cb=cb)),
            ("dsgd_dp", lambda it, cb: C.run_dsgd_dp(
                loss_fn, params0, it, top, steps, eta=eta, sigma_p=sigma,
                eval_cb=cb)),
        ]:
            it = minibatch_source(xs, ys, batch=1)
            cb = lambda p, m: (m["loss"], acc(p, xe, ye))
            t0 = time.perf_counter()
            _, curve = runner(it, cb)
            us = (time.perf_counter() - t0) / steps * 1e6
            key = f"{name}_eps{eps:g}"
            out[key] = [{"step": t, "utility": u, "test_acc": a}
                        for t, u, a in curve]
            emit(f"fig2_{key}", us,
                 f"final_utility={curve[-1][1]:.4f};acc={curve[-1][2]:.4f}")
    _save("fig2_logreg", out)


# ---------------------------------------------------------------------------
# Figure 3: one-hidden-layer NN on MNIST-like
# ---------------------------------------------------------------------------

def bench_fig3_mnist(steps=300):
    x, y = mnist_like(20000, seed=0)
    xs, ys = shard_to_agents(x, y, C.N_AGENTS)
    xe, ye = jnp.asarray(x[:2000]), jnp.asarray(y[:2000])
    m = xs.shape[1]
    top = C.paper_topology()
    loss_fn = C.mlp_loss()
    acc = C.accuracy_fn("mlp")
    params0 = C.mlp_params0()
    out = {}
    for eps in (1e-2, 1e-1):
        sigma = calibrate_sigma(1.0, steps, m, eps, 1e-3)
        eta = 0.03 if eps <= 1e-2 else 0.08  # best-tuned per privacy level
        for name, runner in [
            ("porter_dp", lambda it, cb: C.run_porter(
                loss_fn, params0, it, top, steps, eta=eta, variant="dp",
                sigma_p=sigma, eval_cb=cb)),
            ("soteriafl_sgd", lambda it, cb: C.run_soteria(
                loss_fn, params0, it, steps, eta=eta, sigma_p=sigma,
                eval_cb=cb)),
        ]:
            it = minibatch_source(xs, ys, batch=1)
            cb = lambda p, m: (m["loss"], acc(p, xe, ye))
            t0 = time.perf_counter()
            _, curve = runner(it, cb)
            us = (time.perf_counter() - t0) / steps * 1e6
            key = f"{name}_eps{eps:g}"
            out[key] = [{"step": t, "utility": u, "test_acc": a}
                        for t, u, a in curve]
            emit(f"fig3_{key}", us,
                 f"final_utility={curve[-1][1]:.4f};acc={curve[-1][2]:.4f}")
    _save("fig3_mnist", out)


# ---------------------------------------------------------------------------
# Table 1: utility / communication-round comparison (formulas + measured)
# ---------------------------------------------------------------------------

def bench_table1():
    d, m, eps, delta = 123, 2000, 0.1, 1e-3
    rho, alpha = 0.05, C.paper_topology().alpha
    phi = phi_m(d, m, eps, delta)
    n = C.N_AGENTS
    rows = {
        "dp_sgd": {"utility": phi, "rounds": None},
        "ddp_srm": {"utility": phi / n, "rounds": n**2 * d / phi},
        "soteriafl_sgd": {"utility": (1.0 / n) ** 0.5 * phi,
                          "rounds": n ** (2 / 3) * d / phi},
        "porter_dp_bounded": {
            "utility": phi / ((1 - alpha) ** (8 / 3) * rho ** (4 / 3)),
            "rounds": phi ** -2},
        "porter_dp_general": {
            "utility": phi / ((1 - alpha) ** (16 / 3) * rho ** (8 / 3)),
            "rounds": phi ** -2},
    }
    # measured: rounds for PORTER-DP to reach utility <= 0.68 on fig2 setup
    x, y = a9a_like(20000, 123, seed=0)
    xs, ys = shard_to_agents(x, y, C.N_AGENTS)
    top = C.paper_topology()
    loss_fn = C.logreg_loss()
    params0 = {"w": jnp.zeros(123), "b": jnp.zeros(())}
    steps = 400
    sigma = calibrate_sigma(1.0, steps, xs.shape[1], eps, delta)
    it = minibatch_source(xs, ys, batch=1)
    hit = {"round": None}

    def cb(p, m):
        if hit["round"] is None and m["loss"] <= 0.70:
            hit["round"] = True
        return (m["loss"],)

    t0 = time.perf_counter()
    _, curve = C.run_porter(loss_fn, params0, it, top, steps, eta=0.04,
                            variant="dp", sigma_p=sigma, eval_cb=cb,
                            eval_every=10)
    us = (time.perf_counter() - t0) / steps * 1e6
    reached = [t for t, l in curve if l <= 0.70]
    rows["porter_dp_measured"] = {
        "rounds_to_0.70_utility": reached[0] if reached else None,
        "final_utility": curve[-1][1],
        "accountant_eps": ldp_epsilon(1.0, sigma, steps, xs.shape[1], delta),
        "target_eps": eps,
    }
    _save("table1_complexities", {"phi_m": phi, "alpha": alpha, "rho": rho,
                                  "rows": rows})
    emit("table1_porter_dp", us,
         f"phi_m={phi:.4f};rounds_to_target="
         f"{rows['porter_dp_measured']['rounds_to_0.70_utility']}")


# ---------------------------------------------------------------------------
# Theorem 3/4 scaling trends: final grad norm vs rho and vs alpha
# ---------------------------------------------------------------------------

def bench_scaling(steps=60):
    """Thm 3/4 dependence on rho and alpha.  NOTE: the average iterate's
    dynamics are gossip-independent (the gossip term is mean-zero and
    v-bar tracks g-bar exactly), so the theory's rho/alpha dependence
    shows up in the CONSENSUS error ||X - xbar||_F^2 -- that is what this
    benchmark sweeps; the grad norm of the average is reported as a
    (nearly constant) control."""
    from repro.core import average_params, consensus_error
    x, y = a9a_like(10000, 123, seed=0)
    xs, ys = shard_to_agents(x, y, C.N_AGENTS)
    loss_fn = C.logreg_loss()
    params0 = {"w": jnp.zeros(123), "b": jnp.zeros(())}
    flat = (xs.reshape(-1, 123), ys.reshape(-1))

    def grad_norm(p):
        g = jax.grad(loss_fn)(p, flat)
        sq = sum(jnp.sum(v ** 2) for v in jax.tree_util.tree_leaves(g))
        return float(np.sqrt(np.asarray(sq)))

    out = {"rho": {}, "alpha": {}}
    top = C.paper_topology()
    for rho in (1.0, 0.25, 0.05):
        it = minibatch_source(xs, ys, batch=2)
        st, _ = C.run_porter(loss_fn, params0, it, top, steps, eta=0.05,
                             variant="gc", frac=rho, comp_name="top_k")
        out["rho"][rho] = {"consensus": float(consensus_error(st.x)),
                           "grad": grad_norm(average_params(st.x))}
    for kind in ("complete", "erdos_renyi", "ring"):
        t = C.topology(kind)
        it = minibatch_source(xs, ys, batch=2)
        st, _ = C.run_porter(loss_fn, params0, it, t, steps, eta=0.05,
                             variant="gc", frac=0.05, comp_name="top_k")
        out["alpha"][f"{kind}(a={t.alpha:.2f})"] = {
            "consensus": float(consensus_error(st.x)),
            "grad": grad_norm(average_params(st.x))}
    _save("scaling_trends", out)
    emit("scaling_rho", 0.0,
         ";".join(f"rho={k}:cons={v['consensus']:.3e}"
                  for k, v in out["rho"].items()))
    emit("scaling_alpha", 0.0,
         ";".join(f"{k}:cons={v['consensus']:.3e}"
                  for k, v in out["alpha"].items()))


# ---------------------------------------------------------------------------
# Kernel microbenchmarks (interpret mode on CPU; correctness + fusion ratio)
# ---------------------------------------------------------------------------

def bench_kernels():
    from repro.kernels import ops, ref
    d = 1 << 20
    x = jax.random.normal(jax.random.PRNGKey(0), (d,))
    us_k = C.timed(functools.partial(ops.smooth_clip, tau=1.0,
                                     interpret=True), x)
    us_r = C.timed(jax.jit(functools.partial(ref.smooth_clip_ref, tau=1.0)),
                   x)
    emit("kernel_smooth_clip_1M", us_k, f"ref_us={us_r:.1f}")
    us_k = C.timed(functools.partial(ops.block_topk, frac=0.05,
                                     interpret=True), x)
    emit("kernel_block_topk_1M", us_k, "rho=0.05")
    args = [jax.random.normal(jax.random.PRNGKey(i), (d,)) for i in range(7)]
    us_k = C.timed(lambda *a: ops.ef_track(*a, 0.3, interpret=True), *args)
    us_r = C.timed(jax.jit(lambda *a: ref.ef_track_ref(*a, 0.3)), *args)
    emit("kernel_ef_track_1M", us_k, f"ref_us={us_r:.1f}")


# ---------------------------------------------------------------------------
# Roofline report over dry-run artifacts (deliverable (g) source data)
# ---------------------------------------------------------------------------

def bench_roofline():
    src = Path("artifacts/dryrun")
    if not src.exists():
        emit("roofline", 0.0, "no dryrun artifacts (run repro.launch.dryrun)")
        return
    rows = []
    for f in sorted(src.glob("*.json")):
        rec = json.loads(f.read_text())
        if not rec.get("ok"):
            rows.append({"key": f.stem, "ok": False,
                         "error": rec.get("error", "?")})
            continue
        r = rec["roofline"]
        rows.append({
            "key": f.stem, "ok": True, "arch": rec["arch"],
            "shape": rec["shape"], "mesh": rec["mesh"], "tag": rec.get("tag", ""),
            "compute_s": r["compute_s"], "memory_s": r["memory_s"],
            "collective_s": r["collective_s"], "dominant": r["dominant"],
            "useful_flops_ratio": r["useful_flops_ratio"],
            "params_total": rec["params_total"],
            "params_active": rec["params_active"],
        })
    _save("roofline_table", rows)
    ok = [r for r in rows if r["ok"]]
    n_coll = sum(r["dominant"] == "collective" for r in ok)
    n_mem = sum(r["dominant"] == "memory" for r in ok)
    n_comp = sum(r["dominant"] == "compute" for r in ok)
    emit("roofline_summary", 0.0,
         f"ok={len(ok)}/{len(rows)};collective_bound={n_coll};"
         f"memory_bound={n_mem};compute_bound={n_comp}")


def bench_ablation():
    from benchmarks.ablation import bench_ablation as _ab
    _ab()


def bench_comm_round():
    from benchmarks.bench_comm_round import bench
    rows = bench(n_agents=4, d=20_001, frac=0.05, reps=3)
    _save("comm_round", [
        {"compressor": l, "backend": b, "us_per_round": us,
         "bytes_per_round": wire} for l, b, us, wire in rows])
    for label, backend, us, wire in rows:
        emit(f"comm_round/{label}/{backend}", us, f"bytes_per_round={wire:.0f}")


BENCHES = {
    "fig1": bench_fig1_clipping,
    "fig2": bench_fig2_logreg,
    "fig3": bench_fig3_mnist,
    "table1": bench_table1,
    "scaling": bench_scaling,
    "ablation": bench_ablation,
    "comm_round": bench_comm_round,
    "kernels": bench_kernels,
    "roofline": bench_roofline,
}


def main() -> None:
    names = sys.argv[1:] or list(BENCHES)
    print("name,us_per_call,derived")
    for n in names:
        BENCHES[n]()
    ART.mkdir(parents=True, exist_ok=True)
    (ART / "summary.csv").write_text("name,us_per_call,derived\n"
                                     + "\n".join(ROWS) + "\n")


if __name__ == "__main__":
    main()
