"""Render the paper-figure analogues from artifacts/bench/*.json to PNG
(artifacts/plots/).  Run after ``python -m benchmarks.run``:

    PYTHONPATH=src python -m benchmarks.plots
"""

from __future__ import annotations

import json
from pathlib import Path

import matplotlib
matplotlib.use("Agg")
import matplotlib.pyplot as plt  # noqa: E402

SRC = Path("artifacts/bench")
OUT = Path("artifacts/plots")

STYLE = {"porter_dp": dict(color="tab:red", marker="o", ms=3),
         "soteriafl_sgd": dict(color="tab:blue", marker="s", ms=3),
         "dsgd_dp": dict(color="tab:gray", marker="^", ms=3)}


def _fig_curves(name: str, title: str):
    data = json.loads((SRC / f"{name}.json").read_text())
    eps_levels = sorted({k.rsplit("_eps", 1)[1] for k in data})
    fig, axes = plt.subplots(2, len(eps_levels), figsize=(10, 7),
                             sharex=True)
    for col, eps in enumerate(eps_levels):
        for key, curve in data.items():
            algo, e = key.rsplit("_eps", 1)
            if e != eps:
                continue
            steps = [p["step"] for p in curve]
            axes[0][col].plot(steps, [p["utility"] for p in curve],
                              label=algo, **STYLE.get(algo, {}))
            axes[1][col].plot(steps, [p["test_acc"] for p in curve],
                              label=algo, **STYLE.get(algo, {}))
        axes[0][col].set_title(f"({eps}, 1e-3)-LDP")
        axes[0][col].set_yscale("log")
        axes[0][col].set_ylabel("train utility")
        axes[1][col].set_ylabel("test accuracy")
        axes[1][col].set_xlabel("communication rounds")
        axes[0][col].legend(fontsize=8)
    fig.suptitle(title)
    fig.tight_layout()
    OUT.mkdir(parents=True, exist_ok=True)
    path = OUT / f"{name}.png"
    fig.savefig(path, dpi=120)
    plt.close(fig)
    print("wrote", path)


def _fig1():
    data = json.loads((SRC / "fig1_clipping.json").read_text())
    curve = data["1.0"]
    fig, ax = plt.subplots(figsize=(5, 4))
    ax.plot(curve["input_norm"], curve["smooth"],
            label="smooth clip (Def. 2)", color="tab:red")
    ax.plot(curve["input_norm"], curve["piecewise"],
            label="piecewise clip (Remark 1)", color="tab:blue", ls="--")
    ax.axhline(1.0, color="gray", lw=0.5)
    ax.set_xlabel("input norm")
    ax.set_ylabel("clipped norm (tau = 1)")
    ax.legend()
    fig.tight_layout()
    OUT.mkdir(parents=True, exist_ok=True)
    fig.savefig(OUT / "fig1_clipping.png", dpi=120)
    plt.close(fig)
    print("wrote", OUT / "fig1_clipping.png")


def main():
    if (SRC / "fig1_clipping.json").exists():
        _fig1()
    for name, title in [("fig2_logreg",
                         "Fig. 2 analogue: logistic regression + nonconvex "
                         "reg (a9a-like)"),
                        ("fig3_mnist",
                         "Fig. 3 analogue: 1-hidden-layer NN (MNIST-like)")]:
        if (SRC / f"{name}.json").exists():
            _fig_curves(name, title)


if __name__ == "__main__":
    main()
