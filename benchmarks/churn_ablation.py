"""Churn ablation: convergence vs agent-churn rate on a time-varying graph.

PORTER's rates are parameterized by the network's spectral gap; a static
benchmark probes that trade-off at a single point.  This ablation sweeps the
*churn rate* of a dropout :class:`repro.core.mixing.TopologySchedule` (each
round every agent is offline independently with probability ``rate``; the
round's survivors re-derive Metropolis weights) on the paper's Section-5.1
logreg protocol, and reports convergence against the schedule's joint
spectral gap -- the connectivity axis the paper's theory predicts and the
static harness could not measure.

All contenders run through the registry's uniform metrics schema (``loss``,
``consensus_x``, ``wire_bytes`` -- see repro.core.registry), so the
loss/consensus trajectories and the wire accounting are directly comparable
with benchmarks/ablation.py's static rows.  Training runs through the
scan-fused chunked runtime; like bench_train_loop.py, every chunk size must
compile exactly ONE executable -- the schedule table is indexed by a traced
round counter, so time variation adds zero recompiles (asserted below).

Rows: ``churn/<rate>,final_loss,...``; artifacts land in
artifacts/bench/churn_ablation.json (EXPERIMENTS.md section "Churn").

    PYTHONPATH=src python benchmarks/churn_ablation.py            # full
    PYTHONPATH=src python benchmarks/churn_ablation.py --smoke    # CI
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

if __package__ in (None, ""):  # `python benchmarks/churn_ablation.py`
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import numpy as np

from repro.api import build
from repro.data import a9a_like, minibatch_source, shard_to_agents
from repro.launch.runtime import make_runner
from benchmarks import common as C

RATES = (0.0, 0.1, 0.3, 0.5)
PERIOD = 8
CHUNK = 8


def _run(spec, loss_fn, params0, source, steps, chunk=CHUNK):
    """Train ``spec`` for ``steps`` rounds; return per-round uniform metrics.

    Asserts one executable per chunk size, exactly as bench_train_loop.py
    does for the static path: a churn schedule must not cost recompiles.
    """
    algo = build(spec, loss_fn)
    state = algo.init(params0)
    key = jax.random.PRNGKey(0)
    runners, t, per_round = {}, 0, []
    while t < steps:
        size = min(chunk, steps - t)
        runner = runners.get(size)
        if runner is None:
            runner = runners[size] = make_runner(algo, source, size)
        state, key, metrics = runner(state, key, t)
        t += size
        per_round.append({k: np.asarray(v) for k, v in metrics.items()})
    for size, runner in runners.items():
        n_exec = runner.cache_size()
        assert n_exec in (None, 1), (
            f"chunk={size} compiled {n_exec} executables under the "
            "schedule (expected 1: W_t is a traced gather)")
    stacked = {k: np.concatenate([m[k] for m in per_round])
               for k in per_round[0]}
    return algo, stacked


def run_ablation(steps=400, chunk=CHUNK):
    x, y = a9a_like(12000, 123, seed=0)
    xs, ys = shard_to_agents(x, y, C.N_AGENTS)
    loss_fn = C.logreg_loss()
    params0 = {"w": np.zeros(123, np.float32), "b": np.zeros((), np.float32)}
    source = minibatch_source(xs, ys, batch=4)

    # the Section-5.1 protocol on Metropolis weights (churn schedules
    # re-derive Metropolis on each round's pruned graph; best_constant has
    # no closed form on a disconnected round)
    base = C.PAPER_SPEC.replace(algo="porter-gc", topology_weights="metropolis",
                                compressor="top_k", frac=0.05, eta=0.05,
                                tau=1.0)

    results, rows = {}, []
    for rate in RATES:
        spec = (base if rate == 0.0 else base.replace(
            topology_schedule=(f"dropout:rate={rate},period={PERIOD},"
                               f"base=erdos_renyi")))
        algo, m = _run(spec, loss_fn, params0, source, steps, chunk)
        q = max(len(m["loss"]) // 4, 1)
        sched = algo.schedule
        rec = {
            "rate": rate,
            "schedule": spec.topology_schedule,
            "period": 1 if sched is None else sched.period,
            "window": PERIOD,
            # the connectivity axis: how much a PERIOD-round window mixes
            # (static row raised to the same window so the bases match)
            "joint_spectral_gap": (
                1.0 - algo.topology.alpha ** PERIOD if sched is None
                else sched.joint_spectral_gap),
            "per_round_alpha": (algo.topology.alpha if sched is None
                                else sched.alpha),
            # per-round spectral-gap trajectory over one period (a churn
            # round with offline agents may have gap 0 -- the window saves
            # it; plotted against the loss curve in EXPERIMENTS.md)
            "spectral_gap_trajectory": (
                [algo.topology.spectral_gap] if sched is None
                else [1.0 - a for a in sched.alphas]),
            "gamma": algo.gamma,
            # uniform schema: per-round means over the tail quarter
            "final_loss": float(np.mean(m["loss"][-q:])),
            "final_consensus_x": float(np.mean(m["consensus_x"][-q:])),
            "wire_mb_per_round": float(m["wire_bytes"][-1] / 1e6),
            "wire_mb_total": float(np.sum(m["wire_bytes"]) / 1e6),
            "loss_curve": m["loss"][:: max(steps // 50, 1)].tolist(),
            "consensus_curve":
                m["consensus_x"][:: max(steps // 50, 1)].tolist(),
        }
        results[f"rate_{rate}"] = rec
        rows.append(rec)
        print(f"churn/{rate},final_loss={rec['final_loss']:.4f},"
              f"consensus={rec['final_consensus_x']:.3e},"
              f"joint_gap={rec['joint_spectral_gap']:.3f},"
              f"gamma={rec['gamma']:.4g},"
              f"wire_total={rec['wire_mb_total']:.3f}MB")

    # sanity on the axis itself: more churn can only shrink the window's
    # joint gap (fewer links survive each round)
    gaps = [r["joint_spectral_gap"] for r in rows]
    assert all(g > 0.0 for g in gaps), gaps
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=None,
                    help="rounds per rate (default 400, or 32 with --smoke)")
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    args = ap.parse_args()
    steps = args.steps or (32 if args.smoke else 400)

    results = run_ablation(steps=steps)
    art = Path("artifacts/bench")
    art.mkdir(parents=True, exist_ok=True)
    (art / "churn_ablation.json").write_text(json.dumps(results, indent=2))
    print(f"wrote artifacts/bench/churn_ablation.json "
          f"({len(results)} rates x {steps} rounds)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
