"""Comm-round engine microbenchmark: fused (Pallas) vs reference (jnp)
round time and wire bytes/round across compressors.

One PORTER iteration outside the model is two comm rounds (track + step)
over every parameter: ~13 HBM-bound passes unfused, 7 reads + 4 writes per
round fused (see EXPERIMENTS.md #Perf).  This harness times exactly that
slice -- gradients excluded -- for the engine's two backends:

    ref     pure-jnp tree_map chain (XLA-fused on CPU; the oracle)
    pallas  flat tile planes + ef_track/ef_step kernels
            (Mosaic on TPU; interpret mode on CPU, where it is *slower* --
            interpret exists for correctness CI, the speedup is a TPU
            number)

``--sharded`` adds the model-sharded case: a (data x model) mesh whose
buffers carry model-parallel PartitionSpecs, ref vs the pallas per-shard
planes path (pack/unpack inside shard_map; the layout the launch layer
uses for tensor-parallel training).  Off-TPU this forces
--xla_force_host_platform_device_count=8 host devices.

``--achieved-bytes`` adds the bit-packed wire-format audit: engines built
with ``wire='packed_bits'`` on a 4-agent mesh, asserting the *measured*
shipped-buffer nbytes (``CommRound.wire_bytes``, via jax.eval_shape over
the codec) equals the analytic layout model (``wire_bytes_model``) for the
ring and packed collectives with both registered formats (``topk_bits``,
``qsgd_bits``), and reporting the dense-f32-vs-packed bandwidth ratio plus
the overlap-vs-sequential round time.  Every invocation also writes the
perf-trajectory baseline ``BENCH_comm.json`` at the repo root.

Usage:
    PYTHONPATH=src python benchmarks/bench_comm_round.py            # full
    PYTHONPATH=src python benchmarks/bench_comm_round.py --smoke    # CI
    PYTHONPATH=src python benchmarks/bench_comm_round.py --smoke --sharded
    PYTHONPATH=src python benchmarks/bench_comm_round.py --smoke --achieved-bytes

Rows: compressor,backend,us_per_round,bytes_per_round
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

if __package__ in (None, ""):  # allow `python benchmarks/bench_comm_round.py`
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

# must precede the jax import: device count locks at first backend init
if "--sharded" in sys.argv or "--achieved-bytes" in sys.argv:
    from repro._env import ensure_host_device_count
    ensure_host_device_count(8)

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExperimentSpec, build_engine, resolve_compressor

# the paper's sparse family; 'rand_k' is the registry's random_k
COMPRESSORS = (("top_k", "top_k"), ("block_top_k", "block_top_k"),
               ("rand_k", "random_k"))


def make_buffers(key, n_agents: int, d: int):
    """Agent-stacked PORTER-shaped buffers with odd, non-tile-aligned leaves."""
    d1 = max(d - d // 3 - 1, 1)
    d2 = d - d1
    shapes = {"w": (d1,), "b": (d2,)} if d2 else {"w": (d1,)}
    ks = jax.random.split(key, 7)

    def tree(k):
        sub = jax.random.split(k, len(shapes))
        return {name: jax.random.normal(kk, (n_agents,) + s)
                for kk, (name, s) in zip(sub, shapes.items())}

    # (y, q, m) for the buffer plus (g, g_prev) for the track side
    return tuple(tree(k) for k in ks[:5])


def timed_us(fn, *args, reps: int):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def bench(n_agents: int, d: int, frac: float, reps: int):
    base = ExperimentSpec(n_agents=n_agents, topology="ring",
                          topology_weights="metropolis", frac=frac,
                          interpret=None if jax.default_backend() == "tpu"
                          else True)
    key = jax.random.PRNGKey(0)
    y, q, m, g, gp = make_buffers(key, n_agents, d)
    gamma, eta = 0.1, 0.05

    print(f"# comm-round bench: n_agents={n_agents} d={d} frac={frac} "
          f"reps={reps} backend_device={jax.default_backend()}")
    print("compressor,backend,us_per_round,bytes_per_round")
    rows = []
    for label, reg_name in COMPRESSORS:
        for backend in ("ref", "pallas"):
            eng = build_engine(base.replace(compressor=reg_name,
                                            comm_backend=backend))

            @jax.jit
            def one_round(key, y, q, m, g, gp, eng=eng):
                k1, k2 = jax.random.split(key)
                v, q2, m2 = eng.track(k1, y, q, m, g, gp, gamma)
                x, q3, m3 = eng.step(k2, y, q2, m2, v, gamma, eta)
                return x, v, q3, m3

            us = timed_us(one_round, key, y, q, m, g, gp, reps=reps)
            wire = 2.0 * eng.wire_bytes(y)  # track + step streams
            rows.append((label, backend, us, wire))
            print(f"{label},{backend},{us:.1f},{wire:.0f}", flush=True)
    # headline: fused-vs-reference ratio per compressor
    for label, _ in COMPRESSORS:
        r = {b: us for (l, b, us, _) in rows if l == label}
        print(f"# {label}: pallas/ref time ratio = "
              f"{r['pallas'] / r['ref']:.2f} "
              f"(interpret mode is correctness-only off-TPU)")
    return rows


def bench_sharded(d: int, frac: float, reps: int):
    """Model-sharded case: (data=4, model=2) mesh, per-shard pallas planes
    vs the jnp reference, ring wire format, shard-local compression --
    the engine exactly as the tensor-parallel launch path builds it."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.steps import make_shard_local_compress

    n_data, n_model = 4, 2
    if len(jax.devices()) < n_data * n_model:
        print(f"# sharded bench skipped: needs {n_data * n_model} devices, "
              f"have {len(jax.devices())} (run with --sharded from the CLI "
              "so the host-device flag is set before jax init)")
        return []
    mesh = jax.make_mesh((n_data, n_model), ("data", "model"))
    n = n_data
    d_sh = max(d - d // 3 - 1, 2) // (2 * n_model) * (2 * n_model)
    d_rep = max(d - d_sh, 1)
    shapes = {"w": (d_sh // (2 * n_model), 2 * n_model), "b": (d_rep,)}
    specs = {"w": P("data", None, "model"), "b": P("data", None)}
    sh = {k: NamedSharding(mesh, specs[k]) for k in specs}
    key = jax.random.PRNGKey(0)

    def tree(k):
        ks = jax.random.split(k, len(shapes))
        return {name: jax.device_put(
                    jax.random.normal(kk, (n,) + shapes[name]), sh[name])
                for kk, name in zip(ks, shapes)}

    y, q, m, g, gp = (tree(k) for k in jax.random.split(key, 5))
    gamma, eta = 0.1, 0.05
    base = ExperimentSpec(n_agents=n, topology="ring",
                          topology_weights="metropolis",
                          compressor="block_top_k", frac=frac,
                          gossip_mode="ring",
                          interpret=None if jax.default_backend() == "tpu"
                          else True)
    shard_local = make_shard_local_compress(resolve_compressor(base), mesh,
                                            specs)

    print(f"# sharded comm-round bench: mesh=(data={n_data},model={n_model}) "
          f"d={d} frac={frac} reps={reps}")
    print("compressor,backend,us_per_round,bytes_per_round")
    rows = []
    for backend in ("ref", "pallas"):
        eng = build_engine(base.replace(comm_backend=backend), mesh=mesh,
                           leaf_specs=specs, compress_fn=shard_local)

        @jax.jit
        def one_round(key, y, q, m, g, gp, eng=eng):
            k1, k2 = jax.random.split(key)
            v, q2, m2 = eng.track(k1, y, q, m, g, gp, gamma)
            x, q3, m3 = eng.step(k2, y, q2, m2, v, gamma, eta)
            return x, v, q3, m3

        us = timed_us(one_round, key, y, q, m, g, gp, reps=reps)
        wire = 2.0 * eng.wire_bytes(y)
        rows.append(("block_top_k/sharded", backend, us, wire))
        print(f"block_top_k/sharded,{backend},{us:.1f},{wire:.0f}",
              flush=True)
    return rows


def bench_achieved_bytes(reps: int):
    """Bit-packed wire-format audit on a 4-agent mesh.

    For every (format x collective) pair the engine is built exactly as the
    launch layer builds it (``wire='packed_bits'`` through the api facade)
    and three numbers are pinned:

      measured   CommRound.wire_bytes      -- nbytes of the shipped buffers
                                              (traced shapes of codec.pack)
      model      CommRound.wire_bytes_model -- windows x layout constants
      dense      the same collective shipping dense f32 planes

    measured == model is asserted exactly (the PR-3 drift-bug class);
    the acceptance ratios count *payload* bytes (per-window f32 scales are
    overhead, reported separately): >= 4x for top-k frac=0.25, >= 8x for
    qsgd with the 4-bit (s=16 signed alphabet) code words.  The buffer
    sizes use a window-aligned d -- padding is a property of the problem
    shape, not of the wire format, so the audit excludes it.

    Also times the ring/topk engine sequential vs overlapped (both
    exchanges issued before either fused update) and asserts the two
    orderings are bit-exact.
    """
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.core import wire_formats as WF

    n = 4
    if len(jax.devices()) < n:
        print(f"# achieved-bytes audit skipped: needs {n} devices, have "
              f"{len(jax.devices())} (run --achieved-bytes from the CLI so "
              "the host-device flag is set before jax init)")
        return None
    mesh = jax.make_mesh((n,), ("data",))
    windows = 8
    d = windows * WF.PACK_BLOCK                     # window-aligned
    specs = {"w": P("data", None)}
    sh = NamedSharding(mesh, specs["w"])
    key = jax.random.PRNGKey(0)

    def tree(k):
        return {"w": jax.device_put(jax.random.normal(k, (n, d)), sh)}

    y, q, m, g, gp = (tree(k) for k in jax.random.split(key, 5))
    gamma, eta = 0.1, 0.05
    interpret = None if jax.default_backend() == "tpu" else True
    base = ExperimentSpec(n_agents=n, topology="ring",
                          topology_weights="metropolis", wire="packed_bits",
                          comm_backend="ref", interpret=interpret)
    cases = [
        ("topk_bits", "ring",
         dict(compressor="block_top_k", frac=0.25, gossip_mode="ring")),
        ("topk_bits", "packed",
         dict(compressor="block_top_k", frac=0.25, gossip_mode="packed")),
        ("qsgd_bits", "ring",
         dict(compressor="qsgd", compressor_kwargs={"levels": 7},
              gossip_mode="ring")),
        ("qsgd_bits", "packed",
         dict(compressor="qsgd", compressor_kwargs={"levels": 7},
              gossip_mode="packed")),
    ]
    print(f"# achieved-bytes audit: n_agents={n} d={d} "
          f"(window-aligned, {windows} windows)")
    print("format,mode,us_per_round,measured_bytes,model_bytes,"
          "dense_bytes,payload_ratio,total_ratio")
    out = {"n_agents": n, "d": d, "cases": []}
    engines = {}
    for fmt, mode, kw in cases:
        eng = build_engine(base.replace(**kw), mesh=mesh, leaf_specs=specs)
        engines[(fmt, mode)] = eng
        measured = eng.wire_bytes(y)
        model = eng.wire_bytes_model(y)
        assert measured == model, \
            f"{fmt}/{mode}: measured {measured} != model {model}"
        codec = eng.mixer.wire_codec
        mult = (1.0 if n == 2 else 2.0) if mode == "ring" else float(n)
        dense = mult * d * 4.0                       # dense f32 planes
        overhead = mult * windows * codec.overhead_bytes_per_window
        payload_ratio = dense / (measured - overhead)
        total_ratio = dense / measured

        @jax.jit
        def one_round(key, y, q, m, g, gp, eng=eng):
            k1, k2 = jax.random.split(key)
            v, q2, m2 = eng.track(k1, y, q, m, g, gp, gamma)
            x, q3, m3 = eng.step(k2, y, q2, m2, v, gamma, eta)
            return x, v, q3, m3

        us = timed_us(one_round, key, y, q, m, g, gp, reps=reps)
        print(f"{fmt},{mode},{us:.1f},{measured:.0f},{model:.0f},"
              f"{dense:.0f},{payload_ratio:.3f},{total_ratio:.3f}",
              flush=True)
        out["cases"].append(dict(
            format=fmt, mode=mode, us_per_round=us,
            measured_bytes=measured, model_bytes=model, dense_bytes=dense,
            payload_ratio=payload_ratio, total_ratio=total_ratio))
        floor = 4.0 if fmt == "topk_bits" else 8.0
        assert payload_ratio >= floor, \
            f"{fmt}/{mode}: payload ratio {payload_ratio:.3f} < {floor}x"

    # ---- directed push-sum: the weight scalar rides the codec wire ----
    # dp-csgp ships one exact f32 push-sum weight per agent, bitcast into
    # words of the codec's last wire buffer (+4 bytes per shipped buffer
    # set).  The measured path derives those 4 bytes from the codec's pack
    # signature (wire_formats.measured_weight_nbytes), so measured == model
    # must hold with push_sum=True exactly as it does for the plain rounds,
    # and the delta over the plain round is exactly the collective's
    # shipped-copies multiplier x 4.
    # ring executor needs circulant +-1 bands -> the skip-0 directed ring;
    # packed ships whole tables, so it takes a genuinely asymmetric
    # (one-way link loss) column-stochastic schedule
    dscheds = {"ring": "directed:ring_skips",
               "packed": "directed:one_way,rate=0.3,period=4,skip=2"}
    dbase = base.replace(compressor="block_top_k", frac=0.25)
    ps_rows = []
    for mode in ("ring", "packed"):
        eng = build_engine(dbase.replace(gossip_mode=mode,
                                         topology_schedule=dscheds[mode]),
                           mesh=mesh, leaf_specs=specs)
        plain = eng.wire_bytes(y)
        ps_meas = eng.wire_bytes(y, push_sum=True)
        ps_model = eng.wire_bytes_model(y, push_sum=True)
        assert ps_meas == ps_model, \
            f"directed/{mode}: push-sum measured {ps_meas} != model {ps_model}"
        mult = (1.0 if n == 2 else 2.0) if mode == "ring" else float(n)
        assert ps_meas - plain == mult * 4.0, \
            f"directed/{mode}: weight bytes {ps_meas - plain} != {mult * 4.0}"

        xw = jnp.ones((n,), jnp.float32)
        qw = jnp.zeros((n,), jnp.float32)

        @jax.jit
        def ps_round(key, y, q, xw, qw, eng=eng):
            return eng.exchange_ps(key, y, q, xw, qw,
                                   t=jnp.zeros((), jnp.int32))

        c, wc, cw, wcw = ps_round(key, y, q, xw, qw)
        # column-stochastic W conserves weight mass: 1^T(W cw) == 1^T cw
        mass_in = float(np.asarray(jnp.sum(cw)))
        mass_out = float(np.asarray(jnp.sum(wcw)))
        assert abs(mass_in - mass_out) < 1e-4, (mode, mass_in, mass_out)
        print(f"# directed/{mode}: push_sum bytes {ps_meas:.0f} "
              f"(plain {plain:.0f} + weight {ps_meas - plain:.0f}), "
              f"weight mass {mass_in:.6f} -> {mass_out:.6f}", flush=True)
        ps_rows.append(dict(mode=mode, plain_bytes=plain,
                            push_sum_bytes=ps_meas,
                            weight_bytes=ps_meas - plain))
    out["directed_push_sum"] = ps_rows

    # ---- overlap: both exchanges in flight before either fused update ----
    # PORTER's two rounds run over *independent* buffer pairs -- (v, q_v)
    # and (x, q_x) -- which is exactly why the reorder is bit-exact: the
    # x-side exchange reads nothing the track update writes
    eng = engines[("topk_bits", "ring")]
    q_x, m_x = tree(jax.random.PRNGKey(7)), tree(jax.random.PRNGKey(8))

    @jax.jit
    def seq_round(key, y, q, m, g, gp, q_x, m_x):
        k1, k2 = jax.random.split(key)
        v, q2, m2 = eng.track(k1, y, q, m, g, gp, gamma)
        x, q3, m3 = eng.step(k2, y, q_x, m_x, v, gamma, eta)
        return x, v, q2, q3, m2, m3

    @jax.jit
    def ovl_round(key, y, q, m, g, gp, q_x, m_x):
        k1, k2 = jax.random.split(key)
        c_v, wc_v = eng.exchange(k1, y, q)
        c_x, wc_x = eng.exchange(k2, y, q_x)
        v, q2, m2 = eng.track_update(c_v, wc_v, y, q, m, g, gp, gamma)
        x, q3, m3 = eng.step_update(c_x, wc_x, y, q_x, m_x, v, gamma, eta)
        return x, v, q2, q3, m2, m3

    a = seq_round(key, y, q, m, g, gp, q_x, m_x)
    b = ovl_round(key, y, q, m, g, gp, q_x, m_x)
    bitexact = all(
        np.array_equal(np.asarray(la), np.asarray(lb))
        for la, lb in zip(jax.tree_util.tree_leaves(a),
                          jax.tree_util.tree_leaves(b)))
    assert bitexact, "overlap ordering is not bit-exact to sequential"
    seq_us = timed_us(seq_round, key, y, q, m, g, gp, q_x, m_x, reps=reps)
    ovl_us = timed_us(ovl_round, key, y, q, m, g, gp, q_x, m_x, reps=reps)
    eff = seq_us / ovl_us
    print(f"# overlap(topk_bits/ring): seq={seq_us:.1f}us ovl={ovl_us:.1f}us "
          f"efficiency={eff:.2f}x bitexact={bitexact} "
          "(overlap is a latency-hiding number on TPU; CPU shows parity)")
    out["overlap"] = dict(format="topk_bits", mode="ring", seq_us=seq_us,
                          ovl_us=ovl_us, efficiency=eff, bitexact=bitexact)
    return out


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CPU CI")
    ap.add_argument("--sharded", action="store_true",
                    help="add the model-sharded (per-shard planes) case")
    ap.add_argument("--achieved-bytes", action="store_true",
                    help="audit measured vs modeled bit-packed wire bytes "
                         "(ring/packed x topk_bits/qsgd_bits) + overlap")
    ap.add_argument("--agents", type=int, default=None)
    ap.add_argument("--d", type=int, default=None,
                    help="per-agent parameter count")
    ap.add_argument("--frac", type=float, default=0.05)
    ap.add_argument("--reps", type=int, default=None)
    args = ap.parse_args(argv)

    if args.smoke:
        n, d, reps = 4, 20_001, 3
    else:
        n, d, reps = 8, 1_000_003, 10
    n = args.agents or n
    d = args.d or d
    reps = args.reps or reps
    rows = bench(n, d, args.frac, reps)
    record = {
        "bench": "comm_round", "device_backend": jax.default_backend(),
        "smoke": bool(args.smoke), "n_agents": n, "d": d,
        "frac": args.frac, "reps": reps,
        "rounds": [dict(compressor=l, backend=b, us_per_round=us,
                        steps_per_s=1e6 / us, bytes_per_round=w)
                   for (l, b, us, w) in rows],
    }
    if args.sharded:
        srows = bench_sharded(d, args.frac, reps)
        record["sharded"] = [
            dict(compressor=l, backend=b, us_per_round=us,
                 steps_per_s=1e6 / us, bytes_per_round=w)
            for (l, b, us, w) in srows]
    if args.achieved_bytes:
        record["achieved_bytes"] = bench_achieved_bytes(reps)
    # perf-trajectory baseline: future PRs diff against the checked-in copy
    out = Path(__file__).resolve().parents[1] / "BENCH_comm.json"
    out.write_text(json.dumps(record, indent=2) + "\n")
    print(f"# wrote {out}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
