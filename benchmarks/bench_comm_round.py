"""Comm-round engine microbenchmark: fused (Pallas) vs reference (jnp)
round time and wire bytes/round across compressors.

One PORTER iteration outside the model is two comm rounds (track + step)
over every parameter: ~13 HBM-bound passes unfused, 7 reads + 4 writes per
round fused (see EXPERIMENTS.md #Perf).  This harness times exactly that
slice -- gradients excluded -- for the engine's two backends:

    ref     pure-jnp tree_map chain (XLA-fused on CPU; the oracle)
    pallas  flat tile planes + ef_track/ef_step kernels
            (Mosaic on TPU; interpret mode on CPU, where it is *slower* --
            interpret exists for correctness CI, the speedup is a TPU
            number)

Usage:
    PYTHONPATH=src python benchmarks/bench_comm_round.py            # full
    PYTHONPATH=src python benchmarks/bench_comm_round.py --smoke    # CI

Rows: compressor,backend,us_per_round,bytes_per_round
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

if __package__ in (None, ""):  # allow `python benchmarks/bench_comm_round.py`
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

import jax
import jax.numpy as jnp

from repro.api import ExperimentSpec, build_engine

# the paper's sparse family; 'rand_k' is the registry's random_k
COMPRESSORS = (("top_k", "top_k"), ("block_top_k", "block_top_k"),
               ("rand_k", "random_k"))


def make_buffers(key, n_agents: int, d: int):
    """Agent-stacked PORTER-shaped buffers with odd, non-tile-aligned leaves."""
    d1 = max(d - d // 3 - 1, 1)
    d2 = d - d1
    shapes = {"w": (d1,), "b": (d2,)} if d2 else {"w": (d1,)}
    ks = jax.random.split(key, 7)

    def tree(k):
        sub = jax.random.split(k, len(shapes))
        return {name: jax.random.normal(kk, (n_agents,) + s)
                for kk, (name, s) in zip(sub, shapes.items())}

    # (y, q, m) for the buffer plus (g, g_prev) for the track side
    return tuple(tree(k) for k in ks[:5])


def timed_us(fn, *args, reps: int):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def bench(n_agents: int, d: int, frac: float, reps: int):
    base = ExperimentSpec(n_agents=n_agents, topology="ring",
                          topology_weights="metropolis", frac=frac,
                          interpret=None if jax.default_backend() == "tpu"
                          else True)
    key = jax.random.PRNGKey(0)
    y, q, m, g, gp = make_buffers(key, n_agents, d)
    gamma, eta = 0.1, 0.05

    print(f"# comm-round bench: n_agents={n_agents} d={d} frac={frac} "
          f"reps={reps} backend_device={jax.default_backend()}")
    print("compressor,backend,us_per_round,bytes_per_round")
    rows = []
    for label, reg_name in COMPRESSORS:
        for backend in ("ref", "pallas"):
            eng = build_engine(base.replace(compressor=reg_name,
                                            comm_backend=backend))

            @jax.jit
            def one_round(key, y, q, m, g, gp, eng=eng):
                k1, k2 = jax.random.split(key)
                v, q2, m2 = eng.track(k1, y, q, m, g, gp, gamma)
                x, q3, m3 = eng.step(k2, y, q2, m2, v, gamma, eta)
                return x, v, q3, m3

            us = timed_us(one_round, key, y, q, m, g, gp, reps=reps)
            wire = 2.0 * eng.wire_bytes(y)  # track + step streams
            rows.append((label, backend, us, wire))
            print(f"{label},{backend},{us:.1f},{wire:.0f}", flush=True)
    # headline: fused-vs-reference ratio per compressor
    for label, _ in COMPRESSORS:
        r = {b: us for (l, b, us, _) in rows if l == label}
        print(f"# {label}: pallas/ref time ratio = "
              f"{r['pallas'] / r['ref']:.2f} "
              f"(interpret mode is correctness-only off-TPU)")
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CPU CI")
    ap.add_argument("--agents", type=int, default=None)
    ap.add_argument("--d", type=int, default=None,
                    help="per-agent parameter count")
    ap.add_argument("--frac", type=float, default=0.05)
    ap.add_argument("--reps", type=int, default=None)
    args = ap.parse_args(argv)

    if args.smoke:
        n, d, reps = 4, 20_001, 3
    else:
        n, d, reps = 8, 1_000_003, 10
    n = args.agents or n
    d = args.d or d
    reps = args.reps or reps
    bench(n, d, args.frac, reps)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
