"""Comm-round engine microbenchmark: fused (Pallas) vs reference (jnp)
round time and wire bytes/round across compressors.

One PORTER iteration outside the model is two comm rounds (track + step)
over every parameter: ~13 HBM-bound passes unfused, 7 reads + 4 writes per
round fused (see EXPERIMENTS.md #Perf).  This harness times exactly that
slice -- gradients excluded -- for the engine's two backends:

    ref     pure-jnp tree_map chain (XLA-fused on CPU; the oracle)
    pallas  flat tile planes + ef_track/ef_step kernels
            (Mosaic on TPU; interpret mode on CPU, where it is *slower* --
            interpret exists for correctness CI, the speedup is a TPU
            number)

``--sharded`` adds the model-sharded case: a (data x model) mesh whose
buffers carry model-parallel PartitionSpecs, ref vs the pallas per-shard
planes path (pack/unpack inside shard_map; the layout the launch layer
uses for tensor-parallel training).  Off-TPU this forces
--xla_force_host_platform_device_count=8 host devices.

Usage:
    PYTHONPATH=src python benchmarks/bench_comm_round.py            # full
    PYTHONPATH=src python benchmarks/bench_comm_round.py --smoke    # CI
    PYTHONPATH=src python benchmarks/bench_comm_round.py --smoke --sharded

Rows: compressor,backend,us_per_round,bytes_per_round
"""

from __future__ import annotations

import argparse
import sys
import time
from pathlib import Path

if __package__ in (None, ""):  # allow `python benchmarks/bench_comm_round.py`
    sys.path.insert(0, str(Path(__file__).resolve().parents[1] / "src"))

# must precede the jax import: device count locks at first backend init
if "--sharded" in sys.argv:
    from repro._env import ensure_host_device_count
    ensure_host_device_count(8)

import jax
import jax.numpy as jnp

from repro.api import ExperimentSpec, build_engine, resolve_compressor

# the paper's sparse family; 'rand_k' is the registry's random_k
COMPRESSORS = (("top_k", "top_k"), ("block_top_k", "block_top_k"),
               ("rand_k", "random_k"))


def make_buffers(key, n_agents: int, d: int):
    """Agent-stacked PORTER-shaped buffers with odd, non-tile-aligned leaves."""
    d1 = max(d - d // 3 - 1, 1)
    d2 = d - d1
    shapes = {"w": (d1,), "b": (d2,)} if d2 else {"w": (d1,)}
    ks = jax.random.split(key, 7)

    def tree(k):
        sub = jax.random.split(k, len(shapes))
        return {name: jax.random.normal(kk, (n_agents,) + s)
                for kk, (name, s) in zip(sub, shapes.items())}

    # (y, q, m) for the buffer plus (g, g_prev) for the track side
    return tuple(tree(k) for k in ks[:5])


def timed_us(fn, *args, reps: int):
    out = fn(*args)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6


def bench(n_agents: int, d: int, frac: float, reps: int):
    base = ExperimentSpec(n_agents=n_agents, topology="ring",
                          topology_weights="metropolis", frac=frac,
                          interpret=None if jax.default_backend() == "tpu"
                          else True)
    key = jax.random.PRNGKey(0)
    y, q, m, g, gp = make_buffers(key, n_agents, d)
    gamma, eta = 0.1, 0.05

    print(f"# comm-round bench: n_agents={n_agents} d={d} frac={frac} "
          f"reps={reps} backend_device={jax.default_backend()}")
    print("compressor,backend,us_per_round,bytes_per_round")
    rows = []
    for label, reg_name in COMPRESSORS:
        for backend in ("ref", "pallas"):
            eng = build_engine(base.replace(compressor=reg_name,
                                            comm_backend=backend))

            @jax.jit
            def one_round(key, y, q, m, g, gp, eng=eng):
                k1, k2 = jax.random.split(key)
                v, q2, m2 = eng.track(k1, y, q, m, g, gp, gamma)
                x, q3, m3 = eng.step(k2, y, q2, m2, v, gamma, eta)
                return x, v, q3, m3

            us = timed_us(one_round, key, y, q, m, g, gp, reps=reps)
            wire = 2.0 * eng.wire_bytes(y)  # track + step streams
            rows.append((label, backend, us, wire))
            print(f"{label},{backend},{us:.1f},{wire:.0f}", flush=True)
    # headline: fused-vs-reference ratio per compressor
    for label, _ in COMPRESSORS:
        r = {b: us for (l, b, us, _) in rows if l == label}
        print(f"# {label}: pallas/ref time ratio = "
              f"{r['pallas'] / r['ref']:.2f} "
              f"(interpret mode is correctness-only off-TPU)")
    return rows


def bench_sharded(d: int, frac: float, reps: int):
    """Model-sharded case: (data=4, model=2) mesh, per-shard pallas planes
    vs the jnp reference, ring wire format, shard-local compression --
    the engine exactly as the tensor-parallel launch path builds it."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.steps import make_shard_local_compress

    n_data, n_model = 4, 2
    if len(jax.devices()) < n_data * n_model:
        print(f"# sharded bench skipped: needs {n_data * n_model} devices, "
              f"have {len(jax.devices())} (run with --sharded from the CLI "
              "so the host-device flag is set before jax init)")
        return []
    mesh = jax.make_mesh((n_data, n_model), ("data", "model"))
    n = n_data
    d_sh = max(d - d // 3 - 1, 2) // (2 * n_model) * (2 * n_model)
    d_rep = max(d - d_sh, 1)
    shapes = {"w": (d_sh // (2 * n_model), 2 * n_model), "b": (d_rep,)}
    specs = {"w": P("data", None, "model"), "b": P("data", None)}
    sh = {k: NamedSharding(mesh, specs[k]) for k in specs}
    key = jax.random.PRNGKey(0)

    def tree(k):
        ks = jax.random.split(k, len(shapes))
        return {name: jax.device_put(
                    jax.random.normal(kk, (n,) + shapes[name]), sh[name])
                for kk, name in zip(ks, shapes)}

    y, q, m, g, gp = (tree(k) for k in jax.random.split(key, 5))
    gamma, eta = 0.1, 0.05
    base = ExperimentSpec(n_agents=n, topology="ring",
                          topology_weights="metropolis",
                          compressor="block_top_k", frac=frac,
                          gossip_mode="ring",
                          interpret=None if jax.default_backend() == "tpu"
                          else True)
    shard_local = make_shard_local_compress(resolve_compressor(base), mesh,
                                            specs)

    print(f"# sharded comm-round bench: mesh=(data={n_data},model={n_model}) "
          f"d={d} frac={frac} reps={reps}")
    print("compressor,backend,us_per_round,bytes_per_round")
    rows = []
    for backend in ("ref", "pallas"):
        eng = build_engine(base.replace(comm_backend=backend), mesh=mesh,
                           leaf_specs=specs, compress_fn=shard_local)

        @jax.jit
        def one_round(key, y, q, m, g, gp, eng=eng):
            k1, k2 = jax.random.split(key)
            v, q2, m2 = eng.track(k1, y, q, m, g, gp, gamma)
            x, q3, m3 = eng.step(k2, y, q2, m2, v, gamma, eta)
            return x, v, q3, m3

        us = timed_us(one_round, key, y, q, m, g, gp, reps=reps)
        wire = 2.0 * eng.wire_bytes(y)
        rows.append(("block_top_k/sharded", backend, us, wire))
        print(f"block_top_k/sharded,{backend},{us:.1f},{wire:.0f}",
              flush=True)
    return rows


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="small sizes for CPU CI")
    ap.add_argument("--sharded", action="store_true",
                    help="add the model-sharded (per-shard planes) case")
    ap.add_argument("--agents", type=int, default=None)
    ap.add_argument("--d", type=int, default=None,
                    help="per-agent parameter count")
    ap.add_argument("--frac", type=float, default=0.05)
    ap.add_argument("--reps", type=int, default=None)
    args = ap.parse_args(argv)

    if args.smoke:
        n, d, reps = 4, 20_001, 3
    else:
        n, d, reps = 8, 1_000_003, 10
    n = args.agents or n
    d = args.d or d
    reps = args.reps or reps
    bench(n, d, args.frac, reps)
    if args.sharded:
        bench_sharded(d, args.frac, reps)
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
