"""Mixed-precision memory benchmark: f32 vs bf16 state planes + remat.

Three measurements, each reported f32-vs-bf16 (``plane_dtype``):

* **resident plane bytes** -- the EF/gossip state buffers (q, m, v,
  g_prev; everything but the f32 master params and the step counter),
  summed from the initialized state.  The acceptance gate asserts the
  bf16 engine cuts these by >= 1.9x.
* **gossip wire bytes** -- measured two ways: the engine's per-round
  accounting (the ``wire_bytes`` metric out of the chunked runner) and
  the compiled program itself (collective result bytes attributed to the
  gossip executor in the optimized HLO, via repro.analysis.hlo).  The
  HLO measurement is the load-bearing one: bf16 planes must ship
  <= 2 B/elem (they cross as their u16 bit pattern, like the codec
  executors), and the gate asserts >= 1.9x there too.
* **steps/s + parity** -- the paper's Section-5.1 logreg protocol
  (10 agents, ER(0.8), random-5% compression) through the chunked
  runtime; the bf16 engine must land its final loss within tolerance of
  the f32 run (stochastic rounding keeps the EF recursion unbiased, so
  the curves track).

The ``--lm`` leg builds the tinyllama-1.1b smoke config with
``remat_policy='dots'`` + bf16 planes, compiles it, and runs one chunk --
``compiled.memory_analysis()`` live-bytes are recorded when the backend
reports them (TPU; CPU returns nothing and the field stays null).

Rows land in artifacts/bench/memory.json and the perf-trajectory copy
BENCH_memory.json (future PRs diff against the checked-in file).

    PYTHONPATH=src python benchmarks/bench_memory.py            # full
    PYTHONPATH=src python benchmarks/bench_memory.py --smoke    # CI
    PYTHONPATH=src python benchmarks/bench_memory.py --no-lm    # skip lm leg
"""

from __future__ import annotations

from repro._env import ensure_host_device_count

ensure_host_device_count(8)

import argparse
import json
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.analysis import hlo as H
from repro.api import ExperimentSpec, build
from repro.data import a9a_like, minibatch_source, shard_to_agents
from repro.launch.runtime import make_runner

# the paper's Section-5.1 protocol (standalone, like bench_train_loop.py)
N_AGENTS = 10
PAPER_SPEC = ExperimentSpec(n_agents=N_AGENTS, topology="erdos_renyi",
                            topology_weights="best_constant", topology_p=0.8,
                            topology_seed=1)

PLANE_RATIO_FLOOR = 1.9
PARITY_TOL = 0.02      # |final_loss(f32) - final_loss(bf16)| on Section 5.1

# wire-measurement problem: 4 host agents on a ring, one flat leaf big
# enough that plane traffic dwarfs scalar riders
WIRE_N, WIRE_D = 4, 4096


def _logreg_loss(params, batch):
    f, l = batch
    f = jnp.atleast_2d(f)
    l = jnp.atleast_1d(l)
    logits = f @ params["w"] + params["b"]
    nll = jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits)))
    return nll + 0.2 * jnp.sum(params["w"] ** 2 / (1 + params["w"] ** 2))


def _spec(plane_dtype):
    return PAPER_SPEC.replace(algo="porter-gc", compressor="random_k",
                              frac=0.05, eta=0.05, tau=1.0,
                              plane_dtype=plane_dtype)


def _problem():
    x, y = a9a_like(12000, 123, seed=0)
    xs, ys = shard_to_agents(x, y, N_AGENTS)
    params0 = {"w": jnp.zeros(123), "b": jnp.zeros(())}
    return params0, minibatch_source(xs, ys, batch=4)


# ---------------------------------------------------------------------------
# Resident plane bytes.
# ---------------------------------------------------------------------------

def plane_bytes(state) -> dict:
    """Split the state's bytes into master params (x), EF/gossip planes
    (every other model-size buffer) and scalars (the step counter &c.)."""
    out = {"x": 0, "planes": 0, "other": 0}
    for name in state._fields:
        leaf_bytes = sum(
            int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
            for l in jax.tree_util.tree_leaves(getattr(state, name)))
        if name == "x":
            out["x"] += leaf_bytes
        elif leaf_bytes >= 4 * N_AGENTS:  # model-size agent-stacked buffer
            out["planes"] += leaf_bytes
        else:
            out["other"] += leaf_bytes
    return out


# ---------------------------------------------------------------------------
# Measured gossip wire bytes (optimized HLO, ring executor on a host mesh).
# ---------------------------------------------------------------------------

def _wire_loss(p, b):
    return jnp.mean((p["w"] - b) ** 2)


def hlo_gossip_bytes(plane_dtype) -> int:
    """Sum collective result bytes attributed to the gossip executor in the
    compiled porter-gc step (ring, 4 host agents)."""
    mesh = Mesh(np.asarray(jax.devices()[:WIRE_N]), ("data",))
    spec = ExperimentSpec(algo="porter-gc", n_agents=WIRE_N, topology="ring",
                          topology_weights="metropolis",
                          compressor="block_top_k", frac=0.25,
                          comm_backend="ref", interpret=True, eta=0.1,
                          gossip_mode="ring", plane_dtype=plane_dtype)
    algo = build(spec, _wire_loss, mesh=mesh)
    state = algo.init({"w": jnp.zeros(WIRE_D)})
    shard = lambda l: NamedSharding(
        mesh, P(*(("data",) + (None,) * (l.ndim - 1))
                if getattr(l, "ndim", 0) >= 1 and l.shape[0] == WIRE_N
                else ()))
    state = jax.device_put(state, jax.tree_util.tree_map(shard, state))
    batch = jax.device_put(jnp.zeros((WIRE_N, 1, WIRE_D)),
                           NamedSharding(mesh, P("data", None, None)))
    key = jax.device_put(jax.random.PRNGKey(0), NamedSharding(mesh, P()))
    hlo = jax.jit(algo.step).lower(state, batch, key).compile().as_text()
    return sum(op.result_bytes for op in H.collective_ops(hlo)
               if op.source in H.GOSSIP_SOURCES)


# ---------------------------------------------------------------------------
# Section-5.1 protocol: steps/s, engine wire accounting, parity.
# ---------------------------------------------------------------------------

def run_protocol(plane_dtype, steps: int, chunk: int) -> dict:
    params0, source = _problem()
    algo = build(_spec(plane_dtype), _logreg_loss)
    state = algo.init(params0)
    st = plane_bytes(state)

    runner = make_runner(algo, source, chunk)
    key = jax.random.PRNGKey(0)
    mem = compiled_memory(runner, state)
    state, key, metrics = runner(state, key, 0)  # warmup (compile)
    t0 = time.perf_counter()  # analysis: ok -- host wall-clock IS the measurement
    for t in range(chunk, steps, chunk):
        state, key, metrics = runner(state, key, t)
    jax.block_until_ready(state)
    dt = time.perf_counter() - t0  # analysis: ok -- host wall-clock
    return {
        "plane_dtype": plane_dtype or "f32",
        "state_bytes": st,
        "final_loss": float(metrics["loss"][-1]),
        "wire_bytes_per_round": float(metrics["wire_bytes"][-1]),
        "steps_per_s": (steps - chunk) / dt if steps > chunk else None,
        "memory_analysis": mem,
    }


def compiled_memory(runner, state) -> dict | None:
    """``compiled.memory_analysis()`` of the chunk executable, lowered
    abstractly from the state's shapes.  TPU reports full live-buffer
    accounting; the CPU backend exposes the same interface with partial
    fields, and anything missing stays out of the record."""
    shapes = jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype), state)
    try:
        ma = runner.lower(shapes).compile().memory_analysis()
    except Exception:
        return None
    if ma is None:
        return None
    fields = ("argument_size_in_bytes", "output_size_in_bytes",
              "temp_size_in_bytes", "generated_code_size_in_bytes")
    out = {}
    for f in fields:
        try:
            out[f] = int(getattr(ma, f))
        except Exception:
            continue
    return out or None


# ---------------------------------------------------------------------------
# LM leg: tinyllama-1.1b + loss-level remat + bf16 planes, one real chunk.
# ---------------------------------------------------------------------------

def run_lm(steps: int, chunk: int) -> dict:
    from repro.configs import get_smoke
    from repro.data import batch_source
    from repro.models import build_model
    cfg = get_smoke("tinyllama-1.1b")
    bundle = build_model(cfg)
    spec = ExperimentSpec(algo="porter-gc", n_agents=4, topology="ring",
                          compressor="top_k", frac=0.05, eta=3e-2, tau=1.0,
                          plane_dtype="bf16", remat_policy="dots")
    algo = build(spec, bundle.loss)
    params0, _ = bundle.init(jax.random.PRNGKey(0))
    state = algo.init(params0)
    st = plane_bytes(state)
    runner = make_runner(algo, batch_source(cfg, 4, 2, 64), chunk)
    key = jax.random.PRNGKey(0)
    mem = compiled_memory(runner, state)
    t0 = time.perf_counter()  # analysis: ok -- host wall-clock (compile+run)
    state, key, metrics = runner(state, key, 0)
    jax.block_until_ready(state)
    compile_s = time.perf_counter() - t0  # analysis: ok -- host wall-clock
    t0 = time.perf_counter()  # analysis: ok -- host wall-clock
    for t in range(chunk, steps, chunk):
        state, key, metrics = runner(state, key, t)
    jax.block_until_ready(state)
    dt = time.perf_counter() - t0  # analysis: ok -- host wall-clock
    return {
        "arch": "tinyllama-1.1b (smoke)", "remat_policy": "dots",
        "plane_dtype": "bf16", "state_bytes": st,
        "final_loss": float(metrics["loss"][-1]),
        "compile_plus_first_chunk_s": compile_s,
        "steps_per_s": (steps - chunk) / dt if steps > chunk else None,
        "memory_analysis": mem,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=None,
                    help="protocol rounds (default 256, or 32 with --smoke)")
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    ap.add_argument("--no-lm", action="store_true",
                    help="skip the tinyllama remat leg")
    args = ap.parse_args()
    steps = args.steps or (32 if args.smoke else 256)
    chunk = 8

    rows = [run_protocol(pd, steps, chunk) for pd in (None, "bf16")]
    f32, bf16 = rows
    plane_ratio = (f32["state_bytes"]["planes"]
                   / bf16["state_bytes"]["planes"])
    wire_model_ratio = (f32["wire_bytes_per_round"]
                        / bf16["wire_bytes_per_round"])

    hlo_bytes = {pd or "f32": hlo_gossip_bytes(pd) for pd in (None, "bf16")}
    hlo_ratio = hlo_bytes["f32"] / hlo_bytes["bf16"]
    loss_gap = abs(f32["final_loss"] - bf16["final_loss"])

    print("name,value,derived")
    print(f"memory/planes_f32,{f32['state_bytes']['planes']},"
          f"x_bytes={f32['state_bytes']['x']}")
    print(f"memory/planes_bf16,{bf16['state_bytes']['planes']},"
          f"ratio={plane_ratio:.2f}x")
    print(f"memory/wire_model,{bf16['wire_bytes_per_round']:.0f},"
          f"ratio={wire_model_ratio:.2f}x")
    print(f"memory/wire_hlo,{hlo_bytes['bf16']},"
          f"ratio={hlo_ratio:.2f}x;f32_bytes={hlo_bytes['f32']}")
    print(f"memory/parity,{bf16['final_loss']:.4f},"
          f"f32={f32['final_loss']:.4f};gap={loss_gap:.4f}")
    for r in rows:
        if r["steps_per_s"]:
            print(f"memory/steps_per_s/{r['plane_dtype']},"
                  f"{r['steps_per_s']:.1f},")

    record = {"bench": "memory", "steps": steps, "smoke": bool(args.smoke),
              "rows": rows, "plane_ratio": plane_ratio,
              "wire_model_ratio": wire_model_ratio,
              "wire_hlo_bytes": hlo_bytes, "wire_hlo_ratio": hlo_ratio,
              "parity_gap": loss_gap}
    if not args.no_lm:
        lm = run_lm(steps=max(2 * chunk, 2), chunk=chunk)
        record["lm"] = lm
        print(f"memory/lm_remat,{lm['final_loss']:.4f},"
              f"compile_s={lm['compile_plus_first_chunk_s']:.1f}")

    art = Path("artifacts/bench")
    art.mkdir(parents=True, exist_ok=True)
    (art / "memory.json").write_text(json.dumps(record, indent=2))
    root = Path(__file__).resolve().parents[1]
    (root / "BENCH_memory.json").write_text(
        json.dumps(record, indent=2) + "\n")
    print(f"# wrote {root / 'BENCH_memory.json'}")

    # acceptance gates
    assert plane_ratio >= PLANE_RATIO_FLOOR, \
        f"bf16 planes cut resident bytes {plane_ratio:.2f}x < " \
        f"{PLANE_RATIO_FLOOR}x"
    assert hlo_ratio >= PLANE_RATIO_FLOOR, \
        f"measured gossip wire reduction {hlo_ratio:.2f}x < " \
        f"{PLANE_RATIO_FLOOR}x -- a dense f32 plane is crossing the wire"
    assert wire_model_ratio >= 1.0, \
        f"wire accounting regressed under bf16 ({wire_model_ratio:.2f}x)"
    assert loss_gap <= PARITY_TOL, \
        f"bf16 final loss diverged from f32 by {loss_gap:.4f} > {PARITY_TOL}"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
