"""Dispatch-overhead benchmark: per-step Python loop vs scan-fused chunks.

The comm-round *interior* is covered by bench_comm_round.py; this measures
what the chunked runtime (repro.launch.runtime) removes *between* rounds --
one jit dispatch, one host sync and one state round-trip per round.  On the
dispatch-bound smoke task (the paper's Section-5.1 logreg protocol, where a
round's compute is tens of microseconds) the Python-loop overhead dominates,
so steps/s scales with the chunk size until the scan body does.

Rows: ``train_loop/<task>/<mode>,us_per_step,steps_per_s=...``; the table
lands in EXPERIMENTS.md (SPerf-6) and artifacts/bench/train_loop.json.
Each chunked mode also asserts its runner compiled exactly ONE executable
(the chunk offset is a traced scalar, so every chunk reuses the program).

    PYTHONPATH=src python benchmarks/bench_train_loop.py            # full
    PYTHONPATH=src python benchmarks/bench_train_loop.py --smoke    # CI
    PYTHONPATH=src python benchmarks/bench_train_loop.py --task lm
"""

from __future__ import annotations

import argparse
import json
import math
import time
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExperimentSpec, build
from repro.data import (a9a_like, agent_batch_iterator, minibatch_source,
                        shard_to_agents)
from repro.launch.runtime import make_runner

CHUNKS = (1, 8, 32)

# the paper's Section-5 protocol (kept standalone so this file runs as a
# plain script, like bench_comm_round.py: `python benchmarks/bench_...py`)
N_AGENTS = 10
PAPER_SPEC = ExperimentSpec(n_agents=N_AGENTS, topology="erdos_renyi",
                            topology_weights="best_constant", topology_p=0.8,
                            topology_seed=1)


def _logreg_loss(params, batch):
    f, l = batch
    f = jnp.atleast_2d(f)
    l = jnp.atleast_1d(l)
    logits = f @ params["w"] + params["b"]
    nll = jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits)))
    return nll + 0.2 * jnp.sum(params["w"] ** 2 / (1 + params["w"] ** 2))


def _logreg_problem():
    x, y = a9a_like(12000, 123, seed=0)
    xs, ys = shard_to_agents(x, y, N_AGENTS)
    spec = PAPER_SPEC.replace(algo="porter-gc", compressor="top_k",
                              frac=0.05, eta=0.05, tau=1.0)
    params0 = {"w": jnp.zeros(123), "b": jnp.zeros(())}
    # legacy batches: the pre-runtime benchmarks drew from a host-side
    # numpy iterator and shipped every batch through the dispatch
    it = agent_batch_iterator(xs, ys, batch=4, seed=0)
    return (spec, _logreg_loss, params0, minibatch_source(xs, ys, batch=4),
            lambda kb: next(it))


def _lm_problem():
    from repro.configs import get_smoke
    from repro.data import batch_source, token_batch
    from repro.models import build_model
    cfg = get_smoke("tinyllama-1.1b")
    import dataclasses
    cfg = dataclasses.replace(cfg, remat=False)
    bundle = build_model(cfg)
    spec = ExperimentSpec(algo="porter-gc", n_agents=4, topology="ring",
                          compressor="top_k", frac=0.05, eta=3e-2, tau=1.0)
    params0, _ = bundle.init(jax.random.PRNGKey(0))
    # legacy batches: the pre-runtime train driver synthesized tokens with
    # an eager device op per round
    legacy = lambda kb: {"tokens": token_batch(kb, 4, 2, 64, cfg.vocab)}
    return spec, bundle.loss, params0, batch_source(cfg, 4, 2, 64), legacy


def _per_step(algo, legacy_batch, params0, steps):
    """The historical loop: per-round batch synthesis outside the compiled
    step, one dispatch + one host sync per round."""
    state = algo.init(params0)
    step = jax.jit(algo.step)
    key = jax.random.PRNGKey(0)

    def run():
        nonlocal state, key
        for t in range(steps):
            key, kb, ks = jax.random.split(key, 3)
            state, m = step(state, legacy_batch(kb), ks)
            float(m["loss"])  # the per-round host sync being measured
        return state

    run()  # warmup (compile)
    t0 = time.perf_counter()  # analysis: ok -- host wall-clock IS the measurement
    jax.block_until_ready(run())
    return (time.perf_counter() - t0) / steps  # analysis: ok -- host wall-clock


def _chunked(algo, source, params0, steps, chunk):
    runner = make_runner(algo, source, chunk)
    state = algo.init(params0)
    key = jax.random.PRNGKey(0)

    def run(state, key, start):
        for t in range(start, start + steps, chunk):
            state, key, metrics = runner(state, key, t)
            float(metrics["loss"][-1])  # one sync per chunk
        return state, key

    state, key = run(state, key, 0)  # warmup (compile)
    t0 = time.perf_counter()
    state, key = run(state, key, steps)
    jax.block_until_ready(state)
    dt = (time.perf_counter() - t0) / steps
    n_exec = runner.cache_size()
    assert n_exec in (None, 1), \
        f"chunk={chunk} compiled {n_exec} executables (expected 1)"
    return dt


def bench(task: str, steps: int):
    spec, loss_fn, params0, source, legacy = (
        _logreg_problem() if task == "logreg" else _lm_problem())
    algo = build(spec, loss_fn)
    rows = []
    us = _per_step(algo, legacy, params0, steps) * 1e6
    rows.append(("per_step", us, 1e6 / us))
    for chunk in CHUNKS:
        if chunk > steps:
            continue
        us = _chunked(algo, source, params0, steps, chunk) * 1e6
        rows.append((f"chunk{chunk}", us, 1e6 / us))
    return rows, (spec, loss_fn, params0, source)


def bench_overlap(spec, loss_fn, params0, source, steps, chunk=8):
    """CommRound(overlap=True) vs sequential through the chunked runner.

    Overlap issues both comm rounds' collectives before either fused
    update; it is bit-exact by construction, which is asserted here over a
    short same-key run before timing.  On CPU the efficiency is ~1.0 (XLA
    schedules both orders alike); on TPU it is the latency-hiding number.
    """
    algos = {ovl: build(spec.replace(overlap=ovl), loss_fn)
             for ovl in (False, True)}
    finals = {}
    for ovl, algo in algos.items():
        state = algo.init(params0)
        runner = make_runner(algo, source, chunk)
        key = jax.random.PRNGKey(0)
        for t in range(0, 2 * chunk, chunk):
            state, key, _ = runner(state, key, t)
        finals[ovl] = state
    bitexact = all(
        np.array_equal(np.asarray(a), np.asarray(b))
        for a, b in zip(jax.tree_util.tree_leaves(finals[False]),
                        jax.tree_util.tree_leaves(finals[True])))
    assert bitexact, "overlap=True diverged from the sequential order"
    us = {ovl: _chunked(algo, source, params0, steps, chunk) * 1e6
          for ovl, algo in algos.items()}
    return {"chunk": chunk, "seq_us_per_step": us[False],
            "overlap_us_per_step": us[True],
            "efficiency": us[False] / us[True], "bitexact": bitexact}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--task", default="logreg", choices=["logreg", "lm"])
    ap.add_argument("--steps", type=int, default=None,
                    help="measured rounds (default 256, or 32 with --smoke)")
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    args = ap.parse_args()
    steps = args.steps or (32 if args.smoke else 256)
    # every mode must run the same horizon: round steps up to a common
    # multiple of the chunk sizes
    lcm = math.lcm(*CHUNKS)
    steps = max(steps + (-steps) % lcm, lcm)

    rows, (spec, loss_fn, params0, source) = bench(args.task, steps)
    print("name,us_per_step,derived")
    out = []
    base = rows[0][2]
    for mode, us, sps in rows:
        print(f"train_loop/{args.task}/{mode},{us:.1f},"
              f"steps_per_s={sps:.1f};speedup_vs_per_step={sps/base:.2f}x")
        out.append({"task": args.task, "mode": mode, "us_per_step": us,
                    "steps_per_s": sps, "speedup": sps / base})
    ovl = bench_overlap(spec, loss_fn, params0, source, steps)
    print(f"train_loop/{args.task}/overlap,"
          f"{ovl['overlap_us_per_step']:.1f},"
          f"efficiency_vs_seq={ovl['efficiency']:.2f}x;"
          f"bitexact={ovl['bitexact']}")
    art = Path("artifacts/bench")
    art.mkdir(parents=True, exist_ok=True)
    (art / "train_loop.json").write_text(json.dumps(out, indent=2))
    # perf-trajectory baseline: future PRs diff against the checked-in copy
    record = {"bench": "train_loop", "task": args.task, "steps": steps,
              "smoke": bool(args.smoke), "rows": out, "overlap": ovl}
    root = Path(__file__).resolve().parents[1]
    (root / "BENCH_train.json").write_text(
        json.dumps(record, indent=2) + "\n")
    print(f"# wrote {root / 'BENCH_train.json'}")
    # acceptance: scan fusion must beat the dispatch-bound per-step loop
    chunk8 = next(r for r in out if r["mode"] == "chunk8")
    assert chunk8["speedup"] > 1.0, \
        f"chunk=8 slower than per-step loop ({chunk8['speedup']:.2f}x)"
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
