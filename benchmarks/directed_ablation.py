"""Directed-graph ablation: convergence vs one-way link-loss rate under
push-sum (dp-csgp).

The churn ablation (benchmarks/churn_ablation.py) models *symmetric* outages:
an offline agent loses both directions of every link, and the surviving
graph stays undirected, so the doubly-stochastic family still applies.  The
common fleet failure is asymmetric -- agent i can hear j while j cannot hear
i -- and that breaks double stochasticity outright.  This ablation sweeps
the one-way link-loss rate of a ``directed:one_way`` schedule (each directed
edge of the skip-2 directed ring dropped independently per round) on the
paper's Section-5.1 logreg protocol, trained with the push-sum DP-CSGP
registration; rate 0 is the intact directed ring (``directed:ring_skips``).

All rows use the registry's uniform metrics schema (``loss``,
``consensus_x`` -- computed on the de-biased estimates ``x/xw`` --
``wire_bytes`` including the weight plane's bytes), so they are directly
comparable with the churn and static ablations.  Training runs through the
scan-fused chunked runtime and every chunk size must compile exactly ONE
executable: the column-stochastic ``W_t`` table is indexed by a traced round
counter exactly like the doubly-stochastic schedules, and the push-sum
weight plane rides inside the existing collectives (asserted below).  Each
row also reports the final weight spread ``max(xw)/min(xw)`` -- the push-sum
health signal: it stays near 1 on balanced graphs and widens as one-way
losses skew the stationary mass, while the *de-biased* consensus stays
tight.

Rows: ``directed/<rate>,final_loss,...``; artifacts land in
artifacts/bench/directed_ablation.json (EXPERIMENTS.md cookbook #10).

    PYTHONPATH=src python benchmarks/directed_ablation.py            # full
    PYTHONPATH=src python benchmarks/directed_ablation.py --smoke    # CI
"""

from __future__ import annotations

import argparse
import json
import sys
from pathlib import Path

if __package__ in (None, ""):  # `python benchmarks/directed_ablation.py`
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import numpy as np

from repro.api import build
from repro.data import a9a_like, minibatch_source, shard_to_agents
from repro.launch.runtime import make_runner
from benchmarks import common as C

RATES = (0.0, 0.1, 0.3, 0.5)
PERIOD = 8
SKIP = 2
CHUNK = 8


def _run(spec, loss_fn, params0, source, steps, chunk=CHUNK):
    """Train ``spec`` for ``steps`` rounds; return (algo, metrics, state).

    Asserts one executable per chunk size, exactly as churn_ablation.py
    does for the doubly-stochastic schedules: directed mixing and the
    push-sum weight plane must not cost recompiles.
    """
    algo = build(spec, loss_fn)
    state = algo.init(params0)
    key = jax.random.PRNGKey(0)
    runners, t, per_round = {}, 0, []
    while t < steps:
        size = min(chunk, steps - t)
        runner = runners.get(size)
        if runner is None:
            runner = runners[size] = make_runner(algo, source, size)
        state, key, metrics = runner(state, key, t)
        t += size
        per_round.append({k: np.asarray(v) for k, v in metrics.items()})
    for size, runner in runners.items():
        n_exec = runner.cache_size()
        assert n_exec in (None, 1), (
            f"chunk={size} compiled {n_exec} executables under the directed "
            "schedule (expected 1: W_t is a traced gather and the weight "
            "plane rides the same collectives)")
    stacked = {k: np.concatenate([m[k] for m in per_round])
               for k in per_round[0]}
    return algo, stacked, state


def run_ablation(steps=400, chunk=CHUNK):
    x, y = a9a_like(12000, 123, seed=0)
    xs, ys = shard_to_agents(x, y, C.N_AGENTS)
    loss_fn = C.logreg_loss()
    params0 = {"w": np.zeros(123, np.float32), "b": np.zeros((), np.float32)}
    source = minibatch_source(xs, ys, batch=4)

    # the Section-5.1 protocol, push-sum flavor: dp-csgp clips per sample
    # (tau=1) like PORTER-DP; sigma_p stays 0 so the sweep isolates the
    # connectivity axis (noise would dominate the loss floor)
    base = C.PAPER_SPEC.replace(algo="dp-csgp", compressor="top_k",
                                frac=0.05, eta=0.05, tau=1.0, sigma_p=0.0)

    results, rows = [], []
    for rate in RATES:
        sched = (f"directed:ring_skips,skip={SKIP}" if rate == 0.0 else
                 f"directed:one_way,rate={rate},period={PERIOD},skip={SKIP}")
        spec = base.replace(topology_schedule=sched)
        algo, m, state = _run(spec, loss_fn, params0, source, steps, chunk)
        q = max(len(m["loss"]) // 4, 1)
        s = algo.schedule
        xw = np.asarray(state.xw, np.float64)
        rec = {
            "rate": rate,
            "schedule": sched,
            "period": s.period,
            "window": PERIOD,
            "stochasticity": s.stochasticity,
            # the connectivity axis: contraction of a PERIOD-round window
            # (rate-0's period-1 row raised to the same window basis)
            "joint_contraction_gap": (
                1.0 - s.joint_alpha ** (PERIOD // s.period)
                if s.period < PERIOD else s.joint_spectral_gap),
            "per_round_alpha": s.alpha,
            "contraction_trajectory": [1.0 - a for a in s.alphas],
            "gamma": algo.gamma,
            # push-sum health: total mass is conserved (sum == n) while the
            # per-agent weights drift toward n*pi of the window product
            "weight_mass": float(xw.sum()),
            "weight_spread": float(xw.max() / xw.min()),
            # uniform schema: per-round means over the tail quarter
            "final_loss": float(np.mean(m["loss"][-q:])),
            "final_consensus_x": float(np.mean(m["consensus_x"][-q:])),
            "wire_mb_per_round": float(m["wire_bytes"][-1] / 1e6),
            "wire_mb_total": float(np.sum(m["wire_bytes"]) / 1e6),
            "loss_curve": m["loss"][:: max(steps // 50, 1)].tolist(),
            "consensus_curve":
                m["consensus_x"][:: max(steps // 50, 1)].tolist(),
        }
        rows.append(rec)
        print(f"directed/{rate},final_loss={rec['final_loss']:.4f},"
              f"consensus={rec['final_consensus_x']:.3e},"
              f"joint_gap={rec['joint_contraction_gap']:.3f},"
              f"wspread={rec['weight_spread']:.3f},"
              f"gamma={rec['gamma']:.4g},"
              f"wire_total={rec['wire_mb_total']:.3f}MB")

    # sanity on the axis itself: every window still strongly connects (the
    # generator resamples disconnected rounds), mass is exactly conserved
    for r in rows:
        assert r["joint_contraction_gap"] > 0.0, r
        assert abs(r["weight_mass"] - C.N_AGENTS) < 1e-3, r
    return {f"rate_{r['rate']}": r for r in rows}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=None,
                    help="rounds per rate (default 400, or 32 with --smoke)")
    ap.add_argument("--smoke", action="store_true", help="CI-sized run")
    args = ap.parse_args()
    steps = args.steps or (32 if args.smoke else 400)

    results = run_ablation(steps=steps)
    art = Path("artifacts/bench")
    art.mkdir(parents=True, exist_ok=True)
    (art / "directed_ablation.json").write_text(json.dumps(results, indent=2))
    print(f"wrote artifacts/bench/directed_ablation.json "
          f"({len(results)} rates x {steps} rounds)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
