"""Shared benchmark plumbing: experiment protocol of paper Section 5
(10 agents, ER(0.8), random-5% compression, tau=1, batch 1, best-tuned-ish
learning rates) over synthetic stand-ins with the paper's dimensions."""

from __future__ import annotations

import functools
import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (PorterConfig, average_params, calibrate_sigma,
                        make_compressor, make_mixer, make_porter_step,
                        make_topology, porter_init)
from repro.core import baselines as BL
from repro.core.gossip import make_dense_mixer

N_AGENTS = 10


def timed(fn, *args, reps=3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def paper_topology(seed=1):
    return make_topology("erdos_renyi", N_AGENTS, weights="best_constant",
                         p=0.8, seed=seed)


def logreg_loss(lam=0.2):
    def loss_fn(params, batch):
        f, l = batch
        f = jnp.atleast_2d(f)
        l = jnp.atleast_1d(l)
        logits = f @ params["w"] + params["b"]
        nll = jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits)))
        reg = lam * jnp.sum(params["w"] ** 2 / (1 + params["w"] ** 2))
        return nll + reg
    return loss_fn


def mlp_loss():
    """Paper 5.2: 784 -> 64 sigmoid -> 10 softmax cross-entropy."""
    def loss_fn(params, batch):
        f, l = batch
        f = jnp.atleast_2d(f)
        l = jnp.atleast_1d(l)
        h = jax.nn.sigmoid(f @ params["w1"] + params["c1"])
        logits = h @ params["w2"] + params["c2"]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - gold)
    return loss_fn


def mlp_params0(key=None):
    key = key or jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    return {"w1": 0.05 * jax.random.normal(k1, (784, 64)),
            "c1": jnp.zeros(64),
            "w2": 0.05 * jax.random.normal(k2, (64, 10)),
            "c2": jnp.zeros(10)}


def accuracy_fn(kind):
    if kind == "logreg":
        def acc(params, f, l):
            logits = f @ params["w"] + params["b"]
            return float(jnp.mean((logits > 0) == (l > 0.5)))
    else:
        def acc(params, f, l):
            h = jax.nn.sigmoid(f @ params["w1"] + params["c1"])
            logits = h @ params["w2"] + params["c2"]
            return float(jnp.mean(jnp.argmax(logits, -1) == l))
    return acc


def run_porter(loss_fn, params0, it, top, steps, eta, variant="dp",
               sigma_p=0.0, frac=0.05, comp_name="random_k", tau=1.0,
               eval_every=25, eval_cb=None, seed=0):
    comp = make_compressor(comp_name, frac=frac)
    mixer = make_mixer(top, "dense")
    gamma = 0.5 * (1 - top.alpha) * frac
    cfg = PorterConfig(eta=eta, gamma=gamma, tau=tau, variant=variant,
                       sigma_p=sigma_p)
    state = porter_init(params0, top.n, w=top.w)
    step = jax.jit(make_porter_step(cfg, loss_fn, mixer, comp))
    key = jax.random.PRNGKey(seed)
    curve = []
    for t in range(steps):
        key, k = jax.random.split(key)
        state, m = step(state, next(it), k)
        if eval_cb and (t % eval_every == 0 or t == steps - 1):
            curve.append((t,) + eval_cb(average_params(state.x),
                                        float(m["loss"])))
    return state, curve


def run_soteria(loss_fn, params0, it, steps, eta, sigma_p=0.0, frac=0.05,
                tau=1.0, eval_every=25, eval_cb=None, seed=0):
    comp = make_compressor("random_k", frac=frac)
    state = BL.soteria_init(params0, N_AGENTS)
    step = jax.jit(functools.partial(BL.soteria_step, eta, 0.5, loss_fn,
                                     comp, tau=tau, sigma_p=sigma_p))
    key = jax.random.PRNGKey(seed)
    curve = []
    for t in range(steps):
        key, k = jax.random.split(key)
        state, m = step(state, next(it), k)
        if eval_cb and (t % eval_every == 0 or t == steps - 1):
            curve.append((t,) + eval_cb(state.x, float(m["loss"])))
    return state, curve


def run_dsgd_dp(loss_fn, params0, it, top, steps, eta, sigma_p=0.0, tau=1.0,
                eval_every=25, eval_cb=None, seed=0):
    mixer = make_dense_mixer(top.w)
    state = BL.dsgd_init(params0, top.n)
    step = jax.jit(functools.partial(BL.dsgd_step, eta, 1.0, loss_fn, mixer,
                                     tau=tau, sigma_p=sigma_p, dp=True))
    key = jax.random.PRNGKey(seed)
    curve = []
    for t in range(steps):
        key, k = jax.random.split(key)
        state, m = step(state, next(it), k)
        if eval_cb and (t % eval_every == 0 or t == steps - 1):
            curve.append((t,) + eval_cb(average_params(state.x),
                                        float(m["loss"])))
    return state, curve
