"""Shared benchmark plumbing: experiment protocol of paper Section 5
(10 agents, ER(0.8), random-5% compression, tau=1, batch 1, best-tuned-ish
learning rates) over synthetic stand-ins with the paper's dimensions.

This module is the benchmarks' one stop for algorithm construction: the
``run_*`` helpers and the topology builders delegate to the ``repro.api``
facade, so no benchmark wires mixers/engines by hand."""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExperimentSpec, build, resolve_topology
from repro.core import average_params, calibrate_sigma
from repro.launch.runtime import make_runner
from repro.models import mlp_init, mlp_loss as _shared_mlp_loss

N_AGENTS = 10

# the paper's Section-5 graph: ER(0.8) with the best-constant weights
PAPER_SPEC = ExperimentSpec(n_agents=N_AGENTS, topology="erdos_renyi",
                            topology_weights="best_constant", topology_p=0.8,
                            topology_seed=1)


def timed(fn, *args, reps=3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def paper_topology(seed=1):
    return resolve_topology(PAPER_SPEC.replace(topology_seed=seed))


def topology(kind: str, seed=1):
    """A best-constant-weighted graph of the given kind at benchmark scale
    (the facade-backed replacement for ad-hoc make_topology calls)."""
    return resolve_topology(PAPER_SPEC.replace(topology=kind,
                                               topology_seed=seed))


def logreg_loss(lam=0.2):
    def loss_fn(params, batch):
        f, l = batch
        f = jnp.atleast_2d(f)
        l = jnp.atleast_1d(l)
        logits = f @ params["w"] + params["b"]
        nll = jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits)))
        reg = lam * jnp.sum(params["w"] ** 2 / (1 + params["w"] ** 2))
        return nll + reg
    return loss_fn


def mlp_loss():
    """Paper 5.2 MLP loss (shared definition: repro.models.paper)."""
    return _shared_mlp_loss()


def mlp_params0(key=None):
    """Paper 5.2 MLP init (shared definition: repro.models.paper)."""
    return mlp_init(key)


def accuracy_fn(kind):
    # one explicit numpy boundary conversion per eval; comparisons stay host-side
    if kind == "logreg":
        def acc(params, f, l):
            logits = np.asarray(f @ params["w"] + params["b"])
            return float(np.mean((logits > 0) == (np.asarray(l) > 0.5)))
    else:
        def acc(params, f, l):
            h = jax.nn.sigmoid(f @ params["w1"] + params["c1"])
            logits = np.asarray(h @ params["w2"] + params["c2"])
            return float(np.mean(np.argmax(logits, -1) == np.asarray(l)))
    return acc


def run_algorithm(spec, loss_fn, params0, source, steps, *, topology=None,
                  eval_every=25, eval_cb=None, eval_point=None, seed=0):
    """Build ``spec`` through the facade and run it for ``steps`` rounds
    through the chunked runtime (repro.launch.runtime).

    source: a BatchSource ``(key, step) -> agent-stacked batch`` (e.g.
    ``repro.data.minibatch_source``); batches are synthesized inside the
    compiled chunk, and the run is cut into scan-fused chunks whose
    boundaries land exactly on the historical sample grid
    {0, eval_every, 2*eval_every, ..., steps-1}, so metrics stay on device
    and the host syncs only at curve sample points.

    eval_cb(point, metrics) -> tuple is sampled at each grid point, where
    ``metrics`` is the host dict of that round's metrics (loss,
    wire_bytes, ...); ``eval_point`` maps the state to the evaluation
    iterate (defaults to the average replica for agent-stacked states, the
    server model otherwise).
    """
    algo = build(spec, loss_fn, topology=topology)
    if eval_point is None:
        eval_point = ((lambda s: average_params(s.x))
                      if algo.info.decentralized else (lambda s: s.x))
    state = algo.init(params0, n_agents=(topology.n if topology is not None
                                         else None))
    key = jax.random.PRNGKey(seed)
    if eval_cb:
        # chunk ends one past each sample step: the boundary state/metrics
        # are exactly what the per-step loop sampled at t
        ends = sorted({t + 1 for t in range(0, steps, eval_every)} | {steps})
    else:
        ends = [steps]
    curve = []
    runners, t = {}, 0
    for end in ends:
        size = end - t
        runner = runners.get(size)
        if runner is None:
            runner = runners[size] = make_runner(algo, source, size)
        state, key, metrics = runner(state, key, t)
        t = end
        if eval_cb:
            m = {k: float(v[-1]) for k, v in metrics.items()}
            curve.append((t - 1,) + eval_cb(eval_point(state), m))
    return state, curve


def run_porter(loss_fn, params0, source, top, steps, eta, variant="dp",
               sigma_p=0.0, frac=0.05, comp_name="random_k", tau=1.0,
               eval_every=25, eval_cb=None, seed=0):
    spec = PAPER_SPEC.replace(algo=f"porter-{variant}" if variant != "beer"
                              else "beer", n_agents=top.n, eta=eta,
                              sigma_p=sigma_p, frac=frac,
                              compressor=comp_name, tau=tau)
    return run_algorithm(spec, loss_fn, params0, source, steps, topology=top,
                         eval_every=eval_every, eval_cb=eval_cb, seed=seed)


def run_soteria(loss_fn, params0, source, steps, eta, sigma_p=0.0, frac=0.05,
                tau=1.0, eval_every=25, eval_cb=None, seed=0):
    spec = PAPER_SPEC.replace(algo="soteriafl", eta=eta, sigma_p=sigma_p,
                              frac=frac, compressor="random_k", tau=tau,
                              alpha_shift=0.5)
    return run_algorithm(spec, loss_fn, params0, source, steps,
                         eval_every=eval_every, eval_cb=eval_cb, seed=seed)


def run_dsgd_dp(loss_fn, params0, source, top, steps, eta, sigma_p=0.0, tau=1.0,
                eval_every=25, eval_cb=None, seed=0):
    spec = PAPER_SPEC.replace(algo="dsgd", n_agents=top.n, eta=eta,
                              sigma_p=sigma_p, tau=tau, dp=True)
    return run_algorithm(spec, loss_fn, params0, source, steps, topology=top,
                         eval_every=eval_every, eval_cb=eval_cb, seed=seed)
