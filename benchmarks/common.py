"""Shared benchmark plumbing: experiment protocol of paper Section 5
(10 agents, ER(0.8), random-5% compression, tau=1, batch 1, best-tuned-ish
learning rates) over synthetic stand-ins with the paper's dimensions.

This module is the benchmarks' one stop for algorithm construction: the
``run_*`` helpers and the topology builders delegate to the ``repro.api``
facade, so no benchmark wires mixers/engines by hand."""

from __future__ import annotations

import time
from typing import Callable, Dict, List

import jax
import jax.numpy as jnp
import numpy as np

from repro.api import ExperimentSpec, build, resolve_topology
from repro.core import average_params, calibrate_sigma

N_AGENTS = 10

# the paper's Section-5 graph: ER(0.8) with the best-constant weights
PAPER_SPEC = ExperimentSpec(n_agents=N_AGENTS, topology="erdos_renyi",
                            topology_weights="best_constant", topology_p=0.8,
                            topology_seed=1)


def timed(fn, *args, reps=3):
    fn(*args)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps * 1e6  # us


def paper_topology(seed=1):
    return resolve_topology(PAPER_SPEC.replace(topology_seed=seed))


def topology(kind: str, seed=1):
    """A best-constant-weighted graph of the given kind at benchmark scale
    (the facade-backed replacement for ad-hoc make_topology calls)."""
    return resolve_topology(PAPER_SPEC.replace(topology=kind,
                                               topology_seed=seed))


def logreg_loss(lam=0.2):
    def loss_fn(params, batch):
        f, l = batch
        f = jnp.atleast_2d(f)
        l = jnp.atleast_1d(l)
        logits = f @ params["w"] + params["b"]
        nll = jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits)))
        reg = lam * jnp.sum(params["w"] ** 2 / (1 + params["w"] ** 2))
        return nll + reg
    return loss_fn


def mlp_loss():
    """Paper 5.2: 784 -> 64 sigmoid -> 10 softmax cross-entropy."""
    def loss_fn(params, batch):
        f, l = batch
        f = jnp.atleast_2d(f)
        l = jnp.atleast_1d(l)
        h = jax.nn.sigmoid(f @ params["w1"] + params["c1"])
        logits = h @ params["w2"] + params["c2"]
        lse = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, l[:, None], axis=-1)[:, 0]
        return jnp.mean(lse - gold)
    return loss_fn


def mlp_params0(key=None):
    key = key or jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(key)
    return {"w1": 0.05 * jax.random.normal(k1, (784, 64)),
            "c1": jnp.zeros(64),
            "w2": 0.05 * jax.random.normal(k2, (64, 10)),
            "c2": jnp.zeros(10)}


def accuracy_fn(kind):
    if kind == "logreg":
        def acc(params, f, l):
            logits = f @ params["w"] + params["b"]
            return float(jnp.mean((logits > 0) == (l > 0.5)))
    else:
        def acc(params, f, l):
            h = jax.nn.sigmoid(f @ params["w1"] + params["c1"])
            logits = h @ params["w2"] + params["c2"]
            return float(jnp.mean(jnp.argmax(logits, -1) == l))
    return acc


def run_algorithm(spec, loss_fn, params0, it, steps, *, topology=None,
                  eval_every=25, eval_cb=None, eval_point=None, seed=0):
    """Build ``spec`` through the facade and run it for ``steps`` rounds.

    eval_cb(point, loss) -> tuple is sampled every ``eval_every`` rounds;
    ``eval_point`` maps the state to the evaluation iterate (defaults to the
    average replica for agent-stacked states, the server model otherwise).
    """
    algo = build(spec, loss_fn, topology=topology)
    if eval_point is None:
        eval_point = ((lambda s: average_params(s.x))
                      if algo.info.decentralized else (lambda s: s.x))
    state = algo.init(params0, n_agents=(topology.n if topology is not None
                                         else None))
    step = jax.jit(algo.step)
    key = jax.random.PRNGKey(seed)
    curve = []
    for t in range(steps):
        key, k = jax.random.split(key)
        state, m = step(state, next(it), k)
        if eval_cb and (t % eval_every == 0 or t == steps - 1):
            curve.append((t,) + eval_cb(eval_point(state), float(m["loss"])))
    return state, curve


def run_porter(loss_fn, params0, it, top, steps, eta, variant="dp",
               sigma_p=0.0, frac=0.05, comp_name="random_k", tau=1.0,
               eval_every=25, eval_cb=None, seed=0):
    spec = PAPER_SPEC.replace(algo=f"porter-{variant}" if variant != "beer"
                              else "beer", n_agents=top.n, eta=eta,
                              sigma_p=sigma_p, frac=frac,
                              compressor=comp_name, tau=tau)
    return run_algorithm(spec, loss_fn, params0, it, steps, topology=top,
                         eval_every=eval_every, eval_cb=eval_cb, seed=seed)


def run_soteria(loss_fn, params0, it, steps, eta, sigma_p=0.0, frac=0.05,
                tau=1.0, eval_every=25, eval_cb=None, seed=0):
    spec = PAPER_SPEC.replace(algo="soteriafl", eta=eta, sigma_p=sigma_p,
                              frac=frac, compressor="random_k", tau=tau,
                              alpha_shift=0.5)
    return run_algorithm(spec, loss_fn, params0, it, steps,
                         eval_every=eval_every, eval_cb=eval_cb, seed=seed)


def run_dsgd_dp(loss_fn, params0, it, top, steps, eta, sigma_p=0.0, tau=1.0,
                eval_every=25, eval_cb=None, seed=0):
    spec = PAPER_SPEC.replace(algo="dsgd", n_agents=top.n, eta=eta,
                              sigma_p=sigma_p, tau=tau, dp=True)
    return run_algorithm(spec, loss_fn, params0, it, steps, topology=top,
                         eval_every=eval_every, eval_cb=eval_cb, seed=seed)
