"""Render artifacts/dryrun/*.json into the EXPERIMENTS.md markdown tables
(§Dry-run and §Roofline) and a per-pair bottleneck narrative.

    PYTHONPATH=src python -m benchmarks.report > artifacts/roofline_report.md
"""

from __future__ import annotations

import json
import sys
from pathlib import Path

ARCH_ORDER = ["rwkv6-7b", "minicpm3-4b", "seamless-m4t-medium",
              "tinyllama-1.1b", "h2o-danube-3-4b", "chatglm3-6b",
              "grok-1-314b", "arctic-480b", "paligemma-3b", "zamba2-7b"]
SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def _fmt_s(x):
    if x is None:
        return "-"
    if x >= 0.1:
        return f"{x:.2f}"
    if x >= 1e-3:
        return f"{x*1e3:.1f}m"
    return f"{x*1e6:.0f}u"


def _fmt_b(x):
    if x is None:
        return "-"
    for unit, div in (("GiB", 2**30), ("MiB", 2**20), ("KiB", 2**10)):
        if x >= div:
            return f"{x/div:.1f}{unit}"
    return f"{x:.0f}B"


def load(src: Path, mesh: str, tag: str = ""):
    recs = {}
    for f in src.glob(f"*__{mesh}{'__' + tag if tag else ''}.json"):
        rec = json.loads(f.read_text())
        if rec.get("tag", "") != tag:
            continue
        recs[(rec["arch"], rec["shape"])] = rec
    return recs


def what_moves_it(rec) -> str:
    """One sentence: what would move the dominant term down."""
    r = rec["roofline"]
    dom = r["dominant"]
    kind = rec["kind"]
    if dom == "collective":
        if kind == "train":
            return ("dense gossip all-gathers every EF increment; switch to "
                    "packed top-k or ring ppermute wire formats")
        return "tensor-parallel all-reduces; shard activations or fuse layers"
    if dom == "memory":
        if kind == "decode":
            return ("cache reads dominate (bandwidth-bound decode, as "
                    "expected); shrink cache dtype or window")
        return ("activation traffic; bigger fused blocks / flash-style "
                "attention chunking cuts HBM round-trips")
    return "MXU-bound; increase per-chip batch or reduce precision"


def table(recs, mesh: str):
    lines = [
        f"#### Mesh `{mesh}`",
        "",
        "| arch | shape | compute | memory | collective | dominant | "
        "useful-FLOPs ratio | temp bytes/dev | coll bytes/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for arch in ARCH_ORDER:
        for shape in SHAPE_ORDER:
            rec = recs.get((arch, shape))
            if rec is None:
                continue
            if not rec["ok"]:
                lines.append(f"| {arch} | {shape} | FAILED: "
                             f"{rec.get('error', '?')[:60]} | | | | | | |")
                continue
            r = rec["roofline"]
            ma = rec.get("memory_analysis") or {}
            lines.append(
                f"| {arch} | {shape} | {_fmt_s(r['compute_s'])} "
                f"| {_fmt_s(r['memory_s'])} | {_fmt_s(r['collective_s'])} "
                f"| **{r['dominant']}** "
                f"| {r['useful_flops_ratio']:.2f} "
                f"| {_fmt_b(ma.get('temp_size_in_bytes'))} "
                f"| {_fmt_b(r['wire_bytes_per_chip'])} |")
    return "\n".join(lines)


def narrative(recs):
    lines = ["", "Per-pair dominant bottleneck and the lever that moves it:",
             ""]
    for (arch, shape), rec in sorted(recs.items()):
        if rec["ok"]:
            lines.append(f"* `{arch} x {shape}`: {rec['roofline']['dominant']}"
                         f"-bound -- {what_moves_it(rec)}.")
    return "\n".join(lines)


def main():
    src = Path("artifacts/dryrun")
    tag = sys.argv[1] if len(sys.argv) > 1 else ""
    for mesh in ("pod16x16", "pod2x16x16"):
        recs = load(src, mesh, tag)
        if not recs:
            continue
        print(table(recs, mesh))
        print()
    recs = load(src, "pod16x16", tag)
    print(narrative(recs))


if __name__ == "__main__":
    main()
