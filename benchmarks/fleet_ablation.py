"""Fleet ablation: convergence vs. fleet size n (n >> devices).

The per-device harness tops out at one agent per device; the fleet
subsystem (``core/fleet.py``, ``ExperimentSpec(fleet=True)``) simulates
the whole population as one leading vmapped axis, with sparse COO mixing
above ``FLEET_DENSE_GATE``.  This ablation runs the paper's Section-5.1
logreg protocol (a9a-style features, top-5% compression, tau = 1) at
n = 256 / 1024 / 4096 agents on Dirichlet(0.3)-heterogeneous shards and
reports the two axes the per-device harness cannot measure:

* **convergence vs. n**: final loss / consensus and the loss curve per
  rung, with the rung's spectral gap (the exponential graph keeps the
  same family at every n, so the gap shrinks honestly with log n);
* **throughput**: simulated agent-rounds per wall-clock second through
  the scan-fused chunked runtime.

Every rung must compile exactly ONE executable for its chunk runner (the
round offset is traced, so the n sweep costs one compile per shape and
zero retraces inside a rung -- asserted below).  When the process owns
more than one device (e.g. ``--xla_force_host_platform_device_count=8``
in the CI fleet job), the fleet axis is sharded over a 1-D CPU host mesh
and the same single-executable contract must hold.

Rows: ``fleet/<n>,final_loss,...``; artifacts land in
artifacts/bench/fleet_ablation.json plus the checked-in perf-trajectory
baseline BENCH_fleet.json (EXPERIMENTS.md section "Fleet").

    PYTHONPATH=src python benchmarks/fleet_ablation.py            # full
    PYTHONPATH=src python benchmarks/fleet_ablation.py --smoke    # CI
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

if __package__ in (None, ""):  # `python benchmarks/fleet_ablation.py`
    sys.path.insert(0, str(Path(__file__).resolve().parents[1]))

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.api import ExperimentSpec, build
from repro.core import FLEET_DENSE_GATE
from repro.data import a9a_like, dirichlet_source
from repro.launch.runtime import make_runner
from benchmarks import common as C

RUNGS = (256, 1024, 4096)
D_FEAT = 123            # a9a dimensionality (Section 5.1)
SHARD = 16              # samples per agent (Dirichlet-resampled)
BATCH = 4
CHUNK = 8
ALPHA_DIR = 0.3         # Dirichlet heterogeneity


def _fleet_spec(n: int, algo: str) -> ExperimentSpec:
    # Section-5.1 knobs on the exponential graph: the one generator that
    # keeps the same family from the dense gate to n = 100k (ER(0.8)
    # would materialize ~0.8 n^2 edges; fleet ER is degree-sampled and
    # changes family at the gate)
    return ExperimentSpec(algo=algo, n_agents=n, topology="exponential",
                          topology_weights="metropolis", compressor="top_k",
                          frac=0.05, eta=0.05, tau=1.0, fleet=True)


def _fleet_shardings(state, batch_shape, n):
    """Shard the leading fleet axis over every device the process owns
    (1-D host mesh); replicate everything else.  No-op on one device."""
    devs = jax.devices()
    if len(devs) < 2 or n % len(devs) != 0:
        return None, None
    mesh = Mesh(np.asarray(devs), ("fleet",))

    def spec(leaf):
        if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] == n:
            return NamedSharding(mesh, P("fleet",
                                         *([None] * (leaf.ndim - 1))))
        return NamedSharding(mesh, P())

    state_sh = jax.tree_util.tree_map(spec, state)
    batch_sh = tuple(
        NamedSharding(mesh, P("fleet", *([None] * (len(s) - 1))))
        for s in batch_shape)
    return state_sh, batch_sh


def run_rung(n: int, steps: int, algo_name: str, seed: int = 0):
    x, y = a9a_like(n * SHARD, D_FEAT, seed=seed)
    source = dirichlet_source(np.asarray(x), np.asarray(y), n_agents=n,
                              batch=BATCH, alpha=ALPHA_DIR, seed=seed)
    loss_fn = C.logreg_loss()
    params0 = {"w": np.zeros(D_FEAT, np.float32),
               "b": np.zeros((), np.float32)}

    algo = build(_fleet_spec(n, algo_name), loss_fn)
    state = algo.init(params0)
    state_sh, batch_sh = _fleet_shardings(
        state, ((n, BATCH, D_FEAT), (n, BATCH)), n)
    runner = make_runner(algo, source, CHUNK, state_sharding=state_sh,
                         batch_sharding=batch_sh)

    key = jax.random.PRNGKey(0)
    per_chunk, t = [], 0
    elapsed, timed_rounds = 0.0, 0
    while t + CHUNK <= steps:
        t0 = time.perf_counter()
        state, key, metrics = runner(state, key, t)
        jax.block_until_ready(state)
        dt = time.perf_counter() - t0
        if t > 0:  # skip the compile chunk
            elapsed += dt
            timed_rounds += CHUNK
        t += CHUNK
        per_chunk.append({k: np.asarray(v) for k, v in metrics.items()})
    n_exec = runner.cache_size()
    assert n_exec in (None, 1), (
        f"n={n}: chunk runner compiled {n_exec} executables (expected 1: "
        "the round offset is traced)")

    m = {k: np.concatenate([c[k] for c in per_chunk])
         for k in per_chunk[0]}
    q = max(len(m["loss"]) // 4, 1)
    top = algo.topology
    gap = getattr(top, "spectral_gap", None)
    rec = {
        "n": n,
        "sparse_path": bool(n > FLEET_DENSE_GATE),
        "spectral_gap": None if gap is None else float(gap),
        "gamma": float(algo.gamma),
        "devices": len(jax.devices()),
        "sharded": state_sh is not None,
        "executables": 1 if n_exec is None else int(n_exec),
        "steps": int(len(m["loss"])),
        "first_loss": float(m["loss"][0]),
        "final_loss": float(np.mean(m["loss"][-q:])),
        "final_consensus_x": float(np.mean(m["consensus_x"][-q:])),
        "wire_mb_per_round": float(m["wire_bytes"][-1] / 1e6),
        "loss_curve": m["loss"][:: max(len(m["loss"]) // 40, 1)].tolist(),
        "agent_rounds_per_s": (float(n * timed_rounds / elapsed)
                               if elapsed > 0 else None),
        "s_per_round": (float(elapsed / timed_rounds)
                        if timed_rounds else None),
    }
    assert np.isfinite(m["loss"]).all(), f"n={n}: non-finite loss"
    assert rec["final_loss"] < rec["first_loss"], (
        f"n={n}: no convergence ({rec['first_loss']:.4f} -> "
        f"{rec['final_loss']:.4f})")
    return rec


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=None,
                    help="rounds per rung (default 200, or 24 with --smoke)")
    ap.add_argument("--algo", default="clip21",
                    help="registered fleet-compatible algorithm")
    ap.add_argument("--smoke", action="store_true",
                    help="CI-sized run: n=256 only")
    args = ap.parse_args()
    steps = args.steps or (24 if args.smoke else 200)
    rungs = RUNGS[:1] if args.smoke else RUNGS

    rows = []
    for n in rungs:
        rec = run_rung(n, steps, args.algo)
        rows.append(rec)
        aps = rec["agent_rounds_per_s"]
        print(f"fleet/{n},final_loss={rec['final_loss']:.4f},"
              f"consensus={rec['final_consensus_x']:.3e},"
              f"gap={rec['spectral_gap']:.4f},"
              f"sparse={int(rec['sparse_path'])},"
              f"agent_rounds_per_s={0.0 if aps is None else aps:.0f},"
              f"executables={rec['executables']}")

    # one executable per rung, across the whole n sweep
    assert all(r["executables"] == 1 for r in rows), rows

    art = Path("artifacts/bench")
    art.mkdir(parents=True, exist_ok=True)
    (art / "fleet_ablation.json").write_text(json.dumps(rows, indent=2))
    record = {"bench": "fleet_ablation", "algo": args.algo, "steps": steps,
              "smoke": bool(args.smoke), "protocol": {
                  "topology": "exponential/metropolis",
                  "compressor": "top_k", "frac": 0.05, "tau": 1.0,
                  "eta": 0.05, "dirichlet_alpha": ALPHA_DIR,
                  "shard": SHARD, "batch": BATCH},
              "rungs": rows}
    root = Path(__file__).resolve().parents[1]
    (root / "BENCH_fleet.json").write_text(
        json.dumps(record, indent=2) + "\n")
    print(f"# wrote {root / 'BENCH_fleet.json'}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
