"""Ablation: decentralized algorithms on equal footing -- PORTER-GC vs BEER
vs CHOCO-SGD vs DSGD, measured in (a) rounds and (b) communicated megabytes
to reach a target gradient norm.  This is the systems-level comparison the
paper motivates (communication efficiency) but only reports indirectly.

Wire accounting comes from each algorithm's own ``wire_bytes`` metric (the
uniform schema emitted by every step function via the comm-round engine --
see repro.core.comm_round.CommRound.wire_bytes), so all algorithms are
measured by exactly the bytes their wire format moves per round:

    DSGD      : n * d floats, uncompressed                (1 buffer)
    CHOCO-SGD : n * (rho*d values + indices)              (1 buffer)
    PORTER    : n * (rho*d values + indices) x 2 buffers  (Q_x and Q_v)

Every contender is declared as an ExperimentSpec and built through the
``repro.api`` facade -- the equal footing is the registry's uniform
init/step/metrics protocol.
"""

from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import a9a_like, minibatch_source, shard_to_agents
from benchmarks import common as C

RHO = 0.05
TARGET = 0.08


def run_ablation(steps=400, seed=0):
    x, y = a9a_like(12000, 123, seed=0)
    xs, ys = shard_to_agents(x, y, C.N_AGENTS)
    top = C.paper_topology()
    loss_fn = C.logreg_loss()
    params0 = {"w": jnp.zeros(123), "b": jnp.zeros(())}
    flat = (xs.reshape(-1, 123), ys.reshape(-1))

    def gnorm(p):
        g = jax.grad(loss_fn)(p, flat)
        sq = sum(jnp.sum(v ** 2) for v in jax.tree_util.tree_leaves(g))
        return float(np.sqrt(np.asarray(sq)))

    results = {}

    def track(name, curve):
        """curve rows are (t, |grad(x-bar)|, wire_bytes); wire_bytes is the
        uniform per-round metric so MB-to-target needs no per-algorithm
        accounting here."""
        rounds_to_target = None
        final = None
        bytes_per_round = None
        for t, g, wire in curve:
            final = g
            bytes_per_round = wire
            if rounds_to_target is None and g <= TARGET:
                rounds_to_target = t
        mb = (None if rounds_to_target is None else
              rounds_to_target * bytes_per_round / 1e6)
        results[name] = {"rounds_to_target": rounds_to_target,
                         "MB_to_target": mb, "final_grad": final,
                         "bytes_per_round": bytes_per_round}

    # the four contenders, on one declarative footing (gamma_scale mirrors
    # each method's stable tuning: PORTER/BEER 0.5, CHOCO 0.3; DSGD is
    # uncompressed so its gossip weight defaults to 1)
    base = C.PAPER_SPEC.replace(compressor="top_k", frac=RHO, eta=0.05)
    specs = {
        "porter_gc": base.replace(algo="porter-gc", tau=1.0),
        "beer": base.replace(algo="beer", tau=None),
        "choco_sgd": base.replace(algo="choco", tau=None, gamma_scale=0.3),
        "dsgd": base.replace(algo="dsgd", tau=None),
    }

    source = minibatch_source(xs, ys, batch=4)

    def cb(p_avg, m):
        return (gnorm(p_avg), m["wire_bytes"])

    for name, spec in specs.items():
        # chunked runtime: one scan-fused dispatch per 10-round sample
        # window, host sync only at the sample points (benchmarks.common)
        _, curve = C.run_algorithm(spec, loss_fn, params0, source, steps,
                                   topology=top, eval_every=10, eval_cb=cb,
                                   seed=seed)
        track(name, curve)
    return results


def bench_ablation():
    from benchmarks.run import emit, _save
    res = run_ablation()
    _save("ablation_algorithms", res)
    parts = []
    for name, r in res.items():
        rt = r["rounds_to_target"]
        mb = r["MB_to_target"]
        parts.append(f"{name}:rounds={rt};MB={mb if mb is None else round(mb, 3)}")
    emit("ablation_to_|g|<=0.08", 0.0, "|".join(parts))
