"""Registry completeness + facade contract.

Every registered algorithm must: build from an ExperimentSpec, jit, emit
the uniform ``loss``/``wire_bytes`` metrics schema, and decrease loss on
the logreg smoke task in <= 200 steps.  The engine-footgun fix and the
gamma derivation are pinned here too.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (ExperimentSpec, algorithm_info, build, build_engine,
                       list_algorithms, resolve_compressor, resolve_gamma,
                       resolve_topology)
from repro.core import CommRound, make_compressor, make_mixer, make_topology
from repro.core.porter import porter_init, porter_step

EXPECTED_ALGOS = {"porter-gc", "porter-dp", "beer", "porter-adam", "dsgd",
                  "choco", "dp-sgd", "soteriafl", "dp-csgp", "clip21",
                  "subgrad-comp"}

N, D, B = 4, 24, 6


def _loss_fn(params, batch):
    f, l = batch
    f, l = jnp.atleast_2d(f), jnp.atleast_1d(l)
    logits = f @ params["w"] + params["b"]
    return jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits)))


def _problem(seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=D)
    f = rng.normal(size=(N, B, D)).astype(np.float32)
    l = (f @ w_true > 0).astype(np.float32)
    params0 = {"w": jnp.zeros(D), "b": jnp.zeros(())}
    return params0, (jnp.asarray(f), jnp.asarray(l))


def _spec(name, **over):
    kw = dict(algo=name, n_agents=N, topology="ring", compressor="top_k",
              frac=0.25, eta=0.1, tau=5.0, sigma_p=0.0)
    kw.update(over)
    return ExperimentSpec(**kw)


def test_all_eleven_registered():
    assert set(list_algorithms()) == EXPECTED_ALGOS


@pytest.mark.parametrize("name", sorted(EXPECTED_ALGOS))
def test_registered_algorithm_trains(name):
    """build -> init -> jit(step): uniform schema + loss decreases."""
    params0, batch = _problem()
    algo = build(_spec(name), _loss_fn)
    assert algo.name == name and algo.info is algorithm_info(name)
    state = algo.init(params0)
    assert isinstance(state, algo.state_cls)
    step = jax.jit(algo.step)
    key = jax.random.PRNGKey(0)
    first = None
    for _ in range(120):  # <= 200-step smoke budget
        key, k = jax.random.split(key)
        state, m = step(state, batch, k)
        first = float(m["loss"]) if first is None else first
    # uniform metrics schema
    assert {"loss", "wire_bytes"} <= set(m)
    assert float(m["wire_bytes"]) > 0
    if algo.info.decentralized:
        assert "consensus_x" in m
    last = float(m["loss"])
    assert np.isfinite(last) and last < first


def test_dp_flags_match_oracles():
    for name in ("porter-dp", "dp-sgd", "soteriafl", "dp-csgp"):
        assert algorithm_info(name).dp
    for name in ("porter-gc", "beer", "porter-adam", "choco", "dsgd",
                 "clip21", "subgrad-comp"):
        assert not algorithm_info(name).dp


def test_unclipped_porter_gc_is_beer():
    """tau=None for porter-gc must hit the exact no-clip point (BEER),
    not tau=inf through the smooth clip (whose factor is NaN)."""
    params0, batch = _problem()
    algo = build(_spec("porter-gc", tau=None), _loss_fn)
    assert algo.config.variant == "beer"
    state = algo.init(params0)
    state, m = jax.jit(algo.step)(state, batch, jax.random.PRNGKey(0))
    assert np.isfinite(float(m["loss"]))
    assert all(np.all(np.isfinite(np.asarray(l)))
               for l in jax.tree_util.tree_leaves(state.x))


@pytest.mark.parametrize("name", ["porter-dp", "dp-sgd", "soteriafl",
                                  "dp-csgp"])
def test_dp_algorithms_reject_unclipped_tau(name):
    """Noise is calibrated to tau's sensitivity; tau=None must not silently
    run unclipped."""
    with pytest.raises(ValueError, match="privacy guarantee"):
        build(_spec(name, tau=None), _loss_fn)


def test_dpsgd_rejects_non_agent_stacked_batch():
    params0, _ = _problem()
    algo = build(_spec("dp-sgd"), _loss_fn)
    state = algo.init(params0)
    rng = np.random.default_rng(0)
    central = (jnp.asarray(rng.normal(size=(8, D)).astype(np.float32)),
               jnp.asarray((rng.random(8) > 0.5).astype(np.float32)))
    with pytest.raises(ValueError, match="agent-stacked"):
        algo.step(state, central, jax.random.PRNGKey(0))


def test_registry_populated_via_core_import():
    """Lookups must work no matter which of repro.core / repro.api the
    caller imported first (registrations are triggered lazily)."""
    import subprocess, sys
    code = ("from repro.core import list_algorithms, algorithm_info; "
            "assert len(list_algorithms()) == 11, list_algorithms(); "
            "assert algorithm_info('choco').decentralized")
    res = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True)
    assert res.returncode == 0, res.stderr


def test_unknown_algorithm_rejected():
    with pytest.raises(ValueError, match="unknown algorithm"):
        build(_spec("porter-gc").replace(algo="nope"), _loss_fn)


def test_gamma_derivation_matches_paper_formula():
    spec = _spec("porter-gc", topology="erdos_renyi", topology_p=0.8,
                 topology_seed=1, frac=0.05)
    top = resolve_topology(spec)
    comp = resolve_compressor(spec)
    assert resolve_gamma(spec, top, comp) == pytest.approx(
        0.5 * (1 - top.alpha) * 0.05)
    # explicit gamma wins; gamma_scale rescales the derived value
    assert resolve_gamma(spec.replace(gamma=0.123), top, comp) == 0.123
    assert resolve_gamma(spec.replace(gamma_scale=0.3), top, comp) == \
        pytest.approx(0.3 * (1 - top.alpha) * 0.05)
    algo = build(spec, _loss_fn)
    assert algo.gamma == pytest.approx(0.5 * (1 - top.alpha) * 0.05)


def test_zero_derived_gamma_rejected():
    """low_rank advertises rho=0 (data-dependent); a silently-zero gamma
    would disable gossip, so the facade demands an explicit one."""
    spec = _spec("porter-gc", compressor="low_rank",
                 compressor_kwargs={"rank": 2})
    with pytest.raises(ValueError, match="derived gamma is 0"):
        build(spec, _loss_fn)
    algo = build(spec.replace(gamma=0.01), _loss_fn)
    assert algo.gamma == 0.01


def test_build_engine_matches_spec():
    spec = _spec("porter-gc")
    eng = build_engine(spec)
    assert isinstance(eng, CommRound)
    assert eng.compressor.rho == pytest.approx(spec.frac)
    assert getattr(eng.mixer, "wire_mode", None) == "dense"


def test_engine_conflict_raises():
    """The footgun: engine= plus a *different* mixer/compressor used to be
    silently ignored; now it raises."""
    top = make_topology("ring", N)
    comp = make_compressor("top_k", frac=0.25)
    other_comp = make_compressor("top_k", frac=0.5)
    mixer = make_mixer(top, "dense")
    eng = CommRound(compressor=comp, mixer=mixer)
    params0, batch = _problem()
    state = porter_init(params0, N, w=top.w)
    cfg = build(_spec("porter-gc"), _loss_fn).config
    with pytest.raises(ValueError, match="conflicting compressor"):
        porter_step(cfg, _loss_fn, mixer, other_comp, state, batch,
                    jax.random.PRNGKey(0), engine=eng)
    # same objects (what make_porter_step passes) stay fine
    out_state, _ = porter_step(cfg, _loss_fn, mixer, comp, state, batch,
                               jax.random.PRNGKey(0), engine=eng)
    assert isinstance(out_state, type(state))
    # and the engine-less path still needs a compressor
    with pytest.raises(ValueError, match="compressor"):
        porter_step(cfg, _loss_fn, mixer, None, state, batch,
                    jax.random.PRNGKey(0))


def test_dpsgd_wire_bytes_follow_dtype():
    """bf16 buffers must report half the wire traffic of f32 ones."""
    from repro.core import baselines as BL
    params32 = {"w": jnp.zeros(D, jnp.float32)}
    params16 = {"w": jnp.zeros(D, jnp.bfloat16)}
    rng = np.random.default_rng(0)
    f = jnp.asarray(rng.normal(size=(8, D)).astype(np.float32))
    l = jnp.asarray((rng.random(8) > 0.5).astype(np.float32))

    def loss(p, b):
        ff, ll = b
        logits = ff @ p["w"].astype(jnp.float32)
        return jnp.mean(jnp.log1p(jnp.exp(-(2 * ll - 1) * logits)))

    _, m32 = BL.dpsgd_step(0.1, loss, BL.dpsgd_init(params32), (f, l),
                           jax.random.PRNGKey(0))
    _, m16 = BL.dpsgd_step(0.1, loss, BL.dpsgd_init(params16), (f, l),
                           jax.random.PRNGKey(0))
    assert float(m32["wire_bytes"]) == 4.0 * D
    assert float(m16["wire_bytes"]) == 2.0 * D


def test_spec_is_declarative():
    """Specs are frozen plain-value records: replace() copies, fields hash
    out to something loggable."""
    spec = _spec("choco")
    with pytest.raises(dataclasses.FrozenInstanceError):
        spec.eta = 1.0
    assert spec.replace(eta=1.0).eta == 1.0 and spec.eta == 0.1
