"""Per-kernel allclose tests: interpret-mode Pallas vs pure-jnp oracles,
swept over shapes and dtypes with hypothesis (deliverable (c))."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref
from repro.kernels.block_topk import BLOCK

DTYPES = [jnp.float32, jnp.bfloat16]


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 else \
        dict(rtol=1e-5, atol=1e-6)


@given(st.integers(1, 40000), st.integers(0, 10**6),
       st.sampled_from([0.5, 1.0, 4.0]), st.sampled_from([0, 1]))
@settings(max_examples=20, deadline=None)
def test_smooth_clip_sweep(d, seed, tau, dt):
    dtype = DTYPES[dt]
    x = (jax.random.normal(jax.random.PRNGKey(seed % 997), (d,)) * 3
         ).astype(dtype)
    y_k = ops.smooth_clip(x, tau, interpret=True)
    y_r = ref.smooth_clip_ref(x, tau)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), **_tol(dtype))


@pytest.mark.parametrize("shape", [(7,), (1023,), (8192,), (3, 2048),
                                   (5, 1000, 3)])
@pytest.mark.parametrize("dtype", DTYPES)
def test_smooth_clip_shapes_with_noise(shape, dtype):
    k1, k2 = jax.random.split(jax.random.PRNGKey(0))
    x = jax.random.normal(k1, shape).astype(dtype)
    noise = jax.random.normal(k2, shape).astype(dtype)
    y_k = ops.smooth_clip(x, 1.0, noise, 0.25, interpret=True)
    y_r = ref.smooth_clip_ref(x, 1.0, noise, 0.25)
    np.testing.assert_allclose(np.asarray(y_k, np.float32),
                               np.asarray(y_r, np.float32), **_tol(dtype))


def test_smooth_clip_norm_bound():
    x = jax.random.normal(jax.random.PRNGKey(0), (5000,)) * 100
    y = ops.smooth_clip(x, 2.0, interpret=True)
    assert float(jnp.linalg.norm(y)) < 2.0


@given(st.integers(1, 3 * BLOCK + 17), st.integers(0, 10**6),
       st.sampled_from([0.01, 0.05, 0.25]))
@settings(max_examples=15, deadline=None)
def test_block_topk_sweep(d, seed, frac):
    x = jax.random.normal(jax.random.PRNGKey(seed % 997), (d,))
    y_k = ops.block_topk(x, frac, interpret=True)
    # compare against exact per-block top-k oracle on the padded layout
    pad = (-d) % BLOCK
    x2d = jnp.pad(x, (0, pad)).reshape(-1, BLOCK)
    k = max(int(round(frac * BLOCK)), 1)
    y_r = ref.block_topk_ref(x2d, k).reshape(-1)[:d]
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-6)


@pytest.mark.parametrize("dtype", DTYPES)
def test_block_topk_contract(dtype):
    """Kernel output satisfies Definition 3 with rho = frac."""
    frac = 0.05
    x = jax.random.normal(jax.random.PRNGKey(3), (4 * BLOCK,)).astype(dtype)
    y = ops.block_topk(x, frac, interpret=True)
    err = float(jnp.sum((y.astype(jnp.float32) - x.astype(jnp.float32))**2))
    nrm = float(jnp.sum(x.astype(jnp.float32)**2))
    assert err <= (1 - frac) * nrm * (1 + 1e-3)


@given(st.integers(1, 30000), st.integers(0, 10**6), st.sampled_from([0, 1]))
@settings(max_examples=15, deadline=None)
def test_ef_track_sweep(d, seed, dt):
    dtype = DTYPES[dt]
    keys = jax.random.split(jax.random.PRNGKey(seed % 997), 7)
    args = [jax.random.normal(k, (d,)).astype(dtype) for k in keys]
    out_k = ops.ef_track(*args, 0.37, interpret=True)
    out_r = ref.ef_track_ref(*args, 0.37)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **_tol(dtype))


@given(st.integers(1, 30000), st.integers(0, 10**6), st.sampled_from([0, 1]))
@settings(max_examples=15, deadline=None)
def test_ef_step_sweep(d, seed, dt):
    dtype = DTYPES[dt]
    keys = jax.random.split(jax.random.PRNGKey(seed % 997), 6)
    args = [jax.random.normal(k, (d,)).astype(dtype) for k in keys]
    out_k = ops.ef_step(*args, 0.37, 0.01, interpret=True)
    out_r = ref.ef_step_ref(*args, 0.37, 0.01)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **_tol(dtype))


@given(st.integers(1, 30000), st.integers(0, 10**6), st.sampled_from([0, 1]))
@settings(max_examples=15, deadline=None)
def test_ef_gossip_sweep(d, seed, dt):
    dtype = DTYPES[dt]
    keys = jax.random.split(jax.random.PRNGKey(seed % 997), 5)
    args = [jax.random.normal(k, (d,)).astype(dtype) for k in keys]
    out_k = ops.ef_gossip(*args, 0.37, 0.5, interpret=True)
    out_r = ref.ef_gossip_ref(*args, 0.37, 0.5)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), **_tol(dtype))


def test_ef_track_matches_porter_algebra():
    """The fused kernel implements exactly lines 11-12 of Algorithm 1."""
    d = 4096
    keys = jax.random.split(jax.random.PRNGKey(0), 7)
    q, m, v, c, wc, g, gp = [jax.random.normal(k, (d,)) for k in keys]
    gamma = 0.11
    q2, m2, v2 = ops.ef_track(q, m, v, c, wc, g, gp, gamma, interpret=True)
    np.testing.assert_allclose(np.asarray(q2), np.asarray(q + c), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(m2), np.asarray(m + wc), rtol=1e-6)
    gossip = (m + wc) - (q + c)
    np.testing.assert_allclose(np.asarray(v2),
                               np.asarray(v + gamma * gossip + g - gp),
                               rtol=1e-5, atol=1e-6)
