"""Hypothesis property tests on PORTER's system invariants, independent of
any particular objective:

* mean-preservation: the gossip term is mean-zero, so x-bar evolves exactly
  as x-bar_{t+1} = x-bar_t - eta * v-bar_{t+1} for ANY compressor/graph;
* v-bar == g-bar (gradient-tracking identity) for any variant;
* smooth clipping keeps every shared gradient strictly inside the tau-ball
  (the property Theorem 1's sensitivity argument needs);
* surrogate consistency: q = x0 + sum of increments (error feedback never
  loses mass).
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core import (PorterConfig, make_compressor, make_mixer,
                        make_porter_step, make_topology, porter_init)
from repro.core.clipping import tree_global_norm


def quad_loss(params, batch):
    (a,) = batch if isinstance(batch, tuple) else (batch,)
    return jnp.mean((params["w"] * a[..., None] - 1.0) ** 2)


def _setup(n, graph, comp_name, frac, variant, seed, tau=1.0, sigma=0.0):
    top = make_topology(graph, n, weights="metropolis", seed=seed)
    comp = (make_compressor("identity") if comp_name == "identity"
            else make_compressor(comp_name, frac=frac))
    cfg = PorterConfig(eta=0.05, gamma=0.3 * (1 - top.alpha) * frac,
                       tau=tau, variant=variant, sigma_p=sigma)
    params0 = {"w": jnp.linspace(-1, 1, 7)}
    state = porter_init(params0, n, w=top.w)
    step = jax.jit(make_porter_step(cfg, quad_loss, make_mixer(top, "dense"),
                                    comp))
    return state, step


@given(st.integers(3, 8), st.sampled_from(["ring", "erdos_renyi", "complete"]),
       st.sampled_from([("top_k", 0.3), ("random_k", 0.3),
                        ("identity", 1.0)]),
       st.sampled_from(["gc", "dp", "beer"]), st.integers(0, 100))
@settings(max_examples=12, deadline=None)
def test_tracking_and_mean_preservation(n, graph, comp_spec, variant, seed):
    comp_name, frac = comp_spec
    state, step = _setup(n, graph, comp_name, frac, variant, seed,
                         sigma=0.01 if variant == "dp" else 0.0)
    key = jax.random.PRNGKey(seed)
    for t in range(4):
        key, kb, ks = jax.random.split(key, 3)
        batch = (jax.random.normal(kb, (n, 3)),)
        xbar_before = jnp.mean(state.x["w"], axis=0)
        state, _ = step(state, batch, ks)
        vbar = jnp.mean(state.v["w"], axis=0)
        gbar = jnp.mean(state.g_prev["w"], axis=0)
        # gradient tracking identity (exact up to float assoc.)
        np.testing.assert_allclose(np.asarray(vbar), np.asarray(gbar),
                                   rtol=1e-4, atol=1e-5)
        # mean dynamics are gossip-invariant
        xbar_after = jnp.mean(state.x["w"], axis=0)
        np.testing.assert_allclose(np.asarray(xbar_after),
                                   np.asarray(xbar_before - 0.05 * vbar),
                                   rtol=1e-4, atol=1e-5)


@given(st.integers(3, 8), st.floats(0.2, 3.0), st.integers(0, 50))
@settings(max_examples=10, deadline=None)
def test_shared_gradients_inside_tau_ball(n, tau, seed):
    """Every g an agent ever puts on the wire obeys ||g|| < tau + noise
    (per-sample clipping then averaging keeps the mean inside the ball)."""
    state, step = _setup(n, "ring", "top_k", 0.5, "dp", seed, tau=tau,
                         sigma=0.0)
    key = jax.random.PRNGKey(seed)
    for _ in range(3):
        key, kb, ks = jax.random.split(key, 3)
        batch = (10.0 * jax.random.normal(kb, (n, 3)),)  # huge gradients
        state, _ = step(state, batch, ks)
        for i in range(n):
            g_i = {"w": state.g_prev["w"][i]}
            assert float(tree_global_norm(g_i)) < tau + 1e-4


@given(st.integers(3, 6), st.integers(0, 50))
@settings(max_examples=8, deadline=None)
def test_error_feedback_conserves_increments(n, seed):
    """q_x(t) = x0 + sum of compressed increments; with identity compression
    q converges to x after each step (EF catches up immediately)."""
    state, step = _setup(n, "complete", "identity", 1.0, "gc", seed)
    key = jax.random.PRNGKey(seed)
    for _ in range(3):
        key, kb, ks = jax.random.split(key, 3)
        batch = (jax.random.normal(kb, (n, 3)),)
        prev_x = state.x["w"]
        state, _ = step(state, batch, ks)
        # identity compressor: q_x^t = x^{t-1} exactly
        np.testing.assert_allclose(np.asarray(state.q_x["w"]),
                                   np.asarray(prev_x), rtol=1e-5, atol=1e-6)
