"""Mamba2 SSD chunk-scan Pallas kernel vs the per-token recurrence oracle."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.nn.ssm import ssd_scan_ref


def _inputs(b, s, h, p, n, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 5)
    xh = jax.random.normal(ks[0], (b, s, h, p))
    bm = jax.random.normal(ks[1], (b, s, n))
    cm = jax.random.normal(ks[2], (b, s, n))
    dla = -jax.random.uniform(ks[3], (b, s, h), minval=0.01, maxval=0.5)
    h0 = jax.random.normal(ks[4], (b, h, p, n))
    return xh, bm, cm, dla, h0


@given(st.integers(1, 2), st.sampled_from([64, 128]), st.integers(1, 3),
       st.sampled_from([4, 8]), st.sampled_from([8, 16]),
       st.integers(0, 100))
@settings(max_examples=10, deadline=None)
def test_ssd_kernel_matches_recurrence(b, s, h, p, n, seed):
    xh, bm, cm, dla, h0 = _inputs(b, s, h, p, n, seed)
    y_k, hf_k = ops.ssd_scan(xh, bm, cm, dla, h0, interpret=True)
    y_r, hf_r = ssd_scan_ref(xh, bm, cm, dla, h0)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_r), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf_k), np.asarray(hf_r),
                               rtol=1e-4, atol=1e-4)


def test_ssd_kernel_state_chaining():
    xh, bm, cm, dla, h0 = _inputs(1, 128, 2, 4, 8, 7)
    y_full, hf_full = ops.ssd_scan(xh, bm, cm, dla, h0, interpret=True)
    y1, hm = ops.ssd_scan(xh[:, :64], bm[:, :64], cm[:, :64], dla[:, :64],
                          h0, interpret=True)
    y2, hf2 = ops.ssd_scan(xh[:, 64:], bm[:, 64:], cm[:, 64:], dla[:, 64:],
                           hm, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(hf2), np.asarray(hf_full),
                               rtol=1e-4, atol=1e-4)
