"""RWKV6 chunk-scan Pallas kernel vs the per-token recurrence oracle,
swept over shapes/dtypes with hypothesis (interpret mode)."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref


def _inputs(b, s, h, n, seed, dtype):
    ks = jax.random.split(jax.random.PRNGKey(seed), 6)
    r = jax.random.normal(ks[0], (b, s, h, n)).astype(dtype)
    k = jax.random.normal(ks[1], (b, s, h, n)).astype(dtype)
    v = jax.random.normal(ks[2], (b, s, h, n)).astype(dtype)
    logw = -jax.random.uniform(ks[3], (b, s, h, n), minval=0.01,
                               maxval=4.9).astype(jnp.float32)
    u = jax.random.normal(ks[4], (h, n)).astype(dtype)
    s0 = jax.random.normal(ks[5], (b, h, n, n)).astype(jnp.float32)
    return r, k, v, logw, u, s0


@given(st.integers(1, 3), st.sampled_from([16, 32, 64]),
       st.integers(1, 3), st.sampled_from([8, 16]), st.integers(0, 100))
@settings(max_examples=12, deadline=None)
def test_rwkv6_kernel_matches_recurrence(b, s, h, n, seed):
    r, k, v, logw, u, s0 = _inputs(b, s, h, n, seed, jnp.float32)
    o_k, sf_k = ops.rwkv6_scan(r, k, v, logw, u, s0, interpret=True)
    o_r, sf_r = ref.rwkv6_scan_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(sf_k), np.asarray(sf_r), rtol=1e-4,
                               atol=1e-4)


def test_rwkv6_kernel_bf16_inputs():
    r, k, v, logw, u, s0 = _inputs(2, 32, 2, 16, 0, jnp.bfloat16)
    o_k, sf_k = ops.rwkv6_scan(r, k, v, logw, u, s0, interpret=True)
    o_r, sf_r = ref.rwkv6_scan_ref(r.astype(jnp.float32),
                                   k.astype(jnp.float32),
                                   v.astype(jnp.float32), logw,
                                   u.astype(jnp.float32), s0)
    np.testing.assert_allclose(np.asarray(o_k), np.asarray(o_r), rtol=5e-2,
                               atol=5e-2)


def test_rwkv6_kernel_state_chaining():
    """Running two halves with the carried state == one full pass."""
    r, k, v, logw, u, s0 = _inputs(1, 64, 2, 8, 3, jnp.float32)
    o_full, sf_full = ops.rwkv6_scan(r, k, v, logw, u, s0, interpret=True)
    half = 32
    o1, s_mid = ops.rwkv6_scan(r[:, :half], k[:, :half], v[:, :half],
                               logw[:, :half], u, s0, interpret=True)
    o2, sf2 = ops.rwkv6_scan(r[:, half:], k[:, half:], v[:, half:],
                             logw[:, half:], u, s_mid, interpret=True)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([o1, o2], 1)),
                               np.asarray(o_full), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(sf2), np.asarray(sf_full),
                               rtol=1e-4, atol=1e-4)
