"""Fleet-mode oracle: the vectorized fleet executor vs. the per-device
engine.

The fleet subsystem (``core/fleet.py`` + ``ExperimentSpec(fleet=True)``)
simulates n >> devices agents as one leading vmapped axis.  Its contract,
pinned here:

* **Bit parity below the gate**: at ``n <= FLEET_DENSE_GATE`` the fleet
  mixer reuses the gossip module's schedule-table einsum verbatim, so
  every registered decentralized algorithm must produce *bit-identical*
  trajectories in fleet and per-device mode (same key stream).
* **COO parity above the gate**: the sparse scatter-add sweep agrees with
  its own densified table to f32 accumulation error, and the sparse
  builders reproduce ``make_topology``'s Metropolis weights exactly.
* **Runtime integration**: the chunked scan runner and mid-run checkpoint
  resume see fleet states as ordinary agent-stacked pytrees -- one
  executable per chunk size, bit-exact resume.
* **SPMD**: sharding the fleet axis over 8 host devices changes neither
  the results nor the compiled collective census vs. the per-device dense
  engine (subprocess case, HLO collective-count equality).
* **clip21 degeneracy**: at tau = inf the Clip21 EF clip is the identity
  on the residual, so clip21 must match porter-gc bit-for-bit.
"""

import collections
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (ExperimentSpec, algorithm_info, build, build_engine,
                       list_algorithms, resolve_fleet_schedule,
                       resolve_fleet_topology)
from repro.core import (FLEET_DENSE_GATE, FleetSchedule, FleetTopology,
                        make_topology)
from repro.core.fleet import (fleet_er_schedule, fleet_rotating_schedule,
                              fleet_topology, make_fleet_mixer)
from repro.core.mixing import mixing_rate
from repro.data import dirichlet_partition, dirichlet_source
from repro.launch.checkpoint import latest_step, restore_state, save_state
from repro.launch.runtime import make_runner

D, B = 24, 6

DECENTRALIZED = sorted(a for a in list_algorithms()
                       if algorithm_info(a).decentralized)


def _loss_fn(params, batch):
    f, l = batch
    f, l = jnp.atleast_2d(f), jnp.atleast_1d(l)
    logits = f @ params["w"] + params["b"]
    return jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits)))


def _problem(n, seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=D)
    f = rng.normal(size=(n, B, D)).astype(np.float32)
    l = (f @ w_true > 0).astype(np.float32)
    params0 = {"w": jnp.zeros(D), "b": jnp.zeros(())}
    return params0, (jnp.asarray(f), jnp.asarray(l))


def _spec(name, n, *, fleet, **over):
    kw = dict(algo=name, n_agents=n, topology="ring", compressor="top_k",
              frac=0.25, eta=0.1, tau=5.0,
              sigma_p=0.01 if algorithm_info(name).dp else 0.0,
              fleet=fleet)
    kw.update(over)
    return ExperimentSpec(**kw)


def _run(algo, params0, batch, steps, seed=0):
    """The runtime's key contract: round t's keys are a pure function of
    the absolute index, so fleet/per-device runs share the stream."""
    state = algo.init(params0)
    step = jax.jit(algo.step)
    key = jax.random.PRNGKey(seed)
    losses = []
    for t in range(steps):
        _, ks = jax.random.split(jax.random.fold_in(key, t))
        state, m = step(state, batch, ks)
        losses.append(m["loss"])
    return state, np.asarray(losses)


def _assert_tree_equal(a, b, *, exact, atol=1e-5, msg=""):
    la, lb = jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        x, y = np.asarray(x), np.asarray(y)
        if exact:
            np.testing.assert_array_equal(x, y, err_msg=msg)
        else:
            np.testing.assert_allclose(x, y, atol=atol, rtol=1e-5,
                                       err_msg=msg)


# ---------------------------------------------------------------------------
# Oracle parity: every decentralized algorithm, n = 4 and n = 8
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", DECENTRALIZED)
@pytest.mark.parametrize("n", [4, 8])
def test_fleet_matches_per_device_oracle(name, n):
    """fleet=True is bit-identical to the per-device engine below the
    dense gate (same einsum table), not merely atol-close."""
    params0, batch = _problem(n)
    states, traj = [], []
    for fleet in (False, True):
        algo = build(_spec(name, n, fleet=fleet), _loss_fn)
        st, losses = _run(algo, params0, batch, steps=10)
        states.append(st)
        traj.append(losses)
    np.testing.assert_allclose(traj[1], traj[0], atol=1e-5, rtol=1e-5)
    _assert_tree_equal(states[1], states[0], exact=True,
                       msg=f"{name} n={n}: fleet diverged from oracle")
    assert np.isfinite(traj[1]).all()


def test_fleet_schedule_matches_per_device_oracle():
    """Time-varying tables take the same fleet path (traced W_t gather)."""
    n, sched = 8, "rotate:ring/metropolis+exponential/metropolis"
    params0, batch = _problem(n)
    states = []
    for fleet in (False, True):
        algo = build(_spec("porter-gc", n, fleet=fleet,
                           topology_schedule=sched), _loss_fn)
        st, _ = _run(algo, params0, batch, steps=8)
        states.append(st)
    _assert_tree_equal(states[1], states[0], exact=True)


# ---------------------------------------------------------------------------
# clip21 degeneracy: tau = inf recovers porter-gc exactly
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("tau", [None, float("inf")])
def test_clip21_is_porter_gc_at_infinite_tau(tau):
    """With tau = inf the residual clip factor is 1, the EF estimate locks
    onto the raw gradient (where-branch, not a+1.0*(b-a)), and clip21 is
    bit-for-bit porter-gc with piecewise clipping."""
    n = 8
    params0, batch = _problem(n)
    ref = build(_spec("porter-gc", n, fleet=False, tau=float("inf"),
                      clip_mode="piecewise"), _loss_fn)
    got = build(_spec("clip21", n, fleet=False, tau=tau), _loss_fn)
    st_ref, tr_ref = _run(ref, params0, batch, steps=12)
    st_got, tr_got = _run(got, params0, batch, steps=12)
    np.testing.assert_array_equal(tr_got, tr_ref)
    _assert_tree_equal(st_got.base, st_ref, exact=True)
    # and the EF estimate tracked the raw gradient exactly
    last = build(_spec("clip21", n, fleet=False, tau=tau), _loss_fn)
    st = last.init(params0)
    key = jax.random.PRNGKey(0)
    _, ks = jax.random.split(jax.random.fold_in(key, 0))
    st, m = jax.jit(last.step)(st, batch, ks)
    assert float(m["clip_residual"]) == 0.0


def test_clip21_finite_tau_diverges_from_porter_gc():
    """Sanity: the equivalence is a tau=inf degeneracy, not an identity."""
    n = 4
    params0, batch = _problem(n)
    ref = build(_spec("porter-gc", n, fleet=False, tau=0.5,
                      clip_mode="piecewise"), _loss_fn)
    got = build(_spec("clip21", n, fleet=False, tau=0.5), _loss_fn)
    st_ref, _ = _run(ref, params0, batch, steps=6)
    st_got, _ = _run(got, params0, batch, steps=6)
    diffs = [float(np.max(np.abs(np.asarray(a) - np.asarray(b))))
             for a, b in zip(jax.tree_util.tree_leaves(st_got.base),
                             jax.tree_util.tree_leaves(st_ref))]
    assert max(diffs) > 0.0


# ---------------------------------------------------------------------------
# COO executor vs. its densified table; sparse builders vs. make_topology
# ---------------------------------------------------------------------------

def test_fleet_metropolis_matches_make_topology():
    top = fleet_topology("ring", 16, weights="metropolis")
    dense = make_topology("ring", 16, weights="metropolis")
    np.testing.assert_array_equal(np.asarray(top.densify()),
                                  np.asarray(dense.w))
    assert abs(top.alpha - mixing_rate(dense.w)) < 1e-8


def test_coo_apply_matches_dense_gate():
    """Force the COO scatter-add at small n and compare against the
    einsum path on the same FleetTopology."""
    top = fleet_topology("exponential", 32, weights="lazy")
    coo = make_fleet_mixer(top, dense_gate=0)
    ein = make_fleet_mixer(top)
    assert coo.wire_mode == ein.wire_mode == "dense"
    key = jax.random.PRNGKey(3)
    tree = {"a": jax.random.normal(key, (32, 5, 3)),
            "b": jax.random.normal(jax.random.fold_in(key, 1), (32, 7))}
    out_c, out_e = jax.jit(coo)(tree), jax.jit(ein)(tree)
    _assert_tree_equal(out_c, out_e, exact=False, atol=1e-6)
    # push-sum weight rider: exact on the weight plane
    w0 = jnp.ones((32,))
    (tc, wc) = coo.push(tree, w0)
    (te, we) = ein.push(tree, w0)
    np.testing.assert_allclose(np.asarray(wc), np.asarray(we), atol=1e-6)
    _assert_tree_equal(tc, te, exact=False, atol=1e-6)


def test_coo_schedule_apply_matches_densified():
    sched = fleet_er_schedule(40, period=3, degree=6, seed=1)
    coo = make_fleet_mixer(sched, dense_gate=0)
    assert coo.time_varying
    key = jax.random.PRNGKey(0)
    tree = {"x": jax.random.normal(key, (40, 9))}
    for t in range(4):
        w_t = np.asarray(sched.densify(t % sched.period))
        want = {"x": w_t @ np.asarray(tree["x"])}
        got = jax.jit(coo)(tree, t=jnp.asarray(t))
        np.testing.assert_allclose(np.asarray(got["x"]), want["x"],
                                   atol=1e-5, rtol=1e-5)
    with pytest.raises(TypeError):
        coo(tree)  # time-varying mixers require the round index


def test_fleet_above_gate_trains():
    """End-to-end COO path: n = 512 > FLEET_DENSE_GATE, one executable,
    finite decreasing loss."""
    n = 512
    assert n > FLEET_DENSE_GATE
    params0, _ = _problem(4)
    rng = np.random.default_rng(0)
    w_true = rng.normal(size=D)
    f = rng.normal(size=(n, B, D)).astype(np.float32)
    l = (f @ w_true > 0).astype(np.float32)
    batch = (jnp.asarray(f), jnp.asarray(l))
    algo = build(_spec("clip21", n, fleet=True), _loss_fn)
    assert isinstance(algo.topology, FleetTopology)
    _, losses = _run(algo, params0, batch, steps=8)
    assert np.isfinite(losses).all() and losses[-1] < losses[0]


# ---------------------------------------------------------------------------
# Sparse builders: validation + spectral agreement above the gate
# ---------------------------------------------------------------------------

def test_fleet_topology_spectral_matches_dense():
    top = fleet_topology("ring", 300, weights="metropolis")
    w = np.asarray(top.densify())
    np.testing.assert_allclose(w.sum(0), 1.0, atol=1e-9)
    np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-9)
    assert abs(top.alpha - mixing_rate(jnp.asarray(w))) < 1e-6 * top.alpha
    assert 0.0 < top.spectral_gap < 1.0


def test_fleet_er_schedule_validates():
    sched = fleet_er_schedule(400, period=3, seed=2)
    assert isinstance(sched, FleetSchedule)
    assert sched.period == 3 and not sched.is_directed
    assert 0.0 < sched.joint_alpha < 1.0
    for t in range(sched.period):
        w = np.asarray(sched.densify(t))
        np.testing.assert_allclose(w.sum(0), 1.0, atol=1e-8)
        np.testing.assert_allclose(w.sum(1), 1.0, atol=1e-8)


def test_fleet_rotating_schedule_validates():
    sched = fleet_rotating_schedule(["ring", "exponential/lazy"], 300)
    assert sched.period == 2
    assert 0.0 < sched.alpha < 1.0


def test_fleet_topology_rejects_best_constant():
    with pytest.raises(ValueError):
        fleet_topology("ring", 400, weights="best_constant")


# ---------------------------------------------------------------------------
# Spec routing + rejections
# ---------------------------------------------------------------------------

def test_fleet_spec_rejections():
    with pytest.raises(ValueError, match="gossip_mode"):
        build(_spec("porter-gc", 8, fleet=True, gossip_mode="ring"),
              _loss_fn)
    with pytest.raises(ValueError, match="wire"):
        build(_spec("porter-gc", 8, fleet=True, wire="packed_bits"),
              _loss_fn)
    with pytest.raises(ValueError, match="push-sum"):
        build(_spec("dp-csgp", FLEET_DENSE_GATE + 1, fleet=True), _loss_fn)
    with pytest.raises(ValueError, match="column-stochastic"):
        build(_spec("porter-gc", 8, fleet=True,
                    topology_schedule="directed:one_way,rate=0.2,period=3"),
              _loss_fn)
    with pytest.raises(ValueError):
        resolve_fleet_schedule(_spec("porter-gc", 512, fleet=True,
                                     topology_schedule="dropout:rate=0.2"))


def test_fleet_resolution_below_gate_is_dense():
    spec = _spec("porter-gc", 8, fleet=True)
    top = resolve_fleet_topology(spec)
    assert not isinstance(top, FleetTopology)  # ordinary dense Topology
    eng = build_engine(spec)
    assert eng.mixer.budget.executor == "fleet"
    assert eng.mixer.n == 8


def test_fleet_resolution_above_gate_is_sparse():
    spec = _spec("porter-gc", 512, fleet=True)
    top = resolve_fleet_topology(spec)
    assert isinstance(top, FleetTopology)
    assert top.nnz < 512 * 64  # never materializes (n, n)


# ---------------------------------------------------------------------------
# Runtime integration: chunked scan + mid-run checkpoint resume
# ---------------------------------------------------------------------------

def test_fleet_chunked_runner_parity():
    """The scan-fused chunk runner reproduces the per-step loop on a fleet
    state -- uneven tail chunk, one executable."""
    from repro.data import minibatch_source
    n = 8
    params0, (f, l) = _problem(n)
    source = minibatch_source(np.asarray(f), np.asarray(l), 3)
    algo = build(_spec("clip21", n, fleet=True), _loss_fn)

    key = jax.random.PRNGKey(0)
    step = jax.jit(algo.step)
    st_loop = algo.init(params0)
    for t in range(7):
        kb, ks = jax.random.split(jax.random.fold_in(key, t))
        st_loop, _ = step(st_loop, source(kb, t), ks)

    runner = make_runner(algo, source, chunk=3, donate=False)
    st_run = algo.init(params0)
    st_run, _, _ = runner(st_run, key, start=0)    # t = 0..2
    st_run, _, _ = runner(st_run, key, start=3)    # t = 3..5
    st_run, _, _ = make_runner(algo, source, chunk=1,
                               donate=False)(st_run, key, start=6)
    _assert_tree_equal(st_run, st_loop, exact=False, atol=1e-5)
    assert runner.cache_size() in (None, 1)


def test_fleet_checkpoint_resume(tmp_path):
    """Mid-run save -> restore -> continue is bit-exact vs. uninterrupted
    (the fold_in key contract makes the stream restart-invariant)."""
    n = 8
    params0, batch = _problem(n)
    algo = build(_spec("clip21", n, fleet=True), _loss_fn)
    step = jax.jit(algo.step)
    key = jax.random.PRNGKey(1)

    def advance(st, t0, t1):
        for t in range(t0, t1):
            _, ks = jax.random.split(jax.random.fold_in(key, t))
            st, _ = step(st, batch, ks)
        return st

    st_full = advance(algo.init(params0), 0, 10)

    ckpt = str(tmp_path / "fleet_ckpt")
    st_half = advance(algo.init(params0), 0, 5)
    save_state(ckpt, st_half, step=5)
    assert latest_step(ckpt) == 5
    st_res = restore_state(ckpt, algo.init(params0))
    _assert_tree_equal(st_res, st_half, exact=True)
    st_res = advance(st_res, 5, 10)
    _assert_tree_equal(st_res, st_full, exact=True,
                       msg="resume diverged from uninterrupted run")


# ---------------------------------------------------------------------------
# Dirichlet fleet shards
# ---------------------------------------------------------------------------

def test_dirichlet_partition_shapes_and_determinism():
    rng = np.random.default_rng(0)
    xs = rng.normal(size=(240, 10)).astype(np.float32)
    ys = (xs[:, 0] > 0).astype(np.float32)
    fa, la = dirichlet_partition(xs, ys, n_agents=12, alpha=0.3, seed=7)
    fb, lb = dirichlet_partition(xs, ys, n_agents=12, alpha=0.3, seed=7)
    assert fa.shape == (12, 20, 10) and la.shape == (12, 20)
    np.testing.assert_array_equal(fa, fb)
    # heterogeneity: small alpha concentrates labels per agent
    fh, lh = dirichlet_partition(xs, ys, n_agents=12, alpha=0.05, seed=7)
    skew = np.mean(np.abs(lh.mean(axis=1) - ys.mean()))
    base = np.mean(np.abs(la.mean(axis=1) - ys.mean()))
    assert skew >= base


def test_dirichlet_source_feeds_fleet_training():
    rng = np.random.default_rng(1)
    xs = rng.normal(size=(512, D)).astype(np.float32)
    ys = (xs @ rng.normal(size=D) > 0).astype(np.float32)
    n = 8
    source = dirichlet_source(xs, ys, n_agents=n, batch=4, alpha=0.3)
    params0, _ = _problem(n)
    algo = build(_spec("subgrad-comp", n, fleet=True), _loss_fn)
    st = algo.init(params0)
    step = jax.jit(algo.step)
    key = jax.random.PRNGKey(0)
    for t in range(6):
        kb, ks = jax.random.split(jax.random.fold_in(key, t))
        st, m = step(st, source(kb, t), ks)
    assert np.isfinite(float(m["loss"]))


# ---------------------------------------------------------------------------
# 8-device shard_map subprocess: parity + collective-count equality
# ---------------------------------------------------------------------------

SHARD_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import collections
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.api import ExperimentSpec, build
    from repro.analysis.hlo import collective_counts

    N, D, B = 8, 24, 4
    def loss_fn(params, batch):
        f, l = batch
        f, l = jnp.atleast_2d(f), jnp.atleast_1d(l)
        logits = f @ params["w"] + params["b"]
        return jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits)))

    rng = np.random.default_rng(0)
    w_true = rng.normal(size=D)
    f = rng.normal(size=(N, B, D)).astype(np.float32)
    l = (f @ w_true > 0).astype(np.float32)
    params0 = {"w": jnp.zeros(D), "b": jnp.zeros(())}

    mesh = jax.make_mesh((8,), ("data",))
    def shardings(tree):
        def spec(leaf):
            if getattr(leaf, "ndim", 0) >= 1 and leaf.shape[0] == N:
                return NamedSharding(mesh, P("data",
                                             *([None] * (leaf.ndim - 1))))
            return NamedSharding(mesh, P())
        return jax.tree_util.tree_map(spec, tree)

    texts, finals = {}, {}
    for fleet in (False, True):
        spec = ExperimentSpec(algo="porter-gc", n_agents=N, topology="ring",
                              compressor="top_k", frac=0.25, eta=0.1,
                              tau=5.0, gossip_mode="dense", fleet=fleet)
        algo = build(spec, loss_fn)
        st = jax.device_put(algo.init(params0), shardings(algo.init(params0)))
        batch = (jax.device_put(jnp.asarray(f),
                                NamedSharding(mesh, P("data", None, None))),
                 jax.device_put(jnp.asarray(l),
                                NamedSharding(mesh, P("data", None))))
        key = jax.random.PRNGKey(0)
        step = jax.jit(algo.step)
        texts[fleet] = step.lower(st, batch, key).compile().as_text()
        for t in range(5):
            _, ks = jax.random.split(jax.random.fold_in(key, t))
            st, m = step(st, batch, ks)
        finals[fleet] = [np.asarray(x)
                         for x in jax.tree_util.tree_leaves(st)]

    for a, b in zip(finals[False], finals[True]):
        np.testing.assert_allclose(a, b, atol=1e-5, rtol=1e-5)
    print("shard-parity-ok")

    ca, cb = collective_counts(texts[False]), collective_counts(texts[True])
    assert ca == cb, (ca, cb)
    assert sum(ca.values()) > 0  # the mesh really induced collectives
    print("census-equal-ok", sorted((k, v) for k, v in ca.items() if v))
""")


def test_fleet_shard_map_parity_and_census():
    """Under an 8-device agent mesh the fleet executor's compiled program
    has the same per-category collective counts as the per-device dense
    engine, and the sharded runs agree."""
    import os
    r = subprocess.run([sys.executable, "-c", SHARD_SCRIPT],
                       capture_output=True, text=True, timeout=600,
                       env={**os.environ, "PYTHONPATH": "src",
                            "JAX_PLATFORMS": "cpu"},
                       cwd=str(__import__("pathlib").Path(
                           __file__).resolve().parents[1]))
    assert r.returncode == 0, r.stderr[-4000:]
    assert "shard-parity-ok" in r.stdout
    assert "census-equal-ok" in r.stdout


# ---------------------------------------------------------------------------
# Analyzer census: fleet mixing is device-local math, zero collectives
# ---------------------------------------------------------------------------

def test_fleet_census_zero_collectives():
    """The analyzer's fleet cases (einsum below the gate, COO above) must
    compile to programs with no collective ops at all in the unmeshed
    harness -- the fleet budget's empty per_leaf table makes any
    collective an unbudgeted violation."""
    from repro.analysis.sweep import census_matrix, run_census_case
    fleet_cases = [c for c in census_matrix() if "/fleet/" in c.label]
    assert len(fleet_cases) >= 3  # porter-gc, clip21, subgrad-comp@COO
    assert any(c.spec.n_agents > FLEET_DENSE_GATE for c in fleet_cases)
    for case in fleet_cases:
        assert not case.needs_mesh
        rec = run_census_case(case, mesh=None)
        assert rec["ok"], rec
        census = rec["census"]
        assert sum(census["counts"].values()) == 0, rec
        assert sum(census["spmd_counts"].values()) == 0, rec
        assert census["executor"] == "fleet"
