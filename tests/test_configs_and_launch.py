"""Pin the assigned architecture configs to the assignment sheet, and unit-
test the launcher plumbing (shape registry, cache spec rules, HLO collective
parser, wire-byte accounting) without touching jax device state."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke
from repro.core.gossip import gossip_wire_bytes
from repro.launch import shapes as SH
from repro.analysis.hlo import shape_bytes as _shape_bytes, parse_collectives

EXPECTED = {
    # arch: (layers, d_model, heads, kv, d_ff, vocab)
    "rwkv6-7b": (32, 4096, None, None, 14336, 65536),
    "minicpm3-4b": (62, 2560, 40, 40, 6400, 73448),
    "seamless-m4t-medium": (12, 1024, 16, 16, 4096, 256206),
    "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
    "h2o-danube-3-4b": (24, 3840, 32, 8, 10240, 32000),
    "chatglm3-6b": (28, 4096, 32, 2, 13696, 65024),
    "grok-1-314b": (64, 6144, 48, 8, 32768, 131072),
    "arctic-480b": (35, 7168, 56, 8, 4864, 32000),
    "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
    "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
}


@pytest.mark.parametrize("arch", ARCHS)
def test_config_matches_assignment(arch):
    cfg = get_config(arch)
    L, d, h, kv, ff, v = EXPECTED[arch]
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.d_ff == ff and cfg.vocab == v
    if h is not None:
        assert cfg.n_heads == h and cfg.n_kv_heads == kv


def test_assignment_extras():
    assert get_config("grok-1-314b").n_experts == 8
    assert get_config("grok-1-314b").top_k == 2
    assert get_config("arctic-480b").n_experts == 128
    assert get_config("arctic-480b").dense_residual
    assert get_config("minicpm3-4b").mla
    assert get_config("h2o-danube-3-4b").window == 4096
    assert get_config("chatglm3-6b").rotary_frac == 0.5
    assert get_config("zamba2-7b").ssm_state == 64
    assert get_config("paligemma-3b").n_prefix == 256
    assert get_config("seamless-m4t-medium").n_enc_layers == 12


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_is_reduced(arch):
    cfg = get_smoke(arch)
    assert cfg.n_layers <= 8
    assert cfg.d_model <= 512
    assert cfg.n_experts <= 4


def test_shape_registry():
    assert SH.SHAPES["train_4k"].seq_len == 4096
    assert SH.SHAPES["train_4k"].global_batch == 256
    assert SH.SHAPES["prefill_32k"].global_batch == 32
    assert SH.SHAPES["decode_32k"].global_batch == 128
    assert SH.SHAPES["long_500k"].seq_len == 524288
    # long_500k applicability per DESIGN.md
    runs = [a for a in ARCHS if SH.shape_applicable(a, "long_500k")]
    assert sorted(runs) == sorted(["rwkv6-7b", "h2o-danube-3-4b",
                                   "zamba2-7b"])
    for a in ARCHS:
        assert SH.shape_applicable(a, "train_4k")


def test_train_batch_specs_shapes():
    cfg = get_config("tinyllama-1.1b")
    batch, specs = SH.train_batch_specs(cfg, SH.SHAPES["train_4k"], 16,
                                        ("data",))
    assert batch["tokens"].shape == (16, 16, 4096)
    cfg = get_config("paligemma-3b")
    batch, specs = SH.train_batch_specs(cfg, SH.SHAPES["train_4k"], 16,
                                        ("data",))
    assert batch["tokens"].shape == (16, 16, 4096 - 256)
    assert batch["patches"].shape == (16, 16, 256, 1152)
    cfg = get_config("seamless-m4t-medium")
    batch, specs = SH.train_batch_specs(cfg, SH.SHAPES["train_4k"], 32,
                                        ("pod", "data"))
    assert batch["frames"].shape == (32, 8, 2048, 1024)


def test_cache_pspec_rules():
    from jax.sharding import PartitionSpec as P
    cache = {
        "k": jax.ShapeDtypeStruct((22, 128, 32768, 4, 64), jnp.bfloat16),
        "v": jax.ShapeDtypeStruct((22, 128, 32768, 4, 64), jnp.bfloat16),
        "positions": jax.ShapeDtypeStruct((22, 128, 4096), jnp.int32),
        "S": jax.ShapeDtypeStruct((32, 1, 64, 64, 64), jnp.float32),
        "conv": jax.ShapeDtypeStruct((81, 128, 3, 7296), jnp.float32),
    }
    specs = SH.cache_pspecs(cache, ("data",), 16)
    assert specs["k"] == P(None, "data", "model", None, None)
    assert specs["positions"] == P(None, "data", None)
    assert specs["S"] == P(None, None, "model", None, None)  # B=1
    assert specs["conv"] == P(None, "data", None, "model")


def test_hlo_shape_bytes_and_collective_parser():
    # dryrun re-exports the canonical analysis passes (back-compat surface)
    from repro.launch import dryrun
    assert dryrun.parse_collectives is parse_collectives
    assert dryrun._shape_bytes is _shape_bytes

    assert _shape_bytes("bf16[16,2048]{1,0}") == 16 * 2048 * 2
    assert _shape_bytes("(f32[8,4]{1,0}, s32[8]{0})") == 8 * 4 * 4 + 8 * 4
    hlo = """
      %ag = f32[16,1024]{1,0} all-gather(f32[1,1024] %p), dims={0}
      %ar.1 = bf16[512]{0} all-reduce(bf16[512] %x), to_apply=%add
      %cp = f32[4,4]{1,0} collective-permute(f32[4,4] %y), pairs={{0,1}}
      %ag2 = f32[8]{0} all-gather-start(f32[1] %q)
      %agd = f32[8]{0} all-gather-done(f32[8] %ag2)
      %normal = f32[2]{0} add(f32[2] %a, f32[2] %b)
    """
    out = parse_collectives(hlo)
    assert out["all-gather"]["count"] == 2          # ag + ag-start, not -done
    assert out["all-gather"]["bytes"] == 16 * 1024 * 4 + 8 * 4
    assert out["all-reduce"]["bytes"] == 512 * 2
    assert out["collective-permute"]["count"] == 1


def test_gossip_wire_accounting():
    d, n = 1_000_000, 16
    dense = gossip_wire_bytes("dense", n, d)
    ring = gossip_wire_bytes("ring", n, d)
    packed = gossip_wire_bytes("packed", n, d, frac=0.05)
    assert dense == n * d * 4
    assert ring == 2 * d * 4                         # n-independent for n>2
    # n=2 ring has one neighbor: a single shift crosses the wire
    assert gossip_wire_bytes("ring", 2, d) == d * 4
    # packed follows the executor's block format, ~n*frac*d*8 up to padding
    assert packed == pytest.approx(n * 0.05 * d * 8, rel=0.01)
    # at rho=0.05, n=16: packed (n*rho*2x) beats ring (2x dense payload)
    assert packed < ring < dense


def test_packed_wire_bytes_match_executor_payload():
    """gossip_wire_bytes('packed') must equal the bytes of the actual
    (values, int32 indices) payload make_packed_mixer all-gathers: k_b =
    max(round(frac*PACK_BLOCK), 1) pairs per PACK_BLOCK-padded window per
    agent -- not max(frac*d, 1) pairs (which under-reported for small or
    badly padded buffers)."""
    from repro.core.gossip import PACK_BLOCK

    n = 4
    for d, frac in ((10, 0.05), (123, 0.25), (PACK_BLOCK, 0.05),
                    (5000, 0.1), (1_000_000, 0.05)):
        # the executor's pack stage, verbatim: pad to windows, top-k each
        flat = jnp.arange(1.0, d + 1.0, dtype=jnp.float32)
        rows = jnp.pad(flat, (0, (-d) % PACK_BLOCK)).reshape(-1, PACK_BLOCK)
        k_b = max(int(round(frac * PACK_BLOCK)), 1)
        vals, idx = jax.lax.top_k(jnp.abs(rows), k_b)
        payload = n * (vals.size * 4 + idx.size * 4)  # f32 vals + int32 idx
        assert gossip_wire_bytes("packed", n, d, frac=frac) == payload
    # a 10-element buffer still ships one full window's k_b pairs
    assert gossip_wire_bytes("packed", n, 10, frac=0.05) == \
        n * max(round(0.05 * PACK_BLOCK), 1) * 8


def test_decode_window_rules():
    assert SH.decode_window(get_config("zamba2-7b"),
                            SH.SHAPES["long_500k"]) == 4096
    assert SH.decode_window(get_config("zamba2-7b"),
                            SH.SHAPES["decode_32k"]) == "cfg"
