"""Comm-round engine tests: the Pallas (interpret-mode) backend must match
the pure-jnp reference path bit-for-close for every algorithm that routes
through CommRound, across odd, non-tile-aligned pytree shapes (flat-plane
padding correctness), and the wire-byte metric must be uniform across
algorithms.

These tests run without hypothesis and are never skipped, so ef_track /
ef_step / ef_gossip are always exercised via interpret=True on CPU CI.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CommRound, PorterConfig, make_compressor, make_mixer,
                        make_porter_step, make_topology, porter_init)
from repro.core import baselines as BL
from repro.core.comm_round import compress_stacked
from repro.core.porter_adam import make_porter_adam_step, porter_adam_init
from repro.kernels import flatten as FL
from repro.kernels import ops, ref

N = 5  # agents

# odd, non-tile-aligned shapes: scalar leaf, non-multiple-of-8 vector, 3-D
# leaf, and one leaf that crosses a tile boundary (8*1024 elements per tile)
ODD_PARAMS = {
    "b": jnp.zeros(()),
    "w": jnp.zeros((123,)),
    "k": jnp.zeros((7, 11, 3)),
    "big": jnp.zeros((9000,)),
}


def _loss_fn(params, batch):
    f, l = batch
    f = jnp.atleast_2d(f)
    l = jnp.atleast_1d(l)
    pred = (f @ params["w"] + params["b"] + jnp.sum(params["k"])
            + jnp.mean(params["big"]))
    return jnp.mean((pred - l) ** 2)


def _batch(key, n=N, b=4):
    k1, k2 = jax.random.split(key)
    return (jax.random.normal(k1, (n, b, 123)),
            jax.random.normal(k2, (n, b)))


def _top():
    return make_topology("erdos_renyi", N, weights="best_constant", p=0.9,
                         seed=2)


def _tree_allclose(a, b, atol=1e-5):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    for x, y in zip(fa, fb):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=atol,
                                   rtol=1e-5)


# ---------------------------------------------------------------------------
# flat tile layout: padding correctness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stacked", [True, False])
def test_flatten_roundtrip_odd_shapes(stacked):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, len(ODD_PARAMS))
    lead = (N,) if stacked else ()
    tree = {name: jax.random.normal(k, lead + p.shape).astype(
                jnp.float32 if i % 2 == 0 else jnp.bfloat16)
            for i, (k, (name, p)) in enumerate(zip(ks, ODD_PARAMS.items()))}
    spec = FL.flat_spec(tree, stacked=stacked)
    planes = FL.to_planes(tree, spec)
    assert planes.shape == spec.plane_shape
    assert planes.shape[-1] == FL.TILE
    assert planes.dtype == jnp.float32
    # padding region is zero (kernels may compute garbage there; from_planes
    # must never read it back)
    if stacked:
        flat = planes.reshape(N, -1)
        assert float(jnp.abs(flat[:, spec.d:]).max()) == 0.0
    back = FL.from_planes(planes, spec)
    for name in tree:
        assert back[name].dtype == tree[name].dtype
        np.testing.assert_allclose(np.asarray(back[name], np.float32),
                                   np.asarray(tree[name], np.float32),
                                   atol=2e-2 if tree[name].dtype ==
                                   jnp.bfloat16 else 1e-7)


def test_flatten_rejects_mismatched_agent_axis():
    with pytest.raises(ValueError):
        FL.flat_spec({"a": jnp.zeros((4, 3)), "b": jnp.zeros((5, 3))})


# ---------------------------------------------------------------------------
# ef_gossip kernel vs oracle (ef_track/ef_step sweeps live in test_kernels)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [1, 123, 8192, 9000])
@pytest.mark.parametrize("scale", [1.0, 0.5])
def test_ef_gossip_matches_ref(d, scale):
    keys = jax.random.split(jax.random.PRNGKey(d), 5)
    q, m, y, c, wc = [jax.random.normal(k, (d,)) for k in keys]
    out_k = ops.ef_gossip(q, m, y, c, wc, 0.37, scale, interpret=True)
    out_r = ref.ef_gossip_ref(q, m, y, c, wc, 0.37, scale)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_ef_track_and_step_fused_semantics():
    """The engine's pallas path == running ef_track/ef_step on flat planes
    == the jnp reference, on a non-tile-aligned buffer."""
    d = 355
    keys = jax.random.split(jax.random.PRNGKey(1), 7)
    q, m, v, c, wc, g, gp = [jax.random.normal(k, (d,)) for k in keys]
    qo, mo, vo = ops.ef_track(q, m, v, c, wc, g, gp, 0.2, interpret=True)
    qr, mr, vr = ref.ef_track_ref(q, m, v, c, wc, g, gp, 0.2)
    for a, b in zip((qo, mo, vo), (qr, mr, vr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    xo = ops.ef_step(q, m, v, c, wc, g, 0.2, 0.05, interpret=True)
    xr = ref.ef_step_ref(q, m, v, c, wc, g, 0.2, 0.05)
    for a, b in zip(xo, xr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# engine parity: pallas(interpret) vs ref across algorithms and variants
# ---------------------------------------------------------------------------

def _porter_cfg(variant):
    top = _top()
    gamma = 0.5 * (1 - top.alpha) * 0.1
    sigma = 0.05 if variant == "dp" else 0.0
    return top, PorterConfig(eta=0.03, gamma=gamma, tau=1.0, variant=variant,
                             sigma_p=sigma)


@pytest.mark.parametrize("variant,comp_name",
                         [("gc", "top_k"), ("dp", "random_k"),
                          ("beer", "block_top_k")])
def test_porter_engine_parity(variant, comp_name):
    """PORTER-GC/DP/BEER: pallas interpret backend == jnp reference backend
    after several steps, odd shapes, atol 1e-5."""
    top, cfg = _porter_cfg(variant)
    comp = make_compressor(comp_name, frac=0.1)
    mixer = make_mixer(top, "dense")
    state_ref = state_pal = porter_init(ODD_PARAMS, N, w=top.w)
    step_ref = jax.jit(make_porter_step(cfg, _loss_fn, mixer, comp,
                                        backend="ref"))
    step_pal = jax.jit(make_porter_step(cfg, _loss_fn, mixer, comp,
                                        backend="pallas", interpret=True))
    key = jax.random.PRNGKey(7)
    for _ in range(3):
        key, kb, ks = jax.random.split(key, 3)
        batch = _batch(kb)
        state_ref, m_ref = step_ref(state_ref, batch, ks)
        state_pal, m_pal = step_pal(state_pal, batch, ks)
    for field in ("x", "v", "q_x", "q_v", "m_x", "m_v", "g_prev"):
        _tree_allclose(getattr(state_ref, field), getattr(state_pal, field))
    np.testing.assert_allclose(float(m_ref["loss"]), float(m_pal["loss"]),
                               rtol=1e-5)
    assert float(m_ref["wire_bytes"]) == float(m_pal["wire_bytes"]) > 0


def test_porter_adam_engine_parity():
    top, cfg = _porter_cfg("gc")
    comp = make_compressor("top_k", frac=0.1)
    mixer = make_mixer(top, "dense")
    state_ref = state_pal = porter_adam_init(ODD_PARAMS, N, w=top.w)
    step_ref = jax.jit(make_porter_adam_step(cfg, _loss_fn, mixer, comp,
                                             backend="ref"))
    step_pal = jax.jit(make_porter_adam_step(cfg, _loss_fn, mixer, comp,
                                             backend="pallas",
                                             interpret=True))
    key = jax.random.PRNGKey(9)
    for _ in range(3):
        key, kb, ks = jax.random.split(key, 3)
        batch = _batch(kb)
        state_ref, _ = step_ref(state_ref, batch, ks)
        state_pal, _ = step_pal(state_pal, batch, ks)
    _tree_allclose(state_ref.base.x, state_pal.base.x)
    _tree_allclose(state_ref.m, state_pal.m)
    _tree_allclose(state_ref.s, state_pal.s)


def test_choco_engine_parity():
    top = _top()
    comp = make_compressor("top_k", frac=0.1)
    mixer = make_mixer(top, "dense")
    gamma = 0.3 * (1 - top.alpha) * 0.1
    eng_pal = CommRound(compressor=comp, mixer=mixer, backend="pallas",
                        interpret=True)
    state_ref = state_pal = BL.choco_init(ODD_PARAMS, N)
    step_ref = jax.jit(functools.partial(BL.choco_step, 0.03, gamma,
                                         _loss_fn, mixer, comp))
    step_pal = jax.jit(functools.partial(BL.choco_step, 0.03, gamma,
                                         _loss_fn, mixer, comp,
                                         engine=eng_pal))
    key = jax.random.PRNGKey(11)
    for _ in range(3):
        key, kb, ks = jax.random.split(key, 3)
        batch = _batch(kb)
        state_ref, m_ref = step_ref(state_ref, batch, ks)
        state_pal, m_pal = step_pal(state_pal, batch, ks)
    for field in ("x", "q", "m"):
        _tree_allclose(getattr(state_ref, field), getattr(state_pal, field))
    assert float(m_ref["wire_bytes"]) == float(m_pal["wire_bytes"]) > 0


# ---------------------------------------------------------------------------
# engine invariants and metrics schema
# ---------------------------------------------------------------------------

def test_engine_preserves_mirror_identity():
    """m == W q after every engine round (the wire-protocol identity),
    through the pallas path."""
    top, cfg = _porter_cfg("gc")
    comp = make_compressor("top_k", frac=0.2)
    mixer = make_mixer(top, "dense")
    state = porter_init(ODD_PARAMS, N, w=top.w)
    step = jax.jit(make_porter_step(cfg, _loss_fn, mixer, comp,
                                    backend="pallas", interpret=True))
    key = jax.random.PRNGKey(3)
    for _ in range(4):
        key, kb, ks = jax.random.split(key, 3)
        state, _ = step(state, _batch(kb), ks)
    w = jnp.asarray(top.w, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(state.m_x["w"]),
        np.asarray(jnp.einsum("ij,jd->id", w, state.q_x["w"])),
        rtol=1e-3, atol=1e-5)


def test_wire_bytes_uniform_schema():
    """Every algorithm reports wire_bytes; PORTER moves 2x CHOCO's stream
    and DSGD pays the dense price."""
    top = _top()
    comp = make_compressor("top_k", frac=0.05)
    mixer = make_mixer(top, "dense")
    eng = CommRound(compressor=comp, mixer=mixer)
    d = sum(int(np.prod(p.shape)) for p in
            jax.tree_util.tree_leaves(ODD_PARAMS))
    one_stream = eng.wire_bytes(d, n_agents=N)
    assert one_stream > 0
    # dense identity: full n*d*4 bytes
    ident = CommRound(compressor=make_compressor("identity"), mixer=mixer)
    assert ident.wire_bytes(d, n_agents=N) == pytest.approx(4.0 * N * d)
    # sparse stream strictly cheaper than dense
    assert one_stream < ident.wire_bytes(d, n_agents=N)

    key = jax.random.PRNGKey(5)
    batch = _batch(key)
    _, cfg = _porter_cfg("gc")
    pstate = porter_init(ODD_PARAMS, N, w=top.w)
    pstep = jax.jit(make_porter_step(cfg, _loss_fn, mixer, comp))
    _, pm = pstep(pstate, batch, key)
    cstate = BL.choco_init(ODD_PARAMS, N)
    cstep = jax.jit(functools.partial(BL.choco_step, 0.03, 0.01, _loss_fn,
                                      mixer, comp))
    _, cm = cstep(cstate, batch, key)
    dstate = BL.dsgd_init(ODD_PARAMS, N)
    dstep = jax.jit(functools.partial(BL.dsgd_step, 0.03, 1.0, _loss_fn,
                                      mixer))
    _, dm = dstep(dstate, batch, key)
    sstate = BL.soteria_init(ODD_PARAMS, N)
    sstep = jax.jit(functools.partial(BL.soteria_step, 0.03, 0.5, _loss_fn,
                                      comp, tau=1.0, sigma_p=0.01))
    _, sm = sstep(sstate, batch, key)
    for m in (pm, cm, dm, sm):
        assert "wire_bytes" in m and "loss" in m
    # PORTER gossips two compressed streams, CHOCO one
    assert float(pm["wire_bytes"]) == pytest.approx(2 * float(cm["wire_bytes"]))
    # consensus reported by all decentralized algorithms
    for m in (pm, cm, dm):
        assert "consensus_x" in m
    # DSGD uncompressed: strictly more bytes than CHOCO's sparse stream
    assert float(dm["wire_bytes"]) > float(cm["wire_bytes"])


def test_engine_rejects_unknown_backend():
    comp = make_compressor("top_k", frac=0.1)
    with pytest.raises(ValueError):
        CommRound(compressor=comp, mixer=None, backend="cuda")


def test_compress_stacked_per_agent_rows():
    """Each agent's row is compressed independently (k per row, not global)."""
    comp = make_compressor("top_k", frac=0.5)
    tree = {"w": jnp.asarray([[1.0, -2.0, 0.5, 3.0],
                              [10.0, 0.1, -0.2, 0.05]])}
    out = compress_stacked(comp, jax.random.PRNGKey(0), tree)["w"]
    # frac=0.5 of 4 -> 2 kept per row
    assert int((out[0] != 0).sum()) == 2
    assert int((out[1] != 0).sum()) == 2
    np.testing.assert_allclose(np.asarray(out[0]), [0, -2.0, 0, 3.0])
    np.testing.assert_allclose(np.asarray(out[1]), [10.0, 0, -0.2, 0])
