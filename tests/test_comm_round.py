"""Comm-round engine tests: the Pallas (interpret-mode) backend must match
the pure-jnp reference path bit-for-close for every algorithm that routes
through CommRound, across odd, non-tile-aligned pytree shapes (flat-plane
padding correctness), and the wire-byte metric must be uniform across
algorithms.

These tests run without hypothesis and are never skipped, so ef_track /
ef_step / ef_gossip are always exercised via interpret=True on CPU CI.

The model-sharded (per-shard planes) parity tests run in a subprocess with
--xla_force_host_platform_device_count=8 so this process keeps its single
CPU device (same pattern as tests/test_distributed_gossip.py).
"""

import functools
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (CommRound, PorterConfig, make_compressor, make_mixer,
                        make_porter_step, make_topology, porter_init)
from repro.core import baselines as BL
from repro.core.comm_round import compress_stacked
from repro.core.porter_adam import make_porter_adam_step, porter_adam_init
from repro.kernels import flatten as FL
from repro.kernels import ops, ref

N = 5  # agents

# odd, non-tile-aligned shapes: scalar leaf, non-multiple-of-8 vector, 3-D
# leaf, and one leaf that crosses a tile boundary (8*1024 elements per tile)
ODD_PARAMS = {
    "b": jnp.zeros(()),
    "w": jnp.zeros((123,)),
    "k": jnp.zeros((7, 11, 3)),
    "big": jnp.zeros((9000,)),
}


def _loss_fn(params, batch):
    f, l = batch
    f = jnp.atleast_2d(f)
    l = jnp.atleast_1d(l)
    pred = (f @ params["w"] + params["b"] + jnp.sum(params["k"])
            + jnp.mean(params["big"]))
    return jnp.mean((pred - l) ** 2)


def _batch(key, n=N, b=4):
    k1, k2 = jax.random.split(key)
    return (jax.random.normal(k1, (n, b, 123)),
            jax.random.normal(k2, (n, b)))


def _top():
    return make_topology("erdos_renyi", N, weights="best_constant", p=0.9,
                         seed=2)


def _tree_allclose(a, b, atol=1e-5):
    fa = jax.tree_util.tree_leaves(a)
    fb = jax.tree_util.tree_leaves(b)
    for x, y in zip(fa, fb):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=atol,
                                   rtol=1e-5)


# ---------------------------------------------------------------------------
# flat tile layout: padding correctness
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("stacked", [True, False])
def test_flatten_roundtrip_odd_shapes(stacked):
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, len(ODD_PARAMS))
    lead = (N,) if stacked else ()
    tree = {name: jax.random.normal(k, lead + p.shape).astype(
                jnp.float32 if i % 2 == 0 else jnp.bfloat16)
            for i, (k, (name, p)) in enumerate(zip(ks, ODD_PARAMS.items()))}
    spec = FL.flat_spec(tree, stacked=stacked)
    planes = FL.to_planes(tree, spec)
    assert planes.shape == spec.plane_shape
    assert planes.shape[-1] == FL.TILE
    assert planes.dtype == jnp.float32
    # padding region is zero (kernels may compute garbage there; from_planes
    # must never read it back)
    if stacked:
        flat = planes.reshape(N, -1)
        assert float(jnp.abs(flat[:, spec.d:]).max()) == 0.0
    back = FL.from_planes(planes, spec)
    for name in tree:
        assert back[name].dtype == tree[name].dtype
        np.testing.assert_allclose(np.asarray(back[name], np.float32),
                                   np.asarray(tree[name], np.float32),
                                   atol=2e-2 if tree[name].dtype ==
                                   jnp.bfloat16 else 1e-7)


def test_flatten_rejects_mismatched_agent_axis():
    with pytest.raises(ValueError):
        FL.flat_spec({"a": jnp.zeros((4, 3)), "b": jnp.zeros((5, 3))})


# ---------------------------------------------------------------------------
# ef_gossip kernel vs oracle (ef_track/ef_step sweeps live in test_kernels)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", [1, 123, 8192, 9000])
@pytest.mark.parametrize("scale", [1.0, 0.5])
def test_ef_gossip_matches_ref(d, scale):
    keys = jax.random.split(jax.random.PRNGKey(d), 5)
    q, m, y, c, wc = [jax.random.normal(k, (d,)) for k in keys]
    out_k = ops.ef_gossip(q, m, y, c, wc, 0.37, scale, interpret=True)
    out_r = ref.ef_gossip_ref(q, m, y, c, wc, 0.37, scale)
    for a, b in zip(out_k, out_r):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5,
                                   atol=1e-6)


def test_ef_track_and_step_fused_semantics():
    """The engine's pallas path == running ef_track/ef_step on flat planes
    == the jnp reference, on a non-tile-aligned buffer."""
    d = 355
    keys = jax.random.split(jax.random.PRNGKey(1), 7)
    q, m, v, c, wc, g, gp = [jax.random.normal(k, (d,)) for k in keys]
    qo, mo, vo = ops.ef_track(q, m, v, c, wc, g, gp, 0.2, interpret=True)
    qr, mr, vr = ref.ef_track_ref(q, m, v, c, wc, g, gp, 0.2)
    for a, b in zip((qo, mo, vo), (qr, mr, vr)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)
    xo = ops.ef_step(q, m, v, c, wc, g, 0.2, 0.05, interpret=True)
    xr = ref.ef_step_ref(q, m, v, c, wc, g, 0.2, 0.05)
    for a, b in zip(xo, xr):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


# ---------------------------------------------------------------------------
# engine parity: pallas(interpret) vs ref across algorithms and variants
# ---------------------------------------------------------------------------

def _porter_cfg(variant):
    top = _top()
    gamma = 0.5 * (1 - top.alpha) * 0.1
    sigma = 0.05 if variant == "dp" else 0.0
    return top, PorterConfig(eta=0.03, gamma=gamma, tau=1.0, variant=variant,
                             sigma_p=sigma)


@pytest.mark.parametrize("variant,comp_name",
                         [("gc", "top_k"), ("dp", "random_k"),
                          ("beer", "block_top_k")])
def test_porter_engine_parity(variant, comp_name):
    """PORTER-GC/DP/BEER: pallas interpret backend == jnp reference backend
    after several steps, odd shapes, atol 1e-5."""
    top, cfg = _porter_cfg(variant)
    comp = make_compressor(comp_name, frac=0.1)
    mixer = make_mixer(top, "dense")
    state_ref = state_pal = porter_init(ODD_PARAMS, N, w=top.w)
    step_ref = jax.jit(make_porter_step(cfg, _loss_fn, mixer, comp,
                                        backend="ref"))
    step_pal = jax.jit(make_porter_step(cfg, _loss_fn, mixer, comp,
                                        backend="pallas", interpret=True))
    key = jax.random.PRNGKey(7)
    for _ in range(3):
        key, kb, ks = jax.random.split(key, 3)
        batch = _batch(kb)
        state_ref, m_ref = step_ref(state_ref, batch, ks)
        state_pal, m_pal = step_pal(state_pal, batch, ks)
    for field in ("x", "v", "q_x", "q_v", "m_x", "m_v", "g_prev"):
        _tree_allclose(getattr(state_ref, field), getattr(state_pal, field))
    np.testing.assert_allclose(float(m_ref["loss"]), float(m_pal["loss"]),
                               rtol=1e-5)
    assert float(m_ref["wire_bytes"]) == float(m_pal["wire_bytes"]) > 0


def test_porter_adam_engine_parity():
    top, cfg = _porter_cfg("gc")
    comp = make_compressor("top_k", frac=0.1)
    mixer = make_mixer(top, "dense")
    state_ref = state_pal = porter_adam_init(ODD_PARAMS, N, w=top.w)
    step_ref = jax.jit(make_porter_adam_step(cfg, _loss_fn, mixer, comp,
                                             backend="ref"))
    step_pal = jax.jit(make_porter_adam_step(cfg, _loss_fn, mixer, comp,
                                             backend="pallas",
                                             interpret=True))
    key = jax.random.PRNGKey(9)
    for _ in range(3):
        key, kb, ks = jax.random.split(key, 3)
        batch = _batch(kb)
        state_ref, _ = step_ref(state_ref, batch, ks)
        state_pal, _ = step_pal(state_pal, batch, ks)
    _tree_allclose(state_ref.base.x, state_pal.base.x)
    _tree_allclose(state_ref.m, state_pal.m)
    _tree_allclose(state_ref.s, state_pal.s)


def test_choco_engine_parity():
    top = _top()
    comp = make_compressor("top_k", frac=0.1)
    mixer = make_mixer(top, "dense")
    gamma = 0.3 * (1 - top.alpha) * 0.1
    eng_pal = CommRound(compressor=comp, mixer=mixer, backend="pallas",
                        interpret=True)
    state_ref = state_pal = BL.choco_init(ODD_PARAMS, N)
    step_ref = jax.jit(functools.partial(BL.choco_step, 0.03, gamma,
                                         _loss_fn, mixer, comp))
    step_pal = jax.jit(functools.partial(BL.choco_step, 0.03, gamma,
                                         _loss_fn, mixer, comp,
                                         engine=eng_pal))
    key = jax.random.PRNGKey(11)
    for _ in range(3):
        key, kb, ks = jax.random.split(key, 3)
        batch = _batch(kb)
        state_ref, m_ref = step_ref(state_ref, batch, ks)
        state_pal, m_pal = step_pal(state_pal, batch, ks)
    for field in ("x", "q", "m"):
        _tree_allclose(getattr(state_ref, field), getattr(state_pal, field))
    assert float(m_ref["wire_bytes"]) == float(m_pal["wire_bytes"]) > 0


# ---------------------------------------------------------------------------
# engine invariants and metrics schema
# ---------------------------------------------------------------------------

def test_engine_preserves_mirror_identity():
    """m == W q after every engine round (the wire-protocol identity),
    through the pallas path."""
    top, cfg = _porter_cfg("gc")
    comp = make_compressor("top_k", frac=0.2)
    mixer = make_mixer(top, "dense")
    state = porter_init(ODD_PARAMS, N, w=top.w)
    step = jax.jit(make_porter_step(cfg, _loss_fn, mixer, comp,
                                    backend="pallas", interpret=True))
    key = jax.random.PRNGKey(3)
    for _ in range(4):
        key, kb, ks = jax.random.split(key, 3)
        state, _ = step(state, _batch(kb), ks)
    w = jnp.asarray(top.w, jnp.float32)
    np.testing.assert_allclose(
        np.asarray(state.m_x["w"]),
        np.asarray(jnp.einsum("ij,jd->id", w, state.q_x["w"])),
        rtol=1e-3, atol=1e-5)


def test_wire_bytes_uniform_schema():
    """Every algorithm reports wire_bytes; PORTER moves 2x CHOCO's stream
    and DSGD pays the dense price."""
    top = _top()
    comp = make_compressor("top_k", frac=0.05)
    mixer = make_mixer(top, "dense")
    eng = CommRound(compressor=comp, mixer=mixer)
    d = sum(int(np.prod(p.shape)) for p in
            jax.tree_util.tree_leaves(ODD_PARAMS))
    one_stream = eng.wire_bytes(d, n_agents=N)
    assert one_stream > 0
    # dense identity: full n*d*4 bytes
    ident = CommRound(compressor=make_compressor("identity"), mixer=mixer)
    assert ident.wire_bytes(d, n_agents=N) == pytest.approx(4.0 * N * d)
    # sparse stream strictly cheaper than dense
    assert one_stream < ident.wire_bytes(d, n_agents=N)

    key = jax.random.PRNGKey(5)
    batch = _batch(key)
    _, cfg = _porter_cfg("gc")
    pstate = porter_init(ODD_PARAMS, N, w=top.w)
    pstep = jax.jit(make_porter_step(cfg, _loss_fn, mixer, comp))
    _, pm = pstep(pstate, batch, key)
    cstate = BL.choco_init(ODD_PARAMS, N)
    cstep = jax.jit(functools.partial(BL.choco_step, 0.03, 0.01, _loss_fn,
                                      mixer, comp))
    _, cm = cstep(cstate, batch, key)
    dstate = BL.dsgd_init(ODD_PARAMS, N)
    dstep = jax.jit(functools.partial(BL.dsgd_step, 0.03, 1.0, _loss_fn,
                                      mixer))
    _, dm = dstep(dstate, batch, key)
    sstate = BL.soteria_init(ODD_PARAMS, N)
    sstep = jax.jit(functools.partial(BL.soteria_step, 0.03, 0.5, _loss_fn,
                                      comp, tau=1.0, sigma_p=0.01))
    _, sm = sstep(sstate, batch, key)
    for m in (pm, cm, dm, sm):
        assert "wire_bytes" in m and "loss" in m
    # PORTER gossips two compressed streams, CHOCO one
    assert float(pm["wire_bytes"]) == pytest.approx(2 * float(cm["wire_bytes"]))
    # consensus reported by all decentralized algorithms
    for m in (pm, cm, dm):
        assert "consensus_x" in m
    # DSGD uncompressed: strictly more bytes than CHOCO's sparse stream
    assert float(dm["wire_bytes"]) > float(cm["wire_bytes"])


def test_engine_rejects_unknown_backend():
    comp = make_compressor("top_k", frac=0.1)
    with pytest.raises(ValueError):
        CommRound(compressor=comp, mixer=None, backend="cuda")


# ---------------------------------------------------------------------------
# per-shard planes: model-sharded mesh parity + collective inspection
# ---------------------------------------------------------------------------

def test_specs_have_model_axes():
    from jax.sharding import PartitionSpec as P
    agent_only = {"a": P("data", None), "b": P(("pod", "data"), None)}
    assert not FL.specs_have_model_axes(agent_only, ("pod", "data"))
    sharded = {"a": P("data", None, "model"), "b": P("data", None)}
    assert FL.specs_have_model_axes(sharded, ("data",))
    # a non-agent axis folded into a tuple entry still counts
    assert FL.specs_have_model_axes({"a": P(("data", "model"))}, ("data",))


def test_engine_without_mesh_keeps_single_plane_path():
    comp = make_compressor("top_k", frac=0.1)
    eng = CommRound(compressor=comp, mixer=make_mixer(_top(), "dense"),
                    backend="pallas", interpret=True)
    assert eng._sharded_planes() is None


_SHARDED_SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.api import ExperimentSpec, build_engine, resolve_compressor
    from repro.launch.steps import make_shard_local_compress

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    n = 4
    key = jax.random.PRNGKey(0)

    # odd, non-tile-aligned leaves; 'a'/'c' model-sharded, 'b' replicated
    # over the model axis
    shapes = {"a": (n, 7, 6), "b": (n, 123), "c": (n, 10, 2)}
    specs = {"a": P("data", None, "model"), "b": P("data", None),
             "c": P("data", None, "model")}
    sh = {k: NamedSharding(mesh, specs[k]) for k in specs}

    def tree(k, dtype=jnp.float32):
        ks = jax.random.split(k, len(shapes))
        return {name: jax.device_put(
                    jax.random.normal(kk, shapes[name]).astype(dtype),
                    sh[name])
                for kk, name in zip(ks, shapes)}

    ks = jax.random.split(key, 6)
    y, q, m, g, gp = (tree(k) for k in ks[:5])
    kr = ks[5]

    base = ExperimentSpec(n_agents=n, topology="ring",
                          compressor="block_top_k", frac=0.25,
                          compressor_kwargs={"block": 4})
    comp = resolve_compressor(base)
    shard_local = make_shard_local_compress(comp, mesh, specs)

    def engines(gossip_mode):
        kw = dict(mesh=mesh, leaf_specs=specs, compress_fn=shard_local)
        ref = build_engine(base.replace(gossip_mode=gossip_mode,
                                        comm_backend="ref"), **kw)
        pal = build_engine(base.replace(gossip_mode=gossip_mode,
                                        comm_backend="pallas",
                                        interpret=True), **kw)
        assert pal._sharded_planes() is not None, "per-shard planes inactive"
        return ref, pal

    def check(tref, tpal, atol=1e-5, rtol=1e-5):
        for name in tref:
            np.testing.assert_allclose(
                np.asarray(tref[name], np.float32),
                np.asarray(tpal[name], np.float32), atol=atol, rtol=rtol)

    # --- parity: track / step / gossip_apply, ring + packed wire formats ---
    for mode in ("ring", "packed"):
        ref, pal = engines(mode)
        vr, qr, mr = jax.jit(lambda k: ref.track(k, y, q, m, g, gp, 0.2))(kr)
        vp, qp, mp = jax.jit(lambda k: pal.track(k, y, q, m, g, gp, 0.2))(kr)
        for a, b in ((vr, vp), (qr, qp), (mr, mp)):
            check(a, b)
        xr, _, _ = jax.jit(lambda k: ref.step(k, y, q, m, vr, 0.2, 0.05))(kr)
        xp, _, _ = jax.jit(lambda k: pal.step(k, y, q, m, vp, 0.2, 0.05))(kr)
        check(xr, xp)
        yr, _, _ = jax.jit(lambda k: ref.gossip_apply(k, y, q, m, 0.2, 0.5))(kr)
        yp, _, _ = jax.jit(lambda k: pal.gossip_apply(k, y, q, m, 0.2, 0.5))(kr)
        check(yr, yp)
        print(mode + "-parity-ok")

    # --- bf16 buffer dtype through the per-shard planes ---
    yb, qb, mb, gb, gpb = (tree(k, jnp.bfloat16) for k in ks[:5])
    ref, pal = engines("ring")
    vr, qr, mr = jax.jit(lambda k: ref.track(k, yb, qb, mb, gb, gpb, 0.2))(kr)
    vp, qp, mp = jax.jit(lambda k: pal.track(k, yb, qb, mb, gb, gpb, 0.2))(kr)
    for name in vr:
        assert vp[name].dtype == jnp.bfloat16, vp[name].dtype
    # ref accumulates in bf16, the kernel in f32 -- parity up to bf16 ulps
    for a, b in ((vr, vp), (qr, qp), (mr, mp)):
        check(a, b, atol=6e-2, rtol=6e-2)
    print("bf16-parity-ok")

    # --- collective inspection: pack/unpack must add no all-gather --------
    from repro.analysis.hlo import collective_counts

    def ag_count(eng):
        f = jax.jit(lambda k, y, q, m, g, gp: eng.track(k, y, q, m, g, gp,
                                                        0.2),
                    in_shardings=(NamedSharding(mesh, P()),) + (sh,) * 5)
        txt = f.lower(kr, y, q, m, g, gp).compile().as_text()
        return collective_counts(txt)["all-gather"]

    ref, pal = engines("ring")
    # ring gossip + shard-local compression + per-shard planes: the whole
    # round is ppermutes only -- zero all-gathers anywhere in the HLO
    assert ag_count(pal) == 0, "pallas ring track lowered an all-gather"
    print("ring-no-allgather-ok")

    ref, pal = engines("packed")
    # packed gossip all-gathers (value, index) pairs over the *agent* axis
    # in both backends; per-shard planes must not add model-axis gathers
    n_ref, n_pal = ag_count(ref), ag_count(pal)
    assert n_pal <= n_ref, (n_pal, n_ref)
    print("packed-no-extra-allgather-ok")
""")


def test_sharded_engine_parity_and_collectives():
    """Tentpole oracle: on a data x model host mesh, backend='pallas'
    (interpret, per-shard planes) matches backend='ref' to atol 1e-5 for
    track/step/gossip_apply on odd shapes (+ bf16 buffers), and the plane
    pack/unpack introduces no all-gather over the model axis."""
    res = subprocess.run([sys.executable, "-c", _SHARDED_SCRIPT],
                         capture_output=True, text=True, timeout=900,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"})
    assert res.returncode == 0, res.stderr[-3000:]
    for marker in ("ring-parity-ok", "packed-parity-ok", "bf16-parity-ok",
                   "ring-no-allgather-ok", "packed-no-extra-allgather-ok"):
        assert marker in res.stdout, (marker, res.stdout,
                                      res.stderr[-2000:])


def test_packed_wire_bytes_per_leaf_and_shard_windows():
    """Engine packed accounting matches the executor's padding: one window
    count per leaf and per model shard, not ceil(sum(d)/PACK_BLOCK)."""
    from types import SimpleNamespace

    from jax.sharding import PartitionSpec as P

    from repro.core.gossip import PACK_BLOCK

    comp = make_compressor("block_top_k", frac=0.05)

    def packed_mixer():
        mix = lambda t: t  # noqa: E731 -- wire-mode tag carrier only
        mix.wire_mode, mix.wire_frac = "packed", 0.05
        return mix

    k_b = max(round(0.05 * PACK_BLOCK), 1)
    tree = {"b": jnp.zeros((4, 123)), "w": jnp.zeros((4, 42))}
    eng = CommRound(compressor=comp, mixer=packed_mixer())
    # the executor pads each leaf separately: 2 windows, not ceil(165/2048)=1
    assert eng.wire_bytes(tree) == 4 * 2 * k_b * 8
    # the scalar-d overload keeps gossip_wire_bytes's single-buffer model
    assert eng.wire_bytes(165, n_agents=4) == 4 * 1 * k_b * 8

    # model-sharded layout: local() runs per shard, each pads its own window
    mesh = SimpleNamespace(shape={"data": 4, "model": 2})
    eng2 = CommRound(compressor=comp, mixer=packed_mixer(), mesh=mesh,
                     leaf_specs={"b": P("data", None),
                                 "w": P("data", "model")},
                     agent_axes=("data",))
    assert eng2.wire_bytes(tree) == 4 * 3 * k_b * 8  # w: 2 shards, b: 1


def test_ring_weights_n2_single_band():
    """n=2 ring: both shifts deliver the same agent; the executor must fold
    the whole neighbor weight into one band (regression: w_self*x + 2*w01*nb
    double-counted the neighbor and the circulant check hid it by
    overwriting ref[0,1])."""
    from repro.core.gossip import _ring_weights
    w2 = np.array([[0.5, 0.5], [0.5, 0.5]])
    w_self, w_prev, w_next = _ring_weights(w2)
    assert (w_self, w_prev, w_next) == (0.5, 0.5, 0.0)
    # row sum of the executed update is w_self + w_prev + w_next == 1
    assert w_self + w_prev + w_next == pytest.approx(1.0)
    # the accumulate-style check is honest: asymmetric 2x2 is not a ring band
    with pytest.raises(ValueError):
        _ring_weights(np.array([[0.6, 0.4], [0.3, 0.7]]))
    with pytest.raises(ValueError):
        _ring_weights(np.array([[1.0]]))  # n=1: no ring


def test_compress_stacked_per_agent_rows():
    """Each agent's row is compressed independently (k per row, not global)."""
    comp = make_compressor("top_k", frac=0.5)
    tree = {"w": jnp.asarray([[1.0, -2.0, 0.5, 3.0],
                              [10.0, 0.1, -0.2, 0.05]])}
    out = compress_stacked(comp, jax.random.PRNGKey(0), tree)["w"]
    # frac=0.5 of 4 -> 2 kept per row
    assert int((out[0] != 0).sum()) == 2
    assert int((out[1] != 0).sum()) == 2
    np.testing.assert_allclose(np.asarray(out[0]), [0, -2.0, 0, 3.0])
    np.testing.assert_allclose(np.asarray(out[1]), [10.0, 0, -0.2, 0])
