"""Integration tests for PORTER (Algorithm 1) and the baselines:
convergence on the paper's logistic-regression problem, algebraic
invariants (v-bar = g-bar tracking, mirror exactness), BEER equivalence,
and gossip-mode equivalence."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PorterConfig, average_params, consensus_error,
                        make_compressor, make_mixer, make_porter_step,
                        make_topology, porter_init)
from repro.core import baselines as BL
from repro.core.gossip import make_dense_mixer
from repro.data import a9a_like, agent_batch_iterator, shard_to_agents

N_AGENTS = 10
LAM = 0.2


def loss_fn(params, batch):
    f, l = batch
    f = jnp.atleast_2d(f)
    l = jnp.atleast_1d(l)
    logits = f @ params["w"] + params["b"]
    nll = jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits)))
    reg = LAM * jnp.sum(params["w"] ** 2 / (1 + params["w"] ** 2))
    return nll + reg


@pytest.fixture(scope="module")
def problem():
    x, y = a9a_like(4000, 123, seed=0)
    xs, ys = shard_to_agents(x, y, N_AGENTS)
    top = make_topology("erdos_renyi", N_AGENTS, weights="best_constant",
                        p=0.8, seed=1)
    params0 = {"w": jnp.zeros(123), "b": jnp.zeros(())}
    return xs, ys, top, params0


def full_grad_norm(params, xs, ys):
    batch = (xs.reshape(-1, 123), ys.reshape(-1))
    g = jax.grad(loss_fn)(params, batch)
    return float(jnp.sqrt(sum(jnp.sum(v ** 2)
                              for v in jax.tree_util.tree_leaves(g))))


def run(cfg, comp, top, xs, ys, steps=300, seed=0, gossip="dense"):
    mixer = make_mixer(top, gossip)
    params0 = {"w": jnp.zeros(123), "b": jnp.zeros(())}
    state = porter_init(params0, N_AGENTS, w=top.w)
    step = jax.jit(make_porter_step(cfg, loss_fn, mixer, comp))
    it = agent_batch_iterator(xs, ys, batch=8, seed=seed)
    key = jax.random.PRNGKey(seed)
    m = {}
    for _ in range(steps):
        key, k = jax.random.split(key)
        state, m = step(state, next(it), k)
    return state, m


def test_porter_gc_converges_with_compression(problem):
    xs, ys, top, _ = problem
    gamma = 0.5 * (1 - top.alpha) * 0.05
    cfg = PorterConfig(eta=0.05, gamma=gamma, tau=1.0, variant="gc")
    comp = make_compressor("top_k", frac=0.05)
    state, metrics = run(cfg, comp, top, xs, ys, steps=400)
    gn = full_grad_norm(average_params(state.x), xs, ys)
    assert np.isfinite(float(metrics["loss"]))
    assert gn < 0.1, f"did not converge: |grad| = {gn}"


def test_porter_dp_converges_and_perturbs(problem):
    xs, ys, top, _ = problem
    gamma = 0.5 * (1 - top.alpha) * 0.05
    cfg = PorterConfig(eta=0.03, gamma=gamma, tau=1.0, variant="dp",
                       sigma_p=0.01)
    comp = make_compressor("random_k", frac=0.05)
    state, metrics = run(cfg, comp, top, xs, ys, steps=400)
    gn = full_grad_norm(average_params(state.x), xs, ys)
    assert gn < 0.25, f"PORTER-DP diverged: |grad| = {gn}"


def test_beer_is_unclipped_porter(problem):
    """Paper 4.3: with bounded gradients / tau -> inf, PORTER-GC == BEER."""
    xs, ys, top, _ = problem
    from repro.core.beer import beer_config
    gamma = 0.5 * (1 - top.alpha) * 0.05
    comp = make_compressor("top_k", frac=0.05)
    cfg_beer = beer_config(eta=0.05, gamma=gamma)
    cfg_gc_hi_tau = PorterConfig(eta=0.05, gamma=gamma, tau=1e9,
                                 variant="gc")
    s1, _ = run(cfg_beer, comp, top, xs, ys, steps=50)
    s2, _ = run(cfg_gc_hi_tau, comp, top, xs, ys, steps=50)
    np.testing.assert_allclose(np.asarray(s1.x["w"]), np.asarray(s2.x["w"]),
                               rtol=1e-4, atol=1e-6)


def test_beer_config_rejects_clipping_overrides():
    """beer_config must refuse tau/variant instead of silently dropping them
    (a silently-ignored tau would run a different algorithm than asked)."""
    from repro.core.beer import beer_config
    with pytest.raises(ValueError, match="tau"):
        beer_config(eta=0.05, gamma=0.1, tau=2.0)
    with pytest.raises(ValueError, match="variant"):
        beer_config(eta=0.05, gamma=0.1, variant="gc")
    # other PorterConfig knobs still pass through
    cfg = beer_config(eta=0.05, gamma=0.1, clip_mode="piecewise")
    assert cfg.variant == "beer" and cfg.tau == float("inf")
    assert cfg.clip_mode == "piecewise"


def test_vbar_tracks_gbar(problem):
    """Gradient tracking invariant: mean_i v_i == mean_i g_p,i (exactly,
    by induction -- the gossip term is mean-zero)."""
    xs, ys, top, _ = problem
    gamma = 0.5 * (1 - top.alpha) * 0.5
    cfg = PorterConfig(eta=0.05, gamma=gamma, tau=1.0, variant="gc")
    comp = make_compressor("top_k", frac=0.5)
    mixer = make_mixer(top, "dense")
    params0 = {"w": jnp.zeros(123), "b": jnp.zeros(())}
    state = porter_init(params0, N_AGENTS, w=top.w)
    step = jax.jit(make_porter_step(cfg, loss_fn, mixer, comp))
    it = agent_batch_iterator(xs, ys, batch=8, seed=0)
    key = jax.random.PRNGKey(0)
    for _ in range(10):
        key, k = jax.random.split(key)
        state, _ = step(state, next(it), k)
    vbar = jnp.mean(state.v["w"], axis=0)
    gbar = jnp.mean(state.g_prev["w"], axis=0)
    np.testing.assert_allclose(np.asarray(vbar), np.asarray(gbar),
                               rtol=1e-4, atol=1e-6)


def test_mirror_is_exact(problem):
    """m_i must equal sum_j w_ij q_j at every step (wire-protocol identity)."""
    xs, ys, top, _ = problem
    gamma = 0.5 * (1 - top.alpha) * 0.2
    cfg = PorterConfig(eta=0.05, gamma=gamma, tau=1.0, variant="gc")
    comp = make_compressor("top_k", frac=0.2)
    mixer = make_mixer(top, "dense")
    params0 = {"w": jnp.zeros(123), "b": jnp.zeros(())}
    state = porter_init(params0, N_AGENTS, w=top.w)
    step = jax.jit(make_porter_step(cfg, loss_fn, mixer, comp))
    it = agent_batch_iterator(xs, ys, batch=8, seed=0)
    key = jax.random.PRNGKey(0)
    for _ in range(20):
        key, k = jax.random.split(key)
        state, _ = step(state, next(it), k)
    w = jnp.asarray(top.w, jnp.float32)
    np.testing.assert_allclose(np.asarray(state.m_x["w"]),
                               np.asarray(jnp.einsum("ij,jd->id", w,
                                                     state.q_x["w"])),
                               rtol=1e-3, atol=1e-5)


def test_consensus_error_decreases(problem):
    xs, ys, top, _ = problem
    gamma = 0.5 * (1 - top.alpha) * 0.05
    cfg = PorterConfig(eta=0.02, gamma=gamma, tau=1.0, variant="gc")
    comp = make_compressor("top_k", frac=0.05)
    s_early, m_early = run(cfg, comp, top, xs, ys, steps=30)
    s_late, m_late = run(cfg, comp, top, xs, ys, steps=400)
    # x replicas stay coherent: consensus error stays small relative to ||x||
    xbar_norm = float(jnp.linalg.norm(jnp.mean(s_late.x["w"], 0)))
    assert float(m_late["consensus_x"]) < max(0.5 * xbar_norm ** 2, 1.0)


def test_identity_compression_rho1_fastest(problem):
    """rho = 1 (no compression) should reach a lower gradient norm than
    rho = 0.05 in the same number of steps (Theorems 3/4 trend)."""
    xs, ys, top, _ = problem
    res = {}
    for frac in (1.0, 0.05):
        comp = make_compressor("top_k", frac=frac)
        gamma = 0.5 * (1 - top.alpha) * frac
        cfg = PorterConfig(eta=0.05, gamma=gamma, tau=1.0, variant="gc")
        state, _ = run(cfg, comp, top, xs, ys, steps=150)
        res[frac] = full_grad_norm(average_params(state.x), xs, ys)
    assert res[1.0] <= res[0.05] * 1.5


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------

def test_dsgd_and_choco_converge(problem):
    xs, ys, top, params0 = problem
    mixer_w = make_dense_mixer(top.w)
    it = agent_batch_iterator(xs, ys, batch=8, seed=0)
    key = jax.random.PRNGKey(0)

    state = BL.dsgd_init(params0, N_AGENTS)
    step = jax.jit(functools.partial(BL.dsgd_step, 0.05, 1.0, loss_fn,
                                     mixer_w))
    for _ in range(300):
        key, k = jax.random.split(key)
        state, m = step(state, next(it), k)
    assert full_grad_norm(average_params(state.x), xs, ys) < 0.15

    comp = make_compressor("top_k", frac=0.05)
    gamma = 0.3 * (1 - top.alpha) * 0.05
    cstate = BL.choco_init(params0, N_AGENTS)
    cstep = jax.jit(functools.partial(BL.choco_step, 0.05, gamma, loss_fn,
                                      make_dense_mixer(top.w), comp))
    for _ in range(300):
        key, k = jax.random.split(key)
        cstate, m = cstep(cstate, next(it), k)
    assert full_grad_norm(average_params(cstate.x), xs, ys) < 0.2


def test_dpsgd_and_soteria_converge(problem):
    xs, ys, _, params0 = problem
    it = agent_batch_iterator(xs, ys, batch=8, seed=0)
    key = jax.random.PRNGKey(0)

    state = BL.dpsgd_init(params0)
    step = jax.jit(functools.partial(BL.dpsgd_step, 0.1, loss_fn,
                                     tau=1.0, sigma_p=0.01))
    for _ in range(200):
        key, k = jax.random.split(key)
        flat = jax.tree_util.tree_map(
            lambda a: a.reshape((-1,) + a.shape[2:]), next(it))
        state, m = step(state, flat, k)
    assert np.isfinite(float(m["loss"]))

    comp = make_compressor("random_k", frac=0.05)
    sstate = BL.soteria_init(params0, N_AGENTS)
    sstep = jax.jit(functools.partial(BL.soteria_step, 0.1, 0.5, loss_fn,
                                      comp, tau=1.0, sigma_p=0.01))
    for _ in range(300):
        key, k = jax.random.split(key)
        sstate, m = sstep(sstate, next(it), k)
    gn = full_grad_norm(sstate.x, xs, ys)
    assert gn < 0.25, f"SoteriaFL-SGD diverged: {gn}"
