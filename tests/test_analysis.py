"""Analyzer self-tests (repro.analysis): every pass must fail its known-bad
fixture for exactly its own rule and accept the known-good twin.

* HLO parsing: shape bytes, -start/-done async pairing, source_file
  attribution.
* Census: gossip budgets (over-count, unbudgeted category), the
  partitioner rule (all-reduce / TopK gather / scalar key plumbing pass;
  anything else fails), spmd_dependent report-only mode.
* Dtype flow: packed wire contract with the f32 allowance and source
  scoping.
* Donation: static marker count + the live runtime probe on a 1-device
  runner (known-bad: a jit WITHOUT donate_argnums).
* Retrace: known-bad step whose carried aval alternates between calls.
* AST lint: host escapes inside step functions, host syncs in eval
  callbacks, jax-free modules, suppression token.
* Table completeness over the live registry.

Everything here runs mesh-free (single CPU device) so it stays tier-1.
"""

import textwrap

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis import ast_rules
from repro.analysis.hlo import (GOSSIP_SOURCES, NO_GOSSIP_BUDGET,
                                check_census, check_dtype_flow,
                                check_retrace, collective_counts,
                                collective_ops, donation_hlo_report,
                                parse_collectives, shape_bytes)
from repro.api import ExperimentSpec, build
from repro.core.gossip import GossipBudget
from repro.data import minibatch_source

# ---------------------------------------------------------------------------
# Synthetic HLO fixtures.  Shapes/sources mirror what the CPU backend
# actually emits (see analysis/hlo.py docstring).
# ---------------------------------------------------------------------------

_SRC = 'metadata={op_name="x" source_file="/r/src/repro/%s" source_line=1}'

GOOD_RING_HLO = f"""
  %cp.1 = u16[1,1024]{{1,0}} collective-permute(u16[1,1024] %a), {_SRC % 'core/gossip.py'}
  %cp.2 = u16[1,1024]{{1,0}} collective-permute(u16[1,1024] %b), {_SRC % 'core/gossip.py'}
  %ar.1 = f32[4096]{{0}} all-reduce(f32[4096] %m), {_SRC % 'core/porter.py'}
  %ar.2 = f32[] all-reduce(f32[] %s), {_SRC % 'core/clipping.py'}
  %ag.1 = f32[4,2,2048]{{2,1,0}} all-gather(f32[1,2,2048] %t), {_SRC % 'core/compression.py'}
  %cpk = u32[2]{{0}} collective-permute(u32[2] %k), {_SRC % 'core/porter.py'}
"""

RING_BUDGET = GossipBudget(executor="ring", per_leaf={"collective-permute": 2})


def test_shape_bytes_and_parse():
    assert shape_bytes("bf16[16,2048]{1,0}") == 16 * 2048 * 2
    assert shape_bytes("(f32[8,4]{1,0}, s32[8]{0})") == 8 * 4 * 4 + 8 * 4
    hlo = """
      %ag = f32[16,1024]{1,0} all-gather(f32[1,1024] %p), dims={0}
      %ag2 = f32[8]{0} all-gather-start(f32[1] %q)
      %agd = f32[8]{0} all-gather-done(f32[8] %ag2)
    """
    out = parse_collectives(hlo)
    assert out["all-gather"]["count"] == 2  # -start counted, -done not
    assert out["all-gather"]["bytes"] == 16 * 1024 * 4 + 8 * 4
    assert collective_counts(hlo)["collective-permute"] == 0


def test_collective_source_attribution():
    ops = collective_ops(GOOD_RING_HLO)
    assert [op.source for op in ops] == [
        "core/gossip.py", "core/gossip.py", "core/porter.py",
        "core/clipping.py", "core/compression.py", "core/porter.py"]
    assert [op.gossip for op in ops] == [True, True, False, False, False,
                                         False]
    assert GOSSIP_SOURCES == ("core/gossip.py",)


def test_census_known_good():
    rep = check_census(GOOD_RING_HLO, budget=RING_BUDGET, n_leaves=1,
                       comm_rounds=1)
    assert rep.ok, rep.violations
    assert rep.counts["collective-permute"] == 2
    assert rep.spmd_counts == {"all-reduce": 2, "all-gather": 1,
                               "collective-permute": 1}
    assert rep.to_json()["executor"] == "ring"


def test_census_over_budget_fails():
    hlo = GOOD_RING_HLO + f"""
  %cp.3 = u16[1,1024]{{1,0}} collective-permute(u16[1,1024] %c), {_SRC % 'core/gossip.py'}
"""
    rep = check_census(hlo, budget=RING_BUDGET, n_leaves=1, comm_rounds=1)
    assert not rep.ok
    assert len(rep.violations) == 1
    assert "3 gossip op(s) > budget 2" in rep.violations[0]


def test_census_unbudgeted_category_fails():
    hlo = GOOD_RING_HLO + f"""
  %ag.g = u16[4,1024]{{1,0}} all-gather(u16[1,1024] %g), {_SRC % 'core/gossip.py'}
"""
    rep = check_census(hlo, budget=RING_BUDGET, n_leaves=1, comm_rounds=1)
    assert not rep.ok
    assert len(rep.violations) == 1
    assert "unbudgeted collective 'all-gather'" in rep.violations[0]


def test_census_partitioner_rule():
    # a partitioner all-gather NOT from the compressor = sharded state
    # being materialized -> exactly one violation
    bad = GOOD_RING_HLO + f"""
  %ag.bad = f32[4,4096]{{1,0}} all-gather(f32[1,4096] %z), {_SRC % 'core/porter.py'}
"""
    rep = check_census(bad, budget=RING_BUDGET, n_leaves=1, comm_rounds=1)
    assert not rep.ok
    assert len(rep.violations) == 1
    assert "partitioner-inserted all-gather" in rep.violations[0]
    # model-sharded meshes opt out of the partitioner rule (GSPMD gathers
    # weights for the matmuls there); the gossip budget still enforces
    relaxed = check_census(bad, budget=RING_BUDGET, n_leaves=1,
                           comm_rounds=1, spmd_rule=False)
    assert relaxed.ok and relaxed.spmd_counts["all-gather"] == 2
    over = bad + f"""
  %cp.3 = u16[1,1024]{{1,0}} collective-permute(u16[1,1024] %c), {_SRC % 'core/gossip.py'}
"""
    assert not check_census(over, budget=RING_BUDGET, n_leaves=1,
                            comm_rounds=1, spmd_rule=False).ok
    # ...but the scalar key permute (8 bytes, core/porter.py) in the good
    # fixture passed, as did the TopK gather and the metric all-reduces
    assert check_census(GOOD_RING_HLO, budget=RING_BUDGET).ok


def test_census_no_gossip_budget():
    hlo = f"""
  %cp = u16[1,1024]{{1,0}} collective-permute(u16[1,1024] %a), {_SRC % 'core/gossip.py'}
"""
    rep = check_census(hlo, budget=NO_GOSSIP_BUDGET)
    assert not rep.ok and "declares none" in rep.violations[0]
    assert check_census("", budget=NO_GOSSIP_BUDGET).ok


def test_census_spmd_dependent_report_only():
    dense = GossipBudget(executor="dense", per_leaf={}, spmd_dependent=True)
    hlo = f"""
  %ag = f32[4,4096]{{1,0}} all-gather(f32[1,4096] %x), {_SRC % 'core/gossip.py'}
"""
    meshed = check_census(hlo, budget=dense, meshed=True)
    assert meshed.ok and not meshed.enforced
    unmeshed = check_census(hlo, budget=dense, meshed=False)
    assert not unmeshed.ok and unmeshed.enforced


def test_dtype_flow():
    good = f"""
  %cp.1 = u16[1,2048]{{1,0}} collective-permute(u16[1,2048] %a), {_SRC % 'core/gossip.py'}
  %ar.1 = f32[4096]{{0}} all-reduce(f32[4096] %m), {_SRC % 'core/porter.py'}
"""
    # the 16 KiB f32 metric all-reduce is out of scope (not gossip-sourced)
    rep = check_dtype_flow(good)
    assert rep.ok, rep.violations
    assert rep.dtype_bytes == {"u16": 2048 * 2}

    leak = f"""
  %cp.1 = u16[1,2048]{{1,0}} collective-permute(u16[1,2048] %a), {_SRC % 'core/gossip.py'}
  %cp.2 = f32[1,4096]{{1,0}} collective-permute(f32[1,4096] %d), {_SRC % 'core/gossip.py'}
"""
    rep = check_dtype_flow(leak)
    assert not rep.ok
    assert any("dense plane is leaking" in v for v in rep.violations)
    # the same f32 rider within its allowance (qsgd scales) is fine
    assert check_dtype_flow(leak, f32_allowance_bytes=4096 * 4).ok

    wide = f"""
  %cp = f64[1,64]{{1,0}} collective-permute(f64[1,64] %a), {_SRC % 'core/gossip.py'}
  %cp2 = u32[1,64]{{1,0}} collective-permute(u32[1,64] %b), {_SRC % 'core/gossip.py'}
"""
    rep = check_dtype_flow(wide)
    assert any("f64" in v for v in rep.violations)

    # vacuous pass guard: collectives present but none packed
    allf = f"""
  %cp = f32[1,64]{{1,0}} collective-permute(f32[1,64] %a), {_SRC % 'core/gossip.py'}
"""
    rep = check_dtype_flow(allf, f32_allowance_bytes=10**6)
    assert any("not actually in the compiled program" in v
               for v in rep.violations)


# ---------------------------------------------------------------------------
# Donation + retrace probes (1-device, tier-1 safe).
# ---------------------------------------------------------------------------

N, D, M, B = 4, 16, 32, 3


def _loss_fn(params, batch):
    f, l = batch
    f, l = jnp.atleast_2d(f), jnp.atleast_1d(l)
    logits = f @ params["w"] + params["b"]
    return jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits)))


def _problem(seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=D)
    f = rng.normal(size=(N, M, D)).astype(np.float32)
    l = (f @ w_true > 0).astype(np.float32)
    params0 = {"w": jnp.zeros(D), "b": jnp.zeros(())}
    return params0, minibatch_source(f, l, B)


def test_donation_hlo_report_known_bad():
    # a jit WITHOUT donate_argnums lowers no aliasing marks: the static
    # leg must flag every leaf as un-donated
    params0, _ = _problem()

    @jax.jit
    def step(state):
        return jax.tree_util.tree_map(lambda x: x + 1.0, state)

    hlo = step.lower(params0).as_text()
    rep = donation_hlo_report(hlo, len(jax.tree_util.tree_leaves(params0)))
    assert not rep.ok
    assert "un-donated leaves" in rep.violations[0]
    assert donation_hlo_report(hlo, 0).ok  # nothing carried, nothing owed


def test_retrace_known_good_and_bad():
    params0, source = _problem()
    algo = build(ExperimentSpec(algo="porter-gc", n_agents=N,
                                topology="ring", compressor="top_k",
                                frac=0.25, eta=0.1, tau=5.0), _loss_fn)
    rep = check_retrace(algo, source, params0, chunks=(2, 3), period=1)
    assert rep.ok, rep.violations
    assert all(v in (None, 1) for v in rep.executables.values())

    class StaticStartRunner:
        """Known-bad: the round offset is a static argnum, so every new
        start position compiles a fresh executable -- exactly the
        specialization the retrace rule exists to catch."""

        def __init__(self, algo, source, chunk):
            def run(state, key, start):
                def body(st, t):
                    kb, ks = jax.random.split(jax.random.fold_in(key, t))
                    st, m = algo.step(st, source(kb, t), ks)
                    return st, m

                st, metrics = jax.lax.scan(
                    body, state,
                    start + jnp.arange(chunk, dtype=jnp.int32))
                return st, key, metrics

            self.jitted = jax.jit(run, static_argnums=2)

        def __call__(self, state, key, start):
            return self.jitted(state, key, start)

        def cache_size(self):
            getter = getattr(self.jitted, "_cache_size", None)
            return getter() if getter is not None else None

    rep = check_retrace(algo, source, params0, chunks=(2,), period=3,
                        runner_factory=StaticStartRunner)
    assert not rep.ok
    assert "retracing" in rep.violations[0]


# ---------------------------------------------------------------------------
# AST lint fixtures.
# ---------------------------------------------------------------------------

def _lint(src, **kw):
    return ast_rules.lint_source(textwrap.dedent(src), "fix.py", **kw)


def test_lint_host_escape_in_step():
    findings = _lint("""
        import random
        import time

        def porter_step(state, batch, key):
            if random.random() > 0.5:      # host RNG inside a step
                time.sleep(0.1)            # host clock inside a step
            return float(state), state.item()
    """)
    assert len(findings) == 4, findings
    assert all(f.rule == "host-escape-in-step" for f in findings)


def test_lint_step_scope_clean_and_suppression():
    assert not _lint("""
        import jax.numpy as jnp

        def step(state, batch, key):
            return state + jnp.mean(batch), {}
    """)
    # the token silences exactly the marked line
    assert not _lint("""
        import time

        def my_step(state, batch, key):
            t0 = time.perf_counter()  # analysis: ok -- wall-clock harness
            return state, t0
    """)
    # `from jax import random` must NOT trip the stdlib-random rule
    assert not _lint("""
        from jax import random

        def step(state, batch, key):
            return state + random.normal(key, state.shape), {}
    """)


def test_lint_host_sync():
    findings = _lint("""
        import jax.numpy as jnp

        def eval_cb(params):
            return float(jnp.mean(params)), bool(jnp.all(params > 0))
    """, host_sync=True)
    assert len(findings) == 2
    assert all(f.rule == "host-sync-eval" for f in findings)
    # the numpy-boundary idiom is the sanctioned fix
    assert not _lint("""
        import numpy as np

        def eval_cb(params):
            return float(np.mean(np.asarray(params)))
    """, host_sync=True)


def test_lint_jax_free():
    findings = _lint("""
        import jax
    """, jax_free=True)
    assert findings and findings[0].rule == "jax-free-modules"
    assert not _lint("import os\n", jax_free=True)


def test_lint_finding_format():
    f = ast_rules.LintFinding(rule="host-escape", path="a.py", line=3,
                              message="m")
    assert str(f) == "a.py:3: [host-escape] m"
    assert f.to_json()["rule"] == "host-escape"


def test_tables_complete():
    assert ast_rules.check_tables() == []
