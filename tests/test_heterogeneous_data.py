"""PORTER under data heterogeneity (Assumption 4's regime): agents hold
disjoint label-skewed shards; gradient tracking must still find the global
stationary point while plain DSGD drifts more."""

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (PorterConfig, average_params, make_compressor,
                        make_mixer, make_porter_step, make_topology,
                        porter_init)
from repro.core import baselines as BL
from repro.core.gossip import make_dense_mixer
from repro.data import a9a_like

N = 8


def _skewed_shards(seed=0):
    """Sort by label so each agent sees a heavily label-skewed shard."""
    x, y = a9a_like(8000, 40, seed=seed)
    order = np.argsort(y + 0.01 * np.random.default_rng(seed).random(len(y)))
    x, y = x[order], y[order]
    m = len(x) // N
    xs = x[: m * N].reshape(N, m, 40)
    ys = y[: m * N].reshape(N, m)
    return xs, ys


def loss_fn(params, batch):
    f, l = batch
    f, l = jnp.atleast_2d(f), jnp.atleast_1d(l)
    logits = f @ params["w"] + params["b"]
    return jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits))) \
        + 0.1 * jnp.sum(params["w"] ** 2 / (1 + params["w"] ** 2))


def _iter(xs, ys, batch, seed=0):
    rng = np.random.default_rng(seed)
    m = xs.shape[1]
    while True:
        idx = rng.integers(0, m, size=(N, batch))
        xb = np.take_along_axis(xs, idx[..., None], axis=1)
        yb = np.take_along_axis(ys, idx, axis=1)
        yield jnp.asarray(xb), jnp.asarray(yb)


def test_porter_converges_on_heterogeneous_shards():
    xs, ys = _skewed_shards()
    top = make_topology("erdos_renyi", N, weights="best_constant", p=0.8,
                        seed=2)
    comp = make_compressor("top_k", frac=0.1)
    gamma = 0.4 * (1 - top.alpha) * 0.1
    cfg = PorterConfig(eta=0.05, gamma=gamma, tau=2.0, variant="gc")
    state = porter_init({"w": jnp.zeros(40), "b": jnp.zeros(())}, N, w=top.w)
    step = jax.jit(make_porter_step(cfg, loss_fn, make_mixer(top, "dense"),
                                    comp))
    it = _iter(xs, ys, batch=8)
    key = jax.random.PRNGKey(0)
    for _ in range(400):
        key, k = jax.random.split(key)
        state, metrics = step(state, next(it), k)
    flat = (jnp.asarray(xs.reshape(-1, 40)), jnp.asarray(ys.reshape(-1)))
    g = jax.grad(loss_fn)(average_params(state.x), flat)
    gn = float(jnp.sqrt(sum(jnp.sum(v ** 2)
                            for v in jax.tree_util.tree_leaves(g))))
    # gradient tracking handles heterogeneity: global stationary point found
    assert gn < 0.12, f"PORTER drifted under heterogeneity: |g|={gn}"
    assert np.isfinite(float(metrics["loss"]))
