"""Distributed-mode equivalence: the ring / packed shard_map gossip executors
and the shard-local compressor must agree with the dense single-device math.

These run in a subprocess with --xla_force_host_platform_device_count=8 so the
main pytest process keeps its single CPU device (see launch/dryrun.py notes).
"""

import subprocess
import sys
import textwrap

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.core import make_topology, make_compressor
    from repro.core.gossip import (make_dense_mixer, make_ring_mixer,
                                   make_packed_mixer)

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    top = make_topology("ring", 4, weights="metropolis")
    key = jax.random.PRNGKey(0)
    # agent-stacked tree, second leaf model-sharded on its last dim
    tree = {"a": jax.random.normal(key, (4, 6, 8)),
            "b": jax.random.normal(key, (4, 10))}
    specs = {"a": P("data", None, "model"), "b": P("data", None)}
    sh = {k: NamedSharding(mesh, specs[k]) for k in specs}
    tree_sharded = {k: jax.device_put(tree[k], sh[k]) for k in tree}

    dense = make_dense_mixer(top.w)(tree)

    ring = make_ring_mixer(top.w, mesh, ("data",), leaf_specs=specs)
    out_ring = jax.jit(ring)(tree_sharded)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out_ring[k]),
                                   np.asarray(dense[k]), rtol=1e-5,
                                   atol=1e-6)
    print("ring-ok")

    # packed gossip is exact when the input is already block-sparse:
    # compress per (agent row x model shard) = per shard-local block
    comp = make_compressor("block_top_k", frac=0.25, block=4)
    def shard_local(t):
        from repro.compat import shard_map
        f = shard_map(lambda tt: jax.tree_util.tree_map(
            lambda l: comp(None, l), tt), mesh=mesh, in_specs=(specs,),
            out_specs=specs, check_vma=False)
        return f(t)
    sparse = jax.jit(shard_local)(tree_sharded)
    dense_on_sparse = make_dense_mixer(top.w)(
        jax.tree_util.tree_map(np.asarray, sparse))
    packed = make_packed_mixer(top.w, mesh, frac=0.25, agent_axes=("data",),
                               leaf_specs=specs)
    out_packed = jax.jit(packed)(sparse)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out_packed[k]),
                                   np.asarray(dense_on_sparse[k]), rtol=1e-4,
                                   atol=1e-5)
    print("packed-ok")

    # n=2 ring: both ppermute shifts deliver the same agent; the executor
    # must apply the neighbor once (regression: w_self*x + 2*w01*neighbor)
    mesh2 = jax.make_mesh((2,), ("data",))
    top2 = make_topology("ring", 2, weights="metropolis")
    tree2 = {"a": jax.random.normal(key, (2, 5, 3)),
             "b": jax.random.normal(key, (2, 7))}
    specs2 = {"a": P("data", None, None), "b": P("data", None)}
    sh2 = {k: NamedSharding(mesh2, specs2[k]) for k in specs2}
    tree2_sharded = {k: jax.device_put(tree2[k], sh2[k]) for k in tree2}
    dense2 = make_dense_mixer(top2.w)(tree2)
    ring2 = make_ring_mixer(top2.w, mesh2, ("data",), leaf_specs=specs2)
    out2 = jax.jit(ring2)(tree2_sharded)
    for k in tree2:
        np.testing.assert_allclose(np.asarray(out2[k]),
                                   np.asarray(dense2[k]), rtol=1e-6,
                                   atol=1e-7)
    print("ring2-ok")

    # multi-pod ring seam: agent grid ('pod','data') on a (2,2,2) mesh
    mesh3 = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
    top4 = make_topology("ring", 4, weights="metropolis")
    specs3 = {"a": P(("pod", "data"), None, "model"),
              "b": P(("pod", "data"), None)}
    sh3 = {k: NamedSharding(mesh3, specs3[k]) for k in specs3}
    tree3 = {k: jax.device_put(tree[k], sh3[k]) for k in tree}
    ring3 = make_ring_mixer(top4.w, mesh3, ("pod", "data"),
                            leaf_specs=specs3)
    out3 = jax.jit(ring3)(tree3)
    for k in tree:
        np.testing.assert_allclose(np.asarray(out3[k]),
                                   np.asarray(dense[k]), rtol=1e-5,
                                   atol=1e-6)
    print("multipod-ring-ok")

    # time-varying ring: a weight-rotating banded schedule keeps the
    # two-ppermute structure and only traces the band weights -- each
    # round must match the dense product with that round's W_t
    from repro.core.mixing import rotating_schedule
    sched = rotating_schedule(["ring/metropolis", "ring/lazy"], 4)
    ring_t = make_ring_mixer(sched.ws, mesh, ("data",), leaf_specs=specs)
    assert ring_t.time_varying
    jit_ring_t = jax.jit(ring_t)
    for t in range(3):
        want = make_dense_mixer(sched.ws[t % 2])(tree)
        got = jit_ring_t(tree_sharded, jnp.asarray(t, jnp.int32))
        for k in tree:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(want[k]), rtol=1e-5,
                                       atol=1e-6)
    print("ring-schedule-ok")

    # time-varying packed: the round's W enters the shard_map through the
    # same replicated slot; payload stays (values, indices) only
    packed_t = make_packed_mixer(sched.ws, mesh, frac=0.25,
                                 agent_axes=("data",), leaf_specs=specs)
    jit_packed_t = jax.jit(packed_t)
    for t in range(3):
        want = make_dense_mixer(sched.ws[t % 2])(
            jax.tree_util.tree_map(np.asarray, sparse))
        got = jit_packed_t(sparse, jnp.asarray(t, jnp.int32))
        for k in tree:
            np.testing.assert_allclose(np.asarray(got[k]),
                                       np.asarray(want[k]), rtol=1e-4,
                                       atol=1e-5)
    print("packed-schedule-ok")
""")


def test_distributed_gossip_equivalence():
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=600,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"})
    assert res.returncode == 0, res.stderr[-3000:]
    for marker in ("ring-ok", "packed-ok", "ring2-ok", "multipod-ring-ok",
                   "ring-schedule-ok", "packed-schedule-ok"):
        assert marker in res.stdout, (marker, res.stdout, res.stderr[-2000:])
