"""Hypothesis contracts for the two fleet-PR operators.

* Clip21 EF-clip (:func:`repro.core.clip21.clip21_update`): each
  application contracts the residual r = g_raw - g_est in global norm --
  ``||r'|| <= ||r||`` AND the sharper Clip21 ingredient
  ``||r'|| = max(||r|| - tau, 0)`` (piecewise clip moves the estimate
  exactly tau along the residual until it locks on); tau = inf is the
  bitwise identity on the raw gradient.
* The sign compressor (scaled-sign, arXiv 2607.01755): Definition 3 holds
  with the *exact* data-dependent factor
  ``||C(x) - x||^2 = (1 - ||x||_1^2 / (d ||x||_2^2)) ||x||^2``,
  whose rho floor is 1/d (Cauchy-Schwarz) -- sharper than the registry's
  advertised rho = 0.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.core.clip21 import clip21_update
from repro.core.clipping import tree_global_norm
from repro.core.compression import make_compressor


def _rand_tree(seed, d1, d2, scale):
    k = jax.random.PRNGKey(seed)
    ka, kb, kc = jax.random.split(k, 3)
    return {"w": scale * jax.random.normal(ka, (d1,)),
            "b": scale * jax.random.normal(kb, (d2,)),
            "s": scale * jax.random.normal(kc, ())}


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 64), st.integers(1, 8),
       st.floats(0.05, 20.0), st.floats(0.01, 100.0))
@settings(max_examples=80, deadline=None)
def test_clip21_residual_contraction(seed, d1, d2, tau, scale):
    g_est = _rand_tree(seed, d1, d2, scale)
    g_raw = _rand_tree(seed + 1, d1, d2, scale)
    r0 = float(tree_global_norm(jax.tree_util.tree_map(
        lambda a, b: a - b, g_raw, g_est)))
    g_new = clip21_update(g_est, g_raw, tau)
    r1 = float(tree_global_norm(jax.tree_util.tree_map(
        lambda a, b: a - b, g_raw, g_new)))
    assert r1 <= r0 * (1 + 1e-5) + 1e-6
    want = max(r0 - tau, 0.0)
    assert abs(r1 - want) <= 1e-4 * max(r0, 1.0)


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 64), st.floats(0.1, 10.0))
@settings(max_examples=30, deadline=None)
def test_clip21_infinite_tau_is_bitwise_identity(seed, d1, scale):
    g_est = _rand_tree(seed, d1, 3, scale)
    g_raw = _rand_tree(seed + 7, d1, 3, scale)
    g_new = clip21_update(g_est, g_raw, float("inf"))
    for a, b in zip(jax.tree_util.tree_leaves(g_new),
                    jax.tree_util.tree_leaves(g_raw)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(0, 2 ** 31 - 1), st.integers(1, 64), st.integers(1, 8),
       st.floats(0.05, 5.0))
@settings(max_examples=40, deadline=None)
def test_clip21_fixed_point(seed, d1, d2, tau):
    """Once locked on (g_est == g_raw), the update is idempotent."""
    g_raw = _rand_tree(seed, d1, d2, 1.0)
    g_new = clip21_update(g_raw, g_raw, tau)
    for a, b in zip(jax.tree_util.tree_leaves(g_new),
                    jax.tree_util.tree_leaves(g_raw)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


@given(st.integers(4, 4000), st.integers(0, 2 ** 31 - 1),
       st.floats(0.01, 50.0))
@settings(max_examples=80, deadline=None)
def test_sign_compressor_exact_contract(d, seed, scale):
    comp = make_compressor("sign")
    assert comp.deterministic
    x = scale * jax.random.normal(jax.random.PRNGKey(seed), (d,))
    cx = comp(None, x)
    # C(x) = (||x||_1 / d) sign(x): one magnitude, d signs
    assert len(np.unique(np.abs(np.asarray(cx)))) <= 2  # {mag} or {0, mag}
    n2 = float(jnp.sum(x ** 2))
    n1 = float(jnp.sum(jnp.abs(x)))
    err = float(jnp.sum((cx - x) ** 2))
    want = (1.0 - n1 ** 2 / (d * n2)) * n2
    np.testing.assert_allclose(err, want, rtol=1e-4, atol=1e-5 * n2)
    # Definition 3 with the 1/d floor (Cauchy-Schwarz: ||x||_1^2 >= ||x||_2^2)
    assert err <= (1.0 - 1.0 / d) * n2 * (1 + 1e-5)
