"""Chunked runtime contract (repro.launch.runtime).

* Parity: with an identical key stream, the scan-fused chunk runner must
  reproduce the per-step Python loop -- same final state, same metrics
  trajectory (allclose, atol 1e-5) -- for EVERY registered algorithm,
  including uneven tail chunks.
* Donation: the compiled runner actually donates the state input (buffers
  aliased in the executable, the argument invalidated after the call).
* One executable per chunk size: the chunk offset is traced, not static.
* BatchSource shapes for the model-zoo families + the on-device
  minibatch source.
* The checkpoint-manifest privacy accounting used by train.py --resume.
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec, build, list_algorithms
from repro.data import batch_source, minibatch_source
from repro.launch.runtime import make_runner, run_chunked

N, D, M, B = 4, 16, 32, 3
STEPS, CHUNK = 7, 3  # deliberately uneven: chunks of 3, 3, 1


def _loss_fn(params, batch):
    f, l = batch
    f, l = jnp.atleast_2d(f), jnp.atleast_1d(l)
    logits = f @ params["w"] + params["b"]
    return jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits)))


def _problem(seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=D)
    f = rng.normal(size=(N, M, D)).astype(np.float32)
    l = (f @ w_true > 0).astype(np.float32)
    params0 = {"w": jnp.zeros(D), "b": jnp.zeros(())}
    return params0, minibatch_source(f, l, B)


def _spec(name):
    kw = dict(algo=name, n_agents=N, topology="ring", compressor="top_k",
              frac=0.25, eta=0.1, tau=5.0,
              sigma_p=0.01 if name in ("porter-dp", "dp-sgd", "soteriafl")
              else 0.0)
    return ExperimentSpec(**kw)


def _per_step_loop(algo, source, state, key, steps, start=0):
    """The per-step loop, with the runtime's exact key contract: round t's
    keys are split(fold_in(base, t)) -- a pure function of the absolute
    index, so chunking and restarts cannot change the stream."""
    step = jax.jit(algo.step)
    traj = []
    for t in range(start, start + steps):
        kb, ks = jax.random.split(jax.random.fold_in(key, t))
        state, m = step(state, source(kb, jnp.asarray(t, jnp.int32)), ks)
        traj.append(m)
    return state, traj


@pytest.mark.parametrize("name", sorted(list_algorithms()))
def test_chunked_runner_matches_per_step_loop(name):
    params0, source = _problem()
    algo = build(_spec(name), _loss_fn)

    ref_state, ref_traj = _per_step_loop(
        algo, source, algo.init(params0), jax.random.PRNGKey(7), STEPS)

    chunks = []
    state, _ = run_chunked(
        algo, source, algo.init(params0), jax.random.PRNGKey(7), STEPS,
        chunk=CHUNK, on_chunk=lambda t0, t1, st, m: chunks.append(m))

    assert sum(len(next(iter(m.values()))) for m in chunks) == STEPS
    for k in ref_traj[0]:
        got = np.concatenate([np.atleast_1d(np.asarray(m[k]))
                              for m in chunks])
        want = np.asarray([r[k] for r in ref_traj])
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5,
                                   err_msg=f"metric {k!r} diverged")
    for ref_leaf, got_leaf in zip(jax.tree_util.tree_leaves(ref_state),
                                  jax.tree_util.tree_leaves(state)):
        np.testing.assert_allclose(np.asarray(got_leaf),
                                   np.asarray(ref_leaf),
                                   atol=1e-5, rtol=1e-5)


def test_resume_continues_the_key_stream():
    """A restarted leg (fresh base-key object, later start) must continue
    the uninterrupted stream -- NOT replay the keys (and hence DP noise)
    rounds 0..k already consumed."""
    params0, source = _problem()
    algo = build(_spec("porter-dp"), _loss_fn)

    ref_state, ref_traj = _per_step_loop(
        algo, source, algo.init(params0), jax.random.PRNGKey(7), 8)

    runner = make_runner(algo, source, 4)
    state, _, m_a = runner(algo.init(params0), jax.random.PRNGKey(7), 0)
    # simulate a process restart: same seed, new key object, start=4
    state, _, m_b = runner(state, jax.random.PRNGKey(7), 4)
    # leg 2 must differ from leg 1 (no replay) and match the reference
    assert not np.allclose(np.asarray(m_a["loss"]), np.asarray(m_b["loss"]))
    np.testing.assert_allclose(
        np.concatenate([np.asarray(m_a["loss"]), np.asarray(m_b["loss"])]),
        np.asarray([r["loss"] for r in ref_traj]), atol=1e-5, rtol=1e-5)
    for ref_leaf, got_leaf in zip(jax.tree_util.tree_leaves(ref_state),
                                  jax.tree_util.tree_leaves(state)):
        np.testing.assert_allclose(np.asarray(got_leaf),
                                   np.asarray(ref_leaf),
                                   atol=1e-5, rtol=1e-5)


def test_runner_donates_state():
    from repro.analysis.hlo import donation_hlo_report

    params0, source = _problem()
    algo = build(_spec("porter-gc"), _loss_fn)
    runner = make_runner(algo, source, CHUNK)

    # the compiled program aliases every state leaf input to an output
    state_shapes = jax.eval_shape(lambda p: algo.init(p), params0)
    hlo = runner.lower(state_shapes).as_text()
    report = donation_hlo_report(
        hlo, len(jax.tree_util.tree_leaves(state_shapes)))
    assert report.ok, report.violations

    # and the call-site argument is actually consumed
    state = algo.init(params0)
    new_state, _, _ = runner(state, jax.random.PRNGKey(0), 0)
    # init aliases leaves (q_x is x, ...), so probe via the returned state
    # of a second call: its input is all-distinct buffers
    final, _, _ = runner(new_state, jax.random.PRNGKey(1), CHUNK)
    assert all(leaf.is_deleted()
               for leaf in jax.tree_util.tree_leaves(new_state))
    assert not any(leaf.is_deleted()
                   for leaf in jax.tree_util.tree_leaves(final))


def test_runner_donate_false_keeps_state():
    params0, source = _problem()
    algo = build(_spec("porter-gc"), _loss_fn)
    runner = make_runner(algo, source, CHUNK, donate=False)
    state = algo.init(params0)
    runner(state, jax.random.PRNGKey(0), 0)
    assert not any(leaf.is_deleted()
                   for leaf in jax.tree_util.tree_leaves(state))


def test_one_executable_per_chunk_size():
    params0, source = _problem()
    algo = build(_spec("choco"), _loss_fn)
    runner = make_runner(algo, source, CHUNK)
    state = algo.init(params0)
    key = jax.random.PRNGKey(0)
    for start in (0, CHUNK, 2 * CHUNK):  # different offsets, one program
        state, key, _ = runner(state, key, start)
    assert runner.cache_size() in (None, 1)


def test_donation_never_consumes_caller_params():
    """Server/client inits used to adopt the caller's params buffers into
    state.x; a donated chunk then deleted params0 out from under the next
    run (benchmarks/run.py reuses one params0 across algorithms)."""
    params0, source = _problem()
    for name in ("soteriafl", "dp-sgd", "porter-gc", "choco", "dsgd"):
        algo = build(_spec(name), _loss_fn)
        make_runner(algo, source, 2)(algo.init(params0),
                                     jax.random.PRNGKey(0))
        assert not any(l.is_deleted()
                       for l in jax.tree_util.tree_leaves(params0)), name


def test_aliased_init_is_donatable():
    """porter_init aliases x/q_x/m_x and the zero buffers; the runner must
    still be callable with donation on the *initial* state."""
    params0, source = _problem()
    algo = build(_spec("porter-gc"), _loss_fn)
    state = algo.init(params0)
    leaves = jax.tree_util.tree_leaves(state)
    assert len({id(l) for l in leaves}) < len(leaves)  # init does alias
    out, _, _ = make_runner(algo, source, 2)(state, jax.random.PRNGKey(0))
    assert np.isfinite(float(jax.tree_util.tree_leaves(out)[0].sum()))


# ---------------------------------------------------------------------------
# batch sources
# ---------------------------------------------------------------------------

def test_minibatch_source_on_device_sampling():
    params0, source = _problem()
    key = jax.random.PRNGKey(3)
    xb, yb = source(key, jnp.asarray(0))
    assert xb.shape == (N, B, D) and yb.shape == (N, B)
    # deterministic in the key
    xb2, _ = source(key, jnp.asarray(9))
    np.testing.assert_array_equal(np.asarray(xb), np.asarray(xb2))
    # jit-traceable (the whole point: it runs inside the compiled chunk)
    jitted = jax.jit(source)
    xb3, _ = jitted(key, jnp.asarray(0))
    np.testing.assert_array_equal(np.asarray(xb), np.asarray(xb3))


@pytest.mark.parametrize("arch,keys", [
    ("tinyllama-1.1b", {"tokens"}),
    ("paligemma-3b", {"tokens", "patches"}),
    ("seamless-m4t-medium", {"frames", "tokens"}),
])
def test_batch_source_families(arch, keys):
    """Family-aware synthesis matches the layout train.py always fed the
    loss; checked abstractly (eval_shape) so no model compute runs."""
    from repro.configs import get_smoke
    cfg = get_smoke(arch)
    source = batch_source(cfg, n_agents=2, batch=3, seq=32)
    shapes = jax.eval_shape(source, jax.ShapeDtypeStruct((2,), jnp.uint32),
                            jax.ShapeDtypeStruct((), jnp.int32))
    assert set(shapes) == keys
    for k, s in shapes.items():
        assert s.shape[:2] == (2, 3), (k, s.shape)
    if "patches" in shapes:
        assert shapes["tokens"].shape[2] == 32 - cfg.n_prefix


# ---------------------------------------------------------------------------
# privacy accounting across resume (train.py + checkpoint manifest)
# ---------------------------------------------------------------------------

def _train_args(steps=40, tau=1.0, m=512, eps=0.1, delta=1e-3):
    return argparse.Namespace(steps=steps, tau=tau, local_samples=m,
                              epsilon=eps, delta=delta)


def test_manifest_extra_roundtrip(tmp_path):
    from repro.core.porter import porter_init
    from repro.launch.checkpoint import (read_manifest, restore_state,
                                         save_state)
    state = porter_init({"w": jnp.ones(5)}, n_agents=2)
    extra = {"rounds_executed": 12, "sigma_p": 0.25}
    save_state(tmp_path, state, step=12, extra=extra)
    man = read_manifest(tmp_path)
    assert man["extra"] == extra and man["step"] == 12
    restored = restore_state(tmp_path, like=state)  # extra is inert
    np.testing.assert_array_equal(np.asarray(restored.x["w"]),
                                  np.asarray(state.x["w"]))


def test_resolve_privacy_fresh_vs_resume():
    from repro.api import algorithm_info
    from repro.core import calibrate_sigma
    from repro.launch.train import resolve_privacy

    info = algorithm_info("porter-dp")
    args = _train_args()
    sigma, acct, prev = resolve_privacy(info, args, 0, {})
    assert prev == 0 and acct.steps == 0
    assert sigma == pytest.approx(calibrate_sigma(
        args.tau, args.steps, args.local_samples, args.epsilon, args.delta))

    # resume: sigma pinned to the manifest, accountant pre-advanced by the
    # rounds actually executed -- NOT re-calibrated for the full horizon
    extra = {"rounds_executed": 10, "sigma_p": 0.5}
    sigma_r, acct_r, prev_r = resolve_privacy(info, args, 10, extra)
    assert sigma_r == 0.5 and prev_r == 10 and acct_r.steps == 10
    eps_10 = acct_r.epsilon(args.delta)
    acct_r.step(30)  # the remaining rounds of the 40-step target
    assert acct_r.epsilon(args.delta) > eps_10  # eps grows with spend

    # non-dp algorithms skip accounting but keep the round count
    info_gc = algorithm_info("porter-gc")
    sigma_gc, acct_gc, prev_gc = resolve_privacy(info_gc, args, 7,
                                                 {"rounds_executed": 7})
    assert sigma_gc == 0.0 and acct_gc is None and prev_gc == 7

    # changing tau or local_samples across a resume mixes rounds run under
    # different clipping/noise regimes: refuse, don't mis-state eps
    extra_tau = {"rounds_executed": 10, "sigma_p": 0.5, "tau": 2.0,
                 "local_samples": args.local_samples}
    with pytest.raises(ValueError, match="tau"):
        resolve_privacy(info, args, 10, extra_tau)
    extra_m = {"rounds_executed": 10, "sigma_p": 0.5, "tau": args.tau,
               "local_samples": 9999}
    with pytest.raises(ValueError, match="local-samples"):
        resolve_privacy(info, args, 10, extra_m)

    # a DP resume from a checkpoint with no sigma_p metadata cannot be
    # accounted for -- refuse rather than re-calibrate over spent rounds
    with pytest.raises(ValueError, match="no sigma_p"):
        resolve_privacy(info, args, 10, {})
