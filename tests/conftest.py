"""Shared test config.

``hypothesis`` is an optional dependency (the property sweeps use it); on
containers without it the affected modules are skipped at collection instead
of aborting the whole run with an ImportError.  The skip-list is DERIVED by
scanning the test modules for a hypothesis import -- a hand-maintained list
let a new property file be collected-then-ImportError'd (or silently
missed) whenever someone forgot to update it.

Collection floor: a full-suite run that collects fewer tests than the
recorded floor fails outright, so the skip-list (or a stray conftest edit)
can never silently hollow out tier-1.  The floor is the known
non-hypothesis item count plus a static AST lower bound for the
hypothesis-gated modules (each ``def test_*`` collects at least one item),
so it needs updating only when non-hypothesis tests are removed on purpose.
"""

import ast
import importlib.util
import re
from pathlib import Path

import pytest

_HERE = Path(__file__).parent
_HYP_IMPORT = re.compile(r"^\s*(?:import\s+hypothesis\b|from\s+hypothesis\b)",
                         re.MULTILINE)
_HAVE_HYPOTHESIS = importlib.util.find_spec("hypothesis") is not None

# tests collected by `pytest -q` in a hypothesis-less container (the
# tier-1 baseline this PR was built against); update when intentionally
# removing tests -- additions only ever raise the real count above it
BASE_FLOOR = 371


def _hypothesis_modules():
    return sorted(p.name for p in _HERE.glob("test_*.py")
                  if _HYP_IMPORT.search(p.read_text()))


collect_ignore = [] if _HAVE_HYPOTHESIS else _hypothesis_modules()


def _static_test_count(names):
    """Lower bound on collected items: one per ``def test_*`` (parametrize
    and @given only ever multiply)."""
    total = 0
    for name in names:
        tree = ast.parse((_HERE / name).read_text())
        total += sum(
            isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef))
            and node.name.startswith("test")
            for node in ast.walk(tree))
    return total


def _is_full_suite_run(config) -> bool:
    """Only enforce the floor when the whole suite was asked for: no -k/-m,
    no --ignore/--deselect/--lf/--sw style deselection, no explicit
    file/node selection (CI jobs run single files too)."""
    if config.getoption("keyword", "") or config.getoption("markexpr", ""):
        return False
    for opt in ("ignore", "ignore_glob", "deselect", "lf", "last_failed",
                "stepwise"):
        if config.getoption(opt, None):
            return False
    for arg in config.invocation_params.args:
        arg = str(arg)
        if not arg.startswith("-") and (arg.endswith(".py") or "::" in arg):
            return False
    return True


def pytest_collection_finish(session):
    config = session.config
    if not _is_full_suite_run(config):
        return
    floor = BASE_FLOOR
    if _HAVE_HYPOTHESIS:
        floor += _static_test_count(_hypothesis_modules())
    n = len(session.items)
    if n < floor:
        raise pytest.UsageError(
            f"collected {n} tests but the tier-1 floor is {floor} "
            f"(hypothesis {'present' if _HAVE_HYPOTHESIS else 'absent'}, "
            f"gated modules: {_hypothesis_modules()}); a skip-list or "
            "collection regression is hollowing out the suite -- fix it, "
            "or lower tests/conftest.py::BASE_FLOOR if tests were removed "
            "on purpose")
