"""Shared test config.

``hypothesis`` is an optional dependency (the property sweeps use it); on
containers without it the affected modules are skipped at collection instead
of aborting the whole run with an ImportError.
"""

import importlib.util

collect_ignore = []
if importlib.util.find_spec("hypothesis") is None:
    collect_ignore += [
        "test_clipping_mixing_privacy.py",
        "test_compression.py",
        "test_kernel_rwkv6.py",
        "test_kernel_ssd.py",
        "test_kernels.py",
        "test_porter_properties.py",
    ]
