"""Checkpoint round-trip: save -> restore is exact, latest-step discovery
works, structure/shape mismatches are caught, and the generalized layout
round-trips every registered algorithm's state (not just PorterState)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec, build
from repro.core import (PorterConfig, make_compressor, make_mixer,
                        make_porter_step, make_topology, porter_init)
from repro.launch.checkpoint import latest_step, restore_state, save_state


def _state(n=4, seed=0):
    params = {"w": jax.random.normal(jax.random.PRNGKey(seed), (5, 3)),
              "b": jnp.zeros(3)}
    top = make_topology("ring", n)
    return porter_init(params, n, w=top.w), top


def test_roundtrip_exact(tmp_path):
    state, top = _state()
    # run a couple of steps so buffers are non-trivial
    def loss(p, batch):
        return jnp.mean((batch[0] @ p["w"] + p["b"]) ** 2)
    cfg = PorterConfig(eta=0.05, gamma=0.1, tau=1.0, variant="gc")
    step = jax.jit(make_porter_step(cfg, loss, make_mixer(top, "dense"),
                                    make_compressor("top_k", frac=0.3)))
    key = jax.random.PRNGKey(1)
    for _ in range(3):
        key, kb, ks = jax.random.split(key, 3)
        state, _ = step(state, (jax.random.normal(kb, (4, 2, 5)),), ks)

    path = save_state(str(tmp_path), state)
    assert latest_step(str(tmp_path)) == 3
    restored = restore_state(str(tmp_path), like=state)
    for name in ("x", "v", "q_x", "q_v", "g_prev", "m_x", "m_v"):
        a = getattr(state, name)
        b = getattr(restored, name)
        for la, lb in zip(jax.tree_util.tree_leaves(a),
                          jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    assert int(restored.step) == 3

    # training resumes bitwise-identically from the restored state
    key2 = jax.random.PRNGKey(7)
    s1, _ = step(state, (jax.random.normal(key2, (4, 2, 5)),), key2)
    s2, _ = step(restored, (jax.random.normal(key2, (4, 2, 5)),), key2)
    np.testing.assert_array_equal(np.asarray(s1.x["w"]),
                                  np.asarray(s2.x["w"]))


def test_multiple_steps_latest(tmp_path):
    state, _ = _state()
    save_state(str(tmp_path), state, step=1)
    save_state(str(tmp_path), state, step=20)
    save_state(str(tmp_path), state, step=5)
    assert latest_step(str(tmp_path)) == 20
    restored = restore_state(str(tmp_path), like=state, step=5)
    assert int(restored.step) == 5


def test_shape_mismatch_rejected(tmp_path):
    state, _ = _state()
    save_state(str(tmp_path), state)
    other, _ = _state(n=3)
    with pytest.raises(ValueError):
        restore_state(str(tmp_path), like=other)


def test_missing_dir(tmp_path):
    state, _ = _state()
    with pytest.raises(FileNotFoundError):
        restore_state(str(tmp_path / "nope"), like=state)


# ---------------------------------------------------------------------------
# generalized layout: non-PORTER states through the same two functions
# ---------------------------------------------------------------------------

def _tree_equal(a, b):
    for la, lb in zip(jax.tree_util.tree_leaves(a),
                      jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _loss(p, batch):
    f = batch[0]
    return jnp.mean((f @ p["w"] + p["b"]) ** 2)


def _trained_state(name, n=4, steps=3, seed=0):
    spec = ExperimentSpec(algo=name, n_agents=n, topology="ring",
                          compressor="top_k", frac=0.3, eta=0.05, tau=5.0)
    algo = build(spec, _loss)
    params = {"w": jax.random.normal(jax.random.PRNGKey(seed), (5, 3)),
              "b": jnp.zeros(3)}
    state = algo.init(params)
    step = jax.jit(algo.step)
    key = jax.random.PRNGKey(1)
    for _ in range(steps):
        key, kb, ks = jax.random.split(key, 3)
        state, _ = step(state, (jax.random.normal(kb, (n, 2, 5)),), ks)
    return algo, state


@pytest.mark.parametrize("name", ["choco", "soteriafl", "porter-adam"])
def test_roundtrip_non_porter_states(tmp_path, name):
    algo, state = _trained_state(name)
    save_state(str(tmp_path), state)
    assert latest_step(str(tmp_path)) == 3
    restored = restore_state(str(tmp_path), like=state)
    assert isinstance(restored, algo.state_cls)
    for field in state._fields:
        _tree_equal(getattr(state, field), getattr(restored, field))

    # training resumes bitwise-identically from the restored state
    step = jax.jit(algo.step)
    kb = jax.random.PRNGKey(7)
    batch = (jax.random.normal(kb, (4, 2, 5)),)
    s1, _ = step(state, batch, kb)
    s2, _ = step(restored, batch, kb)
    _tree_equal(s1, s2)


def test_state_class_mismatch_rejected(tmp_path):
    _, choco = _trained_state("choco")
    _, soteria = _trained_state("soteriafl")
    save_state(str(tmp_path), choco)
    with pytest.raises(ValueError, match="ChocoState"):
        restore_state(str(tmp_path), like=soteria)


def test_roundtrip_bf16_planes(tmp_path):
    """Mixed-precision state survives the npz round trip bit-exactly: bf16
    planes are stored as their u16 bit pattern (numpy serializes ml_dtypes
    arrays as raw void records np.load cannot cast back) and viewed back
    through the reference leaf's dtype on restore."""
    spec = ExperimentSpec(algo="porter-gc", n_agents=4, topology="ring",
                          compressor="top_k", frac=0.3, eta=0.05, tau=5.0,
                          plane_dtype="bf16")
    algo = build(spec, _loss)
    params = {"w": jax.random.normal(jax.random.PRNGKey(0), (5, 3)),
              "b": jnp.zeros(3)}
    state = algo.init(params)
    step = jax.jit(algo.step)
    key = jax.random.PRNGKey(1)
    for _ in range(3):
        key, kb, ks = jax.random.split(key, 3)
        state, _ = step(state, (jax.random.normal(kb, (4, 2, 5)),), ks)
    assert state.v["w"].dtype == jnp.bfloat16  # the case under test

    save_state(str(tmp_path), state)
    restored = restore_state(str(tmp_path), like=state)
    for field in state._fields:
        for la, lb in zip(
                jax.tree_util.tree_leaves(getattr(state, field)),
                jax.tree_util.tree_leaves(getattr(restored, field))):
            assert la.dtype == lb.dtype
            np.testing.assert_array_equal(np.asarray(la, jnp.float32),
                                          np.asarray(lb, jnp.float32))

    # training resumes bitwise-identically from the restored state
    kb = jax.random.PRNGKey(7)
    batch = (jax.random.normal(kb, (4, 2, 5)),)
    s1, _ = step(state, batch, kb)
    s2, _ = step(restored, batch, kb)
    _tree_equal(s1.x, s2.x)
    np.testing.assert_array_equal(
        np.asarray(s1.v["w"], jnp.float32),
        np.asarray(s2.v["w"], jnp.float32))
