"""Checkpoint round-trip: save -> restore is exact, latest-step discovery
works, and structure/shape mismatches are caught."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PorterConfig, make_compressor, make_mixer,
                        make_porter_step, make_topology, porter_init)
from repro.launch.checkpoint import latest_step, restore_state, save_state


def _state(n=4, seed=0):
    params = {"w": jax.random.normal(jax.random.PRNGKey(seed), (5, 3)),
              "b": jnp.zeros(3)}
    top = make_topology("ring", n)
    return porter_init(params, n, w=top.w), top


def test_roundtrip_exact(tmp_path):
    state, top = _state()
    # run a couple of steps so buffers are non-trivial
    def loss(p, batch):
        return jnp.mean((batch[0] @ p["w"] + p["b"]) ** 2)
    cfg = PorterConfig(eta=0.05, gamma=0.1, tau=1.0, variant="gc")
    step = jax.jit(make_porter_step(cfg, loss, make_mixer(top, "dense"),
                                    make_compressor("top_k", frac=0.3)))
    key = jax.random.PRNGKey(1)
    for _ in range(3):
        key, kb, ks = jax.random.split(key, 3)
        state, _ = step(state, (jax.random.normal(kb, (4, 2, 5)),), ks)

    path = save_state(str(tmp_path), state)
    assert latest_step(str(tmp_path)) == 3
    restored = restore_state(str(tmp_path), like=state)
    for name in ("x", "v", "q_x", "q_v", "g_prev", "m_x", "m_v"):
        a = getattr(state, name)
        b = getattr(restored, name)
        for la, lb in zip(jax.tree_util.tree_leaves(a),
                          jax.tree_util.tree_leaves(b)):
            np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))
    assert int(restored.step) == 3

    # training resumes bitwise-identically from the restored state
    key2 = jax.random.PRNGKey(7)
    s1, _ = step(state, (jax.random.normal(key2, (4, 2, 5)),), key2)
    s2, _ = step(restored, (jax.random.normal(key2, (4, 2, 5)),), key2)
    np.testing.assert_array_equal(np.asarray(s1.x["w"]),
                                  np.asarray(s2.x["w"]))


def test_multiple_steps_latest(tmp_path):
    state, _ = _state()
    save_state(str(tmp_path), state, step=1)
    save_state(str(tmp_path), state, step=20)
    save_state(str(tmp_path), state, step=5)
    assert latest_step(str(tmp_path)) == 20
    restored = restore_state(str(tmp_path), like=state, step=5)
    assert int(restored.step) == 5


def test_shape_mismatch_rejected(tmp_path):
    state, _ = _state()
    save_state(str(tmp_path), state)
    other, _ = _state(n=3)
    with pytest.raises(ValueError):
        restore_state(str(tmp_path), like=other)


def test_missing_dir(tmp_path):
    state, _ = _state()
    with pytest.raises(FileNotFoundError):
        restore_state(str(tmp_path / "nope"), like=state)
