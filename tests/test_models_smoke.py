"""Per-architecture smoke tests (deliverable (f)): a REDUCED variant of each
assigned architecture family runs one forward/train step on CPU, asserting
output shapes and finiteness; plus decode-vs-forward consistency and the
SSM chunk-vs-recurrent equivalences."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, get_smoke
from repro.models import build_model

B, S = 2, 32
KEY = jax.random.PRNGKey(0)


def make_batch(cfg, b=B, s=S):
    if cfg.family == "vlm":
        return {"tokens": jax.random.randint(KEY, (b, s - cfg.n_prefix), 0,
                                             cfg.vocab),
                "patches": jax.random.normal(KEY, (b, cfg.n_prefix,
                                                   cfg.frontend_dim))}
    if cfg.family == "encdec":
        return {"frames": jax.random.normal(KEY, (b, s, cfg.frontend_dim)),
                "tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab)}
    return {"tokens": jax.random.randint(KEY, (b, s), 0, cfg.vocab)}


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_smoke(arch)
    assert cfg.n_layers <= 8 and cfg.d_model <= 512 and cfg.n_experts <= 4
    bundle = build_model(cfg)
    params, specs = bundle.init(KEY)
    assert jax.tree_util.tree_structure(params) == \
        jax.tree_util.tree_structure(specs)
    batch = make_batch(cfg)

    logits = jax.jit(bundle.forward)(params, batch)
    expect_s = (S - cfg.n_prefix) if cfg.family == "vlm" else S
    if cfg.family == "vlm":
        expect_s = S  # vlm forward returns patch+text positions
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))

    # one SGD train step: loss + grads finite, params update
    loss, grads = jax.jit(jax.value_and_grad(bundle.loss))(params, batch)
    assert np.isfinite(float(loss))
    gn = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                      for g in jax.tree_util.tree_leaves(grads)))
    assert np.isfinite(float(gn)) and float(gn) > 0
    new_params = jax.tree_util.tree_map(lambda p, g: p - 1e-3 * g, params,
                                        grads)
    loss2 = jax.jit(bundle.loss)(new_params, batch)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_prefill_then_decode(arch):
    cfg = get_smoke(arch)
    bundle = build_model(cfg)
    params, _ = bundle.init(KEY)
    batch = make_batch(cfg)
    logits_p, cache = jax.jit(bundle.prefill)(params, batch)
    assert logits_p.shape[-1] == cfg.vocab
    tok = jnp.zeros((B, 1), jnp.int32)
    pos = jnp.asarray(S, jnp.int32)
    logits_d, cache2 = bundle.decode_step(params, cache, tok, pos)
    assert logits_d.shape == (B, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits_d.astype(jnp.float32))))
    # caches keep their structure
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(cache2)


@pytest.mark.parametrize("arch", ["tinyllama-1.1b", "rwkv6-7b", "zamba2-7b",
                                  "minicpm3-4b"])
def test_decode_matches_forward(arch):
    """Greedy decode logits at position t must match the full forward pass
    logits at t (teacher forcing) -- the strongest cache-correctness check."""
    cfg = get_smoke(arch)
    if cfg.window is not None:
        cfg = dataclasses.replace(cfg, window=None)
    cfg = dataclasses.replace(cfg, dtype=jnp.float32)  # tight comparison
    bundle = build_model(cfg)
    params, _ = bundle.init(KEY)
    s = 16 if cfg.family != "rwkv6" else 32  # rwkv chunk = 16
    tokens = jax.random.randint(jax.random.PRNGKey(7), (B, s), 0, cfg.vocab)
    batch = {"tokens": tokens}

    full_logits = bundle.forward(params, batch)        # (B, s, V)

    # prefill on the first s-1 tokens, then decode token s-1
    pre = {"tokens": tokens[:, : s - 1]}
    _, cache = bundle.prefill(params, pre)
    if cfg.family in ("dense", "moe", "vlm", "hybrid"):
        # grow caches to length s so the decode write fits
        def grow(leaf):
            if leaf.ndim >= 3 and leaf.shape[2] == s - 1:
                pad = [(0, 0)] * leaf.ndim
                pad[2] = (0, 1)
                return jnp.pad(leaf, pad)
            return leaf
        cache = jax.tree_util.tree_map(grow, cache)
    logits_d, _ = bundle.decode_step(params, cache, tokens[:, s - 1:s],
                                     jnp.asarray(s - 1, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(full_logits[:, -1]),
                               rtol=2e-3, atol=2e-3)


def test_rwkv_chunk_equals_recurrent():
    from repro.nn.ssm import _rwkv_chunk_scan, rwkv_scan_ref
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    Bh, Sh, H, N = 2, 64, 3, 8
    r, k, v = (jax.random.normal(ks[i], (Bh, Sh, H, N)) for i in range(3))
    logw = -jax.random.uniform(ks[3], (Bh, Sh, H, N), minval=0.01,
                               maxval=4.9)
    u = jax.random.normal(ks[4], (H, N))
    s0 = jax.random.normal(ks[5], (Bh, H, N, N))
    o1, f1 = _rwkv_chunk_scan(r, k, v, logw, u, s0)
    o2, f2 = rwkv_scan_ref(r, k, v, logw, u, s0)
    np.testing.assert_allclose(np.asarray(o1), np.asarray(o2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-4,
                               atol=1e-4)


def test_ssd_chunk_equals_recurrent():
    from repro.nn.ssm import _ssd_chunk_scan, ssd_scan_ref
    ks = jax.random.split(jax.random.PRNGKey(1), 5)
    Bh, Sh, H, P, N = 2, 128, 3, 4, 8
    xh = jax.random.normal(ks[0], (Bh, Sh, H, P))
    bm = jax.random.normal(ks[1], (Bh, Sh, N))
    cm = jax.random.normal(ks[2], (Bh, Sh, N))
    dla = -jax.random.uniform(ks[3], (Bh, Sh, H), minval=0.01, maxval=0.3)
    h0 = jax.random.normal(ks[4], (Bh, H, P, N))
    y1, f1 = _ssd_chunk_scan(xh, bm, cm, dla, h0)
    y2, f2 = ssd_scan_ref(xh, bm, cm, dla, h0)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)
    np.testing.assert_allclose(np.asarray(f1), np.asarray(f2), rtol=1e-4,
                               atol=1e-4)


def test_sliding_window_masks_old_tokens():
    """SWA: token far outside the window must not influence attention."""
    from repro.nn import attention as A
    cfg = A.AttnConfig(d_model=64, n_heads=4, n_kv_heads=2, head_dim=16,
                       window=4)
    p = A.init_attention(jax.random.PRNGKey(0), cfg)
    p = jax.tree_util.tree_map(lambda l: l.value, p,
                               is_leaf=lambda x: hasattr(x, "value"))
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 10, 64))
    y1 = A.attention(p, cfg, x, jnp.arange(10)[None], "causal")
    x2 = x.at[0, 0].set(100.0)  # token 0 is outside every window >= 5
    y2 = A.attention(p, cfg, x2, jnp.arange(10)[None], "causal")
    np.testing.assert_allclose(np.asarray(y1[0, 6:]), np.asarray(y2[0, 6:]),
                               rtol=1e-4, atol=1e-5)


def test_moe_routes_and_balances():
    from repro.nn import moe as M
    cfg = M.MoeConfig(d_model=32, d_ff=64, n_experts=4, top_k=2)
    p = M.init_moe(jax.random.PRNGKey(0), cfg)
    p = jax.tree_util.tree_map(lambda l: l.value, p,
                               is_leaf=lambda x: hasattr(x, "value"))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, 32))
    out, aux = M.moe(p, cfg, x)
    assert out.shape == x.shape
    assert np.isfinite(float(aux)) and float(aux) >= 1.0 - 1e-6  # >= 1 at balance


def test_window_cache_ring_buffer_decode():
    """Decoding past the window: ring-buffer slots recycle and old tokens
    stop influencing logits (danube-style SWA decode)."""
    import dataclasses as dc
    from repro.nn import attention as A
    cfg = A.AttnConfig(d_model=32, n_heads=2, n_kv_heads=1, head_dim=16,
                       window=4)
    p = A.init_attention(jax.random.PRNGKey(0), cfg)
    p = jax.tree_util.tree_map(lambda l: l.value, p,
                               is_leaf=lambda x: hasattr(x, "value"))
    cache = A.init_window_cache(1, 4, cfg, jnp.float32)
    xs = jax.random.normal(jax.random.PRNGKey(1), (10, 1, 1, 32))
    outs = []
    for pos in range(10):
        y, cache = A.attention_decode(p, cfg, xs[pos], cache,
                                      jnp.asarray(pos, jnp.int32))
        outs.append(y)
    assert cache["k"].shape == (1, 4, 1, 16)  # never grows past the window
    assert int(jnp.max(cache["positions"])) == 9
    # token 9 attends only to positions 6..9: rerun with different early
    # tokens, same last four -> identical output
    cache2 = A.init_window_cache(1, 4, cfg, jnp.float32)
    xs2 = xs.at[:6].add(3.0)  # perturb only tokens outside the window
    y_last = None
    for pos in range(10):
        y_last, cache2 = A.attention_decode(p, cfg, xs2[pos] if pos < 6
                                            else xs[pos], cache2,
                                            jnp.asarray(pos, jnp.int32))
    np.testing.assert_allclose(np.asarray(y_last), np.asarray(outs[-1]),
                               rtol=1e-5, atol=1e-6)
