"""Hypothesis sweep on the stochastic-rounding f32 -> bf16 cast.

The mixed-precision engine's correctness argument leans on three facts
about ``sr_cast`` (see kernels/sr_cast.py):

* **bracketing** -- the output is always one of the two bf16 neighbours of
  the input (never a different binade, never a sign flip), so a single
  writeback moves a plane by at most one ulp;
* **exactness** -- bf16-representable values never move, for any key (the
  EF recursion's fixed points stay fixed);
* **unbiasedness** -- E[sr(x)] = x, so the bf16 EF drift on ``q``/``m``
  is mean-zero and the compression contraction survives in expectation.

Plus the system-level pin: the pallas kernel (interpret mode) and the jnp
reference consume identical bits drawn outside the kernel, so they are
BIT-identical for the same key on every odd, non-tile-aligned shape.
"""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels import ops
from repro.kernels import sr_cast as SRK

# odd shapes: scalar, tiny, non-lane-aligned, 3-D, crosses a tile boundary
ODD_SHAPES = [(), (1,), (123,), (7, 11, 3), (9001,)]


def _uniform(key, shape, scale):
    return scale * jax.random.uniform(key, shape, jnp.float32,
                                      minval=-1.0, maxval=1.0)


def _brackets(x):
    """The two admissible bf16 outputs, in bit space: truncate-down and
    (when the low mantissa bits are nonzero) the next representable."""
    b = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.uint32)
    lo = (b >> 16).astype(jnp.uint16)
    hi = lo + (b & jnp.uint32(0xFFFF) != 0).astype(jnp.uint16)
    return lo, hi


@given(st.integers(0, 2**16), st.sampled_from([1e-3, 1.0, 1e3]))
@settings(max_examples=16, deadline=None)
def test_bracketing(seed, scale):
    x = _uniform(jax.random.PRNGKey(seed), (257,), scale)
    lo, hi = _brackets(x)
    y = ops.sr_cast_ref(x, jax.random.PRNGKey(seed + 1))
    yb = jax.lax.bitcast_convert_type(y, jnp.uint16)
    assert bool(jnp.all((yb == lo) | (yb == hi)))


@given(st.integers(0, 2**16))
@settings(max_examples=16, deadline=None)
def test_exact_values_never_move(seed):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    x = _uniform(k1, (300,), 2.0).astype(jnp.bfloat16).astype(jnp.float32)
    y = ops.sr_cast_ref(x, k2)
    np.testing.assert_array_equal(np.asarray(y, jnp.float32),
                                  np.asarray(x))


@given(st.integers(0, 2**16), st.sampled_from([1e-2, 1.0]))
@settings(max_examples=8, deadline=None)
def test_unbiased_mean(seed, scale):
    """Mean over many independent roundings converges to x: the residual
    shrinks as gap/sqrt(K), tested at ~7 sigma so flakes are negligible."""
    x = _uniform(jax.random.PRNGKey(seed), (64,), scale)
    keys = jax.random.split(jax.random.PRNGKey(seed + 7), 512)
    ys = jax.vmap(lambda k: ops.sr_cast_ref(x, k).astype(jnp.float32))(keys)
    lo, hi = _brackets(x)
    # bit-space neighbours order by magnitude, so the value gap needs abs
    # (for x < 0 the +1 neighbour is the more negative one)
    gap = jnp.abs(
        jax.lax.bitcast_convert_type(hi, jnp.bfloat16).astype(jnp.float32)
        - jax.lax.bitcast_convert_type(lo, jnp.bfloat16).astype(jnp.float32))
    err = jnp.abs(jnp.mean(ys, axis=0) - x)
    # sigma(mean) <= gap / (2 sqrt(512)) ~= 0.0221 * gap
    assert bool(jnp.all(err <= 0.16 * gap + 1e-12))


@given(st.integers(0, 2**16))
@settings(max_examples=6, deadline=None)
def test_pallas_interpret_bit_parity(seed):
    """kernel (interpret) == jnp reference, bit for bit, on odd shapes --
    both draw the same bits outside the kernel from the same key."""
    key = jax.random.PRNGKey(seed)
    for shape in ODD_SHAPES:
        kx, kr = jax.random.split(jax.random.fold_in(key, len(shape)))
        x = _uniform(kx, shape, 3.0)
        a = ops.sr_cast(x, kr, interpret=True)
        b = ops.sr_cast_ref(x, kr)
        assert a.dtype == b.dtype == jnp.bfloat16
        np.testing.assert_array_equal(
            np.asarray(jax.lax.bitcast_convert_type(a, jnp.uint16)),
            np.asarray(jax.lax.bitcast_convert_type(b, jnp.uint16)),
            err_msg=f"shape {shape}")


@given(st.integers(0, 2**16))
@settings(max_examples=8, deadline=None)
def test_leaf_cast_properties(seed):
    """sr_cast_leaf (the sharding-preserving writeback path) obeys the same
    bracketing/exactness contract as the padded-plane pair."""
    key = jax.random.PRNGKey(seed)
    for shape in [(), (5,), (4, 33)]:
        kx, kr = jax.random.split(jax.random.fold_in(key, len(shape)))
        x = _uniform(kx, shape, 2.0)
        y = ops.sr_cast_leaf(x, kr)
        assert y.dtype == jnp.bfloat16 and y.shape == shape
        lo, hi = _brackets(x)
        yb = jax.lax.bitcast_convert_type(y, jnp.uint16)
        assert bool(jnp.all((yb == lo) | (yb == hi)))
        xe = x.astype(jnp.bfloat16).astype(jnp.float32)
        ye = ops.sr_cast_leaf(xe, kr)
        np.testing.assert_array_equal(np.asarray(ye, jnp.float32),
                                      np.asarray(xe))


def test_kernel_level_parity_padded_plane():
    """The raw (tiles, TILE) kernel matches its reference on shared bits."""
    key = jax.random.PRNGKey(3)
    x = _uniform(key, (3, SRK.TILE), 1.0)
    bits = jax.random.bits(jax.random.fold_in(key, 1), x.shape, jnp.uint32)
    a = SRK.sr_cast(x, bits, interpret=True)
    b = SRK.sr_cast_ref(x, bits)
    np.testing.assert_array_equal(
        np.asarray(jax.lax.bitcast_convert_type(a, jnp.uint16)),
        np.asarray(jax.lax.bitcast_convert_type(b, jnp.uint16)))
