"""Property tests: every compressor satisfies Definition 3,
E||C(x) - x||^2 <= (1 - rho) ||x||^2, plus scheme-specific facts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import compression as C


def _rand(seed, d):
    return jax.random.normal(jax.random.PRNGKey(seed), (d,))


@pytest.mark.parametrize("name,kwargs,rho", [
    ("identity", {}, 1.0),
    ("top_k", {"frac": 0.1}, 0.1),
    ("top_k", {"frac": 0.05}, 0.05),
    ("block_top_k", {"frac": 0.1, "block": 64}, 0.1),
])
def test_deterministic_contract(name, kwargs, rho):
    comp = C.make_compressor(name, **kwargs)
    for seed in range(5):
        x = _rand(seed, 997)
        y = comp(None, x)
        err = float(jnp.sum((y - x) ** 2))
        nrm = float(jnp.sum(x ** 2))
        assert err <= (1 - rho) * nrm + 1e-5 * nrm


@pytest.mark.parametrize("name,kwargs,rho", [
    ("random_k", {"frac": 0.2}, 0.2),
    ("qsgd", {"levels": 8}, None),
])
def test_randomized_contract_in_expectation(name, kwargs, rho):
    comp = C.make_compressor(name, **kwargs)
    d = 512
    x = _rand(0, d)
    keys = jax.random.split(jax.random.PRNGKey(1), 200)
    errs = jnp.stack([jnp.sum((comp(k, x) - x) ** 2) for k in keys])
    mean_err = float(jnp.mean(errs))
    nrm = float(jnp.sum(x ** 2))
    if rho is None:  # qsgd: rho depends on d
        omega = min(np.sqrt(d) / 8, d / 64)
        rho = 1.0 / (1.0 + omega)
    # 200 trials: allow 10% statistical slack
    assert mean_err <= (1 - rho) * nrm * 1.10 + 1e-6


@given(st.integers(1, 4000), st.integers(0, 2**31 - 1),
       st.sampled_from([0.01, 0.05, 0.25, 1.0]))
@settings(max_examples=25, deadline=None)
def test_topk_contract_hypothesis(d, seed, frac):
    comp = C.make_compressor("top_k", frac=frac)
    x = _rand(seed % 1000, d)
    y = comp(None, x)
    k = max(int(round(frac * d)), 1)
    assert int(jnp.sum(y != 0)) <= k
    err = float(jnp.sum((y - x) ** 2))
    assert err <= (1 - min(frac, k / d)) * float(jnp.sum(x ** 2)) + 1e-4


def test_topk_keeps_largest():
    x = jnp.asarray([0.1, -5.0, 0.2, 3.0, -0.05])
    y = C.make_compressor("top_k", frac=0.4)(None, x)
    np.testing.assert_allclose(y, [0.0, -5.0, 0.0, 3.0, 0.0])


def test_pack_unpack_roundtrip():
    x = _rand(3, 300)
    comp = C.make_compressor("top_k", frac=0.1)
    dense = comp(None, x)
    vals, idx = C.topk_pack(x, k=30)
    recon = C.topk_unpack(vals, idx, 300)
    np.testing.assert_allclose(np.asarray(recon), np.asarray(dense),
                               rtol=1e-6)


def test_compress_tree_per_agent_streams():
    """Agent rows get independent randomness and per-row compression."""
    comp = C.make_compressor("random_k", frac=0.5)
    tree = {"w": jax.random.normal(jax.random.PRNGKey(0), (4, 64))}
    out = C.compress_tree(comp, jax.random.PRNGKey(1), tree)["w"]
    masks = np.asarray(out != 0)
    assert masks.shape == (4, 64)
    assert not all(np.array_equal(masks[0], masks[i]) for i in range(1, 4))


def test_wire_bits_accounting():
    comp = C.make_compressor("top_k", frac=0.05)
    d = 10000
    bits = comp.wire_bits(d)
    assert bits < 32 * d * 0.1  # ~20x reduction
    assert C.make_compressor("identity").wire_bits(d) == 32 * d


def test_sign_compressor_identity_and_wire():
    """Scaled-sign (arXiv 2607.01755): one f32 magnitude + d sign bits,
    and the compression error has a closed form."""
    comp = C.make_compressor("sign")
    assert comp.deterministic
    d = 4096
    assert comp.wire_bits(d) == d + 32
    x = _rand(3, d)
    y = np.asarray(comp(None, x))
    np.testing.assert_allclose(np.abs(y), float(jnp.mean(jnp.abs(x))),
                               rtol=1e-6)
    n2, n1 = float(jnp.sum(x ** 2)), float(jnp.sum(jnp.abs(x)))
    err = float(jnp.sum((jnp.asarray(y) - x) ** 2))
    np.testing.assert_allclose(err, (1 - n1 ** 2 / (d * n2)) * n2,
                               rtol=1e-5)


# ---------------------------------------------------------------------------
# Definition-3 contract for EVERY registry entry (qsgd and low_rank had no
# contract coverage before this sweep), over hypothesis-driven shapes/seeds
# ---------------------------------------------------------------------------

# one representative construction per registry entry; the completeness
# check below makes a newly registered compressor fail until it is covered
CONTRACT_CASES = {
    "identity": {},
    "random_k": {"frac": 0.2},
    "top_k": {"frac": 0.1},
    "block_top_k": {"frac": 0.1, "block": 256},
    "qsgd": {"levels": 8},
    "low_rank": {"rank": 2, "power_iters": 1},
    "sign": {},
}


def test_contract_cases_cover_registry():
    assert set(CONTRACT_CASES) == set(C._REGISTRY), (
        "every make_compressor entry needs a Definition-3 contract case")


def _expected_rho(name, kwargs, d):
    """The tightest rho each scheme provably satisfies at dimension d.

    The sparse family's effective rho is k/d with k = max(round(frac*d), 1)
    -- rounding down below frac*d weakens the bound (a near-uniform vector
    realizes it), rounding up to 1 strengthens it.  qsgd's omega depends on
    d; low_rank only advertises the projection bound (rho = 0)."""
    if name == "identity":
        return 1.0
    if name == "random_k":
        return kwargs["frac"]              # exact in expectation
    if name == "top_k":
        k = max(int(round(kwargs["frac"] * d)), 1)
        return min(kwargs["frac"], k / d)
    if name == "block_top_k":
        block = kwargs["block"]
        k_b = max(int(round(kwargs["frac"] * block)), 1)
        return min(kwargs["frac"], k_b / block)
    if name == "qsgd":
        s = kwargs["levels"]
        omega = min(np.sqrt(d) / s, d / s ** 2)
        return 1.0 / (1.0 + omega)
    if name == "low_rank":
        return 0.0
    if name == "sign":
        # ||C(x)-x||^2 = (1 - ||x||_1^2/(d||x||_2^2))||x||^2 exactly;
        # Cauchy-Schwarz gives the worst case ||x||_1^2 >= ||x||_2^2
        return 1.0 / d
    raise AssertionError(name)


@given(st.sampled_from(sorted(CONTRACT_CASES)), st.integers(4, 3000),
       st.integers(0, 2 ** 31 - 1))
@settings(max_examples=60, deadline=None)
def test_definition3_contract_every_compressor(name, d, seed):
    """E||C(x) - x||^2 <= (1 - rho) ||x||^2 (paper Definition 3)."""
    kwargs = CONTRACT_CASES[name]
    comp = C.make_compressor(name, **kwargs)
    x = _rand(seed % 100003, d)
    nrm = float(jnp.sum(x ** 2))
    rho = _expected_rho(name, kwargs, d)
    if comp.deterministic:
        err = float(jnp.sum((comp(None, x) - x) ** 2))
        assert err <= (1.0 - rho) * nrm + 1e-5 * nrm, (name, d, err / nrm)
        return
    keys = jax.random.split(jax.random.PRNGKey(seed % 7919), 128)
    errs = jax.vmap(lambda k: jnp.sum((comp(k, x) - x) ** 2))(keys)
    if name == "low_rank":
        # projections contract per draw, not just in expectation
        assert float(jnp.max(errs)) <= nrm * (1.0 + 1e-5), (d, seed)
        return
    # statistical slack: 128 trials; small d has fat relative tails
    slack = 1.15 + 1.5 / np.sqrt(d)
    mean_err = float(jnp.mean(errs))
    assert mean_err <= (1.0 - rho) * nrm * slack + 1e-6, (
        name, d, mean_err / nrm, rho)
