"""Tests for the beyond-paper extensions: low-rank compressor, exponential /
hypercube topologies, and PORTER-Adam."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (PorterConfig, average_params, make_compressor,
                        make_mixer, make_topology, make_porter_step,
                        porter_init)
from repro.core.porter_adam import make_porter_adam_step, porter_adam_init
from repro.data import a9a_like, agent_batch_iterator, shard_to_agents


# ---------------------------------------------------------------------------
# low-rank compressor
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rank", [1, 2, 8])
def test_low_rank_is_contraction(rank):
    comp = make_compressor("low_rank", rank=rank)
    for seed in range(4):
        x = jax.random.normal(jax.random.PRNGKey(seed), (797,))
        y = comp(jax.random.PRNGKey(seed + 100), x)
        err = float(jnp.sum((y - x) ** 2))
        nrm = float(jnp.sum(x ** 2))
        assert err <= nrm * (1 + 1e-5)          # Definition 3 with rho >= 0
        assert err < nrm                        # strict for generic inputs


def test_low_rank_exact_on_low_rank_input():
    """A rank-1 matrix (as a flat vector) is reproduced ~exactly."""
    u = jax.random.normal(jax.random.PRNGKey(0), (32,))
    v = jax.random.normal(jax.random.PRNGKey(1), (32,))
    x = jnp.outer(u, v).reshape(-1)
    comp = make_compressor("low_rank", rank=2, power_iters=2)
    y = comp(jax.random.PRNGKey(2), x)
    rel = float(jnp.linalg.norm(y - x) / jnp.linalg.norm(x))
    assert rel < 1e-3


def test_low_rank_higher_rank_less_error():
    x = jax.random.normal(jax.random.PRNGKey(5), (2048,))
    errs = []
    for r in (1, 4, 16):
        y = make_compressor("low_rank", rank=r)(jax.random.PRNGKey(6), x)
        errs.append(float(jnp.sum((y - x) ** 2)))
    assert errs[0] > errs[1] > errs[2]


# ---------------------------------------------------------------------------
# new topologies
# ---------------------------------------------------------------------------

def test_exponential_beats_ring_alpha():
    ring = make_topology("ring", 16)
    expo = make_topology("exponential", 16)
    assert expo.alpha < ring.alpha
    # O(log n) degree
    assert int(expo.adjacency[0].sum()) <= 2 * int(np.log2(16))


def test_hypercube_structure():
    hc = make_topology("hypercube", 16)
    assert int(hc.adjacency[0].sum()) == 4  # log2(16) neighbours
    assert 0 < hc.alpha < 1
    with pytest.raises(ValueError):
        make_topology("hypercube", 12)


def test_porter_converges_on_exponential_graph():
    x, y = a9a_like(4000, 60, seed=0)
    xs, ys = shard_to_agents(x, y, 16)

    def loss_fn(params, batch):
        f, l = batch
        f, l = jnp.atleast_2d(f), jnp.atleast_1d(l)
        logits = f @ params["w"]
        return jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits)))

    top = make_topology("exponential", 16)
    comp = make_compressor("top_k", frac=0.1)
    cfg = PorterConfig(eta=0.05, gamma=0.4 * (1 - top.alpha) * 0.1, tau=1.0,
                       variant="gc")
    state = porter_init({"w": jnp.zeros(60)}, 16, w=top.w)
    step = jax.jit(make_porter_step(cfg, loss_fn, make_mixer(top, "dense"),
                                    comp))
    it = agent_batch_iterator(xs, ys, batch=8, seed=0)
    key = jax.random.PRNGKey(0)
    for _ in range(200):
        key, k = jax.random.split(key)
        state, m = step(state, next(it), k)
    assert np.isfinite(float(m["loss"])) and float(m["loss"]) < 0.68


# ---------------------------------------------------------------------------
# PORTER-Adam
# ---------------------------------------------------------------------------

def test_porter_adam_converges_and_tracks():
    x, y = a9a_like(4000, 80, seed=1)
    xs, ys = shard_to_agents(x, y, 8)

    def loss_fn(params, batch):
        f, l = batch
        f, l = jnp.atleast_2d(f), jnp.atleast_1d(l)
        logits = f @ params["w"] + params["b"]
        return jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits))) \
            + 0.1 * jnp.sum(params["w"] ** 2 / (1 + params["w"] ** 2))

    top = make_topology("erdos_renyi", 8, weights="best_constant", seed=3)
    comp = make_compressor("top_k", frac=0.1)
    cfg = PorterConfig(eta=0.01, gamma=0.4 * (1 - top.alpha) * 0.1, tau=1.0,
                       variant="gc")
    params0 = {"w": jnp.zeros(80), "b": jnp.zeros(())}
    state = porter_adam_init(params0, 8, w=top.w)
    step = jax.jit(make_porter_adam_step(cfg, loss_fn,
                                         make_mixer(top, "dense"), comp))
    it = agent_batch_iterator(xs, ys, batch=8, seed=0)
    key = jax.random.PRNGKey(0)
    for _ in range(300):
        key, k = jax.random.split(key)
        state, m = step(state, next(it), k)
    # tracking identity still holds (preconditioning is after tracking)
    vbar = jnp.mean(state.base.v["w"], axis=0)
    gbar = jnp.mean(state.base.g_prev["w"], axis=0)
    np.testing.assert_allclose(np.asarray(vbar), np.asarray(gbar),
                               rtol=1e-4, atol=1e-5)
    # converges to a good point and agents agree
    flat = (jnp.asarray(xs.reshape(-1, 80)), jnp.asarray(ys.reshape(-1)))
    g = jax.grad(loss_fn)(average_params(state.base.x), flat)
    gn = float(jnp.sqrt(sum(jnp.sum(v ** 2)
                            for v in jax.tree_util.tree_leaves(g))))
    assert gn < 0.15, f"PORTER-Adam failed to converge: {gn}"
    assert float(m["consensus_x"]) < 5.0
