"""Bit-packed wire formats: kernel/reference parity, codec executors, and
comm/compute overlap.

* Pack -> unpack round-trip parity between the fused pallas kernels
  (interpret mode) and the jnp reference codecs in repro.core.wire_formats,
  on odd (non-window-aligned) sizes and bf16 planes.  Both sides implement
  the SAME bisection-threshold selection, so parity is bit-level, asserted
  at the issue's atol 1e-5.
* measured buffer nbytes == the registered layout constants for every d
  (the executor / kernel / byte-model drift-bug class).
* Codec gossip executors (ring ppermute of packed buffers, packed
  all-gather) against the dense-mixer-on-oracle-roundtrip, including n=2
  ring band folding and a model-sharded leaf -- in a subprocess with 8
  host devices (see test_distributed_gossip.py).
* CommRound(overlap=True): bit-exact to the sequential order for all
  eight registered algorithms, and (in the subprocess) the lowered HLO of
  an overlapped PORTER step contains exactly the same collectives as the
  sequential one.
"""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec, build, list_algorithms
from repro.core import wire_formats as WF
from repro.kernels import ops

ODD_SIZES = (5, 2047, 2049, 20_001)
K = 512          # frac=0.25 of PACK_BLOCK
LEVELS = 7       # 4-bit code words (sign + 3-bit magnitude)


def _rows(d, seed=0, dtype=jnp.float32):
    x = jax.random.normal(jax.random.PRNGKey(seed), (d,), dtype)
    return x, WF.to_windows(x.astype(jnp.float32))


# ---------------------------------------------------------------------------
# pallas-interpret vs jnp reference codec parity
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", ODD_SIZES)
def test_topk_pack_parity_odd_shapes(d):
    x, rows = _rows(d)
    vals_r, idx_r = WF.topk_pack_ref(rows, K)
    vals_p, idx_p = ops.wire_topk_pack(rows, K, interpret=True)
    np.testing.assert_array_equal(np.asarray(idx_p, np.int32),
                                  np.asarray(idx_r, np.int32))
    np.testing.assert_allclose(np.asarray(vals_p, np.float32),
                               np.asarray(vals_r, np.float32), atol=1e-5)
    dense_r = WF.topk_unpack_ref(vals_r, idx_r)
    dense_p = ops.wire_topk_unpack(vals_p, idx_p, interpret=True)
    np.testing.assert_allclose(np.asarray(dense_p), np.asarray(dense_r),
                               atol=1e-5)
    # round trip: kept entries survive up to bf16 value rounding, the rest
    # are exactly zero; the padded tail (window beyond d) stays zero
    back = WF.from_windows(dense_r, d, x.shape)
    a = np.abs(np.asarray(x))
    kept = np.asarray(back) != 0
    assert kept.sum() <= min(K * rows.shape[0], d)
    np.testing.assert_allclose(np.asarray(back)[kept],
                               np.asarray(x)[kept], rtol=2 ** -8)


def test_topk_pack_parity_bf16_plane():
    # bf16 parameter planes enter the codec through the f32 staging cast
    # (gossip._pack_local) and leave through unpack(dtype=bf16)
    x, rows = _rows(4097, seed=3, dtype=jnp.bfloat16)
    vals_r, idx_r = WF.topk_pack_ref(rows, K)
    vals_p, idx_p = ops.wire_topk_pack(rows, K, interpret=True)
    np.testing.assert_array_equal(np.asarray(idx_p, np.int32),
                                  np.asarray(idx_r, np.int32))
    out_r = WF.topk_unpack_ref(vals_r, idx_r, dtype=jnp.bfloat16)
    out_p = ops.wire_topk_unpack(vals_p, idx_p,
                                 interpret=True).astype(jnp.bfloat16)
    assert out_r.dtype == jnp.bfloat16
    np.testing.assert_array_equal(
        np.asarray(out_p, np.float32), np.asarray(out_r, np.float32))


@pytest.mark.parametrize("d", ODD_SIZES)
def test_qsgd_pack_parity_odd_shapes(d):
    _, rows = _rows(d, seed=1)
    key = jax.random.PRNGKey(42)
    word_r, scale_r = WF.qsgd_pack_ref(key, rows, LEVELS)
    word_p, scale_p = ops.wire_qsgd_pack(rows, key, LEVELS, interpret=True)
    # identical stochastic rounding noise -> bit-identical code words
    np.testing.assert_array_equal(np.asarray(word_p), np.asarray(word_r))
    np.testing.assert_allclose(np.asarray(scale_p), np.asarray(scale_r),
                               atol=1e-5)
    dense_r = WF.qsgd_unpack_ref(word_r, scale_r, LEVELS, jnp.float32)
    dense_p = ops.wire_qsgd_unpack(word_p, scale_p, LEVELS, interpret=True)
    np.testing.assert_allclose(np.asarray(dense_p), np.asarray(dense_r),
                               atol=1e-5)


def test_qsgd_roundtrip_contract():
    # Definition 3 per window: ||C(x) - x||^2 <= (1 - 1/(1+omega)) ||x||^2
    # with omega = min(sqrt(B)/s, B/s^2); sampled over keys
    d = 3 * WF.PACK_BLOCK
    x, rows = _rows(d, seed=2)
    omega = WF.qsgd_window_omega(LEVELS)
    bound = 1.0 - 1.0 / (1.0 + omega)
    errs = []
    for s in range(5):
        word, scale = WF.qsgd_pack_ref(jax.random.PRNGKey(s), rows, LEVELS)
        back = WF.qsgd_unpack_ref(word, scale, LEVELS, jnp.float32)
        errs.append(float(jnp.sum((back - rows) ** 2) / jnp.sum(rows ** 2)))
    assert np.mean(errs) <= bound + 1e-3, (np.mean(errs), bound)


# ---------------------------------------------------------------------------
# layout constants cannot drift from the shipped buffers
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("d", ODD_SIZES + (WF.PACK_BLOCK, 8 * WF.PACK_BLOCK))
def test_measured_nbytes_match_model(d):
    topk = WF.make_wire_format("block_top_k", frac=0.25)
    qsgd = WF.make_wire_format("qsgd", levels=LEVELS)
    for fmt in (topk, qsgd):
        assert WF.measured_pack_nbytes(fmt, d) == fmt.buffer_bytes(d), fmt.name


def test_wire_format_registry():
    # one shared constants module: every registered format resolves, and
    # qsgd is registered alongside PACK_BLOCK (the former footnote gap)
    assert WF.WIRE_FORMATS == ("topk_bits", "qsgd_bits")
    assert WF.make_wire_format("top_k", frac=0.1).name == "topk_bits"
    assert WF.make_wire_format("qsgd", levels=15).name == "qsgd_bits"
    with pytest.raises(ValueError, match="no registered"):
        WF.make_wire_format("random_k", frac=0.1)


# ---------------------------------------------------------------------------
# overlap is bit-exact for every registered algorithm
# ---------------------------------------------------------------------------

def _loss_fn(params, batch):
    f, l = batch
    f, l = jnp.atleast_2d(f), jnp.atleast_1d(l)
    logits = f @ params["w"] + params["b"]
    return jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits)))


@pytest.mark.parametrize("name", sorted(list_algorithms()))
def test_overlap_bitexact_all_algorithms(name):
    n, d, m, b = 4, 16, 32, 3
    rng = np.random.default_rng(0)
    f = rng.normal(size=(n, m, d)).astype(np.float32)
    l = (f @ rng.normal(size=d) > 0).astype(np.float32)
    params0 = {"w": jnp.zeros(d), "b": jnp.zeros(())}
    spec = ExperimentSpec(
        algo=name, n_agents=n, topology="ring", compressor="top_k",
        frac=0.25, eta=0.1, tau=5.0,
        sigma_p=0.01 if name in ("porter-dp", "dp-sgd", "soteriafl") else 0.0)

    def run(overlap):
        algo = build(spec.replace(overlap=overlap), _loss_fn)
        state = algo.init(params0)
        step = jax.jit(algo.step)
        key = jax.random.PRNGKey(7)
        for t in range(3):
            kb, ks = jax.random.split(jax.random.fold_in(key, t))
            idx = jax.random.randint(kb, (n, b), 0, m)
            batch = (jnp.asarray(f)[jnp.arange(n)[:, None], idx],
                     jnp.asarray(l)[jnp.arange(n)[:, None], idx])
            state, metrics = step(state, batch, ks)
        return state, metrics

    st_seq, m_seq = run(False)
    st_ovl, m_ovl = run(True)
    for a, b_ in zip(jax.tree_util.tree_leaves(st_seq),
                     jax.tree_util.tree_leaves(st_ovl)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b_))
    for k in m_seq:
        np.testing.assert_array_equal(np.asarray(m_seq[k]),
                                      np.asarray(m_ovl[k]))


# ---------------------------------------------------------------------------
# codec executors on a real device mesh (subprocess: 8 host devices)
# ---------------------------------------------------------------------------

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.api import ExperimentSpec, build, build_engine
    from repro.compat import shard_map
    from repro.core import wire_formats as WF
    from repro.core.gossip import make_dense_mixer
    from repro.core.mixing import make_topology

    mesh = jax.make_mesh((4, 2), ("data", "model"))
    key = jax.random.PRNGKey(0)
    tree = {"a": jax.random.normal(key, (4, 6, 8)),
            "b": jax.random.normal(key, (4, 10))}
    specs = {"a": P("data", None, "model"), "b": P("data", None)}
    sh = {k: NamedSharding(mesh, specs[k]) for k in specs}
    y = {k: jax.device_put(tree[k], sh[k]) for k in tree}
    q = jax.tree_util.tree_map(jnp.zeros_like, y)
    top = make_topology("ring", 4, weights="metropolis")

    def oracle_c(codec, tree):
        # shard-local pack -> unpack round trip, the codec's own law
        def per_shard(tt):
            def leaf(l):
                flat = l.reshape(l.shape[0], -1).astype(jnp.float32)
                def one(v):
                    rows = WF.to_windows(v)
                    return WF.from_windows(
                        codec.unpack(*codec.pack(None, rows)),
                        v.shape[0], v.shape)
                return jax.vmap(one)(flat).reshape(l.shape)
            return jax.tree_util.tree_map(leaf, tt)
        f = shard_map(per_shard, mesh=mesh, in_specs=(specs,),
                      out_specs=specs, check_vma=False)
        return jax.jit(f)(tree)

    codec = WF.make_wire_format("block_top_k", frac=0.25)
    want_c = oracle_c(codec, y)
    want_wc = make_dense_mixer(top.w)(
        jax.tree_util.tree_map(np.asarray, want_c))

    for mode, marker in (("ring", "ring-codec-ok"),
                         ("packed", "packed-codec-ok")):
        spec = ExperimentSpec(n_agents=4, topology="ring",
                              topology_weights="metropolis",
                              compressor="block_top_k", frac=0.25,
                              gossip_mode=mode, wire="packed_bits",
                              comm_backend="ref", interpret=True)
        eng = build_engine(spec, mesh=mesh, leaf_specs=specs)
        c, wc = jax.jit(lambda k, a, b, e=eng: e.exchange(k, a, b))(key, y, q)
        for k in tree:
            np.testing.assert_allclose(np.asarray(c[k]),
                                       np.asarray(want_c[k]),
                                       rtol=1e-4, atol=1e-5)
            np.testing.assert_allclose(np.asarray(wc[k]),
                                       np.asarray(want_wc[k]),
                                       rtol=1e-4, atol=1e-5)
        print(marker)

    # qsgd codec: stochastic, so pin same-key determinism + the m=Wq law
    # (wc must equal W @ c for the very same shipped buffers)
    spec_q = ExperimentSpec(n_agents=4, topology="ring",
                            topology_weights="metropolis",
                            compressor="qsgd",
                            compressor_kwargs={"levels": 7},
                            gossip_mode="ring", wire="packed_bits",
                            comm_backend="ref", interpret=True)
    eng_q = build_engine(spec_q, mesh=mesh, leaf_specs=specs)
    ex = jax.jit(lambda k, a, b: eng_q.exchange(k, a, b))
    c1, wc1 = ex(key, y, q)
    c2, wc2 = ex(key, y, q)
    for k in tree:
        np.testing.assert_array_equal(np.asarray(c1[k]), np.asarray(c2[k]))
    want = make_dense_mixer(top.w)(jax.tree_util.tree_map(np.asarray, c1))
    for k in tree:
        np.testing.assert_allclose(np.asarray(wc1[k]), np.asarray(want[k]),
                                   rtol=1e-4, atol=1e-5)
    print("qsgd-codec-ok")

    # n=2 ring folds both bands onto the one live neighbor -- the codec
    # executor must apply the neighbor's unpacked buffers exactly once
    mesh2 = jax.make_mesh((2,), ("data",))
    top2 = make_topology("ring", 2, weights="metropolis")
    specs2 = {"a": P("data", None, None), "b": P("data", None)}
    sh2 = {k: NamedSharding(mesh2, specs2[k]) for k in specs2}
    tree2 = {"a": jax.random.normal(key, (2, 5, 3)),
             "b": jax.random.normal(key, (2, 7))}
    y2 = {k: jax.device_put(tree2[k], sh2[k]) for k in tree2}
    q2 = jax.tree_util.tree_map(jnp.zeros_like, y2)
    spec2 = ExperimentSpec(n_agents=2, topology="ring",
                           topology_weights="metropolis",
                           compressor="block_top_k", frac=0.25,
                           gossip_mode="ring", wire="packed_bits",
                           comm_backend="ref", interpret=True)
    eng2 = build_engine(spec2, mesh=mesh2, leaf_specs=specs2)
    c2t, wc2t = jax.jit(lambda k, a, b: eng2.exchange(k, a, b))(key, y2, q2)

    def oracle2(tt):
        def leaf(l):
            flat = l.reshape(l.shape[0], -1).astype(jnp.float32)
            def one(v):
                rows = WF.to_windows(v)
                return WF.from_windows(
                    codec.unpack(*codec.pack(None, rows)),
                    v.shape[0], v.shape)
            return jax.vmap(one)(flat).reshape(l.shape)
        return jax.tree_util.tree_map(leaf, tt)
    want_c2 = oracle2(tree2)
    want_wc2 = make_dense_mixer(top2.w)(
        jax.tree_util.tree_map(np.asarray, want_c2))
    for k in tree2:
        np.testing.assert_allclose(np.asarray(c2t[k]),
                                   np.asarray(want_c2[k]),
                                   rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(wc2t[k]),
                                   np.asarray(want_wc2[k]),
                                   rtol=1e-5, atol=1e-6)
    print("ring2-codec-ok")

    # overlap introduces no extra collectives: the lowered PORTER step has
    # identical per-category collective counts with overlap on and off
    from repro.analysis.hlo import collective_counts
    d = 2 * WF.PACK_BLOCK
    params0 = {"w": jnp.zeros(d)}
    pspecs = {"w": P("data", None)}

    def loss(p, b):
        return jnp.mean((p["w"] - b) ** 2)

    counts = {}
    for ovl in (False, True):
        spec_o = ExperimentSpec(algo="porter-gc", n_agents=4,
                                topology="ring",
                                topology_weights="metropolis",
                                compressor="block_top_k", frac=0.25,
                                gossip_mode="ring", wire="packed_bits",
                                comm_backend="ref", interpret=True,
                                eta=0.1, overlap=ovl)
        algo = build(spec_o, loss, mesh=mesh2, agent_axes=("data",),
                     leaf_specs=pspecs)
        state = algo.init(params0, n_agents=2)
        batch = jnp.zeros((2, 1, d))
        hlo = (jax.jit(algo.step)
               .lower(state, batch, jax.random.PRNGKey(0))
               .compile().as_text())
        counts[ovl] = collective_counts(hlo)
    assert counts[False] == counts[True], counts
    assert sum(counts[True].values()) > 0, counts
    print("hlo-overlap-ok")
""")


def test_codec_executors_and_overlap_hlo():
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=600,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"})
    assert res.returncode == 0, res.stderr[-3000:]
    for marker in ("ring-codec-ok", "packed-codec-ok", "qsgd-codec-ok",
                   "ring2-codec-ok", "hlo-overlap-ok"):
        assert marker in res.stdout, (marker, res.stdout, res.stderr[-2000:])
