"""Mixed-precision plane tests: bf16 EF state end to end.

Three contracts from the comm-round memory system (no hypothesis, always
collected):

* ``backend='auto'`` resolves to the jnp reference off-TPU -- and an
  auto-built engine steps BIT-identically to an explicit ``'ref'`` build
  (the regression: auto used to pick pallas-interpret on CPU, which is
  orders of magnitude slower and needlessly diverges from the path CI
  pins everywhere else);
* ``plane_dtype='bf16'`` lands exactly the intended state layout: f32
  master params, bf16 EF/gossip planes, f32 push-sum weight columns
  (``xw``/``q_w``/``m_w`` must stay exact -- they carry the push-sum
  mass balance), and untouched f32 runs keep their RNG stream (sr_split
  passthrough);
* every registered algorithm trains through the chunked runtime with
  bf16 planes to the same loss as its f32 twin (loose atol -- stochastic
  rounding is unbiased but not bit-stable).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import ExperimentSpec, build, list_algorithms
from repro.core.comm_round import CommRound, resolve_backend
from repro.core.registry import algorithm_info
from repro.data import a9a_like, minibatch_source, shard_to_agents
from repro.launch.runtime import make_runner

N = 4
PARITY_ATOL = 0.02


def _loss(params, batch):
    f, l = batch
    f = jnp.atleast_2d(f)
    l = jnp.atleast_1d(l)
    logits = f @ params["w"] + params["b"]
    return jnp.mean(jnp.log1p(jnp.exp(-(2 * jnp.atleast_1d(l) - 1) * logits)))


def _spec(algo, **kw):
    base = dict(algo=algo, n_agents=N, topology="ring",
                topology_weights="metropolis", compressor="block_top_k",
                frac=0.25, comm_backend="ref", interpret=True, eta=0.1)
    if algorithm_info(algo).dp:
        base.update(tau=5.0, sigma_p=0.01)
    base.update(kw)
    return ExperimentSpec(**base)


def _problem():
    x, y = a9a_like(400, 33, seed=0)
    xs, ys = shard_to_agents(x, y, N)
    return ({"w": jnp.zeros(33), "b": jnp.zeros(())},
            minibatch_source(xs, ys, batch=4))


def _run_chunked(spec, steps=8, chunk=4):
    params0, source = _problem()
    algo = build(spec, _loss)
    state = algo.init(params0)
    runner = make_runner(algo, source, chunk)
    key = jax.random.PRNGKey(0)
    metrics = None
    for t in range(0, steps, chunk):
        state, key, metrics = runner(state, key, t)
    return state, metrics


# ---------------------------------------------------------------------------
# backend='auto'
# ---------------------------------------------------------------------------

def test_resolve_backend_prefers_ref_off_tpu():
    expect = "pallas" if jax.default_backend() == "tpu" else "ref"
    assert resolve_backend("auto") == expect
    # explicit choices pass through untouched
    assert resolve_backend("ref") == "ref"
    assert resolve_backend("pallas") == "pallas"


def test_auto_backend_steps_bit_identical_to_ref():
    st_auto, m_auto = _run_chunked(_spec("porter-gc", comm_backend="auto"))
    st_ref, m_ref = _run_chunked(_spec("porter-gc", comm_backend="ref"))
    for a, b in zip(jax.tree_util.tree_leaves(st_auto),
                    jax.tree_util.tree_leaves(st_ref)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    np.testing.assert_array_equal(np.asarray(m_auto["loss"]),
                                  np.asarray(m_ref["loss"]))


# ---------------------------------------------------------------------------
# state layout under plane_dtype='bf16'
# ---------------------------------------------------------------------------

def test_bf16_state_layout_porter():
    st, _ = _run_chunked(_spec("porter-gc", plane_dtype="bf16"))
    assert all(l.dtype == jnp.float32
               for l in jax.tree_util.tree_leaves(st.x))
    for plane in ("v", "q_x", "q_v", "g_prev", "m_x", "m_v"):
        assert all(l.dtype == jnp.bfloat16
                   for l in jax.tree_util.tree_leaves(getattr(st, plane))), \
            f"{plane} not bf16"


def test_bf16_push_sum_weight_stays_f32_exact():
    st, _ = _run_chunked(_spec("dp-csgp", plane_dtype="bf16",
                               gossip_mode="dense"))
    for col in ("xw", "q_w", "m_w"):
        assert all(l.dtype == jnp.float32
                   for l in jax.tree_util.tree_leaves(getattr(st, col))), \
            f"{col} must stay f32 (push-sum mass balance)"
    # doubly-stochastic static mixing keeps unit weights exactly
    np.testing.assert_array_equal(np.asarray(st.xw), np.ones(N, np.float32))


def test_sr_split_passthrough_keeps_f32_stream():
    """All-f32 trees must NOT consume a key split: plane_dtype=None runs
    keep the exact RNG stream of the pre-mixed-precision engine."""
    eng = build(_spec("porter-gc"), _loss).engine
    assert isinstance(eng, CommRound)
    key = jax.random.PRNGKey(5)
    f32_tree = {"w": jnp.zeros((N, 7), jnp.float32)}
    out_key, sr_key = eng.sr_split(key, (f32_tree,))
    assert sr_key is None
    np.testing.assert_array_equal(np.asarray(out_key), np.asarray(key))
    bf16_tree = {"w": jnp.zeros((N, 7), jnp.bfloat16)}
    out_key, sr_key = eng.sr_split(key, (f32_tree, bf16_tree))
    assert sr_key is not None
    assert not np.array_equal(np.asarray(out_key), np.asarray(key))


# ---------------------------------------------------------------------------
# chunked parity: every registered algorithm, f32 vs bf16 planes
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("algo", list_algorithms())
def test_chunked_parity_f32_vs_bf16(algo):
    _, m32 = _run_chunked(_spec(algo))
    _, m16 = _run_chunked(_spec(algo, plane_dtype="bf16"))
    l32 = float(m32["loss"][-1])
    l16 = float(m16["loss"][-1])
    assert np.isfinite(l32) and np.isfinite(l16)
    assert abs(l32 - l16) <= PARITY_ATOL, \
        f"{algo}: f32 loss {l32:.4f} vs bf16 loss {l16:.4f}"
    # the wire-byte metric stays reported (and finite) under bf16
    assert np.isfinite(float(m16["wire_bytes"][-1]))


def test_dense_wire_model_documented_f32():
    """Dense gossip is a bandwidth EMULATION (all-to-all averaging on one
    host); its byte model deliberately stays the f32 accounting so ablation
    curves remain comparable across plane dtypes.  The measured-ring
    halving is pinned by the analyzer census + benchmarks/bench_memory.py."""
    _, m32 = _run_chunked(_spec("porter-gc"))
    _, m16 = _run_chunked(_spec("porter-gc", plane_dtype="bf16"))
    assert float(m32["wire_bytes"][-1]) == float(m16["wire_bytes"][-1])
