"""Directed-graph push-sum subsystem (core.push_sum + the engine's
``*_ps`` rounds + the dp-csgp registration).

* De-bias law: ``x / xw`` with weights exactly 1 is IEEE bit-identity --
  the exact-reduction lemma behind the parity test.
* Parity (acceptance): at period 1 with a symmetric doubly-stochastic
  table, dp-csgp is trajectory-identical to porter-dp (state and every
  metric except ``wire_bytes``, which additionally accounts the weight
  plane).
* Engine: the push-sum weight recursion matches a numpy mirror of the
  exact-EF recursion; the plain packed all-gather mixer (no weight slot)
  is rejected with a actionable error; push-sum wire accounting adds
  exactly 4 bytes per shipped buffer set on measured AND model paths.
* Facade: directed schedules reject doubly-stochastic algorithms; dp-csgp
  accepts them; mid-period checkpoint/resume restores the weight plane
  and step counter; a directed-churn schedule trains under chunking with
  one executable per chunk size.
* Subprocess (8 host devices): dense and ring push-sum executors agree
  with the numpy push-sum reference on static directed graphs (atol
  1e-5); the codec executor transports the weight increment exactly
  (``cw == dw`` bit-exact); the lowered dp-csgp step HLO contains exactly
  the same collectives as porter-dp's -- the weight plane rides inside
  existing collectives, never adds one.
"""

import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (ExperimentSpec, algorithm_info, build, build_engine,
                       resolve_schedule)
from repro.core import mixing as MX
from repro.core import push_sum as PS
from repro.core.comm_round import CommRound
from repro.core.compression import make_compressor
from repro.data import minibatch_source
from repro.launch.runtime import make_runner

N, D, M, B = 4, 16, 32, 3


def _loss_fn(params, batch):
    f, l = batch
    f, l = jnp.atleast_2d(f), jnp.atleast_1d(l)
    logits = f @ params["w"] + params["b"]
    return jnp.mean(jnp.log1p(jnp.exp(-(2 * l - 1) * logits)))


def _problem(seed=0):
    rng = np.random.default_rng(seed)
    w_true = rng.normal(size=D)
    f = rng.normal(size=(N, M, D)).astype(np.float32)
    l = (f @ w_true > 0).astype(np.float32)
    params0 = {"w": jnp.zeros(D), "b": jnp.zeros(())}
    return params0, minibatch_source(f, l, B)


def _spec(name, **kw):
    base = dict(algo=name, n_agents=N, topology="ring", compressor="top_k",
                frac=0.25, eta=0.1, tau=5.0, sigma_p=0.01)
    base.update(kw)
    return ExperimentSpec(**base)


def _per_step_loop(algo, source, state, key, steps, start=0):
    step = jax.jit(algo.step)
    traj = []
    for t in range(start, start + steps):
        kb, ks = jax.random.split(jax.random.fold_in(key, t))
        state, m = step(state, source(kb, jnp.asarray(t, jnp.int32)), ks)
        traj.append(m)
    return state, traj


# ---------------------------------------------------------------------------
# de-bias law
# ---------------------------------------------------------------------------

def test_debias_unit_weights_is_bit_identity():
    rng = np.random.default_rng(0)
    x = {"w": jnp.asarray(rng.normal(size=(N, 5)), jnp.float32),
         "b": jnp.asarray(rng.normal(size=(N,)), jnp.float32)}
    z = PS.debias(x, jnp.ones((N,), jnp.float32))
    for la, lb in zip(jax.tree_util.tree_leaves(x),
                      jax.tree_util.tree_leaves(z)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def test_debias_divides_per_agent_and_floors_zero():
    x = {"w": jnp.ones((3, 4), jnp.float32)}
    xw = jnp.asarray([2.0, 0.5, 0.0], jnp.float32)
    z = PS.debias(x, xw)["w"]
    np.testing.assert_allclose(np.asarray(z[0]), 0.5, rtol=1e-6)
    np.testing.assert_allclose(np.asarray(z[1]), 2.0, rtol=1e-6)
    # the zero weight is floored, not a division by zero
    assert np.all(np.isfinite(np.asarray(z[2])))


# ---------------------------------------------------------------------------
# registration + guards
# ---------------------------------------------------------------------------

def test_dp_csgp_registered_as_dp_decentralized():
    info = algorithm_info("dp-csgp")
    assert info.dp and info.decentralized and info.compressed


def test_directed_schedule_rejects_doubly_stochastic_algorithms():
    params0, _ = _problem()
    sched = "directed:one_way,rate=0.2,period=4"
    for name in ("porter-gc", "porter-dp", "beer"):
        with pytest.raises(ValueError, match="dp-csgp"):
            build(_spec(name, topology_schedule=sched), _loss_fn)
    algo = build(_spec("dp-csgp", topology_schedule=sched), _loss_fn)
    assert algo.schedule.is_directed
    state = algo.init(params0)
    assert state.xw.shape == (N,)
    np.testing.assert_array_equal(np.asarray(state.xw), np.ones(N))


def test_exchange_ps_rejects_mixer_without_weight_transport():
    class _NoPushMixer:
        time_varying = False
        wire_mode = "packed"

        def __call__(self, tree, t=None):
            return tree

    eng = CommRound(compressor=make_compressor("top_k", frac=0.25),
                    mixer=_NoPushMixer())
    y = {"w": jnp.ones((N, 8), jnp.float32)}
    q = jax.tree_util.tree_map(jnp.zeros_like, y)
    with pytest.raises(ValueError, match="weight-plane transport"):
        eng.exchange_ps(jax.random.PRNGKey(0), y, q,
                        jnp.ones((N,)), jnp.zeros((N,)))


# ---------------------------------------------------------------------------
# engine: weight recursion + byte accounting
# ---------------------------------------------------------------------------

def test_step_ps_weight_recursion_matches_numpy():
    """The exact-EF weight recursion composes to
    xw' = ((1-gamma) I + gamma W) xw -- pinned against plain numpy."""
    sched = MX.directed_churn_schedule(N, rate=0.3, period=4, skip=2, seed=0)
    spec = ExperimentSpec(algo="dp-csgp", n_agents=N, compressor="identity",
                          topology_schedule="directed:one_way", gamma=0.4,
                          tau=1.0)
    eng = build_engine(spec, schedule=sched)
    gamma = 0.4
    rng = np.random.default_rng(3)
    x = {"w": jnp.asarray(rng.normal(size=(N, 7)), jnp.float32)}
    q = jax.tree_util.tree_map(jnp.zeros_like, x)
    m = jax.tree_util.tree_map(jnp.zeros_like, x)
    v = jax.tree_util.tree_map(jnp.zeros_like, x)
    xw = jnp.asarray(rng.uniform(0.5, 1.5, N), jnp.float32)
    qw = jnp.zeros((N,), jnp.float32)
    mw = jnp.zeros((N,), jnp.float32)
    mass0 = float(jnp.sum(xw))

    # numpy mirror of the same EF recursion (identity compressor)
    nx, nq, nm = (np.asarray(x["w"], np.float64), np.zeros((N, 7)),
                  np.zeros((N, 7)))
    nxw, nqw, nmw = np.asarray(xw, np.float64), np.zeros(N), np.zeros(N)

    key = jax.random.PRNGKey(0)
    for t in range(6):
        tj = jnp.asarray(t, jnp.int32)
        x2, q2, m2, xw2, qw2, mw2 = eng.step_ps(
            key, x, q, m, v, xw, qw, mw, gamma, 0.0, t=tj)
        x, q, m, xw, qw, mw = x2, q2, m2, xw2, qw2, mw2

        w_t = sched.ws[t % sched.period]
        c = nx - nq
        nq = nq + c
        nm = nm + w_t @ c
        nx = nx + gamma * (nm - nq)
        cw = nxw - nqw
        nqw = nqw + cw
        nmw = nmw + w_t @ cw
        nxw = nxw + gamma * (nmw - nqw)

    np.testing.assert_allclose(np.asarray(x["w"]), nx, atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(xw), nxw, atol=1e-5, rtol=1e-5)
    # column stochasticity conserves the initial total weight mass exactly
    np.testing.assert_allclose(float(jnp.sum(xw)), mass0, atol=1e-4)
    assert np.all(np.asarray(xw) > 0)


def test_push_sum_wire_bytes_add_weight_plane():
    """push_sum=True adds 4 bytes per shipped buffer set -- identically on
    the measured and the model path (dense mode: n sets)."""
    spec = ExperimentSpec(algo="dp-csgp", n_agents=N, compressor="top_k",
                          frac=0.25, tau=1.0,
                          topology_schedule="directed:ring_skips,skip=2")
    eng = build_engine(spec)
    y = {"w": jnp.ones((N, 32), jnp.float32)}
    plain, plain_model = eng.wire_bytes(y), eng.wire_bytes_model(y)
    ps, ps_model = (eng.wire_bytes(y, push_sum=True),
                    eng.wire_bytes_model(y, push_sum=True))
    assert plain == plain_model and ps == ps_model
    assert ps - plain == 4.0 * N


# ---------------------------------------------------------------------------
# parity with porter-dp (the exact-reduction acceptance)
# ---------------------------------------------------------------------------

def test_dp_csgp_matches_porter_dp_on_doubly_stochastic_table():
    """Acceptance: with a symmetric doubly-stochastic W (period 1) the
    weight increments are identically zero, xw stays exactly 1, and
    dp-csgp reproduces porter-dp bit-for-bit (wire_bytes excepted: the
    push-sum round honestly accounts its weight plane)."""
    params0, source = _problem()
    ref = build(_spec("porter-dp"), _loss_fn)
    got = build(_spec("dp-csgp"), _loss_fn)
    assert got.gamma == ref.gamma
    ref_state, ref_traj = _per_step_loop(
        ref, source, ref.init(params0), jax.random.PRNGKey(7), 5)
    got_state, got_traj = _per_step_loop(
        got, source, got.init(params0), jax.random.PRNGKey(7), 5)
    # weight plane never moved (q_w inits to 1, so increments are 0)
    np.testing.assert_array_equal(np.asarray(got_state.xw), np.ones(N))
    np.testing.assert_array_equal(np.asarray(got_state.q_w), np.ones(N))
    for field in ("x", "v", "q_x", "q_v", "g_prev", "m_x", "m_v"):
        for rl, gl in zip(
                jax.tree_util.tree_leaves(getattr(ref_state, field)),
                jax.tree_util.tree_leaves(getattr(got_state, field))):
            np.testing.assert_array_equal(np.asarray(rl), np.asarray(gl),
                                          err_msg=field)
    for rm, gm in zip(ref_traj, got_traj):
        for k in rm:
            if k == "wire_bytes":
                assert float(gm[k]) > float(rm[k])  # + weight plane
                continue
            np.testing.assert_array_equal(np.asarray(rm[k]),
                                          np.asarray(gm[k]), err_msg=k)


def test_dp_csgp_directed_departs_from_unit_weights():
    """Anti-vacuity: on a genuinely one-way schedule the weight plane must
    actually move (else the parity test above proves nothing)."""
    params0, source = _problem()
    algo = build(_spec("dp-csgp",
                       topology_schedule="directed:one_way,rate=0.3,"
                                         "period=4,skip=2"), _loss_fn)
    state, _ = _per_step_loop(algo, source, algo.init(params0),
                              jax.random.PRNGKey(7), 6)
    xw = np.asarray(state.xw, np.float64)
    assert not np.allclose(xw, 1.0, atol=1e-6)
    np.testing.assert_allclose(xw.sum(), N, atol=1e-4)  # mass conserved
    assert np.all(xw > 0)


# ---------------------------------------------------------------------------
# chunked training + mid-period resume (runtime-facing contract)
# ---------------------------------------------------------------------------

def test_directed_churn_chunked_training_single_executable():
    params0, source = _problem()
    algo = build(_spec("dp-csgp", sigma_p=0.0,
                       topology_schedule="directed:one_way,rate=0.25,"
                                         "period=4"), _loss_fn)
    runner = make_runner(algo, source, 4)
    state = algo.init(params0)
    key = jax.random.PRNGKey(0)
    losses = []
    for start in (0, 4, 8):   # crosses the period boundary twice
        state, key, m = runner(state, key, start)
        losses.extend(np.asarray(m["loss"]).tolist())
    assert runner.cache_size() in (None, 1)
    assert np.all(np.isfinite(losses))
    assert losses[-1] < losses[0]
    assert int(state.step) == 12


def test_resume_mid_period_restores_weight_plane(tmp_path):
    """The checkpointed step counter AND the (n,) weight planes must both
    survive a restart: round t's W_t and the de-bias denominators continue
    exactly where the crashed run stopped."""
    from repro.launch.checkpoint import restore_state, save_state

    sched_str = "directed:one_way,rate=0.3,period=3,skip=2"  # 4 rounds: mid
    params0, source = _problem()
    spec = _spec("dp-csgp", sigma_p=0.0, topology_schedule=sched_str)
    algo = build(spec, _loss_fn)

    ref_state, _ = _per_step_loop(algo, source, algo.init(params0),
                                  jax.random.PRNGKey(7), 8)

    state, _, _ = make_runner(algo, source, 4)(
        algo.init(params0), jax.random.PRNGKey(7), 0)
    assert not np.allclose(np.asarray(state.xw), 1.0, atol=1e-6)
    save_state(tmp_path, state, step=4,
               extra={"topology_schedule": sched_str})

    algo2 = build(spec, _loss_fn)
    restored = restore_state(tmp_path, like=algo2.init(params0))
    assert int(restored.step) == 4      # 4 mod 3 = 1: mid-window
    np.testing.assert_array_equal(np.asarray(restored.xw),
                                  np.asarray(state.xw))
    np.testing.assert_array_equal(np.asarray(restored.q_w),
                                  np.asarray(state.q_w))
    state2, _, _ = make_runner(algo2, source, 4)(
        restored, jax.random.PRNGKey(7), 4)
    for rl, gl in zip(jax.tree_util.tree_leaves(ref_state),
                      jax.tree_util.tree_leaves(state2)):
        np.testing.assert_allclose(np.asarray(gl), np.asarray(rl),
                                   atol=1e-5, rtol=1e-5)


# ---------------------------------------------------------------------------
# executors on a real device mesh (subprocess: 8 host devices)
# ---------------------------------------------------------------------------

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import jax, jax.numpy as jnp, numpy as np
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.api import ExperimentSpec, build, build_engine
    from repro.core import mixing as MX

    n, d = 8, 24
    mesh = jax.make_mesh((n,), ("data",))
    specs = {"w": P("data", None)}
    sh = NamedSharding(mesh, specs["w"])
    rng = np.random.default_rng(0)
    gamma = 0.4

    def np_push_sum(w, x0, xw0, rounds):
        # numpy mirror of the exact-EF push-sum recursion (identity
        # compressor): q += c; m += W c; x += gamma (m - q), same for xw
        x, q, m = x0.copy(), np.zeros_like(x0), np.zeros_like(x0)
        xw, qw, mw = xw0.copy(), np.zeros(n), np.zeros(n)
        for _ in range(rounds):
            c = x - q;   q = q + c;   m = m + w @ c
            x = x + gamma * (m - q)
            cw = xw - qw; qw = qw + cw; mw = mw + w @ cw
            xw = xw + gamma * (mw - qw)
        return x, xw

    x0 = rng.normal(size=(n, d)).astype(np.float32)
    xw0 = rng.uniform(0.5, 1.5, n).astype(np.float32)

    # acceptance: dense and ring push-sum executors vs the numpy
    # reference on static directed graphs, atol 1e-5.  skip=3 chords are
    # genuinely column-only stochastic (dense/packed executors); the
    # skip-0 directed ring is the circulant band the ppermute ring
    # executor supports.
    cases = (("dense", "directed:ring_skips,skip=3", "dense-ps-ok"),
             ("ring", "directed:ring_skips", "ring-ps-ok"))
    for mode, sched_str, marker in cases:
        spec = ExperimentSpec(algo="dp-csgp", n_agents=n,
                              compressor="identity", tau=1.0, gamma=gamma,
                              topology_schedule=sched_str, gossip_mode=mode)
        eng = build_engine(spec, mesh=mesh, leaf_specs=specs)
        sched = MX.directed_ring_schedule(
            n, skip=3 if "skip=3" in sched_str else 0)
        x = {"w": jax.device_put(jnp.asarray(x0), sh)}
        q = jax.tree_util.tree_map(jnp.zeros_like, x)
        m = jax.tree_util.tree_map(jnp.zeros_like, x)
        v = jax.tree_util.tree_map(jnp.zeros_like, x)
        xw = jnp.asarray(xw0)
        qw = jnp.zeros((n,), jnp.float32)
        mw = jnp.zeros((n,), jnp.float32)

        step = jax.jit(lambda k, x, q, m, v, xw, qw, mw, t, e=eng:
                       e.step_ps(k, x, q, m, v, xw, qw, mw, gamma, 0.0,
                                 t=t))
        key = jax.random.PRNGKey(0)
        for t in range(6):
            x, q, m, xw, qw, mw = step(key, x, q, m, v, xw, qw, mw,
                                       jnp.asarray(t, jnp.int32))
        want_x, want_xw = np_push_sum(sched.ws[0], x0.astype(np.float64),
                                      xw0.astype(np.float64), 6)
        np.testing.assert_allclose(np.asarray(x["w"]), want_x, atol=1e-5,
                                   rtol=1e-5)
        np.testing.assert_allclose(np.asarray(xw), want_xw, atol=1e-5,
                                   rtol=1e-5)
        np.testing.assert_allclose(float(jnp.sum(xw)), float(xw0.sum()),
                                   atol=1e-4)
        print(marker)

    # codec executor: the weight increment travels EXACTLY (bit-exact
    # f32 words on the wire), and its mix follows the round's band weights
    from repro.core import wire_formats as WF
    dd = 2 * WF.PACK_BLOCK
    spec_c = ExperimentSpec(algo="dp-csgp", n_agents=n,
                            compressor="block_top_k", frac=0.25, tau=1.0,
                            gamma=gamma, gossip_mode="ring",
                            wire="packed_bits",
                            topology_schedule="directed:ring_skips",
                            comm_backend="ref", interpret=True)
    eng_c = build_engine(spec_c, mesh=mesh, leaf_specs=specs)
    sched0 = MX.directed_ring_schedule(n, skip=0)
    y = {"w": jax.device_put(
        jnp.asarray(rng.normal(size=(n, dd)).astype(np.float32)), sh)}
    qz = jax.tree_util.tree_map(jnp.zeros_like, y)
    yw = jnp.asarray(rng.uniform(0.5, 1.5, n).astype(np.float32))
    qw = jnp.zeros((n,), jnp.float32)
    c, wc, cw, wcw = jax.jit(
        lambda k, a, b, e, f: eng_c.exchange_ps(
            k, a, b, e, f, t=jnp.asarray(0, jnp.int32)))(
        jax.random.PRNGKey(1), y, qz, yw, qw)
    np.testing.assert_array_equal(np.asarray(cw), np.asarray(yw))  # exact
    np.testing.assert_allclose(np.asarray(wcw),
                               sched0.ws[0] @ np.asarray(yw, np.float64),
                               atol=1e-5, rtol=1e-5)
    print("codec-ps-ok")

    # the weight plane adds no collectives: dp-csgp's lowered step has
    # exactly porter-dp's per-category collective counts on the same spec
    from repro.analysis.hlo import collective_counts
    params0 = {"w": jnp.zeros(dd)}

    def loss(p, b):
        return jnp.mean((p["w"] - b) ** 2)

    counts = {}
    for name in ("porter-dp", "dp-csgp"):
        spec_h = ExperimentSpec(algo=name, n_agents=n, topology="ring",
                                topology_weights="metropolis",
                                compressor="block_top_k", frac=0.25,
                                gossip_mode="ring", wire="packed_bits",
                                comm_backend="ref", interpret=True,
                                eta=0.1, tau=5.0, sigma_p=0.01)
        algo = build(spec_h, loss, mesh=mesh, agent_axes=("data",),
                     leaf_specs=specs)
        state = algo.init(params0, n_agents=n)
        batch = jnp.zeros((n, 1, dd))
        hlo = (jax.jit(algo.step)
               .lower(state, batch, jax.random.PRNGKey(0))
               .compile().as_text())
        counts[name] = collective_counts(hlo)
    assert counts["porter-dp"] == counts["dp-csgp"], counts
    assert sum(counts["dp-csgp"].values()) > 0, counts
    print("hlo-ps-ok")
""")


def test_push_sum_executors_and_hlo():
    res = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, timeout=600,
                         env={**__import__("os").environ,
                              "PYTHONPATH": "src"})
    assert res.returncode == 0, res.stderr[-3000:]
    for marker in ("dense-ps-ok", "ring-ps-ok", "codec-ps-ok", "hlo-ps-ok"):
        assert marker in res.stdout, (marker, res.stdout, res.stderr[-2000:])
