"""Decode-vs-forward teacher-forcing consistency for ALL 10 architectures:
the decode_step logits at position t (from a prefilled cache) must match the
full forward pass logits at t.  This pins every cache format: GQA full,
MLA latent, windowed SWA (disabled here for exactness), RWKV/Mamba recurrent
states, hybrid shared-attn groups, enc-dec self+cross."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_smoke
from repro.models import build_model

B = 2
KEY = jax.random.PRNGKey(11)


def _grow_time_axis(cache, old_len):
    """Pad every (…, old_len, …) time axis by one slot for the decode write."""
    def grow(leaf):
        if leaf.ndim >= 3 and leaf.shape[2] == old_len:
            pad = [(0, 0)] * leaf.ndim
            pad[2] = (0, 1)
            return jnp.pad(leaf, pad)
        return leaf
    return jax.tree_util.tree_map(grow, cache)


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward_all_archs(arch):
    cfg = get_smoke(arch)
    # dropless MoE for this test: capacity drops are batch-composition
    # dependent (a 30-token prefill drops tokens a 1-token decode keeps),
    # which is routing behaviour, not cache state -- remove it so the test
    # isolates cache correctness.
    cfg = dataclasses.replace(cfg, dtype=jnp.float32, window=None,
                              capacity_factor=4.0)
    bundle = build_model(cfg)
    params, _ = bundle.init(KEY)
    s = 32 if cfg.family in ("rwkv6", "hybrid") else 16  # ssd chunk limits

    if cfg.family == "vlm":
        text = jax.random.randint(KEY, (B, s), 0, cfg.vocab)
        patches = jax.random.normal(KEY, (B, cfg.n_prefix, cfg.frontend_dim))
        batch = {"tokens": text, "patches": patches}
        full = bundle.forward(params, batch)             # (B, prefix+s, V)
        pre = {"tokens": text[:, : s - 1], "patches": patches}
        _, cache = bundle.prefill(params, pre)
        cache = _grow_time_axis(cache, cfg.n_prefix + s - 1)
        pos = jnp.asarray(cfg.n_prefix + s - 1, jnp.int32)
        logits_d, _ = bundle.decode_step(params, cache, text[:, s - 1:s],
                                         pos)
    elif cfg.family == "encdec":
        frames = jax.random.normal(KEY, (B, 8, cfg.frontend_dim))
        tokens = jax.random.randint(KEY, (B, s), 0, cfg.vocab)
        batch = {"frames": frames, "tokens": tokens}
        full = bundle.forward(params, batch)
        pre = {"frames": frames, "tokens": tokens[:, : s - 1]}
        _, cache = bundle.prefill(params, pre)
        # grow only the self cache (cross cache length = enc length)
        def grow(path, leaf):
            keys = [str(getattr(p, "key", "")) for p in path]
            if "self" in keys and leaf.ndim >= 3 and leaf.shape[2] == s - 1:
                pad = [(0, 0)] * leaf.ndim
                pad[2] = (0, 1)
                return jnp.pad(leaf, pad)
            return leaf
        cache = jax.tree_util.tree_map_with_path(grow, cache)
        logits_d, _ = bundle.decode_step(params, cache, tokens[:, s - 1:s],
                                         jnp.asarray(s - 1, jnp.int32))
    else:
        tokens = jax.random.randint(KEY, (B, s), 0, cfg.vocab)
        batch = {"tokens": tokens}
        full = bundle.forward(params, batch)
        _, cache = bundle.prefill(params, {"tokens": tokens[:, : s - 1]})
        if cfg.family in ("dense", "moe", "hybrid"):
            cache = _grow_time_axis(cache, s - 1)
        logits_d, _ = bundle.decode_step(params, cache, tokens[:, s - 1:s],
                                         jnp.asarray(s - 1, jnp.int32))

    tol = 2e-3
    np.testing.assert_allclose(np.asarray(logits_d),
                               np.asarray(full[:, -1]), rtol=tol, atol=tol)
